"""AOT pipeline tests: every artifact lowers to parseable HLO text with the
expected parameter shapes, and the manifest is consistent."""

import json
import os
import re

import pytest

from compile import aot


@pytest.fixture(scope="module")
def entries():
    return aot.build_entries()


def test_entry_inventory(entries):
    names = [e[0] for e in entries]
    assert len(names) == len(set(names)), "duplicate artifact names"
    # 6 functions x len(DIMS) configs
    assert len(names) == 6 * len(aot.DIMS)
    for d in aot.DIMS:
        assert f"alsh_data_d{d}_m{aot.M_TERMS}_k{aot.K_HASHES}" in names
        assert f"alsh_query_d{d}_m{aot.M_TERMS}_k{aot.K_HASHES}" in names
        assert f"l2lsh_d{d}_k{aot.K_HASHES}" in names
        assert f"sign_alsh_data_d{d}_m{aot.SIGN_M}_k{aot.K_HASHES}" in names
        assert f"sign_alsh_query_d{d}_m{aot.SIGN_M}_k{aot.K_HASHES}" in names
        assert f"rerank_d{d}_m{aot.RERANK_M}" in names


def test_smallest_artifact_lowers_to_hlo_text(entries):
    import jax

    # Only lower the d=8 configs in tests (the big ones are exercised by
    # `make artifacts`); keep the test suite fast.
    small = [e for e in entries if e[3]["dim"] == min(aot.DIMS)]
    assert len(small) == 6
    for name, fn, args, meta in small:
        text = aot.to_hlo_text(jax.jit(fn).lower(*args))
        assert "HloModule" in text
        # f32 / s32 params must appear with the right leading batch dim
        assert f"f32[{aot.BATCH}," in text
        if meta["function"] != "rerank":
            assert "s32" in text, f"{name}: expected int32 output"


def test_manifest_written(tmp_path, monkeypatch, entries):
    # Run main() against a temp dir but with a single small dim to stay fast.
    monkeypatch.setattr(aot, "DIMS", (8,))
    monkeypatch.setattr(
        "sys.argv", ["aot.py", "--out-dir", str(tmp_path)]
    )
    aot.main()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["batch"] == aot.BATCH
    assert len(manifest["artifacts"]) == 6
    for art in manifest["artifacts"]:
        p = tmp_path / art["file"]
        assert p.exists() and p.stat().st_size > 0
        text = p.read_text()
        assert text.lstrip().startswith("HloModule")
        assert art["name"] == art["file"].replace(".hlo.txt", "")
        assert all(isinstance(s, list) for s in art["arg_shapes"])

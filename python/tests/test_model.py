"""Layer-2 model tests: transforms, the Eq. 17 identity, end-to-end codes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _unit_rows(key, n, d):
    q = jax.random.normal(key, (n, d), dtype=jnp.float32)
    return q / jnp.linalg.norm(q, axis=-1, keepdims=True)


def _bounded_rows(key, n, d, u=0.83):
    x = jax.random.normal(key, (n, d), dtype=jnp.float32)
    norms = jnp.linalg.norm(x, axis=-1, keepdims=True)
    # random norms in (0, u]
    target = u * jax.random.uniform(
        jax.random.fold_in(key, 1), (n, 1), minval=0.05, maxval=1.0
    )
    return x / norms * target


def test_p_transform_shape_and_tail():
    x = _bounded_rows(jax.random.PRNGKey(0), 5, 10)
    m = 3
    px = np.asarray(model.p_transform(x, m))
    assert px.shape == (5, 13)
    n2 = np.sum(np.asarray(x) ** 2, axis=-1)
    np.testing.assert_allclose(px[:, 10], n2, rtol=1e-5)
    np.testing.assert_allclose(px[:, 11], n2**2, rtol=1e-5)
    np.testing.assert_allclose(px[:, 12], n2**4, rtol=1e-4)


def test_q_transform_normalizes_and_pads_halves():
    q = 3.7 * _unit_rows(jax.random.PRNGKey(1), 4, 8)
    m = 4
    qq = np.asarray(model.q_transform(q, m))
    assert qq.shape == (4, 12)
    np.testing.assert_allclose(np.linalg.norm(qq[:, :8], axis=-1), 1.0, rtol=1e-5)
    np.testing.assert_allclose(qq[:, 8:], 0.5)


def test_q_transform_zero_query_is_safe():
    q = jnp.zeros((2, 6), dtype=jnp.float32)
    qq = np.asarray(model.q_transform(q, 3))
    assert np.all(np.isfinite(qq))


def test_eq17_key_identity():
    """||Q(q) - P(x)||^2 == (1 + m/4) - 2 q^T x + ||x||^(2^(m+1))  (Eq. 17)."""
    key = jax.random.PRNGKey(2)
    m = 3
    q = _unit_rows(key, 1, 12)
    x = _bounded_rows(jax.random.fold_in(key, 7), 1, 12, u=0.83)
    pq = np.asarray(model.q_transform(q, m))[0].astype(np.float64)
    px = np.asarray(model.p_transform(x, m))[0].astype(np.float64)
    lhs = np.sum((pq - px) ** 2)
    nx = np.linalg.norm(np.asarray(x)[0].astype(np.float64))
    qx = float(np.asarray(q)[0].astype(np.float64) @ np.asarray(x)[0].astype(np.float64))
    rhs = (1 + m / 4) - 2 * qx + nx ** (2 ** (m + 1))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 5),
    d=st.integers(2, 32),
    seed=st.integers(0, 2**31 - 1),
    u=st.sampled_from([0.5, 0.75, 0.83, 0.95]),
)
def test_eq17_identity_hypothesis(m, d, seed, u):
    key = jax.random.PRNGKey(seed)
    q = _unit_rows(key, 1, d)
    x = _bounded_rows(jax.random.fold_in(key, 13), 1, d, u=u)
    pq = np.asarray(model.q_transform(q, m))[0].astype(np.float64)
    px = np.asarray(model.p_transform(x, m))[0].astype(np.float64)
    lhs = np.sum((pq - px) ** 2)
    nx = np.linalg.norm(np.asarray(x)[0].astype(np.float64))
    qx = float(
        np.asarray(q)[0].astype(np.float64) @ np.asarray(x)[0].astype(np.float64)
    )
    rhs = (1 + m / 4) - 2 * qx + nx ** (2 ** (m + 1))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-6)


def test_distance_rank_correlates_with_inner_product():
    """The reduction's point: argmax q.x == argmin ||Q(q)-P(x)|| for small eps."""
    key = jax.random.PRNGKey(5)
    m = 3
    q = _unit_rows(key, 1, 16)
    x = _bounded_rows(jax.random.fold_in(key, 3), 200, 16, u=0.83)
    ips = np.asarray(x @ q[0])
    pq = np.asarray(model.q_transform(q, m))[0]
    px = np.asarray(model.p_transform(x, m))
    d2 = np.sum((px - pq) ** 2, axis=-1)
    assert np.argmax(ips) == np.argmin(d2)


def test_alsh_data_codes_match_ref():
    key = jax.random.PRNGKey(6)
    m, d, k = 3, 20, 64
    x = _bounded_rows(key, 33, d)
    a = jax.random.normal(jax.random.fold_in(key, 1), (d + m, k), jnp.float32)
    b = jax.random.uniform(jax.random.fold_in(key, 2), (k,), jnp.float32)
    got = np.asarray(model.alsh_data_codes(x, a, b, m=m))
    want = np.asarray(ref.alsh_data_codes_ref(x, a, b, m))
    np.testing.assert_array_equal(got, want)


def test_alsh_query_codes_match_ref():
    key = jax.random.PRNGKey(7)
    m, d, k = 3, 20, 64
    q = 2.5 * _unit_rows(key, 17, d)
    a = jax.random.normal(jax.random.fold_in(key, 1), (d + m, k), jnp.float32)
    b = jax.random.uniform(jax.random.fold_in(key, 2), (k,), jnp.float32)
    got = np.asarray(model.alsh_query_codes(q, a, b, m=m))
    want = np.asarray(ref.alsh_query_codes_ref(q, a, b, m))
    np.testing.assert_array_equal(got, want)


def test_l2lsh_codes_match_ref():
    key = jax.random.PRNGKey(8)
    d, k = 20, 96
    x = jax.random.normal(key, (21, d), jnp.float32)
    a = jax.random.normal(jax.random.fold_in(key, 1), (d, k), jnp.float32)
    b = jax.random.uniform(jax.random.fold_in(key, 2), (k,), jnp.float32)
    got = np.asarray(model.l2lsh_codes(x, a, b))
    want = np.asarray(ref.hash_codes_ref(x, a, b))
    np.testing.assert_array_equal(got, want)


def test_asymmetry_is_real():
    """hash(P(x)) != hash(Q(x)) in general — the asymmetry that fixes MIPS."""
    key = jax.random.PRNGKey(9)
    m, d, k = 3, 16, 128
    x = _bounded_rows(key, 8, d)
    a = jax.random.normal(jax.random.fold_in(key, 1), (d + m, k), jnp.float32)
    b = jax.random.uniform(jax.random.fold_in(key, 2), (k,), jnp.float32)
    data = np.asarray(model.alsh_data_codes(x, a, b, m=m))
    query = np.asarray(model.alsh_query_codes(x, a, b, m=m))
    assert (data != query).any()

"""Pallas hash kernel vs pure-jnp oracle: the core L1 correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.hash_kernel import hash_codes
from compile.kernels.ref import hash_codes_ref


def _rand(key, *shape, scale=1.0):
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


def _check(n, d, k, seed=0, scale=1.0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(ks[0], n, d, scale=scale)
    a = _rand(ks[1], d, k)
    b = jax.random.uniform(ks[2], (k,), dtype=jnp.float32)
    got = hash_codes(x, a, b)
    want = hash_codes_ref(x, a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == jnp.int32


def test_exact_tile_shapes():
    _check(32, 64, 128)


def test_multi_tile_grid():
    _check(64, 16, 512)


def test_unaligned_batch():
    _check(7, 10, 64)


def test_unaligned_hashes():
    _check(16, 10, 33)


def test_unaligned_everything():
    _check(5, 3, 7)


def test_single_row_single_hash():
    _check(1, 1, 1)


def test_large_scale_values():
    # Large magnitudes exercise floor() far from zero.
    _check(16, 8, 16, scale=100.0)


def test_negative_codes_present():
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = _rand(ks[0], 16, 8, scale=10.0)
    a = _rand(ks[1], 8, 32)
    b = jnp.zeros((32,), dtype=jnp.float32)
    got = np.asarray(hash_codes(x, a, b))
    assert (got < 0).any(), "expected some negative hash codes"


def test_custom_block_sizes():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    x = _rand(ks[0], 48, 12)
    a = _rand(ks[1], 12, 80)
    b = jax.random.uniform(ks[2], (80,), dtype=jnp.float32)
    got = hash_codes(x, a, b, bm=16, bk=32)
    want = hash_codes_ref(x, a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_rejects_bad_shapes():
    x = jnp.zeros((4, 5))
    a = jnp.zeros((6, 7))  # mismatched reduction dim
    b = jnp.zeros((7,))
    with pytest.raises(ValueError):
        hash_codes(x, a, b)
    with pytest.raises(ValueError):
        hash_codes(x[0], a, b)  # bad rank


def test_zero_input_gives_floor_of_b():
    x = jnp.zeros((4, 6), dtype=jnp.float32)
    a = jnp.ones((6, 9), dtype=jnp.float32)
    b = jnp.array([0.0, 0.5, 0.99, 1.0, 1.5, -0.5, -1.0, 2.7, -2.7], jnp.float32)
    got = np.asarray(hash_codes(x, a, b))
    want = np.floor(np.asarray(b)).astype(np.int32)
    for row in got:
        np.testing.assert_array_equal(row, want)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 70),
    d=st.integers(1, 40),
    k=st.integers(1, 140),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
)
def test_hypothesis_shape_sweep(n, d, k, seed, scale):
    _check(n, d, k, seed=seed, scale=scale)

"""Sign (SRP) Pallas kernel + Sign-ALSH model vs pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.sign_kernel import sign_codes
from compile.kernels import ref


def _check(n, d, k, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(ks[0], (n, d), dtype=jnp.float32)
    a = jax.random.normal(ks[1], (d, k), dtype=jnp.float32)
    got = np.asarray(sign_codes(x, a))
    want = np.asarray(ref.sign_codes_ref(x, a))
    np.testing.assert_array_equal(got, want)
    assert set(np.unique(got)).issubset({0, 1})


def test_exact_tiles():
    _check(32, 16, 128)


def test_unaligned():
    _check(9, 5, 33)


def test_single():
    _check(1, 1, 1)


def test_rejects_mismatch():
    with pytest.raises(ValueError):
        sign_codes(jnp.zeros((2, 3)), jnp.zeros((4, 5)))


def test_collision_prob_matches_angle():
    # SimHash property: P(collision) = 1 - theta/pi.
    key = jax.random.PRNGKey(1)
    d, k = 16, 8192
    x = jax.random.normal(key, (1, d), dtype=jnp.float32)
    y = x + 0.5 * jax.random.normal(jax.random.fold_in(key, 1), (1, d), jnp.float32)
    a = jax.random.normal(jax.random.fold_in(key, 2), (d, k), jnp.float32)
    cx = np.asarray(sign_codes(x, a))[0]
    cy = np.asarray(sign_codes(y, a))[0]
    frac = (cx == cy).mean()
    cos = float(
        (x @ y.T)[0, 0]
        / (jnp.linalg.norm(x) * jnp.linalg.norm(y))
    )
    theta = np.arccos(np.clip(cos, -1, 1))
    assert abs(frac - (1 - theta / np.pi)) < 0.02


def test_sign_transforms_shapes():
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(2), (4, 10), jnp.float32)
    px = np.asarray(model.p_transform_sign(x, 2))
    qx = np.asarray(model.q_transform_sign(x, 2))
    assert px.shape == (4, 12) and qx.shape == (4, 12)
    n2 = np.sum(np.asarray(x) ** 2, axis=-1)
    np.testing.assert_allclose(px[:, 10], 0.5 - n2, rtol=1e-5)
    np.testing.assert_allclose(px[:, 11], 0.5 - n2**2, rtol=1e-5)
    np.testing.assert_allclose(qx[:, 10:], 0.0)


def test_sign_alsh_codes_match_ref():
    key = jax.random.PRNGKey(3)
    m, d, k = 2, 12, 64
    x = 0.6 * jax.random.normal(key, (7, d), jnp.float32)
    a = jax.random.normal(jax.random.fold_in(key, 1), (d + m, k), jnp.float32)
    got_d = np.asarray(model.sign_alsh_data_codes(x, a, m=m))
    want_d = np.asarray(
        ref.sign_codes_ref(ref.p_transform_sign_ref(x, m), a)
    )
    np.testing.assert_array_equal(got_d, want_d)
    got_q = np.asarray(model.sign_alsh_query_codes(x, a, m=m))
    want_q = np.asarray(
        ref.sign_codes_ref(ref.q_transform_sign_ref(x, m), a)
    )
    np.testing.assert_array_equal(got_q, want_q)


def test_sign_alsh_collisions_increase_with_inner_product():
    # The Sign-ALSH property: collision fraction is monotone-ish in q.x.
    key = jax.random.PRNGKey(4)
    m, d, k = 2, 16, 4096
    q = jax.random.normal(key, (1, d), jnp.float32)
    a = jax.random.normal(jax.random.fold_in(key, 1), (d + m, k), jnp.float32)
    qc = np.asarray(model.sign_alsh_query_codes(q, a, m=m))[0]
    qn = np.asarray(q)[0] / np.linalg.norm(np.asarray(q)[0])
    fracs = []
    ips = []
    for scale in [0.1, 0.4, 0.7]:
        # x aligned with q at increasing norm => increasing q.x
        x = jnp.asarray(scale * qn)[None, :]
        xc = np.asarray(model.sign_alsh_data_codes(x, a, m=m))[0]
        fracs.append((qc == xc).mean())
        ips.append(scale)
    assert fracs[0] < fracs[1] < fracs[2], f"{fracs}"


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 40),
    d=st.integers(1, 32),
    k=st.integers(1, 100),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shape_sweep(n, d, k, seed):
    _check(n, d, k, seed=seed)

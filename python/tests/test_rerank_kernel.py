"""Pallas rerank kernel vs pure-jnp matmul oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.rerank_kernel import rerank_scores
from compile.kernels.ref import rerank_scores_ref


def _check(n, d, m, seed=0, rtol=1e-5, atol=1e-5, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    q = jax.random.normal(ks[0], (n, d), dtype=dtype)
    c_t = jax.random.normal(ks[1], (d, m), dtype=dtype)
    got = rerank_scores(q, c_t)
    want = rerank_scores_ref(q, c_t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol, atol=atol)
    assert got.dtype == jnp.float32


def test_exact_tiles():
    _check(32, 64, 128)


def test_multi_tile():
    _check(64, 32, 512)


def test_unaligned():
    _check(13, 7, 101)


def test_single():
    _check(1, 1, 1)


def test_bf16_inputs_accumulate_f32():
    # bf16 inputs should still produce f32 output within bf16 tolerance.
    _check(16, 32, 64, dtype=jnp.bfloat16, rtol=3e-2, atol=3e-2)


def test_rejects_mismatch():
    with pytest.raises(ValueError):
        rerank_scores(jnp.zeros((4, 5)), jnp.zeros((6, 7)))


def test_identity_candidates():
    q = jax.random.normal(jax.random.PRNGKey(1), (8, 16), dtype=jnp.float32)
    got = np.asarray(rerank_scores(q, jnp.eye(16, dtype=jnp.float32)))
    np.testing.assert_allclose(got, np.asarray(q), rtol=1e-6, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 70),
    d=st.integers(1, 48),
    m=st.integers(1, 150),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shape_sweep(n, d, m, seed):
    _check(n, d, m, seed=seed)

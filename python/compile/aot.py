"""AOT compile path: lower the Layer-2 functions to HLO *text* artifacts.

Run once via ``make artifacts``. Emits, for each canonical shape config:

    artifacts/<name>.hlo.txt       — HLO text, loadable by the xla crate's
                                     HloModuleProto::from_text_file
    artifacts/manifest.json        — shape registry consumed by
                                     rust/src/runtime/registry.rs

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly. Lowered with return_tuple=True; the Rust side unwraps
with to_tuple1(). See /opt/xla-example/gen_hlo.py.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Canonical artifact shapes. The Rust batcher pads query batches to BATCH and
# candidate sets to RERANK_M; D covers the dataset configs used by the paper
# experiments (f=150 Movielens, f=300 Netflix) plus a small dim for examples.
BATCH = 64
K_HASHES = 512
RERANK_M = 1024
DIMS = (8, 50, 150, 300)
M_TERMS = 3  # paper's recommended m
SIGN_M = 2  # Sign-ALSH extension's recommended m (follow-up paper)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def build_entries():
    """(name, fn, example_args, meta) for every artifact we ship."""
    entries = []
    for d in DIMS:
        dp = d + M_TERMS
        entries.append(
            (
                f"alsh_data_d{d}_m{M_TERMS}_k{K_HASHES}",
                functools.partial(model.alsh_data_codes, m=M_TERMS),
                (f32(BATCH, d), f32(dp, K_HASHES), f32(K_HASHES)),
                {
                    "function": "alsh_data",
                    "dim": d,
                    "m": M_TERMS,
                    "k": K_HASHES,
                    "batch": BATCH,
                },
            )
        )
        entries.append(
            (
                f"alsh_query_d{d}_m{M_TERMS}_k{K_HASHES}",
                functools.partial(model.alsh_query_codes, m=M_TERMS),
                (f32(BATCH, d), f32(dp, K_HASHES), f32(K_HASHES)),
                {
                    "function": "alsh_query",
                    "dim": d,
                    "m": M_TERMS,
                    "k": K_HASHES,
                    "batch": BATCH,
                },
            )
        )
        entries.append(
            (
                f"l2lsh_d{d}_k{K_HASHES}",
                model.l2lsh_codes,
                (f32(BATCH, d), f32(d, K_HASHES), f32(K_HASHES)),
                {
                    "function": "l2lsh",
                    "dim": d,
                    "m": 0,
                    "k": K_HASHES,
                    "batch": BATCH,
                },
            )
        )
        dps = d + SIGN_M
        entries.append(
            (
                f"sign_alsh_data_d{d}_m{SIGN_M}_k{K_HASHES}",
                functools.partial(model.sign_alsh_data_codes, m=SIGN_M),
                (f32(BATCH, d), f32(dps, K_HASHES)),
                {
                    "function": "sign_alsh_data",
                    "dim": d,
                    "m": SIGN_M,
                    "k": K_HASHES,
                    "batch": BATCH,
                },
            )
        )
        entries.append(
            (
                f"sign_alsh_query_d{d}_m{SIGN_M}_k{K_HASHES}",
                functools.partial(model.sign_alsh_query_codes, m=SIGN_M),
                (f32(BATCH, d), f32(dps, K_HASHES)),
                {
                    "function": "sign_alsh_query",
                    "dim": d,
                    "m": SIGN_M,
                    "k": K_HASHES,
                    "batch": BATCH,
                },
            )
        )
        entries.append(
            (
                f"rerank_d{d}_m{RERANK_M}",
                model.rerank,
                (f32(BATCH, d), f32(d, RERANK_M)),
                {
                    "function": "rerank",
                    "dim": d,
                    "m": 0,
                    "k": RERANK_M,
                    "batch": BATCH,
                },
            )
        )
    return entries


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"batch": BATCH, "artifacts": []}
    for name, fn, example_args, meta in build_entries():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta = dict(meta)
        meta["name"] = name
        meta["file"] = f"{name}.hlo.txt"
        meta["arg_shapes"] = [list(a.shape) for a in example_args]
        manifest["artifacts"].append(meta)
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out_dir}/manifest.json ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()

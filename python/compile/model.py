"""Layer-2: the ALSH pipeline as JAX computations calling the L1 kernels.

Four build-time functions get AOT-lowered to HLO text (see aot.py) and
executed from the Rust coordinator via PJRT:

  * ``alsh_data_codes(x, a, b)``  — P-transform (Eq. 12) + L2LSH hash.
  * ``alsh_query_codes(q, a, b)`` — Q-transform (Eq. 13) + L2LSH hash.
  * ``l2lsh_codes(x, a, b)``      — plain L2LSH (the paper's baseline).
  * ``rerank(q, c_t)``            — exact inner products for re-ranking.

All randomness (projection matrix ``a``, offsets ``b``) and all data-
dependent scaling (the U/max-norm shrink of Eq. 11, the 1/r pre-scale) are
inputs supplied by Rust at runtime: the artifacts bake in nothing but shapes
and the structural parameter m.

The P/Q transforms are implemented here (not in the kernel) so XLA fuses
the norm computation + concat into the projection matmul; the Pallas kernel
only sees the transformed [B, D+m] batch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels.hash_kernel import hash_codes
from compile.kernels.rerank_kernel import rerank_scores
from compile.kernels.sign_kernel import sign_codes


def p_transform(x: jax.Array, m: int) -> jax.Array:
    """P(x) = [x; ||x||^2; ||x||^4; ...; ||x||^(2^m)]  (Eq. 12).

    Norm powers are built by iterative squaring: ||x||^(2^(i+1)) =
    (||x||^(2^i))^2 — one multiply per extra component, no pow() calls.
    """
    cols = [x]
    n = jnp.sum(x * x, axis=-1, keepdims=True)
    for _ in range(m):
        cols.append(n)
        n = n * n
    return jnp.concatenate(cols, axis=-1)


def q_transform(q: jax.Array, m: int) -> jax.Array:
    """Q(q) = [q/||q||; 1/2; ...; 1/2]  (Eq. 13), with WLOG normalization."""
    norm = jnp.sqrt(jnp.sum(q * q, axis=-1, keepdims=True))
    qn = q / jnp.maximum(norm, 1e-12)
    half = jnp.full(q.shape[:-1] + (m,), 0.5, dtype=q.dtype)
    return jnp.concatenate([qn, half], axis=-1)


@functools.partial(jax.jit, static_argnames=("m",))
def alsh_data_codes(x: jax.Array, a: jax.Array, b: jax.Array, *, m: int = 3):
    """Data-side ALSH codes: hash_codes(P(x), a, b).

    x: [B, D] pre-scaled item vectors (||x|| <= U enforced by caller).
    a: [D + m, K] projection matrix, pre-divided by r.
    b: [K] offsets, pre-divided by r.
    returns [B, K] int32.
    """
    return hash_codes(p_transform(x, m), a, b)


@functools.partial(jax.jit, static_argnames=("m",))
def alsh_query_codes(q: jax.Array, a: jax.Array, b: jax.Array, *, m: int = 3):
    """Query-side ALSH codes: hash_codes(Q(q), a, b)."""
    return hash_codes(q_transform(q, m), a, b)


@jax.jit
def l2lsh_codes(x: jax.Array, a: jax.Array, b: jax.Array):
    """Plain (symmetric) L2LSH codes — the paper's baseline hash function."""
    return hash_codes(x, a, b)


@jax.jit
def rerank(q: jax.Array, c_t: jax.Array):
    """Exact inner products q @ c_t for candidate re-ranking."""
    return rerank_scores(q, c_t)


def p_transform_sign(x: jax.Array, m: int) -> jax.Array:
    """Sign-ALSH data transform: [x; 1/2 - ||x||^2; ...; 1/2 - ||x||^(2^m)].

    With ||x|| <= U < 1 this makes sign(aᵀP(x)) vs sign(aᵀQ(q)) collisions
    monotone in qᵀx (Shrivastava & Li 2015, "Improved ALSH for MIPS").
    """
    cols = [x]
    n = jnp.sum(x * x, axis=-1, keepdims=True)
    for _ in range(m):
        cols.append(0.5 - n)
        n = n * n
    return jnp.concatenate(cols, axis=-1)


def q_transform_sign(q: jax.Array, m: int) -> jax.Array:
    """Sign-ALSH query transform: [q/||q||; 0; ...; 0]."""
    norm = jnp.sqrt(jnp.sum(q * q, axis=-1, keepdims=True))
    qn = q / jnp.maximum(norm, 1e-12)
    zeros = jnp.zeros(q.shape[:-1] + (m,), dtype=q.dtype)
    return jnp.concatenate([qn, zeros], axis=-1)


@functools.partial(jax.jit, static_argnames=("m",))
def sign_alsh_data_codes(x: jax.Array, a: jax.Array, *, m: int = 2):
    """Data-side Sign-ALSH codes: sign_codes(P_sign(x), a)."""
    return sign_codes(p_transform_sign(x, m), a)


@functools.partial(jax.jit, static_argnames=("m",))
def sign_alsh_query_codes(q: jax.Array, a: jax.Array, *, m: int = 2):
    """Query-side Sign-ALSH codes: sign_codes(Q_sign(q), a)."""
    return sign_codes(q_transform_sign(q, m), a)

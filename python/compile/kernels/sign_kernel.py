"""Layer-1 Pallas kernel: sign-random-projection (SimHash) codes.

The Sign-ALSH extension (paper §5 "future work", realized in Shrivastava &
Li 2015) replaces the quantized L2 hash with `h(x) = sign(aᵀx)`, whose
collision probability is `1 - θ(x,y)/π`. The kernel computes the batched
projection and emits 0/1 int32 codes:

    H[i, j] = 1 if A[:, j] . X[i, :] >= 0 else 0

Same MXU-tiled matmul as hash_kernel with a sign epilogue fused on the VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 32
DEFAULT_BK = 128


def _sign_block_kernel(x_ref, a_ref, o_ref):
    acc = jnp.dot(x_ref[...], a_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = (acc >= 0).astype(jnp.int32)


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def sign_codes(
    x: jax.Array,
    a: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    """Sign-random-projection codes ``(x @ a >= 0)`` as int32 {0, 1}.

    x: [B, D'] batch; a: [D', K] projection matrix. Padding note: padded
    (zero) rows produce code 1 for every hash (0 >= 0); callers slice
    the output back to the true batch, so this never leaks.
    """
    if x.ndim != 2 or a.ndim != 2 or x.shape[1] != a.shape[0]:
        raise ValueError(f"shape mismatch: x{x.shape} a{a.shape}")
    n, k = x.shape[0], a.shape[1]
    x = _pad_to(x.astype(jnp.float32), 0, bm)
    a = _pad_to(a.astype(jnp.float32), 1, bk)
    d = x.shape[1]
    grid = (x.shape[0] // bm, a.shape[1] // bk)
    out = pl.pallas_call(
        _sign_block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bk), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], a.shape[1]), jnp.int32),
        interpret=interpret,
    )(x, a)
    return out[:n, :k]

"""Layer-1 Pallas kernel: exact inner-product re-ranking of candidates.

After the ALSH tables return a candidate union, the engine re-ranks the
candidates by their exact inner product with the query:

    S[i, j] = Q[i, :] . C[:, j]

``C`` is the candidate matrix already laid out transposed ([D, M]) so the
kernel is a plain MXU-shaped matmul. The same kernel also powers the
brute-force gold-standard scorer used by the evaluation harness.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 32
DEFAULT_BN = 128


def _rerank_block_kernel(q_ref, c_ref, o_ref):
    o_ref[...] = jnp.dot(
        q_ref[...], c_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def rerank_scores(
    q: jax.Array,
    c_t: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    interpret: bool = True,
) -> jax.Array:
    """Exact inner products ``q @ c_t`` via a tiled Pallas matmul.

    Args:
      q:   [B, D] query batch (f32).
      c_t: [D, M] candidate matrix, transposed.

    Returns:
      [B, M] f32 scores.
    """
    if q.ndim != 2 or c_t.ndim != 2 or q.shape[1] != c_t.shape[0]:
        raise ValueError(f"shape mismatch: q{q.shape} c_t{c_t.shape}")
    n, m = q.shape[0], c_t.shape[1]
    q = _pad_to(q.astype(jnp.float32), 0, bm)
    c_t = _pad_to(c_t.astype(jnp.float32), 1, bn)
    d = q.shape[1]
    grid = (q.shape[0] // bm, c_t.shape[1] // bn)
    out = pl.pallas_call(
        _rerank_block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q.shape[0], c_t.shape[1]), jnp.float32),
        interpret=interpret,
    )(q, c_t)
    return out[:n, :m]

"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal at build time: pytest (and hypothesis)
check every kernel against these definitions, and the Rust side carries an
equivalent mirror (rust/src/lsh) that is cross-checked against the PJRT
artifacts in integration tests.
"""

from __future__ import annotations

import jax.numpy as jnp


def hash_codes_ref(x, a, b):
    """floor(x @ a + b) as int32 — the L2LSH code (Eq. 8, r pre-scaled)."""
    return jnp.floor(x.astype(jnp.float32) @ a.astype(jnp.float32) + b).astype(
        jnp.int32
    )


def rerank_scores_ref(q, c_t):
    """Exact inner products q @ c_t."""
    return q.astype(jnp.float32) @ c_t.astype(jnp.float32)


def p_transform_ref(x, m):
    """Preprocessing transform P(x) = [x; ||x||^2; ||x||^4; ...; ||x||^(2^m)].

    Eq. (12). The caller is responsible for having scaled x so that
    ||x||_2 <= U < 1 (Eq. 11).
    """
    cols = [x]
    n = jnp.sum(x * x, axis=-1, keepdims=True)  # ||x||^2
    for _ in range(m):
        cols.append(n)
        n = n * n  # ||x||^4, ||x||^8, ... by iterative squaring
    return jnp.concatenate(cols, axis=-1)


def q_transform_ref(q, m):
    """Query transform Q(q) = [q/||q||; 1/2; ...; 1/2] (Eq. 13).

    The unit-normalization is WLOG per Section 3.3 (argmax is invariant to
    ||q||); we fold it into the transform so callers can pass raw queries.
    """
    norm = jnp.sqrt(jnp.sum(q * q, axis=-1, keepdims=True))
    qn = q / jnp.maximum(norm, 1e-12)
    half = jnp.full(q.shape[:-1] + (m,), 0.5, dtype=q.dtype)
    return jnp.concatenate([qn, half], axis=-1)


def alsh_data_codes_ref(x, a, b, m):
    """End-to-end data-side ALSH codes: hash(P(x))."""
    return hash_codes_ref(p_transform_ref(x, m), a, b)


def alsh_query_codes_ref(q, a, b, m):
    """End-to-end query-side ALSH codes: hash(Q(q))."""
    return hash_codes_ref(q_transform_ref(q, m), a, b)


def sign_codes_ref(x, a):
    """(x @ a >= 0) as int32 — the SimHash / SRP code."""
    return (x.astype(jnp.float32) @ a.astype(jnp.float32) >= 0).astype(jnp.int32)


def p_transform_sign_ref(x, m):
    """Sign-ALSH preprocessing transform (Shrivastava & Li 2015):

    P(x) = [x; 1/2 - ||x||^2; 1/2 - ||x||^4; ...; 1/2 - ||x||^(2^m)].
    """
    cols = [x]
    n = jnp.sum(x * x, axis=-1, keepdims=True)
    for _ in range(m):
        cols.append(0.5 - n)
        n = n * n
    return jnp.concatenate(cols, axis=-1)


def q_transform_sign_ref(q, m):
    """Sign-ALSH query transform: Q(q) = [q/||q||; 0; ...; 0]."""
    norm = jnp.sqrt(jnp.sum(q * q, axis=-1, keepdims=True))
    qn = q / jnp.maximum(norm, 1e-12)
    zeros = jnp.zeros(q.shape[:-1] + (m,), dtype=q.dtype)
    return jnp.concatenate([qn, zeros], axis=-1)

"""Layer-1 Pallas kernel: batched L2LSH hash-code generation.

The compute hot spot of ALSH is computing K hash codes for a batch of
(already transformed) vectors:

    H[i, j] = floor( (A[:, j] . X[i, :] + b[j]) / r )

The caller pre-scales ``A' = A / r`` and ``b' = b / r`` (r is a scalar), so
the kernel itself computes ``floor(X @ A' + b')`` and emits int32 codes.
This keeps r out of the compiled artifact: the Rust coordinator owns all of
(A, b, r) and can serve any r with the same executable.

TPU mapping (see DESIGN.md section "Hardware adaptation"): the matmul tiles
target the MXU; the ``+b, floor, cast`` epilogue is fused into the same
kernel on the VPU so the f32 activations never round-trip to HBM. The
reduction dimension D' (= D + m, a few hundred) stays resident in VMEM.

Pallas is run with ``interpret=True`` — the CPU PJRT plugin cannot execute
Mosaic custom-calls; interpret mode lowers to plain HLO which runs anywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block sizes. bm x bk accumulator (32*128*4B = 16 KiB) plus an
# X-tile (32 x D') and A-tile (D' x 128) comfortably fit VMEM for D' <= 2048.
DEFAULT_BM = 32
DEFAULT_BK = 128


def _hash_block_kernel(x_ref, a_ref, b_ref, o_ref):
    """One (bm, bk) output tile: floor(X_tile @ A_tile + b_tile) -> int32."""
    acc = jnp.dot(x_ref[...], a_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    o_ref[...] = jnp.floor(acc).astype(jnp.int32)


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def hash_codes(
    x: jax.Array,
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    """Compute int32 L2LSH codes ``floor(x @ a + b)`` with a Pallas kernel.

    Args:
      x: [B, D'] batch of vectors (f32). Caller applies P/Q transform first.
      a: [D', K] pre-scaled projection matrix (A / r).
      b: [K] pre-scaled offsets (b / r).
      bm, bk: output tile sizes (batch x hash).
      interpret: run the Pallas kernel in interpret mode (required on CPU).

    Returns:
      [B, K] int32 hash codes.

    Shapes are padded up to tile multiples internally and sliced back, so any
    B >= 1, K >= 1, D' >= 1 is accepted.
    """
    if x.ndim != 2 or a.ndim != 2 or b.ndim != 1:
        raise ValueError(f"bad ranks: x{x.shape} a{a.shape} b{b.shape}")
    if x.shape[1] != a.shape[0] or a.shape[1] != b.shape[0]:
        raise ValueError(f"shape mismatch: x{x.shape} a{a.shape} b{b.shape}")
    n, k = x.shape[0], a.shape[1]
    x = _pad_to(x.astype(jnp.float32), 0, bm)
    a = _pad_to(a.astype(jnp.float32), 1, bk)
    b = _pad_to(b.astype(jnp.float32), 0, bk)
    d = x.shape[1]
    grid = (x.shape[0] // bm, a.shape[1] // bk)
    out = pl.pallas_call(
        _hash_block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bk), lambda i, j: (0, j)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], a.shape[1]), jnp.int32),
        interpret=interpret,
    )(x, a, b)
    return out[:n, :k]

//! Hermetic stand-in for the `anyhow` crate.
//!
//! The build environment for this repository has no network access to
//! crates.io, so the subset of `anyhow` the workspace actually uses is
//! vendored here as a dependency-free implementation with the same API
//! surface and semantics:
//!
//! * [`Error`]: an opaque error value holding a context chain. `{}` shows
//!   the outermost message, `{:#}` the full `outer: inner: root` chain
//!   (matching anyhow's alternate Display).
//! * `?` conversion from any `std::error::Error + Send + Sync + 'static`
//!   (the source chain is captured eagerly as strings).
//! * [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros, including inline format
//!   captures in string literals.
//!
//! Swapping in the real `anyhow` is a one-line Cargo.toml change; nothing
//! in the workspace relies on behavior beyond the subset above.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: an outermost message plus the chain of causes.
pub struct Error {
    /// `chain[0]` is the outermost context, `chain.last()` the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context layer (what `.context(..)` attaches).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // anyhow's `{:#}`: "outer: cause: root".
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// The anyhow trick: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` coherent
// (and gives `?` conversions from all std error types).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Self { chain }
    }
}

/// Attach context to errors, on both `Result` and `Option`.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or any
/// displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is not satisfied.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file gone")
    }

    #[test]
    fn question_mark_from_std_error() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(format!("{e}"), "file gone");
    }

    #[test]
    fn context_layers_and_alternate_display() {
        let e: Result<()> = Err(io_err()).context("reading manifest");
        let e = e.unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: file gone");
        assert_eq!(e.root_cause(), "file gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(format!("{e:#}"), "missing field");
        let some: Option<u32> = Some(7);
        assert_eq!(some.context("unused").unwrap(), 7);
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u32, std::io::Error> = Ok(3);
        let got = ok
            .with_context(|| -> &'static str { panic!("must not evaluate") })
            .unwrap();
        assert_eq!(got, 3);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            ensure!(x != 6);
            Err(anyhow!("fell through with {}", x))
        }
        assert_eq!(format!("{:#}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{:#}", f(5).unwrap_err()), "five is right out");
        assert!(format!("{:#}", f(6).unwrap_err()).contains("x != 6"));
        assert_eq!(format!("{:#}", f(3).unwrap_err()), "fell through with 3");
        // Single-expression form takes any Display value.
        let from_string = anyhow!(String::from("plain message"));
        assert_eq!(format!("{from_string}"), "plain message");
    }

    #[test]
    fn error_msg_as_fn_pointer() {
        let r: std::result::Result<u32, String> = Err("boom".into());
        let e = r.map_err(Error::msg).unwrap_err();
        assert_eq!(format!("{e}"), "boom");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = Error::msg("root").context("middle").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("Caused by") && dbg.contains("root"));
    }
}

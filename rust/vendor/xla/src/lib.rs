//! Build-hermetic stub of the `xla` crate (PJRT/XLA bindings).
//!
//! Environments without the XLA C library (and without network access to
//! fetch the real bindings) still need the workspace to build, so this
//! crate mirrors the handful of types and methods `alsh::runtime` calls.
//! [`PjRtClient::cpu`] returns an error, which makes `Runtime::load` fail
//! gracefully — every caller in the workspace already has an
//! artifacts-unavailable fallback path (the batcher falls back to the
//! fused pure-Rust hasher, benches and integration tests skip the PJRT
//! cases, `CollisionRanker::build_pjrt` falls back to the scalar mirror).
//!
//! Deployments with real XLA swap this for the actual bindings via a
//! one-line Cargo.toml change; no workspace code changes.

use std::path::Path;

/// Stub error type; `{:?}` matches how call sites format PJRT errors.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error("XLA/PJRT backend not built in (stub xla crate); run with the real xla bindings to use compiled artifacts".into())
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Compiled executable handle (unreachable in the stub: no client exists).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        Err(unavailable())
    }
}

/// XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Sized {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}

/// Host literal (stub: carries no data; all reads fail).
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err:?}").contains("stub"));
    }
}

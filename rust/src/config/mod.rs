//! Experiment and dataset configuration (serde-backed, CLI-overridable).

use crate::data::SyntheticConfig;

/// A named dataset recipe: synthetic ratings + PureSVD latent dimension,
/// mirroring the paper's two evaluation datasets (§4.1).
#[derive(Clone, Debug)]
pub struct DatasetConfig {
    pub name: String,
    pub synthetic: SyntheticConfig,
    /// PureSVD latent dimension f (paper: 150 for Movielens, 300 for
    /// Netflix).
    pub latent_dim: usize,
    pub seed: u64,
}

impl DatasetConfig {
    pub fn movielens_like() -> Self {
        Self {
            name: "movielens-synth".into(),
            synthetic: SyntheticConfig::movielens_like(),
            latent_dim: 150,
            seed: 20140213,
        }
    }

    pub fn netflix_like() -> Self {
        Self {
            name: "netflix-synth".into(),
            synthetic: SyntheticConfig::netflix_like(),
            latent_dim: 300,
            seed: 20141208,
        }
    }

    pub fn tiny() -> Self {
        Self {
            name: "tiny-synth".into(),
            synthetic: SyntheticConfig::tiny(),
            latent_dim: 50,
            seed: 7,
        }
    }

    pub fn by_name(name: &str) -> crate::Result<Self> {
        match name {
            "movielens" | "movielens-synth" => Ok(Self::movielens_like()),
            "netflix" | "netflix-synth" => Ok(Self::netflix_like()),
            "tiny" | "tiny-synth" => Ok(Self::tiny()),
            other => anyhow::bail!("unknown dataset {other:?} (movielens|netflix|tiny)"),
        }
    }
}

/// Parameters of the Figures 5–7 precision–recall experiments (§4.3).
#[derive(Clone, Debug)]
pub struct PrExperimentConfig {
    /// Number of random users to average over (paper: 2000).
    pub n_users: usize,
    /// Hash-count sweep K (paper: 64, 128, 256, 512).
    pub k_values: Vec<usize>,
    /// Gold top-T sweep (paper: 1, 5, 10).
    pub t_values: Vec<usize>,
    /// L2LSH baseline r sweep (paper: 1..5 step 0.5).
    pub l2lsh_r_values: Vec<f32>,
    /// ALSH operating point (paper: m=3, U=0.83, r=2.5).
    pub alsh_m: usize,
    pub alsh_u: f32,
    pub alsh_r: f32,
    pub seed: u64,
}

impl Default for PrExperimentConfig {
    fn default() -> Self {
        Self {
            // Paper averages over 2000 users; 200 is the single-core
            // default — pass --users 2000 for the full protocol.
            n_users: 200,
            k_values: vec![64, 128, 256, 512],
            t_values: vec![1, 5, 10],
            l2lsh_r_values: vec![1.0, 1.5, 2.0, 2.5, 3.0, 3.5, 4.0, 4.5, 5.0],
            alsh_m: 3,
            alsh_u: 0.83,
            alsh_r: 2.5,
            seed: 2014,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_resolves() {
        assert_eq!(DatasetConfig::by_name("movielens").unwrap().latent_dim, 150);
        assert_eq!(DatasetConfig::by_name("netflix").unwrap().latent_dim, 300);
        assert!(DatasetConfig::by_name("imagenet").is_err());
    }

    #[test]
    fn default_experiment_matches_paper_grid() {
        let c = PrExperimentConfig::default();
        assert_eq!(c.k_values, vec![64, 128, 256, 512]);
        assert_eq!(c.t_values, vec![1, 5, 10]);
        assert_eq!(c.l2lsh_r_values.len(), 9);
        assert_eq!((c.alsh_m, c.alsh_u, c.alsh_r), (3, 0.83, 2.5));
    }

}

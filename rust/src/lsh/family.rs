//! Sampled L2LSH hash family: K independent functions over dimension D'.

use crate::util::Rng;

/// Shared dot product for the hash families. The straightforward
/// zip-fold auto-vectorizes well here; an explicit 4-lane unroll was
/// tried during the perf pass and measured *slower* (see EXPERIMENTS.md
/// §Perf), so keep the simple form.
#[inline]
pub(crate) fn dot_simple(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// A family of `k` independent L2LSH functions over `dim`-dimensional input.
///
/// Storage layout matches the AOT artifact inputs: the projection matrix is
/// kept *pre-scaled* by `1/r` in column-major-per-hash order `[k][dim]`
/// (each hash function's direction contiguous), and offsets are `b/r`.
/// Hash code: `floor(dot(a_scaled[k], x) + b_scaled[k])`.
#[derive(Clone, Debug)]
pub struct L2LshFamily {
    dim: usize,
    k: usize,
    r: f32,
    /// `[k * dim]`, row per hash function, already divided by r.
    a_scaled: Vec<f32>,
    /// `[k]`, already divided by r.
    b_scaled: Vec<f32>,
}

impl L2LshFamily {
    /// Sample a fresh family: `a ~ N(0,1)^dim`, `b ~ U[0, r)`.
    pub fn sample(dim: usize, k: usize, r: f32, rng: &mut Rng) -> Self {
        assert!(dim > 0 && k > 0 && r > 0.0);
        let inv_r = 1.0 / r;
        let a_scaled: Vec<f32> = (0..k * dim)
            .map(|_| rng.normal_f32() * inv_r)
            .collect();
        let b_scaled: Vec<f32> = (0..k).map(|_| rng.f32() * r * inv_r).collect();
        Self { dim, k, r, a_scaled, b_scaled }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn r(&self) -> f32 {
        self.r
    }

    /// Pre-scaled projection matrix in `[dim][k]` (artifact layout:
    /// `A[d, k] = a_k[d] / r`), row-major over `dim`. This is exactly the
    /// `a` input of the compiled HLO artifacts.
    pub fn a_matrix_dk(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim * self.k];
        for kk in 0..self.k {
            for d in 0..self.dim {
                out[d * self.k + kk] = self.a_scaled[kk * self.dim + d];
            }
        }
        out
    }

    /// Pre-scaled offsets `b/r` — the `b` input of the compiled artifacts.
    pub fn b_vector(&self) -> &[f32] {
        &self.b_scaled
    }

    /// Raw `[k][dim]` pre-scaled projection storage (persistence).
    pub fn a_scaled_raw(&self) -> Vec<f32> {
        self.a_scaled.clone()
    }

    /// Borrow the raw `[k][dim]` pre-scaled projection rows (each hash
    /// function's direction contiguous) — used by `lsh::fused` to stack
    /// all families into one matrix without copying per call.
    pub fn a_rows(&self) -> &[f32] {
        &self.a_scaled
    }

    /// Rebuild a family from persisted raw storage.
    pub fn from_raw(dim: usize, k: usize, r: f32, a_scaled: Vec<f32>, b_scaled: Vec<f32>) -> Self {
        assert_eq!(a_scaled.len(), k * dim);
        assert_eq!(b_scaled.len(), k);
        Self { dim, k, r, a_scaled, b_scaled }
    }

    /// The fractional part of the (pre-floor) hash value for function
    /// `k_idx` — the distance of the projection to its lower bucket
    /// boundary, used by multi-probe to pick perturbation directions.
    #[inline]
    pub fn hash_frac(&self, x: &[f32], k_idx: usize) -> (i32, f32) {
        let row = &self.a_scaled[k_idx * self.dim..(k_idx + 1) * self.dim];
        let t = dot_simple(row, x) + self.b_scaled[k_idx];
        let f = t.floor();
        (f as i32, t - f)
    }

    /// Hash code of `x` under function `k_idx`.
    #[inline]
    pub fn hash_one(&self, x: &[f32], k_idx: usize) -> i32 {
        debug_assert_eq!(x.len(), self.dim);
        let row = &self.a_scaled[k_idx * self.dim..(k_idx + 1) * self.dim];
        (dot_simple(row, x) + self.b_scaled[k_idx]).floor() as i32
    }

    /// All `k` hash codes of `x`, appended to `out`.
    pub fn hash_into(&self, x: &[f32], out: &mut Vec<i32>) {
        debug_assert_eq!(x.len(), self.dim);
        for k_idx in 0..self.k {
            out.push(self.hash_one(x, k_idx));
        }
    }

    /// All `k` hash codes of `x`.
    pub fn hash(&self, x: &[f32]) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.k);
        self.hash_into(x, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn family(dim: usize, k: usize, r: f32, seed: u64) -> L2LshFamily {
        let mut rng = Rng::seed_from_u64(seed);
        L2LshFamily::sample(dim, k, r, &mut rng)
    }

    #[test]
    fn deterministic_given_seed() {
        let f1 = family(8, 16, 2.5, 1);
        let f2 = family(8, 16, 2.5, 1);
        let x: Vec<f32> = (0..8).map(|i| i as f32 * 0.3 - 1.0).collect();
        assert_eq!(f1.hash(&x), f2.hash(&x));
    }

    #[test]
    fn same_input_same_code() {
        let f = family(16, 32, 2.5, 2);
        let x: Vec<f32> = (0..16).map(|i| (i as f32).sin()).collect();
        assert_eq!(f.hash(&x), f.hash(&x));
    }

    #[test]
    fn translation_by_r_shifts_code_by_one() {
        // h(x) where aᵀx increases by exactly r => code +1.
        let f = family(1, 8, 2.0, 3);
        let x = [1.0f32];
        let codes1 = f.hash(&x);
        // For dim=1, aᵀx = a*x. Moving x so that a*x increases by r means
        // x' = x + r/a (per-hash). Instead test via the scaled projection:
        for k_idx in 0..8 {
            let a = f.a_scaled[k_idx]; // = a_raw / r
            if a.abs() < 1e-3 {
                continue;
            }
            let x_shift = [x[0] + 1.0 / a]; // adds exactly 1.0 to scaled proj
            let c = f.hash_one(&x_shift, k_idx);
            // floor(t + 1) == floor(t) + 1 (away from fp boundaries)
            assert_eq!(c, codes1[k_idx] + 1);
        }
    }

    #[test]
    fn collision_rate_tracks_distance() {
        // Closer pairs collide more: the LSH property, empirically.
        let f = family(16, 4096, 2.5, 4);
        let mut rng = Rng::seed_from_u64(5);
        let base: Vec<f32> = (0..16).map(|_| rng.f32() - 0.5).collect();
        let near: Vec<f32> = base.iter().map(|v| v + 0.05).collect();
        let far: Vec<f32> = base.iter().map(|v| v + 1.5).collect();
        let hb = f.hash(&base);
        let hn = f.hash(&near);
        let hf = f.hash(&far);
        let coll = |a: &[i32], b: &[i32]| a.iter().zip(b).filter(|(x, y)| x == y).count();
        assert!(coll(&hb, &hn) > coll(&hb, &hf));
    }

    #[test]
    fn empirical_collision_matches_theory() {
        // Fraction of colliding hashes ≈ F_r(||x - y||).
        use crate::theory::collision_probability;
        let dim = 24;
        let f = family(dim, 8192, 2.5, 6);
        let mut rng = Rng::seed_from_u64(7);
        let x: Vec<f32> = (0..dim).map(|_| rng.f32() - 0.5).collect();
        let delta = 0.8 / (dim as f32).sqrt();
        let y: Vec<f32> = x.iter().map(|v| v + delta).collect();
        let d: f32 = x
            .iter()
            .zip(&y)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt();
        let hx = f.hash(&x);
        let hy = f.hash(&y);
        let frac =
            hx.iter().zip(&hy).filter(|(a, b)| a == b).count() as f64 / hx.len() as f64;
        let theory = collision_probability(2.5, d as f64);
        assert!(
            (frac - theory).abs() < 0.02,
            "empirical {frac} vs theory {theory} at d={d}"
        );
    }

    #[test]
    fn a_matrix_layout_roundtrip() {
        let f = family(5, 7, 2.5, 8);
        let a_dk = f.a_matrix_dk();
        for kk in 0..7 {
            for d in 0..5 {
                assert_eq!(a_dk[d * 7 + kk], f.a_scaled[kk * 5 + d]);
            }
        }
    }

    #[test]
    fn b_in_unit_range_after_scaling() {
        let f = family(4, 64, 3.5, 9);
        for &b in f.b_vector() {
            assert!((0.0..1.0).contains(&b), "b/r = {b} outside [0,1)");
        }
    }
}

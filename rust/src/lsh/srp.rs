//! Sign-random-projection (SimHash) hash family — the engine of the
//! Sign-ALSH extension (paper §5 future work; Shrivastava & Li 2015).
//!
//! `h(x) = 1[aᵀx >= 0]` with `a ~ N(0, I)`; collision probability between
//! two vectors is `1 − θ/π` where θ is the angle between them.

use crate::util::Rng;

/// A family of `k` independent sign-random-projection functions.
#[derive(Clone, Debug)]
pub struct SrpFamily {
    dim: usize,
    k: usize,
    /// `[k * dim]`, one projection direction per hash function.
    a: Vec<f32>,
}

impl SrpFamily {
    /// Sample a fresh family: `a ~ N(0,1)^dim` per function.
    pub fn sample(dim: usize, k: usize, rng: &mut Rng) -> Self {
        assert!(dim > 0 && k > 0);
        let a = (0..k * dim).map(|_| rng.normal_f32()).collect();
        Self { dim, k, a }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Borrow the raw `[k][dim]` projection rows (each hash function's
    /// direction contiguous) — used by [`super::FusedSrpHasher`] to stack
    /// all families into one matrix without copying per call.
    pub fn a_rows(&self) -> &[f32] {
        &self.a
    }

    /// Rebuild a family from persisted raw `[k][dim]` storage.
    pub fn from_raw(dim: usize, k: usize, a: Vec<f32>) -> Self {
        assert_eq!(a.len(), k * dim);
        Self { dim, k, a }
    }

    /// Projection matrix in artifact layout `[dim][k]` (the `a` input of
    /// the `sign_alsh_*` artifacts).
    pub fn a_matrix_dk(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim * self.k];
        for kk in 0..self.k {
            for d in 0..self.dim {
                out[d * self.k + kk] = self.a[kk * self.dim + d];
            }
        }
        out
    }

    /// Code of `x` under function `k_idx` (0 or 1).
    #[inline]
    pub fn hash_one(&self, x: &[f32], k_idx: usize) -> i32 {
        debug_assert_eq!(x.len(), self.dim);
        let row = &self.a[k_idx * self.dim..(k_idx + 1) * self.dim];
        (super::family::dot_simple(row, x) >= 0.0) as i32
    }

    /// All `k` codes of `x`, appended to `out`.
    pub fn hash_into(&self, x: &[f32], out: &mut Vec<i32>) {
        for k_idx in 0..self.k {
            out.push(self.hash_one(x, k_idx));
        }
    }

    pub fn hash(&self, x: &[f32]) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.k);
        self.hash_into(x, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_bits() {
        let mut rng = Rng::seed_from_u64(1);
        let f = SrpFamily::sample(8, 64, &mut rng);
        let x: Vec<f32> = (0..8).map(|i| (i as f32).sin()).collect();
        assert!(f.hash(&x).iter().all(|&c| c == 0 || c == 1));
    }

    #[test]
    fn scale_invariant() {
        // sign(aᵀ(cx)) == sign(aᵀx) for c > 0.
        let mut rng = Rng::seed_from_u64(2);
        let f = SrpFamily::sample(12, 128, &mut rng);
        let x: Vec<f32> = (0..12).map(|i| (i as f32 * 0.7).cos()).collect();
        let x3: Vec<f32> = x.iter().map(|v| v * 3.0).collect();
        assert_eq!(f.hash(&x), f.hash(&x3));
    }

    #[test]
    fn antipodal_points_flip_all_codes() {
        let mut rng = Rng::seed_from_u64(3);
        let f = SrpFamily::sample(6, 256, &mut rng);
        let x: Vec<f32> = (0..6).map(|i| i as f32 - 2.5).collect();
        let neg: Vec<f32> = x.iter().map(|v| -v).collect();
        let hx = f.hash(&x);
        let hn = f.hash(&neg);
        // aᵀx is continuous, so aᵀx == 0 has measure zero: all flip.
        let flipped = hx.iter().zip(&hn).filter(|(a, b)| a != b).count();
        assert_eq!(flipped, 256);
    }

    #[test]
    fn collision_rate_matches_angle() {
        // P(h(x)=h(y)) = 1 - θ/π.
        let mut rng = Rng::seed_from_u64(4);
        let dim = 16;
        let f = SrpFamily::sample(dim, 16384, &mut rng);
        let x: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
        let y: Vec<f32> = x.iter().map(|v| v + 0.7 * rng.normal_f32() * 0.3).collect();
        let dot: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        let cos = dot
            / (crate::transform::l2_norm(&x) * crate::transform::l2_norm(&y));
        let theta = cos.clamp(-1.0, 1.0).acos() as f64;
        let hx = f.hash(&x);
        let hy = f.hash(&y);
        let frac =
            hx.iter().zip(&hy).filter(|(a, b)| a == b).count() as f64 / hx.len() as f64;
        let want = 1.0 - theta / std::f64::consts::PI;
        assert!((frac - want).abs() < 0.015, "frac {frac} vs 1-θ/π {want}");
    }

    #[test]
    fn layout_roundtrip() {
        let mut rng = Rng::seed_from_u64(5);
        let f = SrpFamily::sample(5, 7, &mut rng);
        let a_dk = f.a_matrix_dk();
        for kk in 0..7 {
            for d in 0..5 {
                assert_eq!(a_dk[d * 7 + kk], f.a[kk * 5 + d]);
            }
        }
    }
}

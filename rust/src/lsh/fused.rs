//! Fused multi-table hashing: all L families' codes in one blocked pass.
//!
//! # Layout
//!
//! A bucketed `(K, L)` index owns L independent [`L2LshFamily`]s over the
//! same input dimension `D' = D + m`. The per-family path computes each of
//! the `L·K` codes with its own `dot_simple` call — `L·K` *serial* f32
//! accumulation chains, each bounded by floating-point add latency (f32
//! addition is not associative, so the compiler cannot unroll a single
//! chain).
//!
//! [`FusedHasher`] stacks every family's pre-scaled `[K × D']` projection
//! rows into one contiguous `[L·K × D']` matrix (row `t·K + j` is hash
//! function `j` of table `t`, matching the `[L·K]` flat code layout used by
//! `AlshIndex::candidates_from_codes` and the PJRT artifacts) and computes
//! a query's codes as one blocked matrix–vector product: blocks of
//! [`LANES`] rows share each load of `x[d]` and run [`LANES`] *independent*
//! accumulation chains that fill the FMA pipeline. A matrix–matrix variant
//! ([`FusedHasher::hash_batch_into`]) additionally reuses each row block
//! across every input in a batch; it serves both the coordinator batcher's
//! fallback hash path and the **build side**: the parallel sharded index
//! build ([`crate::index::build`]) hashes whole item blocks through it,
//! as does [`crate::index::AlshIndex::query_batch_into`] for offline
//! evaluation batches.
//!
//! # Equivalence to per-family hashing
//!
//! The fused kernel is *bit-identical* to `L2LshFamily::hash_one`, not
//! merely approximately equal: each row's accumulation visits dimensions
//! in the same order with the same `acc + x[d] * a[d]` operations — the
//! blocking only interleaves independent rows, never reassociates a single
//! row's sum. So `floor(dot + b)` lands on exactly the same code even at
//! f32 floor boundaries, and candidate sets are guaranteed identical
//! (property-tested in `tests/fused_csr_equivalence.rs`).

use super::family::dot_simple;
use super::L2LshFamily;

/// Rows processed per block: independent accumulator chains per load of x.
pub(super) const LANES: usize = 4;

/// One block of [`LANES`] row dot products against `x`, each accumulated
/// in `dot_simple` order (bit-identical to the per-family path). Shared
/// by [`FusedHasher`] and [`super::FusedSrpHasher`] — the one blocked
/// matvec kernel both fused pipelines are built on.
#[inline]
pub(super) fn dot_block(rows: &[f32], dim: usize, x: &[f32]) -> [f32; LANES] {
    debug_assert_eq!(rows.len(), LANES * dim);
    debug_assert_eq!(x.len(), dim);
    let (r0, rest) = rows.split_at(dim);
    let (r1, rest) = rest.split_at(dim);
    let (r2, r3) = rest.split_at(dim);
    let mut a0 = 0.0f32;
    let mut a1 = 0.0f32;
    let mut a2 = 0.0f32;
    let mut a3 = 0.0f32;
    for d in 0..dim {
        let xv = x[d];
        a0 += r0[d] * xv;
        a1 += r1[d] * xv;
        a2 += r2[d] * xv;
        a3 += r3[d] * xv;
    }
    [a0, a1, a2, a3]
}

/// All L hash families of an index, stacked for single-pass hashing.
#[derive(Clone, Debug)]
pub struct FusedHasher {
    /// Input dimension D' (= D + m for ALSH, raw D for symmetric L2LSH).
    dim: usize,
    /// Codes per table (meta-hash width K).
    k: usize,
    /// Number of tables L.
    l: usize,
    /// `[l*k * dim]` row-major; row `t*k + j` = family t's function j,
    /// pre-scaled by 1/r.
    rows: Vec<f32>,
    /// `[l*k]` offsets, pre-scaled by 1/r.
    offs: Vec<f32>,
}

impl FusedHasher {
    /// Stack `families` (all with equal `dim`, `k`) into one fused matrix.
    pub fn from_families(families: &[L2LshFamily]) -> Self {
        assert!(!families.is_empty(), "no families to fuse");
        let dim = families[0].dim();
        let k = families[0].k();
        assert!(
            families.iter().all(|f| f.dim() == dim && f.k() == k),
            "families disagree on (dim, k)"
        );
        let l = families.len();
        let mut rows = Vec::with_capacity(l * k * dim);
        let mut offs = Vec::with_capacity(l * k);
        for fam in families {
            rows.extend_from_slice(fam.a_rows());
            offs.extend_from_slice(fam.b_vector());
        }
        Self { dim, k, l, rows, offs }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n_tables(&self) -> usize {
        self.l
    }

    /// Total codes per input (= L·K).
    pub fn n_codes(&self) -> usize {
        self.l * self.k
    }

    /// All `L·K` codes of `x` into `out` (len `n_codes()`), one blocked
    /// matrix–vector pass.
    pub fn hash_into(&self, x: &[f32], out: &mut [i32]) {
        let nc = self.n_codes();
        assert_eq!(x.len(), self.dim, "input dim mismatch");
        assert_eq!(out.len(), nc, "output len mismatch");
        let dim = self.dim;
        let mut r = 0;
        while r + LANES <= nc {
            let acc = dot_block(&self.rows[r * dim..(r + LANES) * dim], dim, x);
            for (j, a) in acc.iter().enumerate() {
                out[r + j] = (a + self.offs[r + j]).floor() as i32;
            }
            r += LANES;
        }
        while r < nc {
            let row = &self.rows[r * dim..(r + 1) * dim];
            out[r] = (dot_simple(row, x) + self.offs[r]).floor() as i32;
            r += 1;
        }
    }

    /// Codes plus pre-floor fractional parts (multi-probe confidence):
    /// `fracs[i] = t_i - floor(t_i)` exactly as `L2LshFamily::hash_frac`.
    pub fn hash_frac_into(&self, x: &[f32], codes: &mut [i32], fracs: &mut [f32]) {
        let nc = self.n_codes();
        assert_eq!(x.len(), self.dim, "input dim mismatch");
        assert_eq!(codes.len(), nc, "codes len mismatch");
        assert_eq!(fracs.len(), nc, "fracs len mismatch");
        let dim = self.dim;
        let mut emit = |r: usize, dot: f32| {
            let t = dot + self.offs[r];
            let f = t.floor();
            codes[r] = f as i32;
            fracs[r] = t - f;
        };
        let mut r = 0;
        while r + LANES <= nc {
            let acc = dot_block(&self.rows[r * dim..(r + LANES) * dim], dim, x);
            for (j, a) in acc.iter().enumerate() {
                emit(r + j, *a);
            }
            r += LANES;
        }
        while r < nc {
            emit(r, dot_simple(&self.rows[r * dim..(r + 1) * dim], x));
            r += 1;
        }
    }

    /// Batch matrix–matrix variant: hash `n_rows` inputs (flattened
    /// row-major in `xs`, each `dim` long) into `out[q * n_codes() + i]`.
    ///
    /// Blocks over hash rows in the outer loop so each `[LANES × D']` row
    /// block stays hot in L1 across the whole batch — the coordinator
    /// batcher's pure-Rust hash path.
    pub fn hash_batch_into(&self, xs: &[f32], n_rows: usize, out: &mut [i32]) {
        let nc = self.n_codes();
        let dim = self.dim;
        assert_eq!(xs.len(), n_rows * dim, "batch input size mismatch");
        assert_eq!(out.len(), n_rows * nc, "batch output size mismatch");
        let mut r = 0;
        while r + LANES <= nc {
            let rows = &self.rows[r * dim..(r + LANES) * dim];
            for q in 0..n_rows {
                let x = &xs[q * dim..(q + 1) * dim];
                let acc = dot_block(rows, dim, x);
                for (j, a) in acc.iter().enumerate() {
                    out[q * nc + r + j] = (a + self.offs[r + j]).floor() as i32;
                }
            }
            r += LANES;
        }
        while r < nc {
            let row = &self.rows[r * dim..(r + 1) * dim];
            for q in 0..n_rows {
                let x = &xs[q * dim..(q + 1) * dim];
                out[q * nc + r] = (dot_simple(row, x) + self.offs[r]).floor() as i32;
            }
            r += 1;
        }
    }

    /// Allocating convenience over [`FusedHasher::hash_batch_into`] for
    /// offline tools and tests: returns the `[n_rows × L·K]` code block.
    pub fn hash_batch(&self, xs: &[f32], n_rows: usize) -> Vec<i32> {
        let mut out = vec![0i32; n_rows * self.n_codes()];
        self.hash_batch_into(xs, n_rows, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::util::Rng;

    fn families(l: usize, dim: usize, k: usize, seed: u64) -> Vec<L2LshFamily> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..l).map(|_| L2LshFamily::sample(dim, k, 2.5, &mut rng)).collect()
    }

    #[test]
    fn fused_matches_per_family_bitwise() {
        check(60, |rng| {
            let dim = 1 + rng.below(48);
            let k = 1 + rng.below(9); // exercises the non-multiple-of-LANES tail
            let l = 1 + rng.below(7);
            let fams = families(l, dim, k, rng.next_u64());
            let fused = FusedHasher::from_families(&fams);
            let x: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
            let mut want = Vec::with_capacity(l * k);
            for fam in &fams {
                fam.hash_into(&x, &mut want);
            }
            let mut got = vec![0i32; fused.n_codes()];
            fused.hash_into(&x, &mut got);
            assert_eq!(got, want, "fused codes diverge (dim={dim} k={k} l={l})");
        });
    }

    #[test]
    fn frac_variant_matches_hash_frac() {
        check(40, |rng| {
            let dim = 1 + rng.below(24);
            let k = 1 + rng.below(7);
            let l = 1 + rng.below(5);
            let fams = families(l, dim, k, rng.next_u64());
            let fused = FusedHasher::from_families(&fams);
            let x: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
            let mut codes = vec![0i32; fused.n_codes()];
            let mut fracs = vec![0f32; fused.n_codes()];
            fused.hash_frac_into(&x, &mut codes, &mut fracs);
            for (t, fam) in fams.iter().enumerate() {
                for j in 0..k {
                    let (c, f) = fam.hash_frac(&x, j);
                    assert_eq!(codes[t * k + j], c);
                    assert_eq!(fracs[t * k + j], f);
                }
            }
        });
    }

    #[test]
    fn batch_matches_single() {
        check(30, |rng| {
            let dim = 1 + rng.below(20);
            let k = 1 + rng.below(6);
            let l = 1 + rng.below(5);
            let n = 1 + rng.below(10);
            let fams = families(l, dim, k, rng.next_u64());
            let fused = FusedHasher::from_families(&fams);
            let xs: Vec<f32> = (0..n * dim).map(|_| rng.normal_f32()).collect();
            let mut batch = vec![0i32; n * fused.n_codes()];
            fused.hash_batch_into(&xs, n, &mut batch);
            let mut one = vec![0i32; fused.n_codes()];
            for q in 0..n {
                fused.hash_into(&xs[q * dim..(q + 1) * dim], &mut one);
                assert_eq!(
                    &batch[q * fused.n_codes()..(q + 1) * fused.n_codes()],
                    one.as_slice()
                );
            }
        });
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        let fams = families(2, 8, 4, 1);
        let fused = FusedHasher::from_families(&fams);
        let mut out = vec![0i32; fused.n_codes()];
        fused.hash_into(&[0.0; 5], &mut out);
    }
}

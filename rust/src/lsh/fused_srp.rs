//! Fused multi-table sign-random-projection hashing: the SRP twin of
//! [`super::FusedHasher`], serving the Sign-ALSH and Simple-LSH schemes.
//!
//! # Layout
//!
//! [`FusedSrpHasher`] stacks every [`SrpFamily`]'s `[K × D']` projection
//! rows into one contiguous `[L·K × D']` matrix (row `t·K + j` is hash
//! function `j` of table `t`, matching the `[L·K]` flat code layout the
//! whole query/build machinery speaks) and computes an input's codes as
//! one blocked matrix–vector product over the shared
//! [`super::fused::dot_block`] kernel. Codes are the sign bits
//! `1[aᵀx >= 0]` emitted as `i32` 0/1 values so the existing
//! `QueryScratch` replay, code-fed re-entry, and build pipelines carry
//! them unchanged; per table, the K bits are then packed into one `u64`
//! **bucket key word** by [`crate::index::hash_table::srp_bucket_key`]
//! (bit `j` = code `j`) — no avalanche mix is needed because the key *is*
//! the K-bit SimHash signature.
//!
//! # Multi-probe margins
//!
//! [`FusedSrpHasher::hash_margin_into`] additionally emits each code's
//! **margin** `|aᵀx|` — the distance of the projection to the sign
//! boundary. A small margin means the bit was nearly a coin flip, so
//! multi-probe ranks single-bit flips by ascending margin (the SRP
//! analogue of the L2 path's fractional-part ranking) and probes
//! `key ^ (1 << j)` for the least-confident coordinates.
//!
//! # Equivalence
//!
//! Bit-identical to [`SrpFamily::hash_one`]: each row's accumulation
//! visits dimensions in `dot_simple` order, and blocking only interleaves
//! independent rows — property-tested below against a per-family mirror
//! (all L·K positions, batch vs single, odd dims).

use super::family::dot_simple;
use super::fused::{dot_block, LANES};
use super::SrpFamily;

/// All L SRP families of an index, stacked for single-pass hashing.
#[derive(Clone, Debug)]
pub struct FusedSrpHasher {
    /// Input dimension D' (= D + m for Sign-ALSH, D + 1 for Simple-LSH).
    dim: usize,
    /// Sign bits per table (meta-hash width K, <= 64 so keys pack in u64).
    k: usize,
    /// Number of tables L.
    l: usize,
    /// `[l*k * dim]` row-major; row `t*k + j` = family t's direction j.
    rows: Vec<f32>,
}

impl FusedSrpHasher {
    /// Stack `families` (all with equal `dim`, `k`) into one fused matrix.
    pub fn from_families(families: &[SrpFamily]) -> Self {
        assert!(!families.is_empty(), "no families to fuse");
        let dim = families[0].dim();
        let k = families[0].k();
        assert!(
            families.iter().all(|f| f.dim() == dim && f.k() == k),
            "families disagree on (dim, k)"
        );
        assert!(k <= 64, "SRP meta-hash width K={k} exceeds the 64-bit key word");
        let l = families.len();
        let mut rows = Vec::with_capacity(l * k * dim);
        for fam in families {
            rows.extend_from_slice(fam.a_rows());
        }
        Self { dim, k, l, rows }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n_tables(&self) -> usize {
        self.l
    }

    /// Total codes per input (= L·K).
    pub fn n_codes(&self) -> usize {
        self.l * self.k
    }

    /// All `L·K` sign bits of `x` into `out` (len `n_codes()`), one
    /// blocked matrix–vector pass. Codes are 0/1.
    pub fn hash_into(&self, x: &[f32], out: &mut [i32]) {
        let nc = self.n_codes();
        assert_eq!(x.len(), self.dim, "input dim mismatch");
        assert_eq!(out.len(), nc, "output len mismatch");
        let dim = self.dim;
        let mut r = 0;
        while r + LANES <= nc {
            let acc = dot_block(&self.rows[r * dim..(r + LANES) * dim], dim, x);
            for (j, a) in acc.iter().enumerate() {
                out[r + j] = (*a >= 0.0) as i32;
            }
            r += LANES;
        }
        while r < nc {
            let row = &self.rows[r * dim..(r + 1) * dim];
            out[r] = (dot_simple(row, x) >= 0.0) as i32;
            r += 1;
        }
    }

    /// Sign bits plus per-code margins `|aᵀx|` (multi-probe confidence:
    /// small margin = the bit was nearly a coin flip, flip it first).
    pub fn hash_margin_into(&self, x: &[f32], codes: &mut [i32], margins: &mut [f32]) {
        let nc = self.n_codes();
        assert_eq!(x.len(), self.dim, "input dim mismatch");
        assert_eq!(codes.len(), nc, "codes len mismatch");
        assert_eq!(margins.len(), nc, "margins len mismatch");
        let dim = self.dim;
        let mut emit = |r: usize, dot: f32| {
            codes[r] = (dot >= 0.0) as i32;
            margins[r] = dot.abs();
        };
        let mut r = 0;
        while r + LANES <= nc {
            let acc = dot_block(&self.rows[r * dim..(r + LANES) * dim], dim, x);
            for (j, a) in acc.iter().enumerate() {
                emit(r + j, *a);
            }
            r += LANES;
        }
        while r < nc {
            emit(r, dot_simple(&self.rows[r * dim..(r + 1) * dim], x));
            r += 1;
        }
    }

    /// Batch matrix–matrix variant: hash `n_rows` inputs (flattened
    /// row-major in `xs`, each `dim` long) into `out[q * n_codes() + i]`.
    /// Blocks over hash rows in the outer loop so each `[LANES × D']` row
    /// block stays hot in L1 across the whole batch — the build side and
    /// the batch query path, mirroring `FusedHasher::hash_batch_into`.
    pub fn hash_batch_into(&self, xs: &[f32], n_rows: usize, out: &mut [i32]) {
        let nc = self.n_codes();
        let dim = self.dim;
        assert_eq!(xs.len(), n_rows * dim, "batch input size mismatch");
        assert_eq!(out.len(), n_rows * nc, "batch output size mismatch");
        let mut r = 0;
        while r + LANES <= nc {
            let rows = &self.rows[r * dim..(r + LANES) * dim];
            for q in 0..n_rows {
                let x = &xs[q * dim..(q + 1) * dim];
                let acc = dot_block(rows, dim, x);
                for (j, a) in acc.iter().enumerate() {
                    out[q * nc + r + j] = (*a >= 0.0) as i32;
                }
            }
            r += LANES;
        }
        while r < nc {
            let row = &self.rows[r * dim..(r + 1) * dim];
            for q in 0..n_rows {
                let x = &xs[q * dim..(q + 1) * dim];
                out[q * nc + r] = (dot_simple(row, x) >= 0.0) as i32;
            }
            r += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::util::Rng;

    fn families(l: usize, dim: usize, k: usize, seed: u64) -> Vec<SrpFamily> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..l).map(|_| SrpFamily::sample(dim, k, &mut rng)).collect()
    }

    /// The naive per-family mirror: every one of the L·K positions must
    /// match `SrpFamily::hash`, including odd dims and non-LANES-multiple
    /// code counts.
    #[test]
    fn fused_matches_per_family_bitwise() {
        check(60, |rng| {
            let dim = 1 + rng.below(47); // odd dims included
            let k = 1 + rng.below(9);
            let l = 1 + rng.below(7);
            let fams = families(l, dim, k, rng.next_u64());
            let fused = FusedSrpHasher::from_families(&fams);
            let x: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
            let mut want = Vec::with_capacity(l * k);
            for fam in &fams {
                fam.hash_into(&x, &mut want);
            }
            let mut got = vec![0i32; fused.n_codes()];
            fused.hash_into(&x, &mut got);
            assert_eq!(got, want, "fused SRP codes diverge (dim={dim} k={k} l={l})");
            assert!(got.iter().all(|&c| c == 0 || c == 1));
        });
    }

    /// The margin variant emits the same codes plus |aᵀx| per position.
    #[test]
    fn margin_variant_matches_hash_and_dots() {
        check(40, |rng| {
            let dim = 1 + rng.below(23);
            let k = 1 + rng.below(7);
            let l = 1 + rng.below(5);
            let fams = families(l, dim, k, rng.next_u64());
            let fused = FusedSrpHasher::from_families(&fams);
            let x: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
            let mut codes = vec![0i32; fused.n_codes()];
            let mut margins = vec![0f32; fused.n_codes()];
            fused.hash_margin_into(&x, &mut codes, &mut margins);
            let mut plain = vec![0i32; fused.n_codes()];
            fused.hash_into(&x, &mut plain);
            assert_eq!(codes, plain);
            for (t, fam) in fams.iter().enumerate() {
                for j in 0..k {
                    let dot = crate::lsh::family::dot_simple(
                        &fam.a_rows()[j * dim..(j + 1) * dim],
                        &x,
                    );
                    assert_eq!(margins[t * k + j], dot.abs());
                }
            }
        });
    }

    /// Batch rows must equal single-input hashing row by row.
    #[test]
    fn batch_matches_single() {
        check(30, |rng| {
            let dim = 1 + rng.below(19);
            let k = 1 + rng.below(6);
            let l = 1 + rng.below(5);
            let n = 1 + rng.below(10);
            let fams = families(l, dim, k, rng.next_u64());
            let fused = FusedSrpHasher::from_families(&fams);
            let xs: Vec<f32> = (0..n * dim).map(|_| rng.normal_f32()).collect();
            let mut batch = vec![0i32; n * fused.n_codes()];
            fused.hash_batch_into(&xs, n, &mut batch);
            let mut one = vec![0i32; fused.n_codes()];
            for q in 0..n {
                fused.hash_into(&xs[q * dim..(q + 1) * dim], &mut one);
                assert_eq!(
                    &batch[q * fused.n_codes()..(q + 1) * fused.n_codes()],
                    one.as_slice()
                );
            }
        });
    }

    #[test]
    #[should_panic]
    fn k_over_64_panics() {
        let fams = families(1, 4, 65, 1);
        let _ = FusedSrpHasher::from_families(&fams);
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        let fams = families(2, 8, 4, 1);
        let fused = FusedSrpHasher::from_families(&fams);
        let mut out = vec![0i32; fused.n_codes()];
        fused.hash_into(&[0.0; 5], &mut out);
    }
}

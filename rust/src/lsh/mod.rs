//! The L2LSH hash family (Datar et al. 2004) — Eq. 8 of the paper.
//!
//! `h_{a,b}(x) = floor((aᵀx + b) / r)` with `a ~ N(0, I)` and
//! `b ~ Uniform[0, r)`.
//!
//! This pure-Rust implementation mirrors, bit-for-bit up to f32 rounding,
//! the Pallas kernel shipped in `artifacts/` (which computes
//! `floor(x @ (A/r) + b/r)`); integration tests cross-check the two.

pub mod family;
pub mod fused;
pub mod srp;

pub use family::L2LshFamily;
pub use fused::FusedHasher;
pub use srp::SrpFamily;

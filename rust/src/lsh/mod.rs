//! The L2LSH hash family (Datar et al. 2004) — Eq. 8 of the paper.
//!
//! `h_{a,b}(x) = floor((aᵀx + b) / r)` with `a ~ N(0, I)` and
//! `b ~ Uniform[0, r)`.
//!
//! This pure-Rust implementation mirrors, bit-for-bit up to f32 rounding,
//! the Pallas kernel shipped in `artifacts/` (which computes
//! `floor(x @ (A/r) + b/r)`); integration tests cross-check the two.
//!
//! The sign-random-projection family ([`SrpFamily`], SimHash) and its
//! fused multi-table twin ([`FusedSrpHasher`]) serve the SRP-based
//! schemes (Sign-ALSH, Simple-LSH) behind
//! [`crate::index::MipsHashScheme`].

pub mod family;
pub mod fused;
pub mod fused_srp;
pub mod srp;

pub use family::L2LshFamily;
pub use fused::FusedHasher;
pub use fused_srp::FusedSrpHasher;
pub use srp::SrpFamily;

//! Serving metrics: counters + a log-bucketed latency histogram, all
//! lock-free atomics so the hot path never blocks on observability.
//!
//! Besides the query/batch/error counters the serving tier records its
//! overload behaviour: `shed` (rejected at admission), `deadline_exceeded`
//! (expired before a result), `degraded_queries` (served under a reduced
//! probe budget), `pjrt_fallbacks` (batches the circuit breaker routed to
//! the fused CPU path), and a live `queue_depth` gauge the
//! [`super::admission::LoadController`] reads as its fill signal.
//!
//! A mutable engine additionally publishes the live-tier gauges
//! (`delta_items`, `tombstones`, `compactions`, `wal_bytes`,
//! `last_compaction_ms`) via [`Metrics::record_live_stats`] — refreshed
//! by [`super::MipsEngine::metrics_snapshot`] so background-compactor
//! progress is visible without an intervening mutation.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::index::LiveStats;

/// Number of log2 latency buckets. Bucket 0 covers `[0, 2)` µs (the
/// sub-microsecond samples — explicitly, not via clamping); bucket
/// `i ≥ 1` covers `[2^i, 2^(i+1))` µs.
const N_BUCKETS: usize = 24;

/// Process-wide serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub queries: AtomicU64,
    pub batches: AtomicU64,
    pub batched_queries: AtomicU64,
    pub candidates: AtomicU64,
    pub errors: AtomicU64,
    /// Queries rejected at admission (queue full or ladder at shed).
    pub shed: AtomicU64,
    /// Queries whose deadline expired before a result was produced.
    pub deadline_exceeded: AtomicU64,
    /// Queries served under a reduced probe budget.
    pub degraded_queries: AtomicU64,
    /// Batches served by the fused CPU path because the PJRT backend
    /// failed (breaker open or in-flight failure).
    pub pjrt_fallbacks: AtomicU64,
    /// Hedged backup dispatches fired by the replicated router (primary
    /// replica exceeded the hedge delay or died on dispatch).
    pub hedge_fires: AtomicU64,
    /// Merged replies returned with less than full shard coverage.
    pub partial_replies: AtomicU64,
    /// Replicas quarantined by the integrity scrubber (section checksum
    /// failure).
    pub replica_quarantines: AtomicU64,
    /// Quarantined replicas repaired (rebuilt + re-verified) and
    /// re-admitted through their breaker.
    pub replica_repairs: AtomicU64,
    /// Live admission-queue depth (gauge, not a counter).
    queue_depth: AtomicU64,
    /// Live-tier gauges (all zero on a frozen engine): rows in the
    /// mutable delta, dead rows awaiting compaction, compactions run,
    /// current WAL length, and the last compaction's wall time.
    pub delta_items: AtomicU64,
    pub tombstones: AtomicU64,
    pub compactions: AtomicU64,
    pub wal_bytes: AtomicU64,
    pub last_compaction_ms: AtomicU64,
    latency_us: [AtomicU64; N_BUCKETS],
    latency_sum_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one served query with its end-to-end latency and candidate
    /// count.
    pub fn record_query(&self, latency_us: u64, n_candidates: usize) {
        self.queries.fetch_add(1, Ordering::Relaxed);
        self.candidates.fetch_add(n_candidates as u64, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(latency_us, Ordering::Relaxed);
        // `latency_us < 2` (including 0) lands in bucket 0 explicitly;
        // everything else in its log2 bucket, clamped to the last one.
        let bucket = if latency_us < 2 {
            0
        } else {
            (63 - latency_us.leading_zeros() as usize).min(N_BUCKETS - 1)
        };
        self.latency_us[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one dispatched batch of `n` queries.
    pub fn record_batch(&self, n: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_queries.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// A query was rejected at admission (shed).
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// A query's deadline expired before a result was produced.
    pub fn record_deadline_exceeded(&self) {
        self.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
    }

    /// A query was served under a reduced probe budget.
    pub fn record_degraded(&self) {
        self.degraded_queries.fetch_add(1, Ordering::Relaxed);
    }

    /// A batch was routed to the fused CPU path after PJRT failure.
    pub fn record_pjrt_fallback(&self) {
        self.pjrt_fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    /// The replicated router fired a hedged backup dispatch.
    pub fn record_hedge_fire(&self) {
        self.hedge_fires.fetch_add(1, Ordering::Relaxed);
    }

    /// A merged reply went out with partial shard coverage.
    pub fn record_partial_reply(&self) {
        self.partial_replies.fetch_add(1, Ordering::Relaxed);
    }

    /// The scrubber quarantined a replica on checksum failure.
    pub fn record_replica_quarantine(&self) {
        self.replica_quarantines.fetch_add(1, Ordering::Relaxed);
    }

    /// A quarantined replica was repaired and re-admitted.
    pub fn record_replica_repair(&self) {
        self.replica_repairs.fetch_add(1, Ordering::Relaxed);
    }

    /// A query entered the admission queue.
    pub fn record_queue_push(&self) {
        self.queue_depth.fetch_add(1, Ordering::Relaxed);
    }

    /// A query left the admission queue. Saturating: a pop without a
    /// matched push (e.g. drained during shutdown) never wraps the gauge.
    pub fn record_queue_pop(&self) {
        let _ = self.queue_depth.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| {
            Some(d.saturating_sub(1))
        });
    }

    /// Live admission-queue depth.
    pub fn queue_depth(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed)
    }

    /// Publish the live tier's point-in-time counters as gauges.
    pub fn record_live_stats(&self, s: &LiveStats) {
        self.delta_items.store(s.delta_items, Ordering::Relaxed);
        self.tombstones.store(s.tombstones, Ordering::Relaxed);
        self.compactions.store(s.compactions, Ordering::Relaxed);
        self.wal_bytes.store(s.wal_bytes, Ordering::Relaxed);
        self.last_compaction_ms.store(s.last_compaction_ms, Ordering::Relaxed);
    }

    /// Consistent-enough snapshot for reporting.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let queries = self.queries.load(Ordering::Relaxed);
        let hist: Vec<u64> =
            self.latency_us.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        MetricsSnapshot {
            queries,
            batches: self.batches.load(Ordering::Relaxed),
            batched_queries: self.batched_queries.load(Ordering::Relaxed),
            candidates: self.candidates.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            deadline_exceeded: self.deadline_exceeded.load(Ordering::Relaxed),
            degraded_queries: self.degraded_queries.load(Ordering::Relaxed),
            pjrt_fallbacks: self.pjrt_fallbacks.load(Ordering::Relaxed),
            hedge_fires: self.hedge_fires.load(Ordering::Relaxed),
            partial_replies: self.partial_replies.load(Ordering::Relaxed),
            replica_quarantines: self.replica_quarantines.load(Ordering::Relaxed),
            replica_repairs: self.replica_repairs.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            delta_items: self.delta_items.load(Ordering::Relaxed),
            tombstones: self.tombstones.load(Ordering::Relaxed),
            compactions: self.compactions.load(Ordering::Relaxed),
            wal_bytes: self.wal_bytes.load(Ordering::Relaxed),
            last_compaction_ms: self.last_compaction_ms.load(Ordering::Relaxed),
            mean_latency_us: if queries > 0 {
                self.latency_sum_us.load(Ordering::Relaxed) as f64 / queries as f64
            } else {
                0.0
            },
            p50_latency_us: percentile(&hist, 0.50),
            p99_latency_us: percentile(&hist, 0.99),
        }
    }
}

/// A standalone lock-free log2 latency histogram with [`Metrics`]'
/// exact bucketing, for components that track their own tail
/// distribution — e.g. the replicated router keeps one per shard so the
/// hedge delay can be derived from that shard's measured p99 rather
/// than a process-wide mixture.
#[derive(Debug, Default)]
pub struct LatencyHist {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
}

impl LatencyHist {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, latency_us: u64) {
        let bucket = if latency_us < 2 {
            0
        } else {
            (63 - latency_us.leading_zeros() as usize).min(N_BUCKETS - 1)
        };
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Lower bound (µs) of the bucket holding the `p`-quantile; 0 when
    /// nothing has been recorded.
    pub fn percentile_us(&self, p: f64) -> u64 {
        let hist: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        percentile(&hist, p)
    }
}

fn percentile(hist: &[u64], p: f64) -> u64 {
    let total: u64 = hist.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = ((total as f64) * p).ceil() as u64;
    let mut seen = 0;
    for (i, &c) in hist.iter().enumerate() {
        seen += c;
        if seen >= target {
            // Lower bound of the bucket; bucket 0 is [0, 2) µs.
            return if i == 0 { 0 } else { 1u64 << i };
        }
    }
    1u64 << (hist.len() - 1)
}

/// Point-in-time metrics view.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub queries: u64,
    pub batches: u64,
    pub batched_queries: u64,
    pub candidates: u64,
    pub errors: u64,
    pub shed: u64,
    pub deadline_exceeded: u64,
    pub degraded_queries: u64,
    pub pjrt_fallbacks: u64,
    pub hedge_fires: u64,
    pub partial_replies: u64,
    pub replica_quarantines: u64,
    pub replica_repairs: u64,
    pub queue_depth: u64,
    pub delta_items: u64,
    pub tombstones: u64,
    pub compactions: u64,
    pub wal_bytes: u64,
    pub last_compaction_ms: u64,
    pub mean_latency_us: f64,
    pub p50_latency_us: u64,
    pub p99_latency_us: u64,
}

impl MetricsSnapshot {
    /// Mean batch occupancy (dynamic-batching effectiveness).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_queries as f64 / self.batches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_mean() {
        let m = Metrics::new();
        m.record_query(100, 5);
        m.record_query(300, 15);
        let s = m.snapshot();
        assert_eq!(s.queries, 2);
        assert_eq!(s.candidates, 20);
        assert!((s.mean_latency_us - 200.0).abs() < 1e-9);
    }

    #[test]
    fn percentiles_monotone() {
        let m = Metrics::new();
        for i in 0..1000u64 {
            m.record_query(i + 1, 0);
        }
        let s = m.snapshot();
        assert!(s.p50_latency_us <= s.p99_latency_us);
        assert!(s.p50_latency_us >= 256, "p50 {}", s.p50_latency_us);
        assert!(s.p99_latency_us >= 512, "p99 {}", s.p99_latency_us);
    }

    #[test]
    fn batch_occupancy() {
        let m = Metrics::new();
        m.record_batch(10);
        m.record_batch(20);
        assert!((m.snapshot().mean_batch_size() - 15.0).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.p50_latency_us, 0);
        assert_eq!(s.mean_latency_us, 0.0);
        assert_eq!(s.shed, 0);
        assert_eq!(s.queue_depth, 0);
    }

    #[test]
    fn zero_latency_buckets_explicitly() {
        let m = Metrics::new();
        // All sub-2µs samples — including the literal 0 — land in bucket
        // 0, so the p50 reports the bucket's true lower bound of 0.
        m.record_query(0, 0);
        m.record_query(1, 0);
        let s = m.snapshot();
        assert_eq!(s.queries, 2);
        assert_eq!(s.p50_latency_us, 0);
        assert_eq!(s.p99_latency_us, 0);
        // 2µs is the first sample outside bucket 0.
        m.record_query(2, 0);
        m.record_query(2, 0);
        m.record_query(2, 0);
        assert_eq!(m.snapshot().p99_latency_us, 2);
    }

    #[test]
    fn robustness_counters_and_gauge() {
        let m = Metrics::new();
        m.record_shed();
        m.record_shed();
        m.record_deadline_exceeded();
        m.record_degraded();
        m.record_pjrt_fallback();
        m.record_queue_push();
        m.record_queue_push();
        m.record_queue_pop();
        let s = m.snapshot();
        assert_eq!(s.shed, 2);
        assert_eq!(s.deadline_exceeded, 1);
        assert_eq!(s.degraded_queries, 1);
        assert_eq!(s.pjrt_fallbacks, 1);
        assert_eq!(s.queue_depth, 1);
        // The gauge saturates at zero instead of wrapping.
        m.record_queue_pop();
        m.record_queue_pop();
        assert_eq!(m.queue_depth(), 0);
    }

    #[test]
    fn replica_counters() {
        let m = Metrics::new();
        m.record_hedge_fire();
        m.record_partial_reply();
        m.record_partial_reply();
        m.record_replica_quarantine();
        m.record_replica_repair();
        let s = m.snapshot();
        assert_eq!(s.hedge_fires, 1);
        assert_eq!(s.partial_replies, 2);
        assert_eq!(s.replica_quarantines, 1);
        assert_eq!(s.replica_repairs, 1);
    }

    #[test]
    fn latency_hist_matches_metrics_bucketing() {
        let h = LatencyHist::new();
        assert_eq!(h.percentile_us(0.99), 0);
        for i in 0..1000u64 {
            h.record(i + 1);
        }
        assert_eq!(h.count(), 1000);
        assert!(h.percentile_us(0.50) <= h.percentile_us(0.99));
        assert!(h.percentile_us(0.99) >= 512);
        let m = Metrics::new();
        for i in 0..1000u64 {
            m.record_query(i + 1, 0);
        }
        assert_eq!(h.percentile_us(0.99), m.snapshot().p99_latency_us);
    }

    #[test]
    fn live_gauges_overwrite_not_accumulate() {
        let m = Metrics::new();
        m.record_live_stats(&LiveStats {
            delta_items: 3,
            tombstones: 2,
            compactions: 1,
            wal_bytes: 640,
            last_compaction_ms: 12,
            generation: 1,
            n_items: 100,
        });
        m.record_live_stats(&LiveStats {
            delta_items: 0,
            tombstones: 0,
            compactions: 2,
            wal_bytes: 0,
            last_compaction_ms: 9,
            generation: 2,
            n_items: 100,
        });
        let s = m.snapshot();
        assert_eq!(s.delta_items, 0);
        assert_eq!(s.tombstones, 0);
        assert_eq!(s.compactions, 2);
        assert_eq!(s.wal_bytes, 0);
        assert_eq!(s.last_compaction_ms, 9);
    }
}

//! Serving metrics: counters + log-bucketed latency histograms, all
//! lock-free atomics so the hot path never blocks on observability.
//!
//! Besides the query/batch/error counters the serving tier records its
//! overload behaviour: `shed` (rejected at admission), `deadline_exceeded`
//! (expired before a result), `degraded_queries` (served under a reduced
//! probe budget), `pjrt_fallbacks` (batches the circuit breaker routed to
//! the fused CPU path), and a live `queue_depth` gauge the
//! [`super::admission::LoadController`] reads as its fill signal.
//!
//! Since PR 9 the end-to-end histogram is decomposed per pipeline stage:
//! one [`LatencyHist`] per [`Stage`] plus candidate-flow counters, fed by
//! the tracing layer ([`super::trace`]) at the point each stage is
//! measured, and a [`TraceRecorder`] holding the sampled-span ring and
//! slow-query log. Percentile estimates interpolate linearly within the
//! winning log2 bucket, so a 1900µs p99 reports ≈1900 rather than
//! snapping to the bucket lower bound of 1024.
//!
//! A mutable engine additionally publishes the live-tier gauges
//! (`delta_items`, `tombstones`, `compactions`, `wal_bytes`,
//! `last_compaction_ms`) via [`Metrics::record_live_stats`] — refreshed
//! by [`super::MipsEngine::metrics_snapshot`] so background-compactor
//! progress is visible without an intervening mutation.

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use super::trace::{Stage, TraceRecorder, N_STAGES};
use crate::index::LiveStats;

/// Number of log2 latency buckets. Bucket 0 covers `[0, 2)` µs (the
/// sub-microsecond samples — explicitly, not via clamping); bucket
/// `i ≥ 1` covers `[2^i, 2^(i+1))` µs.
pub const N_BUCKETS: usize = 24;

/// Process-wide serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub queries: AtomicU64,
    pub batches: AtomicU64,
    pub batched_queries: AtomicU64,
    pub candidates: AtomicU64,
    pub errors: AtomicU64,
    /// Queries rejected at admission (queue full or ladder at shed).
    pub shed: AtomicU64,
    /// Queries whose deadline expired before a result was produced.
    pub deadline_exceeded: AtomicU64,
    /// Queries served under a reduced probe budget.
    pub degraded_queries: AtomicU64,
    /// Batches served by the fused CPU path because the PJRT backend
    /// failed (breaker open or in-flight failure).
    pub pjrt_fallbacks: AtomicU64,
    /// Hedged backup dispatches fired by the replicated router (primary
    /// replica exceeded the hedge delay or died on dispatch).
    pub hedge_fires: AtomicU64,
    /// Merged replies returned with less than full shard coverage.
    pub partial_replies: AtomicU64,
    /// Replicas quarantined by the integrity scrubber (section checksum
    /// failure).
    pub replica_quarantines: AtomicU64,
    /// Quarantined replicas repaired (rebuilt + re-verified) and
    /// re-admitted through their breaker.
    pub replica_repairs: AtomicU64,
    /// Replicated mutations acknowledged at (or above) their shard's
    /// write quorum.
    pub writes_replicated: AtomicU64,
    /// Mutations refused with a structured `write_stalled` (delta cap
    /// reached — backpressure, not failure).
    pub write_stalled: AtomicU64,
    /// Replicated mutations that reached fewer member acks than the
    /// write quorum (not acknowledged to the client).
    pub quorum_failures: AtomicU64,
    /// Lagging members caught up by WAL-suffix replay from a peer (full
    /// rebuild fallbacks count as `replica_repairs` instead).
    pub catch_up_replays: AtomicU64,
    /// Candidates produced by the probe stage (candidate-flow counter).
    pub candidates_probed: AtomicU64,
    /// Candidates scored by the exact rerank (candidate-flow counter).
    pub candidates_reranked: AtomicU64,
    /// Live admission-queue depth (gauge, not a counter).
    queue_depth: AtomicU64,
    /// Live-tier gauges (all zero on a frozen engine): rows in the
    /// mutable delta, dead rows awaiting compaction, compactions run,
    /// current WAL length, and the last compaction's wall time.
    pub delta_items: AtomicU64,
    pub tombstones: AtomicU64,
    pub compactions: AtomicU64,
    pub wal_bytes: AtomicU64,
    pub last_compaction_ms: AtomicU64,
    latency_us: [AtomicU64; N_BUCKETS],
    latency_sum_us: AtomicU64,
    /// Per-stage latency histograms, indexed by `Stage as usize`.
    stages: [LatencyHist; N_STAGES],
    /// Sampled span ring + slow-query log for this front end.
    pub tracer: TraceRecorder,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one served query with its end-to-end latency and candidate
    /// count.
    pub fn record_query(&self, latency_us: u64, n_candidates: usize) {
        self.queries.fetch_add(1, Ordering::Relaxed);
        self.candidates.fetch_add(n_candidates as u64, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(latency_us, Ordering::Relaxed);
        self.latency_us[bucket_of(latency_us)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one dispatched batch of `n` queries.
    pub fn record_batch(&self, n: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_queries.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// A query was rejected at admission (shed).
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// A query's deadline expired before a result was produced.
    pub fn record_deadline_exceeded(&self) {
        self.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
    }

    /// A query was served under a reduced probe budget.
    pub fn record_degraded(&self) {
        self.degraded_queries.fetch_add(1, Ordering::Relaxed);
    }

    /// A batch was routed to the fused CPU path after PJRT failure.
    pub fn record_pjrt_fallback(&self) {
        self.pjrt_fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    /// The replicated router fired a hedged backup dispatch.
    pub fn record_hedge_fire(&self) {
        self.hedge_fires.fetch_add(1, Ordering::Relaxed);
    }

    /// A merged reply went out with partial shard coverage.
    pub fn record_partial_reply(&self) {
        self.partial_replies.fetch_add(1, Ordering::Relaxed);
    }

    /// The scrubber quarantined a replica on checksum failure.
    pub fn record_replica_quarantine(&self) {
        self.replica_quarantines.fetch_add(1, Ordering::Relaxed);
    }

    /// A quarantined replica was repaired and re-admitted.
    pub fn record_replica_repair(&self) {
        self.replica_repairs.fetch_add(1, Ordering::Relaxed);
    }

    /// A replicated mutation was acknowledged at quorum.
    pub fn record_write_replicated(&self) {
        self.writes_replicated.fetch_add(1, Ordering::Relaxed);
    }

    /// A mutation was refused with structured backpressure.
    pub fn record_write_stalled(&self) {
        self.write_stalled.fetch_add(1, Ordering::Relaxed);
    }

    /// A replicated mutation missed its write quorum.
    pub fn record_quorum_failure(&self) {
        self.quorum_failures.fetch_add(1, Ordering::Relaxed);
    }

    /// A lagging member was caught up by WAL-suffix replay.
    pub fn record_catch_up_replay(&self) {
        self.catch_up_replays.fetch_add(1, Ordering::Relaxed);
    }

    /// A query entered the admission queue.
    pub fn record_queue_push(&self) {
        self.queue_depth.fetch_add(1, Ordering::Relaxed);
    }

    /// A query left the admission queue. Saturating: a pop without a
    /// matched push (e.g. drained during shutdown) never wraps the gauge.
    pub fn record_queue_pop(&self) {
        let _ = self.queue_depth.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| {
            Some(d.saturating_sub(1))
        });
    }

    /// Live admission-queue depth.
    pub fn queue_depth(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed)
    }

    /// Record one stage timing into that stage's aggregate histogram.
    /// Called by whichever component *measures* the stage, at measure
    /// time, so each stage is counted exactly once per query.
    pub fn record_stage(&self, stage: Stage, us: u64) {
        self.stages[stage as usize].record(us);
    }

    /// Aggregate histogram for one pipeline stage.
    pub fn stage_hist(&self, stage: Stage) -> &LatencyHist {
        &self.stages[stage as usize]
    }

    /// Record the candidate flow of one query (probed → reranked).
    pub fn record_candidate_flow(&self, probed: u64, reranked: u64) {
        self.candidates_probed.fetch_add(probed, Ordering::Relaxed);
        self.candidates_reranked.fetch_add(reranked, Ordering::Relaxed);
    }

    /// Publish the live tier's point-in-time counters as gauges.
    pub fn record_live_stats(&self, s: &LiveStats) {
        self.delta_items.store(s.delta_items, Ordering::Relaxed);
        self.tombstones.store(s.tombstones, Ordering::Relaxed);
        self.compactions.store(s.compactions, Ordering::Relaxed);
        self.wal_bytes.store(s.wal_bytes, Ordering::Relaxed);
        self.last_compaction_ms.store(s.last_compaction_ms, Ordering::Relaxed);
    }

    /// Consistent-enough snapshot for reporting.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let queries = self.queries.load(Ordering::Relaxed);
        let mut latency_buckets = [0u64; N_BUCKETS];
        for (dst, b) in latency_buckets.iter_mut().zip(self.latency_us.iter()) {
            *dst = b.load(Ordering::Relaxed);
        }
        let mut stage_buckets = [[0u64; N_BUCKETS]; N_STAGES];
        for (dst, h) in stage_buckets.iter_mut().zip(self.stages.iter()) {
            *dst = h.buckets_snapshot();
        }
        let latency_sum_us = self.latency_sum_us.load(Ordering::Relaxed);
        MetricsSnapshot {
            queries,
            batches: self.batches.load(Ordering::Relaxed),
            batched_queries: self.batched_queries.load(Ordering::Relaxed),
            candidates: self.candidates.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            deadline_exceeded: self.deadline_exceeded.load(Ordering::Relaxed),
            degraded_queries: self.degraded_queries.load(Ordering::Relaxed),
            pjrt_fallbacks: self.pjrt_fallbacks.load(Ordering::Relaxed),
            hedge_fires: self.hedge_fires.load(Ordering::Relaxed),
            partial_replies: self.partial_replies.load(Ordering::Relaxed),
            replica_quarantines: self.replica_quarantines.load(Ordering::Relaxed),
            replica_repairs: self.replica_repairs.load(Ordering::Relaxed),
            writes_replicated: self.writes_replicated.load(Ordering::Relaxed),
            write_stalled: self.write_stalled.load(Ordering::Relaxed),
            quorum_failures: self.quorum_failures.load(Ordering::Relaxed),
            catch_up_replays: self.catch_up_replays.load(Ordering::Relaxed),
            candidates_probed: self.candidates_probed.load(Ordering::Relaxed),
            candidates_reranked: self.candidates_reranked.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            delta_items: self.delta_items.load(Ordering::Relaxed),
            tombstones: self.tombstones.load(Ordering::Relaxed),
            compactions: self.compactions.load(Ordering::Relaxed),
            wal_bytes: self.wal_bytes.load(Ordering::Relaxed),
            last_compaction_ms: self.last_compaction_ms.load(Ordering::Relaxed),
            mean_latency_us: if queries > 0 {
                latency_sum_us as f64 / queries as f64
            } else {
                0.0
            },
            p50_latency_us: percentile(&latency_buckets, 0.50),
            p99_latency_us: percentile(&latency_buckets, 0.99),
            latency_sum_us,
            latency_buckets,
            stage_buckets,
        }
    }
}

/// A standalone lock-free log2 latency histogram with [`Metrics`]'
/// exact bucketing, for components that track their own tail
/// distribution — e.g. the replicated router keeps one per shard so the
/// hedge delay can be derived from that shard's measured p99 rather
/// than a process-wide mixture.
#[derive(Debug, Default)]
pub struct LatencyHist {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
}

impl LatencyHist {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, latency_us: u64) {
        self.buckets[bucket_of(latency_us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Estimate of the `p`-quantile in µs (linear interpolation within
    /// the winning log2 bucket); 0 when nothing has been recorded.
    pub fn percentile_us(&self, p: f64) -> u64 {
        percentile(&self.buckets_snapshot(), p)
    }

    /// Point-in-time copy of the bucket counts.
    pub fn buckets_snapshot(&self) -> [u64; N_BUCKETS] {
        let mut out = [0u64; N_BUCKETS];
        for (dst, b) in out.iter_mut().zip(self.buckets.iter()) {
            *dst = b.load(Ordering::Relaxed);
        }
        out
    }

    /// Interval quantile: the `p`-quantile of only the samples recorded
    /// since `prev` was last captured, updating `prev` to the current
    /// buckets. `None` when the interval holds no samples. This is what
    /// a rate limiter should read — the cumulative
    /// [`LatencyHist::percentile_us`] never recovers from one slow
    /// phase, so gating on it would defer forever.
    pub fn interval_percentile_us(&self, prev: &mut [u64; N_BUCKETS], p: f64) -> Option<u64> {
        let now = self.buckets_snapshot();
        let mut diff = [0u64; N_BUCKETS];
        for (d, (n, pv)) in diff.iter_mut().zip(now.iter().zip(prev.iter())) {
            *d = n.saturating_sub(*pv);
        }
        *prev = now;
        let total: u64 = diff.iter().sum();
        (total > 0).then(|| percentile(&diff, p))
    }
}

/// Log2 bucket index shared by every histogram in this module.
fn bucket_of(latency_us: u64) -> usize {
    // `latency_us < 2` (including 0) lands in bucket 0 explicitly;
    // everything else in its log2 bucket, clamped to the last one.
    if latency_us < 2 {
        0
    } else {
        (63 - latency_us.leading_zeros() as usize).min(N_BUCKETS - 1)
    }
}

/// Quantile estimate over log2 buckets with linear interpolation inside
/// the winning bucket (midpoint-rank convention). Bucket 0 is `[0, 2)`
/// and reports its true lower bound of 0; every other bucket `[2^i,
/// 2^(i+1))` distributes its count uniformly, so the estimate never
/// snaps to a power of two.
fn percentile(hist: &[u64], p: f64) -> u64 {
    let total: u64 = hist.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = (((total as f64) * p).ceil() as u64).clamp(1, total);
    let mut seen = 0u64;
    for (i, &c) in hist.iter().enumerate() {
        if c > 0 && seen + c >= target {
            if i == 0 {
                return 0;
            }
            let lower = 1u64 << i;
            let rank = (target - seen) as f64 - 0.5;
            let est = lower as f64 + lower as f64 * (rank / c as f64);
            return (est as u64).clamp(lower, (lower << 1) - 1);
        }
        seen += c;
    }
    1u64 << (hist.len() - 1)
}

/// Point-in-time metrics view.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub queries: u64,
    pub batches: u64,
    pub batched_queries: u64,
    pub candidates: u64,
    pub errors: u64,
    pub shed: u64,
    pub deadline_exceeded: u64,
    pub degraded_queries: u64,
    pub pjrt_fallbacks: u64,
    pub hedge_fires: u64,
    pub partial_replies: u64,
    pub replica_quarantines: u64,
    pub replica_repairs: u64,
    pub writes_replicated: u64,
    pub write_stalled: u64,
    pub quorum_failures: u64,
    pub catch_up_replays: u64,
    pub candidates_probed: u64,
    pub candidates_reranked: u64,
    pub queue_depth: u64,
    pub delta_items: u64,
    pub tombstones: u64,
    pub compactions: u64,
    pub wal_bytes: u64,
    pub last_compaction_ms: u64,
    pub mean_latency_us: f64,
    pub p50_latency_us: u64,
    pub p99_latency_us: u64,
    /// Sum of all recorded end-to-end latencies (µs).
    pub latency_sum_us: u64,
    /// Raw end-to-end histogram buckets (log2, see [`N_BUCKETS`]).
    pub latency_buckets: [u64; N_BUCKETS],
    /// Raw per-stage histogram buckets, indexed by `Stage as usize`.
    pub stage_buckets: [[u64; N_BUCKETS]; N_STAGES],
}

impl MetricsSnapshot {
    /// Mean batch occupancy (dynamic-batching effectiveness).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_queries as f64 / self.batches as f64
        }
    }

    /// Observations recorded for one pipeline stage.
    pub fn stage_count(&self, stage: Stage) -> u64 {
        self.stage_buckets[stage as usize].iter().sum()
    }

    /// Interpolated `p`-quantile (µs) for one pipeline stage.
    pub fn stage_percentile_us(&self, stage: Stage, p: f64) -> u64 {
        percentile(&self.stage_buckets[stage as usize], p)
    }

    /// Interval view: everything that happened after `earlier` was taken.
    /// Counters (including histogram buckets) subtract saturating, so a
    /// restarted or wrapped counter yields 0 rather than a huge bogus
    /// delta; gauges (`queue_depth` and the live-tier gauges) keep this
    /// snapshot's latest value since "the queue depth that happened in
    /// the interval" is not a meaningful quantity. Latency statistics
    /// (mean/p50/p99) are recomputed from the diffed buckets, so they
    /// describe only the interval's queries.
    pub fn delta(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        let queries = self.queries.saturating_sub(earlier.queries);
        let latency_sum_us = self.latency_sum_us.saturating_sub(earlier.latency_sum_us);
        let mut latency_buckets = [0u64; N_BUCKETS];
        for (i, dst) in latency_buckets.iter_mut().enumerate() {
            *dst = self.latency_buckets[i].saturating_sub(earlier.latency_buckets[i]);
        }
        let mut stage_buckets = [[0u64; N_BUCKETS]; N_STAGES];
        for (s, dst) in stage_buckets.iter_mut().enumerate() {
            for (i, b) in dst.iter_mut().enumerate() {
                *b = self.stage_buckets[s][i].saturating_sub(earlier.stage_buckets[s][i]);
            }
        }
        MetricsSnapshot {
            queries,
            batches: self.batches.saturating_sub(earlier.batches),
            batched_queries: self.batched_queries.saturating_sub(earlier.batched_queries),
            candidates: self.candidates.saturating_sub(earlier.candidates),
            errors: self.errors.saturating_sub(earlier.errors),
            shed: self.shed.saturating_sub(earlier.shed),
            deadline_exceeded: self.deadline_exceeded.saturating_sub(earlier.deadline_exceeded),
            degraded_queries: self.degraded_queries.saturating_sub(earlier.degraded_queries),
            pjrt_fallbacks: self.pjrt_fallbacks.saturating_sub(earlier.pjrt_fallbacks),
            hedge_fires: self.hedge_fires.saturating_sub(earlier.hedge_fires),
            partial_replies: self.partial_replies.saturating_sub(earlier.partial_replies),
            replica_quarantines: self
                .replica_quarantines
                .saturating_sub(earlier.replica_quarantines),
            replica_repairs: self.replica_repairs.saturating_sub(earlier.replica_repairs),
            writes_replicated: self.writes_replicated.saturating_sub(earlier.writes_replicated),
            write_stalled: self.write_stalled.saturating_sub(earlier.write_stalled),
            quorum_failures: self.quorum_failures.saturating_sub(earlier.quorum_failures),
            catch_up_replays: self.catch_up_replays.saturating_sub(earlier.catch_up_replays),
            candidates_probed: self.candidates_probed.saturating_sub(earlier.candidates_probed),
            candidates_reranked: self
                .candidates_reranked
                .saturating_sub(earlier.candidates_reranked),
            // Gauges: keep the latest observed value.
            queue_depth: self.queue_depth,
            delta_items: self.delta_items,
            tombstones: self.tombstones,
            wal_bytes: self.wal_bytes,
            last_compaction_ms: self.last_compaction_ms,
            // `compactions` counts compactions run, so it diffs like a
            // counter even though the live tier publishes it as a gauge.
            compactions: self.compactions.saturating_sub(earlier.compactions),
            mean_latency_us: if queries > 0 {
                latency_sum_us as f64 / queries as f64
            } else {
                0.0
            },
            p50_latency_us: percentile(&latency_buckets, 0.50),
            p99_latency_us: percentile(&latency_buckets, 0.99),
            latency_sum_us,
            latency_buckets,
            stage_buckets,
        }
    }

    /// Queries per second over a measured wall-clock interval (pair with
    /// [`MetricsSnapshot::delta`]).
    pub fn qps(&self, wall: Duration) -> f64 {
        let secs = wall.as_secs_f64();
        if secs > 0.0 {
            self.queries as f64 / secs
        } else {
            0.0
        }
    }

    /// Fraction of offered queries rejected at admission, where offered =
    /// served + shed + deadline-exceeded + errored.
    pub fn shed_rate(&self) -> f64 {
        let offered = self.queries + self.shed + self.deadline_exceeded + self.errors;
        if offered > 0 {
            self.shed as f64 / offered as f64
        } else {
            0.0
        }
    }

    /// The full snapshot in Prometheus text exposition format
    /// (version 0.0.4): counters as `_total`, gauges bare, the
    /// end-to-end histogram with cumulative `le` buckets, and per-stage
    /// quantile summaries.
    pub fn prometheus_text(&self) -> String {
        let mut out = String::with_capacity(4096);
        let counters: [(&str, u64, &str); 20] = [
            ("alsh_queries_total", self.queries, "Queries served."),
            ("alsh_batches_total", self.batches, "Hash batches dispatched."),
            ("alsh_batched_queries_total", self.batched_queries, "Queries carried by batches."),
            ("alsh_candidates_total", self.candidates, "Candidates produced (legacy counter)."),
            (
                "alsh_candidates_probed_total",
                self.candidates_probed,
                "Candidates produced by the probe stage.",
            ),
            (
                "alsh_candidates_reranked_total",
                self.candidates_reranked,
                "Candidates scored by the exact rerank.",
            ),
            ("alsh_errors_total", self.errors, "Queries that failed."),
            ("alsh_shed_total", self.shed, "Queries rejected at admission."),
            (
                "alsh_deadline_exceeded_total",
                self.deadline_exceeded,
                "Queries expired before a result.",
            ),
            (
                "alsh_degraded_queries_total",
                self.degraded_queries,
                "Queries served under a reduced probe budget.",
            ),
            (
                "alsh_pjrt_fallbacks_total",
                self.pjrt_fallbacks,
                "Batches served by the fused CPU fallback.",
            ),
            ("alsh_hedge_fires_total", self.hedge_fires, "Hedged backup dispatches."),
            (
                "alsh_partial_replies_total",
                self.partial_replies,
                "Replies with partial shard coverage.",
            ),
            (
                "alsh_replica_quarantines_total",
                self.replica_quarantines,
                "Replicas quarantined on checksum failure.",
            ),
            (
                "alsh_replica_repairs_total",
                self.replica_repairs,
                "Quarantined replicas repaired and re-admitted.",
            ),
            (
                "alsh_writes_replicated_total",
                self.writes_replicated,
                "Replicated mutations acknowledged at quorum.",
            ),
            (
                "alsh_write_stalled_total",
                self.write_stalled,
                "Mutations refused with structured backpressure.",
            ),
            (
                "alsh_quorum_failures_total",
                self.quorum_failures,
                "Replicated mutations that missed their write quorum.",
            ),
            (
                "alsh_catch_up_replays_total",
                self.catch_up_replays,
                "Lagging members caught up by WAL-suffix replay.",
            ),
            ("alsh_compactions_total", self.compactions, "Live-tier compactions run."),
        ];
        for (name, value, help) in counters {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {value}");
        }
        let gauges: [(&str, u64, &str); 5] = [
            ("alsh_queue_depth", self.queue_depth, "Live admission-queue depth."),
            ("alsh_delta_items", self.delta_items, "Rows in the mutable delta."),
            ("alsh_tombstones", self.tombstones, "Dead rows awaiting compaction."),
            ("alsh_wal_bytes", self.wal_bytes, "Current WAL length in bytes."),
            (
                "alsh_last_compaction_ms",
                self.last_compaction_ms,
                "Wall time of the last compaction.",
            ),
        ];
        for (name, value, help) in gauges {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {value}");
        }
        let _ = writeln!(out, "# HELP alsh_latency_us End-to-end query latency.");
        let _ = writeln!(out, "# TYPE alsh_latency_us histogram");
        let mut cumulative = 0u64;
        for (i, &c) in self.latency_buckets.iter().enumerate() {
            cumulative += c;
            if i == N_BUCKETS - 1 {
                let _ = writeln!(out, "alsh_latency_us_bucket{{le=\"+Inf\"}} {cumulative}");
            } else {
                let le = 1u64 << (i + 1);
                let _ = writeln!(out, "alsh_latency_us_bucket{{le=\"{le}\"}} {cumulative}");
            }
        }
        let _ = writeln!(out, "alsh_latency_us_sum {}", self.latency_sum_us);
        let _ = writeln!(out, "alsh_latency_us_count {cumulative}");
        let _ = writeln!(out, "# HELP alsh_stage_latency_us Per-stage latency attribution.");
        let _ = writeln!(out, "# TYPE alsh_stage_latency_us summary");
        for stage in Stage::ALL {
            let name = stage.name();
            let p50 = self.stage_percentile_us(stage, 0.50);
            let p99 = self.stage_percentile_us(stage, 0.99);
            let n = self.stage_count(stage);
            let _ = writeln!(out, "alsh_stage_latency_us{{stage=\"{name}\",quantile=\"0.5\"}} {p50}");
            let _ =
                writeln!(out, "alsh_stage_latency_us{{stage=\"{name}\",quantile=\"0.99\"}} {p99}");
            let _ = writeln!(out, "alsh_stage_latency_us_count{{stage=\"{name}\"}} {n}");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_mean() {
        let m = Metrics::new();
        m.record_query(100, 5);
        m.record_query(300, 15);
        let s = m.snapshot();
        assert_eq!(s.queries, 2);
        assert_eq!(s.candidates, 20);
        assert!((s.mean_latency_us - 200.0).abs() < 1e-9);
    }

    #[test]
    fn percentiles_monotone() {
        let m = Metrics::new();
        for i in 0..1000u64 {
            m.record_query(i + 1, 0);
        }
        let s = m.snapshot();
        assert!(s.p50_latency_us <= s.p99_latency_us);
        assert!(s.p50_latency_us >= 256, "p50 {}", s.p50_latency_us);
        assert!(s.p99_latency_us >= 512, "p99 {}", s.p99_latency_us);
    }

    #[test]
    fn percentiles_interpolate_within_bucket() {
        // Uniform 1..=1000µs: the true p50 is 500, deep inside bucket 8
        // ([256, 512)). The interpolated estimate should land near it
        // instead of snapping to the bucket lower bound.
        let m = Metrics::new();
        for i in 0..1000u64 {
            m.record_query(i + 1, 0);
        }
        let s = m.snapshot();
        assert!(
            (495..=505).contains(&s.p50_latency_us),
            "interpolated p50 {} should be ≈500",
            s.p50_latency_us
        );
        // A point mass at 1900µs (bucket 10, [1024, 2048)): the p99 must
        // stay inside the bucket, not report the lower bound 1024.
        let m = Metrics::new();
        for _ in 0..100 {
            m.record_query(1900, 0);
        }
        let s = m.snapshot();
        assert!(
            s.p99_latency_us > 1024 && s.p99_latency_us < 2048,
            "p99 {} should interpolate within [1024, 2048)",
            s.p99_latency_us
        );
    }

    #[test]
    fn batch_occupancy() {
        let m = Metrics::new();
        m.record_batch(10);
        m.record_batch(20);
        assert!((m.snapshot().mean_batch_size() - 15.0).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.p50_latency_us, 0);
        assert_eq!(s.mean_latency_us, 0.0);
        assert_eq!(s.shed, 0);
        assert_eq!(s.queue_depth, 0);
    }

    #[test]
    fn zero_latency_buckets_explicitly() {
        let m = Metrics::new();
        // All sub-2µs samples — including the literal 0 — land in bucket
        // 0, so the p50 reports the bucket's true lower bound of 0.
        m.record_query(0, 0);
        m.record_query(1, 0);
        let s = m.snapshot();
        assert_eq!(s.queries, 2);
        assert_eq!(s.p50_latency_us, 0);
        assert_eq!(s.p99_latency_us, 0);
        // 2µs is the first sample outside bucket 0: the p99 moves into
        // bucket 1 ([2, 4)µs) and interpolates within it.
        m.record_query(2, 0);
        m.record_query(2, 0);
        m.record_query(2, 0);
        let p99 = m.snapshot().p99_latency_us;
        assert!((2..4).contains(&p99), "p99 {p99} should sit in bucket 1 [2, 4)");
    }

    #[test]
    fn robustness_counters_and_gauge() {
        let m = Metrics::new();
        m.record_shed();
        m.record_shed();
        m.record_deadline_exceeded();
        m.record_degraded();
        m.record_pjrt_fallback();
        m.record_queue_push();
        m.record_queue_push();
        m.record_queue_pop();
        let s = m.snapshot();
        assert_eq!(s.shed, 2);
        assert_eq!(s.deadline_exceeded, 1);
        assert_eq!(s.degraded_queries, 1);
        assert_eq!(s.pjrt_fallbacks, 1);
        assert_eq!(s.queue_depth, 1);
        // The gauge saturates at zero instead of wrapping.
        m.record_queue_pop();
        m.record_queue_pop();
        assert_eq!(m.queue_depth(), 0);
    }

    #[test]
    fn replica_counters() {
        let m = Metrics::new();
        m.record_hedge_fire();
        m.record_partial_reply();
        m.record_partial_reply();
        m.record_replica_quarantine();
        m.record_replica_repair();
        let s = m.snapshot();
        assert_eq!(s.hedge_fires, 1);
        assert_eq!(s.partial_replies, 2);
        assert_eq!(s.replica_quarantines, 1);
        assert_eq!(s.replica_repairs, 1);
    }

    #[test]
    fn interval_percentile_diffs_and_resets() {
        let h = LatencyHist::new();
        let mut prev = [0u64; N_BUCKETS];
        assert_eq!(h.interval_percentile_us(&mut prev, 0.99), None);
        for _ in 0..100 {
            h.record(6000);
        }
        let p = h.interval_percentile_us(&mut prev, 0.99).unwrap();
        assert!((4096..8192).contains(&p), "interval p99 {p} in bucket 12");
        // The slow phase is consumed: a fast follow-up interval reports
        // fast, where the cumulative view would stay slow.
        for _ in 0..100 {
            h.record(100);
        }
        let p = h.interval_percentile_us(&mut prev, 0.99).unwrap();
        assert!(p < 256, "interval p99 {p} should forget the slow phase");
        assert!(h.percentile_us(0.99) >= 4096, "cumulative view stays slow");
        assert_eq!(h.interval_percentile_us(&mut prev, 0.99), None, "empty interval");
    }

    #[test]
    fn write_path_counters() {
        let m = Metrics::new();
        m.record_write_replicated();
        m.record_write_replicated();
        m.record_write_stalled();
        m.record_quorum_failure();
        m.record_catch_up_replay();
        let earlier = m.snapshot();
        assert_eq!(earlier.writes_replicated, 2);
        assert_eq!(earlier.write_stalled, 1);
        assert_eq!(earlier.quorum_failures, 1);
        assert_eq!(earlier.catch_up_replays, 1);
        m.record_write_replicated();
        let d = m.snapshot().delta(&earlier);
        assert_eq!(d.writes_replicated, 1, "write counters diff like counters");
        assert_eq!(d.write_stalled, 0);
        let text = m.snapshot().prometheus_text();
        assert!(text.contains("alsh_writes_replicated_total 3"));
        assert!(text.contains("alsh_write_stalled_total 1"));
        assert!(text.contains("alsh_quorum_failures_total 1"));
        assert!(text.contains("alsh_catch_up_replays_total 1"));
    }

    #[test]
    fn latency_hist_matches_metrics_bucketing() {
        let h = LatencyHist::new();
        assert_eq!(h.percentile_us(0.99), 0);
        for i in 0..1000u64 {
            h.record(i + 1);
        }
        assert_eq!(h.count(), 1000);
        assert!(h.percentile_us(0.50) <= h.percentile_us(0.99));
        assert!(h.percentile_us(0.99) >= 512);
        let m = Metrics::new();
        for i in 0..1000u64 {
            m.record_query(i + 1, 0);
        }
        assert_eq!(h.percentile_us(0.99), m.snapshot().p99_latency_us);
    }

    #[test]
    fn live_gauges_overwrite_not_accumulate() {
        let m = Metrics::new();
        m.record_live_stats(&LiveStats {
            delta_items: 3,
            tombstones: 2,
            compactions: 1,
            wal_bytes: 640,
            last_compaction_ms: 12,
            generation: 1,
            n_items: 100,
            high_water: 5,
        });
        m.record_live_stats(&LiveStats {
            delta_items: 0,
            tombstones: 0,
            compactions: 2,
            wal_bytes: 0,
            last_compaction_ms: 9,
            generation: 2,
            n_items: 100,
            high_water: 8,
        });
        let s = m.snapshot();
        assert_eq!(s.delta_items, 0);
        assert_eq!(s.tombstones, 0);
        assert_eq!(s.compactions, 2);
        assert_eq!(s.wal_bytes, 0);
        assert_eq!(s.last_compaction_ms, 9);
    }

    #[test]
    fn stage_hists_record_and_report() {
        let m = Metrics::new();
        for _ in 0..100 {
            m.record_stage(Stage::Hash, 800);
            m.record_stage(Stage::Probe, 100);
            m.record_stage(Stage::Rerank, 0);
        }
        m.record_candidate_flow(5000, 1200);
        let s = m.snapshot();
        assert_eq!(s.stage_count(Stage::Hash), 100);
        assert_eq!(s.stage_count(Stage::Merge), 0, "unfed stage stays empty");
        let hash_p99 = s.stage_percentile_us(Stage::Hash, 0.99);
        assert!((512..1024).contains(&hash_p99), "hash p99 {hash_p99} in bucket 9");
        assert_eq!(s.stage_percentile_us(Stage::Rerank, 0.99), 0);
        assert!(hash_p99 > s.stage_percentile_us(Stage::Probe, 0.99));
        assert_eq!(s.candidates_probed, 5000);
        assert_eq!(s.candidates_reranked, 1200);
        // The standalone accessor matches the snapshot view.
        assert_eq!(m.stage_hist(Stage::Hash).count(), 100);
    }

    #[test]
    fn delta_subtracts_counters_and_recomputes_latency() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.record_query(100, 3);
        }
        m.record_shed();
        let earlier = m.snapshot();
        for _ in 0..10 {
            m.record_query(6400, 7);
        }
        m.record_shed();
        m.record_shed();
        m.record_stage(Stage::Hash, 6000);
        let d = m.snapshot().delta(&earlier);
        assert_eq!(d.queries, 10);
        assert_eq!(d.candidates, 70);
        assert_eq!(d.shed, 2);
        assert_eq!(d.stage_count(Stage::Hash), 1);
        // Interval latency reflects only the 6400µs queries — the earlier
        // 100µs population is subtracted out of the buckets.
        assert!(
            d.p50_latency_us >= 4096,
            "interval p50 {} must ignore pre-interval queries",
            d.p50_latency_us
        );
        assert!((d.mean_latency_us - 6400.0).abs() < 1e-9);
    }

    #[test]
    fn delta_is_wrap_safe_and_keeps_gauges() {
        let m = Metrics::new();
        m.record_query(50, 0);
        m.record_queue_push();
        m.record_live_stats(&LiveStats {
            delta_items: 7,
            tombstones: 1,
            compactions: 4,
            wal_bytes: 512,
            last_compaction_ms: 3,
            generation: 1,
            n_items: 10,
            high_water: 2,
        });
        let earlier = m.snapshot();
        // A "later" snapshot from a fresh process (counter reset): every
        // diffed counter saturates to 0 instead of wrapping to ~u64::MAX.
        let fresh = Metrics::new();
        fresh.record_queue_push();
        fresh.record_queue_push();
        let d = fresh.snapshot().delta(&earlier);
        assert_eq!(d.queries, 0);
        assert_eq!(d.latency_sum_us, 0);
        assert_eq!(d.p99_latency_us, 0);
        assert!(d.latency_buckets.iter().all(|&b| b == 0));
        // Gauges keep the latest snapshot's value, not a difference.
        assert_eq!(d.queue_depth, 2);
        assert_eq!(d.delta_items, 0, "fresh process reports its own gauge");
        // And on the same process, gauges still read latest.
        let d2 = m.snapshot().delta(&earlier);
        assert_eq!(d2.queue_depth, 1);
        assert_eq!(d2.delta_items, 7);
        assert_eq!(d2.compactions, 0, "compactions diffs like a counter");
    }

    #[test]
    fn qps_and_shed_rate() {
        let m = Metrics::new();
        for _ in 0..80 {
            m.record_query(10, 0);
        }
        for _ in 0..20 {
            m.record_shed();
        }
        let s = m.snapshot();
        assert!((s.qps(Duration::from_secs(2)) - 40.0).abs() < 1e-9);
        assert!((s.shed_rate() - 0.2).abs() < 1e-9);
        assert_eq!(Metrics::new().snapshot().shed_rate(), 0.0);
        assert_eq!(s.qps(Duration::ZERO), 0.0);
    }

    #[test]
    fn prometheus_text_exposition() {
        let m = Metrics::new();
        m.record_query(100, 5);
        m.record_query(3000, 5);
        m.record_shed();
        m.record_stage(Stage::Hash, 900);
        m.record_candidate_flow(10, 4);
        let text = m.snapshot().prometheus_text();
        assert!(text.contains("# TYPE alsh_queries_total counter"));
        assert!(text.contains("alsh_queries_total 2"));
        assert!(text.contains("alsh_shed_total 1"));
        assert!(text.contains("# TYPE alsh_queue_depth gauge"));
        assert!(text.contains("# TYPE alsh_latency_us histogram"));
        assert!(text.contains("alsh_latency_us_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("alsh_latency_us_sum 3100"));
        assert!(text.contains("alsh_latency_us_count 2"));
        assert!(text.contains("alsh_stage_latency_us{stage=\"hash\",quantile=\"0.99\"}"));
        assert!(text.contains("alsh_stage_latency_us_count{stage=\"hash\"} 1"));
        assert!(text.contains("alsh_candidates_probed_total 10"));
        // Cumulative le buckets are monotone non-decreasing.
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.starts_with("alsh_latency_us_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "non-monotone cumulative bucket: {line}");
            last = v;
        }
    }
}

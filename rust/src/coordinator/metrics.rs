//! Serving metrics: counters + a log-bucketed latency histogram, all
//! lock-free atomics so the hot path never blocks on observability.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of log2 latency buckets: bucket i covers [2^i, 2^(i+1)) µs.
const N_BUCKETS: usize = 24;

/// Process-wide serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub queries: AtomicU64,
    pub batches: AtomicU64,
    pub batched_queries: AtomicU64,
    pub candidates: AtomicU64,
    pub errors: AtomicU64,
    latency_us: [AtomicU64; N_BUCKETS],
    latency_sum_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one served query with its end-to-end latency and candidate
    /// count.
    pub fn record_query(&self, latency_us: u64, n_candidates: usize) {
        self.queries.fetch_add(1, Ordering::Relaxed);
        self.candidates.fetch_add(n_candidates as u64, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(latency_us, Ordering::Relaxed);
        let bucket = (64 - latency_us.max(1).leading_zeros() as usize - 1).min(N_BUCKETS - 1);
        self.latency_us[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one dispatched batch of `n` queries.
    pub fn record_batch(&self, n: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_queries.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Consistent-enough snapshot for reporting.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let queries = self.queries.load(Ordering::Relaxed);
        let hist: Vec<u64> =
            self.latency_us.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        MetricsSnapshot {
            queries,
            batches: self.batches.load(Ordering::Relaxed),
            batched_queries: self.batched_queries.load(Ordering::Relaxed),
            candidates: self.candidates.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            mean_latency_us: if queries > 0 {
                self.latency_sum_us.load(Ordering::Relaxed) as f64 / queries as f64
            } else {
                0.0
            },
            p50_latency_us: percentile(&hist, 0.50),
            p99_latency_us: percentile(&hist, 0.99),
        }
    }
}

fn percentile(hist: &[u64], p: f64) -> u64 {
    let total: u64 = hist.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = ((total as f64) * p).ceil() as u64;
    let mut seen = 0;
    for (i, &c) in hist.iter().enumerate() {
        seen += c;
        if seen >= target {
            return 1u64 << i; // lower bound of the bucket
        }
    }
    1u64 << (hist.len() - 1)
}

/// Point-in-time metrics view.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub queries: u64,
    pub batches: u64,
    pub batched_queries: u64,
    pub candidates: u64,
    pub errors: u64,
    pub mean_latency_us: f64,
    pub p50_latency_us: u64,
    pub p99_latency_us: u64,
}

impl MetricsSnapshot {
    /// Mean batch occupancy (dynamic-batching effectiveness).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_queries as f64 / self.batches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_mean() {
        let m = Metrics::new();
        m.record_query(100, 5);
        m.record_query(300, 15);
        let s = m.snapshot();
        assert_eq!(s.queries, 2);
        assert_eq!(s.candidates, 20);
        assert!((s.mean_latency_us - 200.0).abs() < 1e-9);
    }

    #[test]
    fn percentiles_monotone() {
        let m = Metrics::new();
        for i in 0..1000u64 {
            m.record_query(i + 1, 0);
        }
        let s = m.snapshot();
        assert!(s.p50_latency_us <= s.p99_latency_us);
        assert!(s.p50_latency_us >= 256, "p50 {}", s.p50_latency_us);
        assert!(s.p99_latency_us >= 512, "p99 {}", s.p99_latency_us);
    }

    #[test]
    fn batch_occupancy() {
        let m = Metrics::new();
        m.record_batch(10);
        m.record_batch(20);
        assert!((m.snapshot().mean_batch_size() - 15.0).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.p50_latency_us, 0);
        assert_eq!(s.mean_latency_us, 0.0);
    }
}

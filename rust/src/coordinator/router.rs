//! Sharded scatter/gather router — §3.7 ("Parallelization") of the paper:
//! each node keeps its own hash tables over an item shard; a query fans
//! out, each shard answers locally, and the final top-k is a cheap merge.
//!
//! Since PR 8 each shard is a **replica group** (see
//! [`super::replica`]): R engines over the same item range with
//! distinct hash seeds. The replicated query path
//! ([`ShardedRouter::query_replicated`]) scatters to each group's
//! primary through per-member worker threads, **tail-hedges** to a
//! backup replica when the primary exceeds a p99-derived hedge delay,
//! enforces a per-shard timeout, and tracks per-member health with
//! circuit breakers. A shard whose whole group is down does not hang
//! the query: the merge returns a **partial result** with explicit
//! coverage accounting ([`RouterReply`]). The synchronous paths
//! ([`ShardedRouter::query_into`] & co.) keep their allocation-free
//! contract by querying each group's first healthy member directly on
//! the caller's scratch — at R = 1 they behave exactly as before.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::index::scratch::with_thread_scratch;
use crate::index::storage::{Mapped, Owned, Storage};
use crate::index::{
    open_mmap_verified, AlshIndex, AlshParams, AnyIndex, BandedParams, NormRangeIndex,
    PersistFormat, ProbeBudget, QueryScratch, ScoredItem,
};

use super::batcher::BreakerState;
use super::engine::MipsEngine;
use super::metrics::Metrics;
use super::replica::{
    corrupt_index_file, lock, ReplicaConfig, ReplicaGroup, ReplicaStorage, ShardFaultPlan,
};
use super::trace::{QuerySpans, Stage, FLAG_DEGRADED, FLAG_HEDGED, FLAG_PARTIAL};

/// A collection of shard replica groups with global-id translation —
/// heap-built shards (the default), zero-copy mapped shards
/// ([`ShardedRouter::open_mmap_shards`]), or file-backed replicated
/// deployments ([`ShardedRouter::create_replicated`]).
pub struct ShardedRouter<S: Storage = Owned> {
    groups: Vec<ReplicaGroup<S>>,
    /// Global id of shard s's local item 0.
    offsets: Vec<u32>,
    dim: usize,
    cfg: ReplicaConfig,
    /// Router-level serving metrics (hedges, partial replies, scrub
    /// events, replicated-query latency). Per-engine metrics stay on
    /// the member engines.
    metrics: Arc<Metrics>,
    scrub_stop: Arc<AtomicBool>,
    scrubber: Mutex<Option<JoinHandle<()>>>,
}

/// A replicated scatter/gather answer with coverage accounting: when
/// every member of some shard's group is down or timed out, the reply
/// still goes out — `degraded`, with the missing range disclosed via
/// `shards_answered`/`shards_total` — instead of hanging or silently
/// pretending full coverage.
#[derive(Clone, Debug)]
pub struct RouterReply {
    /// Merged global top-k over the shards that answered.
    pub hits: Vec<ScoredItem>,
    pub shards_answered: usize,
    pub shards_total: usize,
    /// At least one shard answered through a hedged backup dispatch.
    pub hedge_fired: bool,
    /// `shards_answered < shards_total`: some item range is missing.
    pub degraded: bool,
}

impl RouterReply {
    /// Fraction of shards that contributed to `hits` (1.0 = full
    /// coverage).
    pub fn coverage_fraction(&self) -> f64 {
        if self.shards_total == 0 {
            0.0
        } else {
            self.shards_answered as f64 / self.shards_total as f64
        }
    }
}

/// What one scrub pass ([`ShardedRouter::scrub_now`]) saw and did.
/// Entries are `(shard, member)` coordinates.
#[derive(Clone, Debug, Default)]
pub struct ScrubReport {
    /// File-backed members whose sections were checksum-walked.
    pub checked: usize,
    /// Members whose file failed verification (quarantined).
    pub corrupted: Vec<(usize, usize)>,
    /// Subset of `corrupted` rebuilt, re-verified, and re-admitted.
    pub repaired: Vec<(usize, usize)>,
    /// Repairs that could not complete (with the error); the member
    /// stays quarantined for the next pass.
    pub failed: Vec<(usize, usize, String)>,
}

impl ShardedRouter {
    /// Split `items` into `n_shards` contiguous shards and build one
    /// flat engine per shard (distinct hash seeds per shard, as each
    /// "node" maintains its own hash functions).
    pub fn build(items: &[Vec<f32>], n_shards: usize, params: AlshParams, seed: u64) -> Self {
        Self::build_impl(items, n_shards, 1, ReplicaConfig::default(), seed, |chunk, s| {
            MipsEngine::new(chunk, params, s)
        })
    }

    /// [`ShardedRouter::build`] with norm-range banded engines per shard:
    /// each shard partitions *its* items into norm bands with per-band U
    /// scaling (shard norm profiles differ, so per-shard banding is the
    /// natural fit).
    pub fn build_banded(
        items: &[Vec<f32>],
        n_shards: usize,
        params: AlshParams,
        banded: BandedParams,
        seed: u64,
    ) -> Self {
        Self::build_impl(items, n_shards, 1, ReplicaConfig::default(), seed, |chunk, s| {
            MipsEngine::new_banded(chunk, params, banded, s)
        })
    }

    /// [`ShardedRouter::build`] with `n_replicas` members per shard
    /// group, all in-memory (no backing files, so the scrubber has
    /// nothing to walk — use [`ShardedRouter::create_replicated`] for
    /// the scrubbed deployment shape).
    pub fn build_replicated(
        items: &[Vec<f32>],
        n_shards: usize,
        n_replicas: usize,
        params: AlshParams,
        cfg: ReplicaConfig,
        seed: u64,
    ) -> Self {
        Self::build_impl(items, n_shards, n_replicas, cfg, seed, |chunk, s| {
            MipsEngine::new(chunk, params, s)
        })
    }

    /// [`ShardedRouter::build_replicated`] with banded member engines.
    pub fn build_replicated_banded(
        items: &[Vec<f32>],
        n_shards: usize,
        n_replicas: usize,
        params: AlshParams,
        banded: BandedParams,
        cfg: ReplicaConfig,
        seed: u64,
    ) -> Self {
        Self::build_impl(items, n_shards, n_replicas, cfg, seed, |chunk, s| {
            MipsEngine::new_banded(chunk, params, banded, s)
        })
    }

    /// Member seeds derive in exactly one place: member (s, r) hashes
    /// with `seed + s·R + r`, so every member of every group gets its
    /// own hash family (recall diversity across replicas, §3.7
    /// independence across shards). At R = 1 this is the historical
    /// `seed + s`, so single-replica builds reproduce pre-replication
    /// indexes bit for bit — and `make_engine` receives the final seed
    /// rather than deriving its own, which is what the audit in PR 8
    /// pinned down (the old closure-side `seed.wrapping_add(shard)`
    /// was correct but duplicated per call site; the property tests
    /// below now hold it in place).
    fn build_impl(
        items: &[Vec<f32>],
        n_shards: usize,
        n_replicas: usize,
        cfg: ReplicaConfig,
        seed: u64,
        make_engine: impl Fn(&[Vec<f32>], u64) -> MipsEngine,
    ) -> Self {
        assert!(n_shards >= 1 && n_replicas >= 1 && !items.is_empty());
        let dim = items[0].len();
        let per = items.len().div_ceil(n_shards);
        let mut groups = Vec::new();
        let mut offsets = Vec::new();
        for (s, chunk) in items.chunks(per).enumerate() {
            offsets.push((s * per) as u32);
            let members = (0..n_replicas)
                .map(|r| {
                    let member_seed = seed.wrapping_add((s * n_replicas + r) as u64);
                    (make_engine(chunk, member_seed), None, member_seed)
                })
                .collect();
            groups.push(ReplicaGroup::new(members, &cfg).expect("uniform member chunks"));
        }
        Self::from_groups(groups, offsets, dim, cfg)
    }
}

impl ShardedRouter<Mapped> {
    /// Assemble a router over per-shard v5 index files, each opened
    /// zero-copy (`MipsEngine::open_mmap`): the restart path for a
    /// sharded deployment — O(shards) opens, no postings byte copied,
    /// page-cache shared with any co-resident process. `paths[s]` must
    /// hold shard `s`'s items in the same contiguous-chunk order the
    /// build produced (global ids are reconstructed cumulatively, as in
    /// [`ShardedRouter::build`]).
    pub fn open_mmap_shards<P: AsRef<Path>>(paths: &[P]) -> crate::Result<Self> {
        anyhow::ensure!(!paths.is_empty(), "no shard files given");
        let mut engines = Vec::with_capacity(paths.len());
        for p in paths {
            engines.push(MipsEngine::<Mapped>::open_mmap(p)?);
        }
        Self::from_engines(engines)
    }
}

impl<S: ReplicaStorage> ShardedRouter<S> {
    /// Build every (shard, replica) index from `items`, persist each as
    /// a `V5Checked` file under `dir` (`shard{s}-rep{r}.alsh`), and
    /// serve the **verified** opens — the deployment shape the scrubber
    /// can watch and repair. Flat members, or banded when `banded` is
    /// set; storage (zero-copy mapped vs heap) chosen by `S`.
    #[allow(clippy::too_many_arguments)]
    pub fn create_replicated(
        dir: &Path,
        items: &[Vec<f32>],
        n_shards: usize,
        n_replicas: usize,
        params: AlshParams,
        banded: Option<BandedParams>,
        cfg: ReplicaConfig,
        seed: u64,
    ) -> crate::Result<Self> {
        anyhow::ensure!(
            n_shards >= 1 && n_replicas >= 1 && !items.is_empty(),
            "create_replicated: need at least one shard, one replica, and one item"
        );
        std::fs::create_dir_all(dir)?;
        let dim = items[0].len();
        let per = items.len().div_ceil(n_shards);
        let mut groups = Vec::new();
        let mut offsets = Vec::new();
        for (s, chunk) in items.chunks(per).enumerate() {
            offsets.push(u32::try_from(s * per).map_err(|_| {
                anyhow::anyhow!("total items across shards overflow u32 global ids")
            })?);
            let mut members = Vec::with_capacity(n_replicas);
            for r in 0..n_replicas {
                // Same member-seed derivation as `build_impl`.
                let member_seed = seed.wrapping_add((s * n_replicas + r) as u64);
                let path = dir.join(format!("shard{s}-rep{r}.alsh"));
                let index = match banded {
                    None => AnyIndex::Flat(AlshIndex::build(chunk, params, member_seed)),
                    Some(b) => {
                        AnyIndex::Banded(NormRangeIndex::build(chunk, params, b, member_seed))
                    }
                };
                index.save_as(&path, PersistFormat::V5Checked)?;
                members.push((S::open_verified(&path)?, Some(path), member_seed));
            }
            groups.push(ReplicaGroup::new(members, &cfg)?);
        }
        Ok(Self::from_groups(groups, offsets, dim, cfg))
    }

    /// One synchronous scrub pass: checksum-walk every file-backed
    /// member's sections (`open_mmap_verified`, O(file) per member — no
    /// section escapes the walk). A member whose file fails is
    /// **quarantined** (its breaker refuses traffic), **repaired** —
    /// re-opened if the on-disk bytes verify after all (an atomic
    /// re-save may have raced the failing read), else rebuilt from a
    /// healthy peer's items under the member's own seed, saved
    /// `V5Checked`, and re-verified — then **re-admitted** through its
    /// breaker. Members without a backing file are skipped. The
    /// background scrubber ([`ShardedRouter::spawn_scrubber`]) calls
    /// this on its cadence; tests and benches call it directly for
    /// determinism.
    pub fn scrub_now(&self) -> ScrubReport {
        let mut report = ScrubReport::default();
        for (s, g) in self.groups.iter().enumerate() {
            for (r, member) in g.members.iter().enumerate() {
                let Some(path) = &member.shared.path else { continue };
                report.checked += 1;
                if open_mmap_verified(path).is_ok() {
                    continue;
                }
                report.corrupted.push((s, r));
                member.shared.breaker.quarantine();
                self.metrics.record_replica_quarantine();
                match self.repair(g, r) {
                    Ok(()) => {
                        member.shared.breaker.readmit();
                        self.metrics.record_replica_repair();
                        report.repaired.push((s, r));
                    }
                    Err(e) => report.failed.push((s, r, format!("{e:#}"))),
                }
            }
        }
        report
    }

    /// Restore group member `r` from rot: prefer the surviving on-disk
    /// generation (re-verify — `save_as` is atomic, so a concurrent
    /// rewrite may have already replaced the rotten bytes), else
    /// rebuild from the first healthy, verifying peer's items with the
    /// member's own seed, save `V5Checked`, re-verify, and hot-swap the
    /// serving slot.
    fn repair(&self, g: &ReplicaGroup<S>, r: usize) -> crate::Result<()> {
        let member = &g.members[r];
        let path = member.shared.path.clone().expect("repair: file-backed member");
        if let Ok(engine) = S::open_verified(&path) {
            member.install(engine);
            return Ok(());
        }
        let donor = g.members.iter().enumerate().find(|(i, p)| {
            *i != r
                && !p.shared.breaker.is_quarantined()
                && p.shared.path.as_deref().is_none_or(|pp| open_mmap_verified(pp).is_ok())
        });
        let Some((_, donor)) = donor else {
            anyhow::bail!("replica repair: no healthy peer to rebuild from");
        };
        let donor_engine = donor.engine();
        let src = donor_engine.index();
        let mut items = Vec::with_capacity(src.n_items());
        for id in 0..src.n_items() as u32 {
            items.push(src.item(id).to_vec());
        }
        let params = *donor_engine.params();
        let rebuilt = match src.as_banded() {
            None => AnyIndex::Flat(AlshIndex::build(&items, params, member.shared.seed)),
            Some(b) => AnyIndex::Banded(NormRangeIndex::build(
                &items,
                params,
                BandedParams { n_bands: b.n_bands() },
                member.shared.seed,
            )),
        };
        rebuilt.save_as(&path, PersistFormat::V5Checked)?;
        member.install(S::open_verified(&path)?);
        Ok(())
    }

    /// Start the background scrubber: one full [`ShardedRouter::scrub_now`]
    /// pass every `interval` (the budget knob — a longer interval
    /// spreads the checksum I/O thinner). The thread holds only a
    /// `Weak` reference, so dropping the router ends it on its next
    /// wake-up; call [`ShardedRouter::stop_scrubber`] for a
    /// deterministic join. (An associated fn — `&Arc<Self>` is not a
    /// valid method receiver.)
    pub fn spawn_scrubber(router: &Arc<Self>, interval: Duration) {
        let weak = Arc::downgrade(router);
        let stop = Arc::clone(&router.scrub_stop);
        let handle = std::thread::Builder::new()
            .name("alsh-scrub".into())
            .spawn(move || loop {
                std::thread::sleep(interval);
                if stop.load(Ordering::Relaxed) {
                    return;
                }
                let Some(router) = weak.upgrade() else { return };
                let _ = router.scrub_now();
            })
            .expect("spawn scrubber");
        *lock(&router.scrubber) = Some(handle);
    }

    /// Stop and join the background scrubber (blocks at most one
    /// interval). Idempotent; a no-op if none was spawned.
    pub fn stop_scrubber(&self) {
        self.scrub_stop.store(true, Ordering::Relaxed);
        if let Some(handle) = lock(&self.scrubber).take() {
            let _ = handle.join();
        }
    }
}

/// Per-shard in-flight dispatch state for the replicated scatter.
/// Replies carry the answering member's [`QuerySpans`] so the gather
/// can attribute probe/rerank time to the winning replica.
struct Pending {
    tx: Sender<(usize, Vec<ScoredItem>, QuerySpans)>,
    rx: Receiver<(usize, Vec<ScoredItem>, QuerySpans)>,
    primary: Option<usize>,
    dispatched: Vec<usize>,
}

impl<S: Storage> ShardedRouter<S> {
    /// Assemble a router from pre-built (or pre-opened) shard engines,
    /// reconstructing the cumulative global-id offsets from the shard
    /// sizes. All shards must serve the same item dimension. Each
    /// engine becomes a single-member replica group with no backing
    /// file (so the scrubber skips it).
    pub fn from_engines(shards: Vec<MipsEngine<S>>) -> crate::Result<Self> {
        anyhow::ensure!(!shards.is_empty(), "no shard engines given");
        let cfg = ReplicaConfig::default();
        let dim = shards[0].dim();
        let mut offsets = Vec::with_capacity(shards.len());
        let mut groups = Vec::with_capacity(shards.len());
        let mut next = 0u64;
        for e in shards {
            anyhow::ensure!(e.dim() == dim, "shard dim {} != {dim}", e.dim());
            offsets.push(u32::try_from(next).map_err(|_| {
                anyhow::anyhow!("total items across shards overflow u32 global ids")
            })?);
            next += e.n_items() as u64;
            groups.push(ReplicaGroup::new(vec![(e, None, 0)], &cfg)?);
        }
        anyhow::ensure!(next <= u32::MAX as u64 + 1, "total items overflow u32 global ids");
        Ok(Self::from_groups(groups, offsets, dim, cfg))
    }

    fn from_groups(
        groups: Vec<ReplicaGroup<S>>,
        offsets: Vec<u32>,
        dim: usize,
        cfg: ReplicaConfig,
    ) -> Self {
        Self {
            groups,
            offsets,
            dim,
            cfg,
            metrics: Arc::new(Metrics::new()),
            scrub_stop: Arc::new(AtomicBool::new(false)),
            scrubber: Mutex::new(None),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.groups.len()
    }

    /// Item dimension served by every shard.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Replicas in shard `s`'s group.
    pub fn n_replicas(&self, s: usize) -> usize {
        self.groups[s].members.len()
    }

    /// Shard `s`'s first-healthy member engine (member 0 when every
    /// member is quarantined). Returns a clone of the serving `Arc` —
    /// the slot behind it is hot-swappable by the scrubber's repair.
    pub fn shard(&self, s: usize) -> Arc<MipsEngine<S>> {
        let g = &self.groups[s];
        g.members[g.pick_serving()].engine()
    }

    /// Router-level metrics (hedges, partial replies, scrub events,
    /// replicated-query latency).
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// The replica configuration this router dispatches under.
    pub fn replica_config(&self) -> &ReplicaConfig {
        &self.cfg
    }

    /// Per-member breaker states, indexed `[shard][member]`.
    pub fn breaker_states(&self) -> Vec<Vec<BreakerState>> {
        self.groups
            .iter()
            .map(|g| g.members.iter().map(|m| m.shared.breaker.state()).collect())
            .collect()
    }

    /// Per-shard answer-latency p99 gauges (µs; 0 until a shard has
    /// answered a replicated query).
    pub fn shard_p99_us(&self) -> Vec<u64> {
        self.groups.iter().map(|g| g.latency.percentile_us(0.99)).collect()
    }

    /// Install a fault plan on group `shard`'s member `member` (tests
    /// and benches only; defaults all-off).
    pub fn set_shard_faults(&self, shard: usize, member: usize, plan: ShardFaultPlan) {
        self.groups[shard].members[member].set_faults(plan);
    }

    /// The backing file of group `shard`'s member `member`, if any.
    pub fn replica_path(&self, shard: usize, member: usize) -> Option<PathBuf> {
        self.groups[shard].members[member].shared.path.clone()
    }

    /// Flip a corruption burst into the member's backing file (tests
    /// and benches; see `replica::corrupt_index_file`). Errors when the
    /// member has no backing file.
    pub fn corrupt_replica(&self, shard: usize, member: usize) -> crate::Result<()> {
        match self.replica_path(shard, member) {
            Some(path) => corrupt_index_file(&path),
            None => anyhow::bail!("replica ({shard}, {member}) has no backing file"),
        }
    }

    /// Scatter the query to all shards, gather local top-k lists, merge to
    /// the global top-k. The merge communicates only `k` scored ids per
    /// shard — the "one single number per node" economics of §3.7.
    ///
    /// Allocation-free: one caller-owned scratch serves every shard (its
    /// buffers grow to the largest shard once, then are reused). This
    /// path queries each group's first healthy member in-thread — no
    /// hedging or timeouts; use [`ShardedRouter::query_replicated`] for
    /// the fault-tolerant scatter.
    pub fn query_into<'s>(
        &self,
        query: &[f32],
        top_k: usize,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        self.query_budgeted_into(query, top_k, ProbeBudget::full(), s)
    }

    /// [`ShardedRouter::query_into`] with every shard probing under
    /// `budget` — the degraded serving path fans the same reduced budget
    /// out to all shards. Bit-identical to the plain path at
    /// [`ProbeBudget::full`].
    pub fn query_budgeted_into<'s>(
        &self,
        query: &[f32],
        top_k: usize,
        budget: ProbeBudget,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        assert_eq!(query.len(), self.dim);
        s.merged.clear();
        for (g, &off) in self.groups.iter().zip(&self.offsets) {
            let engine = g.members[g.pick_serving()].engine();
            let n = engine.query_budgeted_into(query, top_k, budget, s).len();
            for i in 0..n {
                let hit = s.top[i];
                s.merged.push(ScoredItem { id: hit.id + off, score: hit.score });
            }
        }
        s.merged.sort_unstable_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        s.merged.truncate(top_k);
        &s.merged
    }

    /// Allocating convenience wrapper over [`ShardedRouter::query_into`].
    pub fn query(&self, query: &[f32], top_k: usize) -> Vec<ScoredItem> {
        with_thread_scratch(|s| self.query_into(query, top_k, s).to_vec())
    }

    /// Allocating convenience wrapper over
    /// [`ShardedRouter::query_budgeted_into`].
    pub fn query_budgeted(
        &self,
        query: &[f32],
        top_k: usize,
        budget: ProbeBudget,
    ) -> Vec<ScoredItem> {
        with_thread_scratch(|s| self.query_budgeted_into(query, top_k, budget, s).to_vec())
    }

    /// The fault-tolerant scatter/gather: dispatch every shard's
    /// primary replica concurrently (each member serves on its own
    /// worker thread), then collect per shard — hedging to a backup
    /// member when the primary exceeds the hedge delay
    /// ([`ReplicaConfig::hedge_delay`], or derived from the shard's
    /// measured p99), walking away at [`ReplicaConfig::shard_timeout`].
    /// Member successes/failures feed the per-member breakers; a shard
    /// whose group never answers makes the reply partial rather than
    /// hanging it (see [`RouterReply`]).
    pub fn query_replicated(&self, query: &[f32], top_k: usize, budget: ProbeBudget) -> RouterReply {
        let mut spans = QuerySpans::default();
        let reply = self.query_replicated_traced(query, top_k, budget, &mut spans);
        self.metrics.tracer.offer(&spans);
        reply
    }

    /// [`ShardedRouter::query_replicated`] with caller-owned span
    /// attribution: per-member probe/rerank timings are absorbed from
    /// whichever replica answered each shard, the gather wait lands in
    /// [`Stage::ShardWait`], the sort/truncate in [`Stage::Merge`], and
    /// hedge/partial/degraded outcomes become span flags. The caller
    /// owns offering `spans` to a [`super::trace::TraceRecorder`] —
    /// this method only fills it in and feeds the stage aggregates.
    pub fn query_replicated_traced(
        &self,
        query: &[f32],
        top_k: usize,
        budget: ProbeBudget,
        spans: &mut QuerySpans,
    ) -> RouterReply {
        assert_eq!(query.len(), self.dim);
        let start = Instant::now();
        let q: Arc<[f32]> = Arc::from(query.to_vec());
        let shards_total = self.groups.len();

        // Scatter: every group's primary goes out before any collect
        // blocks, so one slow shard never delays another's dispatch.
        let mut pending = Vec::with_capacity(shards_total);
        for g in &self.groups {
            let (tx, rx) = mpsc::channel();
            let mut dispatched = Vec::new();
            let primary = g.pick_primary();
            if let Some(p) = primary {
                if g.members[p].dispatch(p, &q, top_k, budget, tx.clone()) {
                    dispatched.push(p);
                } else {
                    // Dead worker (crashed member): an instant failure.
                    g.members[p].shared.breaker.on_failure();
                }
            }
            pending.push(Pending { tx, rx, primary, dispatched });
        }

        // Gather, hedging stragglers.
        let mut hits: Vec<ScoredItem> = Vec::new();
        let mut shards_answered = 0usize;
        let mut hedge_fired = false;
        for ((g, &off), p) in self.groups.iter().zip(&self.offsets).zip(pending) {
            if let Some((shard_hits, fired, who, member_spans)) =
                self.collect_shard(g, &q, top_k, budget, start, p)
            {
                g.latency.record(start.elapsed().as_micros() as u64);
                hedge_fired |= fired;
                shards_answered += 1;
                spans.absorb_member(&member_spans);
                spans.winning_replica = who.min(u8::MAX as usize) as u8;
                hits.extend(
                    shard_hits.iter().map(|h| ScoredItem { id: h.id + off, score: h.score }),
                );
            }
        }
        let shard_wait_us = start.elapsed().as_micros() as u64;
        spans.set_stage(Stage::ShardWait, shard_wait_us);

        let merge_start = Instant::now();
        hits.sort_unstable_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        hits.truncate(top_k);
        let merge_us = merge_start.elapsed().as_micros() as u64;
        spans.set_stage(Stage::Merge, merge_us);

        let degraded = shards_answered < shards_total;
        if degraded {
            self.metrics.record_partial_reply();
            spans.set_flag(FLAG_PARTIAL);
            spans.set_flag(FLAG_DEGRADED);
        }
        if hedge_fired {
            spans.set_flag(FLAG_HEDGED);
        }
        spans.shards_answered = shards_answered.min(u8::MAX as usize) as u8;
        spans.shards_total = shards_total.min(u8::MAX as usize) as u8;
        spans.hits = hits.len().min(u16::MAX as usize) as u16;
        spans.top_k = top_k.min(u16::MAX as usize) as u16;
        spans.total_us = start.elapsed().as_micros() as u64;

        // Stage aggregates: the members' engines recorded probe/rerank
        // into their *own* metrics; re-record the absorbed values here
        // so the router's front-end histograms see them too.
        if let Some(us) = spans.stage(Stage::Probe) {
            self.metrics.record_stage(Stage::Probe, us);
        }
        if let Some(us) = spans.stage(Stage::Rerank) {
            self.metrics.record_stage(Stage::Rerank, us);
        }
        self.metrics.record_stage(Stage::ShardWait, shard_wait_us);
        self.metrics.record_stage(Stage::Merge, merge_us);
        self.metrics.record_candidate_flow(spans.candidates_probed, spans.candidates_reranked);
        self.metrics.record_query(start.elapsed().as_micros() as u64, 0);
        RouterReply { hits, shards_answered, shards_total, hedge_fired, degraded }
    }

    /// Collect one shard's answer: wait for the primary up to the hedge
    /// delay, dispatch one backup if it hasn't answered, then wait out
    /// the shard timeout for whoever replies first. Returns the winning
    /// hit list, whether a true hedge fired (backup dispatched while
    /// the primary was still in flight), the winning member index, and
    /// the winner's per-stage spans.
    fn collect_shard(
        &self,
        g: &ReplicaGroup<S>,
        q: &Arc<[f32]>,
        top_k: usize,
        budget: ProbeBudget,
        start: Instant,
        mut p: Pending,
    ) -> Option<(Vec<ScoredItem>, bool, usize, QuerySpans)> {
        let deadline = start + self.cfg.shard_timeout;
        let hedge_at = start + self.hedge_delay_for(g).min(self.cfg.shard_timeout);
        let mut hedge_fired = false;

        let mut winner: Option<(usize, Vec<ScoredItem>, QuerySpans)> = None;
        if !p.dispatched.is_empty() {
            winner = p.rx.recv_timeout(hedge_at.saturating_duration_since(Instant::now())).ok();
        }
        if winner.is_none() {
            // Hedge (or fail over a dead/denied primary): the next
            // admitted member. `pick_backup(len)` when there was no
            // primary at all degenerates to "first admitted member".
            let avoid = p.primary.unwrap_or(g.members.len());
            if let Some(b) = g.pick_backup(avoid) {
                if g.members[b].dispatch(b, q, top_k, budget, p.tx.clone()) {
                    if !p.dispatched.is_empty() {
                        hedge_fired = true;
                        self.metrics.record_hedge_fire();
                    }
                    p.dispatched.push(b);
                } else {
                    g.members[b].shared.breaker.on_failure();
                }
            }
        }
        // From here only in-flight jobs hold senders: a disconnect
        // means every dispatched worker died without replying.
        drop(p.tx);
        if winner.is_none() && !p.dispatched.is_empty() {
            winner = p.rx.recv_timeout(deadline.saturating_duration_since(Instant::now())).ok();
        }

        // Health accounting: the winner and any already-arrived loser
        // answered; members still outstanding when we walk away count a
        // failure (their late replies land in a dropped channel).
        let mut answered = vec![false; g.members.len()];
        if let Some((who, _, _)) = &winner {
            answered[*who] = true;
        }
        while let Ok((who, _, _)) = p.rx.try_recv() {
            answered[who] = true;
        }
        for &i in &p.dispatched {
            if answered[i] {
                g.members[i].shared.breaker.on_success();
            } else {
                g.members[i].shared.breaker.on_failure();
            }
        }
        winner.map(|(who, shard_hits, spans)| (shard_hits, hedge_fired, who, spans))
    }

    /// The hedge delay for one shard: the configured override, or
    /// `hedge_multiplier ×` the shard's measured answer p99 clamped to
    /// `[hedge_min, hedge_max]` (the lower clamp keeps a cold histogram
    /// from hedging every query).
    fn hedge_delay_for(&self, g: &ReplicaGroup<S>) -> Duration {
        if let Some(d) = self.cfg.hedge_delay {
            return d;
        }
        let p99 = g.latency.percentile_us(0.99);
        let scaled = (p99 as f64 * self.cfg.hedge_multiplier).round() as u64;
        Duration::from_micros(scaled).clamp(self.cfg.hedge_min, self.cfg.hedge_max)
    }

    /// Total queries served across all member engines.
    pub fn total_queries(&self) -> u64 {
        self.groups
            .iter()
            .flat_map(|g| g.members.iter())
            .map(|m| m.engine().metrics().snapshot().queries)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transform::dot;
    use crate::util::Rng;

    fn items(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n)
            .map(|i| {
                let s = 0.2 + 2.0 * (i as f32 / n as f32);
                (0..d).map(|_| (rng.f32() - 0.5) * s).collect()
            })
            .collect()
    }

    #[test]
    fn global_ids_score_correctly() {
        let its = items(400, 8, 1);
        let router = ShardedRouter::build(&its, 4, AlshParams::default(), 2);
        let q: Vec<f32> = (0..8).map(|i| (i as f32).sin()).collect();
        for hit in router.query(&q, 10) {
            let want = dot(&q, &its[hit.id as usize]);
            assert!((hit.score - want).abs() < 1e-6, "global id mis-translated");
        }
    }

    #[test]
    fn sharded_matches_single_shard_quality() {
        // With generous tables both configurations find the exact top-1
        // almost always; sharding must not lose it (it only adds tables).
        let its = items(600, 12, 3);
        let params = AlshParams { n_tables: 48, k_per_table: 4, ..Default::default() };
        let sharded = ShardedRouter::build(&its, 3, params, 4);
        let mut rng = Rng::seed_from_u64(5);
        let mut hits = 0;
        for _ in 0..30 {
            let q: Vec<f32> = (0..12).map(|_| rng.f32() - 0.5).collect();
            let want = (0..its.len())
                .max_by(|&a, &b| dot(&its[a], &q).partial_cmp(&dot(&its[b], &q)).unwrap())
                .unwrap() as u32;
            if sharded.query(&q, 10).iter().any(|h| h.id == want) {
                hits += 1;
            }
        }
        assert!(hits >= 27, "sharded top-1 recall {hits}/30");
    }

    #[test]
    fn scratch_path_equals_convenience_path() {
        let its = items(500, 10, 20);
        let router = ShardedRouter::build(&its, 4, AlshParams::default(), 21);
        let mut s = QueryScratch::new();
        let mut rng = Rng::seed_from_u64(22);
        for _ in 0..10 {
            let q: Vec<f32> = (0..10).map(|_| rng.normal_f32()).collect();
            let via_scratch = router.query_into(&q, 7, &mut s).to_vec();
            assert_eq!(via_scratch, router.query(&q, 7));
        }
    }

    #[test]
    fn banded_router_scores_global_ids_exactly() {
        let its = items(500, 8, 30);
        let router = ShardedRouter::build_banded(
            &its,
            4,
            AlshParams::default(),
            BandedParams { n_bands: 3 },
            31,
        );
        assert_eq!(router.n_shards(), 4);
        assert_eq!(router.shard(0).n_bands(), 3);
        let mut s = QueryScratch::new();
        let mut rng = Rng::seed_from_u64(32);
        for _ in 0..10 {
            let q: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
            let out = router.query_into(&q, 10, &mut s).to_vec();
            assert_eq!(out, router.query(&q, 10));
            for hit in &out {
                let want = dot(&q, &its[hit.id as usize]);
                assert!((hit.score - want).abs() < 1e-6, "global id mis-translated");
            }
        }
    }

    #[test]
    fn merge_is_globally_sorted() {
        let its = items(300, 6, 6);
        let router = ShardedRouter::build(&its, 5, AlshParams::default(), 7);
        let out = router.query(&vec![0.4; 6], 15);
        for w in out.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    /// A live shard routes next to frozen ones: the router only sees the
    /// engine query surface, so mutations on one shard show up in merged
    /// results with correctly translated global ids.
    #[test]
    fn live_shard_mutates_behind_router() {
        use crate::index::LiveConfig;
        let dir = std::env::temp_dir().join(format!(
            "alsh_router_live_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let its = items(200, 6, 60);
        let frozen = MipsEngine::new(&its[..100], AlshParams::default(), 61);
        let live = MipsEngine::create_live(
            &dir,
            &its[100..],
            LiveConfig { params: AlshParams::default(), n_bands: 1, seed: 61 },
        )
        .unwrap();
        let router = ShardedRouter::from_engines(vec![frozen, live]).unwrap();
        assert_eq!(router.n_shards(), 2);
        let q: Vec<f32> = (0..6).map(|i| (i as f32 * 0.43).cos()).collect();
        let before = router.query(&q, 10);
        assert!(before.iter().all(|h| (h.id as usize) < 200));
        // Mutate the live shard; shard-local ext id 7 dies, so global id
        // 107 must vanish from every later merged result.
        router.shard(1).delete(7).unwrap();
        let after = router.query(&q, 200);
        assert!(after.iter().all(|h| h.id != 107), "deleted item resurfaced");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn single_shard_degenerates_gracefully() {
        let its = items(100, 4, 8);
        let router = ShardedRouter::build(&its, 1, AlshParams::default(), 9);
        assert_eq!(router.n_shards(), 1);
        assert!(!router.query(&vec![0.1; 4], 5).is_empty());
    }

    #[test]
    fn uneven_shard_sizes() {
        let its = items(101, 4, 10);
        let router = ShardedRouter::build(&its, 4, AlshParams::default(), 11);
        // 101 items over 4 shards: 26+26+26+23
        assert_eq!(router.n_shards(), 4);
        let out = router.query(&vec![0.2; 4], 101);
        // Every returned id must be in range.
        assert!(out.iter().all(|h| (h.id as usize) < 101));
    }

    // -- PR 8: seed-derivation audit (satellite) ---------------------------

    /// Every shard must hash with its own family: `build_impl` derives
    /// member (s, r)'s seed as `seed + s·R + r` in exactly one place.
    /// This pins the derivation: shard families differ pairwise (their
    /// L2 offsets are fresh uniform draws per seed).
    #[test]
    fn per_shard_families_are_distinct() {
        let its = items(300, 6, 70);
        let router = ShardedRouter::build(&its, 3, AlshParams::default(), 71);
        for a in 0..3 {
            for b in (a + 1)..3 {
                assert_ne!(
                    router.shard(a).families()[0].b_vector(),
                    router.shard(b).families()[0].b_vector(),
                    "shards {a} and {b} share a hash family"
                );
            }
        }
        // Replicas within one group are families of their own too.
        let rep = ShardedRouter::build_replicated(
            &its,
            2,
            2,
            AlshParams::default(),
            ReplicaConfig::default(),
            71,
        );
        for s in 0..2 {
            let g0 = rep.shard(s).families()[0].b_vector().to_vec();
            // Member 1 = the backup: reach it via breaker_states shape
            // plus the internal accessor used by repair.
            assert_eq!(rep.n_replicas(s), 2);
            let g1 = rep.groups[s].members[1].engine().families()[0].b_vector().to_vec();
            assert_ne!(g0, g1, "replicas of shard {s} share a hash family");
        }
    }

    /// Identical inputs rebuild identical routers (merge determinism),
    /// and at R = 1 the replicated builder is bit-compatible with the
    /// historical per-shard seeding, so shard-count changes reshuffle
    /// ranges but never scores.
    #[test]
    fn build_is_deterministic_and_r1_matches_legacy_seeding() {
        let its = items(240, 6, 72);
        let q: Vec<f32> = (0..6).map(|i| (i as f32 * 0.7).sin()).collect();
        let a = ShardedRouter::build(&its, 3, AlshParams::default(), 73);
        let b = ShardedRouter::build(&its, 3, AlshParams::default(), 73);
        assert_eq!(a.query(&q, 20), b.query(&q, 20), "rebuild changed results");
        let r1 = ShardedRouter::build_replicated(
            &its,
            3,
            1,
            AlshParams::default(),
            ReplicaConfig::default(),
            73,
        );
        assert_eq!(a.query(&q, 20), r1.query(&q, 20), "R=1 diverged from legacy seeding");
        // Exact scores survive any shard count (merge is score-exact:
        // every hit's score equals the true dot product).
        for n_shards in [1, 2, 5] {
            let r = ShardedRouter::build(&its, n_shards, AlshParams::default(), 73);
            for hit in r.query(&q, 15) {
                let want = dot(&q, &its[hit.id as usize]);
                assert!(
                    (hit.score - want).abs() < 1e-6,
                    "{n_shards} shards: score drifted for id {}",
                    hit.id
                );
            }
        }
    }

    // -- PR 8: replicated dispatch basics ----------------------------------

    #[test]
    fn replicated_path_matches_sync_path_when_healthy() {
        let its = items(300, 8, 80);
        // Generous waits: a hedge or timeout under CI-load jitter would
        // let a differently-seeded backup win and break the equality.
        let cfg = ReplicaConfig {
            shard_timeout: Duration::from_secs(10),
            hedge_delay: Some(Duration::from_secs(5)),
            ..Default::default()
        };
        let router =
            ShardedRouter::build_replicated(&its, 3, 2, AlshParams::default(), cfg, 81);
        let q: Vec<f32> = (0..8).map(|i| (i as f32 * 0.3).cos()).collect();
        let reply = router.query_replicated(&q, 10, ProbeBudget::full());
        assert_eq!(reply.shards_answered, 3);
        assert_eq!(reply.shards_total, 3);
        assert!(!reply.degraded);
        assert!((reply.coverage_fraction() - 1.0).abs() < 1e-12);
        // The primary member of every group is the sync path's pick, so
        // a healthy replicated scatter returns the same merged top-k.
        assert_eq!(reply.hits, router.query(&q, 10));
    }

    #[test]
    fn replica_groups_validate_uniform_members() {
        let its = items(100, 4, 90);
        let a = MipsEngine::new(&its[..50], AlshParams::default(), 91);
        let b = MipsEngine::new(&its[..40], AlshParams::default(), 92);
        let err = ReplicaGroup::new(vec![(a, None, 0), (b, None, 1)], &ReplicaConfig::default());
        assert!(err.is_err(), "mismatched member sizes accepted");
    }
}

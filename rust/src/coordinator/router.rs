//! Sharded scatter/gather router — §3.7 ("Parallelization") of the paper:
//! each node keeps its own hash tables over an item shard; a query fans
//! out, each shard answers locally, and the final top-k is a cheap merge.

use crate::index::scratch::with_thread_scratch;
use crate::index::storage::{Mapped, Owned, Storage};
use crate::index::{AlshParams, BandedParams, ProbeBudget, QueryScratch, ScoredItem};

use super::engine::MipsEngine;

/// A collection of shard engines with global-id translation — heap-built
/// shards (the default) or zero-copy mapped shards
/// ([`ShardedRouter::open_mmap_shards`]).
pub struct ShardedRouter<S: Storage = Owned> {
    shards: Vec<MipsEngine<S>>,
    /// Global id of shard s's local item 0.
    offsets: Vec<u32>,
    dim: usize,
}

impl ShardedRouter {
    /// Split `items` into `n_shards` contiguous shards and build one
    /// flat engine per shard (distinct hash seeds per shard, as each
    /// "node" maintains its own hash functions).
    pub fn build(items: &[Vec<f32>], n_shards: usize, params: AlshParams, seed: u64) -> Self {
        Self::build_impl(items, n_shards, |chunk, shard| {
            MipsEngine::new(chunk, params, seed.wrapping_add(shard))
        })
    }

    /// [`ShardedRouter::build`] with norm-range banded engines per shard:
    /// each shard partitions *its* items into norm bands with per-band U
    /// scaling (shard norm profiles differ, so per-shard banding is the
    /// natural fit).
    pub fn build_banded(
        items: &[Vec<f32>],
        n_shards: usize,
        params: AlshParams,
        banded: BandedParams,
        seed: u64,
    ) -> Self {
        Self::build_impl(items, n_shards, |chunk, shard| {
            MipsEngine::new_banded(chunk, params, banded, seed.wrapping_add(shard))
        })
    }

    fn build_impl(
        items: &[Vec<f32>],
        n_shards: usize,
        make_engine: impl Fn(&[Vec<f32>], u64) -> MipsEngine,
    ) -> Self {
        assert!(n_shards >= 1 && !items.is_empty());
        let dim = items[0].len();
        let per = items.len().div_ceil(n_shards);
        let mut shards = Vec::new();
        let mut offsets = Vec::new();
        for (s, chunk) in items.chunks(per).enumerate() {
            offsets.push((s * per) as u32);
            shards.push(make_engine(chunk, s as u64));
        }
        Self { shards, offsets, dim }
    }
}

impl ShardedRouter<Mapped> {
    /// Assemble a router over per-shard v5 index files, each opened
    /// zero-copy (`MipsEngine::open_mmap`): the restart path for a
    /// sharded deployment — O(shards) opens, no postings byte copied,
    /// page-cache shared with any co-resident process. `paths[s]` must
    /// hold shard `s`'s items in the same contiguous-chunk order the
    /// build produced (global ids are reconstructed cumulatively, as in
    /// [`ShardedRouter::build`]).
    pub fn open_mmap_shards<P: AsRef<std::path::Path>>(paths: &[P]) -> crate::Result<Self> {
        anyhow::ensure!(!paths.is_empty(), "no shard files given");
        let mut engines = Vec::with_capacity(paths.len());
        for p in paths {
            engines.push(MipsEngine::<Mapped>::open_mmap(p)?);
        }
        Self::from_engines(engines)
    }
}

impl<S: Storage> ShardedRouter<S> {
    /// Assemble a router from pre-built (or pre-opened) shard engines,
    /// reconstructing the cumulative global-id offsets from the shard
    /// sizes. All shards must serve the same item dimension.
    pub fn from_engines(shards: Vec<MipsEngine<S>>) -> crate::Result<Self> {
        anyhow::ensure!(!shards.is_empty(), "no shard engines given");
        let dim = shards[0].dim();
        let mut offsets = Vec::with_capacity(shards.len());
        let mut next = 0u64;
        for e in &shards {
            anyhow::ensure!(e.dim() == dim, "shard dim {} != {dim}", e.dim());
            offsets.push(u32::try_from(next).map_err(|_| {
                anyhow::anyhow!("total items across shards overflow u32 global ids")
            })?);
            next += e.n_items() as u64;
        }
        anyhow::ensure!(next <= u32::MAX as u64 + 1, "total items overflow u32 global ids");
        Ok(Self { shards, offsets, dim })
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shard(&self, s: usize) -> &MipsEngine<S> {
        &self.shards[s]
    }

    /// Scatter the query to all shards, gather local top-k lists, merge to
    /// the global top-k. The merge communicates only `k` scored ids per
    /// shard — the "one single number per node" economics of §3.7.
    ///
    /// Allocation-free: one caller-owned scratch serves every shard (its
    /// buffers grow to the largest shard once, then are reused).
    pub fn query_into<'s>(
        &self,
        query: &[f32],
        top_k: usize,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        self.query_budgeted_into(query, top_k, ProbeBudget::full(), s)
    }

    /// [`ShardedRouter::query_into`] with every shard probing under
    /// `budget` — the degraded serving path fans the same reduced budget
    /// out to all shards. Bit-identical to the plain path at
    /// [`ProbeBudget::full`].
    pub fn query_budgeted_into<'s>(
        &self,
        query: &[f32],
        top_k: usize,
        budget: ProbeBudget,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        assert_eq!(query.len(), self.dim);
        s.merged.clear();
        for (engine, &off) in self.shards.iter().zip(&self.offsets) {
            let n = engine.query_budgeted_into(query, top_k, budget, s).len();
            for i in 0..n {
                let hit = s.top[i];
                s.merged.push(ScoredItem { id: hit.id + off, score: hit.score });
            }
        }
        s.merged.sort_unstable_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        s.merged.truncate(top_k);
        &s.merged
    }

    /// Allocating convenience wrapper over [`ShardedRouter::query_into`].
    pub fn query(&self, query: &[f32], top_k: usize) -> Vec<ScoredItem> {
        with_thread_scratch(|s| self.query_into(query, top_k, s).to_vec())
    }

    /// Allocating convenience wrapper over
    /// [`ShardedRouter::query_budgeted_into`].
    pub fn query_budgeted(
        &self,
        query: &[f32],
        top_k: usize,
        budget: ProbeBudget,
    ) -> Vec<ScoredItem> {
        with_thread_scratch(|s| self.query_budgeted_into(query, top_k, budget, s).to_vec())
    }

    /// Total queries served across shards.
    pub fn total_queries(&self) -> u64 {
        self.shards.iter().map(|s| s.metrics().snapshot().queries).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transform::dot;
    use crate::util::Rng;

    fn items(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n)
            .map(|i| {
                let s = 0.2 + 2.0 * (i as f32 / n as f32);
                (0..d).map(|_| (rng.f32() - 0.5) * s).collect()
            })
            .collect()
    }

    #[test]
    fn global_ids_score_correctly() {
        let its = items(400, 8, 1);
        let router = ShardedRouter::build(&its, 4, AlshParams::default(), 2);
        let q: Vec<f32> = (0..8).map(|i| (i as f32).sin()).collect();
        for hit in router.query(&q, 10) {
            let want = dot(&q, &its[hit.id as usize]);
            assert!((hit.score - want).abs() < 1e-6, "global id mis-translated");
        }
    }

    #[test]
    fn sharded_matches_single_shard_quality() {
        // With generous tables both configurations find the exact top-1
        // almost always; sharding must not lose it (it only adds tables).
        let its = items(600, 12, 3);
        let params = AlshParams { n_tables: 48, k_per_table: 4, ..Default::default() };
        let sharded = ShardedRouter::build(&its, 3, params, 4);
        let mut rng = Rng::seed_from_u64(5);
        let mut hits = 0;
        for _ in 0..30 {
            let q: Vec<f32> = (0..12).map(|_| rng.f32() - 0.5).collect();
            let want = (0..its.len())
                .max_by(|&a, &b| dot(&its[a], &q).partial_cmp(&dot(&its[b], &q)).unwrap())
                .unwrap() as u32;
            if sharded.query(&q, 10).iter().any(|h| h.id == want) {
                hits += 1;
            }
        }
        assert!(hits >= 27, "sharded top-1 recall {hits}/30");
    }

    #[test]
    fn scratch_path_equals_convenience_path() {
        let its = items(500, 10, 20);
        let router = ShardedRouter::build(&its, 4, AlshParams::default(), 21);
        let mut s = QueryScratch::new();
        let mut rng = Rng::seed_from_u64(22);
        for _ in 0..10 {
            let q: Vec<f32> = (0..10).map(|_| rng.normal_f32()).collect();
            let via_scratch = router.query_into(&q, 7, &mut s).to_vec();
            assert_eq!(via_scratch, router.query(&q, 7));
        }
    }

    #[test]
    fn banded_router_scores_global_ids_exactly() {
        let its = items(500, 8, 30);
        let router = ShardedRouter::build_banded(
            &its,
            4,
            AlshParams::default(),
            BandedParams { n_bands: 3 },
            31,
        );
        assert_eq!(router.n_shards(), 4);
        assert_eq!(router.shard(0).n_bands(), 3);
        let mut s = QueryScratch::new();
        let mut rng = Rng::seed_from_u64(32);
        for _ in 0..10 {
            let q: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
            let out = router.query_into(&q, 10, &mut s).to_vec();
            assert_eq!(out, router.query(&q, 10));
            for hit in &out {
                let want = dot(&q, &its[hit.id as usize]);
                assert!((hit.score - want).abs() < 1e-6, "global id mis-translated");
            }
        }
    }

    #[test]
    fn merge_is_globally_sorted() {
        let its = items(300, 6, 6);
        let router = ShardedRouter::build(&its, 5, AlshParams::default(), 7);
        let out = router.query(&vec![0.4; 6], 15);
        for w in out.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    /// A live shard routes next to frozen ones: the router only sees the
    /// engine query surface, so mutations on one shard show up in merged
    /// results with correctly translated global ids.
    #[test]
    fn live_shard_mutates_behind_router() {
        use crate::index::LiveConfig;
        let dir = std::env::temp_dir().join(format!(
            "alsh_router_live_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let its = items(200, 6, 60);
        let frozen = MipsEngine::new(&its[..100], AlshParams::default(), 61);
        let live = MipsEngine::create_live(
            &dir,
            &its[100..],
            LiveConfig { params: AlshParams::default(), n_bands: 1, seed: 61 },
        )
        .unwrap();
        let router = ShardedRouter::from_engines(vec![frozen, live]).unwrap();
        assert_eq!(router.n_shards(), 2);
        let q: Vec<f32> = (0..6).map(|i| (i as f32 * 0.43).cos()).collect();
        let before = router.query(&q, 10);
        assert!(before.iter().all(|h| (h.id as usize) < 200));
        // Mutate the live shard; shard-local ext id 7 dies, so global id
        // 107 must vanish from every later merged result.
        router.shard(1).delete(7).unwrap();
        let after = router.query(&q, 200);
        assert!(after.iter().all(|h| h.id != 107), "deleted item resurfaced");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn single_shard_degenerates_gracefully() {
        let its = items(100, 4, 8);
        let router = ShardedRouter::build(&its, 1, AlshParams::default(), 9);
        assert_eq!(router.n_shards(), 1);
        assert!(!router.query(&vec![0.1; 4], 5).is_empty());
    }

    #[test]
    fn uneven_shard_sizes() {
        let its = items(101, 4, 10);
        let router = ShardedRouter::build(&its, 4, AlshParams::default(), 11);
        // 101 items over 4 shards: 26+26+26+23
        assert_eq!(router.n_shards(), 4);
        let out = router.query(&vec![0.2; 4], 101);
        // Every returned id must be in range.
        assert!(out.iter().all(|h| (h.id as usize) < 101));
    }
}

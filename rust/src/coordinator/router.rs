//! Sharded scatter/gather router — §3.7 ("Parallelization") of the paper:
//! each node keeps its own hash tables over an item shard; a query fans
//! out, each shard answers locally, and the final top-k is a cheap merge.
//!
//! Since PR 8 each shard is a **replica group** (see
//! [`super::replica`]): R engines over the same item range with
//! distinct hash seeds. The replicated query path
//! ([`ShardedRouter::query_replicated`]) scatters to each group's
//! primary through per-member worker threads, **tail-hedges** to a
//! backup replica when the primary exceeds a p99-derived hedge delay,
//! enforces a per-shard timeout, and tracks per-member health with
//! circuit breakers. A shard whose whole group is down does not hang
//! the query: the merge returns a **partial result** with explicit
//! coverage accounting ([`RouterReply`]). The synchronous paths
//! ([`ShardedRouter::query_into`] & co.) keep their allocation-free
//! contract by querying each group's first healthy member directly on
//! the caller's scratch — at R = 1 they behave exactly as before.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::index::delta::LiveStorage;
use crate::index::scratch::with_thread_scratch;
use crate::index::storage::{Mapped, Owned, Storage};
use crate::index::{
    open_mmap_verified, AlshIndex, AlshParams, AnyIndex, BandedParams, LiveConfig, LiveIndex,
    LiveStats, NormRangeIndex, PersistFormat, ProbeBudget, QueryScratch, ScoredItem, Wal,
    WalRecord,
};

use super::batcher::BreakerState;
use super::engine::MipsEngine;
use super::metrics::Metrics;
use super::replica::{
    corrupt_index_file, lock, QuorumFailed, ReplicaConfig, ReplicaGroup, ReplicaStorage,
    ShardFaultPlan,
};
use super::trace::{QuerySpans, Stage, FLAG_DEGRADED, FLAG_HEDGED, FLAG_PARTIAL};

/// A collection of shard replica groups with global-id translation —
/// heap-built shards (the default), zero-copy mapped shards
/// ([`ShardedRouter::open_mmap_shards`]), or file-backed replicated
/// deployments ([`ShardedRouter::create_replicated`]).
pub struct ShardedRouter<S: Storage = Owned> {
    groups: Vec<ReplicaGroup<S>>,
    /// Global id of shard s's local item 0. Live replicated deployments
    /// ([`ShardedRouter::create_live_replicated`]) shard by external-id
    /// modulo and store all-zero offsets: their members answer with
    /// external ids directly, so no translation applies.
    offsets: Vec<u32>,
    dim: usize,
    cfg: ReplicaConfig,
    /// Per-shard write serialization: the replicated mutation fan-out
    /// ([`ShardedRouter::upsert`] & co.) and a member catch-up
    /// ([`ShardedRouter::catch_up`]) each hold the owning shard's lock,
    /// so group sequence numbers are assigned uniquely and a converging
    /// member never races new writes.
    write_locks: Vec<Mutex<()>>,
    /// Router-level serving metrics (hedges, partial replies, scrub
    /// events, replicated-query latency). Per-engine metrics stay on
    /// the member engines.
    metrics: Arc<Metrics>,
    scrub_stop: Arc<AtomicBool>,
    scrubber: Mutex<Option<JoinHandle<()>>>,
}

/// A replicated scatter/gather answer with coverage accounting: when
/// every member of some shard's group is down or timed out, the reply
/// still goes out — `degraded`, with the missing range disclosed via
/// `shards_answered`/`shards_total` — instead of hanging or silently
/// pretending full coverage.
#[derive(Clone, Debug)]
pub struct RouterReply {
    /// Merged global top-k over the shards that answered.
    pub hits: Vec<ScoredItem>,
    pub shards_answered: usize,
    pub shards_total: usize,
    /// At least one shard answered through a hedged backup dispatch.
    pub hedge_fired: bool,
    /// `shards_answered < shards_total`: some item range is missing.
    pub degraded: bool,
}

impl RouterReply {
    /// Fraction of shards that contributed to `hits` (1.0 = full
    /// coverage).
    pub fn coverage_fraction(&self) -> f64 {
        if self.shards_total == 0 {
            0.0
        } else {
            self.shards_answered as f64 / self.shards_total as f64
        }
    }
}

/// What one scrub pass ([`ShardedRouter::scrub_now`]) saw and did.
/// Entries are `(shard, member)` coordinates.
#[derive(Clone, Debug, Default)]
pub struct ScrubReport {
    /// File-backed members whose sections were checksum-walked.
    pub checked: usize,
    /// Members whose file failed verification (quarantined).
    pub corrupted: Vec<(usize, usize)>,
    /// Subset of `corrupted` rebuilt, re-verified, and re-admitted.
    pub repaired: Vec<(usize, usize)>,
    /// Repairs that could not complete (with the error); the member
    /// stays quarantined for the next pass.
    pub failed: Vec<(usize, usize, String)>,
    /// Live members the divergence exchange flagged (WAL high-water
    /// behind the group's most advanced member, or a state-checksum
    /// mismatch at equal high-water) and quarantined.
    pub diverged: Vec<(usize, usize)>,
    /// Live members brought back in sync (WAL-suffix replay or full
    /// rebuild-from-peer — see [`ShardedRouter::catch_up`]) and
    /// re-admitted.
    pub caught_up: Vec<(usize, usize)>,
}

/// Outcome of one acknowledged replicated write.
#[derive(Clone, Copy, Debug)]
pub struct WriteReply {
    /// The group sequence number the mutation landed at (identical in
    /// every member's WAL).
    pub seq: u64,
    /// Owning shard of the mutated id(s).
    pub shard: usize,
    /// Members that durably applied the mutation.
    pub acked: usize,
    /// Group size.
    pub replicas: usize,
    /// `acked < replicas`: the write is quorum-durable but at least one
    /// member missed it (down or quarantined) — the structured
    /// `write_degraded` signal.
    pub degraded: bool,
}

/// How [`ShardedRouter::catch_up`] brought a member back in sync.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CatchUpMode {
    /// The missing WAL suffix was replayed from a peer (`n` records
    /// applied; 0 when the member was already current after recovery).
    Replayed(usize),
    /// The suffix was compacted away on every donor — the member was
    /// rebuilt from the donor's live item set (PR 8's rebuild-from-peer
    /// fallback, with WAL numbering continued at the donor's
    /// high-water).
    Rebuilt,
}

/// What one [`ShardedRouter::catch_up`] call did.
#[derive(Clone, Copy, Debug)]
pub struct CatchUpReport {
    pub shard: usize,
    pub member: usize,
    pub mode: CatchUpMode,
    /// The member's WAL high-water after convergence (equals the
    /// donor's at the time of the call).
    pub high_water: u64,
}

impl ShardedRouter {
    /// Split `items` into `n_shards` contiguous shards and build one
    /// flat engine per shard (distinct hash seeds per shard, as each
    /// "node" maintains its own hash functions).
    pub fn build(items: &[Vec<f32>], n_shards: usize, params: AlshParams, seed: u64) -> Self {
        Self::build_impl(items, n_shards, 1, ReplicaConfig::default(), seed, |chunk, s| {
            MipsEngine::new(chunk, params, s)
        })
    }

    /// [`ShardedRouter::build`] with norm-range banded engines per shard:
    /// each shard partitions *its* items into norm bands with per-band U
    /// scaling (shard norm profiles differ, so per-shard banding is the
    /// natural fit).
    pub fn build_banded(
        items: &[Vec<f32>],
        n_shards: usize,
        params: AlshParams,
        banded: BandedParams,
        seed: u64,
    ) -> Self {
        Self::build_impl(items, n_shards, 1, ReplicaConfig::default(), seed, |chunk, s| {
            MipsEngine::new_banded(chunk, params, banded, s)
        })
    }

    /// [`ShardedRouter::build`] with `n_replicas` members per shard
    /// group, all in-memory (no backing files, so the scrubber has
    /// nothing to walk — use [`ShardedRouter::create_replicated`] for
    /// the scrubbed deployment shape).
    pub fn build_replicated(
        items: &[Vec<f32>],
        n_shards: usize,
        n_replicas: usize,
        params: AlshParams,
        cfg: ReplicaConfig,
        seed: u64,
    ) -> Self {
        Self::build_impl(items, n_shards, n_replicas, cfg, seed, |chunk, s| {
            MipsEngine::new(chunk, params, s)
        })
    }

    /// [`ShardedRouter::build_replicated`] with banded member engines.
    pub fn build_replicated_banded(
        items: &[Vec<f32>],
        n_shards: usize,
        n_replicas: usize,
        params: AlshParams,
        banded: BandedParams,
        cfg: ReplicaConfig,
        seed: u64,
    ) -> Self {
        Self::build_impl(items, n_shards, n_replicas, cfg, seed, |chunk, s| {
            MipsEngine::new_banded(chunk, params, banded, s)
        })
    }

    /// Member seeds derive in exactly one place: member (s, r) hashes
    /// with `seed + s·R + r`, so every member of every group gets its
    /// own hash family (recall diversity across replicas, §3.7
    /// independence across shards). At R = 1 this is the historical
    /// `seed + s`, so single-replica builds reproduce pre-replication
    /// indexes bit for bit — and `make_engine` receives the final seed
    /// rather than deriving its own, which is what the audit in PR 8
    /// pinned down (the old closure-side `seed.wrapping_add(shard)`
    /// was correct but duplicated per call site; the property tests
    /// below now hold it in place).
    fn build_impl(
        items: &[Vec<f32>],
        n_shards: usize,
        n_replicas: usize,
        cfg: ReplicaConfig,
        seed: u64,
        make_engine: impl Fn(&[Vec<f32>], u64) -> MipsEngine,
    ) -> Self {
        assert!(n_shards >= 1 && n_replicas >= 1 && !items.is_empty());
        let dim = items[0].len();
        let per = items.len().div_ceil(n_shards);
        let mut groups = Vec::new();
        let mut offsets = Vec::new();
        for (s, chunk) in items.chunks(per).enumerate() {
            offsets.push((s * per) as u32);
            let members = (0..n_replicas)
                .map(|r| {
                    let member_seed = seed.wrapping_add((s * n_replicas + r) as u64);
                    (make_engine(chunk, member_seed), None, member_seed)
                })
                .collect();
            groups.push(ReplicaGroup::new(members, &cfg).expect("uniform member chunks"));
        }
        Self::from_groups(groups, offsets, dim, cfg)
    }
}

impl ShardedRouter<Mapped> {
    /// Assemble a router over per-shard v5 index files, each opened
    /// zero-copy (`MipsEngine::open_mmap`): the restart path for a
    /// sharded deployment — O(shards) opens, no postings byte copied,
    /// page-cache shared with any co-resident process. `paths[s]` must
    /// hold shard `s`'s items in the same contiguous-chunk order the
    /// build produced (global ids are reconstructed cumulatively, as in
    /// [`ShardedRouter::build`]).
    pub fn open_mmap_shards<P: AsRef<Path>>(paths: &[P]) -> crate::Result<Self> {
        anyhow::ensure!(!paths.is_empty(), "no shard files given");
        let mut engines = Vec::with_capacity(paths.len());
        for p in paths {
            engines.push(MipsEngine::<Mapped>::open_mmap(p)?);
        }
        Self::from_engines(engines)
    }
}

impl<S: ReplicaStorage + LiveStorage> ShardedRouter<S> {
    /// Build every (shard, replica) index from `items`, persist each as
    /// a `V5Checked` file under `dir` (`shard{s}-rep{r}.alsh`), and
    /// serve the **verified** opens — the deployment shape the scrubber
    /// can watch and repair. Flat members, or banded when `banded` is
    /// set; storage (zero-copy mapped vs heap) chosen by `S`.
    #[allow(clippy::too_many_arguments)]
    pub fn create_replicated(
        dir: &Path,
        items: &[Vec<f32>],
        n_shards: usize,
        n_replicas: usize,
        params: AlshParams,
        banded: Option<BandedParams>,
        cfg: ReplicaConfig,
        seed: u64,
    ) -> crate::Result<Self> {
        anyhow::ensure!(
            n_shards >= 1 && n_replicas >= 1 && !items.is_empty(),
            "create_replicated: need at least one shard, one replica, and one item"
        );
        std::fs::create_dir_all(dir)?;
        let dim = items[0].len();
        let per = items.len().div_ceil(n_shards);
        let mut groups = Vec::new();
        let mut offsets = Vec::new();
        for (s, chunk) in items.chunks(per).enumerate() {
            offsets.push(u32::try_from(s * per).map_err(|_| {
                anyhow::anyhow!("total items across shards overflow u32 global ids")
            })?);
            let mut members = Vec::with_capacity(n_replicas);
            for r in 0..n_replicas {
                // Same member-seed derivation as `build_impl`.
                let member_seed = seed.wrapping_add((s * n_replicas + r) as u64);
                let path = dir.join(format!("shard{s}-rep{r}.alsh"));
                let index = match banded {
                    None => AnyIndex::Flat(AlshIndex::build(chunk, params, member_seed)),
                    Some(b) => {
                        AnyIndex::Banded(NormRangeIndex::build(chunk, params, b, member_seed))
                    }
                };
                index.save_as(&path, PersistFormat::V5Checked)?;
                members.push((S::open_verified(&path)?, Some(path), member_seed));
            }
            groups.push(ReplicaGroup::new(members, &cfg)?);
        }
        Ok(Self::from_groups(groups, offsets, dim, cfg))
    }

    /// The **writable** replicated deployment: every member of every
    /// shard group is a [`LiveIndex`] directory
    /// (`dir/shard{s}-rep{r}/`), so the router-level mutations
    /// ([`ShardedRouter::upsert`] & co.) fan out WAL-sequence-numbered
    /// records and the scrubber's divergence exchange can catch up a
    /// lagging member from a peer's log.
    ///
    /// Sharding is by **external-id modulo** — item `i` (external id
    /// `i`) is owned by shard `i % n_shards` — rather than contiguous
    /// ranges: under live churn ids arrive in any order, and modulo
    /// keeps ownership derivable from the id alone. Members answer
    /// queries with external ids directly (offsets are all zero).
    /// Member (s, r) builds with seed `live_cfg.seed + s·R + r`, the
    /// same derivation as every other builder here, so replica answers
    /// stay recall-diverse.
    pub fn create_live_replicated(
        dir: &Path,
        items: &[Vec<f32>],
        n_shards: usize,
        n_replicas: usize,
        live_cfg: LiveConfig,
        cfg: ReplicaConfig,
    ) -> crate::Result<Self> {
        anyhow::ensure!(
            n_shards >= 1 && n_replicas >= 1,
            "create_live_replicated: need at least one shard and one replica"
        );
        anyhow::ensure!(
            items.len() >= n_shards,
            "create_live_replicated: every shard needs at least one initial item \
             ({} items over {n_shards} shards)",
            items.len()
        );
        std::fs::create_dir_all(dir)?;
        let dim = items[0].len();
        let mut groups = Vec::with_capacity(n_shards);
        let mut offsets = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            let entries: Vec<(u32, Vec<f32>)> = items
                .iter()
                .enumerate()
                .filter(|(i, _)| i % n_shards == s)
                .map(|(i, v)| (i as u32, v.clone()))
                .collect();
            offsets.push(0);
            let mut members = Vec::with_capacity(n_replicas);
            for r in 0..n_replicas {
                let member_seed = live_cfg.seed.wrapping_add((s * n_replicas + r) as u64);
                let mdir = dir.join(format!("shard{s}-rep{r}"));
                let live = LiveIndex::<S>::create_with_state(
                    &mdir,
                    &entries,
                    LiveConfig { seed: member_seed, ..live_cfg },
                    1,
                )?;
                members.push((MipsEngine::from_live(live), Some(mdir), member_seed));
            }
            groups.push(ReplicaGroup::new(members, &cfg)?);
        }
        Ok(Self::from_groups(groups, offsets, dim, cfg))
    }

    // -- replicated writes --------------------------------------------------

    /// Owning shard of an external id (modulo placement — see
    /// [`ShardedRouter::create_live_replicated`]).
    pub fn shard_of(&self, ext_id: u32) -> usize {
        (ext_id as usize) % self.groups.len()
    }

    /// Replicated upsert: route to the owning shard, fan the record out
    /// to every group member at one group sequence number, acknowledge
    /// at the write quorum ([`ReplicaConfig::write_quorum`]). Errors
    /// carry structure: a [`crate::index::WriteStalled`] when the
    /// group's delta backlog is at its cap (retry after compaction
    /// drains it), a [`QuorumFailed`] when too few members applied the
    /// record.
    pub fn upsert(&self, ext_id: u32, vector: &[f32]) -> crate::Result<WriteReply> {
        anyhow::ensure!(
            vector.len() == self.dim,
            "upsert: vector dim {} != index dim {}",
            vector.len(),
            self.dim
        );
        self.replicate(
            self.shard_of(ext_id),
            &WalRecord::Upsert { ext_id, vector: vector.to_vec() },
        )
    }

    /// Replicated delete (idempotent), routed and fanned out like
    /// [`ShardedRouter::upsert`].
    pub fn delete(&self, ext_id: u32) -> crate::Result<WriteReply> {
        self.replicate(self.shard_of(ext_id), &WalRecord::Delete { ext_id })
    }

    /// Replicated bulk upsert: entries are split by owning shard and
    /// each shard's slice commits as **one** group-commit batch record
    /// (all-or-nothing per shard, like the engine-level batch). Returns
    /// one reply per shard that received entries. Atomicity is
    /// per-shard, not cross-shard: an error from a later shard leaves
    /// earlier shards' batches durably applied (the returned error
    /// names the failing shard; completed shards are acknowledged
    /// writes and are never rolled back).
    pub fn upsert_batch(&self, entries: &[(u32, Vec<f32>)]) -> crate::Result<Vec<WriteReply>> {
        let n_shards = self.groups.len();
        let mut by_shard: Vec<Vec<(u32, Vec<f32>)>> = (0..n_shards).map(|_| Vec::new()).collect();
        for (ext_id, vector) in entries {
            anyhow::ensure!(
                vector.len() == self.dim,
                "upsert_batch: vector dim {} != index dim {} (id {ext_id})",
                vector.len(),
                self.dim
            );
            by_shard[self.shard_of(*ext_id)].push((*ext_id, vector.clone()));
        }
        let mut replies = Vec::new();
        for (s, items) in by_shard.into_iter().enumerate() {
            if items.is_empty() {
                continue;
            }
            let reply = self
                .replicate(s, &WalRecord::Batch { items })
                .map_err(|e| e.context(format!("upsert_batch: shard {s}")))?;
            replies.push(reply);
        }
        Ok(replies)
    }

    /// The fan-out core shared by the three mutations. Under the
    /// shard's write lock: backpressure-check every serving member
    /// *before* a sequence is assigned (a stalled group refuses the
    /// write uniformly — members never diverge on which writes they
    /// accepted), derive the group sequence as max member high-water +
    /// 1, apply on every non-quarantined member, count acks against the
    /// quorum.
    fn replicate(&self, shard: usize, rec: &WalRecord) -> crate::Result<WriteReply> {
        let g = &self.groups[shard];
        let _wl = lock(&self.write_locks[shard]);
        let mut serving = 0usize;
        for m in &g.members {
            if m.shared.breaker.is_quarantined() {
                continue;
            }
            serving += 1;
            if let Some(stall) = m.engine().would_stall() {
                self.metrics.record_write_stalled();
                return Err(stall.into());
            }
        }
        anyhow::ensure!(serving > 0, "shard {shard}: every member is quarantined");
        let seq = g
            .members
            .iter()
            .filter(|m| !m.shared.breaker.is_quarantined())
            .filter_map(|m| m.engine().high_water())
            .max()
            .ok_or_else(|| {
                anyhow::anyhow!("shard {shard}: no live member to replicate to (frozen group?)")
            })?
            + 1;
        let replicas = g.members.len();
        let mut acked = 0usize;
        for m in &g.members {
            if m.shared.breaker.is_quarantined() {
                continue;
            }
            if m.write_crashes_now() {
                // Injected mid-write-stream member crash: the record is
                // not applied here; the member leaves rotation until a
                // catch-up re-admits it.
                m.shared.breaker.quarantine();
                self.metrics.record_replica_quarantine();
                continue;
            }
            let engine = m.engine();
            let applied = match rec {
                WalRecord::Upsert { ext_id, vector } => {
                    engine.upsert_at(seq, *ext_id, vector).map(|_| ())
                }
                WalRecord::Delete { ext_id } => engine.delete_at(seq, *ext_id).map(|_| ()),
                WalRecord::Batch { items } => engine.upsert_batch_at(seq, items).map(|_| ()),
            };
            match applied {
                Ok(()) => acked += 1,
                // A member that refuses the record (sequence gap after a
                // missed write, crashed instance, I/O error) is a write
                // failure for its breaker; the scrubber's divergence
                // pass will catch it up.
                Err(_) => m.shared.breaker.on_failure(),
            }
        }
        let needed = self.cfg.effective_write_quorum(replicas);
        if acked < needed {
            self.metrics.record_quorum_failure();
            return Err(QuorumFailed { shard, acked, needed, replicas }.into());
        }
        self.metrics.record_write_replicated();
        self.sync_live_gauges();
        Ok(WriteReply { seq, shard, acked, replicas, degraded: acked < replicas })
    }

    /// Publish aggregate live-tier gauges onto the router metrics, so
    /// the routed `metrics`/`metrics_prom` commands report the same
    /// gauge families as the single-engine front end. Each shard
    /// contributes its most advanced healthy member (replicas hold
    /// copies of the same rows — summing every member would
    /// double-count); sums across shards, except `last_compaction_ms`
    /// which reports the slowest shard's latest compaction.
    pub fn sync_live_gauges(&self) {
        let mut agg = LiveStats {
            delta_items: 0,
            tombstones: 0,
            compactions: 0,
            wal_bytes: 0,
            last_compaction_ms: 0,
            generation: 0,
            n_items: 0,
            high_water: 0,
        };
        let mut any = false;
        for g in &self.groups {
            let reference = g
                .members
                .iter()
                .filter(|m| !m.shared.breaker.is_quarantined())
                .max_by_key(|m| m.engine().high_water())
                .or_else(|| g.members.first());
            let Some(s) = reference.and_then(|m| m.engine().live_stats()) else { continue };
            any = true;
            agg.delta_items += s.delta_items;
            agg.tombstones += s.tombstones;
            agg.compactions += s.compactions;
            agg.wal_bytes += s.wal_bytes;
            agg.last_compaction_ms = agg.last_compaction_ms.max(s.last_compaction_ms);
            agg.n_items += s.n_items;
        }
        if any {
            self.metrics.record_live_stats(&agg);
        }
    }

    /// Bring group `shard`'s member `member` back in sync with its most
    /// advanced live peer, then re-admit it through its breaker. Holds
    /// the shard's write lock, so the group's log is frozen while the
    /// member converges.
    ///
    /// The member is first re-opened from disk — recovery replays its
    /// surviving WAL, truncates a torn tail, and sweeps orphan
    /// temp/generation files left by a crashed compaction or rebuild.
    /// Then, if it still lags the donor: replay the missing WAL suffix
    /// from the donor's log ([`Wal::read_suffix`]); when the suffix is
    /// gone (compacted away) or replay fails to converge, fall back to
    /// a full rebuild from the donor's live item set with the member's
    /// own seed, WAL numbering continued at the donor's high-water.
    /// Convergence is verified (high-water equality + seed-independent
    /// state checksum) before the rebuilt engine swaps into the serving
    /// slot.
    pub fn catch_up(&self, shard: usize, member: usize) -> crate::Result<CatchUpReport> {
        let g = &self.groups[shard];
        let _wl = lock(&self.write_locks[shard]);
        let m = &g.members[member];
        let mdir = m
            .shared
            .path
            .clone()
            .filter(|p| p.is_dir())
            .ok_or_else(|| anyhow::anyhow!("catch_up: ({shard}, {member}) is not a live member"))?;
        // The outgoing engine may still be running a background
        // compactor against this directory; stop it before a second
        // instance opens (or rebuilds into) the same files.
        let outgoing = m.engine();
        if let Some(live) = outgoing.live() {
            live.stop_compactor();
        }
        let reopened = MipsEngine::<S>::open_live(&mdir)?;
        let donor_idx = (0..g.members.len())
            .filter(|&i| i != member && !g.members[i].shared.breaker.is_quarantined())
            .max_by_key(|&i| g.members[i].engine().high_water().unwrap_or(0))
            .ok_or_else(|| anyhow::anyhow!("catch_up: shard {shard} has no healthy peer"))?;
        let donor = g.members[donor_idx].engine();
        let donor_live = donor
            .live()
            .ok_or_else(|| anyhow::anyhow!("catch_up: donor ({shard}, {donor_idx}) is frozen"))?;
        let donor_hw = donor_live.high_water();
        let donor_sum = donor_live.state_checksum();

        let rebuild = || -> crate::Result<MipsEngine<S>> {
            let entries = donor_live.live_items();
            let live = LiveIndex::<S>::create_with_state(
                &mdir,
                &entries,
                LiveConfig {
                    params: *donor.params(),
                    n_bands: donor.n_bands(),
                    seed: m.shared.seed,
                    delta_cap: donor_live.delta_cap(),
                },
                donor_hw + 1,
            )?;
            Ok(MipsEngine::from_live(live))
        };

        let my_hw = reopened.high_water().unwrap_or(0);
        let (mut engine, mut mode) = if my_hw >= donor_hw {
            (reopened, CatchUpMode::Replayed(0))
        } else {
            match Wal::read_suffix(&donor_live.current_wal_path(), my_hw + 1)? {
                Some(suffix) => {
                    let live = reopened
                        .live()
                        .ok_or_else(|| anyhow::anyhow!("catch_up: reopened member is frozen"))?;
                    let n = live.apply_suffix(&suffix)?;
                    self.metrics.record_catch_up_replay();
                    (reopened, CatchUpMode::Replayed(n))
                }
                None => (rebuild()?, CatchUpMode::Rebuilt),
            }
        };
        let converged = |e: &MipsEngine<S>| {
            e.high_water() == Some(donor_hw) && e.state_checksum() == Some(donor_sum)
        };
        if !converged(&engine) && mode != CatchUpMode::Rebuilt {
            // Replay landed on a diverged history (same high-water,
            // different state) — the rebuild fallback is authoritative.
            engine = rebuild()?;
            mode = CatchUpMode::Rebuilt;
        }
        anyhow::ensure!(
            converged(&engine),
            "catch_up: ({shard}, {member}) failed to converge with donor {donor_idx} \
             (hw {:?} vs {donor_hw})",
            engine.high_water()
        );
        if mode == CatchUpMode::Rebuilt {
            self.metrics.record_replica_repair();
        }
        m.install(engine);
        m.shared.breaker.readmit();
        Ok(CatchUpReport { shard, member, mode, high_water: donor_hw })
    }

    /// One synchronous scrub pass: checksum-walk every file-backed
    /// member's sections (`open_mmap_verified`, O(file) per member — no
    /// section escapes the walk). A member whose file fails is
    /// **quarantined** (its breaker refuses traffic), **repaired** —
    /// re-opened if the on-disk bytes verify after all (an atomic
    /// re-save may have raced the failing read), else rebuilt from a
    /// healthy peer's items under the member's own seed, saved
    /// `V5Checked`, and re-verified — then **re-admitted** through its
    /// breaker. Members without a backing file are skipped. The
    /// background scrubber ([`ShardedRouter::spawn_scrubber`]) calls
    /// this on its cadence; tests and benches call it directly for
    /// determinism.
    pub fn scrub_now(&self) -> ScrubReport {
        let mut report = ScrubReport::default();
        for (s, g) in self.groups.iter().enumerate() {
            self.scrub_live_group(s, g, &mut report);
            for (r, member) in g.members.iter().enumerate() {
                let Some(path) = &member.shared.path else { continue };
                if path.is_dir() {
                    // Live member: handled by the divergence exchange
                    // above — its generation files carry no section
                    // checksums to walk.
                    continue;
                }
                report.checked += 1;
                if open_mmap_verified(path).is_ok() {
                    continue;
                }
                report.corrupted.push((s, r));
                member.shared.breaker.quarantine();
                self.metrics.record_replica_quarantine();
                match self.repair(g, r) {
                    Ok(()) => {
                        member.shared.breaker.readmit();
                        self.metrics.record_replica_repair();
                        report.repaired.push((s, r));
                    }
                    Err(e) => report.failed.push((s, r, format!("{e:#}"))),
                }
            }
        }
        report
    }

    /// The live-tier divergence exchange of one scrub pass: under the
    /// shard's write lock (so nothing moves mid-comparison), every live
    /// member's WAL high-water and state checksum are compared against
    /// the group's most advanced serving member. A member that lags, or
    /// disagrees at equal high-water, is quarantined; quarantined live
    /// members (including ones a write-path crash parked earlier) are
    /// then caught up and re-admitted — outside the detection lock,
    /// because [`ShardedRouter::catch_up`] takes it itself.
    fn scrub_live_group(&self, s: usize, g: &ReplicaGroup<S>, report: &mut ScrubReport) {
        let live_members: Vec<usize> = g
            .members
            .iter()
            .enumerate()
            .filter(|(_, m)| m.shared.path.as_deref().is_some_and(|p| p.is_dir()))
            .map(|(i, _)| i)
            .collect();
        if live_members.is_empty() {
            return;
        }
        let mut to_catch_up = Vec::new();
        {
            let _wl = lock(&self.write_locks[s]);
            let reference = live_members
                .iter()
                .copied()
                .filter(|&i| !g.members[i].shared.breaker.is_quarantined())
                .max_by_key(|&i| g.members[i].engine().high_water().unwrap_or(0));
            let Some(ref_i) = reference else { return };
            let ref_engine = g.members[ref_i].engine();
            let ref_hw = ref_engine.high_water().unwrap_or(0);
            let ref_sum = ref_engine.state_checksum();
            for &r in &live_members {
                report.checked += 1;
                if r == ref_i {
                    continue;
                }
                let m = &g.members[r];
                if !m.shared.breaker.is_quarantined() {
                    let e = m.engine();
                    let lagging = e.high_water().unwrap_or(0) < ref_hw;
                    let disagrees = !lagging && e.state_checksum() != ref_sum;
                    if lagging || disagrees {
                        m.shared.breaker.quarantine();
                        self.metrics.record_replica_quarantine();
                        report.diverged.push((s, r));
                    }
                }
                if m.shared.breaker.is_quarantined() {
                    to_catch_up.push(r);
                }
            }
        }
        for r in to_catch_up {
            match self.catch_up(s, r) {
                Ok(_) => report.caught_up.push((s, r)),
                Err(e) => report.failed.push((s, r, format!("{e:#}"))),
            }
        }
    }

    /// Restore group member `r` from rot: prefer the surviving on-disk
    /// generation (re-verify — `save_as` is atomic, so a concurrent
    /// rewrite may have already replaced the rotten bytes), else
    /// rebuild from the first healthy, verifying peer's items with the
    /// member's own seed, save `V5Checked`, re-verify, and hot-swap the
    /// serving slot.
    fn repair(&self, g: &ReplicaGroup<S>, r: usize) -> crate::Result<()> {
        let member = &g.members[r];
        let path = member.shared.path.clone().expect("repair: file-backed member");
        if let Ok(engine) = S::open_verified(&path) {
            member.install(engine);
            return Ok(());
        }
        let donor = g.members.iter().enumerate().find(|(i, p)| {
            *i != r
                && !p.shared.breaker.is_quarantined()
                && p.shared.path.as_deref().is_none_or(|pp| open_mmap_verified(pp).is_ok())
        });
        let Some((_, donor)) = donor else {
            anyhow::bail!("replica repair: no healthy peer to rebuild from");
        };
        let donor_engine = donor.engine();
        let src = donor_engine.index();
        let mut items = Vec::with_capacity(src.n_items());
        for id in 0..src.n_items() as u32 {
            items.push(src.item(id).to_vec());
        }
        let params = *donor_engine.params();
        let rebuilt = match src.as_banded() {
            None => AnyIndex::Flat(AlshIndex::build(&items, params, member.shared.seed)),
            Some(b) => AnyIndex::Banded(NormRangeIndex::build(
                &items,
                params,
                BandedParams { n_bands: b.n_bands() },
                member.shared.seed,
            )),
        };
        rebuilt.save_as(&path, PersistFormat::V5Checked)?;
        member.install(S::open_verified(&path)?);
        Ok(())
    }

    /// Start the background scrubber: one full [`ShardedRouter::scrub_now`]
    /// pass every `interval` (the budget knob — a longer interval
    /// spreads the checksum I/O thinner). The thread holds only a
    /// `Weak` reference, so dropping the router ends it on its next
    /// wake-up; call [`ShardedRouter::stop_scrubber`] for a
    /// deterministic join. (An associated fn — `&Arc<Self>` is not a
    /// valid method receiver.)
    pub fn spawn_scrubber(router: &Arc<Self>, interval: Duration) {
        let weak = Arc::downgrade(router);
        let stop = Arc::clone(&router.scrub_stop);
        let handle = std::thread::Builder::new()
            .name("alsh-scrub".into())
            .spawn(move || loop {
                std::thread::sleep(interval);
                if stop.load(Ordering::Relaxed) {
                    return;
                }
                let Some(router) = weak.upgrade() else { return };
                let _ = router.scrub_now();
            })
            .expect("spawn scrubber");
        *lock(&router.scrubber) = Some(handle);
    }

    /// Stop and join the background scrubber (blocks at most one
    /// interval). Idempotent; a no-op if none was spawned.
    pub fn stop_scrubber(&self) {
        self.scrub_stop.store(true, Ordering::Relaxed);
        if let Some(handle) = lock(&self.scrubber).take() {
            let _ = handle.join();
        }
    }
}

/// Per-shard in-flight dispatch state for the replicated scatter.
/// Replies carry the answering member's [`QuerySpans`] so the gather
/// can attribute probe/rerank time to the winning replica.
struct Pending {
    tx: Sender<(usize, Vec<ScoredItem>, QuerySpans)>,
    rx: Receiver<(usize, Vec<ScoredItem>, QuerySpans)>,
    primary: Option<usize>,
    dispatched: Vec<usize>,
}

impl<S: Storage> ShardedRouter<S> {
    /// Assemble a router from pre-built (or pre-opened) shard engines,
    /// reconstructing the cumulative global-id offsets from the shard
    /// sizes. All shards must serve the same item dimension. Each
    /// engine becomes a single-member replica group with no backing
    /// file (so the scrubber skips it).
    pub fn from_engines(shards: Vec<MipsEngine<S>>) -> crate::Result<Self> {
        anyhow::ensure!(!shards.is_empty(), "no shard engines given");
        let cfg = ReplicaConfig::default();
        let dim = shards[0].dim();
        let mut offsets = Vec::with_capacity(shards.len());
        let mut groups = Vec::with_capacity(shards.len());
        let mut next = 0u64;
        for e in shards {
            anyhow::ensure!(e.dim() == dim, "shard dim {} != {dim}", e.dim());
            offsets.push(u32::try_from(next).map_err(|_| {
                anyhow::anyhow!("total items across shards overflow u32 global ids")
            })?);
            next += e.n_items() as u64;
            groups.push(ReplicaGroup::new(vec![(e, None, 0)], &cfg)?);
        }
        anyhow::ensure!(next <= u32::MAX as u64 + 1, "total items overflow u32 global ids");
        Ok(Self::from_groups(groups, offsets, dim, cfg))
    }

    fn from_groups(
        groups: Vec<ReplicaGroup<S>>,
        offsets: Vec<u32>,
        dim: usize,
        cfg: ReplicaConfig,
    ) -> Self {
        let write_locks = groups.iter().map(|_| Mutex::new(())).collect();
        Self {
            groups,
            offsets,
            dim,
            cfg,
            write_locks,
            metrics: Arc::new(Metrics::new()),
            scrub_stop: Arc::new(AtomicBool::new(false)),
            scrubber: Mutex::new(None),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.groups.len()
    }

    /// Item dimension served by every shard.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Replicas in shard `s`'s group.
    pub fn n_replicas(&self, s: usize) -> usize {
        self.groups[s].members.len()
    }

    /// Shard `s`'s first-healthy member engine (member 0 when every
    /// member is quarantined). Returns a clone of the serving `Arc` —
    /// the slot behind it is hot-swappable by the scrubber's repair.
    pub fn shard(&self, s: usize) -> Arc<MipsEngine<S>> {
        let g = &self.groups[s];
        g.members[g.pick_serving()].engine()
    }

    /// Group `shard`'s member `member`'s serving engine, healthy or not
    /// — divergence inspection, fault injection, and per-member
    /// verification in tests. A clone of the serving `Arc`: a
    /// concurrent repair swaps the slot, not the engine behind a held
    /// clone.
    pub fn member_engine(&self, shard: usize, member: usize) -> Arc<MipsEngine<S>> {
        self.groups[shard].members[member].engine()
    }

    /// Router-level metrics (hedges, partial replies, scrub events,
    /// replicated-query latency).
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// The replica configuration this router dispatches under.
    pub fn replica_config(&self) -> &ReplicaConfig {
        &self.cfg
    }

    /// Per-member breaker states, indexed `[shard][member]`.
    pub fn breaker_states(&self) -> Vec<Vec<BreakerState>> {
        self.groups
            .iter()
            .map(|g| g.members.iter().map(|m| m.shared.breaker.state()).collect())
            .collect()
    }

    /// Per-shard answer-latency p99 gauges (µs; 0 until a shard has
    /// answered a replicated query).
    pub fn shard_p99_us(&self) -> Vec<u64> {
        self.groups.iter().map(|g| g.latency.percentile_us(0.99)).collect()
    }

    /// Install a fault plan on group `shard`'s member `member` (tests
    /// and benches only; defaults all-off).
    pub fn set_shard_faults(&self, shard: usize, member: usize, plan: ShardFaultPlan) {
        self.groups[shard].members[member].set_faults(plan);
    }

    /// The backing file of group `shard`'s member `member`, if any.
    pub fn replica_path(&self, shard: usize, member: usize) -> Option<PathBuf> {
        self.groups[shard].members[member].shared.path.clone()
    }

    /// Flip a corruption burst into the member's backing file (tests
    /// and benches; see `replica::corrupt_index_file`). Errors when the
    /// member has no backing file.
    pub fn corrupt_replica(&self, shard: usize, member: usize) -> crate::Result<()> {
        match self.replica_path(shard, member) {
            Some(path) => corrupt_index_file(&path),
            None => anyhow::bail!("replica ({shard}, {member}) has no backing file"),
        }
    }

    /// Scatter the query to all shards, gather local top-k lists, merge to
    /// the global top-k. The merge communicates only `k` scored ids per
    /// shard — the "one single number per node" economics of §3.7.
    ///
    /// Allocation-free: one caller-owned scratch serves every shard (its
    /// buffers grow to the largest shard once, then are reused). This
    /// path queries each group's first healthy member in-thread — no
    /// hedging or timeouts; use [`ShardedRouter::query_replicated`] for
    /// the fault-tolerant scatter.
    pub fn query_into<'s>(
        &self,
        query: &[f32],
        top_k: usize,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        self.query_budgeted_into(query, top_k, ProbeBudget::full(), s)
    }

    /// [`ShardedRouter::query_into`] with every shard probing under
    /// `budget` — the degraded serving path fans the same reduced budget
    /// out to all shards. Bit-identical to the plain path at
    /// [`ProbeBudget::full`].
    pub fn query_budgeted_into<'s>(
        &self,
        query: &[f32],
        top_k: usize,
        budget: ProbeBudget,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        assert_eq!(query.len(), self.dim);
        s.merged.clear();
        for (g, &off) in self.groups.iter().zip(&self.offsets) {
            let engine = g.members[g.pick_serving()].engine();
            let n = engine.query_budgeted_into(query, top_k, budget, s).len();
            for i in 0..n {
                let hit = s.top[i];
                s.merged.push(ScoredItem { id: hit.id + off, score: hit.score });
            }
        }
        s.merged.sort_unstable_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        s.merged.truncate(top_k);
        &s.merged
    }

    /// Allocating convenience wrapper over [`ShardedRouter::query_into`].
    pub fn query(&self, query: &[f32], top_k: usize) -> Vec<ScoredItem> {
        with_thread_scratch(|s| self.query_into(query, top_k, s).to_vec())
    }

    /// Allocating convenience wrapper over
    /// [`ShardedRouter::query_budgeted_into`].
    pub fn query_budgeted(
        &self,
        query: &[f32],
        top_k: usize,
        budget: ProbeBudget,
    ) -> Vec<ScoredItem> {
        with_thread_scratch(|s| self.query_budgeted_into(query, top_k, budget, s).to_vec())
    }

    /// The fault-tolerant scatter/gather: dispatch every shard's
    /// primary replica concurrently (each member serves on its own
    /// worker thread), then collect per shard — hedging to a backup
    /// member when the primary exceeds the hedge delay
    /// ([`ReplicaConfig::hedge_delay`], or derived from the shard's
    /// measured p99), walking away at [`ReplicaConfig::shard_timeout`].
    /// Member successes/failures feed the per-member breakers; a shard
    /// whose group never answers makes the reply partial rather than
    /// hanging it (see [`RouterReply`]).
    pub fn query_replicated(&self, query: &[f32], top_k: usize, budget: ProbeBudget) -> RouterReply {
        let mut spans = QuerySpans::default();
        let reply = self.query_replicated_traced(query, top_k, budget, &mut spans);
        self.metrics.tracer.offer(&spans);
        reply
    }

    /// [`ShardedRouter::query_replicated`] with caller-owned span
    /// attribution: per-member probe/rerank timings are absorbed from
    /// whichever replica answered each shard, the gather wait lands in
    /// [`Stage::ShardWait`], the sort/truncate in [`Stage::Merge`], and
    /// hedge/partial/degraded outcomes become span flags. The caller
    /// owns offering `spans` to a [`super::trace::TraceRecorder`] —
    /// this method only fills it in and feeds the stage aggregates.
    pub fn query_replicated_traced(
        &self,
        query: &[f32],
        top_k: usize,
        budget: ProbeBudget,
        spans: &mut QuerySpans,
    ) -> RouterReply {
        assert_eq!(query.len(), self.dim);
        let start = Instant::now();
        let q: Arc<[f32]> = Arc::from(query.to_vec());
        let shards_total = self.groups.len();

        // Scatter: every group's primary goes out before any collect
        // blocks, so one slow shard never delays another's dispatch.
        let mut pending = Vec::with_capacity(shards_total);
        for g in &self.groups {
            let (tx, rx) = mpsc::channel();
            let mut dispatched = Vec::new();
            let primary = g.pick_primary();
            if let Some(p) = primary {
                if g.members[p].dispatch(p, &q, top_k, budget, tx.clone()) {
                    dispatched.push(p);
                } else {
                    // Dead worker (crashed member): an instant failure.
                    g.members[p].shared.breaker.on_failure();
                }
            }
            pending.push(Pending { tx, rx, primary, dispatched });
        }

        // Gather, hedging stragglers.
        let mut hits: Vec<ScoredItem> = Vec::new();
        let mut shards_answered = 0usize;
        let mut hedge_fired = false;
        for ((g, &off), p) in self.groups.iter().zip(&self.offsets).zip(pending) {
            if let Some((shard_hits, fired, who, member_spans)) =
                self.collect_shard(g, &q, top_k, budget, start, p)
            {
                g.latency.record(start.elapsed().as_micros() as u64);
                hedge_fired |= fired;
                shards_answered += 1;
                spans.absorb_member(&member_spans);
                spans.winning_replica = who.min(u8::MAX as usize) as u8;
                hits.extend(
                    shard_hits.iter().map(|h| ScoredItem { id: h.id + off, score: h.score }),
                );
            }
        }
        let shard_wait_us = start.elapsed().as_micros() as u64;
        spans.set_stage(Stage::ShardWait, shard_wait_us);

        let merge_start = Instant::now();
        hits.sort_unstable_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        hits.truncate(top_k);
        let merge_us = merge_start.elapsed().as_micros() as u64;
        spans.set_stage(Stage::Merge, merge_us);

        let degraded = shards_answered < shards_total;
        if degraded {
            self.metrics.record_partial_reply();
            spans.set_flag(FLAG_PARTIAL);
            spans.set_flag(FLAG_DEGRADED);
        }
        if hedge_fired {
            spans.set_flag(FLAG_HEDGED);
        }
        spans.shards_answered = shards_answered.min(u8::MAX as usize) as u8;
        spans.shards_total = shards_total.min(u8::MAX as usize) as u8;
        spans.hits = hits.len().min(u16::MAX as usize) as u16;
        spans.top_k = top_k.min(u16::MAX as usize) as u16;
        spans.total_us = start.elapsed().as_micros() as u64;

        // Stage aggregates: the members' engines recorded probe/rerank
        // into their *own* metrics; re-record the absorbed values here
        // so the router's front-end histograms see them too.
        if let Some(us) = spans.stage(Stage::Probe) {
            self.metrics.record_stage(Stage::Probe, us);
        }
        if let Some(us) = spans.stage(Stage::Rerank) {
            self.metrics.record_stage(Stage::Rerank, us);
        }
        self.metrics.record_stage(Stage::ShardWait, shard_wait_us);
        self.metrics.record_stage(Stage::Merge, merge_us);
        self.metrics.record_candidate_flow(spans.candidates_probed, spans.candidates_reranked);
        self.metrics.record_query(start.elapsed().as_micros() as u64, 0);
        RouterReply { hits, shards_answered, shards_total, hedge_fired, degraded }
    }

    /// Collect one shard's answer: wait for the primary up to the hedge
    /// delay, dispatch one backup if it hasn't answered, then wait out
    /// the shard timeout for whoever replies first. Returns the winning
    /// hit list, whether a true hedge fired (backup dispatched while
    /// the primary was still in flight), the winning member index, and
    /// the winner's per-stage spans.
    fn collect_shard(
        &self,
        g: &ReplicaGroup<S>,
        q: &Arc<[f32]>,
        top_k: usize,
        budget: ProbeBudget,
        start: Instant,
        mut p: Pending,
    ) -> Option<(Vec<ScoredItem>, bool, usize, QuerySpans)> {
        let deadline = start + self.cfg.shard_timeout;
        let hedge_at = start + self.hedge_delay_for(g).min(self.cfg.shard_timeout);
        let mut hedge_fired = false;

        let mut winner: Option<(usize, Vec<ScoredItem>, QuerySpans)> = None;
        if !p.dispatched.is_empty() {
            winner = p.rx.recv_timeout(hedge_at.saturating_duration_since(Instant::now())).ok();
        }
        if winner.is_none() {
            // Hedge (or fail over a dead/denied primary): the next
            // admitted member. `pick_backup(len)` when there was no
            // primary at all degenerates to "first admitted member".
            let avoid = p.primary.unwrap_or(g.members.len());
            if let Some(b) = g.pick_backup(avoid) {
                if g.members[b].dispatch(b, q, top_k, budget, p.tx.clone()) {
                    if !p.dispatched.is_empty() {
                        hedge_fired = true;
                        self.metrics.record_hedge_fire();
                    }
                    p.dispatched.push(b);
                } else {
                    g.members[b].shared.breaker.on_failure();
                }
            }
        }
        // From here only in-flight jobs hold senders: a disconnect
        // means every dispatched worker died without replying.
        drop(p.tx);
        if winner.is_none() && !p.dispatched.is_empty() {
            winner = p.rx.recv_timeout(deadline.saturating_duration_since(Instant::now())).ok();
        }

        // Health accounting: the winner and any already-arrived loser
        // answered; members still outstanding when we walk away count a
        // failure (their late replies land in a dropped channel).
        let mut answered = vec![false; g.members.len()];
        if let Some((who, _, _)) = &winner {
            answered[*who] = true;
        }
        while let Ok((who, _, _)) = p.rx.try_recv() {
            answered[who] = true;
        }
        for &i in &p.dispatched {
            if answered[i] {
                g.members[i].shared.breaker.on_success();
            } else {
                g.members[i].shared.breaker.on_failure();
            }
        }
        winner.map(|(who, shard_hits, spans)| (shard_hits, hedge_fired, who, spans))
    }

    /// The hedge delay for one shard: the configured override, or
    /// `hedge_multiplier ×` the shard's measured answer p99 clamped to
    /// `[hedge_min, hedge_max]` (the lower clamp keeps a cold histogram
    /// from hedging every query).
    fn hedge_delay_for(&self, g: &ReplicaGroup<S>) -> Duration {
        if let Some(d) = self.cfg.hedge_delay {
            return d;
        }
        let p99 = g.latency.percentile_us(0.99);
        let scaled = (p99 as f64 * self.cfg.hedge_multiplier).round() as u64;
        Duration::from_micros(scaled).clamp(self.cfg.hedge_min, self.cfg.hedge_max)
    }

    /// Total queries served across all member engines.
    pub fn total_queries(&self) -> u64 {
        self.groups
            .iter()
            .flat_map(|g| g.members.iter())
            .map(|m| m.engine().metrics().snapshot().queries)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transform::dot;
    use crate::util::Rng;

    fn items(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n)
            .map(|i| {
                let s = 0.2 + 2.0 * (i as f32 / n as f32);
                (0..d).map(|_| (rng.f32() - 0.5) * s).collect()
            })
            .collect()
    }

    #[test]
    fn global_ids_score_correctly() {
        let its = items(400, 8, 1);
        let router = ShardedRouter::build(&its, 4, AlshParams::default(), 2);
        let q: Vec<f32> = (0..8).map(|i| (i as f32).sin()).collect();
        for hit in router.query(&q, 10) {
            let want = dot(&q, &its[hit.id as usize]);
            assert!((hit.score - want).abs() < 1e-6, "global id mis-translated");
        }
    }

    #[test]
    fn sharded_matches_single_shard_quality() {
        // With generous tables both configurations find the exact top-1
        // almost always; sharding must not lose it (it only adds tables).
        let its = items(600, 12, 3);
        let params = AlshParams { n_tables: 48, k_per_table: 4, ..Default::default() };
        let sharded = ShardedRouter::build(&its, 3, params, 4);
        let mut rng = Rng::seed_from_u64(5);
        let mut hits = 0;
        for _ in 0..30 {
            let q: Vec<f32> = (0..12).map(|_| rng.f32() - 0.5).collect();
            let want = (0..its.len())
                .max_by(|&a, &b| dot(&its[a], &q).partial_cmp(&dot(&its[b], &q)).unwrap())
                .unwrap() as u32;
            if sharded.query(&q, 10).iter().any(|h| h.id == want) {
                hits += 1;
            }
        }
        assert!(hits >= 27, "sharded top-1 recall {hits}/30");
    }

    #[test]
    fn scratch_path_equals_convenience_path() {
        let its = items(500, 10, 20);
        let router = ShardedRouter::build(&its, 4, AlshParams::default(), 21);
        let mut s = QueryScratch::new();
        let mut rng = Rng::seed_from_u64(22);
        for _ in 0..10 {
            let q: Vec<f32> = (0..10).map(|_| rng.normal_f32()).collect();
            let via_scratch = router.query_into(&q, 7, &mut s).to_vec();
            assert_eq!(via_scratch, router.query(&q, 7));
        }
    }

    #[test]
    fn banded_router_scores_global_ids_exactly() {
        let its = items(500, 8, 30);
        let router = ShardedRouter::build_banded(
            &its,
            4,
            AlshParams::default(),
            BandedParams { n_bands: 3 },
            31,
        );
        assert_eq!(router.n_shards(), 4);
        assert_eq!(router.shard(0).n_bands(), 3);
        let mut s = QueryScratch::new();
        let mut rng = Rng::seed_from_u64(32);
        for _ in 0..10 {
            let q: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
            let out = router.query_into(&q, 10, &mut s).to_vec();
            assert_eq!(out, router.query(&q, 10));
            for hit in &out {
                let want = dot(&q, &its[hit.id as usize]);
                assert!((hit.score - want).abs() < 1e-6, "global id mis-translated");
            }
        }
    }

    #[test]
    fn merge_is_globally_sorted() {
        let its = items(300, 6, 6);
        let router = ShardedRouter::build(&its, 5, AlshParams::default(), 7);
        let out = router.query(&vec![0.4; 6], 15);
        for w in out.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    /// A live shard routes next to frozen ones: the router only sees the
    /// engine query surface, so mutations on one shard show up in merged
    /// results with correctly translated global ids.
    #[test]
    fn live_shard_mutates_behind_router() {
        use crate::index::LiveConfig;
        let dir = std::env::temp_dir().join(format!(
            "alsh_router_live_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let its = items(200, 6, 60);
        let frozen = MipsEngine::new(&its[..100], AlshParams::default(), 61);
        let live = MipsEngine::create_live(
            &dir,
            &its[100..],
            LiveConfig { params: AlshParams::default(), n_bands: 1, seed: 61, ..LiveConfig::default() },
        )
        .unwrap();
        let router = ShardedRouter::from_engines(vec![frozen, live]).unwrap();
        assert_eq!(router.n_shards(), 2);
        let q: Vec<f32> = (0..6).map(|i| (i as f32 * 0.43).cos()).collect();
        let before = router.query(&q, 10);
        assert!(before.iter().all(|h| (h.id as usize) < 200));
        // Mutate the live shard; shard-local ext id 7 dies, so global id
        // 107 must vanish from every later merged result.
        router.shard(1).delete(7).unwrap();
        let after = router.query(&q, 200);
        assert!(after.iter().all(|h| h.id != 107), "deleted item resurfaced");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn single_shard_degenerates_gracefully() {
        let its = items(100, 4, 8);
        let router = ShardedRouter::build(&its, 1, AlshParams::default(), 9);
        assert_eq!(router.n_shards(), 1);
        assert!(!router.query(&vec![0.1; 4], 5).is_empty());
    }

    #[test]
    fn uneven_shard_sizes() {
        let its = items(101, 4, 10);
        let router = ShardedRouter::build(&its, 4, AlshParams::default(), 11);
        // 101 items over 4 shards: 26+26+26+23
        assert_eq!(router.n_shards(), 4);
        let out = router.query(&vec![0.2; 4], 101);
        // Every returned id must be in range.
        assert!(out.iter().all(|h| (h.id as usize) < 101));
    }

    // -- PR 8: seed-derivation audit (satellite) ---------------------------

    /// Every shard must hash with its own family: `build_impl` derives
    /// member (s, r)'s seed as `seed + s·R + r` in exactly one place.
    /// This pins the derivation: shard families differ pairwise (their
    /// L2 offsets are fresh uniform draws per seed).
    #[test]
    fn per_shard_families_are_distinct() {
        let its = items(300, 6, 70);
        let router = ShardedRouter::build(&its, 3, AlshParams::default(), 71);
        for a in 0..3 {
            for b in (a + 1)..3 {
                assert_ne!(
                    router.shard(a).families()[0].b_vector(),
                    router.shard(b).families()[0].b_vector(),
                    "shards {a} and {b} share a hash family"
                );
            }
        }
        // Replicas within one group are families of their own too.
        let rep = ShardedRouter::build_replicated(
            &its,
            2,
            2,
            AlshParams::default(),
            ReplicaConfig::default(),
            71,
        );
        for s in 0..2 {
            let g0 = rep.shard(s).families()[0].b_vector().to_vec();
            // Member 1 = the backup: reach it via breaker_states shape
            // plus the internal accessor used by repair.
            assert_eq!(rep.n_replicas(s), 2);
            let g1 = rep.groups[s].members[1].engine().families()[0].b_vector().to_vec();
            assert_ne!(g0, g1, "replicas of shard {s} share a hash family");
        }
    }

    /// Identical inputs rebuild identical routers (merge determinism),
    /// and at R = 1 the replicated builder is bit-compatible with the
    /// historical per-shard seeding, so shard-count changes reshuffle
    /// ranges but never scores.
    #[test]
    fn build_is_deterministic_and_r1_matches_legacy_seeding() {
        let its = items(240, 6, 72);
        let q: Vec<f32> = (0..6).map(|i| (i as f32 * 0.7).sin()).collect();
        let a = ShardedRouter::build(&its, 3, AlshParams::default(), 73);
        let b = ShardedRouter::build(&its, 3, AlshParams::default(), 73);
        assert_eq!(a.query(&q, 20), b.query(&q, 20), "rebuild changed results");
        let r1 = ShardedRouter::build_replicated(
            &its,
            3,
            1,
            AlshParams::default(),
            ReplicaConfig::default(),
            73,
        );
        assert_eq!(a.query(&q, 20), r1.query(&q, 20), "R=1 diverged from legacy seeding");
        // Exact scores survive any shard count (merge is score-exact:
        // every hit's score equals the true dot product).
        for n_shards in [1, 2, 5] {
            let r = ShardedRouter::build(&its, n_shards, AlshParams::default(), 73);
            for hit in r.query(&q, 15) {
                let want = dot(&q, &its[hit.id as usize]);
                assert!(
                    (hit.score - want).abs() < 1e-6,
                    "{n_shards} shards: score drifted for id {}",
                    hit.id
                );
            }
        }
    }

    // -- PR 8: replicated dispatch basics ----------------------------------

    #[test]
    fn replicated_path_matches_sync_path_when_healthy() {
        let its = items(300, 8, 80);
        // Generous waits: a hedge or timeout under CI-load jitter would
        // let a differently-seeded backup win and break the equality.
        let cfg = ReplicaConfig {
            shard_timeout: Duration::from_secs(10),
            hedge_delay: Some(Duration::from_secs(5)),
            ..Default::default()
        };
        let router =
            ShardedRouter::build_replicated(&its, 3, 2, AlshParams::default(), cfg, 81);
        let q: Vec<f32> = (0..8).map(|i| (i as f32 * 0.3).cos()).collect();
        let reply = router.query_replicated(&q, 10, ProbeBudget::full());
        assert_eq!(reply.shards_answered, 3);
        assert_eq!(reply.shards_total, 3);
        assert!(!reply.degraded);
        assert!((reply.coverage_fraction() - 1.0).abs() < 1e-12);
        // The primary member of every group is the sync path's pick, so
        // a healthy replicated scatter returns the same merged top-k.
        assert_eq!(reply.hits, router.query(&q, 10));
    }

    // -- PR 10: replicated writes ------------------------------------------

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "alsh_router_{tag}_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn live_cfg(seed: u64) -> LiveConfig {
        LiveConfig { params: AlshParams::default(), n_bands: 1, seed, ..LiveConfig::default() }
    }

    fn group_checksums(router: &ShardedRouter, s: usize) -> Vec<u64> {
        router.groups[s]
            .members
            .iter()
            .map(|m| m.engine().state_checksum().unwrap())
            .collect()
    }

    #[test]
    fn live_replicated_write_fanout_and_divergence_scrub() {
        let dir = tmp_dir("wfan");
        let its = items(60, 6, 100);
        let router = ShardedRouter::<Owned>::create_live_replicated(
            &dir,
            &its,
            2,
            3,
            live_cfg(101),
            ReplicaConfig::default(),
        )
        .unwrap();
        // Upsert routes by id modulo and fans out to all three members.
        let r = router.upsert(60, &its[0]).unwrap();
        assert_eq!((r.shard, r.seq, r.acked, r.replicas), (0, 1, 3, 3));
        assert!(!r.degraded);
        let r = router.delete(3).unwrap();
        assert_eq!(r.shard, 1);
        let replies =
            router.upsert_batch(&[(61, its[1].clone()), (62, its[2].clone())]).unwrap();
        assert_eq!(replies.len(), 2, "batch split across both owning shards");
        assert_eq!((replies[0].shard, replies[1].shard), (0, 1));
        // Every member of a group applied the same history.
        for s in 0..2 {
            let sums = group_checksums(&router, s);
            assert!(sums.windows(2).all(|w| w[0] == w[1]), "shard {s} members diverged");
        }
        // The new item serves under its external id; the deleted one is
        // gone.
        let hits = router.query(&its[0], 70);
        assert!(hits.iter().any(|h| h.id == 60), "upserted id 60 not served");
        assert!(hits.iter().all(|h| h.id != 3), "deleted id 3 resurfaced");
        // Shard-0 members have seen 2 write ops (seq counter at 2):
        // crash member (0,1) on its next write. The write still
        // quorum-acks 2/3 and reports degraded.
        router.set_shard_faults(
            0,
            1,
            ShardFaultPlan { write_crash_at: Some(2), ..Default::default() },
        );
        let r = router.upsert(64, &its[4]).unwrap();
        assert_eq!((r.shard, r.acked, r.replicas), (0, 2, 3));
        assert!(r.degraded, "missing member ack must surface as write_degraded");
        assert!(router.groups[0].members[1].shared.breaker.is_quarantined());
        // The divergence scrub catches the member up from a peer's WAL
        // suffix and re-admits it.
        let report = router.scrub_now();
        assert!(report.caught_up.contains(&(0, 1)), "report: {report:?}");
        assert!(!router.groups[0].members[1].shared.breaker.is_quarantined());
        let sums = group_checksums(&router, 0);
        assert!(sums.windows(2).all(|w| w[0] == w[1]), "caught-up member still diverged");
        let snap = router.metrics().snapshot();
        assert_eq!(snap.writes_replicated, 5);
        assert_eq!(snap.catch_up_replays, 1);
        assert_eq!(snap.replica_quarantines, 1);
        // Fully healed: the next write acks all three again.
        let r = router.upsert(66, &its[6]).unwrap();
        assert_eq!(r.acked, 3);
        assert!(!r.degraded);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn catch_up_rebuilds_when_suffix_compacted_away() {
        let dir = tmp_dir("wrebuild");
        let its = items(40, 6, 110);
        let router = ShardedRouter::<Owned>::create_live_replicated(
            &dir,
            &its,
            1,
            3,
            live_cfg(111),
            ReplicaConfig::default(),
        )
        .unwrap();
        for i in 0..3u32 {
            router.upsert(40 + i, &its[i as usize]).unwrap();
        }
        // Crash member 2 on the next write, then land it (2/3 quorum).
        router.set_shard_faults(
            0,
            2,
            ShardFaultPlan { write_crash_at: Some(3), ..Default::default() },
        );
        router.upsert(43, &its[3]).unwrap();
        assert!(router.groups[0].members[2].shared.breaker.is_quarantined());
        // Compact both healthy peers: every donor's WAL restarts past
        // the suffix the lagging member needs.
        router.groups[0].members[0].engine().compact().unwrap();
        router.groups[0].members[1].engine().compact().unwrap();
        let report = router.catch_up(0, 2).unwrap();
        assert_eq!(report.mode, CatchUpMode::Rebuilt, "expected the rebuild fallback");
        assert_eq!(report.high_water, 4);
        let sums = group_checksums(&router, 0);
        assert!(sums.windows(2).all(|w| w[0] == w[1]), "rebuilt member diverged");
        assert!(!router.groups[0].members[2].shared.breaker.is_quarantined());
        assert_eq!(router.metrics().snapshot().replica_repairs, 1);
        // The rebuilt member accepts the next fan-out at the group seq.
        let r = router.upsert(44, &its[4]).unwrap();
        assert_eq!((r.seq, r.acked), (5, 3));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_backpressure_is_structured_and_uniform() {
        use crate::index::WriteStalled;
        let dir = tmp_dir("wstall");
        let its = items(30, 6, 120);
        let router = ShardedRouter::<Owned>::create_live_replicated(
            &dir,
            &its,
            1,
            2,
            LiveConfig { delta_cap: 4, ..live_cfg(121) },
            ReplicaConfig::default(),
        )
        .unwrap();
        for i in 0..4u32 {
            router.upsert(100 + i, &its[i as usize]).unwrap();
        }
        let err = router.upsert(104, &its[4]).unwrap_err();
        let stall = err
            .downcast_ref::<WriteStalled>()
            .expect("stall must be structured, not a string");
        assert_eq!((stall.pending, stall.cap), (4, 4));
        assert!(stall.retry_after_ms >= 10);
        assert_eq!(router.metrics().snapshot().write_stalled, 1);
        // No member accepted a sequence for the refused write.
        let hws: Vec<_> = router.groups[0]
            .members
            .iter()
            .map(|m| m.engine().high_water().unwrap())
            .collect();
        assert_eq!(hws, vec![4, 4], "stall diverged member logs");
        // Reads keep answering at the cap.
        assert!(!router.query(&its[0], 10).is_empty());
        // Compaction drains the backlog; the write then lands.
        router.groups[0].members[0].engine().compact().unwrap();
        router.groups[0].members[1].engine().compact().unwrap();
        let r = router.upsert(104, &its[4]).unwrap();
        assert_eq!((r.seq, r.acked), (5, 2));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn replica_groups_validate_uniform_members() {
        let its = items(100, 4, 90);
        let a = MipsEngine::new(&its[..50], AlshParams::default(), 91);
        let b = MipsEngine::new(&its[..40], AlshParams::default(), 92);
        let err = ReplicaGroup::new(vec![(a, None, 0), (b, None, 1)], &ReplicaConfig::default());
        assert!(err.is_err(), "mismatched member sizes accepted");
    }
}

//! Per-query tracing: stage-level latency attribution, a lock-free sampled
//! span recorder, and an always-capture slow-query log.
//!
//! The paper's argument is a cost decomposition — hashing effort (K·L
//! projections) buys a smaller candidate set so exact rerank stays cheap —
//! and this module makes that decomposition observable per query. A
//! [`QuerySpans`] record rides alongside each request through
//! batcher → engine → router → replica, collecting one timing per pipeline
//! [`Stage`] plus context (trace id, scheme/kind, probe budget, candidate
//! counts, degraded/hedged/partial flags, winning replica).
//!
//! # Hot-path contract
//!
//! With sampling and the slow-query threshold disabled (both default to 0),
//! [`TraceRecorder::offer`] performs three relaxed atomic operations and no
//! allocation; stage timing in the pipeline costs only monotonic clock
//! reads. `tests/zero_alloc.rs` pins this, and the serve benchmark's
//! `observability` phase ratchets the measured overhead at 0%/1%/100%
//! sampling.
//!
//! # Ring semantics
//!
//! Spans are recorded into fixed-capacity seqlock rings (one for sampled
//! spans, one for slow queries). Writers never block or allocate: each
//! claims a monotonically increasing ticket, marks the slot odd, stores the
//! encoded span as plain `u64` words, then marks the slot complete. Readers
//! ([`TraceRecorder::drain_sampled`] / [`TraceRecorder::drain_slow`])
//! validate the sequence word before and after copying, so a span that was
//! overwritten mid-read is simply dropped rather than returned torn. Under
//! extreme wrap (a writer lapping the ring by exactly `2^63` tickets between
//! a reader's two sequence checks) a torn read is theoretically possible;
//! at one query per nanosecond that takes ~292 years, which we accept.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// Number of pipeline stages a query can pass through.
pub const N_STAGES: usize = 9;

/// One stage of the query pipeline. Discriminants index fixed-size arrays
/// in [`QuerySpans`] and `Metrics::stages`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Stage {
    /// Admission control: load-ladder evaluation + bounded-queue enqueue.
    AdmissionWait = 0,
    /// Time spent in the admission queue before the batch loop popped it.
    QueueWait = 1,
    /// From first pop of the batch to dispatching the hash job.
    BatchAssembly = 2,
    /// Batched hashing round-trip (pjrt worker or fused fallback).
    Hash = 3,
    /// Bucket probing / candidate gathering (whole query on live indexes).
    Probe = 4,
    /// Exact inner-product rerank over the candidate set.
    Rerank = 5,
    /// Routed path: scatter + hedged gather wait across shards.
    ShardWait = 6,
    /// Routed path: merge-sort + truncate of per-shard hit lists.
    Merge = 7,
    /// Serializing and writing the reply line to the socket.
    ReplyWrite = 8,
}

impl Stage {
    /// All stages in pipeline order.
    pub const ALL: [Stage; N_STAGES] = [
        Stage::AdmissionWait,
        Stage::QueueWait,
        Stage::BatchAssembly,
        Stage::Hash,
        Stage::Probe,
        Stage::Rerank,
        Stage::ShardWait,
        Stage::Merge,
        Stage::ReplyWrite,
    ];

    /// Stable wire name used in `metrics`, `metrics_prom`, and span JSON.
    pub fn name(self) -> &'static str {
        match self {
            Stage::AdmissionWait => "admission_wait",
            Stage::QueueWait => "queue_wait",
            Stage::BatchAssembly => "batch_assembly",
            Stage::Hash => "hash",
            Stage::Probe => "probe",
            Stage::Rerank => "rerank",
            Stage::ShardWait => "shard_wait",
            Stage::Merge => "merge",
            Stage::ReplyWrite => "reply_write",
        }
    }
}

/// Query was served with a degraded probe budget (load ladder level 1+).
pub const FLAG_DEGRADED: u8 = 1 << 0;
/// At least one shard fired a hedge to a backup replica.
pub const FLAG_HEDGED: u8 = 1 << 1;
/// Reply covers fewer shards than the index holds.
pub const FLAG_PARTIAL: u8 = 1 << 2;
/// The hash stage was served by the pjrt backend (else fused CPU).
pub const FLAG_PJRT_HASH: u8 = 1 << 3;
/// Served by a live (mutable) index; probe covers the whole query.
pub const FLAG_LIVE: u8 = 1 << 4;
/// Captured because total latency crossed the slow-query threshold.
pub const FLAG_SLOW: u8 = 1 << 5;

/// Words in the fixed-size encoding of a [`QuerySpans`].
pub const SPAN_WORDS: usize = 15;

/// Per-query trace record: one timing slot per [`Stage`] plus context.
///
/// `Copy` and fixed-size by design — it is threaded through request structs
/// and written into ring slots without allocating. A stage's timing is only
/// meaningful if its bit is set in the internal mask, distinguishing "ran
/// in 0µs" from "never ran on this path".
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct QuerySpans {
    /// Client-supplied or generated trace id (echoed in every reply).
    pub trace_id: u64,
    /// End-to-end latency in µs (widened by each enclosing layer).
    pub total_us: u64,
    stage_us: [u64; N_STAGES],
    mask: u16,
    /// Candidates produced by the probe stage (summed across shards).
    pub candidates_probed: u64,
    /// Candidates scored by the exact rerank (summed across shards).
    pub candidates_reranked: u64,
    /// Hits returned to the client.
    pub hits: u16,
    /// Requested top-k.
    pub top_k: u16,
    /// `FLAG_*` bits.
    pub flags: u8,
    /// Member index that answered the (last-gathered) shard on the routed
    /// path; 0 on the single-engine path.
    pub winning_replica: u8,
    /// Shards that answered before the deadline (routed path).
    pub shards_answered: u8,
    /// Total shards scattered to (routed path).
    pub shards_total: u8,
    /// Hash scheme: 0 = L2-ALSH, 1 = Sign-ALSH, 2 = Simple-LSH.
    pub scheme: u8,
    /// Index kind: 0 = flat, 1 = norm-range banded.
    pub kind: u8,
    /// Probe budget's table cap, clamped to u16 (`u16::MAX` = unlimited).
    pub budget_tables: u16,
}

impl QuerySpans {
    /// A fresh record carrying `trace_id` and nothing else.
    pub fn with_id(trace_id: u64) -> Self {
        QuerySpans { trace_id, ..QuerySpans::default() }
    }

    /// Record a stage timing (overwrites any previous value for the stage).
    pub fn set_stage(&mut self, stage: Stage, us: u64) {
        self.stage_us[stage as usize] = us;
        self.mask |= 1 << (stage as usize);
    }

    /// Add to a stage timing (used when a stage runs more than once, e.g.
    /// probe across several shards attributed by critical path).
    pub fn max_stage(&mut self, stage: Stage, us: u64) {
        let i = stage as usize;
        if self.mask & (1 << i) == 0 || us > self.stage_us[i] {
            self.stage_us[i] = us;
        }
        self.mask |= 1 << i;
    }

    /// The stage's timing, or `None` if the stage never ran on this path.
    pub fn stage(&self, stage: Stage) -> Option<u64> {
        if self.mask & (1 << (stage as usize)) != 0 {
            Some(self.stage_us[stage as usize])
        } else {
            None
        }
    }

    /// Set a `FLAG_*` bit.
    pub fn set_flag(&mut self, flag: u8) {
        self.flags |= flag;
    }

    /// Test a `FLAG_*` bit.
    pub fn has_flag(&self, flag: u8) -> bool {
        self.flags & flag != 0
    }

    /// The stage with the largest recorded timing, if any stage ran.
    pub fn dominant_stage(&self) -> Option<Stage> {
        Stage::ALL
            .iter()
            .copied()
            .filter(|&s| self.mask & (1 << (s as usize)) != 0)
            .max_by_key(|&s| self.stage_us[s as usize])
    }

    /// Fold a replica member's span record into this routed-query record:
    /// probe/rerank take the critical-path maximum, candidate counts sum,
    /// and context flags union.
    pub fn absorb_member(&mut self, member: &QuerySpans) {
        if let Some(us) = member.stage(Stage::Probe) {
            self.max_stage(Stage::Probe, us);
        }
        if let Some(us) = member.stage(Stage::Rerank) {
            self.max_stage(Stage::Rerank, us);
        }
        self.candidates_probed += member.candidates_probed;
        self.candidates_reranked += member.candidates_reranked;
        self.flags |= member.flags & (FLAG_LIVE | FLAG_PJRT_HASH);
        self.scheme = member.scheme;
        self.kind = member.kind;
    }

    /// Pack into a fixed word array for lock-free ring storage.
    pub fn encode(&self) -> [u64; SPAN_WORDS] {
        let mut w = [0u64; SPAN_WORDS];
        w[0] = self.trace_id;
        w[1] = self.total_us;
        w[2..2 + N_STAGES].copy_from_slice(&self.stage_us);
        w[11] = self.candidates_probed;
        w[12] = self.candidates_reranked;
        w[13] = (self.hits as u64) << 48
            | (self.top_k as u64) << 32
            | (self.flags as u64) << 24
            | (self.winning_replica as u64) << 16
            | (self.shards_answered as u64) << 8
            | self.shards_total as u64;
        w[14] = (self.mask as u64) << 32
            | (self.scheme as u64) << 24
            | (self.kind as u64) << 16
            | self.budget_tables as u64;
        w
    }

    /// Inverse of [`QuerySpans::encode`].
    pub fn decode(w: &[u64; SPAN_WORDS]) -> Self {
        let mut stage_us = [0u64; N_STAGES];
        stage_us.copy_from_slice(&w[2..2 + N_STAGES]);
        QuerySpans {
            trace_id: w[0],
            total_us: w[1],
            stage_us,
            mask: (w[14] >> 32) as u16,
            candidates_probed: w[11],
            candidates_reranked: w[12],
            hits: (w[13] >> 48) as u16,
            top_k: (w[13] >> 32) as u16,
            flags: (w[13] >> 24) as u8,
            winning_replica: (w[13] >> 16) as u8,
            shards_answered: (w[13] >> 8) as u8,
            shards_total: w[13] as u8,
            scheme: (w[14] >> 24) as u8,
            kind: (w[14] >> 16) as u8,
            budget_tables: w[14] as u16,
        }
    }

    /// JSON form used by the `trace` / `slowlog` drain commands.
    /// Allocates — drain path only, never on the hot path.
    pub fn to_json(&self) -> Json {
        let mut stages: Vec<(&str, Json)> = Vec::new();
        for st in Stage::ALL {
            if let Some(us) = self.stage(st) {
                stages.push((st.name(), Json::Num(us as f64)));
            }
        }
        crate::util::json::obj([
            ("trace_id", Json::Num(self.trace_id as f64)),
            ("total_us", Json::Num(self.total_us as f64)),
            ("stages", crate::util::json::obj(stages)),
            (
                "dominant_stage",
                match self.dominant_stage() {
                    Some(s) => Json::Str(s.name().to_string()),
                    None => Json::Null,
                },
            ),
            ("candidates_probed", Json::Num(self.candidates_probed as f64)),
            ("candidates_reranked", Json::Num(self.candidates_reranked as f64)),
            ("hits", Json::Num(self.hits as f64)),
            ("top_k", Json::Num(self.top_k as f64)),
            ("degraded", Json::Bool(self.has_flag(FLAG_DEGRADED))),
            ("hedged", Json::Bool(self.has_flag(FLAG_HEDGED))),
            ("partial", Json::Bool(self.has_flag(FLAG_PARTIAL))),
            ("pjrt_hash", Json::Bool(self.has_flag(FLAG_PJRT_HASH))),
            ("live", Json::Bool(self.has_flag(FLAG_LIVE))),
            ("slow", Json::Bool(self.has_flag(FLAG_SLOW))),
            ("winning_replica", Json::Num(self.winning_replica as f64)),
            ("shards_answered", Json::Num(self.shards_answered as f64)),
            ("shards_total", Json::Num(self.shards_total as f64)),
            ("scheme", Json::Num(self.scheme as f64)),
            ("kind", Json::Num(self.kind as f64)),
            ("budget_tables", Json::Num(self.budget_tables as f64)),
        ])
    }
}

/// One seqlock slot: sequence word + the encoded span.
#[derive(Default)]
struct Slot {
    seq: AtomicU64,
    words: [AtomicU64; SPAN_WORDS],
}

/// Fixed-capacity multi-writer ring. Writers claim tickets and never block;
/// torn slots are detected and skipped by readers.
struct Ring {
    slots: Box<[Slot]>,
    head: AtomicU64,
    /// Drain watermark: tickets below this were already returned.
    tail: AtomicU64,
}

impl Ring {
    fn new(capacity: usize) -> Self {
        let cap = capacity.max(1);
        Ring {
            slots: (0..cap).map(|_| Slot::default()).collect(),
            head: AtomicU64::new(0),
            tail: AtomicU64::new(0),
        }
    }

    /// Zero-allocation publish of an encoded span.
    fn push(&self, words: &[u64; SPAN_WORDS]) {
        let t = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(t % self.slots.len() as u64) as usize];
        slot.seq.store(t * 2 + 1, Ordering::Release);
        for (a, &w) in slot.words.iter().zip(words.iter()) {
            a.store(w, Ordering::Relaxed);
        }
        slot.seq.store(t * 2 + 2, Ordering::Release);
    }

    /// Pop every undrained, fully-written span. Concurrent drains get
    /// disjoint ticket ranges; spans overwritten by a lapping writer are
    /// dropped (newest data wins).
    fn drain(&self) -> Vec<QuerySpans> {
        let head = self.head.load(Ordering::Acquire);
        let cap = self.slots.len() as u64;
        let from = self.tail.swap(head, Ordering::AcqRel).max(head.saturating_sub(cap));
        let mut out = Vec::with_capacity((head - from) as usize);
        for t in from..head {
            let slot = &self.slots[(t % cap) as usize];
            if slot.seq.load(Ordering::Acquire) != t * 2 + 2 {
                continue;
            }
            let mut w = [0u64; SPAN_WORDS];
            for (dst, a) in w.iter_mut().zip(slot.words.iter()) {
                *dst = a.load(Ordering::Relaxed);
            }
            if slot.seq.load(Ordering::Acquire) != t * 2 + 2 {
                continue;
            }
            out.push(QuerySpans::decode(&w));
        }
        out
    }
}

/// Recorder counters, as returned by [`TraceRecorder::stats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceStats {
    /// Spans offered (every completed traced query).
    pub seen: u64,
    /// Spans captured by 1-in-N sampling.
    pub sampled: u64,
    /// Spans captured by the slow-query threshold.
    pub slow_captured: u64,
}

/// Lock-free span recorder: a sampled ring plus an always-capture slow ring.
///
/// Both knobs default to 0 (off) so a freshly built serving stack pays only
/// three relaxed atomic operations per query until an operator turns
/// sampling on via the `trace` server command.
pub struct TraceRecorder {
    sampled: Ring,
    slow: Ring,
    /// Capture 1 in N offered spans; 0 disables sampling.
    sample_every: AtomicU64,
    sample_tick: AtomicU64,
    /// Always capture spans with `total_us >= threshold`; 0 disables.
    slow_threshold_us: AtomicU64,
    seen: AtomicU64,
    n_sampled: AtomicU64,
    n_slow: AtomicU64,
    next_id: AtomicU64,
}

/// Default capacity of the sampled-span ring.
pub const DEFAULT_SAMPLED_CAP: usize = 256;
/// Default capacity of the slow-query ring.
pub const DEFAULT_SLOW_CAP: usize = 64;

impl Default for TraceRecorder {
    fn default() -> Self {
        TraceRecorder::new(DEFAULT_SAMPLED_CAP, DEFAULT_SLOW_CAP)
    }
}

impl std::fmt::Debug for TraceRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceRecorder")
            .field("sample_every", &self.sample_every())
            .field("slow_threshold_us", &self.slow_threshold_us())
            .field("stats", &self.stats())
            .finish()
    }
}

impl TraceRecorder {
    /// A recorder with explicit ring capacities (each at least 1).
    pub fn new(sampled_cap: usize, slow_cap: usize) -> Self {
        TraceRecorder {
            sampled: Ring::new(sampled_cap),
            slow: Ring::new(slow_cap),
            sample_every: AtomicU64::new(0),
            sample_tick: AtomicU64::new(0),
            slow_threshold_us: AtomicU64::new(0),
            seen: AtomicU64::new(0),
            n_sampled: AtomicU64::new(0),
            n_slow: AtomicU64::new(0),
            next_id: AtomicU64::new(0),
        }
    }

    /// A fresh server-generated trace id (never 0, never collides with
    /// another generated id from this recorder).
    pub fn next_trace_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Capture 1 in `n` spans into the sampled ring; 0 turns sampling off.
    pub fn set_sample_every(&self, n: u64) {
        self.sample_every.store(n, Ordering::Relaxed);
    }

    /// Current sampling cadence (0 = off).
    pub fn sample_every(&self) -> u64 {
        self.sample_every.load(Ordering::Relaxed)
    }

    /// Always capture spans at least this slow (µs); 0 turns the slow log off.
    pub fn set_slow_threshold_us(&self, us: u64) {
        self.slow_threshold_us.store(us, Ordering::Relaxed);
    }

    /// Current slow-query threshold in µs (0 = off).
    pub fn slow_threshold_us(&self) -> u64 {
        self.slow_threshold_us.load(Ordering::Relaxed)
    }

    /// Offer a completed span. Never blocks and never allocates; with both
    /// knobs off this is three relaxed atomic operations.
    pub fn offer(&self, spans: &QuerySpans) {
        self.seen.fetch_add(1, Ordering::Relaxed);
        let every = self.sample_every.load(Ordering::Relaxed);
        let threshold = self.slow_threshold_us.load(Ordering::Relaxed);
        let sampled =
            every > 0 && self.sample_tick.fetch_add(1, Ordering::Relaxed) % every == 0;
        let slow = threshold > 0 && spans.total_us >= threshold;
        if !sampled && !slow {
            return;
        }
        let mut copy = *spans;
        if slow {
            copy.set_flag(FLAG_SLOW);
        }
        let words = copy.encode();
        if sampled {
            self.n_sampled.fetch_add(1, Ordering::Relaxed);
            self.sampled.push(&words);
        }
        if slow {
            self.n_slow.fetch_add(1, Ordering::Relaxed);
            self.slow.push(&words);
        }
    }

    /// Pop all undrained sampled spans (oldest first, up to ring capacity).
    pub fn drain_sampled(&self) -> Vec<QuerySpans> {
        self.sampled.drain()
    }

    /// Pop all undrained slow-query spans (oldest first, up to ring capacity).
    pub fn drain_slow(&self) -> Vec<QuerySpans> {
        self.slow.drain()
    }

    /// Offered / captured counters since construction.
    pub fn stats(&self) -> TraceStats {
        TraceStats {
            seen: self.seen.load(Ordering::Relaxed),
            sampled: self.n_sampled.load(Ordering::Relaxed),
            slow_captured: self.n_slow.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_span(id: u64) -> QuerySpans {
        let mut s = QuerySpans::with_id(id);
        s.total_us = 1234;
        s.set_stage(Stage::QueueWait, 10);
        s.set_stage(Stage::Hash, 900);
        s.set_stage(Stage::Probe, 200);
        s.set_stage(Stage::Rerank, 0); // ran, took <1µs
        s.candidates_probed = 4242;
        s.candidates_reranked = 1000;
        s.hits = 10;
        s.top_k = 10;
        s.set_flag(FLAG_DEGRADED);
        s.set_flag(FLAG_LIVE);
        s.winning_replica = 2;
        s.shards_answered = 3;
        s.shards_total = 4;
        s.scheme = 1;
        s.kind = 1;
        s.budget_tables = 16;
        s
    }

    #[test]
    fn encode_decode_roundtrip() {
        let s = sample_span(987654321);
        assert_eq!(QuerySpans::decode(&s.encode()), s);
        // Default (all-unset) record also roundtrips.
        let d = QuerySpans::default();
        assert_eq!(QuerySpans::decode(&d.encode()), d);
    }

    #[test]
    fn mask_distinguishes_zero_from_unset() {
        let s = sample_span(1);
        assert_eq!(s.stage(Stage::Rerank), Some(0)); // ran in 0µs
        assert_eq!(s.stage(Stage::Merge), None); // never ran
        let rt = QuerySpans::decode(&s.encode());
        assert_eq!(rt.stage(Stage::Rerank), Some(0));
        assert_eq!(rt.stage(Stage::Merge), None);
    }

    #[test]
    fn dominant_stage_picks_largest_recorded() {
        let s = sample_span(1);
        assert_eq!(s.dominant_stage(), Some(Stage::Hash));
        assert_eq!(QuerySpans::default().dominant_stage(), None);
    }

    #[test]
    fn absorb_member_takes_critical_path() {
        let mut router = QuerySpans::with_id(7);
        let mut a = QuerySpans::default();
        a.set_stage(Stage::Probe, 100);
        a.set_stage(Stage::Rerank, 50);
        a.candidates_probed = 10;
        a.candidates_reranked = 10;
        let mut b = QuerySpans::default();
        b.set_stage(Stage::Probe, 300);
        b.set_stage(Stage::Rerank, 20);
        b.candidates_probed = 30;
        b.candidates_reranked = 25;
        b.set_flag(FLAG_LIVE);
        router.absorb_member(&a);
        router.absorb_member(&b);
        assert_eq!(router.stage(Stage::Probe), Some(300));
        assert_eq!(router.stage(Stage::Rerank), Some(50));
        assert_eq!(router.candidates_probed, 40);
        assert_eq!(router.candidates_reranked, 35);
        assert!(router.has_flag(FLAG_LIVE));
    }

    #[test]
    fn off_by_default_captures_nothing() {
        let r = TraceRecorder::default();
        for i in 0..100 {
            let mut s = sample_span(i);
            s.total_us = 1_000_000; // would trip any plausible threshold
            r.offer(&s);
        }
        assert!(r.drain_sampled().is_empty());
        assert!(r.drain_slow().is_empty());
        let st = r.stats();
        assert_eq!(st.seen, 100);
        assert_eq!(st.sampled, 0);
        assert_eq!(st.slow_captured, 0);
    }

    #[test]
    fn one_in_n_sampling_cadence() {
        let r = TraceRecorder::new(1024, 64);
        r.set_sample_every(10);
        for i in 0..100 {
            r.offer(&sample_span(i));
        }
        let got = r.drain_sampled();
        assert_eq!(got.len(), 10, "exactly 1 in 10 of 100 offers");
        // Ticket cadence: ids 0, 10, 20, ...
        let ids: Vec<u64> = got.iter().map(|s| s.trace_id).collect();
        assert_eq!(ids, (0..100).step_by(10).collect::<Vec<u64>>());
        assert_eq!(r.stats().sampled, 10);
        // A second drain returns nothing new.
        assert!(r.drain_sampled().is_empty());
    }

    #[test]
    fn slow_threshold_always_captures_and_flags() {
        let r = TraceRecorder::default();
        r.set_slow_threshold_us(500);
        let mut fast = sample_span(1);
        fast.total_us = 499;
        let mut slow = sample_span(2);
        slow.total_us = 500;
        r.offer(&fast);
        r.offer(&slow);
        let got = r.drain_slow();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].trace_id, 2);
        assert!(got[0].has_flag(FLAG_SLOW));
        assert_eq!(r.stats().slow_captured, 1);
        // Sampled ring untouched: sampling is still off.
        assert!(r.drain_sampled().is_empty());
    }

    #[test]
    fn ring_wrap_keeps_newest() {
        let r = TraceRecorder::new(8, 8);
        r.set_sample_every(1);
        for i in 0..20 {
            r.offer(&sample_span(i));
        }
        let got = r.drain_sampled();
        assert_eq!(got.len(), 8, "ring keeps only the newest capacity spans");
        let ids: Vec<u64> = got.iter().map(|s| s.trace_id).collect();
        assert_eq!(ids, (12..20).collect::<Vec<u64>>());
    }

    #[test]
    fn drain_watermark_resumes_where_it_left_off() {
        let r = TraceRecorder::new(64, 8);
        r.set_sample_every(1);
        for i in 0..5 {
            r.offer(&sample_span(i));
        }
        assert_eq!(r.drain_sampled().len(), 5);
        for i in 5..9 {
            r.offer(&sample_span(i));
        }
        let got = r.drain_sampled();
        let ids: Vec<u64> = got.iter().map(|s| s.trace_id).collect();
        assert_eq!(ids, vec![5, 6, 7, 8]);
    }

    #[test]
    fn concurrent_writers_never_tear() {
        use std::sync::Arc;
        let r = Arc::new(TraceRecorder::new(32, 8));
        r.set_sample_every(1);
        let mut threads = Vec::new();
        for t in 0..4u64 {
            let r = Arc::clone(&r);
            threads.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    let mut s = sample_span(t * 1000 + i);
                    // Correlated payload lets the reader detect tearing.
                    s.total_us = s.trace_id * 3;
                    s.candidates_probed = s.trace_id * 7;
                    r.offer(&s);
                }
            }));
        }
        // Drain concurrently with the writers.
        let mut seen = 0usize;
        for _ in 0..50 {
            for s in r.drain_sampled() {
                assert_eq!(s.total_us, s.trace_id * 3, "torn span");
                assert_eq!(s.candidates_probed, s.trace_id * 7, "torn span");
                seen += 1;
            }
        }
        for th in threads {
            th.join().unwrap();
        }
        for s in r.drain_sampled() {
            assert_eq!(s.total_us, s.trace_id * 3);
            assert_eq!(s.candidates_probed, s.trace_id * 7);
            seen += 1;
        }
        assert!(seen > 0);
        assert_eq!(r.stats().seen, 2000);
    }

    #[test]
    fn generated_ids_are_unique_and_nonzero() {
        let r = TraceRecorder::default();
        let a = r.next_trace_id();
        let b = r.next_trace_id();
        assert_ne!(a, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn span_json_has_wire_fields() {
        let s = sample_span(42);
        let j = s.to_json();
        assert_eq!(j.get("trace_id").and_then(|v| v.as_f64()), Some(42.0));
        assert_eq!(
            j.get("dominant_stage").and_then(|v| v.as_str()).map(str::to_string),
            Some("hash".to_string())
        );
        let stages = j.get("stages").expect("stages object");
        assert_eq!(stages.get("hash").and_then(|v| v.as_f64()), Some(900.0));
        assert_eq!(stages.get("rerank").and_then(|v| v.as_f64()), Some(0.0));
        assert!(stages.get("merge").is_none(), "unset stage omitted");
        assert_eq!(j.get("degraded"), Some(&Json::Bool(true)));
    }
}

//! Dynamic batcher over the hash path: PJRT artifact when available,
//! fused pure-Rust matrix–matrix hashing otherwise — hardened for
//! overload and runtime faults.
//!
//! PJRT executables are shape-monomorphic (fixed batch) and their handles
//! are not `Send`, so the design is:
//!
//! * a dedicated **worker thread** owns the primary hash backend — either
//!   the `Runtime` with the compiled `alsh_query` executable, or (when no
//!   artifacts are present / no XLA backend is built in) the engine's
//!   [`crate::lsh::FusedHasher`], driven in batch matrix–matrix mode. A
//!   failing primary is retried with capped exponential backoff, then the
//!   **circuit breaker** trips ([`BreakerState::Open`]) and the batch —
//!   and subsequent batches — serve through the fused CPU path until a
//!   cooldown elapses and a half-open probe succeeds;
//! * a **batcher thread** pops admitted queries from the bounded queue,
//!   collects a batch until it fills (`max_batch`), the wait deadline
//!   passes (`max_wait`), or the first query's own deadline looms, then
//!   ships one batch to the worker and fans results back out per query
//!   (budgeted CSR probe + exact rerank on the shared `MipsEngine`,
//!   through one reused `QueryScratch`). Expired or malformed requests
//!   are triaged *before* dispatch, so a backend failure is always
//!   genuine. If the worker dies mid-job (see [`FaultPlan::poison_at`])
//!   the batcher serves the batch inline on the fused path — readers
//!   never hang on a dead worker.
//!
//! Admission is deadline-aware ([`BatcherHandle::query_deadline`]): every
//! request carries a deadline, expired requests are rejected with a
//! structured `deadline_exceeded` error instead of a stale answer, and
//! the [`LoadController`] ladder decides per request whether it runs at
//! full budget, at the degraded [`crate::index::ProbeBudget`], or is shed
//! with `overloaded`. Channels are std mpsc; per-request responses travel
//! over one-shot channels (an mpsc used once).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::index::storage::Storage;
use crate::index::{MipsHashScheme, ProbeBudget, ScoredItem};
use crate::runtime::{ArtifactMeta, Runtime};

use super::admission::{AdmissionConfig, LoadController, ServeError};
use super::engine::MipsEngine;
use super::metrics::Metrics;
use super::trace::{QuerySpans, Stage, FLAG_DEGRADED, FLAG_PJRT_HASH};

/// Dynamic-batching + robustness policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Max queries per dispatched batch (clamped to the artifact batch).
    pub max_batch: usize,
    /// Max time the first query in a batch waits for company.
    pub max_wait: Duration,
    /// Depth of the bounded admission queue (backpressure bound; a full
    /// queue sheds with a structured `overloaded` error).
    pub queue_depth: usize,
    /// Deadline/ladder configuration (see [`AdmissionConfig`]).
    pub admission: AdmissionConfig,
    /// Primary-hash retries before the circuit breaker trips.
    pub hash_retries: usize,
    /// Initial retry backoff; doubles per retry, capped at 8×.
    pub retry_backoff: Duration,
    /// How long the breaker stays open before a half-open re-probe.
    pub breaker_cooldown: Duration,
    /// Test-only fault injection (None in production).
    pub fault_plan: Option<FaultPlan>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            queue_depth: 1024,
            admission: AdmissionConfig::default(),
            hash_retries: 2,
            retry_backoff: Duration::from_micros(500),
            breaker_cooldown: Duration::from_millis(250),
            fault_plan: None,
        }
    }
}

/// Test-only fault injection, keyed by the worker's batch sequence
/// number. `fails_at` batches make the primary hash attempt error (so
/// retries, the breaker, and the fused fallback are exercised on real
/// plumbing); `delay_for` batches sleep first (latency spikes);
/// `poison_at` kills the worker thread mid-job without a reply (the
/// batcher must detect the drop and serve inline).
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultPlan {
    /// First batch seq whose primary hash attempt fails…
    pub fail_from: usize,
    /// …up to (exclusive) this one. `usize::MAX` = permanent.
    pub fail_until: usize,
    /// First batch seq delayed by `delay`…
    pub delay_from: usize,
    /// …up to (exclusive) this one.
    pub delay_until: usize,
    /// Injected latency per delayed batch.
    pub delay: Duration,
    /// Batch seq at which the worker thread exits without replying.
    pub poison_at: Option<usize>,
}

impl FaultPlan {
    fn fails_at(&self, seq: usize) -> bool {
        seq >= self.fail_from && seq < self.fail_until
    }

    fn delay_for(&self, seq: usize) -> Option<Duration> {
        (seq >= self.delay_from && seq < self.delay_until && !self.delay.is_zero())
            .then_some(self.delay)
    }
}

/// Circuit-breaker state over the primary hash backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Primary path healthy; batches hash through it.
    Closed,
    /// Primary path failed `hash_retries + 1` times in a row (or the
    /// worker died): batches serve via the fused CPU path until the
    /// cooldown elapses.
    Open,
    /// Cooldown elapsed: the next batch probes the primary path; success
    /// re-closes the breaker, failure re-opens it.
    HalfOpen,
}

impl BreakerState {
    pub(crate) fn from_u8(v: u8) -> Self {
        match v {
            0 => BreakerState::Closed,
            1 => BreakerState::Open,
            _ => BreakerState::HalfOpen,
        }
    }

    /// Wire name used by the server `metrics` command.
    pub fn as_str(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half_open",
        }
    }
}

struct HashJob {
    rows: Vec<Vec<f32>>,
    resp: Sender<crate::Result<Vec<Vec<i32>>>>,
}

struct QueryRequest {
    vector: Vec<f32>,
    top_k: usize,
    /// Hard completion deadline; past it the request errors, never
    /// serves a stale answer.
    deadline: Instant,
    /// Admission time, for end-to-end latency (the ladder's p99 signal).
    enqueued: Instant,
    /// Probe budget assigned at admission (full or the degraded budget).
    budget: ProbeBudget,
    degraded: bool,
    /// Per-stage trace record, threaded through the pipeline and
    /// returned on the reply.
    spans: QuerySpans,
    resp: Sender<Result<QueryReply, ServeError>>,
}

/// A served query: the top-k hits plus whether the query ran under the
/// degraded budget (surfaced to clients as `degraded: true`), the echoed
/// trace id, and the per-stage span record.
#[derive(Clone, Debug, PartialEq)]
pub struct QueryReply {
    pub hits: Vec<ScoredItem>,
    pub degraded: bool,
    /// Client-supplied or generated trace id, echoed in every reply.
    pub trace_id: u64,
    /// Per-stage latency attribution for this query.
    pub spans: QuerySpans,
}

enum Msg {
    Query(QueryRequest),
    /// Explicit stop: `recv()` blocks forever if any handle clone is
    /// still alive, so shutdown is signalled in-band.
    Shutdown,
}

/// Which hash implementation the worker thread drives.
enum HashBackend {
    /// Compiled `alsh_query` artifact through PJRT.
    Pjrt { meta: ArtifactMeta, a_dk: Vec<f32>, b: Vec<f32> },
    /// Fused pure-Rust batch hashing on the engine's stacked matrix.
    Fused,
}

/// Cheap-to-clone client handle.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: SyncSender<Msg>,
    controller: Arc<LoadController>,
    metrics: Arc<Metrics>,
    breaker: Arc<AtomicU8>,
    degraded_budget: ProbeBudget,
    default_deadline: Duration,
}

impl BatcherHandle {
    /// Submit one MIPS query with the configured default deadline;
    /// blocks until its batch is served. Compatibility wrapper over
    /// [`BatcherHandle::query_deadline`].
    pub fn query(&self, vector: Vec<f32>, top_k: usize) -> crate::Result<Vec<ScoredItem>> {
        self.query_deadline(vector, top_k, None)
            .map(|r| r.hits)
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Submit one MIPS query under a deadline (None = the configured
    /// default). Admission is where the ladder bites: already-expired
    /// requests get `deadline_exceeded`, shed-level load gets
    /// `overloaded` (as does a full queue), and degraded-level load runs
    /// under the reduced probe budget with `degraded: true` in the
    /// reply.
    pub fn query_deadline(
        &self,
        vector: Vec<f32>,
        top_k: usize,
        deadline: Option<Instant>,
    ) -> Result<QueryReply, ServeError> {
        self.query_traced(vector, top_k, deadline, None)
    }

    /// [`BatcherHandle::query_deadline`] with an explicit trace id
    /// (client-supplied; `None` generates one). The reply carries the
    /// trace id and the per-stage span record with admission wait, queue
    /// wait, batch assembly, hash, probe, and rerank attributed.
    pub fn query_traced(
        &self,
        vector: Vec<f32>,
        top_k: usize,
        deadline: Option<Instant>,
        trace_id: Option<u64>,
    ) -> Result<QueryReply, ServeError> {
        let now = Instant::now();
        let deadline = deadline.unwrap_or(now + self.default_deadline);
        if deadline <= now {
            self.metrics.record_deadline_exceeded();
            return Err(ServeError::DeadlineExceeded(
                "deadline expired before admission".into(),
            ));
        }
        let level = self.controller.evaluate();
        if level >= 2 {
            self.metrics.record_shed();
            return Err(ServeError::Overloaded("server is shedding load".into()));
        }
        let (budget, degraded) = if level == 1 {
            (self.degraded_budget, true)
        } else {
            (ProbeBudget::full(), false)
        };
        let trace_id = trace_id.unwrap_or_else(|| self.metrics.tracer.next_trace_id());
        let mut spans = QuerySpans::with_id(trace_id);
        if degraded {
            spans.set_flag(FLAG_DEGRADED);
        }
        // Admission wait: ladder evaluation + budget assignment. The
        // queue push itself is the head of the queue-wait stage.
        let admission_us = now.elapsed().as_micros() as u64;
        spans.set_stage(Stage::AdmissionWait, admission_us);
        self.metrics.record_stage(Stage::AdmissionWait, admission_us);
        let (resp, rx) = mpsc::channel();
        let req = QueryRequest {
            vector,
            top_k,
            deadline,
            enqueued: now,
            budget,
            degraded,
            spans,
            resp,
        };
        match self.tx.try_send(Msg::Query(req)) {
            Ok(()) => self.controller.on_enqueue(),
            Err(TrySendError::Full(_)) => {
                self.metrics.record_shed();
                return Err(ServeError::Overloaded("admission queue is full".into()));
            }
            Err(TrySendError::Disconnected(_)) => {
                return Err(ServeError::Internal("batcher is gone".into()));
            }
        }
        rx.recv().map_err(|_| ServeError::Internal("batcher dropped the request".into()))?
    }

    /// The shared metrics (tracer, stage histograms, counters).
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// The shared ladder state (level, recent p99).
    pub fn controller(&self) -> &LoadController {
        &self.controller
    }

    /// Current ladder level without re-evaluating.
    pub fn level(&self) -> u8 {
        self.controller.level()
    }

    /// Current circuit-breaker state.
    pub fn breaker_state(&self) -> BreakerState {
        BreakerState::from_u8(self.breaker.load(Ordering::Relaxed))
    }

    /// The probe budget degraded-level queries run under.
    pub fn degraded_budget(&self) -> ProbeBudget {
        self.degraded_budget
    }
}

/// The running batcher: handle + join handles for shutdown.
pub struct PjrtBatcher {
    handle: Option<BatcherHandle>,
    batcher_thread: Option<std::thread::JoinHandle<()>>,
    worker_thread: Option<std::thread::JoinHandle<()>>,
}

/// Batch-hash `rows` with the fused pure-Rust matrix–matrix kernel:
/// Q-transform each row per the index's scheme, then one blocked pass
/// over the stacked `[L·K × D']` matrix (shared by both index kinds —
/// the banded index hashes queries with the same fused family set as the
/// flat one, whatever the scheme; a live engine's hasher is stable
/// across base generations, so the codes stay valid through compaction
/// swaps). The scratch buffers are owned by the calling loop.
fn fused_hash_batch<S: Storage>(
    engine: &MipsEngine<S>,
    rows: &[Vec<f32>],
    qx: &mut Vec<f32>,
    xs: &mut Vec<f32>,
    codes: &mut Vec<i32>,
) -> crate::Result<Vec<Vec<i32>>> {
    let dim = engine.dim();
    let m = engine.params().m;
    let scheme = engine.scheme();
    let hasher = engine.hasher();
    let nc = hasher.n_codes();
    xs.clear();
    for row in rows {
        anyhow::ensure!(row.len() == dim, "row dim {} != {dim}", row.len());
        scheme.query_into(row, m, qx);
        xs.extend_from_slice(qx);
    }
    let need = rows.len() * nc;
    if codes.len() < need {
        codes.resize(need, 0);
    }
    hasher.hash_batch_into(xs, rows.len(), &mut codes[..need]);
    Ok((0..rows.len()).map(|i| codes[i * nc..(i + 1) * nc].to_vec()).collect())
}

/// One attempt at the primary hash path: PJRT when loaded, the fused CPU
/// kernel otherwise. Fault injection fails the attempt *before* it runs,
/// so injected failures exercise exactly the retry/breaker plumbing a
/// real backend failure would.
fn primary_hash_once<S: Storage>(
    pjrt: &mut Option<(Runtime, ArtifactMeta, Vec<f32>, Vec<f32>)>,
    engine: &MipsEngine<S>,
    rows: &[Vec<f32>],
    injected: bool,
    qx: &mut Vec<f32>,
    xs: &mut Vec<f32>,
    codes: &mut Vec<i32>,
) -> crate::Result<Vec<Vec<i32>>> {
    anyhow::ensure!(!injected, "injected hash failure (fault plan)");
    match pjrt {
        Some((runtime, meta, a_dk, b)) => runtime.run_hash(meta, rows, a_dk, b),
        None => fused_hash_batch(engine, rows, qx, xs, codes),
    }
}

impl PjrtBatcher {
    /// Spawn the worker thread + batcher thread.
    ///
    /// When `artifacts_dir` holds a matching `alsh_query` artifact, the
    /// worker hashes through PJRT; the artifact must match the engine's
    /// item dimension and `m`, and the engine's `L*K` hashes must fit in
    /// its K columns (a mismatch is a hard error). When no runtime can be
    /// loaded at all, the worker falls back to the engine's fused CPU
    /// hasher and serving works without artifacts.
    ///
    /// Storage-generic: a zero-copy mapped engine
    /// (`MipsEngine::open_mmap`) batches exactly like a heap one — the
    /// fused fallback hashes through the owned family matrix and the
    /// probes walk the mapped CSR sections.
    pub fn spawn<S: Storage>(
        engine: Arc<MipsEngine<S>>,
        artifacts_dir: impl Into<std::path::PathBuf>,
        cfg: BatcherConfig,
    ) -> crate::Result<Self> {
        let dir = artifacts_dir.into();
        let dim = engine.dim();
        let m = engine.params().m;
        let params = *engine.params();
        let lk = params.n_tables * params.k_per_table;

        // Probe the runtime on the caller thread for a fast error on real
        // config mismatches; fall back to fused hashing when the runtime
        // itself is unavailable. Only the L2-ALSH scheme has a compiled
        // `alsh_query` artifact — the SRP schemes always hash through the
        // fused CPU kernel (which serves them at full speed; the bit-pack
        // keys need no artifact).
        let backend = if params.scheme != MipsHashScheme::L2Alsh {
            crate::log_info!(
                "scheme {} has no PJRT query artifact; batcher using fused CPU hashing",
                params.scheme
            );
            HashBackend::Fused
        } else {
            match Runtime::load(&dir) {
                Ok(probe) => {
                    let meta = probe.find("alsh_query", dim)?;
                    anyhow::ensure!(
                        meta.m == m,
                        "artifact m={} but index m={m}; re-run make artifacts",
                        meta.m
                    );
                    drop(probe);
                    anyhow::ensure!(
                        lk <= meta.k,
                        "index uses {lk} hashes > artifact capacity {}",
                        meta.k
                    );
                    let (a_dk, b) = engine.concat_family_inputs(meta.k);
                    HashBackend::Pjrt { meta, a_dk, b }
                }
                Err(e) => {
                    crate::log_info!(
                        "PJRT runtime unavailable ({e:#}); batcher using fused CPU hashing"
                    );
                    HashBackend::Fused
                }
            }
        };
        let max_batch = match &backend {
            HashBackend::Pjrt { meta, .. } => cfg.max_batch.min(meta.batch).max(1),
            HashBackend::Fused => cfg.max_batch.max(1),
        };
        let pjrt_primary = matches!(&backend, HashBackend::Pjrt { .. });

        let metrics = engine.metrics();
        let controller = Arc::new(LoadController::new(
            cfg.admission,
            cfg.queue_depth,
            Arc::clone(&metrics),
        ));
        let breaker = Arc::new(AtomicU8::new(0)); // Closed

        // Degraded budget: a fraction of the tables (and, for banded
        // indexes, of the norm bands — the smallest-norm bands are
        // dropped first) plus a rerank-pool cap. n_probes stays 1: the
        // serving path is single-probe today, so the degraded knobs are
        // the ones that cut real work.
        let frac = cfg.admission.degraded_table_frac;
        let nb = engine.n_bands();
        let degraded_budget = ProbeBudget {
            n_probes: 1,
            max_tables: ((params.n_tables as f64 * frac).ceil() as usize)
                .clamp(1, params.n_tables),
            max_bands: ((nb as f64 * frac).ceil() as usize).clamp(1, nb),
            max_rerank: cfg.admission.degraded_rerank_cap.max(1),
        };

        // Worker thread: owns the primary hash backend (PJRT handles are
        // not Send, so the runtime is re-created on this thread), the
        // retry/backoff loop, and the breaker transitions.
        let (job_tx, job_rx) = mpsc::channel::<HashJob>();
        let worker_dir = dir.clone();
        let worker_engine = Arc::clone(&engine);
        let worker_breaker = Arc::clone(&breaker);
        let worker_metrics = Arc::clone(&metrics);
        let plan = cfg.fault_plan;
        let retries = cfg.hash_retries;
        let retry_backoff = cfg.retry_backoff.max(Duration::from_micros(1));
        let cooldown = cfg.breaker_cooldown;
        let worker_thread = std::thread::Builder::new()
            .name("hash-worker".into())
            .spawn(move || {
                let mut pjrt = match backend {
                    HashBackend::Pjrt { meta, a_dk, b } => match Runtime::load(&worker_dir) {
                        Ok(r) => Some((r, meta, a_dk, b)),
                        Err(e) => {
                            // Load failure is not a runtime fault: the
                            // fused path simply becomes the primary and
                            // the breaker stays closed over it.
                            crate::log_error!(
                                "pjrt worker failed to start ({e:#}); fused CPU hashing is the primary path"
                            );
                            None
                        }
                    },
                    HashBackend::Fused => None,
                };
                let (mut qx, mut xs, mut codes) = (Vec::new(), Vec::new(), Vec::new());
                let mut seq: usize = 0;
                let mut reopen_at = Instant::now();
                while let Ok(job) = job_rx.recv() {
                    let s = seq;
                    seq += 1;
                    if let Some(p) = plan {
                        if p.poison_at == Some(s) {
                            crate::log_warn!("fault plan: poisoning hash worker at batch {s}");
                            return; // job unanswered; the batcher serves it inline
                        }
                        if let Some(d) = p.delay_for(s) {
                            std::thread::sleep(d);
                        }
                    }
                    let injected = plan.map_or(false, |p| p.fails_at(s));
                    let state = BreakerState::from_u8(worker_breaker.load(Ordering::Relaxed));
                    let attempt_primary = match state {
                        BreakerState::Closed => true,
                        BreakerState::Open | BreakerState::HalfOpen => {
                            if Instant::now() >= reopen_at {
                                worker_breaker
                                    .store(BreakerState::HalfOpen as u8, Ordering::Relaxed);
                                true
                            } else {
                                false
                            }
                        }
                    };
                    let res = if attempt_primary {
                        let mut backoff = retry_backoff;
                        let mut out = None;
                        let mut last_err = None;
                        for attempt in 0..=retries {
                            match primary_hash_once(
                                &mut pjrt, &worker_engine, &job.rows, injected, &mut qx,
                                &mut xs, &mut codes,
                            ) {
                                Ok(rows) => {
                                    out = Some(rows);
                                    break;
                                }
                                Err(e) => {
                                    last_err = Some(e);
                                    if attempt < retries {
                                        std::thread::sleep(backoff);
                                        backoff = (backoff * 2).min(retry_backoff * 8);
                                    }
                                }
                            }
                        }
                        match out {
                            Some(rows) => {
                                if state != BreakerState::Closed {
                                    crate::log_info!(
                                        "hash breaker re-closed after successful probe"
                                    );
                                }
                                worker_breaker
                                    .store(BreakerState::Closed as u8, Ordering::Relaxed);
                                Ok(rows)
                            }
                            None => {
                                crate::log_warn!(
                                    "primary hash path failed {} times ({:#}); breaker open, serving via fused CPU path",
                                    retries + 1,
                                    last_err.as_ref().expect("failure implies an error")
                                );
                                worker_breaker
                                    .store(BreakerState::Open as u8, Ordering::Relaxed);
                                reopen_at = Instant::now() + cooldown;
                                worker_metrics.record_pjrt_fallback();
                                fused_hash_batch(
                                    &worker_engine, &job.rows, &mut qx, &mut xs, &mut codes,
                                )
                            }
                        }
                    } else {
                        worker_metrics.record_pjrt_fallback();
                        fused_hash_batch(&worker_engine, &job.rows, &mut qx, &mut xs, &mut codes)
                    };
                    let _ = job.resp.send(res);
                }
            })
            .expect("spawn hash worker");

        // Batcher thread: dynamic batching + triage + fan-out.
        let (tx, rx) = mpsc::sync_channel::<Msg>(cfg.queue_depth);
        let loop_controller = Arc::clone(&controller);
        let loop_breaker = Arc::clone(&breaker);
        let loop_metrics = Arc::clone(&metrics);
        let default_deadline = cfg.admission.default_deadline;
        let batcher_thread = std::thread::Builder::new()
            .name("alsh-batcher".into())
            .spawn(move || {
                Self::batch_loop(
                    engine,
                    loop_metrics,
                    loop_controller,
                    loop_breaker,
                    rx,
                    job_tx,
                    max_batch,
                    cfg.max_wait,
                    lk,
                    pjrt_primary,
                )
            })
            .expect("spawn batcher");

        Ok(Self {
            handle: Some(BatcherHandle {
                tx,
                controller,
                metrics,
                breaker,
                degraded_budget,
                default_deadline,
            }),
            batcher_thread: Some(batcher_thread),
            worker_thread: Some(worker_thread),
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn batch_loop<S: Storage>(
        engine: Arc<MipsEngine<S>>,
        metrics: Arc<Metrics>,
        controller: Arc<LoadController>,
        breaker: Arc<AtomicU8>,
        rx: Receiver<Msg>,
        job_tx: Sender<HashJob>,
        max_batch: usize,
        max_wait: Duration,
        lk: usize,
        pjrt_primary: bool,
    ) {
        // One scratch for the whole loop: probes + reranks are
        // allocation-free at steady state. The f-prefixed buffers back
        // the inline fused fallback (worker-death path only).
        let mut scratch = engine.scratch();
        let dim = engine.dim();
        let (mut fqx, mut fxs, mut fcodes) = (Vec::new(), Vec::new(), Vec::new());
        'outer: while let Ok(first) = rx.recv() {
            let Msg::Query(mut first) = first else { break };
            controller.on_dequeue();
            let assembly_start = Instant::now();
            let qw = first.enqueued.elapsed().as_micros() as u64;
            first.spans.set_stage(Stage::QueueWait, qw);
            metrics.record_stage(Stage::QueueWait, qw);
            let mut reqs = vec![first];
            // Close the batch at max_wait, or earlier if the first
            // query's deadline would otherwise expire while waiting.
            let close = (assembly_start + max_wait).min(reqs[0].deadline);
            let mut stop_after = false;
            while reqs.len() < max_batch {
                let now = Instant::now();
                if now >= close {
                    break;
                }
                match rx.recv_timeout(close - now) {
                    Ok(Msg::Query(mut r)) => {
                        controller.on_dequeue();
                        let qw = r.enqueued.elapsed().as_micros() as u64;
                        r.spans.set_stage(Stage::QueueWait, qw);
                        metrics.record_stage(Stage::QueueWait, qw);
                        reqs.push(r);
                    }
                    Ok(Msg::Shutdown) => {
                        stop_after = true;
                        break;
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            // Pre-dispatch triage: expired deadlines and wrong-dim
            // vectors never reach the hash backend, so a backend failure
            // is always genuine (the breaker never trips on a client
            // mistake).
            let mut live: Vec<QueryRequest> = Vec::with_capacity(reqs.len());
            let now = Instant::now();
            for req in reqs {
                if now >= req.deadline {
                    metrics.record_deadline_exceeded();
                    let _ = req.resp.send(Err(ServeError::DeadlineExceeded(
                        "deadline expired while queued".into(),
                    )));
                } else if req.vector.len() != dim {
                    metrics.record_error();
                    let _ = req.resp.send(Err(ServeError::InvalidArgument(format!(
                        "vector dim {} != index dim {dim}",
                        req.vector.len()
                    ))));
                } else {
                    live.push(req);
                }
            }
            if live.is_empty() {
                if stop_after {
                    break 'outer;
                }
                continue;
            }
            metrics.record_batch(live.len());
            // Batch assembly: first pop → hash dispatch, shared by every
            // query in the batch.
            let assembly_us = assembly_start.elapsed().as_micros() as u64;
            for req in live.iter_mut() {
                req.spans.set_stage(Stage::BatchAssembly, assembly_us);
                metrics.record_stage(Stage::BatchAssembly, assembly_us);
            }
            let rows: Vec<Vec<f32>> = live.iter().map(|r| r.vector.clone()).collect();
            let hash_start = Instant::now();
            let (resp, hash_rx) = mpsc::channel();
            let worker_result = if job_tx.send(HashJob { rows: rows.clone(), resp }).is_err() {
                None
            } else {
                hash_rx.recv().ok()
            };
            let from_worker = worker_result.is_some();
            let hashed = match worker_result {
                Some(res) => res,
                None => {
                    // Worker gone or poisoned mid-job: the reply channel
                    // dropped without a result. Serve this batch — and
                    // signal the breaker open — inline on the fused CPU
                    // path, so readers never hang on a dead worker.
                    breaker.store(BreakerState::Open as u8, Ordering::Relaxed);
                    metrics.record_pjrt_fallback();
                    crate::log_warn!(
                        "hash worker unavailable; serving batch inline via fused CPU path"
                    );
                    fused_hash_batch(&engine, &rows, &mut fqx, &mut fxs, &mut fcodes)
                }
            };
            let hash_us = hash_start.elapsed().as_micros() as u64;
            // The hash ran on PJRT iff that backend is the primary, the
            // worker answered, and the breaker did not trip on this batch.
            let pjrt_served = pjrt_primary
                && from_worker
                && BreakerState::from_u8(breaker.load(Ordering::Relaxed))
                    == BreakerState::Closed;
            match hashed {
                Ok(code_rows) => {
                    for (mut req, codes) in live.into_iter().zip(code_rows) {
                        if Instant::now() >= req.deadline {
                            metrics.record_deadline_exceeded();
                            let _ = req.resp.send(Err(ServeError::DeadlineExceeded(
                                "deadline expired during batch".into(),
                            )));
                            continue;
                        }
                        req.spans.set_stage(Stage::Hash, hash_us);
                        metrics.record_stage(Stage::Hash, hash_us);
                        if pjrt_served {
                            req.spans.set_flag(FLAG_PJRT_HASH);
                        }
                        let hits = engine
                            .query_with_codes_traced_into(
                                &req.vector,
                                &codes[..lk],
                                req.top_k,
                                req.budget,
                                &mut req.spans,
                                &mut scratch,
                            )
                            .to_vec();
                        if req.degraded {
                            metrics.record_degraded();
                        }
                        let total_us = req.enqueued.elapsed().as_micros() as u64;
                        req.spans.total_us = total_us;
                        controller.record_latency(total_us);
                        let _ = req.resp.send(Ok(QueryReply {
                            hits,
                            degraded: req.degraded,
                            trace_id: req.spans.trace_id,
                            spans: req.spans,
                        }));
                    }
                }
                Err(e) => {
                    metrics.record_error();
                    let msg = format!("hash failed: {e:#}");
                    for req in live {
                        let _ = req.resp.send(Err(ServeError::Internal(msg.clone())));
                    }
                }
            }
            if stop_after {
                break 'outer;
            }
        }
    }

    pub fn handle(&self) -> BatcherHandle {
        self.handle.clone().expect("batcher already shut down")
    }

    /// Graceful shutdown: stop the batch loop (even if client handles are
    /// still alive), then join both threads. In-flight queries finish;
    /// later `query()` calls fail with a structured internal error.
    pub fn shutdown(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.tx.send(Msg::Shutdown);
        }
        if let Some(t) = self.batcher_thread.take() {
            let _ = t.join();
        }
        // The batcher thread owned the only job_tx; its exit disconnects
        // the worker's queue.
        if let Some(t) = self.worker_thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::AlshParams;
    use crate::util::Rng;

    fn items(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n)
            .map(|_| {
                let s = 0.2 + 1.8 * rng.f32();
                (0..d).map(|_| rng.normal_f32() * s).collect()
            })
            .collect()
    }

    /// A banded engine behind the batcher: the fused fallback hashes once
    /// per query and the banded probe consumes the same code rows, so
    /// batched answers must equal the direct engine path.
    #[test]
    fn fused_fallback_serves_banded_engine() {
        use crate::index::BandedParams;
        let its = items(500, 10, 40);
        let engine = Arc::new(MipsEngine::new_banded(
            &its,
            AlshParams::default(),
            BandedParams { n_bands: 4 },
            41,
        ));
        let batcher = PjrtBatcher::spawn(
            Arc::clone(&engine),
            "definitely-not-an-artifacts-dir",
            BatcherConfig { max_wait: Duration::from_micros(200), ..Default::default() },
        )
        .expect("fused fallback must spawn for banded engines");
        let handle = batcher.handle();
        let mut rng = Rng::seed_from_u64(42);
        for _ in 0..15 {
            let q: Vec<f32> = (0..10).map(|_| rng.normal_f32()).collect();
            let batched = handle.query(q.clone(), 10).expect("batched query");
            assert_eq!(batched, engine.query(&q, 10));
        }
        batcher.shutdown();
    }

    /// Without artifacts the batcher must still serve, via the fused CPU
    /// backend, and agree exactly with the direct engine path.
    #[test]
    fn fused_fallback_serves_and_matches_direct_path() {
        let its = items(400, 12, 1);
        let engine = Arc::new(MipsEngine::new(&its, AlshParams::default(), 2));
        let batcher = PjrtBatcher::spawn(
            Arc::clone(&engine),
            "definitely-not-an-artifacts-dir",
            BatcherConfig { max_wait: Duration::from_micros(200), ..Default::default() },
        )
        .expect("fused fallback must spawn");
        let handle = batcher.handle();
        let mut rng = Rng::seed_from_u64(3);
        for _ in 0..20 {
            let q: Vec<f32> = (0..12).map(|_| rng.normal_f32()).collect();
            let batched = handle.query(q.clone(), 10).expect("batched query");
            assert_eq!(batched, engine.query(&q, 10));
        }
        batcher.shutdown();
    }

    /// A live engine behind the batcher: batched answers equal the
    /// direct live path, and upserts/deletes land mid-stream without
    /// disturbing the batcher (its fused hasher is generation-stable).
    #[test]
    fn fused_fallback_serves_live_engine_through_mutation() {
        use crate::index::LiveConfig;
        let dir = std::env::temp_dir().join(format!(
            "alsh_batcher_live_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let its = items(300, 10, 50);
        let engine = Arc::new(
            MipsEngine::create_live(
                &dir,
                &its,
                LiveConfig { params: AlshParams::default(), n_bands: 1, seed: 51, ..LiveConfig::default() },
            )
            .unwrap(),
        );
        let batcher = PjrtBatcher::spawn(
            Arc::clone(&engine),
            "definitely-not-an-artifacts-dir",
            BatcherConfig { max_wait: Duration::from_micros(200), ..Default::default() },
        )
        .expect("fused fallback must spawn for live engines");
        let handle = batcher.handle();
        let mut rng = Rng::seed_from_u64(52);
        for round in 0..10 {
            let q: Vec<f32> = (0..10).map(|_| rng.normal_f32()).collect();
            let batched = handle.query(q.clone(), 10).expect("batched query");
            assert_eq!(batched, engine.query(&q, 10));
            // Mutate between rounds; later batches serve the new state.
            engine.upsert(1000 + round, &its[round as usize]).unwrap();
        }
        engine.compact().unwrap();
        let q: Vec<f32> = (0..10).map(|_| rng.normal_f32()).collect();
        assert_eq!(handle.query(q.clone(), 10).unwrap(), engine.query(&q, 10));
        batcher.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fused_fallback_rejects_bad_dims() {
        let its = items(100, 8, 4);
        let engine = Arc::new(MipsEngine::new(&its, AlshParams::default(), 5));
        let batcher = PjrtBatcher::spawn(
            Arc::clone(&engine),
            "definitely-not-an-artifacts-dir",
            BatcherConfig::default(),
        )
        .unwrap();
        let handle = batcher.handle();
        assert!(handle.query(vec![1.0, 2.0], 5).is_err(), "dim mismatch must error");
        // The batcher survives the bad request.
        let q = vec![0.1f32; 8];
        assert!(handle.query(q, 5).is_ok());
        batcher.shutdown();
    }

    #[test]
    fn concurrent_clients_batch_together() {
        let its = items(300, 8, 6);
        let engine = Arc::new(MipsEngine::new(&its, AlshParams::default(), 7));
        let batcher = PjrtBatcher::spawn(
            Arc::clone(&engine),
            "definitely-not-an-artifacts-dir",
            BatcherConfig { max_wait: Duration::from_millis(2), ..Default::default() },
        )
        .unwrap();
        let handle = batcher.handle();
        let threads: Vec<_> = (0..8)
            .map(|c| {
                let h = handle.clone();
                std::thread::spawn(move || {
                    let mut rng = Rng::seed_from_u64(100 + c);
                    for _ in 0..10 {
                        let q: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
                        h.query(q, 5).expect("query");
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let snap = engine.metrics().snapshot();
        assert_eq!(snap.queries, 80);
        assert!(snap.batches <= 80, "batches recorded");
        batcher.shutdown();
    }

    /// An already-expired deadline is rejected at admission with the
    /// structured error, before any work happens.
    #[test]
    fn expired_deadline_rejected_at_admission() {
        let its = items(100, 8, 8);
        let engine = Arc::new(MipsEngine::new(&its, AlshParams::default(), 9));
        let batcher = PjrtBatcher::spawn(
            Arc::clone(&engine),
            "definitely-not-an-artifacts-dir",
            BatcherConfig::default(),
        )
        .unwrap();
        let handle = batcher.handle();
        let past = Instant::now() - Duration::from_millis(1);
        let err = handle
            .query_deadline(vec![0.1f32; 8], 5, Some(past))
            .expect_err("expired deadline must be rejected");
        assert_eq!(err.code(), "deadline_exceeded");
        assert_eq!(engine.metrics().snapshot().deadline_exceeded, 1);
        // Healthy defaults: the breaker is closed, the ladder at 0, and
        // a normal query still flows.
        assert_eq!(handle.breaker_state(), BreakerState::Closed);
        assert_eq!(handle.level(), 0);
        let reply = handle.query_deadline(vec![0.1f32; 8], 5, None).expect("healthy query");
        assert!(!reply.degraded);
        batcher.shutdown();
    }

    #[test]
    fn fault_plan_windows() {
        let p = FaultPlan {
            fail_from: 2,
            fail_until: 4,
            delay_from: 1,
            delay_until: 2,
            delay: Duration::from_millis(5),
            poison_at: Some(7),
        };
        assert!(!p.fails_at(1) && p.fails_at(2) && p.fails_at(3) && !p.fails_at(4));
        assert_eq!(p.delay_for(1), Some(Duration::from_millis(5)));
        assert_eq!(p.delay_for(2), None);
        // Default plan injects nothing.
        let d = FaultPlan::default();
        assert!(!d.fails_at(0));
        assert_eq!(d.delay_for(0), None);
        assert_eq!(d.poison_at, None);
    }
}

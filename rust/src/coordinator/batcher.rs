//! Dynamic batcher over the PJRT hash artifact.
//!
//! PJRT executables are shape-monomorphic (fixed batch) and their handles
//! are not `Send`, so the design is:
//!
//! * a dedicated **worker thread** owns the `Runtime` and the compiled
//!   `alsh_query` executable;
//! * a **batcher thread** collects incoming queries until the batch fills
//!   (`max_batch`) or a deadline passes (`max_wait`), ships one padded
//!   batch to the worker, and fans results back out per query (bucket
//!   probe + exact rerank on the shared `MipsEngine`).
//!
//! Channels are std mpsc; per-request responses travel over one-shot
//! channels (an mpsc used once).

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::index::ScoredItem;
use crate::runtime::Runtime;

use super::engine::MipsEngine;
use super::metrics::Metrics;

/// Dynamic-batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Max queries per dispatched batch (clamped to the artifact batch).
    pub max_batch: usize,
    /// Max time the first query in a batch waits for company.
    pub max_wait: Duration,
    /// Depth of the ingress queue (backpressure bound).
    pub queue_depth: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 64, max_wait: Duration::from_millis(2), queue_depth: 1024 }
    }
}

struct HashJob {
    rows: Vec<Vec<f32>>,
    resp: Sender<crate::Result<Vec<Vec<i32>>>>,
}

struct QueryRequest {
    vector: Vec<f32>,
    top_k: usize,
    resp: Sender<Result<Vec<ScoredItem>, String>>,
}

enum Msg {
    Query(QueryRequest),
    /// Explicit stop: `recv()` blocks forever if any handle clone is
    /// still alive, so shutdown is signalled in-band.
    Shutdown,
}

/// Cheap-to-clone client handle.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: SyncSender<Msg>,
}

impl BatcherHandle {
    /// Submit one MIPS query; blocks until its batch is served.
    pub fn query(&self, vector: Vec<f32>, top_k: usize) -> crate::Result<Vec<ScoredItem>> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Msg::Query(QueryRequest { vector, top_k, resp }))
            .map_err(|_| anyhow::anyhow!("batcher is gone"))?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("batcher dropped the request"))?
            .map_err(|e| anyhow::anyhow!(e))
    }
}

/// The running batcher: handle + join handles for shutdown.
pub struct PjrtBatcher {
    handle: Option<BatcherHandle>,
    batcher_thread: Option<std::thread::JoinHandle<()>>,
    worker_thread: Option<std::thread::JoinHandle<()>>,
}

impl PjrtBatcher {
    /// Spawn the worker thread + batcher thread.
    ///
    /// `artifacts_dir` must contain an `alsh_query` artifact matching the
    /// engine's item dimension and `m`; the engine's `L*K` hashes must fit
    /// in the artifact's K columns.
    pub fn spawn(
        engine: Arc<MipsEngine>,
        artifacts_dir: impl Into<std::path::PathBuf>,
        cfg: BatcherConfig,
    ) -> crate::Result<Self> {
        let dir = artifacts_dir.into();
        let dim = engine.index().dim();
        let m = engine.index().params().m;

        // Validate the artifact on the caller thread for a fast error.
        let probe = Runtime::load(&dir)?;
        let meta = probe.find("alsh_query", dim)?;
        anyhow::ensure!(
            meta.m == m,
            "artifact m={} but index m={m}; re-run make artifacts",
            meta.m
        );
        drop(probe);
        let params = *engine.index().params();
        let lk = params.n_tables * params.k_per_table;
        anyhow::ensure!(
            lk <= meta.k,
            "index uses {lk} hashes > artifact capacity {}",
            meta.k
        );
        let (a_dk, b) = engine.concat_family_inputs(meta.k);

        // Worker thread: owns the (non-Send) PJRT runtime.
        let (job_tx, job_rx) = mpsc::channel::<HashJob>();
        let meta_worker = meta.clone();
        let worker_dir = dir.clone();
        let worker_thread = std::thread::Builder::new()
            .name("pjrt-worker".into())
            .spawn(move || {
                let mut runtime = match Runtime::load(&worker_dir) {
                    Ok(r) => r,
                    Err(e) => {
                        crate::log_error!("pjrt worker failed to start: {e:#}");
                        while let Ok(job) = job_rx.recv() {
                            let _ =
                                job.resp.send(Err(anyhow::anyhow!("runtime load failed")));
                        }
                        return;
                    }
                };
                while let Ok(job) = job_rx.recv() {
                    let res = runtime.run_hash(&meta_worker, &job.rows, &a_dk, &b);
                    let _ = job.resp.send(res);
                }
            })
            .expect("spawn pjrt worker");

        // Batcher thread: dynamic batching + fan-out.
        let (tx, rx) = mpsc::sync_channel::<Msg>(cfg.queue_depth);
        let max_batch = cfg.max_batch.min(meta.batch).max(1);
        let metrics = engine.metrics();
        let batcher_thread = std::thread::Builder::new()
            .name("alsh-batcher".into())
            .spawn(move || {
                Self::batch_loop(engine, metrics, rx, job_tx, max_batch, cfg.max_wait, lk)
            })
            .expect("spawn batcher");

        Ok(Self {
            handle: Some(BatcherHandle { tx }),
            batcher_thread: Some(batcher_thread),
            worker_thread: Some(worker_thread),
        })
    }

    fn batch_loop(
        engine: Arc<MipsEngine>,
        metrics: Arc<Metrics>,
        rx: Receiver<Msg>,
        job_tx: Sender<HashJob>,
        max_batch: usize,
        max_wait: Duration,
        lk: usize,
    ) {
        'outer: while let Ok(first) = rx.recv() {
            let Msg::Query(first) = first else { break };
            let mut reqs = vec![first];
            let deadline = Instant::now() + max_wait;
            let mut stop_after = false;
            while reqs.len() < max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(Msg::Query(r)) => reqs.push(r),
                    Ok(Msg::Shutdown) => {
                        stop_after = true;
                        break;
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            metrics.record_batch(reqs.len());
            let rows: Vec<Vec<f32>> = reqs.iter().map(|r| r.vector.clone()).collect();
            let (resp, hash_rx) = mpsc::channel();
            if job_tx.send(HashJob { rows, resp }).is_err() {
                metrics.record_error();
                for req in reqs {
                    let _ = req.resp.send(Err("pjrt worker is gone".into()));
                }
                continue;
            }
            match hash_rx.recv() {
                Ok(Ok(code_rows)) => {
                    for (req, codes) in reqs.into_iter().zip(code_rows) {
                        let out =
                            engine.query_with_codes(&req.vector, &codes[..lk], req.top_k);
                        let _ = req.resp.send(Ok(out));
                    }
                }
                Ok(Err(e)) => {
                    metrics.record_error();
                    let msg = format!("hash failed: {e:#}");
                    for req in reqs {
                        let _ = req.resp.send(Err(msg.clone()));
                    }
                }
                Err(_) => {
                    metrics.record_error();
                    for req in reqs {
                        let _ = req.resp.send(Err("pjrt worker dropped the job".into()));
                    }
                }
            }
            if stop_after {
                break 'outer;
            }
        }
    }

    pub fn handle(&self) -> BatcherHandle {
        self.handle.clone().expect("batcher already shut down")
    }

    /// Graceful shutdown: stop the batch loop (even if client handles are
    /// still alive), then join both threads. In-flight queries finish;
    /// later `query()` calls fail with "batcher is gone".
    pub fn shutdown(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.tx.send(Msg::Shutdown);
        }
        if let Some(t) = self.batcher_thread.take() {
            let _ = t.join();
        }
        // The batcher thread owned the only job_tx; its exit disconnects
        // the worker's queue.
        if let Some(t) = self.worker_thread.take() {
            let _ = t.join();
        }
    }
}

//! Dynamic batcher over the hash path: PJRT artifact when available,
//! fused pure-Rust matrix–matrix hashing otherwise.
//!
//! PJRT executables are shape-monomorphic (fixed batch) and their handles
//! are not `Send`, so the design is:
//!
//! * a dedicated **worker thread** owns the hash backend — either the
//!   `Runtime` with the compiled `alsh_query` executable, or (when no
//!   artifacts are present / no XLA backend is built in) the engine's
//!   [`crate::lsh::FusedHasher`], driven in batch matrix–matrix mode;
//! * a **batcher thread** collects incoming queries until the batch fills
//!   (`max_batch`) or a deadline passes (`max_wait`), ships one padded
//!   batch to the worker, and fans results back out per query (CSR bucket
//!   probe + exact rerank on the shared `MipsEngine`, through one reused
//!   `QueryScratch` — the fan-out loop allocates only the response
//!   vectors).
//!
//! Channels are std mpsc; per-request responses travel over one-shot
//! channels (an mpsc used once).

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::index::storage::Storage;
use crate::index::{AnyIndex, MipsHashScheme, ScoredItem};
use crate::runtime::{ArtifactMeta, Runtime};

use super::engine::MipsEngine;
use super::metrics::Metrics;

/// Dynamic-batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Max queries per dispatched batch (clamped to the artifact batch).
    pub max_batch: usize,
    /// Max time the first query in a batch waits for company.
    pub max_wait: Duration,
    /// Depth of the ingress queue (backpressure bound).
    pub queue_depth: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 64, max_wait: Duration::from_millis(2), queue_depth: 1024 }
    }
}

struct HashJob {
    rows: Vec<Vec<f32>>,
    resp: Sender<crate::Result<Vec<Vec<i32>>>>,
}

struct QueryRequest {
    vector: Vec<f32>,
    top_k: usize,
    resp: Sender<Result<Vec<ScoredItem>, String>>,
}

enum Msg {
    Query(QueryRequest),
    /// Explicit stop: `recv()` blocks forever if any handle clone is
    /// still alive, so shutdown is signalled in-band.
    Shutdown,
}

/// Which hash implementation the worker thread drives.
enum HashBackend {
    /// Compiled `alsh_query` artifact through PJRT.
    Pjrt { meta: ArtifactMeta, a_dk: Vec<f32>, b: Vec<f32> },
    /// Fused pure-Rust batch hashing on the engine's stacked matrix.
    Fused,
}

/// Cheap-to-clone client handle.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: SyncSender<Msg>,
}

impl BatcherHandle {
    /// Submit one MIPS query; blocks until its batch is served.
    pub fn query(&self, vector: Vec<f32>, top_k: usize) -> crate::Result<Vec<ScoredItem>> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Msg::Query(QueryRequest { vector, top_k, resp }))
            .map_err(|_| anyhow::anyhow!("batcher is gone"))?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("batcher dropped the request"))?
            .map_err(|e| anyhow::anyhow!(e))
    }
}

/// The running batcher: handle + join handles for shutdown.
pub struct PjrtBatcher {
    handle: Option<BatcherHandle>,
    batcher_thread: Option<std::thread::JoinHandle<()>>,
    worker_thread: Option<std::thread::JoinHandle<()>>,
}

/// Batch-hash `rows` with the fused pure-Rust matrix–matrix kernel:
/// Q-transform each row per the index's scheme, then one blocked pass
/// over the stacked `[L·K × D']` matrix (shared by both index kinds —
/// the banded index hashes queries with the same fused family set as the
/// flat one, whatever the scheme). The scratch buffers are owned by the
/// worker loop.
fn fused_hash_batch<S: Storage>(
    index: &AnyIndex<S>,
    rows: &[Vec<f32>],
    qx: &mut Vec<f32>,
    xs: &mut Vec<f32>,
    codes: &mut Vec<i32>,
) -> crate::Result<Vec<Vec<i32>>> {
    let dim = index.dim();
    let m = index.params().m;
    let scheme = index.scheme();
    let hasher = index.hasher();
    let nc = hasher.n_codes();
    xs.clear();
    for row in rows {
        anyhow::ensure!(row.len() == dim, "row dim {} != {dim}", row.len());
        scheme.query_into(row, m, qx);
        xs.extend_from_slice(qx);
    }
    let need = rows.len() * nc;
    if codes.len() < need {
        codes.resize(need, 0);
    }
    hasher.hash_batch_into(xs, rows.len(), &mut codes[..need]);
    Ok((0..rows.len()).map(|i| codes[i * nc..(i + 1) * nc].to_vec()).collect())
}

impl PjrtBatcher {
    /// Spawn the worker thread + batcher thread.
    ///
    /// When `artifacts_dir` holds a matching `alsh_query` artifact, the
    /// worker hashes through PJRT; the artifact must match the engine's
    /// item dimension and `m`, and the engine's `L*K` hashes must fit in
    /// its K columns (a mismatch is a hard error). When no runtime can be
    /// loaded at all, the worker falls back to the engine's fused CPU
    /// hasher and serving works without artifacts.
    ///
    /// Storage-generic: a zero-copy mapped engine
    /// (`MipsEngine::open_mmap`) batches exactly like a heap one — the
    /// fused fallback hashes through the owned family matrix and the
    /// probes walk the mapped CSR sections.
    pub fn spawn<S: Storage>(
        engine: Arc<MipsEngine<S>>,
        artifacts_dir: impl Into<std::path::PathBuf>,
        cfg: BatcherConfig,
    ) -> crate::Result<Self> {
        let dir = artifacts_dir.into();
        let dim = engine.index().dim();
        let m = engine.index().params().m;
        let params = *engine.index().params();
        let lk = params.n_tables * params.k_per_table;

        // Probe the runtime on the caller thread for a fast error on real
        // config mismatches; fall back to fused hashing when the runtime
        // itself is unavailable. Only the L2-ALSH scheme has a compiled
        // `alsh_query` artifact — the SRP schemes always hash through the
        // fused CPU kernel (which serves them at full speed; the bit-pack
        // keys need no artifact).
        let backend = if params.scheme != MipsHashScheme::L2Alsh {
            crate::log_info!(
                "scheme {} has no PJRT query artifact; batcher using fused CPU hashing",
                params.scheme
            );
            HashBackend::Fused
        } else {
            match Runtime::load(&dir) {
                Ok(probe) => {
                    let meta = probe.find("alsh_query", dim)?;
                    anyhow::ensure!(
                        meta.m == m,
                        "artifact m={} but index m={m}; re-run make artifacts",
                        meta.m
                    );
                    drop(probe);
                    anyhow::ensure!(
                        lk <= meta.k,
                        "index uses {lk} hashes > artifact capacity {}",
                        meta.k
                    );
                    let (a_dk, b) = engine.concat_family_inputs(meta.k);
                    HashBackend::Pjrt { meta, a_dk, b }
                }
                Err(e) => {
                    crate::log_info!(
                        "PJRT runtime unavailable ({e:#}); batcher using fused CPU hashing"
                    );
                    HashBackend::Fused
                }
            }
        };
        let max_batch = match &backend {
            HashBackend::Pjrt { meta, .. } => cfg.max_batch.min(meta.batch).max(1),
            HashBackend::Fused => cfg.max_batch.max(1),
        };

        // Worker thread: owns the hash backend (PJRT handles are not Send,
        // so the runtime is re-created on this thread).
        let (job_tx, job_rx) = mpsc::channel::<HashJob>();
        let worker_dir = dir.clone();
        let worker_engine = Arc::clone(&engine);
        let worker_thread = std::thread::Builder::new()
            .name("hash-worker".into())
            .spawn(move || match backend {
                HashBackend::Pjrt { meta, a_dk, b } => {
                    let mut runtime = match Runtime::load(&worker_dir) {
                        Ok(r) => r,
                        Err(e) => {
                            crate::log_error!("pjrt worker failed to start: {e:#}");
                            while let Ok(job) = job_rx.recv() {
                                let _ =
                                    job.resp.send(Err(anyhow::anyhow!("runtime load failed")));
                            }
                            return;
                        }
                    };
                    while let Ok(job) = job_rx.recv() {
                        let res = runtime.run_hash(&meta, &job.rows, &a_dk, &b);
                        let _ = job.resp.send(res);
                    }
                }
                HashBackend::Fused => {
                    let index = worker_engine.index();
                    let mut qx = Vec::new();
                    let mut xs = Vec::new();
                    let mut codes = Vec::new();
                    while let Ok(job) = job_rx.recv() {
                        let res =
                            fused_hash_batch(index, &job.rows, &mut qx, &mut xs, &mut codes);
                        let _ = job.resp.send(res);
                    }
                }
            })
            .expect("spawn hash worker");

        // Batcher thread: dynamic batching + fan-out.
        let (tx, rx) = mpsc::sync_channel::<Msg>(cfg.queue_depth);
        let metrics = engine.metrics();
        let batcher_thread = std::thread::Builder::new()
            .name("alsh-batcher".into())
            .spawn(move || {
                Self::batch_loop(engine, metrics, rx, job_tx, max_batch, cfg.max_wait, lk)
            })
            .expect("spawn batcher");

        Ok(Self {
            handle: Some(BatcherHandle { tx }),
            batcher_thread: Some(batcher_thread),
            worker_thread: Some(worker_thread),
        })
    }

    fn batch_loop<S: Storage>(
        engine: Arc<MipsEngine<S>>,
        metrics: Arc<Metrics>,
        rx: Receiver<Msg>,
        job_tx: Sender<HashJob>,
        max_batch: usize,
        max_wait: Duration,
        lk: usize,
    ) {
        // One scratch for the whole loop: probes + reranks are
        // allocation-free at steady state.
        let mut scratch = engine.index().scratch();
        'outer: while let Ok(first) = rx.recv() {
            let Msg::Query(first) = first else { break };
            let mut reqs = vec![first];
            let deadline = Instant::now() + max_wait;
            let mut stop_after = false;
            while reqs.len() < max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(Msg::Query(r)) => reqs.push(r),
                    Ok(Msg::Shutdown) => {
                        stop_after = true;
                        break;
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            metrics.record_batch(reqs.len());
            let rows: Vec<Vec<f32>> = reqs.iter().map(|r| r.vector.clone()).collect();
            let (resp, hash_rx) = mpsc::channel();
            if job_tx.send(HashJob { rows, resp }).is_err() {
                metrics.record_error();
                for req in reqs {
                    let _ = req.resp.send(Err("hash worker is gone".into()));
                }
                continue;
            }
            match hash_rx.recv() {
                Ok(Ok(code_rows)) => {
                    for (req, codes) in reqs.into_iter().zip(code_rows) {
                        let out = engine
                            .query_with_codes_into(
                                &req.vector,
                                &codes[..lk],
                                req.top_k,
                                &mut scratch,
                            )
                            .to_vec();
                        let _ = req.resp.send(Ok(out));
                    }
                }
                Ok(Err(e)) => {
                    metrics.record_error();
                    let msg = format!("hash failed: {e:#}");
                    for req in reqs {
                        let _ = req.resp.send(Err(msg.clone()));
                    }
                }
                Err(_) => {
                    metrics.record_error();
                    for req in reqs {
                        let _ = req.resp.send(Err("hash worker dropped the job".into()));
                    }
                }
            }
            if stop_after {
                break 'outer;
            }
        }
    }

    pub fn handle(&self) -> BatcherHandle {
        self.handle.clone().expect("batcher already shut down")
    }

    /// Graceful shutdown: stop the batch loop (even if client handles are
    /// still alive), then join both threads. In-flight queries finish;
    /// later `query()` calls fail with "batcher is gone".
    pub fn shutdown(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.tx.send(Msg::Shutdown);
        }
        if let Some(t) = self.batcher_thread.take() {
            let _ = t.join();
        }
        // The batcher thread owned the only job_tx; its exit disconnects
        // the worker's queue.
        if let Some(t) = self.worker_thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::AlshParams;
    use crate::util::Rng;

    fn items(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n)
            .map(|_| {
                let s = 0.2 + 1.8 * rng.f32();
                (0..d).map(|_| rng.normal_f32() * s).collect()
            })
            .collect()
    }

    /// A banded engine behind the batcher: the fused fallback hashes once
    /// per query and the banded probe consumes the same code rows, so
    /// batched answers must equal the direct engine path.
    #[test]
    fn fused_fallback_serves_banded_engine() {
        use crate::index::BandedParams;
        let its = items(500, 10, 40);
        let engine = Arc::new(MipsEngine::new_banded(
            &its,
            AlshParams::default(),
            BandedParams { n_bands: 4 },
            41,
        ));
        let batcher = PjrtBatcher::spawn(
            Arc::clone(&engine),
            "definitely-not-an-artifacts-dir",
            BatcherConfig { max_wait: Duration::from_micros(200), ..Default::default() },
        )
        .expect("fused fallback must spawn for banded engines");
        let handle = batcher.handle();
        let mut rng = Rng::seed_from_u64(42);
        for _ in 0..15 {
            let q: Vec<f32> = (0..10).map(|_| rng.normal_f32()).collect();
            let batched = handle.query(q.clone(), 10).expect("batched query");
            assert_eq!(batched, engine.query(&q, 10));
        }
        batcher.shutdown();
    }

    /// Without artifacts the batcher must still serve, via the fused CPU
    /// backend, and agree exactly with the direct engine path.
    #[test]
    fn fused_fallback_serves_and_matches_direct_path() {
        let its = items(400, 12, 1);
        let engine = Arc::new(MipsEngine::new(&its, AlshParams::default(), 2));
        let batcher = PjrtBatcher::spawn(
            Arc::clone(&engine),
            "definitely-not-an-artifacts-dir",
            BatcherConfig { max_wait: Duration::from_micros(200), ..Default::default() },
        )
        .expect("fused fallback must spawn");
        let handle = batcher.handle();
        let mut rng = Rng::seed_from_u64(3);
        for _ in 0..20 {
            let q: Vec<f32> = (0..12).map(|_| rng.normal_f32()).collect();
            let batched = handle.query(q.clone(), 10).expect("batched query");
            assert_eq!(batched, engine.query(&q, 10));
        }
        batcher.shutdown();
    }

    #[test]
    fn fused_fallback_rejects_bad_dims() {
        let its = items(100, 8, 4);
        let engine = Arc::new(MipsEngine::new(&its, AlshParams::default(), 5));
        let batcher = PjrtBatcher::spawn(
            Arc::clone(&engine),
            "definitely-not-an-artifacts-dir",
            BatcherConfig::default(),
        )
        .unwrap();
        let handle = batcher.handle();
        assert!(handle.query(vec![1.0, 2.0], 5).is_err(), "dim mismatch must error");
        // The batcher survives the bad request.
        let q = vec![0.1f32; 8];
        assert!(handle.query(q, 5).is_ok());
        batcher.shutdown();
    }

    #[test]
    fn concurrent_clients_batch_together() {
        let its = items(300, 8, 6);
        let engine = Arc::new(MipsEngine::new(&its, AlshParams::default(), 7));
        let batcher = PjrtBatcher::spawn(
            Arc::clone(&engine),
            "definitely-not-an-artifacts-dir",
            BatcherConfig { max_wait: Duration::from_millis(2), ..Default::default() },
        )
        .unwrap();
        let handle = batcher.handle();
        let threads: Vec<_> = (0..8)
            .map(|c| {
                let h = handle.clone();
                std::thread::spawn(move || {
                    let mut rng = Rng::seed_from_u64(100 + c);
                    for _ in 0..10 {
                        let q: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
                        h.query(q, 5).expect("query");
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let snap = engine.metrics().snapshot();
        assert_eq!(snap.queries, 80);
        assert!(snap.batches <= 80, "batches recorded");
        batcher.shutdown();
    }
}

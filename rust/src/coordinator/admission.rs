//! Admission control for the serving tier: structured serve errors, the
//! overload configuration, and the [`LoadController`] that drives the
//! three-level degradation ladder.
//!
//! # The ladder
//!
//! Every admitted query flows through a bounded queue; the controller
//! watches two measured signals — queue fill (depth / capacity) and the
//! recent p99 of end-to-end admitted-query latency — and holds one of
//! three levels:
//!
//! * **0 — healthy**: full [`crate::index::ProbeBudget`], every admitted
//!   query gets the unconstrained answer.
//! * **1 — degraded**: queries run under a reduced probe budget (fewer
//!   tables, capped rerank pool — see
//!   [`AdmissionConfig::degraded_table_frac`] /
//!   [`AdmissionConfig::degraded_rerank_cap`]) with a declared recall
//!   floor ([`AdmissionConfig::recall_floor`], asserted in
//!   `tests/overload.rs`): shed *work* before shedding *requests*.
//! * **2 — shedding**: new queries are rejected up front with a
//!   structured `overloaded` error; queries already admitted still drain.
//!
//! # Hysteresis
//!
//! Escalation is immediate (overload hurts now); de-escalation is one
//! level at a time and only after [`AdmissionConfig::min_dwell`] at the
//! current level **and** both signals have recovered (fill below
//! [`AdmissionConfig::recover_fill`], recent p99 below 80% of target) —
//! so the ladder ratchets down slowly instead of flapping around the
//! thresholds. Latency samples carry timestamps and age out of the
//! [`AdmissionConfig::latency_window`], so a burst's p99 cannot pin the
//! ladder high after the burst has drained.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::metrics::Metrics;

/// Structured serve-path error: every failure a client can observe maps
/// to one of these codes, and the server renders them as
/// `{ok: false, code, error}` JSON — never a panic, never a silently
/// truncated answer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The request itself is malformed (bad vector, bad `top_k`, …).
    InvalidArgument(String),
    /// The request's deadline expired before a result was produced; the
    /// answer would be stale, so none is served.
    DeadlineExceeded(String),
    /// The admission queue is full or the ladder is at the shed level.
    Overloaded(String),
    /// Write backpressure: the live delta hit its cap and the mutation
    /// was refused before any replica logged it. Retryable — the reply
    /// carries the compactor's `retry_after_ms` hint.
    WriteStalled(String),
    /// A replicated write reached fewer member acknowledgements than the
    /// configured write quorum, so it is not durable and was not
    /// acknowledged.
    QuorumFailed(String),
    /// Serving-stack failure (worker gone, channel closed, hash error).
    Internal(String),
}

impl ServeError {
    /// The stable machine-readable code clients switch on.
    pub fn code(&self) -> &'static str {
        match self {
            ServeError::InvalidArgument(_) => "invalid_argument",
            ServeError::DeadlineExceeded(_) => "deadline_exceeded",
            ServeError::Overloaded(_) => "overloaded",
            ServeError::WriteStalled(_) => "write_stalled",
            ServeError::QuorumFailed(_) => "quorum_failed",
            ServeError::Internal(_) => "internal",
        }
    }

    /// The human-readable detail.
    pub fn message(&self) -> &str {
        match self {
            ServeError::InvalidArgument(m)
            | ServeError::DeadlineExceeded(m)
            | ServeError::Overloaded(m)
            | ServeError::WriteStalled(m)
            | ServeError::QuorumFailed(m)
            | ServeError::Internal(m) => m,
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code(), self.message())
    }
}

impl std::error::Error for ServeError {}

/// Clients may stretch their deadline only so far: anything above an
/// hour is clamped (also keeps `Duration::from_secs_f64` panic-free).
pub const MAX_DEADLINE_MS: f64 = 3_600_000.0;

/// Triage one client-supplied `deadline_ms` value into an absolute
/// deadline: positive finite milliseconds, clamped to
/// [`MAX_DEADLINE_MS`]; anything else is an `invalid_argument`. Shared
/// by the single-engine and routed server paths so both enforce
/// identical deadline semantics.
pub fn triage_deadline_ms(ms: f64) -> Result<Instant, ServeError> {
    if ms.is_finite() && ms > 0.0 {
        Ok(Instant::now() + Duration::from_secs_f64(ms.min(MAX_DEADLINE_MS) / 1000.0))
    } else {
        Err(ServeError::InvalidArgument(
            "deadline_ms must be a positive finite number of milliseconds".into(),
        ))
    }
}

/// Whether an optional absolute deadline has already passed — the
/// pre-dispatch and post-merge staleness checks of both serve paths.
pub fn deadline_expired(deadline: Option<Instant>) -> bool {
    deadline.is_some_and(|d| Instant::now() >= d)
}

/// Overload/admission configuration. The defaults are deliberately
/// generous (2 s deadline, 500 ms p99 target) so that lightly loaded
/// deployments — and the existing test suites — never degrade or shed;
/// production configs tighten them to the SLO.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionConfig {
    /// Deadline applied when the client sends no `deadline_ms`.
    pub default_deadline: Duration,
    /// p99 target: recent p99 above this escalates to degraded.
    pub target_p99: Duration,
    /// Queue fill (depth/capacity) at or above which the ladder degrades.
    pub degrade_fill: f64,
    /// Queue fill at or above which new queries are shed outright.
    pub shed_fill: f64,
    /// Queue fill the ladder must fall to before de-escalating.
    pub recover_fill: f64,
    /// Minimum time at a level before de-escalating (hysteresis).
    pub min_dwell: Duration,
    /// Ladder re-evaluation throttle: at most one evaluation per
    /// interval across all threads. `Duration::ZERO` evaluates on every
    /// call (used by unit tests for determinism).
    pub eval_interval: Duration,
    /// Only latency samples younger than this feed the recent p99.
    pub latency_window: Duration,
    /// Fraction of the L tables probed at the degraded level (ceil,
    /// clamped to `[1, L]`).
    pub degraded_table_frac: f64,
    /// Rerank-pool cap at the degraded level.
    pub degraded_rerank_cap: usize,
    /// Declared recall floor at the degraded level, as a fraction of
    /// healthy recall on the same workload (asserted in
    /// `tests/overload.rs` and ratcheted in `BENCH_serve.json`).
    pub recall_floor: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        Self {
            default_deadline: Duration::from_secs(2),
            target_p99: Duration::from_millis(500),
            degrade_fill: 0.5,
            shed_fill: 0.9,
            recover_fill: 0.25,
            min_dwell: Duration::from_millis(500),
            eval_interval: Duration::from_millis(2),
            latency_window: Duration::from_secs(1),
            degraded_table_frac: 0.75,
            degraded_rerank_cap: 4096,
            recall_floor: 0.9,
        }
    }
}

/// Latency ring size (power of two; ~the last few hundred queries).
const RING: usize = 512;
/// Low bits of each packed slot hold the latency (µs, saturated).
const LAT_BITS: u32 = 24;
const LAT_MAX: u64 = (1u64 << LAT_BITS) - 1;

/// The shared ladder state: lock-free, updated from connection threads
/// (admission) and the batcher thread (completion latencies).
pub struct LoadController {
    cfg: AdmissionConfig,
    queue_cap: usize,
    metrics: Arc<Metrics>,
    /// Current ladder level (0 healthy / 1 degraded / 2 shedding).
    level: AtomicU8,
    /// µs-since-start the current level was entered (hysteresis dwell).
    level_since_us: AtomicU64,
    /// µs-since-start of the last ladder evaluation (throttle CAS).
    last_eval_us: AtomicU64,
    /// Ring of packed `(timestamp_us << 24) | latency_us` samples. A
    /// zero slot is empty; timestamps wrap after ~2^40 µs (12 days),
    /// which at worst mis-ages a window of samples once.
    lats: Vec<AtomicU64>,
    lat_idx: AtomicU64,
    start: Instant,
}

impl LoadController {
    pub fn new(cfg: AdmissionConfig, queue_cap: usize, metrics: Arc<Metrics>) -> Self {
        Self {
            cfg,
            queue_cap: queue_cap.max(1),
            metrics,
            level: AtomicU8::new(0),
            level_since_us: AtomicU64::new(0),
            last_eval_us: AtomicU64::new(0),
            lats: (0..RING).map(|_| AtomicU64::new(0)).collect(),
            lat_idx: AtomicU64::new(0),
            start: Instant::now(),
        }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    pub fn queue_cap(&self) -> usize {
        self.queue_cap
    }

    /// Current ladder level (0/1/2) without re-evaluating.
    pub fn level(&self) -> u8 {
        self.level.load(Ordering::Relaxed)
    }

    fn now_us(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }

    /// A query was admitted to the bounded queue.
    pub fn on_enqueue(&self) {
        self.metrics.record_queue_push();
    }

    /// A query left the queue (dispatched into a batch).
    pub fn on_dequeue(&self) {
        self.metrics.record_queue_pop();
    }

    /// Record one admitted query's end-to-end latency (admission →
    /// response), timestamped so it ages out of the p99 window.
    pub fn record_latency(&self, latency_us: u64) {
        let packed = (self.now_us() << LAT_BITS) | latency_us.min(LAT_MAX);
        let i = self.lat_idx.fetch_add(1, Ordering::Relaxed) as usize % RING;
        self.lats[i].store(packed, Ordering::Relaxed);
    }

    /// p99 over the latency samples inside the window (0 if none).
    pub fn recent_p99_us(&self) -> u64 {
        self.recent_p99_at(self.now_us())
    }

    fn recent_p99_at(&self, now_us: u64) -> u64 {
        let window = self.cfg.latency_window.as_micros() as u64;
        let cutoff = now_us.saturating_sub(window);
        let mut lats: Vec<u64> = Vec::with_capacity(RING);
        for slot in &self.lats {
            let packed = slot.load(Ordering::Relaxed);
            if packed != 0 && (packed >> LAT_BITS) >= cutoff {
                lats.push(packed & LAT_MAX);
            }
        }
        if lats.is_empty() {
            return 0;
        }
        lats.sort_unstable();
        let idx = ((lats.len() as f64) * 0.99).ceil() as usize;
        lats[idx.saturating_sub(1).min(lats.len() - 1)]
    }

    /// Re-evaluate the ladder (throttled to one evaluation per
    /// [`AdmissionConfig::eval_interval`] across threads) and return the
    /// level in force. Escalation is immediate; de-escalation steps one
    /// level after the dwell once both signals have recovered.
    pub fn evaluate(&self) -> u8 {
        let now = self.now_us();
        let interval = self.cfg.eval_interval.as_micros() as u64;
        if interval > 0 {
            let last = self.last_eval_us.load(Ordering::Relaxed);
            if now.saturating_sub(last) < interval
                || self
                    .last_eval_us
                    .compare_exchange(last, now, Ordering::Relaxed, Ordering::Relaxed)
                    .is_err()
            {
                return self.level.load(Ordering::Relaxed);
            }
        }
        let fill = self.metrics.queue_depth() as f64 / self.queue_cap as f64;
        let p99 = self.recent_p99_at(now);
        let target = self.cfg.target_p99.as_micros() as u64;
        let level = self.level.load(Ordering::Relaxed);
        let desired: u8 = if fill >= self.cfg.shed_fill {
            2
        } else if fill >= self.cfg.degrade_fill || p99 > target {
            1
        } else {
            0
        };
        if desired > level {
            self.level.store(desired, Ordering::Relaxed);
            self.level_since_us.store(now, Ordering::Relaxed);
            crate::log_info!(
                "load ladder: {level} -> {desired} (fill {fill:.2}, recent p99 {p99}us)"
            );
            return desired;
        }
        if desired < level {
            let since = self.level_since_us.load(Ordering::Relaxed);
            let dwell = self.cfg.min_dwell.as_micros() as u64;
            if now.saturating_sub(since) >= dwell
                && fill <= self.cfg.recover_fill
                && p99 <= target.saturating_mul(4) / 5
            {
                let next = level - 1;
                self.level.store(next, Ordering::Relaxed);
                self.level_since_us.store(now, Ordering::Relaxed);
                crate::log_info!(
                    "load ladder: {level} -> {next} (fill {fill:.2}, recent p99 {p99}us)"
                );
                return next;
            }
        }
        level
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn controller(cfg: AdmissionConfig, cap: usize) -> LoadController {
        LoadController::new(cfg, cap, Arc::new(Metrics::new()))
    }

    /// Evaluate-every-call config with instant de-escalation so unit
    /// tests are deterministic.
    fn fast_cfg() -> AdmissionConfig {
        AdmissionConfig {
            eval_interval: Duration::ZERO,
            min_dwell: Duration::ZERO,
            latency_window: Duration::from_secs(60),
            ..Default::default()
        }
    }

    #[test]
    fn error_codes_are_stable() {
        assert_eq!(ServeError::InvalidArgument("x".into()).code(), "invalid_argument");
        assert_eq!(ServeError::DeadlineExceeded("x".into()).code(), "deadline_exceeded");
        assert_eq!(ServeError::Overloaded("x".into()).code(), "overloaded");
        assert_eq!(ServeError::WriteStalled("x".into()).code(), "write_stalled");
        assert_eq!(ServeError::QuorumFailed("x".into()).code(), "quorum_failed");
        assert_eq!(ServeError::Internal("x".into()).code(), "internal");
        let e = ServeError::Overloaded("queue full".into());
        assert_eq!(e.to_string(), "overloaded: queue full");
        assert_eq!(e.message(), "queue full");
    }

    #[test]
    fn deadline_triage_accepts_positive_and_rejects_junk() {
        assert!(triage_deadline_ms(250.0).is_ok());
        assert!(triage_deadline_ms(0.0).is_err());
        assert!(triage_deadline_ms(-5.0).is_err());
        assert!(triage_deadline_ms(f64::INFINITY).is_err());
        assert!(triage_deadline_ms(f64::NAN).is_err());
        // Absurd values clamp instead of panicking Duration::from_secs_f64.
        let far = triage_deadline_ms(1e300).unwrap();
        assert!(far <= Instant::now() + Duration::from_secs(3601));
        assert!(!deadline_expired(None));
        assert!(!deadline_expired(Some(Instant::now() + Duration::from_secs(60))));
        let past = Instant::now();
        std::thread::sleep(Duration::from_millis(2));
        assert!(deadline_expired(Some(past)));
    }

    #[test]
    fn ladder_escalates_on_queue_fill_and_sheds() {
        let c = controller(fast_cfg(), 10);
        assert_eq!(c.evaluate(), 0);
        // 50% fill → degrade.
        for _ in 0..5 {
            c.on_enqueue();
        }
        assert_eq!(c.evaluate(), 1);
        // 90% fill → shed.
        for _ in 0..4 {
            c.on_enqueue();
        }
        assert_eq!(c.evaluate(), 2);
        assert_eq!(c.level(), 2);
    }

    #[test]
    fn ladder_escalates_on_p99() {
        let c = controller(fast_cfg(), 1024);
        // Empty window → healthy.
        assert_eq!(c.evaluate(), 0);
        for _ in 0..100 {
            c.record_latency(2_000_000); // 2 s >> 500 ms target
        }
        assert_eq!(c.evaluate(), 1);
    }

    #[test]
    fn deescalation_steps_one_level_with_recovered_signals() {
        let c = controller(fast_cfg(), 10);
        for _ in 0..9 {
            c.on_enqueue();
        }
        assert_eq!(c.evaluate(), 2);
        // Drain to 10% fill (below recover_fill 0.25): one step per eval.
        for _ in 0..8 {
            c.on_dequeue();
        }
        assert_eq!(c.evaluate(), 1);
        assert_eq!(c.evaluate(), 0);
        assert_eq!(c.evaluate(), 0);
    }

    #[test]
    fn deescalation_respects_dwell() {
        let cfg = AdmissionConfig { min_dwell: Duration::from_secs(3600), ..fast_cfg() };
        let c = controller(cfg, 10);
        for _ in 0..9 {
            c.on_enqueue();
        }
        assert_eq!(c.evaluate(), 2);
        for _ in 0..9 {
            c.on_dequeue();
        }
        // Signals recovered but dwell not elapsed: the level holds.
        assert_eq!(c.evaluate(), 2);
        assert_eq!(c.level(), 2);
    }

    #[test]
    fn deescalation_blocked_while_p99_is_hot() {
        let c = controller(fast_cfg(), 10);
        for _ in 0..6 {
            c.on_enqueue();
        }
        assert_eq!(c.evaluate(), 1);
        for _ in 0..6 {
            c.on_dequeue();
        }
        c.record_latency(2_000_000);
        // Queue drained but the window still holds a hot sample.
        assert_eq!(c.evaluate(), 1);
    }

    #[test]
    fn latency_samples_age_out_of_window() {
        let cfg =
            AdmissionConfig { latency_window: Duration::from_millis(40), ..fast_cfg() };
        let c = controller(cfg, 1024);
        for _ in 0..50 {
            c.record_latency(2_000_000);
        }
        assert_eq!(c.evaluate(), 1);
        std::thread::sleep(Duration::from_millis(80));
        // The hot samples aged out; recovery follows.
        assert_eq!(c.recent_p99_us(), 0);
        assert_eq!(c.evaluate(), 0);
    }

    #[test]
    fn eval_interval_throttles_reevaluation() {
        let cfg =
            AdmissionConfig { eval_interval: Duration::from_secs(3600), ..fast_cfg() };
        let c = controller(cfg, 10);
        assert_eq!(c.evaluate(), 0);
        for _ in 0..9 {
            c.on_enqueue();
        }
        // Calls inside the interval return the cached level — the queue
        // spike is not observed until the interval elapses.
        assert_eq!(c.evaluate(), 0);
        assert_eq!(c.level(), 0);
    }

    #[test]
    fn p99_tracks_the_tail() {
        let c = controller(fast_cfg(), 1024);
        for i in 0..200u64 {
            c.record_latency(if i < 198 { 100 } else { 50_000 });
        }
        let p99 = c.recent_p99_us();
        assert!(p99 >= 100, "p99 {p99}");
        // 2/200 hot samples sit exactly at the 99th percentile edge.
        assert!(p99 >= 100 && p99 <= 50_000);
    }
}

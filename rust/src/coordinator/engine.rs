//! The per-shard query engine: ALSH index + exact rerank + metrics.

use anyhow::bail;
use std::sync::Arc;
use std::time::Instant;

use crate::index::delta::LiveStorage;
use crate::index::scratch::with_thread_scratch;
use crate::index::storage::{Mapped, Owned, Storage};
use crate::index::{
    AlshIndex, AlshParams, AnyIndex, BandedBuildStats, BandedParams, BuildOpts, BuildStats,
    LiveConfig, LiveIndex, LiveStats, MipsHashScheme, NormRangeIndex, ProbeBudget, QueryScratch,
    SchemeHasher, ScoredItem, WriteStalled,
};
use crate::lsh::L2LshFamily;

use super::metrics::{Metrics, N_BUCKETS};
use super::trace::{QuerySpans, Stage, FLAG_LIVE};

/// Size-tiered compaction triggers for a live engine's background
/// compactor, rate-limited against reader tail latency: compaction is
/// discretionary while the probe-stage p99 (measured over the interval
/// since the last poll, from the [`super::trace`] stage histograms) is
/// above `p99_ceiling_us`, until the backlog reaches the `max_pending`
/// relief valve — at the delta cap, deferring compaction would stall
/// writes, which costs more than a slow reader tail.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveCompactionConfig {
    /// Size-tiered trigger: compact when pending delta rows (live +
    /// dead) reach this fraction of the current logical item count.
    pub tier_fraction: f64,
    /// Floor under the tiered trigger, so tiny indexes don't churn a
    /// generation per handful of writes.
    pub min_pending: usize,
    /// Relief valve: at or above this many pending rows compaction runs
    /// regardless of reader latency. Set it at (or just below) the
    /// delta cap so backpressure stalls stay transient.
    pub max_pending: usize,
    /// Reader probe-stage interval p99 (µs) above which discretionary
    /// compaction is deferred.
    pub p99_ceiling_us: u64,
    /// Compactor poll interval.
    pub poll: std::time::Duration,
}

impl Default for AdaptiveCompactionConfig {
    fn default() -> Self {
        Self {
            tier_fraction: 0.25,
            min_pending: 512,
            max_pending: LiveConfig::default().delta_cap,
            p99_ceiling_us: 5_000,
            poll: std::time::Duration::from_millis(20),
        }
    }
}

/// What the engine serves: a frozen index (heap or mmap) or the live
/// mutable tier layered over one.
enum EngineCore<S: Storage> {
    Frozen(AnyIndex<S>),
    Live(LiveIndex<S>),
}

/// A self-contained MIPS engine over one item collection, serving either
/// the flat [`AlshIndex`] or the norm-range banded [`NormRangeIndex`]
/// behind [`AnyIndex`] dispatch — over heap storage (the default) or a
/// zero-copy mapped index ([`MipsEngine::open_mmap`]) — or the live
/// mutable tier ([`LiveIndex`], [`MipsEngine::open_live`]), which serves
/// the same four query paths over a frozen base plus an in-memory delta
/// and accepts crash-consistent [`MipsEngine::upsert`] /
/// [`MipsEngine::delete`] while readers run.
///
/// The allocation-free request path (`query_into` with a caller-owned
/// [`QueryScratch`]) is used per-shard by the router and by the batcher;
/// the PJRT-accelerated path hashes whole batches through the AOT
/// artifact (see `batcher`) and re-enters here via `query_with_codes_into`
/// — both index kinds consume the same `[L·K]` code rows, since the
/// banded index shares one hash family set across its bands (and the
/// live tier shares its base's families across generations).
pub struct MipsEngine<S: Storage = Owned> {
    core: EngineCore<S>,
    metrics: Arc<Metrics>,
}

impl MipsEngine {
    /// Build a flat-index engine with the default parallel sharded build
    /// pipeline (all available cores).
    pub fn new(items: &[Vec<f32>], params: AlshParams, seed: u64) -> Self {
        Self::from_any(AnyIndex::Flat(AlshIndex::build(items, params, seed)))
    }

    /// Rebuild entry point with explicit build-pipeline options (worker
    /// thread count, hash block size); returns the engine plus the
    /// build's observability stats. The served index is byte-identical
    /// for every `opts` choice — only build latency and transient memory
    /// change.
    pub fn new_with(
        items: &[Vec<f32>],
        params: AlshParams,
        seed: u64,
        opts: BuildOpts,
    ) -> (Self, BuildStats) {
        let (index, stats) = AlshIndex::build_with(items, params, seed, opts);
        (Self::from_index(index), stats)
    }

    /// Build a norm-range banded engine (per-band U scaling, shared hash
    /// families) with the default pipeline options.
    pub fn new_banded(
        items: &[Vec<f32>],
        params: AlshParams,
        banded: BandedParams,
        seed: u64,
    ) -> Self {
        Self::from_any(AnyIndex::Banded(NormRangeIndex::build(items, params, banded, seed)))
    }

    /// [`MipsEngine::new_banded`] with explicit pipeline options (thread
    /// count, block size, concurrent-band memory cap), returning the
    /// banded build's observability stats.
    pub fn new_banded_with(
        items: &[Vec<f32>],
        params: AlshParams,
        banded: BandedParams,
        seed: u64,
        opts: BuildOpts,
    ) -> (Self, BandedBuildStats) {
        let (index, stats) = NormRangeIndex::build_with(items, params, banded, seed, opts);
        (Self::from_any(AnyIndex::Banded(index)), stats)
    }

    pub fn from_index(index: AlshIndex) -> Self {
        Self::from_any(AnyIndex::Flat(index))
    }
}

impl MipsEngine<Mapped> {
    /// Serve straight out of a v5 index file: zero-copy open (O(header),
    /// no array read or copied — see `index::persist::open_mmap`),
    /// whichever kind and scheme the file holds. The returned engine has
    /// the exact same query surface as a heap engine.
    pub fn open_mmap(path: impl AsRef<std::path::Path>) -> crate::Result<Self> {
        Ok(Self::from_any(crate::index::persist::open_mmap(path)?))
    }
}

impl<S: LiveStorage> MipsEngine<S> {
    /// Create a live directory from an initial item set and serve it.
    pub fn create_live(
        dir: impl AsRef<std::path::Path>,
        items: &[Vec<f32>],
        cfg: LiveConfig,
    ) -> crate::Result<Self> {
        Ok(Self::from_live(LiveIndex::create(dir, items, cfg)?))
    }

    /// Open an existing live directory (manifest + base generation + WAL
    /// replay — see `index::delta` for the recovery contract).
    pub fn open_live(dir: impl AsRef<std::path::Path>) -> crate::Result<Self> {
        Ok(Self::from_live(LiveIndex::open(dir)?))
    }

    /// Drain the live delta into a fresh frozen generation and swap it
    /// in. Errors on a frozen engine.
    pub fn compact(&self) -> crate::Result<u64> {
        match &self.core {
            EngineCore::Live(live) => {
                let generation = live.compact_once()?;
                self.sync_live_metrics();
                Ok(generation)
            }
            EngineCore::Frozen(_) => bail!("compact: engine serves a frozen index"),
        }
    }

    /// Spawn the background compactor with size-tiered triggers
    /// rate-limited against this engine's reader probe-stage p99 (see
    /// [`AdaptiveCompactionConfig`]). Errors on a frozen engine.
    pub fn spawn_adaptive_compactor(&self, cfg: AdaptiveCompactionConfig) -> crate::Result<()> {
        let EngineCore::Live(live) = &self.core else {
            bail!("spawn_adaptive_compactor: engine serves a frozen index");
        };
        let metrics = Arc::clone(&self.metrics);
        let probe_prev = std::sync::Mutex::new([0u64; N_BUCKETS]);
        live.spawn_compactor_when(cfg.poll, move |s: &LiveStats| {
            let pending = (s.delta_items + s.tombstones) as usize;
            if pending >= cfg.max_pending.max(1) {
                return true; // relief valve: beat the write stall
            }
            let tier = (s.n_items as f64 * cfg.tier_fraction) as usize;
            if pending < tier.max(cfg.min_pending) {
                return false;
            }
            // Rate limit: defer while readers are already slow. An idle
            // interval (no probe samples) reads as "free to compact".
            let mut prev = probe_prev.lock().unwrap_or_else(|e| e.into_inner());
            match metrics
                .stage_hist(Stage::Probe)
                .interval_percentile_us(&mut prev, 0.99)
            {
                Some(p99) => p99 <= cfg.p99_ceiling_us,
                None => true,
            }
        });
        Ok(())
    }

    /// Stop and join the background compactor, if one is running (no-op
    /// on a frozen engine).
    pub fn stop_compactor(&self) {
        if let EngineCore::Live(live) = &self.core {
            live.stop_compactor();
        }
    }
}

impl<S: Storage> MipsEngine<S> {
    /// Wrap an already-built (or mapped) index of either kind.
    pub fn from_any(index: AnyIndex<S>) -> Self {
        Self { core: EngineCore::Frozen(index), metrics: Arc::new(Metrics::new()) }
    }

    /// Wrap a live mutable index.
    pub fn from_live(live: LiveIndex<S>) -> Self {
        let engine = Self { core: EngineCore::Live(live), metrics: Arc::new(Metrics::new()) };
        engine.sync_live_metrics();
        engine
    }

    /// The frozen index. Panics on a live engine (the live tier swaps
    /// its base generation under readers, so there is no stable handle
    /// to lend out) — use the engine-level accessors (`dim`, `params`,
    /// `scheme`, `hasher`, …) or [`MipsEngine::live`] instead.
    pub fn index(&self) -> &AnyIndex<S> {
        match &self.core {
            EngineCore::Frozen(index) => index,
            EngineCore::Live(_) => {
                panic!("MipsEngine::index: live engine has no stable frozen index handle")
            }
        }
    }

    /// The live tier, if this engine serves one.
    pub fn live(&self) -> Option<&LiveIndex<S>> {
        match &self.core {
            EngineCore::Live(live) => Some(live),
            EngineCore::Frozen(_) => None,
        }
    }

    /// Whether this engine serves the live mutable tier.
    pub fn is_live(&self) -> bool {
        matches!(self.core, EngineCore::Live(_))
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Item dimensionality.
    pub fn dim(&self) -> usize {
        match &self.core {
            EngineCore::Frozen(index) => index.dim(),
            EngineCore::Live(live) => live.dim(),
        }
    }

    /// Current logical item count (for a live engine: base − tombstones
    /// + delta).
    pub fn n_items(&self) -> usize {
        match &self.core {
            EngineCore::Frozen(index) => index.n_items(),
            EngineCore::Live(live) => live.n_items(),
        }
    }

    /// Norm bands (1 = flat layout).
    pub fn n_bands(&self) -> usize {
        match &self.core {
            EngineCore::Frozen(index) => index.n_bands(),
            EngineCore::Live(live) => live.n_bands(),
        }
    }

    /// ALSH parameters.
    pub fn params(&self) -> &AlshParams {
        match &self.core {
            EngineCore::Frozen(index) => index.params(),
            EngineCore::Live(live) => live.params(),
        }
    }

    /// The hash scheme.
    pub fn scheme(&self) -> MipsHashScheme {
        match &self.core {
            EngineCore::Frozen(index) => index.scheme(),
            EngineCore::Live(live) => live.scheme(),
        }
    }

    /// The fused multi-table hasher (batcher CPU fallback; stable across
    /// live generations because every generation rebuilds from the same
    /// seed).
    pub fn hasher(&self) -> &SchemeHasher {
        match &self.core {
            EngineCore::Frozen(index) => index.hasher(),
            EngineCore::Live(live) => live.hasher(),
        }
    }

    /// The L2 hash families (PJRT artifact inputs). Panics for SRP
    /// schemes, matching [`AnyIndex::families`].
    pub fn families(&self) -> &[L2LshFamily] {
        match &self.core {
            EngineCore::Frozen(index) => index.families(),
            EngineCore::Live(live) => live
                .scheme_families()
                .as_l2()
                .expect("families: SRP-scheme index has no L2 families"),
        }
    }

    /// Point-in-time live-tier counters; `None` on a frozen engine.
    pub fn live_stats(&self) -> Option<LiveStats> {
        self.live().map(|live| live.stats())
    }

    /// Upsert (insert or replace) an item by external id. Errors on a
    /// frozen engine; the WAL append is durable before this returns.
    pub fn upsert(&self, ext_id: u32, vector: &[f32]) -> crate::Result<()> {
        match &self.core {
            EngineCore::Live(live) => {
                live.upsert(ext_id, vector)?;
                self.sync_live_metrics();
                Ok(())
            }
            EngineCore::Frozen(_) => {
                bail!("upsert: engine serves a frozen index (open a live directory to mutate)")
            }
        }
    }

    /// Group-commit bulk upsert: one WAL write + one fsync for the
    /// whole batch, one snapshot swap (see
    /// [`LiveIndex::upsert_batch`](crate::index::LiveIndex::upsert_batch)).
    /// Errors on a frozen engine; the batch is durable before this
    /// returns.
    pub fn upsert_batch(&self, entries: &[(u32, Vec<f32>)]) -> crate::Result<()> {
        match &self.core {
            EngineCore::Live(live) => {
                live.upsert_batch(entries)?;
                self.sync_live_metrics();
                Ok(())
            }
            EngineCore::Frozen(_) => {
                bail!("upsert_batch: engine serves a frozen index (open a live directory to mutate)")
            }
        }
    }

    /// Delete an item by external id (idempotent). Errors on a frozen
    /// engine; the WAL append is durable before this returns.
    pub fn delete(&self, ext_id: u32) -> crate::Result<()> {
        match &self.core {
            EngineCore::Live(live) => {
                live.delete(ext_id)?;
                self.sync_live_metrics();
                Ok(())
            }
            EngineCore::Frozen(_) => {
                bail!("delete: engine serves a frozen index (open a live directory to mutate)")
            }
        }
    }

    /// Replicated-fan-out twin of [`MipsEngine::upsert`]: the record
    /// must land at exactly group sequence `seq` (see
    /// [`crate::index::SeqGap`]). Returns the assigned sequence.
    pub fn upsert_at(&self, seq: u64, ext_id: u32, vector: &[f32]) -> crate::Result<u64> {
        match &self.core {
            EngineCore::Live(live) => {
                let assigned = live.upsert_at(seq, ext_id, vector)?;
                self.sync_live_metrics();
                Ok(assigned)
            }
            EngineCore::Frozen(_) => {
                bail!("upsert_at: engine serves a frozen index (open a live directory to mutate)")
            }
        }
    }

    /// Replicated-fan-out twin of [`MipsEngine::upsert_batch`] (the
    /// whole batch is one WAL record at `seq`).
    pub fn upsert_batch_at(&self, seq: u64, entries: &[(u32, Vec<f32>)]) -> crate::Result<u64> {
        match &self.core {
            EngineCore::Live(live) => {
                let assigned = live.upsert_batch_at(seq, entries)?;
                self.sync_live_metrics();
                Ok(assigned)
            }
            EngineCore::Frozen(_) => {
                bail!(
                    "upsert_batch_at: engine serves a frozen index (open a live directory to mutate)"
                )
            }
        }
    }

    /// Replicated-fan-out twin of [`MipsEngine::delete`].
    pub fn delete_at(&self, seq: u64, ext_id: u32) -> crate::Result<u64> {
        match &self.core {
            EngineCore::Live(live) => {
                let assigned = live.delete_at(seq, ext_id)?;
                self.sync_live_metrics();
                Ok(assigned)
            }
            EngineCore::Frozen(_) => {
                bail!("delete_at: engine serves a frozen index (open a live directory to mutate)")
            }
        }
    }

    /// Highest durable WAL sequence number (`None` on a frozen engine).
    pub fn high_water(&self) -> Option<u64> {
        self.live().map(|live| live.high_water())
    }

    /// Seed-independent checksum of the live logical item set (`None`
    /// on a frozen engine) — the scrub exchange's divergence detector.
    pub fn state_checksum(&self) -> Option<u64> {
        self.live().map(|live| live.state_checksum())
    }

    /// The structured stall a mutation would currently fail with, if
    /// any (`None` on a frozen engine or below the delta cap).
    pub fn would_stall(&self) -> Option<WriteStalled> {
        self.live().and_then(|live| live.would_stall())
    }

    /// Push the live tier's current counters into the metrics gauges.
    /// No-op on a frozen engine.
    fn sync_live_metrics(&self) {
        if let EngineCore::Live(live) = &self.core {
            self.metrics.record_live_stats(&live.stats());
        }
    }

    /// A metrics snapshot with the live-tier gauges refreshed first, so
    /// background-compactor progress is visible without a mutation in
    /// between.
    pub fn metrics_snapshot(&self) -> super::metrics::MetricsSnapshot {
        self.sync_live_metrics();
        self.metrics.snapshot()
    }

    /// A scratch pre-sized for this engine's index.
    pub fn scratch(&self) -> QueryScratch {
        match &self.core {
            EngineCore::Frozen(index) => index.scratch(),
            EngineCore::Live(live) => live.scratch(),
        }
    }

    /// Allocation-free query path: Q-transform + fused hash + CSR probe +
    /// exact rerank, all through the caller's scratch.
    pub fn query_into<'s>(
        &self,
        query: &[f32],
        top_k: usize,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        self.query_budgeted_into(query, top_k, ProbeBudget::full(), s)
    }

    /// PJRT path re-entry: the batcher already hashed this query (via the
    /// compiled artifact or the fused CPU fallback) and hands us its
    /// `[L*K]` code row.
    pub fn query_with_codes_into<'s>(
        &self,
        query: &[f32],
        codes: &[i32],
        top_k: usize,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        self.query_with_codes_budgeted_into(query, codes, top_k, ProbeBudget::full(), s)
    }

    /// Budgeted query path (degraded serving): same shape as
    /// [`MipsEngine::query_into`] with the probe constrained by `budget`.
    /// Bit-identical at [`ProbeBudget::full`].
    pub fn query_budgeted_into<'s>(
        &self,
        query: &[f32],
        top_k: usize,
        budget: ProbeBudget,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        let mut spans = QuerySpans::default();
        let out = self.query_traced_into(query, top_k, budget, &mut spans, s);
        self.metrics.tracer.offer(&spans);
        out
    }

    /// Budgeted code-fed re-entry (the degraded batcher path): the hash
    /// already happened batch-wide, the probe honours `budget`.
    pub fn query_with_codes_budgeted_into<'s>(
        &self,
        query: &[f32],
        codes: &[i32],
        top_k: usize,
        budget: ProbeBudget,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        let mut spans = QuerySpans::default();
        let out = self.query_with_codes_traced_into(query, codes, top_k, budget, &mut spans, s);
        self.metrics.tracer.offer(&spans);
        out
    }

    /// [`MipsEngine::query_budgeted_into`] with per-stage attribution:
    /// probe and rerank timings, candidate counts, and scheme/kind
    /// context land in `spans` (and in the per-stage [`Metrics`]
    /// histograms). On a live engine the whole query is attributed to
    /// the probe stage — the live tier's base+delta+rerank pipeline is
    /// opaque here — and the span carries `FLAG_LIVE`. Allocation-free:
    /// the span is written in place and only monotonic clock reads are
    /// added over the untraced path.
    pub fn query_traced_into<'s>(
        &self,
        query: &[f32],
        top_k: usize,
        budget: ProbeBudget,
        spans: &mut QuerySpans,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        let t0 = Instant::now();
        self.fill_span_context(spans, top_k, budget);
        match &self.core {
            EngineCore::Frozen(index) => {
                index.candidates_budgeted_into(query, budget, s);
                let probe_us = t0.elapsed().as_micros() as u64;
                let n_cands = s.candidates().len();
                let t1 = Instant::now();
                let out = index.rerank_into(query, top_k, s);
                let rerank_us = t1.elapsed().as_micros() as u64;
                self.finish_frozen_span(spans, probe_us, rerank_us, n_cands, out.len());
                self.metrics.record_query(t0.elapsed().as_micros() as u64, n_cands);
                out
            }
            EngineCore::Live(live) => {
                let n_top = live.query_budgeted_into(query, top_k, budget, s).len();
                let n_cands = s.candidates().len();
                self.finish_live_span(spans, t0.elapsed().as_micros() as u64, n_cands, n_top);
                self.metrics.record_query(t0.elapsed().as_micros() as u64, n_cands);
                &s.top[..n_top]
            }
        }
    }

    /// [`MipsEngine::query_with_codes_budgeted_into`] with per-stage
    /// attribution (see [`MipsEngine::query_traced_into`]); the hash
    /// stage is not timed here because it already happened batch-wide
    /// in the batcher.
    pub fn query_with_codes_traced_into<'s>(
        &self,
        query: &[f32],
        codes: &[i32],
        top_k: usize,
        budget: ProbeBudget,
        spans: &mut QuerySpans,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        let t0 = Instant::now();
        self.fill_span_context(spans, top_k, budget);
        match &self.core {
            EngineCore::Frozen(index) => {
                index.candidates_from_codes_budgeted_into(codes, budget, s);
                let probe_us = t0.elapsed().as_micros() as u64;
                let n_cands = s.candidates().len();
                let t1 = Instant::now();
                let out = index.rerank_into(query, top_k, s);
                let rerank_us = t1.elapsed().as_micros() as u64;
                self.finish_frozen_span(spans, probe_us, rerank_us, n_cands, out.len());
                self.metrics.record_query(t0.elapsed().as_micros() as u64, n_cands);
                out
            }
            EngineCore::Live(live) => {
                let n_top =
                    live.query_from_codes_budgeted_into(codes, query, top_k, budget, s).len();
                let n_cands = s.candidates().len();
                self.finish_live_span(spans, t0.elapsed().as_micros() as u64, n_cands, n_top);
                self.metrics.record_query(t0.elapsed().as_micros() as u64, n_cands);
                &s.top[..n_top]
            }
        }
    }

    /// Stamp scheme/kind/top-k/budget context onto a span.
    fn fill_span_context(&self, spans: &mut QuerySpans, top_k: usize, budget: ProbeBudget) {
        spans.scheme = match self.scheme() {
            MipsHashScheme::L2Alsh => 0,
            MipsHashScheme::SignAlsh => 1,
            MipsHashScheme::SimpleLsh => 2,
        };
        spans.kind = match &self.core {
            EngineCore::Frozen(index) => u8::from(index.as_banded().is_some()),
            EngineCore::Live(live) => u8::from(live.n_bands() > 1),
        };
        spans.top_k = top_k.min(u16::MAX as usize) as u16;
        spans.budget_tables = budget.max_tables.min(u16::MAX as usize) as u16;
    }

    /// Record the frozen path's probe/rerank split into the span and the
    /// per-stage histograms.
    fn finish_frozen_span(
        &self,
        spans: &mut QuerySpans,
        probe_us: u64,
        rerank_us: u64,
        n_cands: usize,
        n_hits: usize,
    ) {
        spans.set_stage(Stage::Probe, probe_us);
        spans.set_stage(Stage::Rerank, rerank_us);
        spans.candidates_probed += n_cands as u64;
        spans.candidates_reranked += n_cands as u64;
        spans.hits = n_hits.min(u16::MAX as usize) as u16;
        spans.total_us = spans.total_us.max(probe_us + rerank_us);
        self.metrics.record_stage(Stage::Probe, probe_us);
        self.metrics.record_stage(Stage::Rerank, rerank_us);
        self.metrics.record_candidate_flow(n_cands as u64, n_cands as u64);
    }

    /// Record the live path's single opaque probe span.
    fn finish_live_span(
        &self,
        spans: &mut QuerySpans,
        probe_us: u64,
        n_cands: usize,
        n_hits: usize,
    ) {
        spans.set_stage(Stage::Probe, probe_us);
        spans.set_flag(FLAG_LIVE);
        spans.candidates_probed += n_cands as u64;
        spans.candidates_reranked += n_cands as u64;
        spans.hits = n_hits.min(u16::MAX as usize) as u16;
        spans.total_us = spans.total_us.max(probe_us);
        self.metrics.record_stage(Stage::Probe, probe_us);
        self.metrics.record_candidate_flow(n_cands as u64, n_cands as u64);
    }

    /// Allocating convenience wrapper over [`MipsEngine::query_into`]
    /// (thread-local scratch).
    pub fn query(&self, query: &[f32], top_k: usize) -> Vec<ScoredItem> {
        with_thread_scratch(|s| self.query_into(query, top_k, s).to_vec())
    }

    /// Allocating convenience wrapper over
    /// [`MipsEngine::query_budgeted_into`].
    pub fn query_budgeted(&self, query: &[f32], top_k: usize, budget: ProbeBudget) -> Vec<ScoredItem> {
        with_thread_scratch(|s| self.query_budgeted_into(query, top_k, budget, s).to_vec())
    }

    /// Allocating convenience wrapper over
    /// [`MipsEngine::query_with_codes_into`].
    pub fn query_with_codes(&self, query: &[f32], codes: &[i32], top_k: usize) -> Vec<ScoredItem> {
        with_thread_scratch(|s| self.query_with_codes_into(query, codes, top_k, s).to_vec())
    }

    /// The flat `(a, b)` artifact inputs spanning all L tables: columns
    /// `t*K..(t+1)*K` of `a` are table t's family, zero-padded up to
    /// `k_total` columns (the artifact's fixed K). L2-ALSH only — the
    /// batcher never calls this for SRP-scheme engines (they hash
    /// through the fused CPU path), and an SRP index has no L2 families
    /// to concatenate.
    pub fn concat_family_inputs(&self, k_total: usize) -> (Vec<f32>, Vec<f32>) {
        let p = self.params();
        let dp = self.dim() + p.m;
        let l = p.n_tables;
        let k = p.k_per_table;
        assert!(
            l * k <= k_total,
            "index needs {} hashes > artifact capacity {k_total}",
            l * k
        );
        let mut a = vec![0.0f32; dp * k_total];
        let mut b = vec![0.0f32; k_total];
        for (t, fam) in self.families().iter().enumerate() {
            let fam_a = fam.a_matrix_dk(); // [dp, k]
            for d in 0..dp {
                for j in 0..k {
                    a[d * k_total + t * k + j] = fam_a[d * k + j];
                }
            }
            b[t * k..(t + 1) * k].copy_from_slice(fam.b_vector());
        }
        (a, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transform::q_transform;
    use crate::util::Rng;

    fn items(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n)
            .map(|i| {
                let s = 0.2 + 2.0 * (i as f32 / n as f32);
                (0..d).map(|_| (rng.f32() - 0.5) * s).collect()
            })
            .collect()
    }

    #[test]
    fn new_with_serves_identical_results() {
        let its = items(300, 8, 20);
        let base = MipsEngine::new(&its, AlshParams::default(), 21);
        let (eng, stats) =
            MipsEngine::new_with(&its, AlshParams::default(), 21, BuildOpts::threads(3));
        assert_eq!(stats.n_threads, 3);
        assert_eq!(stats.n_items, 300);
        let mut rng = Rng::seed_from_u64(22);
        for _ in 0..10 {
            let q: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
            assert_eq!(eng.query(&q, 5), base.query(&q, 5));
        }
    }

    #[test]
    fn banded_engine_matches_direct_banded_index() {
        let its = items(400, 8, 30);
        let banded = BandedParams { n_bands: 4 };
        let eng = MipsEngine::new_banded(&its, AlshParams::default(), banded, 31);
        assert_eq!(eng.index().n_bands(), 4);
        let (eng2, stats) = MipsEngine::new_banded_with(
            &its,
            AlshParams::default(),
            banded,
            31,
            BuildOpts::threads(2),
        );
        assert_eq!(stats.n_bands, 4);
        let idx = NormRangeIndex::build(&its, AlshParams::default(), banded, 31);
        let mut rng = Rng::seed_from_u64(32);
        for _ in 0..10 {
            let q: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
            assert_eq!(eng.query(&q, 5), idx.query(&q, 5));
            assert_eq!(eng2.query(&q, 5), idx.query(&q, 5));
        }
        // Code-fed re-entry (the batcher path): the banded index consumes
        // the same [L·K] code rows as the flat one.
        let q: Vec<f32> = (0..8).map(|i| (i as f32 * 0.61).cos()).collect();
        let qx = q_transform(&q, eng.index().params().m);
        let mut codes = Vec::new();
        for fam in eng.index().families() {
            fam.hash_into(&qx, &mut codes);
        }
        assert_eq!(eng.query_with_codes(&q, &codes, 10), eng.query(&q, 10));
    }

    #[test]
    fn query_records_metrics() {
        let eng = MipsEngine::new(&items(200, 8, 1), AlshParams::default(), 2);
        let _ = eng.query(&vec![0.5; 8], 5);
        let _ = eng.query(&vec![-0.25; 8], 5);
        let s = eng.metrics().snapshot();
        assert_eq!(s.queries, 2);
    }

    #[test]
    fn scratch_path_records_metrics_and_matches() {
        let eng = MipsEngine::new(&items(200, 8, 9), AlshParams::default(), 10);
        let mut scratch = eng.scratch();
        let q: Vec<f32> = (0..8).map(|i| (i as f32 * 0.3).sin()).collect();
        let a = eng.query_into(&q, 5, &mut scratch).to_vec();
        assert_eq!(a, eng.query(&q, 5));
        assert_eq!(eng.metrics().snapshot().queries, 2);
    }

    #[test]
    fn codes_path_equals_inline_path() {
        let eng = MipsEngine::new(&items(300, 8, 3), AlshParams::default(), 4);
        let q: Vec<f32> = (0..8).map(|i| (i as f32 * 0.61).cos()).collect();
        // Reproduce the batcher's code layout with the pure-Rust family.
        let qx = q_transform(&q, eng.index().params().m);
        let mut codes = Vec::new();
        for fam in eng.index().families() {
            fam.hash_into(&qx, &mut codes);
        }
        let a = eng.query(&q, 10);
        let b = eng.query_with_codes(&q, &codes, 10);
        assert_eq!(a, b);
    }

    #[test]
    fn concat_inputs_reproduce_per_family_codes() {
        // Hashing with the concatenated (a, b) must give, per column
        // block, the same codes as each family separately.
        let eng = MipsEngine::new(&items(50, 6, 5), AlshParams::default(), 6);
        let p = *eng.index().params();
        let dp = 6 + p.m;
        let k_total = 512;
        let (a, b) = eng.concat_family_inputs(k_total);
        let q: Vec<f32> = (0..6).map(|i| 0.1 * i as f32).collect();
        let qx = q_transform(&q, p.m);
        // Manual matmul: code_j = floor(sum_d qx[d] * a[d, j] + b[j])
        for (t, fam) in eng.index().families().iter().enumerate() {
            let want = fam.hash(&qx);
            for j in 0..p.k_per_table {
                let col = t * p.k_per_table + j;
                let mut acc = 0.0f32;
                for d in 0..dp {
                    acc += qx[d] * a[d * k_total + col];
                }
                let code = (acc + b[col]).floor() as i32;
                assert_eq!(code, want[j], "table {t} hash {j}");
            }
        }
    }

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "alsh_engine_{tag}_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn live_engine_matches_live_index_and_mutates() {
        let dir = tmp_dir("live");
        let its = items(120, 8, 40);
        let cfg = LiveConfig::default();
        let eng = MipsEngine::create_live(&dir, &its, cfg).unwrap();
        let live = LiveIndex::<Owned>::open(&dir).unwrap();
        assert!(eng.is_live());
        assert_eq!(eng.dim(), 8);
        assert_eq!(eng.n_items(), 120);
        let q: Vec<f32> = (0..8).map(|i| (i as f32 * 0.37).sin()).collect();
        assert_eq!(eng.query(&q, 5), live.query(&q, 5));
        // Mutations flow through and the gauges follow.
        eng.upsert(700, &its[3]).unwrap();
        eng.delete(5).unwrap();
        assert_eq!(eng.n_items(), 120);
        let stats = eng.live_stats().unwrap();
        assert_eq!(stats.delta_items, 1);
        assert_eq!(stats.tombstones, 1);
        let snap = eng.metrics_snapshot();
        assert_eq!(snap.delta_items, 1);
        assert_eq!(snap.tombstones, 1);
        assert!(snap.wal_bytes > 0);
        // Compaction drains the delta into generation 1.
        assert_eq!(eng.compact().unwrap(), 1);
        let snap = eng.metrics_snapshot();
        assert_eq!(snap.delta_items, 0);
        assert_eq!(snap.compactions, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn seq_variant_mutations_and_replication_accessors() {
        let dir = tmp_dir("live_seq");
        let its = items(60, 8, 50);
        let eng = MipsEngine::create_live(&dir, &its, LiveConfig::default()).unwrap();
        assert_eq!(eng.high_water(), Some(0));
        let base_sum = eng.state_checksum().unwrap();
        assert_eq!(eng.upsert_at(1, 900, &its[0]).unwrap(), 1);
        assert_eq!(eng.delete_at(2, 3).unwrap(), 2);
        let batch = [(901u32, its[1].clone()), (902u32, its[2].clone())];
        assert_eq!(eng.upsert_batch_at(3, &batch).unwrap(), 3);
        assert_eq!(eng.high_water(), Some(3));
        assert!(eng.upsert_at(7, 903, &its[0]).is_err(), "sequence gap must be refused");
        assert_eq!(eng.high_water(), Some(3), "refused write must not advance the log");
        assert_ne!(eng.state_checksum().unwrap(), base_sum);
        assert!(eng.would_stall().is_none());
        // Frozen engines expose no replication state and refuse the
        // seq-variant mutations.
        let frozen = MipsEngine::new(&its, AlshParams::default(), 51);
        assert_eq!(frozen.high_water(), None);
        assert_eq!(frozen.state_checksum(), None);
        assert!(frozen.would_stall().is_none());
        assert!(frozen.upsert_at(1, 0, &its[0]).is_err());
        assert!(frozen.delete_at(1, 0).is_err());
        assert!(frozen.upsert_batch_at(1, &batch).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn adaptive_compactor_tiered_trigger_fires() {
        let dir = tmp_dir("adaptive");
        let its = items(80, 8, 60);
        let eng = MipsEngine::create_live(&dir, &its, LiveConfig::default()).unwrap();
        eng.spawn_adaptive_compactor(AdaptiveCompactionConfig {
            tier_fraction: 0.05,
            min_pending: 4,
            max_pending: 1 << 20,
            p99_ceiling_us: u64::MAX,
            poll: std::time::Duration::from_millis(2),
        })
        .unwrap();
        for i in 0..8u32 {
            eng.upsert(1000 + i, &its[i as usize]).unwrap();
        }
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while eng.live_stats().unwrap().compactions == 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        eng.stop_compactor();
        assert!(eng.live_stats().unwrap().compactions >= 1, "tiered trigger never fired");
        // A frozen engine refuses the compactor outright (and the stop
        // is a harmless no-op).
        let frozen = MipsEngine::new(&its, AlshParams::default(), 61);
        assert!(frozen.spawn_adaptive_compactor(AdaptiveCompactionConfig::default()).is_err());
        frozen.stop_compactor();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn frozen_engine_rejects_mutation() {
        let eng = MipsEngine::new(&items(50, 6, 41), AlshParams::default(), 42);
        assert!(!eng.is_live());
        assert!(eng.live_stats().is_none());
        assert!(eng.upsert(1, &[0.0; 6]).is_err());
        assert!(eng.delete(1).is_err());
        assert!(eng.compact().is_err());
    }

    #[test]
    #[should_panic]
    fn concat_overflow_panics() {
        let eng = MipsEngine::new(
            &items(10, 4, 7),
            AlshParams { n_tables: 100, k_per_table: 8, ..Default::default() },
            8,
        );
        let _ = eng.concat_family_inputs(512);
    }
}

//! Layer-3 coordinator: the overload-robust MIPS serving system.
//!
//! Shape (vLLM-router-like, scaled to this paper):
//!
//! ```text
//!  TCP/JSON clients ──► server ──► admission ──► dynamic batcher ──► PJRT worker
//!                         │        (deadline,         │ retry/breaker │ thread
//!                         │         ladder,           ▼               ▼
//!                         │         bounded   per-query budgeted   fused CPU
//!                         │         queue)    probes + rerank      fallback
//!                         ▼
//!  sharded corpora:  router ──► shard engines ──► scatter/gather merge
//! ```
//!
//! **Admission queue.** The batcher's queue is bounded
//! ([`BatcherConfig::queue_depth`]); admission uses a non-blocking
//! `try_send`, so a full queue rejects immediately with a structured
//! `overloaded` error instead of building unbounded latency. Queue
//! pushes/pops drive the [`Metrics`] depth gauge that the load
//! controller reads as its fill signal.
//!
//! **Deadline semantics.** Every request carries a deadline — the
//! client's `deadline_ms` or [`AdmissionConfig::default_deadline`].
//! Expired requests are rejected with `deadline_exceeded` at three
//! points: before admission, when popped from the queue (never hashed),
//! and again at fan-out after the batch returns (never answered stale).
//! A reply is therefore either on time or an explicit error — no stale
//! answers.
//!
//! **Degradation ladder.** The [`LoadController`] maps measured queue
//! fill and recent p99 onto three levels: 0 healthy (full probe budget),
//! 1 degraded (reduced [`crate::index::ProbeBudget`] — fewer
//! tables/bands and a rerank cap — with a declared recall floor,
//! [`AdmissionConfig::recall_floor`]), 2 shed (reject with
//! `overloaded`). Escalation is immediate; de-escalation steps one level
//! at a time after a minimum dwell with recovered signals (hysteresis),
//! so the ladder never flaps. Degraded replies are marked
//! `degraded: true` — work is shed before requests are.
//!
//! **Circuit breaker.** PJRT batch failures retry with capped backoff;
//! persistent failure trips a breaker (`Closed → Open`) and batches are
//! served by the bit-identical fused CPU hash path instead. After a
//! cooldown the breaker half-opens and re-probes the backend with one
//! live batch (`Open → HalfOpen → Closed` on success). A test-only
//! [`FaultPlan`] injects latency spikes, batch failures, and poisoned
//! workers to prove readers never hang through any of this.
//!
//! **Live mutation.** A [`MipsEngine`] can serve the crash-consistent
//! live tier ([`crate::index::LiveIndex`], [`MipsEngine::open_live`])
//! instead of a frozen index: the server's `upsert`/`delete` commands
//! WAL-log and apply mutations while readers keep running lock-free on
//! epoch-swapped snapshots, and a background compactor drains the delta
//! back into a fresh frozen generation. The whole serving stack —
//! batcher fan-out (its fused hasher is generation-stable), budgeted
//! degradation, router sharding — works unchanged on top, and the
//! live-tier gauges flow through [`Metrics`] into the `metrics`
//! command.

pub mod admission;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod server;

pub use admission::{AdmissionConfig, LoadController, ServeError};
pub use batcher::{
    BatcherConfig, BatcherHandle, BreakerState, FaultPlan, PjrtBatcher, QueryReply,
};
pub use engine::MipsEngine;
pub use metrics::{Metrics, MetricsSnapshot};
pub use router::ShardedRouter;
pub use server::{handle_request, serve, serve_on, ServeConfig};

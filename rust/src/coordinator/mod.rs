//! Layer-3 coordinator: the overload-robust MIPS serving system.
//!
//! Shape (vLLM-router-like, scaled to this paper):
//!
//! ```text
//!  TCP/JSON clients ──► server ──► admission ──► dynamic batcher ──► PJRT worker
//!                         │        (deadline,         │ retry/breaker │ thread
//!                         │         ladder,           ▼               ▼
//!                         │         bounded   per-query budgeted   fused CPU
//!                         │         queue)    probes + rerank      fallback
//!                         ▼
//!  sharded corpora:  router ──► shard engines ──► scatter/gather merge
//! ```
//!
//! **Admission queue.** The batcher's queue is bounded
//! ([`BatcherConfig::queue_depth`]); admission uses a non-blocking
//! `try_send`, so a full queue rejects immediately with a structured
//! `overloaded` error instead of building unbounded latency. Queue
//! pushes/pops drive the [`Metrics`] depth gauge that the load
//! controller reads as its fill signal.
//!
//! **Deadline semantics.** Every request carries a deadline — the
//! client's `deadline_ms` or [`AdmissionConfig::default_deadline`].
//! Expired requests are rejected with `deadline_exceeded` at three
//! points: before admission, when popped from the queue (never hashed),
//! and again at fan-out after the batch returns (never answered stale).
//! A reply is therefore either on time or an explicit error — no stale
//! answers.
//!
//! **Degradation ladder.** The [`LoadController`] maps measured queue
//! fill and recent p99 onto three levels: 0 healthy (full probe budget),
//! 1 degraded (reduced [`crate::index::ProbeBudget`] — fewer
//! tables/bands and a rerank cap — with a declared recall floor,
//! [`AdmissionConfig::recall_floor`]), 2 shed (reject with
//! `overloaded`). Escalation is immediate; de-escalation steps one level
//! at a time after a minimum dwell with recovered signals (hysteresis),
//! so the ladder never flaps. Degraded replies are marked
//! `degraded: true` — work is shed before requests are.
//!
//! **Circuit breaker.** PJRT batch failures retry with capped backoff;
//! persistent failure trips a breaker (`Closed → Open`) and batches are
//! served by the bit-identical fused CPU hash path instead. After a
//! cooldown the breaker half-opens and re-probes the backend with one
//! live batch (`Open → HalfOpen → Closed` on success). A test-only
//! [`FaultPlan`] injects latency spikes, batch failures, and poisoned
//! workers to prove readers never hang through any of this.
//!
//! **Live mutation.** A [`MipsEngine`] can serve the crash-consistent
//! live tier ([`crate::index::LiveIndex`], [`MipsEngine::open_live`])
//! instead of a frozen index: the server's `upsert`/`delete` commands
//! WAL-log and apply mutations while readers keep running lock-free on
//! epoch-swapped snapshots, and a background compactor drains the delta
//! back into a fresh frozen generation. Bulk loads go through
//! `upsert_batch` — one WAL batch, one fsync for the whole group
//! ([`crate::index::LiveIndex::upsert_batch`]) — with all-or-prefix
//! durability. The whole serving stack — batcher fan-out (its fused
//! hasher is generation-stable), budgeted degradation, router sharding —
//! works unchanged on top, and the live-tier gauges flow through
//! [`Metrics`] into the `metrics` command.
//!
//! # Replication, hedging, and partial results
//!
//! **Replica groups.** Each shard of a [`ShardedRouter`] is a replica
//! group ([`crate::coordinator::replica`]): R engines over the same
//! contiguous item range, built with **distinct hash seeds** (member
//! (s, r) seeds with `seed + s·R + r`, derived in exactly one place).
//! Distinct seeds make replicas recall-diverse by construction — a
//! hedged retry probes independent hash tables, not a copy of the
//! randomness that was already slow or unlucky.
//!
//! **Hedged scatter/gather.** [`ShardedRouter::query_replicated`]
//! scatters every shard's primary dispatch before any collect blocks,
//! then waits per shard: if the primary exceeds the hedge delay (fixed
//! [`ReplicaConfig::hedge_delay`], or derived per shard as
//! `clamp(hedge_multiplier × shard p99, hedge_min, hedge_max)`), one
//! backup replica is dispatched and whichever answers first wins. The
//! wait is bounded by [`ReplicaConfig::shard_timeout`]; workers that
//! answer after the dispatcher walked away reply into a dropped channel.
//!
//! **Partial results.** A shard whose whole group is down does not hang
//! or fail the query: the merge returns whatever shards answered, with
//! coverage disclosed on the reply ([`RouterReply`]:
//! `shards_answered`/`shards_total`, `coverage_fraction()`,
//! `degraded: true`) and counted in [`Metrics`] (`partial_replies`,
//! `hedge_fires`, per-shard answer-p99 gauges). The routed server path
//! carries the same fields on every response.
//!
//! **Per-replica breakers.** Each member has a PR 6-style circuit
//! breaker: consecutive dispatch failures (timeouts, crashed workers)
//! trip it Open, a cooldown later the next dispatch is the half-open
//! probe, success re-closes. Tripped members are skipped by
//! primary/backup picks, so a flapping replica sheds its own traffic
//! without dragging the shard down.
//!
//! **Scrubbing.** A background scrubber
//! ([`ShardedRouter::spawn_scrubber`], or [`ShardedRouter::scrub_now`]
//! synchronously) checksum-walks every file-backed member's `V5Checked`
//! sections via [`crate::index::open_mmap_verified`] on a budgeted
//! cadence. A member whose file fails is quarantined (a breaker state
//! only repair clears), repaired — re-opened from the surviving on-disk
//! generation if it verifies, else rebuilt from a healthy peer's items
//! under the member's own seed and re-verified — then re-admitted
//! through its breaker. Faults for all of this are injectable per
//! member with [`ShardFaultPlan`] (stall windows, crash-on-query,
//! crash-on-write, on-disk corruption bursts).
//!
//! # Replicated durable writes
//!
//! **Write fan-out and quorum.** Live replica groups
//! ([`ShardedRouter::create_live_replicated`]: every member a
//! [`crate::index::LiveIndex`] over the shard's rows, modulo-sharded by
//! external id) accept mutations through the router:
//! [`ShardedRouter::upsert`] / [`ShardedRouter::delete`] /
//! [`ShardedRouter::upsert_batch`] route by `id % n_shards` to the
//! owning shard and replicate the mutation to **every** group member as
//! a WAL record. Each group maintains one logical mutation log:
//! under the shard's write lock the router assigns the record the next
//! **group sequence number** (the most advanced healthy member's
//! high-water + 1), and each member appends it to its own WAL at
//! exactly that sequence — a member that cannot (it missed a write and
//! has a sequence gap) refuses the record instead of silently forking
//! history. The write acknowledges once
//! [`ReplicaConfig::write_quorum`] members (default: majority,
//! `R/2 + 1`) have durably logged **and** applied it; fewer acks fail
//! the write with a typed [`QuorumFailed`]. A quorum-satisfying write
//! that still missed some member reports `degraded` on its
//! [`WriteReply`] (`write_degraded` on the wire) so clients know a
//! catch-up is owed. Batches replicate as **one** WAL record per owning
//! shard: atomic per shard, all-or-nothing across replicas.
//!
//! **Divergence detection and catch-up.** Replicas compare two cheap
//! facts: the WAL high-water mark (equal marks ⇒ equal applied
//! history, because sequence assignment is gap-free) and a
//! seed-independent state checksum
//! ([`crate::index::LiveIndex::state_checksum`], XXH64 over the sorted
//! live `(id, vector)` set — comparable across members even though
//! their hash seeds differ). The scrubber's live pass
//! ([`ShardedRouter::scrub_now`]) exchanges both under the shard's
//! write lock, quarantines any lagging or disagreeing member, and then
//! repairs it with [`ShardedRouter::catch_up`]: re-open from disk
//! (replays the member's own WAL, truncates torn tails, sweeps orphan
//! temp/generation files), then **replay the missing WAL suffix** from
//! the most advanced healthy peer ([`crate::index::Wal::read_suffix`]).
//! When the donor has compacted past the suffix — its WAL restarts at a
//! base sequence beyond the gap — the member instead does a **full
//! rebuild** from the donor's live item set under its own seed
//! ([`CatchUpMode::Rebuilt`], counted as a repair; replays count as
//! `catch_up_replays`). Either way convergence is verified (high-water
//! equality + state checksum) before the engine swaps into the serving
//! slot and the member re-admits through its breaker.
//!
//! **Write backpressure.** A mutation is refused *before* sequence
//! assignment when any serving member's delta is at its cap
//! ([`crate::index::LiveConfig::delta_cap`]), with a typed
//! [`crate::index::WriteStalled`] carrying a `retry_after_ms` hint
//! derived from recent compaction time — `code: "write_stalled"` on the
//! wire; stalls therefore never diverge replicas. Compaction is paced
//! by [`MipsEngine::spawn_adaptive_compactor`]: size-tiered triggers
//! (pending work ≥ a fraction of the base) gated on the recent reader
//! probe p99 from the stage histograms, with a relief valve that
//! compacts unconditionally as the delta nears the cap.
//!
//! # Observability: end-to-end query tracing
//!
//! Answering "*why was this query slow?*" takes more than a total-latency
//! histogram. Every query now carries a [`QuerySpans`] — a fixed-size,
//! heap-free record of per-stage wall time — through its whole server-side
//! life, attributed at these stages ([`Stage`]):
//!
//! ```text
//! admission_wait → queue_wait → batch_assembly → hash → probe → rerank
//!                  shard_wait → merge                      (routed path)
//!                  reply_write                              (socket path)
//! ```
//!
//! Each stage is timed exactly once, by the component that measures it:
//! the batcher stamps admission/queue/assembly/hash, the engine stamps
//! probe/rerank (plus candidate-flow counts), the router stamps
//! shard_wait/merge and absorbs the winning replica's probe/rerank, and
//! the connection loop stamps reply_write after the bytes hit the
//! socket. The same values feed per-stage [`LatencyHist`]s in
//! [`Metrics`], so the `metrics` command reports stage p50/p99 without
//! any sampling enabled.
//!
//! **Span capture.** [`TraceRecorder`] (one per [`Metrics`]) holds two
//! lock-free seqlock rings: a *sampled* ring fed 1-in-N
//! (`sample_every`), and a *slow-query log* that captures **every**
//! query whose total exceeds `slow_threshold_us` (marked
//! `FLAG_SLOW`, with `dominant_stage` naming the guilty stage). Both
//! default **off**; the `trace` command flips them at runtime and
//! drains the sampled ring, `slowlog` drains the slow ring. Writers
//! never block and never allocate — with both knobs off an offer is
//! three relaxed atomic ops, so the hot path keeps its zero-allocation
//! contract (enforced by the `zero_alloc` test and the serve
//! benchmark's overhead ratchet: ≤5% p99 at 1-in-100 sampling).
//!
//! **Exposition.** `metrics` (JSON, now with a `stages` breakdown and
//! candidate-flow counters), `metrics_prom` (Prometheus text format
//! 0.0.4: counters, gauges, the full latency histogram with cumulative
//! buckets, and per-stage quantile summaries), `trace`, and `slowlog`
//! are served inline on both front ends. Every query reply echoes its
//! `trace_id` (client-supplied or server-assigned) so client logs join
//! against captured spans; see [`server`] docs for the wire contract.

pub mod admission;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod replica;
pub mod router;
pub mod server;
pub mod trace;

pub use admission::{AdmissionConfig, LoadController, ServeError};
pub use batcher::{
    BatcherConfig, BatcherHandle, BreakerState, FaultPlan, PjrtBatcher, QueryReply,
};
pub use engine::{AdaptiveCompactionConfig, MipsEngine};
pub use metrics::{LatencyHist, Metrics, MetricsSnapshot};
pub use replica::{
    corrupt_index_file, QuorumFailed, ReplicaConfig, ReplicaStorage, ShardFaultPlan,
};
pub use router::{
    CatchUpMode, CatchUpReport, RouterReply, ScrubReport, ShardedRouter, WriteReply,
};
pub use server::{
    handle_request, handle_router_request, serve, serve_on, serve_router_on, ServeConfig,
};
pub use trace::{QuerySpans, Stage, TraceRecorder, TraceStats};

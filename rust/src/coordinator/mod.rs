//! Layer-3 coordinator: the MIPS serving system.
//!
//! Shape (vLLM-router-like, scaled to this paper):
//!
//! ```text
//!  TCP/JSON clients ──► server ──► dynamic batcher ──► PJRT worker thread
//!                                        │                (hash artifact)
//!                                        ▼
//!                              per-query bucket probes ──► exact rerank
//!                                        │
//!  sharded corpora:  router ──► shard engines ──► scatter/gather merge
//! ```
//!
//! Python never appears here: hashing runs through the AOT artifacts via
//! PJRT on a dedicated worker thread (PJRT handles are not `Send`), and
//! table probing + reranking are pure Rust. Concurrency is std threads +
//! channels (the offline build has no async runtime; see Cargo.toml).

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{BatcherConfig, BatcherHandle, PjrtBatcher};
pub use engine::MipsEngine;
pub use metrics::{Metrics, MetricsSnapshot};
pub use router::ShardedRouter;
pub use server::{serve, serve_on, ServeConfig};

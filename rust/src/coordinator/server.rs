//! JSON-lines TCP front end (std::net + a thread per connection).
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! → {"vector": [0.1, ...], "top_k": 10}
//! ← {"ok": true, "items": [5, 2], "scores": [1.9, 1.2], "latency_us": 830}
//! → {"cmd": "metrics"}
//! ← {"ok": true, "metrics": {...}}
//! → {"cmd": "ping"}
//! ← {"ok": true}
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Instant;

use crate::util::json::{num_arr, obj, Json};

use super::batcher::BatcherHandle;
use super::engine::MipsEngine;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub addr: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { addr: "127.0.0.1:7878".into() }
    }
}

fn err_response(msg: impl Into<String>) -> Json {
    obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.into()))])
}

/// Handle one JSON-lines request string. Pure function over the request
/// text — directly unit/integration testable without sockets.
pub fn handle_request(line: &str, handle: &BatcherHandle, engine: &Arc<MipsEngine>) -> Json {
    let req = match Json::parse(line) {
        Ok(r) => r,
        Err(e) => return err_response(format!("bad request: {e}")),
    };
    match req.get("cmd").and_then(Json::as_str) {
        Some("ping") => obj(vec![("ok", Json::Bool(true))]),
        Some("metrics") => {
            let s = engine.metrics().snapshot();
            obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "metrics",
                    obj(vec![
                        ("queries", Json::Num(s.queries as f64)),
                        ("batches", Json::Num(s.batches as f64)),
                        ("batched_queries", Json::Num(s.batched_queries as f64)),
                        ("candidates", Json::Num(s.candidates as f64)),
                        ("errors", Json::Num(s.errors as f64)),
                        ("mean_latency_us", Json::Num(s.mean_latency_us)),
                        ("p50_latency_us", Json::Num(s.p50_latency_us as f64)),
                        ("p99_latency_us", Json::Num(s.p99_latency_us as f64)),
                        ("mean_batch_size", Json::Num(s.mean_batch_size())),
                    ]),
                ),
            ])
        }
        Some(other) => err_response(format!("unknown cmd {other:?}")),
        None => {
            let Some(vector) = req.get("vector").and_then(Json::as_f32_vec) else {
                return err_response("missing or malformed vector");
            };
            if vector.len() != engine.index().dim() {
                return err_response(format!(
                    "vector dim {} != index dim {}",
                    vector.len(),
                    engine.index().dim()
                ));
            }
            let top_k = req.get("top_k").and_then(Json::as_usize).unwrap_or(10);
            let t0 = Instant::now();
            match handle.query(vector, top_k) {
                Ok(hits) => {
                    let ids: Vec<f64> = hits.iter().map(|h| h.id as f64).collect();
                    let scores: Vec<f64> = hits.iter().map(|h| h.score as f64).collect();
                    obj(vec![
                        ("ok", Json::Bool(true)),
                        ("items", num_arr(&ids)),
                        ("scores", num_arr(&scores)),
                        (
                            "latency_us",
                            Json::Num(t0.elapsed().as_micros() as f64),
                        ),
                    ])
                }
                Err(e) => err_response(format!("{e:#}")),
            }
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    handle: BatcherHandle,
    engine: Arc<MipsEngine>,
) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = handle_request(&line, &handle, &engine);
        let mut out = resp.to_string();
        out.push('\n');
        writer.write_all(out.as_bytes())?;
    }
    Ok(())
}

/// Bind `cfg.addr` and serve forever (thread per connection).
pub fn serve(cfg: ServeConfig, handle: BatcherHandle, engine: Arc<MipsEngine>) -> crate::Result<()> {
    let listener = TcpListener::bind(&cfg.addr)?;
    crate::log_info!("serving MIPS on {}", cfg.addr);
    serve_on(listener, handle, engine)
}

/// Accept loop over an existing listener (testable entry point).
pub fn serve_on(
    listener: TcpListener,
    handle: BatcherHandle,
    engine: Arc<MipsEngine>,
) -> crate::Result<()> {
    loop {
        let (stream, peer) = listener.accept()?;
        crate::log_debug!("connection from {peer}");
        let h = handle.clone();
        let e = Arc::clone(&engine);
        std::thread::spawn(move || {
            if let Err(err) = handle_conn(stream, h, e) {
                crate::log_warn!("connection error: {err}");
            }
        });
    }
}

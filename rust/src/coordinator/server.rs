//! JSON-lines TCP front end (std::net + a thread per connection).
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! → {"vector": [0.1, ...], "top_k": 10, "deadline_ms": 250, "trace_id": 7}
//! ← {"ok": true, "items": [5, 2], "scores": [1.9, 1.2], "degraded": false,
//!    "trace_id": 7, "latency_us": 830}
//! → {"cmd": "metrics"}
//! ← {"ok": true, "metrics": {..., "stages": {"hash": {"count": ..., "p50_us": ..., "p99_us": ...}, ...}}}
//! → {"cmd": "metrics_prom"}
//! ← {"ok": true, "content_type": "text/plain; version=0.0.4", "body": "# HELP ..."}
//! → {"cmd": "trace", "sample_every": 100, "slow_threshold_us": 20000}
//! ← {"ok": true, "sample_every": 100, ..., "spans": [{...}, ...]}
//! → {"cmd": "slowlog"}
//! ← {"ok": true, "slow_threshold_us": 20000, "spans": [{...}, ...]}
//! → {"cmd": "ping"}
//! ← {"ok": true}
//! → {"cmd": "upsert", "id": 42, "vector": [0.1, ...]}
//! ← {"ok": true, "n_items": 1001}
//! → {"cmd": "upsert_batch", "ids": [7, 8], "vectors": [[...], [...]]}
//! ← {"ok": true, "n_items": 1003, "count": 2}
//! → {"cmd": "delete", "id": 42}
//! ← {"ok": true, "n_items": 1000}
//! ```
//!
//! On the routed front end the same three mutation commands replicate
//! instead (see below) and answer acknowledgement accounting:
//!
//! ```text
//! → {"cmd": "upsert", "id": 42, "vector": [0.1, ...]}
//! ← {"ok": true, "seq": 17, "shard": 0, "acked": 3, "replicas": 3,
//!    "write_degraded": false}
//! ← {"ok": false, "code": "write_stalled", "error": "...", "pending": 1048576,
//!    "cap": 1048576, "retry_after_ms": 40}
//! ```
//!
//! `upsert`/`delete` mutate a live engine ([`MipsEngine::open_live`]):
//! the WAL append is durable before the `ok` line is written, and the
//! new state is visible to every query admitted afterwards.
//! `upsert_batch` group-commits the whole batch — one WAL record batch,
//! one fsync ([`crate::index::LiveIndex::upsert_batch`]) — and is
//! validated in full before any byte is logged, so a rejected batch
//! mutates nothing. Against a frozen engine the mutation commands
//! answer `invalid_argument`. The `metrics`
//! command additionally reports the live-tier gauges (`delta_items`,
//! `tombstones`, `compactions`, `wal_bytes`, `last_compaction_ms` — all
//! zero on a frozen engine).
//!
//! Every failure is a structured `{"ok": false, "code": ..., "error": ...}`
//! line — `invalid_argument` (malformed/non-finite vector, bad `top_k`,
//! bad `deadline_ms`, oversized line), `deadline_exceeded`, `overloaded`,
//! or `internal` — and never kills the connection: the offending line is
//! consumed (oversized lines are discarded to the next newline) and the
//! connection keeps serving. `ping`, `metrics`, `metrics_prom`, `trace`,
//! and `slowlog` are answered inline on the connection thread, never
//! through the batcher queue, so health checks and trace drains stay
//! responsive while queries are being shed.
//!
//! **Tracing.** A query may carry a client `trace_id` (non-negative
//! integer ≤ 2^53); the server assigns one otherwise. The id is echoed
//! byte-for-byte on the reply — success *and* every error past request
//! parsing — so a client log line can always be joined against the
//! server's sampled spans and slow-query log (see
//! [`super::trace::TraceRecorder`]). Both knobs default off; the `trace`
//! command turns them on at runtime.
//!
//! The **routed** front end ([`serve_router_on`] /
//! [`handle_router_request`]) serves a replicated [`ShardedRouter`]
//! instead of a single engine: queries run through the hedged
//! scatter/gather and every response discloses coverage
//! (`shards_answered`, `shards_total`, `coverage_fraction`, `degraded`,
//! `hedge_fired`); its `metrics` command reports hedge/partial/scrub
//! counters, write-replication counters, per-shard p99 gauges, and
//! per-member breaker states. Routed `upsert`/`delete`/`upsert_batch`
//! fan the mutation out to every member of the owning shard's replica
//! group and acknowledge at write quorum
//! ([`ShardedRouter::upsert`]); success replies carry `{seq, shard,
//! acked, replicas, write_degraded}`, backpressure answers
//! `code: "write_stalled"` with a `retry_after_ms` hint, and a fan-out
//! that misses quorum answers `code: "quorum_failed"`.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Instant;

use crate::index::{LiveStorage, ProbeBudget, WriteStalled};
use crate::util::json::{num_arr, obj, Json};

use super::admission::{deadline_expired, triage_deadline_ms};
use super::batcher::{BatcherHandle, BreakerState};
use super::engine::MipsEngine;
use super::metrics::{Metrics, MetricsSnapshot};
use super::replica::QuorumFailed;
use super::router::{ShardedRouter, WriteReply};
use super::trace::{QuerySpans, Stage};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub addr: String,
    /// Longest accepted request line in bytes; longer lines get a
    /// structured error and are discarded without killing the connection.
    pub max_line_len: usize,
    /// Largest accepted `top_k` (absurd values are client mistakes, and
    /// each admitted `top_k` costs rerank heap work).
    pub max_top_k: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { addr: "127.0.0.1:7878".into(), max_line_len: 1 << 20, max_top_k: 1024 }
    }
}

fn err_response(code: &str, msg: impl Into<String>) -> Json {
    obj(vec![
        ("ok", Json::Bool(false)),
        ("code", Json::Str(code.into())),
        ("error", Json::Str(msg.into())),
    ])
}

/// A handler's answer plus deferred span finalisation: when a query
/// produced a [`QuerySpans`], the connection loop times the reply write
/// ([`Stage::ReplyWrite`]) before offering the span to the recorder, so
/// captured traces account for the full server-side lifetime. The
/// socketless wrappers ([`handle_request`], [`handle_router_request`])
/// offer inline instead — no write to measure.
struct TracedResponse {
    resp: Json,
    finish: Option<(Arc<Metrics>, QuerySpans)>,
}

impl TracedResponse {
    fn plain(resp: Json) -> Self {
        Self { resp, finish: None }
    }

    fn finish_inline(self) -> Json {
        if let Some((metrics, spans)) = self.finish {
            metrics.tracer.offer(&spans);
        }
        self.resp
    }
}

/// Echo the client's (or server-assigned) trace id on a response.
fn with_trace_id(mut resp: Json, trace_id: u64) -> Json {
    if let Json::Obj(map) = &mut resp {
        map.insert("trace_id".to_string(), Json::Num(trace_id as f64));
    }
    resp
}

/// The optional `trace_id` request field. Absent is fine — the server
/// assigns one. Present, it must be a non-negative integer no larger
/// than 2^53, the range a JSON double echoes byte-for-byte. `Err` is
/// the ready-to-send error response.
fn parse_trace_id(req: &Json) -> Result<Option<u64>, Json> {
    const MAX_TRACE_ID: f64 = 9_007_199_254_740_992.0; // 2^53
    match req.get("trace_id") {
        None => Ok(None),
        Some(v) => match v.as_f64() {
            Some(t) if t.is_finite() && t >= 0.0 && t.fract() == 0.0 && t <= MAX_TRACE_ID => {
                Ok(Some(t as u64))
            }
            _ => Err(err_response(
                "invalid_argument",
                "trace_id must be a non-negative integer no larger than 2^53",
            )),
        },
    }
}

/// Length/parse validation shared by both front ends. `Err` is the
/// ready-to-send error response.
fn parse_line(line: &str, cfg: &ServeConfig) -> Result<Json, Json> {
    if line.len() > cfg.max_line_len {
        return Err(err_response(
            "invalid_argument",
            format!("request line exceeds {} bytes", cfg.max_line_len),
        ));
    }
    Json::parse(line).map_err(|e| err_response("invalid_argument", format!("bad request: {e}")))
}

/// The `trace` command, shared by both front ends: optionally
/// reconfigure the recorder (`sample_every` — 0 disables sampling;
/// `slow_threshold_us` — 0 disables the slow log), then report recorder
/// stats and drain the sampled ring.
fn handle_trace_cmd(req: &Json, metrics: &Metrics) -> Json {
    if let Some(v) = req.get("sample_every") {
        let Some(n) = v.as_usize() else {
            return err_response(
                "invalid_argument",
                "sample_every must be a non-negative integer (0 disables sampling)",
            );
        };
        metrics.tracer.set_sample_every(n as u64);
    }
    if let Some(v) = req.get("slow_threshold_us") {
        let Some(n) = v.as_usize() else {
            return err_response(
                "invalid_argument",
                "slow_threshold_us must be a non-negative integer (0 disables the slow log)",
            );
        };
        metrics.tracer.set_slow_threshold_us(n as u64);
    }
    let stats = metrics.tracer.stats();
    let spans: Vec<Json> =
        metrics.tracer.drain_sampled().iter().map(QuerySpans::to_json).collect();
    obj(vec![
        ("ok", Json::Bool(true)),
        ("sample_every", Json::Num(metrics.tracer.sample_every() as f64)),
        ("slow_threshold_us", Json::Num(metrics.tracer.slow_threshold_us() as f64)),
        ("seen", Json::Num(stats.seen as f64)),
        ("sampled", Json::Num(stats.sampled as f64)),
        ("slow_captured", Json::Num(stats.slow_captured as f64)),
        ("spans", Json::Arr(spans)),
    ])
}

/// The `slowlog` command: drain every span the always-on slow-query
/// ring captured since the last drain.
fn handle_slowlog_cmd(metrics: &Metrics) -> Json {
    let spans: Vec<Json> = metrics.tracer.drain_slow().iter().map(QuerySpans::to_json).collect();
    obj(vec![
        ("ok", Json::Bool(true)),
        ("slow_threshold_us", Json::Num(metrics.tracer.slow_threshold_us() as f64)),
        ("spans", Json::Arr(spans)),
    ])
}

/// The `metrics_prom` command: the full snapshot in Prometheus text
/// exposition format 0.0.4, carried in the JSON-lines envelope.
fn metrics_prom_response(body: String) -> Json {
    obj(vec![
        ("ok", Json::Bool(true)),
        ("content_type", Json::Str("text/plain; version=0.0.4".into())),
        ("body", Json::Str(body)),
    ])
}

/// Router-only gauges appended to the routed Prometheus body, so every
/// family the routed `metrics` command reports has an exposition
/// counterpart (asserted in `tests/replicated_writes.rs`).
fn router_prom_extras<S: LiveStorage>(router: &ShardedRouter<S>, body: &mut String) {
    use std::fmt::Write as _;
    let _ = writeln!(body, "# HELP alsh_shard_answer_p99_us Per-shard answer latency p99.");
    let _ = writeln!(body, "# TYPE alsh_shard_answer_p99_us gauge");
    for (s, v) in router.shard_p99_us().iter().enumerate() {
        let _ = writeln!(body, "alsh_shard_answer_p99_us{{shard=\"{s}\"}} {v}");
    }
    let _ = writeln!(
        body,
        "# HELP alsh_replica_breaker_state Member breaker state (0 closed, 1 half-open, 2 open, 3 quarantined)."
    );
    let _ = writeln!(body, "# TYPE alsh_replica_breaker_state gauge");
    for (s, g) in router.breaker_states().into_iter().enumerate() {
        for (r, b) in g.into_iter().enumerate() {
            let code = match b.as_str() {
                "closed" => 0,
                "half_open" => 1,
                "open" => 2,
                _ => 3,
            };
            let _ = writeln!(
                body,
                "alsh_replica_breaker_state{{shard=\"{s}\",member=\"{r}\"}} {code}"
            );
        }
    }
}

/// Per-stage `{count, p50_us, p99_us}` breakdown for the `metrics`
/// command. Stages a deployment never exercises report zero counts.
fn stages_json(s: &MetricsSnapshot) -> Json {
    obj(Stage::ALL
        .iter()
        .map(|&st| {
            (
                st.name(),
                obj(vec![
                    ("count", Json::Num(s.stage_count(st) as f64)),
                    ("p50_us", Json::Num(s.stage_percentile_us(st, 0.5) as f64)),
                    ("p99_us", Json::Num(s.stage_percentile_us(st, 0.99) as f64)),
                ]),
            )
        })
        .collect())
}

/// Handle one JSON-lines request string. Pure function over the request
/// text — directly unit/integration testable without sockets. Spans
/// produced by query lines are offered to the trace recorder inline
/// (the socket path defers them past the reply write instead).
pub fn handle_request(
    line: &str,
    handle: &BatcherHandle,
    engine: &Arc<MipsEngine>,
    cfg: &ServeConfig,
) -> Json {
    handle_request_full(line, handle, engine, cfg).finish_inline()
}

fn handle_request_full(
    line: &str,
    handle: &BatcherHandle,
    engine: &Arc<MipsEngine>,
    cfg: &ServeConfig,
) -> TracedResponse {
    let req = match parse_line(line, cfg) {
        Ok(r) => r,
        Err(resp) => return TracedResponse::plain(resp),
    };
    match req.get("cmd").and_then(Json::as_str) {
        Some(cmd) => TracedResponse::plain(handle_engine_cmd(cmd, &req, handle, engine)),
        None => handle_engine_query(&req, handle, engine, cfg),
    }
}

fn handle_engine_cmd(
    cmd: &str,
    req: &Json,
    handle: &BatcherHandle,
    engine: &Arc<MipsEngine>,
) -> Json {
    match cmd {
        "ping" => obj(vec![("ok", Json::Bool(true))]),
        "trace" => handle_trace_cmd(req, handle.metrics()),
        "slowlog" => handle_slowlog_cmd(handle.metrics()),
        "metrics_prom" => metrics_prom_response(engine.metrics_snapshot().prometheus_text()),
        "metrics" => {
            let s = engine.metrics_snapshot();
            let breaker = match handle.breaker_state() {
                BreakerState::Closed => "closed",
                BreakerState::Open => "open",
                BreakerState::HalfOpen => "half_open",
            };
            obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "metrics",
                    obj(vec![
                        ("queries", Json::Num(s.queries as f64)),
                        ("batches", Json::Num(s.batches as f64)),
                        ("batched_queries", Json::Num(s.batched_queries as f64)),
                        ("candidates", Json::Num(s.candidates as f64)),
                        ("errors", Json::Num(s.errors as f64)),
                        ("shed", Json::Num(s.shed as f64)),
                        ("deadline_exceeded", Json::Num(s.deadline_exceeded as f64)),
                        ("degraded_queries", Json::Num(s.degraded_queries as f64)),
                        ("pjrt_fallbacks", Json::Num(s.pjrt_fallbacks as f64)),
                        ("queue_depth", Json::Num(s.queue_depth as f64)),
                        ("delta_items", Json::Num(s.delta_items as f64)),
                        ("tombstones", Json::Num(s.tombstones as f64)),
                        ("compactions", Json::Num(s.compactions as f64)),
                        ("wal_bytes", Json::Num(s.wal_bytes as f64)),
                        ("last_compaction_ms", Json::Num(s.last_compaction_ms as f64)),
                        ("load_level", Json::Num(handle.level() as f64)),
                        ("breaker", Json::Str(breaker.into())),
                        ("mean_latency_us", Json::Num(s.mean_latency_us)),
                        ("p50_latency_us", Json::Num(s.p50_latency_us as f64)),
                        ("p99_latency_us", Json::Num(s.p99_latency_us as f64)),
                        ("mean_batch_size", Json::Num(s.mean_batch_size())),
                        ("candidates_probed", Json::Num(s.candidates_probed as f64)),
                        ("candidates_reranked", Json::Num(s.candidates_reranked as f64)),
                        ("stages", stages_json(&s)),
                    ]),
                ),
            ])
        }
        "upsert" => {
            let Some(id) = parse_ext_id(req) else {
                return err_response("invalid_argument", "id must be an integer in u32 range");
            };
            let Some(vector) = req.get("vector").and_then(Json::as_f32_vec) else {
                return err_response("invalid_argument", "missing or malformed vector");
            };
            if vector.iter().any(|v| !v.is_finite()) {
                return err_response("invalid_argument", "vector contains non-finite components");
            }
            if vector.len() != engine.dim() {
                return err_response(
                    "invalid_argument",
                    format!("vector dim {} != index dim {}", vector.len(), engine.dim()),
                );
            }
            if !engine.is_live() {
                return err_response(
                    "invalid_argument",
                    "engine serves a frozen index; upsert requires a live index",
                );
            }
            match engine.upsert(id, &vector) {
                Ok(()) => obj(vec![
                    ("ok", Json::Bool(true)),
                    ("n_items", Json::Num(engine.n_items() as f64)),
                ]),
                Err(e) => err_response("internal", format!("upsert failed: {e:#}")),
            }
        }
        "delete" => {
            let Some(id) = parse_ext_id(req) else {
                return err_response("invalid_argument", "id must be an integer in u32 range");
            };
            if !engine.is_live() {
                return err_response(
                    "invalid_argument",
                    "engine serves a frozen index; delete requires a live index",
                );
            }
            match engine.delete(id) {
                Ok(()) => obj(vec![
                    ("ok", Json::Bool(true)),
                    ("n_items", Json::Num(engine.n_items() as f64)),
                ]),
                Err(e) => err_response("internal", format!("delete failed: {e:#}")),
            }
        }
        "upsert_batch" => {
            let Some(ids) = req.get("ids").and_then(Json::as_arr) else {
                return err_response("invalid_argument", "missing or malformed ids array");
            };
            let Some(vectors) = req.get("vectors").and_then(Json::as_arr) else {
                return err_response("invalid_argument", "missing or malformed vectors array");
            };
            if ids.is_empty() || ids.len() != vectors.len() {
                return err_response(
                    "invalid_argument",
                    format!(
                        "ids ({}) and vectors ({}) must be equal-length and non-empty",
                        ids.len(),
                        vectors.len()
                    ),
                );
            }
            if !engine.is_live() {
                return err_response(
                    "invalid_argument",
                    "engine serves a frozen index; upsert_batch requires a live index",
                );
            }
            // Validate the whole batch before touching the WAL, so a
            // rejected batch leaves no partial mutation behind.
            let mut entries = Vec::with_capacity(ids.len());
            for (i, (id, vec)) in ids.iter().zip(vectors).enumerate() {
                let Some(id) = id.as_usize().and_then(|v| u32::try_from(v).ok()) else {
                    return err_response(
                        "invalid_argument",
                        format!("ids[{i}] must be an integer in u32 range"),
                    );
                };
                let Some(vector) = vec.as_f32_vec() else {
                    return err_response(
                        "invalid_argument",
                        format!("vectors[{i}] is missing or malformed"),
                    );
                };
                if vector.iter().any(|v| !v.is_finite()) {
                    return err_response(
                        "invalid_argument",
                        format!("vectors[{i}] contains non-finite components"),
                    );
                }
                if vector.len() != engine.dim() {
                    return err_response(
                        "invalid_argument",
                        format!(
                            "vectors[{i}] dim {} != index dim {}",
                            vector.len(),
                            engine.dim()
                        ),
                    );
                }
                entries.push((id, vector));
            }
            match engine.upsert_batch(&entries) {
                Ok(()) => obj(vec![
                    ("ok", Json::Bool(true)),
                    ("n_items", Json::Num(engine.n_items() as f64)),
                    ("count", Json::Num(entries.len() as f64)),
                ]),
                Err(e) => err_response("internal", format!("upsert_batch failed: {e:#}")),
            }
        }
        other => err_response("invalid_argument", format!("unknown cmd {other:?}")),
    }
}

/// The engine-server query line: parse the trace id first so every
/// later rejection can echo it, run through the batcher's traced path,
/// and hand the filled spans back for reply-write timing.
fn handle_engine_query(
    req: &Json,
    handle: &BatcherHandle,
    engine: &Arc<MipsEngine>,
    cfg: &ServeConfig,
) -> TracedResponse {
    let trace_id = match parse_trace_id(req) {
        Ok(t) => t,
        Err(resp) => return TracedResponse::plain(resp),
    };
    let tid = trace_id.unwrap_or_else(|| handle.metrics().tracer.next_trace_id());
    let (vector, top_k, deadline) = match parse_query(req, engine.dim(), cfg) {
        Ok(parts) => parts,
        Err(resp) => return TracedResponse::plain(with_trace_id(resp, tid)),
    };
    let t0 = Instant::now();
    match handle.query_traced(vector, top_k, deadline, Some(tid)) {
        Ok(reply) => {
            let ids: Vec<f64> = reply.hits.iter().map(|h| h.id as f64).collect();
            let scores: Vec<f64> = reply.hits.iter().map(|h| h.score as f64).collect();
            let resp = obj(vec![
                ("ok", Json::Bool(true)),
                ("items", num_arr(&ids)),
                ("scores", num_arr(&scores)),
                ("degraded", Json::Bool(reply.degraded)),
                ("trace_id", Json::Num(reply.trace_id as f64)),
                ("latency_us", Json::Num(t0.elapsed().as_micros() as f64)),
            ]);
            TracedResponse { resp, finish: Some((Arc::clone(handle.metrics()), reply.spans)) }
        }
        Err(e) => TracedResponse::plain(with_trace_id(err_response(e.code(), e.message()), tid)),
    }
}

/// Handle one JSON-lines request against a replicated router — the
/// routed analogue of [`handle_request`]. Queries run through
/// [`ShardedRouter::query_replicated`] (hedged scatter/gather, per-shard
/// timeouts), and every query response carries the coverage fields
/// (`shards_answered`, `shards_total`, `coverage_fraction`, `degraded`,
/// `hedge_fired`) so a client can always tell a full answer from a
/// partial one. Mutations route by id to the owning shard and replicate
/// to every group member with quorum acknowledgement
/// ([`ShardedRouter::upsert`]); against frozen replica groups they
/// answer `internal` (no live member to replicate to). The `metrics`
/// command reports the router counters: hedge fires, partial replies,
/// scrub quarantines/repairs, write-replication counters, live-tier
/// gauges, per-shard answer-p99 gauges, and per-member breaker states.
pub fn handle_router_request<S: LiveStorage>(
    line: &str,
    router: &ShardedRouter<S>,
    cfg: &ServeConfig,
) -> Json {
    handle_router_request_full(line, router, cfg).finish_inline()
}

fn handle_router_request_full<S: LiveStorage>(
    line: &str,
    router: &ShardedRouter<S>,
    cfg: &ServeConfig,
) -> TracedResponse {
    let req = match parse_line(line, cfg) {
        Ok(r) => r,
        Err(resp) => return TracedResponse::plain(resp),
    };
    match req.get("cmd").and_then(Json::as_str) {
        Some(cmd) => TracedResponse::plain(handle_router_cmd(cmd, &req, router)),
        None => handle_router_query(&req, router, cfg),
    }
}

fn handle_router_cmd<S: LiveStorage>(cmd: &str, req: &Json, router: &ShardedRouter<S>) -> Json {
    match cmd {
        "ping" => obj(vec![("ok", Json::Bool(true))]),
        "trace" => handle_trace_cmd(req, &router.metrics()),
        "slowlog" => handle_slowlog_cmd(&router.metrics()),
        "metrics_prom" => {
            router.sync_live_gauges();
            let mut body = router.metrics().snapshot().prometheus_text();
            router_prom_extras(router, &mut body);
            metrics_prom_response(body)
        }
        "metrics" => {
            router.sync_live_gauges();
            let s = router.metrics().snapshot();
            let shard_p99: Vec<f64> =
                router.shard_p99_us().iter().map(|&v| v as f64).collect();
            let breakers: Vec<Json> = router
                .breaker_states()
                .into_iter()
                .map(|g| {
                    Json::Arr(g.into_iter().map(|b| Json::Str(b.as_str().into())).collect())
                })
                .collect();
            obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "metrics",
                    obj(vec![
                        ("queries", Json::Num(s.queries as f64)),
                        ("hedge_fires", Json::Num(s.hedge_fires as f64)),
                        ("partial_replies", Json::Num(s.partial_replies as f64)),
                        ("replica_quarantines", Json::Num(s.replica_quarantines as f64)),
                        ("replica_repairs", Json::Num(s.replica_repairs as f64)),
                        ("writes_replicated", Json::Num(s.writes_replicated as f64)),
                        ("write_stalled", Json::Num(s.write_stalled as f64)),
                        ("quorum_failures", Json::Num(s.quorum_failures as f64)),
                        ("catch_up_replays", Json::Num(s.catch_up_replays as f64)),
                        ("delta_items", Json::Num(s.delta_items as f64)),
                        ("tombstones", Json::Num(s.tombstones as f64)),
                        ("compactions", Json::Num(s.compactions as f64)),
                        ("wal_bytes", Json::Num(s.wal_bytes as f64)),
                        ("last_compaction_ms", Json::Num(s.last_compaction_ms as f64)),
                        ("p50_latency_us", Json::Num(s.p50_latency_us as f64)),
                        ("p99_latency_us", Json::Num(s.p99_latency_us as f64)),
                        ("shard_p99_us", num_arr(&shard_p99)),
                        ("breakers", Json::Arr(breakers)),
                        ("candidates_probed", Json::Num(s.candidates_probed as f64)),
                        ("candidates_reranked", Json::Num(s.candidates_reranked as f64)),
                        ("stages", stages_json(&s)),
                    ]),
                ),
            ])
        }
        "upsert" => {
            let Some(id) = parse_ext_id(req) else {
                return err_response("invalid_argument", "id must be an integer in u32 range");
            };
            let vector = match parse_mutation_vector(req.get("vector"), router.dim()) {
                Ok(v) => v,
                Err(resp) => return resp,
            };
            match router.upsert(id, &vector) {
                Ok(r) => write_ok_response(&r),
                Err(e) => write_err_response(&e, "upsert"),
            }
        }
        "delete" => {
            let Some(id) = parse_ext_id(req) else {
                return err_response("invalid_argument", "id must be an integer in u32 range");
            };
            match router.delete(id) {
                Ok(r) => write_ok_response(&r),
                Err(e) => write_err_response(&e, "delete"),
            }
        }
        "upsert_batch" => {
            let Some(ids) = req.get("ids").and_then(Json::as_arr) else {
                return err_response("invalid_argument", "missing or malformed ids array");
            };
            let Some(vectors) = req.get("vectors").and_then(Json::as_arr) else {
                return err_response("invalid_argument", "missing or malformed vectors array");
            };
            if ids.is_empty() || ids.len() != vectors.len() {
                return err_response(
                    "invalid_argument",
                    format!(
                        "ids ({}) and vectors ({}) must be equal-length and non-empty",
                        ids.len(),
                        vectors.len()
                    ),
                );
            }
            // Validate the whole batch before any shard logs a byte, so
            // a rejected batch mutates nothing anywhere.
            let mut entries = Vec::with_capacity(ids.len());
            for (i, (id, vec)) in ids.iter().zip(vectors).enumerate() {
                let Some(id) = id.as_usize().and_then(|v| u32::try_from(v).ok()) else {
                    return err_response(
                        "invalid_argument",
                        format!("ids[{i}] must be an integer in u32 range"),
                    );
                };
                let vector = match parse_mutation_vector(Some(vec), router.dim()) {
                    Ok(v) => v,
                    Err(_) => {
                        return err_response(
                            "invalid_argument",
                            format!("vectors[{i}] is missing, malformed, or mis-dimensioned"),
                        )
                    }
                };
                entries.push((id, vector));
            }
            match router.upsert_batch(&entries) {
                Ok(replies) => obj(vec![
                    ("ok", Json::Bool(true)),
                    ("count", Json::Num(entries.len() as f64)),
                    ("write_degraded", Json::Bool(replies.iter().any(|r| r.degraded))),
                    ("writes", Json::Arr(replies.iter().map(write_reply_json).collect())),
                ]),
                Err(e) => write_err_response(&e, "upsert_batch"),
            }
        }
        other => err_response("invalid_argument", format!("unknown cmd {other:?}")),
    }
}

/// A mutation command's `vector` field, validated like a query vector
/// (present, all-finite, right dimension). `Err` is the ready-to-send
/// error response.
fn parse_mutation_vector(v: Option<&Json>, dim: usize) -> Result<Vec<f32>, Json> {
    let Some(vector) = v.and_then(Json::as_f32_vec) else {
        return Err(err_response("invalid_argument", "missing or malformed vector"));
    };
    if vector.iter().any(|c| !c.is_finite()) {
        return Err(err_response("invalid_argument", "vector contains non-finite components"));
    }
    if vector.len() != dim {
        return Err(err_response(
            "invalid_argument",
            format!("vector dim {} != index dim {dim}", vector.len()),
        ));
    }
    Ok(vector)
}

/// The per-shard acknowledgement fields of one replicated write.
fn write_reply_json(r: &WriteReply) -> Json {
    obj(vec![
        ("seq", Json::Num(r.seq as f64)),
        ("shard", Json::Num(r.shard as f64)),
        ("acked", Json::Num(r.acked as f64)),
        ("replicas", Json::Num(r.replicas as f64)),
        ("write_degraded", Json::Bool(r.degraded)),
    ])
}

fn write_ok_response(r: &WriteReply) -> Json {
    obj(vec![
        ("ok", Json::Bool(true)),
        ("seq", Json::Num(r.seq as f64)),
        ("shard", Json::Num(r.shard as f64)),
        ("acked", Json::Num(r.acked as f64)),
        ("replicas", Json::Num(r.replicas as f64)),
        ("write_degraded", Json::Bool(r.degraded)),
    ])
}

/// Map a routed write failure onto the wire. Typed stalls answer
/// `write_stalled` and carry the backpressure fields — `retry_after_ms`
/// tells the client when the compactor expects to have drained room —
/// and quorum misses answer `quorum_failed` with the ack arithmetic, so
/// clients can tell "slow down" from "shard unhealthy" without string
/// matching. Everything else is `internal`.
fn write_err_response(e: &anyhow::Error, op: &str) -> Json {
    if let Some(stall) = e.downcast_ref::<WriteStalled>() {
        return obj(vec![
            ("ok", Json::Bool(false)),
            ("code", Json::Str("write_stalled".into())),
            ("error", Json::Str(stall.to_string())),
            ("pending", Json::Num(stall.pending as f64)),
            ("cap", Json::Num(stall.cap as f64)),
            ("retry_after_ms", Json::Num(stall.retry_after_ms as f64)),
        ]);
    }
    if let Some(q) = e.downcast_ref::<QuorumFailed>() {
        return obj(vec![
            ("ok", Json::Bool(false)),
            ("code", Json::Str("quorum_failed".into())),
            ("error", Json::Str(q.to_string())),
            ("acked", Json::Num(q.acked as f64)),
            ("needed", Json::Num(q.needed as f64)),
            ("replicas", Json::Num(q.replicas as f64)),
        ]);
    }
    err_response("internal", format!("{op} failed: {e:#}"))
}

/// The routed query line: same trace-id contract as the engine path,
/// with spans filled by the hedged scatter/gather
/// ([`ShardedRouter::query_replicated_traced`]). A query that blew its
/// deadline mid-gather still hands its spans back — exactly the slow
/// query the slow log exists to explain.
fn handle_router_query<S: LiveStorage>(
    req: &Json,
    router: &ShardedRouter<S>,
    cfg: &ServeConfig,
) -> TracedResponse {
    let trace_id = match parse_trace_id(req) {
        Ok(t) => t,
        Err(resp) => return TracedResponse::plain(resp),
    };
    let metrics = router.metrics();
    let tid = trace_id.unwrap_or_else(|| metrics.tracer.next_trace_id());
    let (vector, top_k, deadline) = match parse_query(req, router.dim(), cfg) {
        Ok(parts) => parts,
        Err(resp) => return TracedResponse::plain(with_trace_id(resp, tid)),
    };
    if deadline_expired(deadline) {
        return TracedResponse::plain(with_trace_id(
            err_response("deadline_exceeded", "deadline expired before dispatch"),
            tid,
        ));
    }
    let t0 = Instant::now();
    let mut spans = QuerySpans::with_id(tid);
    let reply = router.query_replicated_traced(&vector, top_k, ProbeBudget::full(), &mut spans);
    if deadline_expired(deadline) {
        let resp = with_trace_id(
            err_response("deadline_exceeded", "deadline expired during scatter/gather"),
            tid,
        );
        return TracedResponse { resp, finish: Some((metrics, spans)) };
    }
    let ids: Vec<f64> = reply.hits.iter().map(|h| h.id as f64).collect();
    let scores: Vec<f64> = reply.hits.iter().map(|h| h.score as f64).collect();
    let resp = obj(vec![
        ("ok", Json::Bool(true)),
        ("items", num_arr(&ids)),
        ("scores", num_arr(&scores)),
        ("degraded", Json::Bool(reply.degraded)),
        ("shards_answered", Json::Num(reply.shards_answered as f64)),
        ("shards_total", Json::Num(reply.shards_total as f64)),
        ("coverage_fraction", Json::Num(reply.coverage_fraction())),
        ("hedge_fired", Json::Bool(reply.hedge_fired)),
        ("trace_id", Json::Num(tid as f64)),
        ("latency_us", Json::Num(t0.elapsed().as_micros() as f64)),
    ]);
    TracedResponse { resp, finish: Some((metrics, spans)) }
}

/// Validate a query request's `vector`, `top_k`, and `deadline_ms`
/// against the index dimension and the server limits — shared by the
/// batched single-engine path and the routed replica path so both
/// enforce identical request semantics. `Err` is the ready-to-send
/// error response.
fn parse_query(
    req: &Json,
    dim: usize,
    cfg: &ServeConfig,
) -> Result<(Vec<f32>, usize, Option<Instant>), Json> {
    let Some(vector) = req.get("vector").and_then(Json::as_f32_vec) else {
        return Err(err_response("invalid_argument", "missing or malformed vector"));
    };
    // JSON numbers can't spell NaN, but overflow (1e39 → f32 Inf,
    // 1e999 → f64 inf) can still smuggle non-finite components in.
    if vector.iter().any(|v| !v.is_finite()) {
        return Err(err_response(
            "invalid_argument",
            "vector contains non-finite components",
        ));
    }
    if vector.len() != dim {
        return Err(err_response(
            "invalid_argument",
            format!("vector dim {} != index dim {dim}", vector.len()),
        ));
    }
    let top_k = match req.get("top_k") {
        None => 10,
        Some(v) => match v.as_usize() {
            Some(k) if (1..=cfg.max_top_k).contains(&k) => k,
            Some(0) => return Err(err_response("invalid_argument", "top_k must be >= 1")),
            Some(k) => {
                return Err(err_response(
                    "invalid_argument",
                    format!("top_k {k} exceeds max {}", cfg.max_top_k),
                ))
            }
            None => {
                return Err(err_response(
                    "invalid_argument",
                    "top_k must be a positive integer",
                ))
            }
        },
    };
    let deadline = match req.get("deadline_ms") {
        None => None,
        Some(v) => match v.as_f64().map(triage_deadline_ms) {
            Some(Ok(d)) => Some(d),
            Some(Err(e)) => return Err(err_response(e.code(), e.message())),
            None => {
                return Err(err_response(
                    "invalid_argument",
                    "deadline_ms must be a positive finite number of milliseconds",
                ))
            }
        },
    };
    Ok((vector, top_k, deadline))
}

/// The `id` field of a mutation command, if it is an integer that fits
/// an external item id (u32).
fn parse_ext_id(req: &Json) -> Option<u32> {
    let id = req.get("id")?.as_usize()?;
    u32::try_from(id).ok()
}

/// Drop bytes until (and including) the next newline — the tail of an
/// oversized request line. EOF ends the discard.
fn discard_to_newline(reader: &mut BufReader<TcpStream>) -> std::io::Result<()> {
    loop {
        let (n, done) = {
            let avail = reader.fill_buf()?;
            if avail.is_empty() {
                return Ok(());
            }
            match avail.iter().position(|&b| b == b'\n') {
                Some(pos) => (pos + 1, true),
                None => (avail.len(), false),
            }
        };
        reader.consume(n);
        if done {
            return Ok(());
        }
    }
}

fn write_json_line(writer: &mut TcpStream, resp: &Json) -> std::io::Result<()> {
    let mut out = resp.to_string();
    out.push('\n');
    writer.write_all(out.as_bytes())
}

/// One connection's read-dispatch-write loop, generic over the request
/// handler — the single-engine path and the routed replica path differ
/// only in what answers a line. Query spans are finalised here, after
/// the reply hits the socket: the write is timed into
/// [`Stage::ReplyWrite`], added to the span's total, and only then is
/// the span offered to the recorder — so sampled traces and slow-log
/// entries cover the query's full server-side lifetime.
fn conn_loop(
    stream: TcpStream,
    cfg: &ServeConfig,
    mut handle_line: impl FnMut(&str) -> TracedResponse,
) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let cap = cfg.max_line_len as u64;
    let mut buf = Vec::new();
    loop {
        buf.clear();
        // Bounded read: at most max_line_len + 1 bytes buffer per read,
        // however long the client's line is.
        let n = (&mut reader).take(cap + 1).read_until(b'\n', &mut buf)?;
        if n == 0 {
            return Ok(()); // EOF
        }
        if buf.last() != Some(&b'\n') && n as u64 > cap {
            // Oversized line: structured error, discard the tail, keep
            // the connection serving.
            discard_to_newline(&mut reader)?;
            let resp = err_response(
                "invalid_argument",
                format!("request line exceeds {} bytes", cfg.max_line_len),
            );
            write_json_line(&mut writer, &resp)?;
            continue;
        }
        let text = String::from_utf8_lossy(&buf);
        let line = text.trim();
        if line.is_empty() {
            continue;
        }
        let traced = handle_line(line);
        let write_start = Instant::now();
        write_json_line(&mut writer, &traced.resp)?;
        if let Some((metrics, mut spans)) = traced.finish {
            let write_us = write_start.elapsed().as_micros() as u64;
            spans.set_stage(Stage::ReplyWrite, write_us);
            spans.total_us += write_us;
            metrics.record_stage(Stage::ReplyWrite, write_us);
            metrics.tracer.offer(&spans);
        }
    }
}

/// Bind `cfg.addr` and serve forever (thread per connection).
pub fn serve(cfg: ServeConfig, handle: BatcherHandle, engine: Arc<MipsEngine>) -> crate::Result<()> {
    let listener = TcpListener::bind(&cfg.addr)?;
    crate::log_info!("serving MIPS on {}", cfg.addr);
    serve_on(listener, handle, engine, cfg)
}

/// Accept loop over an existing listener (testable entry point).
pub fn serve_on(
    listener: TcpListener,
    handle: BatcherHandle,
    engine: Arc<MipsEngine>,
    cfg: ServeConfig,
) -> crate::Result<()> {
    let cfg = Arc::new(cfg);
    loop {
        let (stream, peer) = listener.accept()?;
        crate::log_debug!("connection from {peer}");
        let h = handle.clone();
        let e = Arc::clone(&engine);
        let c = Arc::clone(&cfg);
        std::thread::spawn(move || {
            let r = conn_loop(stream, &c, |line| handle_request_full(line, &h, &e, &c));
            if let Err(err) = r {
                crate::log_warn!("connection error: {err}");
            }
        });
    }
}

/// Accept loop serving a replicated router — the routed analogue of
/// [`serve_on`]: every line is answered by [`handle_router_request`],
/// so queries get hedged scatter/gather and coverage-disclosed partial
/// results.
pub fn serve_router_on<S: LiveStorage>(
    listener: TcpListener,
    router: Arc<ShardedRouter<S>>,
    cfg: ServeConfig,
) -> crate::Result<()> {
    let cfg = Arc::new(cfg);
    loop {
        let (stream, peer) = listener.accept()?;
        crate::log_debug!("connection from {peer}");
        let r = Arc::clone(&router);
        let c = Arc::clone(&cfg);
        std::thread::spawn(move || {
            let res = conn_loop(stream, &c, |line| handle_router_request_full(line, &r, &c));
            if let Err(err) = res {
                crate::log_warn!("connection error: {err}");
            }
        });
    }
}

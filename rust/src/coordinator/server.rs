//! JSON-lines TCP front end (std::net + a thread per connection).
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! → {"vector": [0.1, ...], "top_k": 10, "deadline_ms": 250}
//! ← {"ok": true, "items": [5, 2], "scores": [1.9, 1.2], "degraded": false, "latency_us": 830}
//! → {"cmd": "metrics"}
//! ← {"ok": true, "metrics": {...}}
//! → {"cmd": "ping"}
//! ← {"ok": true}
//! → {"cmd": "upsert", "id": 42, "vector": [0.1, ...]}
//! ← {"ok": true, "n_items": 1001}
//! → {"cmd": "upsert_batch", "ids": [7, 8], "vectors": [[...], [...]]}
//! ← {"ok": true, "n_items": 1003, "count": 2}
//! → {"cmd": "delete", "id": 42}
//! ← {"ok": true, "n_items": 1000}
//! ```
//!
//! `upsert`/`delete` mutate a live engine ([`MipsEngine::open_live`]):
//! the WAL append is durable before the `ok` line is written, and the
//! new state is visible to every query admitted afterwards.
//! `upsert_batch` group-commits the whole batch — one WAL record batch,
//! one fsync ([`crate::index::LiveIndex::upsert_batch`]) — and is
//! validated in full before any byte is logged, so a rejected batch
//! mutates nothing. Against a frozen engine the mutation commands
//! answer `invalid_argument`. The `metrics`
//! command additionally reports the live-tier gauges (`delta_items`,
//! `tombstones`, `compactions`, `wal_bytes`, `last_compaction_ms` — all
//! zero on a frozen engine).
//!
//! Every failure is a structured `{"ok": false, "code": ..., "error": ...}`
//! line — `invalid_argument` (malformed/non-finite vector, bad `top_k`,
//! bad `deadline_ms`, oversized line), `deadline_exceeded`, `overloaded`,
//! or `internal` — and never kills the connection: the offending line is
//! consumed (oversized lines are discarded to the next newline) and the
//! connection keeps serving. `ping` and `metrics` are answered inline on
//! the connection thread, never through the batcher queue, so health
//! checks stay responsive while queries are being shed.
//!
//! The **routed** front end ([`serve_router_on`] /
//! [`handle_router_request`]) serves a replicated [`ShardedRouter`]
//! instead of a single engine: queries run through the hedged
//! scatter/gather and every response discloses coverage
//! (`shards_answered`, `shards_total`, `coverage_fraction`, `degraded`,
//! `hedge_fired`); its `metrics` command reports hedge/partial/scrub
//! counters, per-shard p99 gauges, and per-member breaker states.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Instant;

use crate::index::storage::Storage;
use crate::index::ProbeBudget;
use crate::util::json::{num_arr, obj, Json};

use super::admission::{deadline_expired, triage_deadline_ms};
use super::batcher::{BatcherHandle, BreakerState};
use super::engine::MipsEngine;
use super::router::ShardedRouter;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub addr: String,
    /// Longest accepted request line in bytes; longer lines get a
    /// structured error and are discarded without killing the connection.
    pub max_line_len: usize,
    /// Largest accepted `top_k` (absurd values are client mistakes, and
    /// each admitted `top_k` costs rerank heap work).
    pub max_top_k: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { addr: "127.0.0.1:7878".into(), max_line_len: 1 << 20, max_top_k: 1024 }
    }
}

fn err_response(code: &str, msg: impl Into<String>) -> Json {
    obj(vec![
        ("ok", Json::Bool(false)),
        ("code", Json::Str(code.into())),
        ("error", Json::Str(msg.into())),
    ])
}

/// Handle one JSON-lines request string. Pure function over the request
/// text — directly unit/integration testable without sockets.
pub fn handle_request(
    line: &str,
    handle: &BatcherHandle,
    engine: &Arc<MipsEngine>,
    cfg: &ServeConfig,
) -> Json {
    if line.len() > cfg.max_line_len {
        return err_response(
            "invalid_argument",
            format!("request line exceeds {} bytes", cfg.max_line_len),
        );
    }
    let req = match Json::parse(line) {
        Ok(r) => r,
        Err(e) => return err_response("invalid_argument", format!("bad request: {e}")),
    };
    match req.get("cmd").and_then(Json::as_str) {
        Some("ping") => obj(vec![("ok", Json::Bool(true))]),
        Some("metrics") => {
            let s = engine.metrics_snapshot();
            let breaker = match handle.breaker_state() {
                BreakerState::Closed => "closed",
                BreakerState::Open => "open",
                BreakerState::HalfOpen => "half_open",
            };
            obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "metrics",
                    obj(vec![
                        ("queries", Json::Num(s.queries as f64)),
                        ("batches", Json::Num(s.batches as f64)),
                        ("batched_queries", Json::Num(s.batched_queries as f64)),
                        ("candidates", Json::Num(s.candidates as f64)),
                        ("errors", Json::Num(s.errors as f64)),
                        ("shed", Json::Num(s.shed as f64)),
                        ("deadline_exceeded", Json::Num(s.deadline_exceeded as f64)),
                        ("degraded_queries", Json::Num(s.degraded_queries as f64)),
                        ("pjrt_fallbacks", Json::Num(s.pjrt_fallbacks as f64)),
                        ("queue_depth", Json::Num(s.queue_depth as f64)),
                        ("delta_items", Json::Num(s.delta_items as f64)),
                        ("tombstones", Json::Num(s.tombstones as f64)),
                        ("compactions", Json::Num(s.compactions as f64)),
                        ("wal_bytes", Json::Num(s.wal_bytes as f64)),
                        ("last_compaction_ms", Json::Num(s.last_compaction_ms as f64)),
                        ("load_level", Json::Num(handle.level() as f64)),
                        ("breaker", Json::Str(breaker.into())),
                        ("mean_latency_us", Json::Num(s.mean_latency_us)),
                        ("p50_latency_us", Json::Num(s.p50_latency_us as f64)),
                        ("p99_latency_us", Json::Num(s.p99_latency_us as f64)),
                        ("mean_batch_size", Json::Num(s.mean_batch_size())),
                    ]),
                ),
            ])
        }
        Some("upsert") => {
            let Some(id) = parse_ext_id(&req) else {
                return err_response("invalid_argument", "id must be an integer in u32 range");
            };
            let Some(vector) = req.get("vector").and_then(Json::as_f32_vec) else {
                return err_response("invalid_argument", "missing or malformed vector");
            };
            if vector.iter().any(|v| !v.is_finite()) {
                return err_response("invalid_argument", "vector contains non-finite components");
            }
            if vector.len() != engine.dim() {
                return err_response(
                    "invalid_argument",
                    format!("vector dim {} != index dim {}", vector.len(), engine.dim()),
                );
            }
            if !engine.is_live() {
                return err_response(
                    "invalid_argument",
                    "engine serves a frozen index; upsert requires a live index",
                );
            }
            match engine.upsert(id, &vector) {
                Ok(()) => obj(vec![
                    ("ok", Json::Bool(true)),
                    ("n_items", Json::Num(engine.n_items() as f64)),
                ]),
                Err(e) => err_response("internal", format!("upsert failed: {e:#}")),
            }
        }
        Some("delete") => {
            let Some(id) = parse_ext_id(&req) else {
                return err_response("invalid_argument", "id must be an integer in u32 range");
            };
            if !engine.is_live() {
                return err_response(
                    "invalid_argument",
                    "engine serves a frozen index; delete requires a live index",
                );
            }
            match engine.delete(id) {
                Ok(()) => obj(vec![
                    ("ok", Json::Bool(true)),
                    ("n_items", Json::Num(engine.n_items() as f64)),
                ]),
                Err(e) => err_response("internal", format!("delete failed: {e:#}")),
            }
        }
        Some("upsert_batch") => {
            let Some(ids) = req.get("ids").and_then(Json::as_arr) else {
                return err_response("invalid_argument", "missing or malformed ids array");
            };
            let Some(vectors) = req.get("vectors").and_then(Json::as_arr) else {
                return err_response("invalid_argument", "missing or malformed vectors array");
            };
            if ids.is_empty() || ids.len() != vectors.len() {
                return err_response(
                    "invalid_argument",
                    format!(
                        "ids ({}) and vectors ({}) must be equal-length and non-empty",
                        ids.len(),
                        vectors.len()
                    ),
                );
            }
            if !engine.is_live() {
                return err_response(
                    "invalid_argument",
                    "engine serves a frozen index; upsert_batch requires a live index",
                );
            }
            // Validate the whole batch before touching the WAL, so a
            // rejected batch leaves no partial mutation behind.
            let mut entries = Vec::with_capacity(ids.len());
            for (i, (id, vec)) in ids.iter().zip(vectors).enumerate() {
                let Some(id) = id.as_usize().and_then(|v| u32::try_from(v).ok()) else {
                    return err_response(
                        "invalid_argument",
                        format!("ids[{i}] must be an integer in u32 range"),
                    );
                };
                let Some(vector) = vec.as_f32_vec() else {
                    return err_response(
                        "invalid_argument",
                        format!("vectors[{i}] is missing or malformed"),
                    );
                };
                if vector.iter().any(|v| !v.is_finite()) {
                    return err_response(
                        "invalid_argument",
                        format!("vectors[{i}] contains non-finite components"),
                    );
                }
                if vector.len() != engine.dim() {
                    return err_response(
                        "invalid_argument",
                        format!(
                            "vectors[{i}] dim {} != index dim {}",
                            vector.len(),
                            engine.dim()
                        ),
                    );
                }
                entries.push((id, vector));
            }
            match engine.upsert_batch(&entries) {
                Ok(()) => obj(vec![
                    ("ok", Json::Bool(true)),
                    ("n_items", Json::Num(engine.n_items() as f64)),
                    ("count", Json::Num(entries.len() as f64)),
                ]),
                Err(e) => err_response("internal", format!("upsert_batch failed: {e:#}")),
            }
        }
        Some(other) => err_response("invalid_argument", format!("unknown cmd {other:?}")),
        None => {
            let (vector, top_k, deadline) = match parse_query(&req, engine.dim(), cfg) {
                Ok(parts) => parts,
                Err(resp) => return resp,
            };
            let t0 = Instant::now();
            match handle.query_deadline(vector, top_k, deadline) {
                Ok(reply) => {
                    let ids: Vec<f64> = reply.hits.iter().map(|h| h.id as f64).collect();
                    let scores: Vec<f64> =
                        reply.hits.iter().map(|h| h.score as f64).collect();
                    obj(vec![
                        ("ok", Json::Bool(true)),
                        ("items", num_arr(&ids)),
                        ("scores", num_arr(&scores)),
                        ("degraded", Json::Bool(reply.degraded)),
                        (
                            "latency_us",
                            Json::Num(t0.elapsed().as_micros() as f64),
                        ),
                    ])
                }
                Err(e) => err_response(e.code(), e.message()),
            }
        }
    }
}

/// Handle one JSON-lines request against a replicated router — the
/// routed analogue of [`handle_request`]. Queries run through
/// [`ShardedRouter::query_replicated`] (hedged scatter/gather, per-shard
/// timeouts), and every query response carries the coverage fields
/// (`shards_answered`, `shards_total`, `coverage_fraction`, `degraded`,
/// `hedge_fired`) so a client can always tell a full answer from a
/// partial one. Mutations are rejected — replica groups serve frozen
/// index files. The `metrics` command reports the router counters:
/// hedge fires, partial replies, scrub quarantines/repairs, per-shard
/// answer-p99 gauges, and per-member breaker states.
pub fn handle_router_request<S: Storage>(
    line: &str,
    router: &ShardedRouter<S>,
    cfg: &ServeConfig,
) -> Json {
    if line.len() > cfg.max_line_len {
        return err_response(
            "invalid_argument",
            format!("request line exceeds {} bytes", cfg.max_line_len),
        );
    }
    let req = match Json::parse(line) {
        Ok(r) => r,
        Err(e) => return err_response("invalid_argument", format!("bad request: {e}")),
    };
    match req.get("cmd").and_then(Json::as_str) {
        Some("ping") => obj(vec![("ok", Json::Bool(true))]),
        Some("metrics") => {
            let s = router.metrics().snapshot();
            let shard_p99: Vec<f64> =
                router.shard_p99_us().iter().map(|&v| v as f64).collect();
            let breakers: Vec<Json> = router
                .breaker_states()
                .into_iter()
                .map(|g| {
                    Json::Arr(g.into_iter().map(|b| Json::Str(b.as_str().into())).collect())
                })
                .collect();
            obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "metrics",
                    obj(vec![
                        ("queries", Json::Num(s.queries as f64)),
                        ("hedge_fires", Json::Num(s.hedge_fires as f64)),
                        ("partial_replies", Json::Num(s.partial_replies as f64)),
                        ("replica_quarantines", Json::Num(s.replica_quarantines as f64)),
                        ("replica_repairs", Json::Num(s.replica_repairs as f64)),
                        ("p50_latency_us", Json::Num(s.p50_latency_us as f64)),
                        ("p99_latency_us", Json::Num(s.p99_latency_us as f64)),
                        ("shard_p99_us", num_arr(&shard_p99)),
                        ("breakers", Json::Arr(breakers)),
                    ]),
                ),
            ])
        }
        Some(other) => err_response(
            "invalid_argument",
            format!("unknown cmd {other:?} (mutations are not served on the routed path)"),
        ),
        None => {
            let (vector, top_k, deadline) = match parse_query(&req, router.dim(), cfg) {
                Ok(parts) => parts,
                Err(resp) => return resp,
            };
            if deadline_expired(deadline) {
                return err_response("deadline_exceeded", "deadline expired before dispatch");
            }
            let t0 = Instant::now();
            let reply = router.query_replicated(&vector, top_k, ProbeBudget::full());
            if deadline_expired(deadline) {
                return err_response(
                    "deadline_exceeded",
                    "deadline expired during scatter/gather",
                );
            }
            let ids: Vec<f64> = reply.hits.iter().map(|h| h.id as f64).collect();
            let scores: Vec<f64> = reply.hits.iter().map(|h| h.score as f64).collect();
            obj(vec![
                ("ok", Json::Bool(true)),
                ("items", num_arr(&ids)),
                ("scores", num_arr(&scores)),
                ("degraded", Json::Bool(reply.degraded)),
                ("shards_answered", Json::Num(reply.shards_answered as f64)),
                ("shards_total", Json::Num(reply.shards_total as f64)),
                ("coverage_fraction", Json::Num(reply.coverage_fraction())),
                ("hedge_fired", Json::Bool(reply.hedge_fired)),
                ("latency_us", Json::Num(t0.elapsed().as_micros() as f64)),
            ])
        }
    }
}

/// Validate a query request's `vector`, `top_k`, and `deadline_ms`
/// against the index dimension and the server limits — shared by the
/// batched single-engine path and the routed replica path so both
/// enforce identical request semantics. `Err` is the ready-to-send
/// error response.
fn parse_query(
    req: &Json,
    dim: usize,
    cfg: &ServeConfig,
) -> Result<(Vec<f32>, usize, Option<Instant>), Json> {
    let Some(vector) = req.get("vector").and_then(Json::as_f32_vec) else {
        return Err(err_response("invalid_argument", "missing or malformed vector"));
    };
    // JSON numbers can't spell NaN, but overflow (1e39 → f32 Inf,
    // 1e999 → f64 inf) can still smuggle non-finite components in.
    if vector.iter().any(|v| !v.is_finite()) {
        return Err(err_response(
            "invalid_argument",
            "vector contains non-finite components",
        ));
    }
    if vector.len() != dim {
        return Err(err_response(
            "invalid_argument",
            format!("vector dim {} != index dim {dim}", vector.len()),
        ));
    }
    let top_k = match req.get("top_k") {
        None => 10,
        Some(v) => match v.as_usize() {
            Some(k) if (1..=cfg.max_top_k).contains(&k) => k,
            Some(0) => return Err(err_response("invalid_argument", "top_k must be >= 1")),
            Some(k) => {
                return Err(err_response(
                    "invalid_argument",
                    format!("top_k {k} exceeds max {}", cfg.max_top_k),
                ))
            }
            None => {
                return Err(err_response(
                    "invalid_argument",
                    "top_k must be a positive integer",
                ))
            }
        },
    };
    let deadline = match req.get("deadline_ms") {
        None => None,
        Some(v) => match v.as_f64().map(triage_deadline_ms) {
            Some(Ok(d)) => Some(d),
            Some(Err(e)) => return Err(err_response(e.code(), e.message())),
            None => {
                return Err(err_response(
                    "invalid_argument",
                    "deadline_ms must be a positive finite number of milliseconds",
                ))
            }
        },
    };
    Ok((vector, top_k, deadline))
}

/// The `id` field of a mutation command, if it is an integer that fits
/// an external item id (u32).
fn parse_ext_id(req: &Json) -> Option<u32> {
    let id = req.get("id")?.as_usize()?;
    u32::try_from(id).ok()
}

/// Drop bytes until (and including) the next newline — the tail of an
/// oversized request line. EOF ends the discard.
fn discard_to_newline(reader: &mut BufReader<TcpStream>) -> std::io::Result<()> {
    loop {
        let (n, done) = {
            let avail = reader.fill_buf()?;
            if avail.is_empty() {
                return Ok(());
            }
            match avail.iter().position(|&b| b == b'\n') {
                Some(pos) => (pos + 1, true),
                None => (avail.len(), false),
            }
        };
        reader.consume(n);
        if done {
            return Ok(());
        }
    }
}

fn write_json_line(writer: &mut TcpStream, resp: &Json) -> std::io::Result<()> {
    let mut out = resp.to_string();
    out.push('\n');
    writer.write_all(out.as_bytes())
}

/// One connection's read-dispatch-write loop, generic over the request
/// handler — the single-engine path and the routed replica path differ
/// only in what answers a line.
fn conn_loop(
    stream: TcpStream,
    cfg: &ServeConfig,
    mut handle_line: impl FnMut(&str) -> Json,
) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let cap = cfg.max_line_len as u64;
    let mut buf = Vec::new();
    loop {
        buf.clear();
        // Bounded read: at most max_line_len + 1 bytes buffer per read,
        // however long the client's line is.
        let n = (&mut reader).take(cap + 1).read_until(b'\n', &mut buf)?;
        if n == 0 {
            return Ok(()); // EOF
        }
        if buf.last() != Some(&b'\n') && n as u64 > cap {
            // Oversized line: structured error, discard the tail, keep
            // the connection serving.
            discard_to_newline(&mut reader)?;
            let resp = err_response(
                "invalid_argument",
                format!("request line exceeds {} bytes", cfg.max_line_len),
            );
            write_json_line(&mut writer, &resp)?;
            continue;
        }
        let text = String::from_utf8_lossy(&buf);
        let line = text.trim();
        if line.is_empty() {
            continue;
        }
        let resp = handle_line(line);
        write_json_line(&mut writer, &resp)?;
    }
}

/// Bind `cfg.addr` and serve forever (thread per connection).
pub fn serve(cfg: ServeConfig, handle: BatcherHandle, engine: Arc<MipsEngine>) -> crate::Result<()> {
    let listener = TcpListener::bind(&cfg.addr)?;
    crate::log_info!("serving MIPS on {}", cfg.addr);
    serve_on(listener, handle, engine, cfg)
}

/// Accept loop over an existing listener (testable entry point).
pub fn serve_on(
    listener: TcpListener,
    handle: BatcherHandle,
    engine: Arc<MipsEngine>,
    cfg: ServeConfig,
) -> crate::Result<()> {
    let cfg = Arc::new(cfg);
    loop {
        let (stream, peer) = listener.accept()?;
        crate::log_debug!("connection from {peer}");
        let h = handle.clone();
        let e = Arc::clone(&engine);
        let c = Arc::clone(&cfg);
        std::thread::spawn(move || {
            let r = conn_loop(stream, &c, |line| handle_request(line, &h, &e, &c));
            if let Err(err) = r {
                crate::log_warn!("connection error: {err}");
            }
        });
    }
}

/// Accept loop serving a replicated router — the routed analogue of
/// [`serve_on`]: every line is answered by [`handle_router_request`],
/// so queries get hedged scatter/gather and coverage-disclosed partial
/// results.
pub fn serve_router_on<S: Storage>(
    listener: TcpListener,
    router: Arc<ShardedRouter<S>>,
    cfg: ServeConfig,
) -> crate::Result<()> {
    let cfg = Arc::new(cfg);
    loop {
        let (stream, peer) = listener.accept()?;
        crate::log_debug!("connection from {peer}");
        let r = Arc::clone(&router);
        let c = Arc::clone(&cfg);
        std::thread::spawn(move || {
            let res = conn_loop(stream, &c, |line| handle_router_request(line, &r, &c));
            if let Err(err) = res {
                crate::log_warn!("connection error: {err}");
            }
        });
    }
}

//! Replica groups for the sharded router: the fault-tolerance layer
//! that turns a demo fan-out into a serving tier that degrades instead
//! of failing.
//!
//! Each shard of a [`super::ShardedRouter`] is a **replica group**: R
//! engines over the same contiguous item range, built with distinct
//! hash seeds. Distinct seeds make the members recall-diverse by
//! construction — an item the primary's tables happen to miss is
//! usually found by the backup's independent projections — so hedging
//! to a replica is never a wasted retry of the same randomness.
//!
//! Every member runs a dedicated **worker thread** serving dispatched
//! query jobs over an mpsc channel. The dispatcher therefore never
//! blocks on a stalled member: it waits on the reply channel with a
//! timeout, hedges to a backup when the primary exceeds the hedge
//! delay, and walks away (leaving the worker to finish into a dropped
//! channel) when the shard timeout expires.
//!
//! Per-member health is a PR 6-style **circuit breaker**
//! ([`ReplicaBreaker`]): consecutive failures (timeouts, crashed
//! workers) trip it Open, a cooldown later the next dispatch is the
//! half-open probe, success re-closes. The scrubber's quarantine is a
//! stronger Open that only an explicit repair clears.
//!
//! The **scrubber** ([`super::ShardedRouter::scrub_now`]) walks each
//! file-backed member's `V5Checked` sections via
//! [`crate::index::open_mmap_verified`], quarantines a member whose
//! file fails the checksum walk, rebuilds its index from a healthy
//! peer's items (with the member's own seed, preserving recall
//! diversity), re-verifies the rewritten file, hot-swaps the engine
//! slot, and re-admits the member through its breaker.
//!
//! Faults are injected per member with a [`ShardFaultPlan`] (stall
//! windows, crash-on-query, on-disk bit flips), mirroring the batcher's
//! [`super::FaultPlan`] idiom: plans are keyed by the member's job
//! sequence number so tests can stage exact scenarios.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc, Mutex, MutexGuard, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::index::storage::{Mapped, Owned, Storage};
use crate::index::{open_mmap_verified, AnyIndex, ProbeBudget, ScoredItem};

use super::batcher::BreakerState;
use super::engine::MipsEngine;
use super::metrics::LatencyHist;
use super::trace::QuerySpans;

/// Survive a poisoned mutex: none of the guarded state here can be left
/// inconsistent by a panicking holder (plans and instants are written
/// atomically in one statement).
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn read_slot<S: Storage>(slot: &RwLock<Arc<MipsEngine<S>>>) -> Arc<MipsEngine<S>> {
    Arc::clone(&slot.read().unwrap_or_else(|e| e.into_inner()))
}

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// Tuning for the replicated scatter/gather path.
#[derive(Clone, Copy, Debug)]
pub struct ReplicaConfig {
    /// Hard per-shard wait: past it the shard goes unanswered and the
    /// merged reply turns partial ([`super::router::RouterReply`]).
    pub shard_timeout: Duration,
    /// Fixed hedge delay override. `None` (the default) derives it per
    /// shard from that shard's measured answer p99:
    /// `clamp(hedge_multiplier × p99, hedge_min, hedge_max)`.
    pub hedge_delay: Option<Duration>,
    /// Multiplier over the shard p99 for the derived hedge delay.
    pub hedge_multiplier: f64,
    /// Lower clamp for the derived hedge delay (keeps a cold histogram
    /// from hedging every query).
    pub hedge_min: Duration,
    /// Upper clamp for the derived hedge delay.
    pub hedge_max: Duration,
    /// Consecutive member failures (timeout / crashed worker) that trip
    /// its breaker Open.
    pub breaker_failures: u32,
    /// How long a tripped breaker stays Open before the half-open
    /// re-probe dispatch.
    pub breaker_cooldown: Duration,
    /// Member acks required before a replicated write is acknowledged
    /// to the client. `None` (the default) means majority: `R/2 + 1`
    /// for a group of R members. Clamped to `1..=R` at use.
    pub write_quorum: Option<usize>,
}

impl Default for ReplicaConfig {
    fn default() -> Self {
        Self {
            shard_timeout: Duration::from_millis(250),
            hedge_delay: None,
            hedge_multiplier: 2.0,
            hedge_min: Duration::from_micros(500),
            hedge_max: Duration::from_millis(50),
            breaker_failures: 3,
            breaker_cooldown: Duration::from_millis(100),
            write_quorum: None,
        }
    }
}

impl ReplicaConfig {
    /// Resolve the effective write quorum for a group of `replicas`
    /// members: the configured value clamped to `1..=replicas`, or
    /// majority (`R/2 + 1`) when unset.
    pub fn effective_write_quorum(&self, replicas: usize) -> usize {
        match self.write_quorum {
            Some(q) => q.clamp(1, replicas.max(1)),
            None => replicas / 2 + 1,
        }
    }
}

// ---------------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------------

/// Per-member fault plan (tests and benches only; defaults all-off).
/// Windows are keyed by the member's **job sequence number** — the
/// 0-based count of jobs its worker has received — mirroring the
/// batch-sequence windows of the batcher's [`super::FaultPlan`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardFaultPlan {
    /// First job seq stalled…
    pub stall_from: usize,
    /// …up to (exclusive) this one.
    pub stall_until: usize,
    /// Injected stall per affected job.
    pub stall: Duration,
    /// Job seq at which the worker exits without replying — a crashed
    /// replica process: the in-flight query times out and every later
    /// dispatch to this member fails immediately.
    pub crash_at: Option<usize>,
    /// Job seq at which a burst of bytes is flipped in the member's
    /// backing file before it answers — silent media corruption. The
    /// already-opened engine keeps serving its mapped/loaded state;
    /// only the scrubber's checksum walk catches the rot.
    pub corrupt_file_at: Option<usize>,
    /// **Write-op** seq (the 0-based count of replicated mutations fanned
    /// out to this member — a separate clock from the query-job seq) at
    /// which the member "crashes" mid-write-stream: the mutation is NOT
    /// applied, the member is quarantined, and every later write skips
    /// it until catch-up re-admits it.
    pub write_crash_at: Option<usize>,
}

impl ShardFaultPlan {
    fn stall_for(&self, seq: usize) -> Option<Duration> {
        (seq >= self.stall_from && seq < self.stall_until && !self.stall.is_zero())
            .then_some(self.stall)
    }

    fn crashes_at(&self, seq: usize) -> bool {
        self.crash_at == Some(seq)
    }

    fn corrupts_at(&self, seq: usize) -> bool {
        self.corrupt_file_at == Some(seq)
    }

    fn write_crashes_at(&self, seq: usize) -> bool {
        self.write_crash_at == Some(seq)
    }
}

/// Flip a burst of bytes in the middle of `path` — the corruption
/// injector behind [`ShardFaultPlan::corrupt_file_at`] and the failover
/// tests. The burst is 65 bytes: v5 sections are 64-byte aligned with
/// sub-64-byte padding gaps between them, so a 65-byte run in the body
/// is guaranteed to dirty at least one checksummed section byte (a
/// single flipped byte could land entirely in uncovered padding and
/// make "the scrubber detects 100% of injected corruptions" flaky).
pub fn corrupt_index_file(path: &Path) -> crate::Result<()> {
    let mut bytes = std::fs::read(path)?;
    anyhow::ensure!(bytes.len() >= 512, "file too small to corrupt meaningfully");
    let start = bytes.len() / 2;
    let end = (start + 65).min(bytes.len());
    for b in &mut bytes[start..end] {
        *b ^= 0x5A;
    }
    std::fs::write(path, &bytes)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Write-path errors
// ---------------------------------------------------------------------------

/// A replicated mutation reached fewer member acks than the shard's
/// write quorum. The write is **not** acknowledged: surviving applies
/// are repaired by the scrub/catch-up cycle, and the client must retry.
#[derive(Clone, Copy, Debug)]
pub struct QuorumFailed {
    /// Owning shard of the mutated id.
    pub shard: usize,
    /// Members that durably applied the mutation.
    pub acked: usize,
    /// The quorum the group required.
    pub needed: usize,
    /// Group size.
    pub replicas: usize,
}

impl std::fmt::Display for QuorumFailed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "write quorum failed on shard {}: {}/{} member acks (quorum {})",
            self.shard, self.acked, self.replicas, self.needed
        )
    }
}

impl std::error::Error for QuorumFailed {}

// ---------------------------------------------------------------------------
// Breaker
// ---------------------------------------------------------------------------

/// Circuit breaker over one replica member (see module docs): 0 =
/// Closed, 1 = Open, 2 = HalfOpen, same numbering as the batcher's
/// backend breaker so [`BreakerState::from_u8`] is shared.
pub(crate) struct ReplicaBreaker {
    state: AtomicU8,
    consecutive_failures: AtomicU32,
    trip_after: u32,
    cooldown: Duration,
    /// When an Open breaker may half-open. Behind a mutex (not the hot
    /// path): written on trip, read on admit while Open.
    reopen_at: Mutex<Instant>,
    /// Scrubber quarantine: out of rotation regardless of cooldown
    /// until a successful repair re-admits the member.
    quarantined: AtomicBool,
}

impl ReplicaBreaker {
    fn new(trip_after: u32, cooldown: Duration) -> Self {
        Self {
            state: AtomicU8::new(0),
            consecutive_failures: AtomicU32::new(0),
            trip_after: trip_after.max(1),
            cooldown,
            reopen_at: Mutex::new(Instant::now()),
            quarantined: AtomicBool::new(false),
        }
    }

    pub(crate) fn state(&self) -> BreakerState {
        BreakerState::from_u8(self.state.load(Ordering::Acquire))
    }

    pub(crate) fn is_quarantined(&self) -> bool {
        self.quarantined.load(Ordering::Acquire)
    }

    /// Whether the dispatcher may route a query here right now. Flips
    /// Open → HalfOpen once the cooldown has elapsed; that dispatch is
    /// the probe (its outcome re-closes or re-opens the breaker).
    pub(crate) fn admit(&self) -> bool {
        if self.is_quarantined() {
            return false;
        }
        match self.state.load(Ordering::Acquire) {
            1 => {
                if Instant::now() >= *lock(&self.reopen_at) {
                    self.state.store(2, Ordering::Release);
                    true
                } else {
                    false
                }
            }
            _ => true, // Closed, or HalfOpen probe already granted
        }
    }

    pub(crate) fn on_success(&self) {
        self.consecutive_failures.store(0, Ordering::Relaxed);
        self.state.store(0, Ordering::Release);
    }

    pub(crate) fn on_failure(&self) {
        let n = self.consecutive_failures.fetch_add(1, Ordering::Relaxed) + 1;
        // A failed half-open probe re-opens immediately; otherwise the
        // consecutive-failure threshold decides.
        if self.state.load(Ordering::Acquire) == 2 || n >= self.trip_after {
            *lock(&self.reopen_at) = Instant::now() + self.cooldown;
            self.state.store(1, Ordering::Release);
        }
    }

    pub(crate) fn quarantine(&self) {
        self.quarantined.store(true, Ordering::Release);
        *lock(&self.reopen_at) = Instant::now() + self.cooldown;
        self.state.store(1, Ordering::Release);
    }

    pub(crate) fn readmit(&self) {
        self.quarantined.store(false, Ordering::Release);
        self.consecutive_failures.store(0, Ordering::Relaxed);
        self.state.store(0, Ordering::Release);
    }
}

// ---------------------------------------------------------------------------
// Verified opens, generic over storage
// ---------------------------------------------------------------------------

/// Storage-generic **verified** open for replica engines: both flavors
/// serve the same `V5Checked` file — [`Mapped`] zero-copy, [`Owned`]
/// deep-copied to the heap — and both walk every section checksum
/// before the engine is admitted, so a scrub-repaired file is proven
/// intact before it swaps into the serving slot.
pub trait ReplicaStorage: Storage + Sized {
    fn open_verified(path: &Path) -> crate::Result<MipsEngine<Self>>;
}

impl ReplicaStorage for Mapped {
    fn open_verified(path: &Path) -> crate::Result<MipsEngine<Self>> {
        Ok(MipsEngine::from_any(open_mmap_verified(path)?))
    }
}

impl ReplicaStorage for Owned {
    fn open_verified(path: &Path) -> crate::Result<MipsEngine<Self>> {
        // The heap loader verifies checksums when the file carries them
        // (`SectionVerify::IfPresent`), but "carries them" is exactly
        // what a corrupted header could lie about — walk the sections
        // through the same Require path as the mapped open first.
        open_mmap_verified(path)?;
        Ok(MipsEngine::from_any(AnyIndex::load(path)?))
    }
}

// ---------------------------------------------------------------------------
// Replica member + worker
// ---------------------------------------------------------------------------

/// State shared between a member's dispatcher-facing handle and its
/// worker thread.
pub(crate) struct ReplicaShared<S: Storage> {
    /// The serving engine, hot-swappable by the scrubber's repair.
    slot: RwLock<Arc<MipsEngine<S>>>,
    /// Backing `V5Checked` file for file-backed members (`None` for
    /// in-memory members, which the scrubber skips).
    pub(crate) path: Option<PathBuf>,
    /// The member's own hash seed — a repair rebuilds with it so the
    /// group stays recall-diverse.
    pub(crate) seed: u64,
    pub(crate) breaker: ReplicaBreaker,
    faults: Mutex<ShardFaultPlan>,
    /// Jobs received by the worker (the fault plans' clock).
    seq: AtomicUsize,
    /// Replicated mutations fanned out to this member (the write fault
    /// plan's clock — see [`ShardFaultPlan::write_crash_at`]).
    writes: AtomicUsize,
}

struct ReplicaJob {
    /// This member's index within its group, echoed in the reply so the
    /// dispatcher knows who won a hedged race.
    member: usize,
    query: Arc<[f32]>,
    top_k: usize,
    budget: ProbeBudget,
    reply: Sender<(usize, Vec<ScoredItem>, QuerySpans)>,
}

/// One member of a replica group: shared state plus the dispatch sender
/// and worker join handle.
pub(crate) struct Replica<S: Storage> {
    pub(crate) shared: Arc<ReplicaShared<S>>,
    /// `None` only during teardown (Drop takes it to unblock the
    /// worker's `recv` before joining).
    tx: Option<Sender<ReplicaJob>>,
    worker: Mutex<Option<JoinHandle<()>>>,
}

fn worker_loop<S: Storage>(shared: Arc<ReplicaShared<S>>, rx: Receiver<ReplicaJob>) {
    // One scratch reused across jobs *and* across repair swaps — its
    // buffers grow to whatever engine currently occupies the slot (the
    // same reuse contract the router's merge scratch relies on).
    let mut scratch = None;
    while let Ok(job) = rx.recv() {
        let seq = shared.seq.fetch_add(1, Ordering::Relaxed);
        let plan = *lock(&shared.faults);
        if plan.crashes_at(seq) {
            // Exit without replying: the in-flight dispatcher times
            // out, and every later dispatch fails fast on the dropped
            // receiver.
            return;
        }
        if let Some(stall) = plan.stall_for(seq) {
            std::thread::sleep(stall);
        }
        if plan.corrupts_at(seq) {
            if let Some(path) = &shared.path {
                let _ = corrupt_index_file(path);
            }
        }
        let engine = read_slot(&shared.slot);
        let s = scratch.get_or_insert_with(|| engine.scratch());
        let mut spans = QuerySpans::default();
        let hits = engine
            .query_traced_into(&job.query, job.top_k, job.budget, &mut spans, s)
            .to_vec();
        // A dispatcher that already gave up dropped the receiver; a
        // late answer is discarded, not an error.
        let _ = job.reply.send((job.member, hits, spans));
    }
}

impl<S: Storage> Replica<S> {
    fn spawn(engine: MipsEngine<S>, path: Option<PathBuf>, seed: u64, cfg: &ReplicaConfig) -> Self {
        let shared = Arc::new(ReplicaShared {
            slot: RwLock::new(Arc::new(engine)),
            path,
            seed,
            breaker: ReplicaBreaker::new(cfg.breaker_failures, cfg.breaker_cooldown),
            faults: Mutex::new(ShardFaultPlan::default()),
            seq: AtomicUsize::new(0),
            writes: AtomicUsize::new(0),
        });
        let (tx, rx) = mpsc::channel();
        let handle = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("alsh-replica".into())
                .spawn(move || worker_loop(shared, rx))
                .expect("spawn replica worker")
        };
        Self { shared, tx: Some(tx), worker: Mutex::new(Some(handle)) }
    }

    /// Hand a job to the worker. `false` means the worker is gone (a
    /// crashed member) — an immediate dispatch failure.
    pub(crate) fn dispatch(
        &self,
        member: usize,
        query: &Arc<[f32]>,
        top_k: usize,
        budget: ProbeBudget,
        reply: Sender<(usize, Vec<ScoredItem>, QuerySpans)>,
    ) -> bool {
        match &self.tx {
            Some(tx) => tx
                .send(ReplicaJob {
                    member,
                    query: Arc::clone(query),
                    top_k,
                    budget,
                    reply,
                })
                .is_ok(),
            None => false,
        }
    }

    /// The engine currently serving this member's slot.
    pub(crate) fn engine(&self) -> Arc<MipsEngine<S>> {
        read_slot(&self.shared.slot)
    }

    /// Swap a freshly repaired engine into the serving slot.
    pub(crate) fn install(&self, engine: MipsEngine<S>) {
        *self.shared.slot.write().unwrap_or_else(|e| e.into_inner()) = Arc::new(engine);
    }

    pub(crate) fn set_faults(&self, plan: ShardFaultPlan) {
        *lock(&self.shared.faults) = plan;
    }

    /// Advance this member's write clock and report whether the fault
    /// plan crashes it at this write op (router fan-out path).
    pub(crate) fn write_crashes_now(&self) -> bool {
        let seq = self.shared.writes.fetch_add(1, Ordering::Relaxed);
        lock(&self.shared.faults).write_crashes_at(seq)
    }
}

impl<S: Storage> Drop for Replica<S> {
    fn drop(&mut self) {
        // Drop the sender first so the worker's recv unblocks, then
        // join (a worker mid-stall finishes that stall first).
        self.tx = None;
        if let Some(handle) = lock(&self.worker).take() {
            let _ = handle.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Replica group
// ---------------------------------------------------------------------------

/// One shard's replica set: R members over the same item range, plus
/// the shard's answer-latency histogram (dispatch → winning reply) that
/// drives the p99-derived hedge delay.
pub(crate) struct ReplicaGroup<S: Storage> {
    pub(crate) members: Vec<Replica<S>>,
    pub(crate) latency: LatencyHist,
}

impl<S: Storage> ReplicaGroup<S> {
    /// Assemble a group from `(engine, backing file, seed)` triples.
    /// Members must agree on dimension and item count — they serve the
    /// same range, only their hash randomness differs.
    pub(crate) fn new(
        members: Vec<(MipsEngine<S>, Option<PathBuf>, u64)>,
        cfg: &ReplicaConfig,
    ) -> crate::Result<Self> {
        anyhow::ensure!(!members.is_empty(), "replica group needs at least one member");
        let dim = members[0].0.dim();
        let n_items = members[0].0.n_items();
        for (e, _, _) in &members {
            anyhow::ensure!(
                e.dim() == dim && e.n_items() == n_items,
                "replica group members disagree: {}×{} vs {dim}×{n_items} items×dim",
                e.n_items(),
                e.dim()
            );
        }
        Ok(Self {
            members: members
                .into_iter()
                .map(|(engine, path, seed)| Replica::spawn(engine, path, seed, cfg))
                .collect(),
            latency: LatencyHist::new(),
        })
    }

    /// First member whose breaker admits traffic (primary pick).
    pub(crate) fn pick_primary(&self) -> Option<usize> {
        (0..self.members.len()).find(|&i| self.members[i].shared.breaker.admit())
    }

    /// First admitted member other than `primary` (hedge pick).
    pub(crate) fn pick_backup(&self, primary: usize) -> Option<usize> {
        (0..self.members.len())
            .find(|&i| i != primary && self.members[i].shared.breaker.admit())
    }

    /// First non-quarantined member (the sync fan-out path's pick);
    /// falls back to member 0 so a fully quarantined group still
    /// answers best-effort rather than panicking.
    pub(crate) fn pick_serving(&self) -> usize {
        (0..self.members.len())
            .find(|&i| !self.members[i].shared.breaker.is_quarantined())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breaker_trips_after_consecutive_failures_and_half_opens() {
        let b = ReplicaBreaker::new(3, Duration::from_millis(20));
        assert_eq!(b.state(), BreakerState::Closed);
        b.on_failure();
        b.on_failure();
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.admit());
        b.on_failure();
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.admit(), "open breaker admitted before cooldown");
        std::thread::sleep(Duration::from_millis(25));
        assert!(b.admit(), "cooldown elapsed but probe refused");
        assert_eq!(b.state(), BreakerState::HalfOpen);
        // A failed probe re-opens immediately, without needing the
        // consecutive threshold again.
        b.on_failure();
        assert_eq!(b.state(), BreakerState::Open);
        std::thread::sleep(Duration::from_millis(25));
        assert!(b.admit());
        b.on_success();
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn success_resets_consecutive_failures() {
        let b = ReplicaBreaker::new(2, Duration::from_millis(10));
        b.on_failure();
        b.on_success();
        b.on_failure();
        assert_eq!(b.state(), BreakerState::Closed, "non-consecutive failures tripped");
        b.on_failure();
        assert_eq!(b.state(), BreakerState::Open);
    }

    #[test]
    fn quarantine_overrides_cooldown_until_readmit() {
        let b = ReplicaBreaker::new(1, Duration::from_millis(1));
        b.quarantine();
        assert!(b.is_quarantined());
        std::thread::sleep(Duration::from_millis(5));
        assert!(!b.admit(), "quarantined member admitted after cooldown");
        b.readmit();
        assert!(!b.is_quarantined());
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.admit());
    }

    #[test]
    fn fault_plan_windows() {
        let plan = ShardFaultPlan {
            stall_from: 2,
            stall_until: 4,
            stall: Duration::from_millis(5),
            crash_at: Some(7),
            corrupt_file_at: Some(9),
            write_crash_at: Some(3),
        };
        assert!(plan.stall_for(1).is_none());
        assert!(plan.stall_for(2).is_some());
        assert!(plan.stall_for(3).is_some());
        assert!(plan.stall_for(4).is_none());
        assert!(!plan.crashes_at(6) && plan.crashes_at(7));
        assert!(!plan.corrupts_at(7) && plan.corrupts_at(9));
        assert!(!plan.write_crashes_at(2) && plan.write_crashes_at(3));
        assert!(ShardFaultPlan::default().stall_for(0).is_none());
        assert!(!ShardFaultPlan::default().write_crashes_at(0));
    }

    #[test]
    fn write_quorum_defaults_to_majority_and_clamps() {
        let cfg = ReplicaConfig::default();
        assert_eq!(cfg.effective_write_quorum(1), 1);
        assert_eq!(cfg.effective_write_quorum(2), 2);
        assert_eq!(cfg.effective_write_quorum(3), 2);
        assert_eq!(cfg.effective_write_quorum(5), 3);
        let all = ReplicaConfig { write_quorum: Some(99), ..Default::default() };
        assert_eq!(all.effective_write_quorum(3), 3);
        let one = ReplicaConfig { write_quorum: Some(0), ..Default::default() };
        assert_eq!(one.effective_write_quorum(3), 1);
    }

    #[test]
    fn corruptor_flips_body_bytes() {
        let dir = std::env::temp_dir().join("alsh-replica-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("corrupt_{}.bin", std::process::id()));
        let clean: Vec<u8> = (0..4096u32).map(|i| (i % 251) as u8).collect();
        std::fs::write(&path, &clean).unwrap();
        corrupt_index_file(&path).unwrap();
        let dirty = std::fs::read(&path).unwrap();
        assert_eq!(dirty.len(), clean.len());
        let flipped = clean.iter().zip(&dirty).filter(|(a, b)| a != b).count();
        assert_eq!(flipped, 65, "expected a 65-byte corruption burst");
        // Too-small files are refused rather than half-corrupted.
        let tiny = dir.join("tiny.bin");
        std::fs::write(&tiny, [0u8; 16]).unwrap();
        assert!(corrupt_index_file(&tiny).is_err());
    }
}

//! L2LSH collision probability F_r(d)  (Eq. 9–10, Datar et al. 2004).

use super::normal::normal_cdf;

/// Collision probability of two points at L2 distance `d` under the
/// quantized random-projection hash `h(x) = floor((aᵀx + b) / r)`:
///
/// ```text
/// F_r(d) = 1 - 2Φ(-r/d) - (2 / (sqrt(2π) (r/d))) (1 - e^{-(r/d)²/2})
/// ```
///
/// Monotonically decreasing in `d`. At `d -> 0` it tends to 1; at
/// `d -> ∞` it tends to 0.
pub fn collision_probability(r: f64, d: f64) -> f64 {
    assert!(r > 0.0, "r must be positive");
    if d <= 0.0 {
        return 1.0;
    }
    let t = r / d;
    let p = 1.0 - 2.0 * normal_cdf(-t)
        - 2.0 / ((2.0 * std::f64::consts::PI).sqrt() * t) * (1.0 - (-(t * t) / 2.0).exp());
    p.clamp(0.0, 1.0)
}

/// Collision probability of two vectors at angle `θ = cos⁻¹(cos_theta)`
/// under a sign random projection `h(x) = 1[aᵀx >= 0]` (Goemans &
/// Williamson 1995; the SimHash engine of Sign-ALSH and Simple-LSH):
///
/// ```text
/// P[h(x) = h(y)] = 1 − θ/π
/// ```
///
/// Monotonically increasing in `cos_theta`: 1 at cos = 1 (θ = 0), ½ at
/// cos = 0 (orthogonal), 0 at cos = −1 (antipodal).
pub fn srp_collision_probability(cos_theta: f64) -> f64 {
    let theta = cos_theta.clamp(-1.0, 1.0).acos();
    (1.0 - theta / std::f64::consts::PI).clamp(0.0, 1.0)
}

/// Monte-Carlo estimate of the SRP collision probability (validation
/// only, the SimHash twin of [`collision_probability_mc`]): draws `n`
/// projections `a ~ N(0, I₂)` against the planar pair `u = (1, 0)`,
/// `v = (cos θ, sin θ)` — WLOG, since SRP collision depends only on the
/// angle within the pair's span — and counts sign agreements.
pub fn srp_collision_probability_mc(
    cos_theta: f64,
    n: usize,
    rng: &mut crate::util::Rng,
) -> f64 {
    let theta = cos_theta.clamp(-1.0, 1.0).acos();
    let (sin_t, cos_t) = theta.sin_cos();
    let mut hits = 0usize;
    for _ in 0..n {
        let a0: f64 = rng.normal_f64();
        let a1: f64 = rng.normal_f64();
        let su = a0 >= 0.0;
        let sv = a0 * cos_t + a1 * sin_t >= 0.0;
        if su == sv {
            hits += 1;
        }
    }
    hits as f64 / n as f64
}

/// Monte-Carlo estimate of the collision probability (validation only):
/// draws `n` (a, b) pairs and counts collisions of two 1-D points at
/// distance `d`. Used by tests to validate the closed form.
pub fn collision_probability_mc(r: f64, d: f64, n: usize, rng: &mut crate::util::Rng) -> f64 {
    let mut hits = 0usize;
    for _ in 0..n {
        let a: f64 = rng.normal_f64();
        let b: f64 = rng.f64() * r;
        // Points 0 and d on a line; projections 0*a and d*a.
        let h1 = ((b) / r).floor();
        let h2 = ((a * d + b) / r).floor();
        if h1 == h2 {
            hits += 1;
        }
    }
    hits as f64 / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn limits() {
        assert!((collision_probability(2.5, 1e-9) - 1.0).abs() < 1e-6);
        assert!(collision_probability(2.5, 1e9) < 1e-6);
        assert_eq!(collision_probability(2.5, 0.0), 1.0);
    }

    #[test]
    fn monotone_decreasing_in_d() {
        for r in [0.5, 1.0, 2.5, 5.0] {
            let mut prev = 1.0;
            let mut d = 0.01;
            while d < 10.0 {
                let p = collision_probability(r, d);
                assert!(p <= prev + 1e-9, "F_{r}({d}) not decreasing");
                assert!((0.0..=1.0).contains(&p));
                prev = p;
                d += 0.01;
            }
        }
    }

    #[test]
    fn monotone_increasing_in_r() {
        // Wider buckets collide more.
        let mut prev = 0.0;
        for i in 1..100 {
            let r = i as f64 * 0.1;
            let p = collision_probability(r, 1.0);
            assert!(p >= prev - 1e-9);
            prev = p;
        }
    }

    #[test]
    fn matches_monte_carlo() {
        let mut rng = Rng::seed_from_u64(12);
        for (r, d) in [(2.5, 1.0), (1.0, 1.0), (2.0, 3.0), (4.0, 0.5)] {
            let closed = collision_probability(r, d);
            let mc = collision_probability_mc(r, d, 200_000, &mut rng);
            assert!(
                (closed - mc).abs() < 5e-3,
                "F_{r}({d}): closed {closed} vs mc {mc}"
            );
        }
    }

    #[test]
    fn srp_limits_and_monotonicity() {
        assert!((srp_collision_probability(1.0) - 1.0).abs() < 1e-12);
        assert!((srp_collision_probability(0.0) - 0.5).abs() < 1e-12);
        assert!(srp_collision_probability(-1.0).abs() < 1e-12);
        // Out-of-range cosines clamp instead of NaN.
        assert_eq!(srp_collision_probability(1.5), 1.0);
        let mut prev = 0.0;
        for i in -100..=100 {
            let p = srp_collision_probability(i as f64 / 100.0);
            assert!(p >= prev - 1e-12, "not increasing in cos θ");
            assert!((0.0..=1.0).contains(&p));
            prev = p;
        }
    }

    /// The Monte-Carlo validation the Sign-ALSH ρ curves rest on: the
    /// closed form 1 − θ/π matches sampled sign random projections.
    #[test]
    fn srp_matches_monte_carlo() {
        let mut rng = Rng::seed_from_u64(21);
        for cos_theta in [0.95, 0.7, 0.3, 0.0, -0.5, -0.9] {
            let closed = srp_collision_probability(cos_theta);
            let mc = srp_collision_probability_mc(cos_theta, 200_000, &mut rng);
            assert!(
                (closed - mc).abs() < 5e-3,
                "SRP p(cos={cos_theta}): closed {closed} vs mc {mc}"
            );
        }
    }

    #[test]
    fn depends_only_on_ratio() {
        // F_r(d) is a function of r/d only.
        let a = collision_probability(2.5, 1.0);
        let b = collision_probability(5.0, 2.0);
        assert!((a - b).abs() < 1e-12);
    }
}

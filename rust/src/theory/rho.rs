//! The query-time exponent ρ for ALSH-for-MIPS and its grid-search
//! optimizer ρ\* (Eq. 19–20) — the math behind Figures 1–3 — plus the
//! **Sign-ALSH** exponent (Shrivastava & Li 2015, "Improved ALSH for
//! MIPS") behind the scheme-comparison figure
//! (`figures::theory_figs::fig9_sign_vs_l2`).
//!
//! # Sign-ALSH ρ
//!
//! Under the sign transforms `P(x) = [x; ½−‖x‖²; …; ½−‖x‖^(2^m)]`,
//! `Q(q) = [q/‖q‖; 0; …]` with data scaled so `‖x‖ <= U`, the
//! transformed pair satisfies `Q(q)·P(x) = qᵀx`, `‖Q(q)‖ = 1` and
//! `‖P(x)‖² = m/4 + ‖x‖^(2^(m+1))` (telescoping the appended squares),
//! so SRP collision probability is `1 − cos⁻¹(z)/π` with
//! `z = qᵀx / √(m/4 + ‖x‖^(2^(m+1)))`. Over the good side (`qᵀx >= S0`,
//! `‖x‖ <= U`) the worst case is `z₁ = S0/√(m/4 + U^(2^(m+1)))`; over
//! the bad side (`qᵀx <= cS0`, and `‖x‖ >= qᵀx` for unit q) the best
//! case is `z₂ = cS0/√(m/4 + (cS0)^(2^(m+1)))` — giving
//! `ρ = log p(z₁) / log p(z₂)`. There is no quantization width r and no
//! additive error term: only (m, U) remain, and the resulting ρ\*
//! **dominates** L2-ALSH's everywhere on the paper's grid (validated in
//! `figures::theory_figs` tests against the closed forms here, which the
//! `srp_matches_monte_carlo` test pins to sampled projections).

use super::collision::{collision_probability, srp_collision_probability};

/// p1 for a c-approximate MIPS instance: collision probability at the
/// *good* side (qᵀx >= S0), including the transform error term U^(2^(m+1)).
pub fn p1_alsh(s0: f64, u: f64, m: u32, r: f64) -> f64 {
    let err = u.powi(2i32.pow(m + 1));
    let d2 = 1.0 + m as f64 / 4.0 - 2.0 * s0 + err;
    collision_probability(r, d2.max(0.0).sqrt())
}

/// p2: collision probability at the *bad* side (qᵀx <= c·S0).
pub fn p2_alsh(s0: f64, c: f64, m: u32, r: f64) -> f64 {
    let d2 = 1.0 + m as f64 / 4.0 - 2.0 * c * s0;
    collision_probability(r, d2.max(0.0).sqrt())
}

/// ρ = log p1 / log p2  (Eq. 19). Returns `None` when the parameters are
/// infeasible (p1 <= p2, i.e. no sublinear guarantee).
pub fn rho_alsh(s0: f64, c: f64, u: f64, m: u32, r: f64) -> Option<f64> {
    // Feasibility (Sec 3.4): U^(2^(m+1)) / (2 S0) < 1 - c.
    let err = u.powi(2i32.pow(m + 1));
    if err / (2.0 * s0) >= 1.0 - c {
        return None;
    }
    let p1 = p1_alsh(s0, u, m, r);
    let p2 = p2_alsh(s0, c, m, r);
    if !(p1 > p2 && p1 < 1.0 && p2 > 0.0) {
        return None;
    }
    let rho = p1.ln() / p2.ln();
    (rho.is_finite() && rho > 0.0).then_some(rho)
}

/// Sign-ALSH p1: SRP collision probability at the good side's worst-case
/// cosine `S0 / √(m/4 + U^(2^(m+1)))`.
pub fn p1_sign_alsh(s0: f64, u: f64, m: u32) -> f64 {
    let denom = (m as f64 / 4.0 + u.powi(2i32.pow(m + 1))).sqrt();
    srp_collision_probability(s0 / denom)
}

/// Sign-ALSH p2: SRP collision probability at the bad side's best-case
/// cosine `cS0 / √(m/4 + (cS0)^(2^(m+1)))`.
pub fn p2_sign_alsh(s0: f64, c: f64, m: u32) -> f64 {
    let t = c * s0;
    let denom = (m as f64 / 4.0 + t.powi(2i32.pow(m + 1))).sqrt();
    srp_collision_probability(t / denom)
}

/// Sign-ALSH ρ = log p1 / log p2. Returns `None` when infeasible
/// (p1 <= p2: no sublinear guarantee at these parameters).
pub fn rho_sign_alsh(s0: f64, c: f64, u: f64, m: u32) -> Option<f64> {
    let p1 = p1_sign_alsh(s0, u, m);
    let p2 = p2_sign_alsh(s0, c, m);
    if !(p1 > p2 && p1 < 1.0 && p2 > 0.0) {
        return None;
    }
    let rho = p1.ln() / p2.ln();
    (rho.is_finite() && rho > 0.0).then_some(rho)
}

/// ρ\* for Sign-ALSH: min over the grid's (m, U) of [`rho_sign_alsh`]
/// at `S0 = s0_frac · U` (SRP has no quantization width, so the grid's
/// `rs` axis is unused and the reported `r` is 0).
pub fn optimize_rho_sign(s0_frac: f64, c: f64, grid: &GridSpec) -> Option<RhoOpt> {
    let mut best: Option<RhoOpt> = None;
    for &m in &grid.ms {
        for &u in &grid.us {
            let s0 = s0_frac * u;
            if s0 <= 0.0 {
                continue;
            }
            if let Some(rho) = rho_sign_alsh(s0, c, u, m) {
                if best.map_or(true, |b| rho < b.rho) {
                    best = Some(RhoOpt { rho, m, u, r: 0.0 });
                }
            }
        }
    }
    best
}

/// Search grid for the ρ\* optimization (Eq. 20).
#[derive(Clone, Debug)]
pub struct GridSpec {
    /// Candidate m values (paper: small integers).
    pub ms: Vec<u32>,
    /// U grid over (0, 1).
    pub us: Vec<f64>,
    /// r grid over (0, ∞).
    pub rs: Vec<f64>,
}

impl Default for GridSpec {
    fn default() -> Self {
        Self {
            ms: (1..=6).collect(),
            us: (1..100).map(|i| i as f64 * 0.01).collect(),
            rs: (1..=50).map(|i| i as f64 * 0.1).collect(),
        }
    }
}

impl GridSpec {
    /// A coarser grid for tests and quick sweeps.
    pub fn coarse() -> Self {
        Self {
            ms: (1..=5).collect(),
            us: (1..20).map(|i| i as f64 * 0.05).collect(),
            rs: (1..=20).map(|i| i as f64 * 0.25).collect(),
        }
    }
}

/// Result of the ρ\* grid search.
#[derive(Clone, Copy, Debug)]
pub struct RhoOpt {
    pub rho: f64,
    pub m: u32,
    pub u: f64,
    pub r: f64,
}

/// ρ\* = min over (U, m, r) of ρ, for threshold `S0 = s0_frac · U` and
/// approximation ratio `c` (Eq. 20; Figure 1–2). `S0` scales with `U`
/// because the transform first shrinks all data so max norm = U, and the
/// achievable inner product is at most U.
pub fn optimize_rho(s0_frac: f64, c: f64, grid: &GridSpec) -> Option<RhoOpt> {
    let mut best: Option<RhoOpt> = None;
    for &m in &grid.ms {
        for &u in &grid.us {
            let s0 = s0_frac * u;
            if s0 <= 0.0 {
                continue;
            }
            for &r in &grid.rs {
                if let Some(rho) = rho_alsh(s0, c, u, m, r) {
                    if best.map_or(true, |b| rho < b.rho) {
                        best = Some(RhoOpt { rho, m, u, r });
                    }
                }
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p1_exceeds_p2_for_reasonable_params() {
        // Paper's recommended operating point.
        let (s0, c, u, m, r) = (0.9 * 0.83, 0.5, 0.83, 3, 2.5);
        assert!(p1_alsh(s0, u, m, r) > p2_alsh(s0, c, m, r));
    }

    #[test]
    fn rho_is_sublinear_at_recommended_params() {
        let rho = rho_alsh(0.9 * 0.83, 0.5, 0.83, 3, 2.5).expect("feasible");
        assert!(rho > 0.0 && rho < 1.0, "rho = {rho}");
    }

    #[test]
    fn rho_decreases_as_c_decreases() {
        // Easier approximation (smaller c) => smaller exponent.
        let grid = GridSpec::coarse();
        let r_09 = optimize_rho(0.9, 0.9, &grid).unwrap().rho;
        let r_05 = optimize_rho(0.9, 0.5, &grid).unwrap().rho;
        let r_02 = optimize_rho(0.9, 0.2, &grid).unwrap().rho;
        assert!(r_02 < r_05 && r_05 < r_09, "{r_02} {r_05} {r_09}");
    }

    #[test]
    fn rho_star_below_one_everywhere_feasible() {
        let grid = GridSpec::coarse();
        for s0_frac in [0.5, 0.7, 0.9] {
            for c10 in 1..10 {
                let c = c10 as f64 * 0.1;
                if let Some(opt) = optimize_rho(s0_frac, c, &grid) {
                    assert!(opt.rho < 1.0, "rho*({s0_frac},{c}) = {}", opt.rho);
                    assert!(opt.rho > 0.0);
                }
            }
        }
    }

    #[test]
    fn infeasible_when_error_dominates() {
        // Big U, tiny m, c close to 1: the error term kills the gap.
        assert!(rho_alsh(0.9 * 0.99, 0.999, 0.99, 1, 2.5).is_none());
    }

    #[test]
    fn sign_rho_sublinear_at_recommended_params() {
        // Shrivastava & Li 2015's recommended (m=2, U=0.75).
        let rho = rho_sign_alsh(0.9 * 0.75, 0.5, 0.75, 2).expect("feasible");
        assert!(rho > 0.0 && rho < 1.0, "sign rho = {rho}");
        // And it beats the L2-ALSH recommended point at the same task.
        let l2 = rho_alsh(0.9 * 0.83, 0.5, 0.83, 3, 2.5).unwrap();
        assert!(rho < l2, "sign {rho} !< l2 {l2}");
    }

    #[test]
    fn sign_rho_increases_in_c() {
        let grid = GridSpec::coarse();
        let r_02 = optimize_rho_sign(0.9, 0.2, &grid).unwrap().rho;
        let r_05 = optimize_rho_sign(0.9, 0.5, &grid).unwrap().rho;
        let r_09 = optimize_rho_sign(0.9, 0.9, &grid).unwrap().rho;
        assert!(r_02 < r_05 && r_05 < r_09, "{r_02} {r_05} {r_09}");
    }

    /// The Shrivastava & Li 2015 headline: Sign-ALSH ρ* dominates
    /// L2-ALSH ρ* across the whole (S0, c) plane.
    #[test]
    fn sign_rho_star_dominates_l2_everywhere() {
        let grid = GridSpec::coarse();
        for s0_frac in [0.5, 0.7, 0.9] {
            for c10 in 1..10 {
                let c = c10 as f64 * 0.1;
                let l2 = optimize_rho(s0_frac, c, &grid);
                let sign = optimize_rho_sign(s0_frac, c, &grid);
                if let (Some(l2), Some(sign)) = (l2, sign) {
                    assert!(sign.rho > 0.0 && sign.rho < 1.0);
                    assert!(
                        sign.rho <= l2.rho + 1e-9,
                        "sign rho*({s0_frac},{c}) = {} > l2 {}",
                        sign.rho,
                        l2.rho
                    );
                }
            }
        }
    }

    /// p1/p2 sanity: good side collides more, and both are genuine
    /// probabilities.
    #[test]
    fn sign_p1_exceeds_p2_for_reasonable_params() {
        let (s0, c, u, m) = (0.9 * 0.75, 0.5, 0.75, 2);
        let p1 = p1_sign_alsh(s0, u, m);
        let p2 = p2_sign_alsh(s0, c, m);
        assert!(p1 > p2, "{p1} vs {p2}");
        assert!((0.0..=1.0).contains(&p1) && (0.0..=1.0).contains(&p2));
    }

    #[test]
    fn sign_infeasible_when_no_gap() {
        // c = 1: the good and bad sides coincide — no gap, no guarantee.
        assert!(rho_sign_alsh(0.9 * 0.75, 1.0, 0.75, 2).is_none());
    }

    #[test]
    fn optimal_params_in_paper_range() {
        // Fig 2: for high S0 (0.8–0.9 U) and mid c, optimum is m∈{2,3,4},
        // U∈[0.7,0.9], r∈[1.5,3].
        let grid = GridSpec::default();
        let opt = optimize_rho(0.9, 0.5, &grid).unwrap();
        assert!((2..=4).contains(&opt.m), "m = {}", opt.m);
        assert!((0.7..=0.92).contains(&opt.u), "U = {}", opt.u);
        assert!((1.0..=3.5).contains(&opt.r), "r = {}", opt.r);
    }

    #[test]
    fn recommended_params_near_optimal() {
        // Fig 3: ρ(m=3, U=0.83, r=2.5) tracks ρ* closely.
        let grid = GridSpec::default();
        for c10 in 2..=8 {
            let c = c10 as f64 * 0.1;
            let star = optimize_rho(0.9, c, &grid).unwrap().rho;
            let fixed = rho_alsh(0.9 * 0.83, c, 0.83, 3, 2.5).unwrap();
            assert!(fixed >= star - 1e-9);
            assert!(fixed - star < 0.12, "c={c}: fixed {fixed} vs star {star}");
        }
    }
}

//! Standard-normal special functions (no external math crates).

/// Error function, Abramowitz & Stegun 7.1.26 (max abs error ~1.5e-7).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    const A1: f64 = 0.254829592;
    const A2: f64 = -0.284496736;
    const A3: f64 = 1.421413741;
    const A4: f64 = -1.453152027;
    const A5: f64 = 1.061405429;
    const P: f64 = 0.3275911;
    let t = 1.0 / (1.0 + P * x);
    let y = 1.0 - (((((A5 * t + A4) * t) + A3) * t + A2) * t + A1) * t * (-x * x).exp();
    sign * y
}

/// Standard normal CDF Φ(x).
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Standard normal PDF φ(x).
pub fn normal_pdf(x: f64) -> f64 {
    (-(x * x) / 2.0).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        // Reference values from tables.
        let cases = [
            (0.0, 0.0),
            (0.5, 0.5204998778),
            (1.0, 0.8427007929),
            (2.0, 0.9953222650),
            (3.0, 0.9999779095),
        ];
        for (x, want) in cases {
            assert!((erf(x) - want).abs() < 2e-7, "erf({x}) = {} != {want}", erf(x));
        }
    }

    #[test]
    fn erf_is_odd() {
        for x in [0.1, 0.7, 1.3, 2.5] {
            assert!((erf(-x) + erf(x)).abs() < 1e-12);
        }
    }

    #[test]
    fn cdf_known_values() {
        let cases = [
            (0.0, 0.5),
            (1.0, 0.8413447461),
            (-1.0, 0.1586552539),
            (1.959964, 0.975),
            (-2.575829, 0.005),
        ];
        for (x, want) in cases {
            assert!(
                (normal_cdf(x) - want).abs() < 1e-6,
                "Φ({x}) = {} != {want}",
                normal_cdf(x)
            );
        }
    }

    #[test]
    fn cdf_monotone_and_bounded() {
        let mut prev = 0.0;
        let mut x = -6.0;
        while x <= 6.0 {
            let p = normal_cdf(x);
            assert!((0.0..=1.0).contains(&p));
            assert!(p + 1e-9 >= prev, "CDF not monotone at {x}");
            prev = p;
            x += 0.01;
        }
    }

    #[test]
    fn pdf_integrates_to_one() {
        let mut sum = 0.0;
        let h = 0.001;
        let mut x = -8.0;
        while x <= 8.0 {
            sum += normal_pdf(x) * h;
            x += h;
        }
        assert!((sum - 1.0).abs() < 1e-4);
    }
}

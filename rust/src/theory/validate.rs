//! Empirical validation of Theorem 3: the measured collision probability
//! of `h(Q(q)) = h(P(x))` must obey the paper's bounds
//!
//! * `qᵀx >= S0`   ⇒  P[collision] >= F_r(√(1 + m/4 − 2·S0 + U^(2^(m+1))))
//! * `qᵀx <= c·S0` ⇒  P[collision] <= F_r(√(1 + m/4 − 2·c·S0))
//!
//! and, pointwise, equal `F_r(‖Q(q) − P(x)‖)` exactly (Eq. 9 applied to
//! the transformed pair). The `repro validate` CLI prints this table; the
//! tests assert it.

use crate::lsh::L2LshFamily;
use crate::theory::collision_probability;
use crate::transform::{l2_norm, p_transform, q_transform};
use crate::util::Rng;

/// One row of the validation table.
#[derive(Clone, Debug)]
pub struct ValidationRow {
    /// Inner product of the (unit q, bounded x) pair.
    pub ip: f64,
    /// Transformed distance ‖Q(q) − P(x)‖.
    pub dist: f64,
    /// Empirical collision fraction over `n_hashes` functions.
    pub empirical: f64,
    /// Closed-form F_r(dist).
    pub theoretical: f64,
}

/// Build pairs (q, x) with controlled inner products and measure the
/// asymmetric collision rate against `F_r`.
pub fn validate_theorem3(
    dim: usize,
    m: usize,
    u: f32,
    r: f32,
    n_hashes: usize,
    seed: u64,
) -> Vec<ValidationRow> {
    let mut rng = Rng::seed_from_u64(seed);
    let fam = L2LshFamily::sample(dim + m, n_hashes, r, &mut rng);
    // Unit query.
    let mut q: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
    let qn = l2_norm(&q);
    q.iter_mut().for_each(|v| *v /= qn);
    let hq = fam.hash(&q_transform(&q, m));

    let mut rows = Vec::new();
    // x = alpha * u * q + beta * orthogonal noise, with ‖x‖ = u exactly:
    // sweeping alpha sweeps the inner product qᵀx = alpha * u.
    for step in 0..=10 {
        let alpha = -1.0 + 0.2 * step as f32;
        let mut noise: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
        // Orthogonalize the noise against q.
        let proj: f32 = noise.iter().zip(&q).map(|(n, qv)| n * qv).sum();
        noise.iter_mut().zip(&q).for_each(|(n, qv)| *n -= proj * qv);
        let nn = l2_norm(&noise).max(1e-9);
        let beta = (1.0 - alpha * alpha).max(0.0).sqrt();
        let x: Vec<f32> = q
            .iter()
            .zip(&noise)
            .map(|(qv, nv)| u * (alpha * qv + beta * nv / nn))
            .collect();
        let ip: f32 = q.iter().zip(&x).map(|(a, b)| a * b).sum();
        let pq = q_transform(&q, m);
        let px = p_transform(&x, m);
        let dist: f64 = pq
            .iter()
            .zip(&px)
            .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let hx = fam.hash(&px);
        let collisions = hq.iter().zip(&hx).filter(|(a, b)| a == b).count();
        rows.push(ValidationRow {
            ip: ip as f64,
            dist,
            empirical: collisions as f64 / n_hashes as f64,
            theoretical: collision_probability(r as f64, dist),
        });
    }
    rows
}

/// CSV rendering for the CLI (`ip,dist,empirical,theoretical`).
pub fn validation_csv(rows: &[ValidationRow]) -> String {
    let mut out = String::from("ip,transformed_dist,empirical_collision,F_r\n");
    for row in rows {
        out.push_str(&format!(
            "{:.4},{:.4},{:.4},{:.4}\n",
            row.ip, row.dist, row.empirical, row.theoretical
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows() -> Vec<ValidationRow> {
        validate_theorem3(24, 3, 0.83, 2.5, 20_000, 42)
    }

    #[test]
    fn empirical_matches_closed_form_pointwise() {
        // Eq. 9 on the transformed pair: empirical ≈ F_r(dist) everywhere.
        for row in rows() {
            assert!(
                (row.empirical - row.theoretical).abs() < 0.015,
                "ip {:.2}: empirical {:.4} vs F_r {:.4}",
                row.ip,
                row.empirical,
                row.theoretical
            );
        }
    }

    #[test]
    fn collision_monotone_in_inner_product() {
        // The whole point: bigger qᵀx ⇒ more collisions.
        let rows = rows();
        for w in rows.windows(2) {
            assert!(
                w[1].empirical >= w[0].empirical - 0.02,
                "collision not increasing: ip {:.2}→{:.2} gave {:.4}→{:.4}",
                w[0].ip,
                w[1].ip,
                w[0].empirical,
                w[1].empirical
            );
        }
    }

    #[test]
    fn theorem3_bounds_hold() {
        // p1 bound at S0 = 0.8U, p2 bound at c = 0.5.
        let (m, u, r) = (3usize, 0.83f64, 2.5f64);
        let s0 = 0.8 * u;
        let c = 0.5;
        let p1_bound =
            collision_probability(r, (1.0 + m as f64 / 4.0 - 2.0 * s0 + u.powi(16)).sqrt());
        let p2_bound =
            collision_probability(r, (1.0 + m as f64 / 4.0 - 2.0 * c * s0).sqrt());
        for row in rows() {
            if row.ip >= s0 {
                assert!(
                    row.empirical >= p1_bound - 0.02,
                    "p1 bound violated at ip {:.2}: {:.4} < {:.4}",
                    row.ip,
                    row.empirical,
                    p1_bound
                );
            }
            if row.ip <= c * s0 {
                assert!(
                    row.empirical <= p2_bound + 0.02,
                    "p2 bound violated at ip {:.2}: {:.4} > {:.4}",
                    row.ip,
                    row.empirical,
                    p2_bound
                );
            }
        }
    }

    #[test]
    fn csv_well_formed() {
        let csv = validation_csv(&rows());
        assert_eq!(csv.lines().count(), 12); // header + 11 alpha steps
        for line in csv.lines().skip(1) {
            assert_eq!(line.split(',').count(), 4);
        }
    }
}

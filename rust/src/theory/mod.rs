//! The paper's theory, executable: collision probabilities, ρ exponents,
//! and the grid-search optimizer behind Figures 1–4 — plus the Sign-ALSH
//! collision probability and ρ\* (Shrivastava & Li 2015) behind the
//! scheme-comparison figure.

pub mod collision;
pub mod normal;
pub mod rho;
pub mod validate;

pub use collision::{
    collision_probability, srp_collision_probability, srp_collision_probability_mc,
};
pub use normal::{erf, normal_cdf};
pub use rho::{
    optimize_rho, optimize_rho_sign, rho_alsh, rho_sign_alsh, GridSpec, RhoOpt,
};
pub use validate::{validate_theorem3, validation_csv, ValidationRow};

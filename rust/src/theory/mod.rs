//! The paper's theory, executable: collision probabilities, ρ exponents,
//! and the grid-search optimizer behind Figures 1–4.

pub mod collision;
pub mod normal;
pub mod rho;
pub mod validate;

pub use collision::collision_probability;
pub use normal::{erf, normal_cdf};
pub use rho::{optimize_rho, rho_alsh, GridSpec, RhoOpt};
pub use validate::{validate_theorem3, validation_csv, ValidationRow};

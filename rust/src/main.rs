//! `repro` — the ALSH-MIPS leader binary.
//!
//! ```text
//! repro figure <1..8> [--dataset D] [--users N] [--out-dir results]
//! repro serve  [--dataset tiny] [--addr 127.0.0.1:7878] [--artifacts artifacts]
//!              [--max-batch 64] [--max-wait-us 2000] [--tables 32] [--codes-per-table 6]
//! repro query  [--dataset tiny] [--top-k 10] [--n-queries 5]
//! repro info   [--artifacts artifacts] [--dataset tiny]
//! ```

use std::path::{Path, PathBuf};
use std::sync::Arc;

use alsh::config::{DatasetConfig, PrExperimentConfig};
use alsh::coordinator::{serve, BatcherConfig, MipsEngine, PjrtBatcher, ServeConfig};
use alsh::data::generate_dataset;
use alsh::figures;
use alsh::index::AlshParams;
use alsh::theory::GridSpec;
use alsh::util::cli::Args;
use alsh::{log_error, log_info};

const USAGE: &str = "\
repro — ALSH for sublinear-time MIPS (NIPS 2014) reproduction

USAGE:
  repro figure <1..9> [--dataset movielens|netflix|tiny] [--users N]
                      [--out-dir results] [--coarse]
  repro serve  [--dataset tiny] [--addr 127.0.0.1:7878] [--artifacts artifacts]
               [--max-batch 64] [--max-wait-us 2000] [--tables 32]
               [--codes-per-table 6]
  repro query  [--dataset tiny] [--top-k 10] [--n-queries 5]
  repro validate [--dim 24] [--m 3] [--u 0.83] [--r 2.5] [--hashes 20000]
  repro info   [--artifacts artifacts] [--dataset tiny]

Figures: 1 rho* vs c | 2 optimal (m,U,r) | 3 recommended params |
         4 collision prob | 5 Movielens PR | 6 Netflix PR | 7 r-sweep |
         8 L2-ALSH vs Sign-ALSH ablation (extension)
";

fn main() {
    alsh::util::log::init();
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match args.positional.first().map(|s| s.as_str()) {
        Some("figure") => run_figure(&args),
        Some("serve") => run_serve(&args),
        Some("query") => run_query(&args),
        Some("validate") => run_validate(&args),
        Some("info") => run_info(&args),
        _ => {
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        log_error!("{e:#}");
        std::process::exit(1);
    }
}

fn parse_flags(args: &Args) -> anyhow::Result<PrExperimentConfig> {
    let mut cfg = PrExperimentConfig::default();
    if let Some(u) = args.get_parse::<usize>("users").map_err(anyhow::Error::msg)? {
        cfg.n_users = u;
    }
    Ok(cfg)
}

fn run_figure(args: &Args) -> anyhow::Result<()> {
    let n: u32 = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("figure number required (1-9)"))?
        .parse()?;
    let out_dir = PathBuf::from(args.get_or("out-dir", "results"));
    let pr_cfg = parse_flags(args)?;
    let grid = if args.has("coarse") { GridSpec::coarse() } else { GridSpec::default() };
    let (name, csv) = match n {
        1 => ("fig1_rho_star".to_string(), figures::fig1_rho_star(&grid)),
        2 => ("fig2_optimal_params".to_string(), figures::fig2_optimal_params(&grid)),
        3 => ("fig3_recommended".to_string(), figures::fig3_recommended(&grid)),
        4 => ("fig4_collision".to_string(), figures::fig4_collision()),
        5 | 6 => {
            let ds = match args.get("dataset") {
                Some(d) => DatasetConfig::by_name(d)?,
                None if n == 5 => DatasetConfig::movielens_like(),
                None => DatasetConfig::netflix_like(),
            };
            log_info!("figure {n}: dataset={} users={}", ds.name, pr_cfg.n_users);
            let points = figures::run_pr_figure(&ds, &pr_cfg)?;
            let mut csv = figures::pr_figs::PR_CSV_HEADER.to_string();
            for p in &points {
                csv.push_str(&p.csv_rows());
            }
            (format!("fig{n}_{}", ds.name), csv)
        }
        7 => {
            let ds = match args.get("dataset") {
                Some(d) => DatasetConfig::by_name(d)?,
                None => DatasetConfig::movielens_like(),
            };
            log_info!("figure 7: dataset={} users={}", ds.name, pr_cfg.n_users);
            let points = figures::fig7_r_sensitivity(&ds, &pr_cfg)?;
            let mut csv = figures::pr_figs::PR_CSV_HEADER.to_string();
            for p in &points {
                csv.push_str(&p.csv_rows());
            }
            (format!("fig7_{}", ds.name), csv)
        }
        8 => {
            let ds = match args.get("dataset") {
                Some(d) => DatasetConfig::by_name(d)?,
                None => DatasetConfig::movielens_like(),
            };
            log_info!(
                "figure 8 (extension): L2-ALSH vs Sign-ALSH, dataset={} users={}",
                ds.name,
                pr_cfg.n_users
            );
            let points = figures::fig8_sign_ablation(&ds, &pr_cfg)?;
            let mut csv = figures::pr_figs::PR_CSV_HEADER.to_string();
            for p in &points {
                csv.push_str(&p.csv_rows());
            }
            (format!("fig8_{}", ds.name), csv)
        }
        9 => (
            "fig9_sign_vs_l2_rho".to_string(),
            figures::fig9_sign_vs_l2(&grid),
        ),
        other => anyhow::bail!("unknown figure {other} (1-9)"),
    };
    print!("{csv}");
    let path = figures::write_csv(&out_dir, &name, &csv)?;
    log_info!("wrote {}", path.display());
    Ok(())
}

fn run_serve(args: &Args) -> anyhow::Result<()> {
    let ds = DatasetConfig::by_name(args.get_or("dataset", "tiny"))?;
    let addr = args.get_or("addr", "127.0.0.1:7878").to_string();
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let max_batch = args.get_parse_or("max-batch", 64usize).map_err(anyhow::Error::msg)?;
    let max_wait_us =
        args.get_parse_or("max-wait-us", 2000u64).map_err(anyhow::Error::msg)?;
    let tables = args.get_parse_or("tables", 32usize).map_err(anyhow::Error::msg)?;
    let codes = args.get_parse_or("codes-per-table", 6usize).map_err(anyhow::Error::msg)?;

    log_info!("building dataset {} (PureSVD f={})", ds.name, ds.latent_dim);
    let data = generate_dataset(&ds)?;
    let params =
        AlshParams { n_tables: tables, k_per_table: codes, ..AlshParams::default() };
    log_info!(
        "indexing {} items dim={} (L={} K={})",
        data.items.len(),
        data.latent_dim,
        params.n_tables,
        params.k_per_table
    );
    let engine = Arc::new(MipsEngine::new(&data.items, params, ds.seed ^ 0xA15));
    let batcher = PjrtBatcher::spawn(
        Arc::clone(&engine),
        artifacts,
        BatcherConfig {
            max_batch,
            max_wait: std::time::Duration::from_micros(max_wait_us),
            ..Default::default()
        },
    )?;
    serve(ServeConfig { addr, ..Default::default() }, batcher.handle(), engine)
}

fn run_query(args: &Args) -> anyhow::Result<()> {
    let ds = DatasetConfig::by_name(args.get_or("dataset", "tiny"))?;
    let top_k = args.get_parse_or("top-k", 10usize).map_err(anyhow::Error::msg)?;
    let n_queries =
        args.get_parse_or("n-queries", 5usize).map_err(anyhow::Error::msg)?;
    let data = generate_dataset(&ds)?;
    let engine = MipsEngine::new(&data.items, AlshParams::default(), ds.seed ^ 0xA15);
    for (i, user) in data.users.iter().take(n_queries).enumerate() {
        let hits = engine.query(user, top_k);
        let ids: Vec<u32> = hits.iter().map(|h| h.id).collect();
        println!(
            "user {i}: top-{top_k} items {ids:?} (best ip {:.4})",
            hits.first().map(|h| h.score).unwrap_or(f32::NAN)
        );
    }
    let snap = engine.metrics().snapshot();
    println!(
        "served {} queries, mean latency {:.0}µs, mean candidates {:.1}",
        snap.queries,
        snap.mean_latency_us,
        snap.candidates as f64 / snap.queries.max(1) as f64
    );
    Ok(())
}

/// Print the Theorem-3 empirical-vs-theory collision table.
fn run_validate(args: &Args) -> anyhow::Result<()> {
    let dim = args.get_parse_or("dim", 24usize).map_err(anyhow::Error::msg)?;
    let m = args.get_parse_or("m", 3usize).map_err(anyhow::Error::msg)?;
    let u = args.get_parse_or("u", 0.83f32).map_err(anyhow::Error::msg)?;
    let r = args.get_parse_or("r", 2.5f32).map_err(anyhow::Error::msg)?;
    let hashes = args.get_parse_or("hashes", 20_000usize).map_err(anyhow::Error::msg)?;
    let rows = alsh::theory::validate_theorem3(dim, m, u, r, hashes, 42);
    print!("{}", alsh::theory::validation_csv(&rows));
    Ok(())
}

fn run_info(args: &Args) -> anyhow::Result<()> {
    let artifacts = Path::new(args.get_or("artifacts", "artifacts"));
    match alsh::runtime::Runtime::load(artifacts) {
        Ok(rt) => {
            println!("artifacts ({}):", artifacts.display());
            for a in &rt.manifest().artifacts {
                println!(
                    "  {:<28} fn={:<10} d={} m={} k={} batch={}",
                    a.name, a.function, a.dim, a.m, a.k, a.batch
                );
            }
        }
        Err(e) => println!("artifacts not available: {e:#}"),
    }
    let ds = DatasetConfig::by_name(args.get_or("dataset", "tiny"))?;
    let data = generate_dataset(&ds)?;
    let norms: Vec<f32> = data.items.iter().map(|v| alsh::transform::l2_norm(v)).collect();
    let (mut mn, mut mx, mut sum) = (f32::MAX, 0.0f32, 0.0f64);
    for &n in &norms {
        mn = mn.min(n);
        mx = mx.max(n);
        sum += n as f64;
    }
    println!(
        "dataset {}: {} users, {} items, f={}, item-norm min/mean/max = {:.3}/{:.3}/{:.3}",
        data.name,
        data.users.len(),
        data.items.len(),
        data.latent_dim,
        mn,
        sum / norms.len() as f64,
        mx
    );
    Ok(())
}

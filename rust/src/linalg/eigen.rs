//! Cyclic Jacobi eigensolver for symmetric matrices.
//!
//! The randomized SVD reduces the problem to the eigendecomposition of the
//! small `k x k` Gram matrix `B Bᵀ`; Jacobi is simple, numerically robust,
//! and plenty fast at k <= a few hundred.

use super::dense::Mat;

/// Eigendecomposition of a symmetric matrix: `a = V diag(w) Vᵀ`.
///
/// Returns `(w, v)` with eigenvalues `w` sorted descending and eigenvectors
/// as *columns* of `v`.
pub fn symmetric_eigen(a: &Mat) -> (Vec<f64>, Mat) {
    let n = a.rows();
    assert_eq!(n, a.cols(), "symmetric_eigen needs a square matrix");
    let mut m = a.clone();
    let mut v = Mat::eye(n);
    let max_sweeps = 60;
    for _ in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-13 * (1.0 + m.fro_norm()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Apply the rotation on rows/cols p and q.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    // Extract + sort descending.
    let mut order: Vec<usize> = (0..n).collect();
    let w: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    order.sort_by(|&i, &j| w[j].partial_cmp(&w[i]).unwrap());
    let w_sorted: Vec<f64> = order.iter().map(|&i| w[i]).collect();
    let v_sorted = Mat::from_fn(n, n, |i, j| v[(i, order[j])]);
    (w_sorted, v_sorted)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_symmetric(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::seed_from_u64(seed);
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = rng.f64() * 2.0 - 1.0;
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        a
    }

    #[test]
    fn diagonal_matrix() {
        let a = Mat::from_fn(3, 3, |i, j| if i == j { (3 - i) as f64 } else { 0.0 });
        let (w, _v) = symmetric_eigen(&a);
        assert!((w[0] - 3.0).abs() < 1e-12);
        assert!((w[1] - 2.0).abs() < 1e-12);
        assert!((w[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let a = Mat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let (w, v) = symmetric_eigen(&a);
        assert!((w[0] - 3.0).abs() < 1e-12);
        assert!((w[1] - 1.0).abs() < 1e-12);
        // eigenvector for 3 is (1,1)/sqrt(2) up to sign
        let ratio = v[(0, 0)] / v[(1, 0)];
        assert!((ratio - 1.0).abs() < 1e-8);
    }

    #[test]
    fn reconstructs_matrix() {
        let a = rand_symmetric(12, 7);
        let (w, v) = symmetric_eigen(&a);
        // A ?= V diag(w) Vᵀ
        let mut vd = v.clone();
        for i in 0..12 {
            for j in 0..12 {
                vd[(i, j)] = v[(i, j)] * w[j];
            }
        }
        let recon = vd.matmul(&v.transpose());
        assert!(recon.max_abs_diff(&a) < 1e-9, "diff {}", recon.max_abs_diff(&a));
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let a = rand_symmetric(15, 8);
        let (_w, v) = symmetric_eigen(&a);
        let vtv = v.t_matmul(&v);
        assert!(vtv.max_abs_diff(&Mat::eye(15)) < 1e-9);
    }

    #[test]
    fn eigenvalues_sorted_descending() {
        let a = rand_symmetric(10, 9);
        let (w, _) = symmetric_eigen(&a);
        for i in 1..w.len() {
            assert!(w[i - 1] >= w[i] - 1e-12);
        }
    }

    #[test]
    fn trace_equals_eigen_sum() {
        let a = rand_symmetric(9, 10);
        let (w, _) = symmetric_eigen(&a);
        let trace: f64 = (0..9).map(|i| a[(i, i)]).sum();
        assert!((trace - w.iter().sum::<f64>()).abs() < 1e-9);
    }
}

//! Dense + sparse linear algebra substrate.
//!
//! The paper's evaluation pipeline (§4.1, "PureSVD" of Cremonesi et al.)
//! needs a truncated SVD of a large sparse user–item ratings matrix. No
//! external linear-algebra crates are used: this module implements dense
//! matrices, Householder QR, a Jacobi symmetric eigensolver, CSR sparse
//! matrices, and randomized truncated SVD (Halko–Martinsson–Tropp) on top
//! of them.

pub mod dense;
pub mod eigen;
pub mod qr;
pub mod sparse;
pub mod svd;

pub use dense::Mat;
pub use sparse::Csr;
pub use svd::{randomized_svd, LinOp, Svd};

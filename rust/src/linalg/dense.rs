//! Row-major dense f64 matrix with the operations the SVD pipeline needs.

use std::fmt;

/// Row-major dense matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major data vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length != rows*cols");
        Self { rows, cols, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Underlying row-major storage.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Matrix transpose.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Dense matmul `self * other` (ikj loop order for cache friendliness).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let row = out.row_mut(i);
                for j in 0..other.cols {
                    row[j] += a * orow[j];
                }
            }
        }
        out
    }

    /// `selfᵀ * other` without materializing the transpose.
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        let mut out = Mat::zeros(self.cols, other.cols);
        for k in 0..self.rows {
            let arow = self.row(k);
            let brow = other.row(k);
            for i in 0..self.cols {
                let a = arow[i];
                if a == 0.0 {
                    continue;
                }
                let row = out.row_mut(i);
                for j in 0..brow.len() {
                    row[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Column `j` copied out.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Elementwise maximum absolute difference against `other`.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eye_matmul_is_identity_map() {
        let a = Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f64);
        let out = Mat::eye(3).matmul(&a);
        assert_eq!(out, a);
        let out2 = a.matmul(&Mat::eye(4));
        assert_eq!(out2, a);
    }

    #[test]
    fn matmul_known_values() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.as_slice(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_fn(3, 5, |i, j| (i * 7 + j * 3) as f64);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let a = Mat::from_fn(4, 3, |i, j| (i as f64 - j as f64) * 0.5);
        let b = Mat::from_fn(4, 2, |i, j| (i + j) as f64);
        let got = a.t_matmul(&b);
        let want = a.transpose().matmul(&b);
        assert!(got.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn fro_norm() {
        let a = Mat::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}

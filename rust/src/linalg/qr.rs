//! Thin QR factorization via Householder reflections.
//!
//! Used by the randomized SVD range-finder to orthonormalize the sampled
//! subspace after each power iteration.

use super::dense::Mat;

/// Thin QR: returns `Q` with orthonormal columns such that `A = Q R`.
///
/// `A` is `m x n` with `m >= n`; the returned `Q` is `m x n`.
pub fn thin_qr_q(a: &Mat) -> Mat {
    let (m, n) = (a.rows(), a.cols());
    assert!(m >= n, "thin_qr_q requires rows >= cols (got {m}x{n})");
    // Work on a copy; store Householder vectors in-place below the diagonal.
    let mut r = a.clone();
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);
    for k in 0..n {
        // Build the Householder vector for column k.
        let mut v: Vec<f64> = (k..m).map(|i| r[(i, k)]).collect();
        let alpha = -v[0].signum() * v.iter().map(|x| x * x).sum::<f64>().sqrt();
        if alpha.abs() < 1e-300 {
            // Zero column: skip (keep identity reflector).
            vs.push(vec![0.0; m - k]);
            continue;
        }
        v[0] -= alpha;
        let vnorm2 = v.iter().map(|x| x * x).sum::<f64>();
        if vnorm2 < 1e-300 {
            vs.push(vec![0.0; m - k]);
            continue;
        }
        // Apply reflector H = I - 2 v vᵀ / (vᵀv) to the trailing block.
        // Row-major layout: iterate rows in the outer loop (two passes)
        // so memory is walked with stride 1 — ~5x faster than the naive
        // column-at-a-time loop at n in the hundreds.
        let mut dots = vec![0.0f64; n - k];
        for i in k..m {
            let vi = v[i - k];
            if vi == 0.0 {
                continue;
            }
            let row = &r.row(i)[k..];
            for (j, rv) in row.iter().enumerate() {
                dots[j] += vi * rv;
            }
        }
        let inv = 2.0 / vnorm2;
        for i in k..m {
            let vi = v[i - k] * inv;
            if vi == 0.0 {
                continue;
            }
            let row = &mut r.row_mut(i)[k..];
            for (j, rv) in row.iter_mut().enumerate() {
                *rv -= vi * dots[j];
            }
        }
        vs.push(v);
    }
    // Accumulate Q = H_0 H_1 ... H_{n-1} applied to the thin identity.
    let mut q = Mat::zeros(m, n);
    for j in 0..n {
        q[(j, j)] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 < 1e-300 {
            continue;
        }
        let mut dots = vec![0.0f64; n];
        for i in k..m {
            let vi = v[i - k];
            if vi == 0.0 {
                continue;
            }
            let row = q.row(i);
            for (j, qv) in row.iter().enumerate() {
                dots[j] += vi * qv;
            }
        }
        let inv = 2.0 / vnorm2;
        for i in k..m {
            let vi = v[i - k] * inv;
            if vi == 0.0 {
                continue;
            }
            let row = q.row_mut(i);
            for (j, qv) in row.iter_mut().enumerate() {
                *qv -= vi * dots[j];
            }
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::Mat;
    use crate::util::Rng;

    fn rand_mat(m: usize, n: usize, seed: u64) -> Mat {
        let mut rng = Rng::seed_from_u64(seed);
        Mat::from_fn(m, n, |_, _| rng.f64() * 2.0 - 1.0)
    }

    fn assert_orthonormal(q: &Mat, tol: f64) {
        let qtq = q.t_matmul(q);
        let eye = Mat::eye(q.cols());
        assert!(
            qtq.max_abs_diff(&eye) < tol,
            "QᵀQ deviates from identity by {}",
            qtq.max_abs_diff(&eye)
        );
    }

    #[test]
    fn q_is_orthonormal() {
        let a = rand_mat(20, 7, 1);
        let q = thin_qr_q(&a);
        assert_eq!((q.rows(), q.cols()), (20, 7));
        assert_orthonormal(&q, 1e-10);
    }

    #[test]
    fn q_spans_column_space() {
        // Projection of A onto span(Q) should recover A.
        let a = rand_mat(15, 5, 2);
        let q = thin_qr_q(&a);
        let proj = q.matmul(&q.t_matmul(&a));
        assert!(proj.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn square_full_rank() {
        let a = rand_mat(6, 6, 3);
        let q = thin_qr_q(&a);
        assert_orthonormal(&q, 1e-10);
    }

    #[test]
    fn handles_rank_deficiency() {
        // Two identical columns: QR must not produce NaNs.
        let mut a = rand_mat(10, 3, 4);
        for i in 0..10 {
            let v = a[(i, 0)];
            a[(i, 2)] = v;
        }
        let q = thin_qr_q(&a);
        assert!(q.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn tall_skinny() {
        let a = rand_mat(200, 3, 5);
        let q = thin_qr_q(&a);
        assert_orthonormal(&q, 1e-10);
    }
}

//! CSR sparse matrix — the ratings-matrix substrate for PureSVD.

use super::dense::Mat;

/// Compressed sparse row matrix of `f64`.
#[derive(Clone, Debug)]
pub struct Csr {
    rows: usize,
    cols: usize,
    /// Row pointer array, length `rows + 1`.
    indptr: Vec<usize>,
    /// Column indices, length nnz.
    indices: Vec<u32>,
    /// Values, length nnz.
    values: Vec<f64>,
}

impl Csr {
    /// Build from (row, col, value) triplets. Duplicate entries are summed.
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        triplets: impl IntoIterator<Item = (usize, usize, f64)>,
    ) -> Self {
        let mut per_row: Vec<Vec<(u32, f64)>> = vec![Vec::new(); rows];
        for (r, c, v) in triplets {
            assert!(r < rows && c < cols, "triplet ({r},{c}) out of bounds");
            per_row[r].push((c as u32, v));
        }
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for row in &mut per_row {
            row.sort_by_key(|&(c, _)| c);
            let mut i = 0;
            while i < row.len() {
                let c = row[i].0;
                let mut v = row[i].1;
                let mut j = i + 1;
                while j < row.len() && row[j].0 == c {
                    v += row[j].1;
                    j += 1;
                }
                indices.push(c);
                values.push(v);
                i = j;
            }
            indptr.push(indices.len());
        }
        Self { rows, cols, indptr, indices, values }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Entries of row `i` as (col, value) pairs.
    pub fn row_iter(&self, i: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        self.indices[lo..hi]
            .iter()
            .zip(&self.values[lo..hi])
            .map(|(&c, &v)| (c as usize, v))
    }

    /// Dense product `self * x` for a dense matrix `x` (cols x k).
    pub fn matmul_dense(&self, x: &Mat) -> Mat {
        assert_eq!(self.cols, x.rows(), "spmm shape mismatch");
        let k = x.cols();
        let mut out = Mat::zeros(self.rows, k);
        for i in 0..self.rows {
            let orow = out.row_mut(i);
            for idx in self.indptr[i]..self.indptr[i + 1] {
                let c = self.indices[idx] as usize;
                let v = self.values[idx];
                let xrow = x.row(c);
                for j in 0..k {
                    orow[j] += v * xrow[j];
                }
            }
        }
        out
    }

    /// Dense product `selfᵀ * x` (x is rows x k) without materializing the
    /// transpose.
    pub fn t_matmul_dense(&self, x: &Mat) -> Mat {
        assert_eq!(self.rows, x.rows(), "spmmᵀ shape mismatch");
        let k = x.cols();
        let mut out = Mat::zeros(self.cols, k);
        for i in 0..self.rows {
            let xrow = x.row(i);
            for idx in self.indptr[i]..self.indptr[i + 1] {
                let c = self.indices[idx] as usize;
                let v = self.values[idx];
                let orow = out.row_mut(c);
                for j in 0..k {
                    orow[j] += v * xrow[j];
                }
            }
        }
        out
    }

    /// Densify (tests / tiny matrices only).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for (c, v) in self.row_iter(i) {
                m[(i, c)] = v;
            }
        }
        m
    }

    /// Frobenius norm of the stored entries.
    pub fn fro_norm(&self) -> f64 {
        self.values.iter().map(|v| v * v).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        Csr::from_triplets(
            3,
            4,
            vec![(0, 1, 2.0), (0, 3, -1.0), (1, 0, 4.0), (2, 2, 0.5), (2, 2, 0.5)],
        )
    }

    #[test]
    fn from_triplets_sums_duplicates() {
        let m = sample();
        assert_eq!(m.nnz(), 4);
        let d = m.to_dense();
        assert_eq!(d[(2, 2)], 1.0);
        assert_eq!(d[(0, 1)], 2.0);
        assert_eq!(d[(0, 3)], -1.0);
        assert_eq!(d[(1, 0)], 4.0);
    }

    #[test]
    fn spmm_matches_dense() {
        let m = sample();
        let x = Mat::from_fn(4, 2, |i, j| (i * 2 + j) as f64 - 3.0);
        let got = m.matmul_dense(&x);
        let want = m.to_dense().matmul(&x);
        assert!(got.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn spmm_t_matches_dense() {
        let m = sample();
        let x = Mat::from_fn(3, 2, |i, j| (i + j) as f64 * 0.7);
        let got = m.t_matmul_dense(&x);
        let want = m.to_dense().transpose().matmul(&x);
        assert!(got.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn empty_rows_are_fine() {
        let m = Csr::from_triplets(5, 3, vec![(4, 2, 1.0)]);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.row_iter(0).count(), 0);
        assert_eq!(m.row_iter(4).count(), 1);
    }

    #[test]
    fn fro_norm_matches_dense() {
        let m = sample();
        assert!((m.fro_norm() - m.to_dense().fro_norm()).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_triplet_panics() {
        let _ = Csr::from_triplets(2, 2, vec![(2, 0, 1.0)]);
    }
}

//! Randomized truncated SVD (Halko–Martinsson–Tropp).
//!
//! Computes the top-k singular triplets of any linear operator via a
//! Gaussian range-finder with power iterations:
//!
//! ```text
//! Y = (A Aᵀ)^q A Ω,   Q = thin_qr(Y),   B = Qᵀ A   (k' x n)
//! B Bᵀ = V̂ diag(σ²) V̂ᵀ  →  U = Q V̂,  V = Bᵀ V̂ diag(1/σ)
//! ```
//!
//! This is the engine behind the PureSVD latent-factor pipeline (§4.1 of
//! the paper): `R ≈ W Σ Vᵀ`, users = rows of `WΣ`, items = rows of `V`.

use crate::util::Rng;

use super::dense::Mat;
use super::eigen::symmetric_eigen;
use super::qr::thin_qr_q;
use super::sparse::Csr;

/// Abstract linear operator: enough surface for the randomized range finder.
pub trait LinOp {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    /// `self * x` where `x` is `cols x k`.
    fn apply(&self, x: &Mat) -> Mat;
    /// `selfᵀ * x` where `x` is `rows x k`.
    fn apply_t(&self, x: &Mat) -> Mat;
}

impl LinOp for Mat {
    fn rows(&self) -> usize {
        Mat::rows(self)
    }
    fn cols(&self) -> usize {
        Mat::cols(self)
    }
    fn apply(&self, x: &Mat) -> Mat {
        self.matmul(x)
    }
    fn apply_t(&self, x: &Mat) -> Mat {
        self.t_matmul(x)
    }
}

impl LinOp for Csr {
    fn rows(&self) -> usize {
        Csr::rows(self)
    }
    fn cols(&self) -> usize {
        Csr::cols(self)
    }
    fn apply(&self, x: &Mat) -> Mat {
        self.matmul_dense(x)
    }
    fn apply_t(&self, x: &Mat) -> Mat {
        self.t_matmul_dense(x)
    }
}

/// Truncated SVD result: `A ≈ U diag(s) Vᵀ`.
#[derive(Clone, Debug)]
pub struct Svd {
    /// `rows x k` left singular vectors (columns orthonormal).
    pub u: Mat,
    /// Top-k singular values, descending.
    pub s: Vec<f64>,
    /// `cols x k` right singular vectors (columns orthonormal).
    pub v: Mat,
}

/// Randomized truncated SVD of `a` with target rank `k`.
///
/// `oversample` extra probe vectors (default choice: 10) and `n_iter`
/// power iterations (2 is plenty for ratings matrices) control accuracy.
pub fn randomized_svd(
    a: &impl LinOp,
    k: usize,
    oversample: usize,
    n_iter: usize,
    rng: &mut Rng,
) -> Svd {
    let (m, n) = (a.rows(), a.cols());
    let k = k.min(m).min(n);
    let l = (k + oversample).min(m).min(n);
    // Gaussian probe.
    let omega = Mat::from_fn(n, l, |_, _| rng.normal_f64());
    let mut y = a.apply(&omega); // m x l
    // Power iterations with re-orthonormalization for stability.
    for _ in 0..n_iter {
        let q = thin_qr_q(&y);
        let z = a.apply_t(&q); // n x l
        let qz = thin_qr_q(&z);
        y = a.apply(&qz); // m x l
    }
    let q = thin_qr_q(&y); // m x l, orthonormal
    // B = Qᵀ A  is  l x n; we form Bᵀ = Aᵀ Q  (n x l) with one operator call.
    let bt = a.apply_t(&q); // n x l
    // B Bᵀ = (Bᵀ)ᵀ Bᵀ  is  l x l.
    let gram = bt.t_matmul(&bt);
    let (w, vhat) = symmetric_eigen(&gram); // gram = vhat diag(w) vhatᵀ
    // Keep top-k non-negative eigenvalues.
    let mut s = Vec::with_capacity(k);
    for i in 0..k {
        s.push(w[i].max(0.0).sqrt());
    }
    // U = Q * vhat[:, :k]
    let vhat_k = Mat::from_fn(l, k, |i, j| vhat[(i, j)]);
    let u = q.matmul(&vhat_k); // m x k
    // V = Bᵀ vhat diag(1/σ)
    let mut v = bt.matmul(&vhat_k); // n x k
    for i in 0..n {
        let row = v.row_mut(i);
        for j in 0..k {
            if s[j] > 1e-12 {
                row[j] /= s[j];
            } else {
                row[j] = 0.0;
            }
        }
    }
    Svd { u, s, v }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a dense matrix with known singular values via U diag(s) Vᵀ.
    fn known_svd_matrix(m: usize, n: usize, s: &[f64], seed: u64) -> Mat {
        let mut rng = Rng::seed_from_u64(seed);
        let a = Mat::from_fn(m, s.len(), |_, _| rng.normal_f64());
        let b = Mat::from_fn(n, s.len(), |_, _| rng.normal_f64());
        let u = thin_qr_q(&a);
        let v = thin_qr_q(&b);
        // u diag(s) vᵀ
        let mut ud = u.clone();
        for i in 0..m {
            for j in 0..s.len() {
                ud[(i, j)] = u[(i, j)] * s[j];
            }
        }
        ud.matmul(&v.transpose())
    }

    #[test]
    fn recovers_singular_values_exact_rank() {
        let s_true = [10.0, 5.0, 2.0, 1.0];
        let a = known_svd_matrix(30, 20, &s_true, 1);
        let mut rng = Rng::seed_from_u64(2);
        let svd = randomized_svd(&a, 4, 8, 3, &mut rng);
        for (got, want) in svd.s.iter().zip(s_true.iter()) {
            assert!((got - want).abs() < 1e-8, "σ {got} vs {want}");
        }
    }

    #[test]
    fn reconstruction_error_small() {
        let s_true = [8.0, 4.0, 2.0];
        let a = known_svd_matrix(25, 18, &s_true, 3);
        let mut rng = Rng::seed_from_u64(4);
        let svd = randomized_svd(&a, 3, 6, 3, &mut rng);
        // U diag(s) Vᵀ ≈ A
        let mut ud = svd.u.clone();
        for i in 0..25 {
            for j in 0..3 {
                ud[(i, j)] = svd.u[(i, j)] * svd.s[j];
            }
        }
        let recon = ud.matmul(&svd.v.transpose());
        assert!(recon.max_abs_diff(&a) < 1e-7, "err {}", recon.max_abs_diff(&a));
    }

    #[test]
    fn factors_are_orthonormal() {
        let s_true = [6.0, 3.0, 1.5, 0.7];
        let a = known_svd_matrix(40, 22, &s_true, 5);
        let mut rng = Rng::seed_from_u64(6);
        let svd = randomized_svd(&a, 4, 6, 3, &mut rng);
        let utu = svd.u.t_matmul(&svd.u);
        let vtv = svd.v.t_matmul(&svd.v);
        assert!(utu.max_abs_diff(&Mat::eye(4)) < 1e-8);
        assert!(vtv.max_abs_diff(&Mat::eye(4)) < 1e-8);
    }

    #[test]
    fn works_on_sparse_input() {
        // Rank-2 sparse-ish matrix.
        let mut trips = Vec::new();
        for i in 0..30usize {
            for j in 0..15usize {
                if (i + j) % 3 == 0 {
                    let v = (i as f64 * 0.3) * (j as f64 * 0.2 + 1.0)
                        + (i as f64).cos() * (j as f64).sin();
                    trips.push((i, j, v));
                }
            }
        }
        let sp = Csr::from_triplets(30, 15, trips);
        let dense = sp.to_dense();
        let mut rng1 = Rng::seed_from_u64(7);
        let mut rng2 = Rng::seed_from_u64(7);
        let s1 = randomized_svd(&sp, 5, 5, 3, &mut rng1);
        let s2 = randomized_svd(&dense, 5, 5, 3, &mut rng2);
        for (a, b) in s1.s.iter().zip(s2.s.iter()) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn truncation_captures_dominant_energy() {
        // Rank-6 matrix, ask for top-2: σ̂ should match the top two σ.
        let s_true = [20.0, 10.0, 1.0, 0.5, 0.2, 0.1];
        let a = known_svd_matrix(35, 30, &s_true, 8);
        let mut rng = Rng::seed_from_u64(9);
        let svd = randomized_svd(&a, 2, 10, 4, &mut rng);
        assert!((svd.s[0] - 20.0).abs() < 0.05);
        assert!((svd.s[1] - 10.0).abs() < 0.05);
    }

    #[test]
    fn k_larger_than_rank_is_clamped_gracefully() {
        let s_true = [5.0, 2.0];
        let a = known_svd_matrix(10, 8, &s_true, 10);
        let mut rng = Rng::seed_from_u64(11);
        let svd = randomized_svd(&a, 6, 4, 3, &mut rng);
        assert!((svd.s[0] - 5.0).abs() < 1e-7);
        assert!((svd.s[1] - 2.0).abs() < 1e-7);
        // Trailing singular values are ~0.
        for v in &svd.s[2..] {
            assert!(*v < 1e-6);
        }
        assert!(svd.u.as_slice().iter().all(|x| x.is_finite()));
        assert!(svd.v.as_slice().iter().all(|x| x.is_finite()));
    }
}

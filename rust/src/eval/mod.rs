//! Evaluation harness: gold-standard top-T, precision–recall curves
//! (Eq. 22), and averaging across users — the measurement machinery of
//! Figures 5–7.

pub mod gold;
pub mod metrics;
pub mod pr;

pub use gold::{gold_top_t, gold_top_t_batch};
pub use metrics::{ndcg_at_k, spearman};
pub use pr::{average_curves, pr_curve, PrCurve};

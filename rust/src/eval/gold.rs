//! Exact top-T inner products: the gold standard of §4.3.

use crate::transform::dot;

/// Offer `(score, id)` to a descending-sorted top-`t` buffer — the one
/// insertion rule both the single-query and batch gold scans share, so
/// they cannot diverge (ties keep the first-seen id).
#[inline]
fn offer(top: &mut Vec<(f32, u32)>, t: usize, s: f32, id: u32) {
    if top.len() < t {
        top.push((s, id));
        top.sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    } else if s > top[t - 1].0 {
        top[t - 1] = (s, id);
        let mut j = t - 1;
        while j > 0 && top[j].0 > top[j - 1].0 {
            top.swap(j, j - 1);
            j -= 1;
        }
    }
}

/// The ids of the `t` items with the largest inner product with `query`,
/// in descending score order (full scan; this defines ground truth).
pub fn gold_top_t(items: &[Vec<f32>], query: &[f32], t: usize) -> Vec<u32> {
    let t = t.min(items.len());
    if t == 0 {
        return Vec::new();
    }
    // Max-heap by (-score) via a small sorted buffer: t is tiny (<= 10).
    let mut top: Vec<(f32, u32)> = Vec::with_capacity(t + 1);
    for (i, item) in items.iter().enumerate() {
        offer(&mut top, t, dot(item, query), i as u32);
    }
    top.into_iter().map(|(_, i)| i).collect()
}

/// Batch gold scan (the offline-eval batch API): exact top-`t` ids for
/// every query in **one pass over the corpus** — each item row is loaded
/// once and scored against all queries, instead of `Q` full scans
/// re-streaming the item matrix. Results are identical to per-query
/// [`gold_top_t`] (same insertion rule, same f32 `dot`).
pub fn gold_top_t_batch(items: &[Vec<f32>], queries: &[Vec<f32>], t: usize) -> Vec<Vec<u32>> {
    let t = t.min(items.len());
    if t == 0 || queries.is_empty() {
        return vec![Vec::new(); queries.len()];
    }
    let mut tops: Vec<Vec<(f32, u32)>> =
        (0..queries.len()).map(|_| Vec::with_capacity(t + 1)).collect();
    for (i, item) in items.iter().enumerate() {
        for (q, top) in queries.iter().zip(tops.iter_mut()) {
            offer(top, t, dot(item, q), i as u32);
        }
    }
    tops.into_iter()
        .map(|top| top.into_iter().map(|(_, i)| i).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn finds_known_max() {
        let items = vec![vec![1.0, 0.0], vec![0.0, 1.0], vec![2.0, 2.0]];
        let got = gold_top_t(&items, &[1.0, 1.0], 2);
        assert_eq!(got, vec![2, 0]); // ties broken by first-seen (id 0 before 1)
    }

    #[test]
    fn matches_full_sort() {
        let mut rng = Rng::seed_from_u64(1);
        let items: Vec<Vec<f32>> = (0..200)
            .map(|_| (0..8).map(|_| rng.f32() - 0.5).collect())
            .collect();
        let q: Vec<f32> = (0..8).map(|_| rng.f32() - 0.5).collect();
        let got = gold_top_t(&items, &q, 10);
        let mut all: Vec<(f32, u32)> = items
            .iter()
            .enumerate()
            .map(|(i, v)| (dot(v, &q), i as u32))
            .collect();
        all.sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let want: Vec<u32> = all[..10].iter().map(|&(_, i)| i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn t_larger_than_corpus() {
        let items = vec![vec![1.0f32], vec![2.0]];
        let got = gold_top_t(&items, &[1.0], 10);
        assert_eq!(got, vec![1, 0]);
    }

    #[test]
    fn t_zero() {
        let items = vec![vec![1.0f32]];
        assert!(gold_top_t(&items, &[1.0], 0).is_empty());
    }

    #[test]
    fn batch_matches_per_query_scan() {
        let mut rng = Rng::seed_from_u64(5);
        let items: Vec<Vec<f32>> = (0..300)
            .map(|_| (0..10).map(|_| rng.normal_f32() * 0.5).collect())
            .collect();
        let queries: Vec<Vec<f32>> = (0..17)
            .map(|_| (0..10).map(|_| rng.normal_f32()).collect())
            .collect();
        for t in [1usize, 5, 10, 500] {
            let batch = gold_top_t_batch(&items, &queries, t);
            assert_eq!(batch.len(), queries.len());
            for (q, got) in queries.iter().zip(&batch) {
                assert_eq!(got, &gold_top_t(&items, q, t), "t={t}");
            }
        }
        // Degenerate shapes.
        assert!(gold_top_t_batch(&items, &[], 10).is_empty());
        let empty_t = gold_top_t_batch(&items, &queries, 0);
        assert!(empty_t.iter().all(|v| v.is_empty()));
    }
}

//! Exact top-T inner products: the gold standard of §4.3.

use crate::transform::dot;

/// The ids of the `t` items with the largest inner product with `query`,
/// in descending score order (full scan; this defines ground truth).
pub fn gold_top_t(items: &[Vec<f32>], query: &[f32], t: usize) -> Vec<u32> {
    let t = t.min(items.len());
    if t == 0 {
        return Vec::new();
    }
    // Max-heap by (-score) via a small sorted buffer: t is tiny (<= 10).
    let mut top: Vec<(f32, u32)> = Vec::with_capacity(t + 1);
    for (i, item) in items.iter().enumerate() {
        let s = dot(item, query);
        if top.len() < t {
            top.push((s, i as u32));
            top.sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        } else if s > top[t - 1].0 {
            top[t - 1] = (s, i as u32);
            let mut j = t - 1;
            while j > 0 && top[j].0 > top[j - 1].0 {
                top.swap(j, j - 1);
                j -= 1;
            }
        }
    }
    top.into_iter().map(|(_, i)| i).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn finds_known_max() {
        let items = vec![vec![1.0, 0.0], vec![0.0, 1.0], vec![2.0, 2.0]];
        let got = gold_top_t(&items, &[1.0, 1.0], 2);
        assert_eq!(got, vec![2, 0]); // ties broken by first-seen (id 0 before 1)
    }

    #[test]
    fn matches_full_sort() {
        let mut rng = Rng::seed_from_u64(1);
        let items: Vec<Vec<f32>> = (0..200)
            .map(|_| (0..8).map(|_| rng.f32() - 0.5).collect())
            .collect();
        let q: Vec<f32> = (0..8).map(|_| rng.f32() - 0.5).collect();
        let got = gold_top_t(&items, &q, 10);
        let mut all: Vec<(f32, u32)> = items
            .iter()
            .enumerate()
            .map(|(i, v)| (dot(v, &q), i as u32))
            .collect();
        all.sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let want: Vec<u32> = all[..10].iter().map(|&(_, i)| i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn t_larger_than_corpus() {
        let items = vec![vec![1.0f32], vec![2.0]];
        let got = gold_top_t(&items, &[1.0], 10);
        assert_eq!(got, vec![1, 0]);
    }

    #[test]
    fn t_zero() {
        let items = vec![vec![1.0f32]];
        assert!(gold_top_t(&items, &[1.0], 0).is_empty());
    }
}

//! Ranking-quality metrics beyond precision–recall: NDCG@k and Spearman
//! rank correlation — used by the end-to-end example and ablation benches
//! to summarize retrieval quality in one scalar.

/// NDCG@k of a ranked id list against graded relevances.
///
/// `relevance(id)` returns the gain of an item (e.g. its exact inner
/// product clamped at 0); the ideal ordering is by descending relevance.
pub fn ndcg_at_k(ranked: &[u32], k: usize, relevance: impl Fn(u32) -> f64) -> f64 {
    let k = k.min(ranked.len());
    if k == 0 {
        return 0.0;
    }
    let dcg: f64 = ranked[..k]
        .iter()
        .enumerate()
        .map(|(i, &id)| relevance(id) / ((i + 2) as f64).log2())
        .sum();
    // Ideal DCG: top-k relevances over the *ranked universe*.
    let mut rels: Vec<f64> = ranked.iter().map(|&id| relevance(id)).collect();
    rels.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
    let idcg: f64 =
        rels[..k].iter().enumerate().map(|(i, r)| r / ((i + 2) as f64).log2()).sum();
    if idcg <= 0.0 {
        0.0
    } else {
        dcg / idcg
    }
}

/// Spearman rank correlation between two total orders over the same ids
/// (each a permutation of 0..n).
pub fn spearman(rank_a: &[u32], rank_b: &[u32]) -> f64 {
    assert_eq!(rank_a.len(), rank_b.len());
    let n = rank_a.len();
    if n < 2 {
        return 1.0;
    }
    let mut pos_a = vec![0usize; n];
    let mut pos_b = vec![0usize; n];
    for (i, &id) in rank_a.iter().enumerate() {
        pos_a[id as usize] = i;
    }
    for (i, &id) in rank_b.iter().enumerate() {
        pos_b[id as usize] = i;
    }
    let d2: f64 = (0..n)
        .map(|id| {
            let d = pos_a[id] as f64 - pos_b[id] as f64;
            d * d
        })
        .sum();
    1.0 - 6.0 * d2 / (n as f64 * (n as f64 * n as f64 - 1.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_ranking_ndcg_is_one() {
        let rels = [5.0, 4.0, 3.0, 2.0, 1.0];
        let ranked: Vec<u32> = (0..5).collect();
        let v = ndcg_at_k(&ranked, 5, |id| rels[id as usize]);
        assert!((v - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reversed_ranking_ndcg_below_one() {
        let rels = [5.0, 4.0, 3.0, 2.0, 1.0];
        let ranked: Vec<u32> = (0..5).rev().collect();
        let v = ndcg_at_k(&ranked, 5, |id| rels[id as usize]);
        assert!(v < 0.8, "reversed NDCG {v}");
        assert!(v > 0.0);
    }

    #[test]
    fn ndcg_k_truncates() {
        let rels = [0.0, 10.0];
        // relevant item at position 2, k=1 → dcg 0.
        let v = ndcg_at_k(&[0, 1], 1, |id| rels[id as usize]);
        assert_eq!(v, 0.0);
    }

    #[test]
    fn ndcg_zero_relevance_is_zero() {
        assert_eq!(ndcg_at_k(&[0, 1, 2], 3, |_| 0.0), 0.0);
    }

    #[test]
    fn spearman_identity_and_reverse() {
        let a: Vec<u32> = (0..100).collect();
        let b: Vec<u32> = (0..100).rev().collect();
        assert!((spearman(&a, &a) - 1.0).abs() < 1e-12);
        assert!((spearman(&a, &b) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_random_near_zero() {
        use crate::util::Rng;
        let mut rng = Rng::seed_from_u64(1);
        let a: Vec<u32> = (0..1000).collect();
        let mut b = a.clone();
        rng.shuffle(&mut b);
        let s = spearman(&a, &b);
        assert!(s.abs() < 0.1, "random spearman {s}");
    }

    #[test]
    fn spearman_small_perturbation_high() {
        let a: Vec<u32> = (0..50).collect();
        let mut b = a.clone();
        b.swap(0, 1);
        b.swap(10, 11);
        assert!(spearman(&a, &b) > 0.99);
    }
}

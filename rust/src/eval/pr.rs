//! Precision–recall curves per §4.3 / Eq. 22.
//!
//! Walking down the ranked list, at rank k having seen `rel` of the T gold
//! items: precision = rel / k, recall = rel / T. We record the curve at
//! each of the T recall levels (i.e. at the rank where the j-th gold item
//! is found), which makes curves from different users directly averageable
//! point-by-point — the paper averages over 2000 random users.

/// A precision–recall curve sampled at the T recall levels 1/T .. T/T.
#[derive(Clone, Debug)]
pub struct PrCurve {
    /// recall\[j\] = (j+1)/T.
    pub recall: Vec<f64>,
    /// precision\[j\] = precision at the rank where recall first reaches
    /// (j+1)/T.
    pub precision: Vec<f64>,
}

/// Compute the PR curve of `ranked` against the `gold` set (order of gold
/// irrelevant). `ranked` must contain every gold id somewhere.
pub fn pr_curve(ranked: &[u32], gold: &[u32]) -> PrCurve {
    let t = gold.len();
    let mut recall = Vec::with_capacity(t);
    let mut precision = Vec::with_capacity(t);
    let mut rel = 0usize;
    for (k0, id) in ranked.iter().enumerate() {
        if gold.contains(id) {
            rel += 1;
            recall.push(rel as f64 / t as f64);
            precision.push(rel as f64 / (k0 + 1) as f64);
            if rel == t {
                break;
            }
        }
    }
    assert_eq!(rel, t, "ranked list does not contain all gold items");
    PrCurve { recall, precision }
}

/// Point-wise average of equal-length PR curves (across users).
pub fn average_curves(curves: &[PrCurve]) -> PrCurve {
    assert!(!curves.is_empty());
    let t = curves[0].recall.len();
    assert!(curves.iter().all(|c| c.recall.len() == t));
    let n = curves.len() as f64;
    let recall = curves[0].recall.clone();
    let precision = (0..t)
        .map(|j| curves.iter().map(|c| c.precision[j]).sum::<f64>() / n)
        .collect();
    PrCurve { recall, precision }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_ranking_has_precision_one() {
        let c = pr_curve(&[3, 1, 4, 0, 2], &[3, 1, 4]);
        assert_eq!(c.recall, vec![1.0 / 3.0, 2.0 / 3.0, 1.0]);
        assert_eq!(c.precision, vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn worst_ranking_precision_decays() {
        // gold items at the very end of a 10-item list
        let ranked: Vec<u32> = (0..10).collect();
        let c = pr_curve(&ranked, &[8, 9]);
        assert!((c.precision[0] - 1.0 / 9.0).abs() < 1e-12);
        assert!((c.precision[1] - 2.0 / 10.0).abs() < 1e-12);
    }

    #[test]
    fn interleaved() {
        let c = pr_curve(&[7, 0, 8, 1, 9], &[0, 1]);
        assert!((c.precision[0] - 0.5).abs() < 1e-12); // found at rank 2
        assert!((c.precision[1] - 0.5).abs() < 1e-12); // 2 of 4
    }

    #[test]
    #[should_panic]
    fn missing_gold_panics() {
        let _ = pr_curve(&[1, 2, 3], &[9]);
    }

    #[test]
    fn averaging() {
        let a = PrCurve { recall: vec![0.5, 1.0], precision: vec![1.0, 0.5] };
        let b = PrCurve { recall: vec![0.5, 1.0], precision: vec![0.0, 0.5] };
        let avg = average_curves(&[a, b]);
        assert_eq!(avg.precision, vec![0.5, 0.5]);
        assert_eq!(avg.recall, vec![0.5, 1.0]);
    }

    #[test]
    fn precision_monotone_relationship() {
        // Precision at recall level j is rel/k for increasing k: it can
        // go up or down, but is always in (0, 1].
        let ranked: Vec<u32> = (0..100).collect();
        let c = pr_curve(&ranked, &[0, 50, 99]);
        for p in &c.precision {
            assert!(*p > 0.0 && *p <= 1.0);
        }
    }
}

//! # alsh — Asymmetric LSH for sublinear-time Maximum Inner Product Search
//!
//! A production-grade reproduction of Shrivastava & Li, *"Asymmetric LSH
//! (ALSH) for Sublinear Time Maximum Inner Product Search (MIPS)"*
//! (NIPS 2014), built as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1 (Pallas, build-time)** — the hash-code and rerank matmul
//!   kernels (`python/compile/kernels/`), AOT-lowered to HLO text.
//! * **Layer 2 (JAX, build-time)** — the ALSH pipeline: asymmetric
//!   transforms P/Q (Eq. 12–13) fused with the L2LSH projection
//!   (`python/compile/model.py`).
//! * **Layer 3 (this crate)** — the serving system: hash-table index,
//!   dynamic batcher over PJRT executables, query router, the theory
//!   (ρ\*) optimizer, the PureSVD data pipeline, and the full evaluation
//!   harness that regenerates every figure in the paper.
//!
//! The index serves three hash **schemes** behind one pluggable layer
//! ([`index::MipsHashScheme`], selected by `AlshParams::scheme`): the
//! paper's L2-ALSH, **Sign-ALSH** (SRP over the sign transforms,
//! Shrivastava & Li 2015 — the §5 follow-on), and **Simple-LSH**
//! (single-append symmetric SRP, Neyshabur & Srebro 2015). Every layer
//! — fused hashing ([`lsh::FusedHasher`] / [`lsh::FusedSrpHasher`]),
//! the sharded streaming CSR build, the allocation-free query scratch,
//! multi-probe, norm-range banding, persistence (v4 streaming / v5
//! zero-copy mmap, [`index::persist`]), engine / batcher / router —
//! dispatches per scheme, over owned or memory-mapped storage
//! ([`index::storage`]).
//!
//! ## Module map (serving spine)
//!
//! * [`transform`] — the asymmetric P/Q transform pairs, per scheme.
//! * [`lsh`] — hash families (L2LSH, SRP) and their fused multi-table
//!   hashers.
//! * [`index`] — the scheme layer, flat/banded indexes, frozen CSR
//!   tables, build pipeline, multi-probe, persistence.
//! * [`coordinator`] — engine, dynamic batcher, sharded router, server.
//! * [`theory`] / [`figures`] / [`eval`] — ρ curves (L2 and Sign),
//!   figure regeneration, offline evaluation.
//!
//! Python never runs on the request path: `make artifacts` lowers the
//! compute once; the Rust binary loads `artifacts/*.hlo.txt` via PJRT.
//!
//! ## Quick start
//!
//! ```no_run
//! use alsh::index::{AlshIndex, AlshParams};
//!
//! // 1000 item vectors of dim 32 with varying norms.
//! let items: Vec<Vec<f32>> = (0..1000)
//!     .map(|i| (0..32).map(|j| ((i * 31 + j) % 17) as f32 / 17.0).collect())
//!     .collect();
//! let index = AlshIndex::build(&items, AlshParams::default(), 42);
//! let query: Vec<f32> = (0..32).map(|j| (j as f32).sin()).collect();
//! let top = index.query(&query, 10);
//! println!("best item = {} (ip = {})", top[0].id, top[0].score);
//! ```

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod figures;
pub mod index;
pub mod linalg;
pub mod lsh;
pub mod runtime;
pub mod theory;
pub mod transform;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

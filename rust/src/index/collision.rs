//! Collision-count ranking (Eq. 21) — the paper's evaluation protocol.
//!
//! For K independent hash functions, every item j is scored by
//! `Matches_j = Σ_t 1(h_t(query) = h_t(item_j))` and items are ranked by
//! that count. Figures 5–7 are precision–recall curves of this ranking
//! against the exact top-T inner products.
//!
//! [`Scheme`] here is the *evaluation-protocol* selector for this ranker
//! (it predates the serving-side scheme layer and carries per-variant
//! `m`); the production indexes select their construction through
//! [`crate::index::MipsHashScheme`] instead.

use crate::util::Rng;

use crate::lsh::{L2LshFamily, SrpFamily};
use crate::transform::{
    p_transform, p_transform_sign, q_transform, q_transform_sign, UScale,
};

/// Which hashing scheme the ranker evaluates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    /// Proposed L2-ALSH: hash(P(x)) for data, hash(Q(q)) for queries.
    Alsh { m: usize },
    /// Baseline symmetric L2LSH on the raw vectors (§4.2).
    L2Lsh,
    /// Sign-ALSH extension (§5 future work; Shrivastava & Li 2015):
    /// SimHash over the sign transforms.
    SignAlsh { m: usize },
}

/// Either hash family behind a ranker.
enum Family {
    L2(L2LshFamily),
    Srp(SrpFamily),
}

impl Family {
    fn hash_into(&self, x: &[f32], out: &mut Vec<i32>) {
        match self {
            Family::L2(f) => f.hash_into(x, out),
            Family::Srp(f) => f.hash_into(x, out),
        }
    }

    fn hash(&self, x: &[f32]) -> Vec<i32> {
        match self {
            Family::L2(f) => f.hash(x),
            Family::Srp(f) => f.hash(x),
        }
    }
}

/// Descending-count ranking via counting sort; ties broken by ascending
/// id (iteration order is already ascending).
pub fn rank_by_counts(matches: &[u32], k_max: usize) -> Vec<u32> {
    let mut hist = vec![0u32; k_max + 2];
    for &c in matches {
        debug_assert!((c as usize) <= k_max);
        hist[c as usize] += 1;
    }
    // Offsets for descending counts: position of count c is after all
    // counts > c.
    let mut offsets = vec![0u32; k_max + 1];
    let mut acc = 0u32;
    for c in (0..=k_max).rev() {
        offsets[c] = acc;
        acc += hist[c];
    }
    let mut out = vec![0u32; matches.len()];
    for (id, &c) in matches.iter().enumerate() {
        let slot = &mut offsets[c as usize];
        out[*slot as usize] = id as u32;
        *slot += 1;
    }
    out
}

/// Precomputed item hash codes + the family, ready to rank queries.
pub struct CollisionRanker {
    scheme: Scheme,
    family: Family,
    scale: Option<UScale>,
    /// [n_items * k] codes, row per item.
    item_codes: Vec<i32>,
    k: usize,
    n_items: usize,
}

impl CollisionRanker {
    /// Hash all `items` with `k` functions of width `r` under `scheme`.
    ///
    /// For ALSH the items are first shrunk so max norm = `u` (Eq. 11) and
    /// P-transformed; for L2LSH they are hashed raw (the baseline of §4.2).
    pub fn build(
        items: &[Vec<f32>],
        scheme: Scheme,
        k: usize,
        r: f32,
        u: f32,
        seed: u64,
    ) -> Self {
        Self::build_impl(items, scheme, k, r, u, seed, None)
    }

    /// Like [`CollisionRanker::build`] but bulk-hashes the items through
    /// the compiled PJRT artifact (the L1 Pallas matmul) when one matches
    /// the scheme/dim/K — ~2x faster than the scalar path on the figure
    /// datasets. Falls back to the scalar path if no artifact fits.
    pub fn build_pjrt(
        items: &[Vec<f32>],
        scheme: Scheme,
        k: usize,
        r: f32,
        u: f32,
        seed: u64,
        rt: &mut crate::runtime::Runtime,
    ) -> Self {
        Self::build_impl(items, scheme, k, r, u, seed, Some(rt))
    }

    fn build_impl(
        items: &[Vec<f32>],
        scheme: Scheme,
        k: usize,
        r: f32,
        u: f32,
        seed: u64,
        rt: Option<&mut crate::runtime::Runtime>,
    ) -> Self {
        assert!(!items.is_empty());
        let dim = items[0].len();
        let mut rng = Rng::seed_from_u64(seed);
        let (family, scale) = match scheme {
            Scheme::Alsh { m } => (
                Family::L2(L2LshFamily::sample(dim + m, k, r, &mut rng)),
                Some(UScale::fit(items.iter().map(|v| v.as_slice()), u)),
            ),
            Scheme::L2Lsh => (Family::L2(L2LshFamily::sample(dim, k, r, &mut rng)), None),
            Scheme::SignAlsh { m } => (
                Family::Srp(SrpFamily::sample(dim + m, k, &mut rng)),
                Some(UScale::fit(items.iter().map(|v| v.as_slice()), u)),
            ),
        };
        let item_codes = rt
            .and_then(|rt| {
                Self::pjrt_item_codes(items, scheme, k, &family, scale.as_ref(), rt)
            })
            .unwrap_or_else(|| {
                let mut item_codes = Vec::with_capacity(items.len() * k);
                for item in items {
                    match scheme {
                        Scheme::Alsh { m } => {
                            let px =
                                p_transform(&scale.as_ref().unwrap().apply(item), m);
                            family.hash_into(&px, &mut item_codes);
                        }
                        Scheme::L2Lsh => family.hash_into(item, &mut item_codes),
                        Scheme::SignAlsh { m } => {
                            let px = p_transform_sign(
                                &scale.as_ref().unwrap().apply(item),
                                m,
                            );
                            family.hash_into(&px, &mut item_codes);
                        }
                    }
                }
                item_codes
            });
        assert_eq!(item_codes.len(), items.len() * k);
        Self { scheme, family, scale, item_codes, k, n_items: items.len() }
    }

    /// Bulk item hashing through the AOT artifact. Returns None when no
    /// artifact matches (caller falls back to the scalar mirror).
    fn pjrt_item_codes(
        items: &[Vec<f32>],
        scheme: Scheme,
        k: usize,
        family: &Family,
        scale: Option<&UScale>,
        rt: &mut crate::runtime::Runtime,
    ) -> Option<Vec<i32>> {
        let dim = items[0].len();
        let (function, a_dk, b, m, scaled): (&str, Vec<f32>, Vec<f32>, usize, bool) =
            match (scheme, family) {
                (Scheme::Alsh { m }, Family::L2(f)) => {
                    ("alsh_data", f.a_matrix_dk(), f.b_vector().to_vec(), m, true)
                }
                (Scheme::L2Lsh, Family::L2(f)) => {
                    ("l2lsh", f.a_matrix_dk(), f.b_vector().to_vec(), 0, false)
                }
                (Scheme::SignAlsh { m }, Family::Srp(f)) => {
                    ("sign_alsh_data", f.a_matrix_dk(), Vec::new(), m, true)
                }
                _ => return None,
            };
        let meta = rt.find(function, dim).ok()?;
        if meta.m != m || k > meta.k {
            return None;
        }
        // Pad the projection matrix from [dp, k] to the artifact's
        // [dp, meta.k] column count (extra columns produce unused codes).
        let dp = dim + m;
        let mut a_pad = vec![0.0f32; dp * meta.k];
        for d in 0..dp {
            a_pad[d * meta.k..d * meta.k + k]
                .copy_from_slice(&a_dk[d * k..(d + 1) * k]);
        }
        let rows: Vec<Vec<f32>> = if scaled {
            items.iter().map(|v| scale.unwrap().apply(v)).collect()
        } else {
            items.to_vec()
        };
        let code_rows = if function == "sign_alsh_data" {
            rt.run_sign_hash(&meta, &rows, &a_pad).ok()?
        } else {
            let mut b_pad = vec![0.0f32; meta.k];
            b_pad[..k].copy_from_slice(&b);
            rt.run_hash(&meta, &rows, &a_pad, &b_pad).ok()?
        };
        let mut out = Vec::with_capacity(items.len() * k);
        for row in code_rows {
            out.extend_from_slice(&row[..k]);
        }
        Some(out)
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n_items(&self) -> usize {
        self.n_items
    }

    /// Query-side hash codes under the scheme (Q-transform for ALSH).
    pub fn query_codes(&self, query: &[f32]) -> Vec<i32> {
        match self.scheme {
            Scheme::Alsh { m } => self.family.hash(&q_transform(query, m)),
            Scheme::L2Lsh => self.family.hash(query),
            Scheme::SignAlsh { m } => self.family.hash(&q_transform_sign(query, m)),
        }
    }

    /// `Matches_j` for every item, using the first `k_prefix` hash
    /// functions (so one build at K=512 serves the K ∈ {64,128,256,512}
    /// sweep of Figures 5–6).
    pub fn matches(&self, query_codes: &[i32], k_prefix: usize) -> Vec<u32> {
        let k_prefix = k_prefix.min(self.k);
        assert!(query_codes.len() >= k_prefix);
        let mut out = vec![0u32; self.n_items];
        let qc = &query_codes[..k_prefix];
        for (j, cnt) in out.iter_mut().enumerate() {
            let row = &self.item_codes[j * self.k..j * self.k + k_prefix];
            let mut c = 0u32;
            for (a, b) in row.iter().zip(qc) {
                c += (a == b) as u32;
            }
            *cnt = c;
        }
        out
    }

    /// `Matches_j` for every item at *each* K in `ks` (ascending),
    /// computed incrementally in one pass over the code matrix: the codes
    /// in segment [ks[i-1], ks[i]) are only compared once. This is the
    /// inner loop of the Figures 5-6 K-sweep (see EXPERIMENTS.md §Perf).
    pub fn matches_at_ks(&self, query_codes: &[i32], ks: &[usize]) -> Vec<Vec<u32>> {
        assert!(!ks.is_empty());
        assert!(ks.windows(2).all(|w| w[0] < w[1]), "ks must be ascending");
        let k_max = (*ks.last().unwrap()).min(self.k);
        assert!(query_codes.len() >= k_max);
        let mut out: Vec<Vec<u32>> = Vec::with_capacity(ks.len());
        let mut acc = vec![0u32; self.n_items];
        let mut prev = 0usize;
        for &k in ks {
            let k = k.min(self.k);
            let qc = &query_codes[prev..k];
            for (j, a) in acc.iter_mut().enumerate() {
                let row = &self.item_codes[j * self.k + prev..j * self.k + k];
                let mut c = 0u32;
                for (x, y) in row.iter().zip(qc) {
                    c += (x == y) as u32;
                }
                *a += c;
            }
            out.push(acc.clone());
            prev = k;
        }
        out
    }

    /// Item ids sorted by descending match count (ties broken by
    /// ascending id for determinism) — the ranked list Figures 5–7 are
    /// computed over. Counting sort over the [0, K] count range: O(n + K)
    /// instead of O(n log n) (EXPERIMENTS.md §Perf).
    pub fn rank(&self, query: &[f32], k_prefix: usize) -> Vec<u32> {
        let qc = self.query_codes(query);
        let m = self.matches(&qc, k_prefix);
        rank_by_counts(&m, k_prefix.min(self.k))
    }

    /// Direct access to one item's code row (PJRT cross-check tests).
    pub fn item_code_row(&self, j: usize) -> &[i32] {
        &self.item_codes[j * self.k..(j + 1) * self.k]
    }

    pub fn scale(&self) -> Option<&UScale> {
        self.scale.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transform::dot;

    fn items_with_norm_spread(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n)
            .map(|i| {
                let scale = 0.1 + 3.0 * (i as f32 / n as f32).powi(2);
                (0..d).map(|_| (rng.f32() - 0.5) * scale).collect()
            })
            .collect()
    }

    #[test]
    fn matches_bounded_by_k() {
        let items = items_with_norm_spread(50, 8, 1);
        let ranker =
            CollisionRanker::build(&items, Scheme::Alsh { m: 3 }, 32, 2.5, 0.83, 2);
        let q = vec![0.4f32; 8];
        let qc = ranker.query_codes(&q);
        for c in ranker.matches(&qc, 32) {
            assert!(c <= 32);
        }
    }

    #[test]
    fn prefix_matches_consistent() {
        // matches at k_prefix must equal counting over the first k_prefix
        // codes by hand.
        let items = items_with_norm_spread(30, 6, 3);
        let ranker =
            CollisionRanker::build(&items, Scheme::Alsh { m: 2 }, 16, 2.5, 0.83, 4);
        let q = vec![0.2f32, -0.1, 0.5, 0.9, -0.3, 0.0];
        let qc = ranker.query_codes(&q);
        let m8 = ranker.matches(&qc, 8);
        for j in 0..30 {
            let row = ranker.item_code_row(j);
            let want = row[..8].iter().zip(&qc[..8]).filter(|(a, b)| a == b).count();
            assert_eq!(m8[j], want as u32);
        }
    }

    #[test]
    fn rank_is_a_permutation() {
        let items = items_with_norm_spread(40, 5, 5);
        let ranker = CollisionRanker::build(&items, Scheme::L2Lsh, 16, 2.0, 0.83, 6);
        let ranked = ranker.rank(&[0.1, 0.2, 0.3, 0.4, 0.5], 16);
        let mut s = ranked.clone();
        s.sort_unstable();
        assert_eq!(s, (0..40).collect::<Vec<u32>>());
    }

    #[test]
    fn alsh_ranks_high_ip_items_above_random_on_average() {
        // With many hashes the top-ranked item should have much higher
        // inner product than the corpus median.
        let items = items_with_norm_spread(400, 16, 7);
        let ranker =
            CollisionRanker::build(&items, Scheme::Alsh { m: 3 }, 256, 2.5, 0.83, 8);
        let mut rng = Rng::seed_from_u64(9);
        let mut top_beats_median = 0;
        let trials = 20;
        for _ in 0..trials {
            let q: Vec<f32> = (0..16).map(|_| rng.f32() - 0.5).collect();
            let ranked = ranker.rank(&q, 256);
            let ips: Vec<f32> = items.iter().map(|v| dot(v, &q)).collect();
            let mut sorted_ips = ips.clone();
            sorted_ips.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let median = sorted_ips[200];
            if ips[ranked[0] as usize] > median {
                top_beats_median += 1;
            }
        }
        assert!(top_beats_median >= 18, "{top_beats_median}/{trials}");
    }

    #[test]
    fn alsh_beats_l2lsh_on_norm_spread_data() {
        // The headline claim, in miniature: on data with a wide norm
        // spread, ALSH top-10 retrieval beats symmetric L2LSH.
        let items = items_with_norm_spread(500, 16, 10);
        let alsh =
            CollisionRanker::build(&items, Scheme::Alsh { m: 3 }, 256, 2.5, 0.83, 11);
        let l2 = CollisionRanker::build(&items, Scheme::L2Lsh, 256, 2.5, 0.83, 11);
        let mut rng = Rng::seed_from_u64(12);
        let (mut alsh_hits, mut l2_hits) = (0usize, 0usize);
        for _ in 0..30 {
            let q: Vec<f32> = (0..16).map(|_| rng.f32() - 0.5).collect();
            let mut ips: Vec<(usize, f32)> =
                items.iter().enumerate().map(|(i, v)| (i, dot(v, &q))).collect();
            ips.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            let gold: Vec<u32> = ips[..10].iter().map(|&(i, _)| i as u32).collect();
            let in_gold = |ranked: &[u32]| {
                ranked[..50].iter().filter(|id| gold.contains(id)).count()
            };
            alsh_hits += in_gold(&alsh.rank(&q, 256));
            l2_hits += in_gold(&l2.rank(&q, 256));
        }
        assert!(
            alsh_hits > l2_hits,
            "ALSH {alsh_hits} vs L2LSH {l2_hits} gold-in-top-50 hits"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let items = items_with_norm_spread(20, 4, 13);
        let a = CollisionRanker::build(&items, Scheme::Alsh { m: 3 }, 8, 2.5, 0.83, 14);
        let b = CollisionRanker::build(&items, Scheme::Alsh { m: 3 }, 8, 2.5, 0.83, 14);
        let q = vec![0.5f32; 4];
        assert_eq!(a.rank(&q, 8), b.rank(&q, 8));
    }

    #[test]
    fn sign_alsh_codes_are_bits_and_ranker_works() {
        let items = items_with_norm_spread(60, 8, 20);
        let ranker =
            CollisionRanker::build(&items, Scheme::SignAlsh { m: 2 }, 64, 2.5, 0.75, 21);
        let q = vec![0.4f32; 8];
        let qc = ranker.query_codes(&q);
        assert!(qc.iter().all(|&c| c == 0 || c == 1));
        let ranked = ranker.rank(&q, 64);
        let mut s = ranked.clone();
        s.sort_unstable();
        assert_eq!(s, (0..60).collect::<Vec<u32>>());
    }

    #[test]
    fn sign_alsh_also_beats_l2lsh_on_norm_spread_data() {
        let items = items_with_norm_spread(500, 16, 22);
        let sign =
            CollisionRanker::build(&items, Scheme::SignAlsh { m: 2 }, 256, 2.5, 0.75, 23);
        let l2 = CollisionRanker::build(&items, Scheme::L2Lsh, 256, 2.5, 0.75, 23);
        let mut rng = Rng::seed_from_u64(24);
        let (mut sign_hits, mut l2_hits) = (0usize, 0usize);
        for _ in 0..30 {
            let q: Vec<f32> = (0..16).map(|_| rng.f32() - 0.5).collect();
            let mut ips: Vec<(usize, f32)> =
                items.iter().enumerate().map(|(i, v)| (i, dot(v, &q))).collect();
            ips.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            let gold: Vec<u32> = ips[..10].iter().map(|&(i, _)| i as u32).collect();
            let in_gold = |ranked: &[u32]| {
                ranked[..50].iter().filter(|id| gold.contains(id)).count()
            };
            sign_hits += in_gold(&sign.rank(&q, 256));
            l2_hits += in_gold(&l2.rank(&q, 256));
        }
        assert!(
            sign_hits > l2_hits,
            "Sign-ALSH {sign_hits} vs L2LSH {l2_hits} gold-in-top-50 hits"
        );
    }

    #[test]
    fn matches_at_ks_equals_individual_matches() {
        let items = items_with_norm_spread(40, 6, 30);
        let ranker =
            CollisionRanker::build(&items, Scheme::Alsh { m: 3 }, 64, 2.5, 0.83, 31);
        let q = vec![0.3f32; 6];
        let qc = ranker.query_codes(&q);
        let ks = [8usize, 16, 64];
        let swept = ranker.matches_at_ks(&qc, &ks);
        for (i, &k) in ks.iter().enumerate() {
            assert_eq!(swept[i], ranker.matches(&qc, k), "K={k}");
        }
    }
}

//! The pluggable MIPS hash-scheme layer: one enum that selects, end to
//! end, which asymmetric construction an index runs — transforms, hash
//! family, fused hasher, bucket keys, and multi-probe perturbation all
//! dispatch through it.
//!
//! # The three schemes
//!
//! | scheme | transform pair | hash | bucket key |
//! |---|---|---|---|
//! | [`MipsHashScheme::L2Alsh`] | `P(x)=[x; ‖x‖²; …]`, `Q(q)=[q/‖q‖; ½; …]` (Eq. 12–13) | quantized L2LSH `floor((aᵀx+b)/r)` | avalanche mix of K i32 codes |
//! | [`MipsHashScheme::SignAlsh`] | `P(x)=[x; ½−‖x‖²; …]`, `Q(q)=[q/‖q‖; 0; …]` (Shrivastava & Li 2015) | SRP sign bit `1[aᵀx>=0]` | K bits packed into one u64 word |
//! | [`MipsHashScheme::SimpleLsh`] | `P(x)=[x; √(1−‖x‖²)]`, `Q(q)=[q/‖q‖; 0]` (Neyshabur & Srebro 2015) | SRP sign bit | K bits packed into one u64 word |
//!
//! All three share the Eq. 11 norm shrink (`max ‖x‖ -> U < 1`) on the
//! data side, and all three query transforms are **scale-free**, which is
//! why the norm-range banded [`super::NormRangeIndex`] works per scheme:
//! a query hashes once and the codes replay against every band.
//!
//! Simple-LSH appends exactly **one** component, so `AlshParams::m` is
//! ignored by it (the effective append length is
//! [`MipsHashScheme::append_len`]).
//!
//! # Dispatch design
//!
//! Scheme state rides in [`crate::index::AlshParams::scheme`], so every
//! existing build/serve entry point (`AlshIndex::build`,
//! `MipsEngine::new`, `ShardedRouter::build`, persistence) selects a
//! scheme without signature changes. The index stores its families as a
//! [`SchemeFamilies`] and hashes through a [`SchemeHasher`] — two-variant
//! enums (L2 / SRP), not trait objects, for the same reasons as
//! [`super::AnyIndex`]: the hot paths borrow out of the caller's scratch
//! and the match arms inline. With `scheme = L2Alsh` (the default) every
//! code path — family sampling RNG stream, fused hashing, bucket keys,
//! probe order — is **byte-identical** to the pre-scheme-layer code.

use crate::lsh::{FusedHasher, FusedSrpHasher, L2LshFamily, SrpFamily};
use crate::transform::{
    q_transform_sign_into, q_transform_sign_slice, q_transform_slice,
    scale_p_transform_sign_slice, scale_p_transform_simple_slice, scale_p_transform_slice,
};
use crate::util::Rng;

use super::hash_table::{bucket_key, srp_bucket_key};

/// Which asymmetric MIPS construction an index runs (see module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MipsHashScheme {
    /// The paper's L2-ALSH (Eq. 11–17): quantized L2LSH over P/Q.
    #[default]
    L2Alsh,
    /// Sign-ALSH (Shrivastava & Li 2015): SRP over the sign transforms.
    SignAlsh,
    /// Simple-LSH (Neyshabur & Srebro 2015): single-append symmetric SRP.
    SimpleLsh,
}

impl MipsHashScheme {
    /// Every scheme, in persist-id order.
    pub const ALL: [MipsHashScheme; 3] =
        [MipsHashScheme::L2Alsh, MipsHashScheme::SignAlsh, MipsHashScheme::SimpleLsh];

    /// Stable id (persist v4 header discriminator).
    pub fn id(self) -> u32 {
        match self {
            MipsHashScheme::L2Alsh => 0,
            MipsHashScheme::SignAlsh => 1,
            MipsHashScheme::SimpleLsh => 2,
        }
    }

    /// Inverse of [`MipsHashScheme::id`].
    pub fn from_id(id: u32) -> Option<Self> {
        Self::ALL.into_iter().find(|s| s.id() == id)
    }

    /// Canonical CLI / JSON name.
    pub fn name(self) -> &'static str {
        match self {
            MipsHashScheme::L2Alsh => "l2-alsh",
            MipsHashScheme::SignAlsh => "sign-alsh",
            MipsHashScheme::SimpleLsh => "simple-lsh",
        }
    }

    /// Parse a CLI name (`l2-alsh` | `sign-alsh` | `simple-lsh`).
    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|v| v.name() == s)
    }

    /// Scan CLI args for the shared `--scheme <name>` flag (the
    /// examples' selector). Absent flag means the default L2-ALSH; an
    /// unknown name returns a ready-to-print usage error so every
    /// binary reports the same scheme list.
    pub fn from_cli_args(args: &[String]) -> Result<Self, String> {
        match args.iter().position(|a| a == "--scheme") {
            Some(i) => {
                let name = args.get(i + 1).map(String::as_str).unwrap_or("");
                Self::parse(name).ok_or_else(|| {
                    format!(
                        "unknown --scheme {name:?}; use l2-alsh, sign-alsh or simple-lsh"
                    )
                })
            }
            None => Ok(Self::L2Alsh),
        }
    }

    /// Whether the scheme hashes with sign random projections (bit-packed
    /// u64 bucket keys, bit-flip multi-probe).
    pub fn is_srp(self) -> bool {
        !matches!(self, MipsHashScheme::L2Alsh)
    }

    /// Components appended to data/query vectors: `m` for the two ALSH
    /// schemes, always 1 for Simple-LSH (its transform is single-append).
    pub fn append_len(self, m: usize) -> usize {
        match self {
            MipsHashScheme::L2Alsh | MipsHashScheme::SignAlsh => m,
            MipsHashScheme::SimpleLsh => 1,
        }
    }

    /// Fused Eq. 11 scaling + P transform into a preallocated `[D +
    /// append_len]` slice — the build-side block-fill path, per scheme.
    #[inline]
    pub fn data_row_into(self, x: &[f32], factor: f32, m: usize, out: &mut [f32]) {
        match self {
            MipsHashScheme::L2Alsh => scale_p_transform_slice(x, factor, m, out),
            MipsHashScheme::SignAlsh => scale_p_transform_sign_slice(x, factor, m, out),
            MipsHashScheme::SimpleLsh => scale_p_transform_simple_slice(x, factor, out),
        }
    }

    /// Q transform into a preallocated `[D + append_len]` slice (the
    /// batch query path). All three are scale-free in the query norm.
    #[inline]
    pub fn query_row_into(self, q: &[f32], m: usize, out: &mut [f32]) {
        match self {
            MipsHashScheme::L2Alsh => q_transform_slice(q, m, out),
            MipsHashScheme::SignAlsh => q_transform_sign_slice(q, m, out),
            MipsHashScheme::SimpleLsh => q_transform_sign_slice(q, 1, out),
        }
    }

    /// Allocation-free Q transform reusing `out`'s capacity (the
    /// single-query hot path).
    #[inline]
    pub fn query_into(self, q: &[f32], m: usize, out: &mut Vec<f32>) {
        match self {
            MipsHashScheme::L2Alsh => crate::transform::q_transform_into(q, m, out),
            MipsHashScheme::SignAlsh => q_transform_sign_into(q, m, out),
            MipsHashScheme::SimpleLsh => q_transform_sign_into(q, 1, out),
        }
    }

    /// One table's bucket key from its K codes: avalanche mix for L2LSH
    /// codes, bit-pack for SRP sign bits.
    #[inline]
    pub fn table_key(self, codes_t: &[i32]) -> u64 {
        if self.is_srp() {
            srp_bucket_key(codes_t)
        } else {
            bucket_key(codes_t)
        }
    }

    /// Sample the L hash families for this scheme over input dimension
    /// `dp` (= D + append_len). For `L2Alsh` the RNG stream is exactly
    /// the historical `L2LshFamily::sample` sequence — the pre-scheme
    /// byte-identity rests on this.
    pub fn sample_families(
        self,
        dp: usize,
        k_per_table: usize,
        n_tables: usize,
        r: f32,
        rng: &mut Rng,
    ) -> SchemeFamilies {
        if self.is_srp() {
            assert!(
                k_per_table <= 64,
                "SRP schemes pack K sign bits into a u64 bucket key; K={k_per_table} > 64"
            );
            SchemeFamilies::Srp(
                (0..n_tables).map(|_| SrpFamily::sample(dp, k_per_table, rng)).collect(),
            )
        } else {
            SchemeFamilies::L2(
                (0..n_tables).map(|_| L2LshFamily::sample(dp, k_per_table, r, rng)).collect(),
            )
        }
    }
}

impl std::fmt::Display for MipsHashScheme {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The L hash families of an index, per scheme (persistence, PJRT
/// artifact inputs, reference/code-fed paths).
#[derive(Clone, Debug)]
pub enum SchemeFamilies {
    /// K-wide L2LSH families (the `L2Alsh` scheme).
    L2(Vec<L2LshFamily>),
    /// K-wide SRP families (the `SignAlsh` / `SimpleLsh` schemes).
    Srp(Vec<SrpFamily>),
}

impl SchemeFamilies {
    /// Number of families (= L tables).
    pub fn len(&self) -> usize {
        match self {
            SchemeFamilies::L2(f) => f.len(),
            SchemeFamilies::Srp(f) => f.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The L2LSH families, if this is the L2-ALSH scheme.
    pub fn as_l2(&self) -> Option<&[L2LshFamily]> {
        match self {
            SchemeFamilies::L2(f) => Some(f),
            SchemeFamilies::Srp(_) => None,
        }
    }

    /// The SRP families, if this is an SRP scheme.
    pub fn as_srp(&self) -> Option<&[SrpFamily]> {
        match self {
            SchemeFamilies::L2(_) => None,
            SchemeFamilies::Srp(f) => Some(f),
        }
    }

    /// Stack the families into the scheme's fused multi-table hasher.
    pub fn fuse(&self) -> SchemeHasher {
        match self {
            SchemeFamilies::L2(f) => SchemeHasher::L2(FusedHasher::from_families(f)),
            SchemeFamilies::Srp(f) => SchemeHasher::Srp(FusedSrpHasher::from_families(f)),
        }
    }
}

/// The fused multi-table hasher of an index, per scheme: one blocked
/// matvec/matmat pass produces all `L·K` codes whichever hash family the
/// scheme uses. Mirrors the [`FusedHasher`] surface so `BuildScratch`,
/// the sharded streaming build, `QueryScratch` replay, and the batchers
/// drive either variant identically.
#[derive(Clone, Debug)]
pub enum SchemeHasher {
    /// Quantized L2LSH (codes are `floor` quantization cells).
    L2(FusedHasher),
    /// Sign random projections (codes are 0/1 sign bits).
    Srp(FusedSrpHasher),
}

impl SchemeHasher {
    /// Input dimension D' (= D + append_len).
    pub fn dim(&self) -> usize {
        match self {
            SchemeHasher::L2(h) => h.dim(),
            SchemeHasher::Srp(h) => h.dim(),
        }
    }

    /// Codes per table (meta-hash width K).
    pub fn k(&self) -> usize {
        match self {
            SchemeHasher::L2(h) => h.k(),
            SchemeHasher::Srp(h) => h.k(),
        }
    }

    /// Number of tables L.
    pub fn n_tables(&self) -> usize {
        match self {
            SchemeHasher::L2(h) => h.n_tables(),
            SchemeHasher::Srp(h) => h.n_tables(),
        }
    }

    /// Total codes per input (= L·K).
    pub fn n_codes(&self) -> usize {
        match self {
            SchemeHasher::L2(h) => h.n_codes(),
            SchemeHasher::Srp(h) => h.n_codes(),
        }
    }

    /// The L2 fused hasher, if this is the L2-ALSH scheme (benches,
    /// PJRT-parity reference paths).
    pub fn as_l2(&self) -> Option<&FusedHasher> {
        match self {
            SchemeHasher::L2(h) => Some(h),
            SchemeHasher::Srp(_) => None,
        }
    }

    /// One table's bucket key from its K codes, derived from the hasher
    /// variant itself (avalanche mix for L2 codes, bit-pack for SRP sign
    /// bits). The build pipeline keys through this so a hasher and its
    /// key function can never disagree; it always matches
    /// [`MipsHashScheme::table_key`] for the scheme the hasher was
    /// sampled under.
    #[inline]
    pub fn table_key(&self, codes_t: &[i32]) -> u64 {
        match self {
            SchemeHasher::L2(_) => bucket_key(codes_t),
            SchemeHasher::Srp(_) => srp_bucket_key(codes_t),
        }
    }

    /// All `L·K` codes of `x` into `out` (len `n_codes()`), one blocked
    /// matrix–vector pass.
    #[inline]
    pub fn hash_into(&self, x: &[f32], out: &mut [i32]) {
        match self {
            SchemeHasher::L2(h) => h.hash_into(x, out),
            SchemeHasher::Srp(h) => h.hash_into(x, out),
        }
    }

    /// Codes plus the per-code multi-probe confidence channel: pre-floor
    /// fractional parts for L2 (boundary distance within the cell), sign
    /// margins `|aᵀx|` for SRP (distance to the sign boundary).
    #[inline]
    pub fn hash_conf_into(&self, x: &[f32], codes: &mut [i32], conf: &mut [f32]) {
        match self {
            SchemeHasher::L2(h) => h.hash_frac_into(x, codes, conf),
            SchemeHasher::Srp(h) => h.hash_margin_into(x, codes, conf),
        }
    }

    /// Batch matrix–matrix variant (`[n_rows × D']` in, `[n_rows × L·K]`
    /// out) — the build side and the batch query path.
    #[inline]
    pub fn hash_batch_into(&self, xs: &[f32], n_rows: usize, out: &mut [i32]) {
        match self {
            SchemeHasher::L2(h) => h.hash_batch_into(xs, n_rows, out),
            SchemeHasher::Srp(h) => h.hash_batch_into(xs, n_rows, out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_and_names_roundtrip() {
        for scheme in MipsHashScheme::ALL {
            assert_eq!(MipsHashScheme::from_id(scheme.id()), Some(scheme));
            assert_eq!(MipsHashScheme::parse(scheme.name()), Some(scheme));
            assert_eq!(format!("{scheme}"), scheme.name());
        }
        assert_eq!(MipsHashScheme::from_id(99), None);
        assert_eq!(MipsHashScheme::parse("alsh"), None);
        assert_eq!(MipsHashScheme::default(), MipsHashScheme::L2Alsh);
    }

    #[test]
    fn append_len_per_scheme() {
        assert_eq!(MipsHashScheme::L2Alsh.append_len(3), 3);
        assert_eq!(MipsHashScheme::SignAlsh.append_len(2), 2);
        // Simple-LSH is single-append whatever m says.
        assert_eq!(MipsHashScheme::SimpleLsh.append_len(3), 1);
        assert_eq!(MipsHashScheme::SimpleLsh.append_len(0), 1);
    }

    #[test]
    fn table_key_dispatch() {
        // L2: avalanche mix; SRP: bit pack.
        assert_eq!(MipsHashScheme::L2Alsh.table_key(&[1, 0, 1]), bucket_key(&[1, 0, 1]));
        assert_eq!(MipsHashScheme::SignAlsh.table_key(&[1, 0, 1]), 0b101);
        assert_eq!(MipsHashScheme::SimpleLsh.table_key(&[0, 1]), 0b10);
    }

    #[test]
    fn sampled_families_fuse_consistently() {
        let mut rng = Rng::seed_from_u64(3);
        for scheme in MipsHashScheme::ALL {
            let fams = scheme.sample_families(10, 4, 3, 2.5, &mut rng);
            assert_eq!(fams.len(), 3);
            assert_eq!(fams.as_l2().is_some(), !scheme.is_srp());
            assert_eq!(fams.as_srp().is_some(), scheme.is_srp());
            let hasher = fams.fuse();
            assert_eq!(hasher.dim(), 10);
            assert_eq!(hasher.k(), 4);
            assert_eq!(hasher.n_tables(), 3);
            assert_eq!(hasher.n_codes(), 12);
            let x: Vec<f32> = (0..10).map(|i| (i as f32 * 0.37).sin()).collect();
            let mut codes = vec![0i32; 12];
            hasher.hash_into(&x, &mut codes);
            let mut conf = vec![0f32; 12];
            let mut codes2 = vec![0i32; 12];
            hasher.hash_conf_into(&x, &mut codes2, &mut conf);
            assert_eq!(codes, codes2, "{scheme}: conf variant changed codes");
            if scheme.is_srp() {
                assert!(codes.iter().all(|&c| c == 0 || c == 1), "{scheme}");
            }
            // The hasher-derived key function agrees with the scheme's
            // (the build pipeline keys through the hasher).
            assert_eq!(
                hasher.table_key(&codes[..4]),
                scheme.table_key(&codes[..4]),
                "{scheme}: hasher/scheme key disagreement"
            );
        }
    }

    /// L2-ALSH family sampling must be the exact historical RNG stream.
    #[test]
    fn l2_sampling_matches_direct_family_sampling() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        let fams = MipsHashScheme::L2Alsh.sample_families(9, 5, 4, 2.5, &mut a);
        let direct: Vec<L2LshFamily> =
            (0..4).map(|_| L2LshFamily::sample(9, 5, 2.5, &mut b)).collect();
        let x: Vec<f32> = (0..9).map(|i| i as f32 * 0.21 - 0.9).collect();
        for (fam, want) in fams.as_l2().unwrap().iter().zip(&direct) {
            assert_eq!(fam.hash(&x), want.hash(&x));
        }
    }

    #[test]
    #[should_panic]
    fn srp_k_over_64_rejected() {
        let mut rng = Rng::seed_from_u64(1);
        let _ = MipsHashScheme::SignAlsh.sample_families(4, 65, 1, 2.5, &mut rng);
    }

    /// Data/query rows agree with the standalone transform functions and
    /// preserve the transformed inner product per scheme's contract.
    #[test]
    fn transform_dispatch_matches_standalone() {
        let x = [0.3f32, 0.4];
        let q = [3.0f32, 4.0];
        let m = 2;
        for scheme in MipsHashScheme::ALL {
            let dp = 2 + scheme.append_len(m);
            let mut data = vec![0.0f32; dp];
            scheme.data_row_into(&x, 1.0, m, &mut data);
            let mut qrow = vec![0.0f32; dp];
            scheme.query_row_into(&q, m, &mut qrow);
            let mut qvec = Vec::new();
            scheme.query_into(&q, m, &mut qvec);
            assert_eq!(qvec, qrow, "{scheme}: vec vs slice Q diverge");
            match scheme {
                MipsHashScheme::L2Alsh => {
                    assert_eq!(data, crate::transform::p_transform(&x, m));
                    assert_eq!(qrow, crate::transform::q_transform(&q, m));
                }
                MipsHashScheme::SignAlsh => {
                    assert_eq!(data, crate::transform::p_transform_sign(&x, m));
                    assert_eq!(qrow, crate::transform::q_transform_sign(&q, m));
                }
                MipsHashScheme::SimpleLsh => {
                    assert_eq!(data, crate::transform::p_transform_simple(&x));
                    assert_eq!(qrow, crate::transform::q_transform_sign(&q, 1));
                }
            }
        }
    }
}

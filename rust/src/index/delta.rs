//! Live mutable index tier: crash-consistent upserts/deletes under
//! concurrent readers, with verified background compaction.
//!
//! A [`LiveIndex`] layers a mutable **delta** over a frozen base
//! generation (any [`AnyIndex`] layout — flat or banded, heap or mmap).
//! Every query path replays the query's codes against both layers in one
//! dedup pass and reranks the union with the shared exact kernel, so a
//! live index with an **empty** delta returns results byte-identical to
//! its frozen base.
//!
//! # On-disk layout and recovery contract
//!
//! A live index owns a directory:
//!
//! ```text
//! MANIFEST        current generation G + build seed (atomic rename, checksummed)
//! gen-<G>.alsh    the frozen base for generation G (v5 container)
//! gen-<G>.ids     external ids of the base rows, ascending (checksummed)
//! wal-<G>.log     append-only WAL of mutations since gen-<G> (see `index::wal`)
//! ```
//!
//! Every upsert/delete is appended to the WAL — checksummed, `fsync`'d —
//! **before** it is applied in memory, so the on-disk state is always
//! `snapshot ⊕ durable WAL prefix`. Recovery ([`LiveIndex::open`]) reads
//! the MANIFEST, opens the generation it names, replays the WAL over it
//! (truncating a torn tail at the first bad record), and reaches a state
//! byte-equal to a from-scratch instance that applied the same surviving
//! mutation prefix live (property-tested in `tests/crash_recovery.rs`).
//! Files from other generations and stale `*.tmp.*` save leftovers are
//! swept on open — they are compaction or save attempts that never
//! reached their MANIFEST commit point.
//!
//! # Reader guarantee (epoch snapshot swap)
//!
//! Readers never take a lock on the query path's steady state. The
//! current [`LiveSnapshot`] (base generation + delta) is published
//! through an epoch cell: one atomic generation counter plus a mutex'd
//! `Arc` slot that writers replace wholesale. Each reader caches the
//! `(cell, generation, Arc)` triple in its [`QueryScratch`]; while the
//! generation is unchanged a query costs one atomic load, and when it
//! has changed the reader re-clones the `Arc` under a lock held only for
//! that clone — never while building, hashing, or compacting. Queries
//! then run entirely against their snapshot, so a reader mid-query is
//! immune to concurrent mutations and compaction swaps (asserted by the
//! serve-while-compacting tests in `tests/live_mutation.rs`).
//!
//! # Delta structure
//!
//! The delta holds, per snapshot: appended rows (`vectors`), per-table
//! sorted `(bucket key, row)` runs binary-searched with the **same**
//! scheme codes the frozen tables are keyed by, a tombstone bitset over
//! base rows, and the external-id maps. Upserting an id that lives in
//! the base tombstones the base row and appends a delta row; upserting
//! an id already in the delta kills the old delta row. Internally ids
//! are dense: `0..n_base` are base rows, `n_base..` index delta rows,
//! and results are translated back to external ids after rerank.
//!
//! # Norm-band migration
//!
//! Over a banded base, a delta row is hashed with the scale factor of
//! the band whose frozen `[min_norm, max_norm]` range covers its norm
//! (clamped to the extreme bands when it falls outside every range —
//! the approximation-quality cost of serving a drifted norm from a
//! frozen banding). When an upsert changes an item's norm across a band
//! boundary, the delta row simply carries its new band assignment; the
//! next compaction re-fits the band partition and per-band U scales over
//! the live item set, completing the migration exactly.
//!
//! # Compaction
//!
//! [`LiveIndex::compact_once`] collects the live rows (base minus
//! tombstones, plus live delta rows), sorted by external id, and
//! rebuilds a frozen index with the **original** seed and params through
//! the normal sharded build pipeline — so the new generation is
//! byte-identical to a from-scratch build over the same logical item
//! set. The protocol: write `gen-<G+1>.alsh` + `gen-<G+1>.ids`, create
//! an empty `wal-<G+1>.log`, then atomically rename the new MANIFEST —
//! the single commit point — then swap the in-memory snapshot and sweep
//! old-generation files. A crash (or injected [`CompactorFaultPlan`]
//! fault) before the MANIFEST rename recovers to the old generation
//! plus its WAL; after it, to the new generation with an empty delta.
//! Mutations stall for the duration of a compaction (they share the
//! writer lock); readers never do.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, Weak};

use super::any::AnyIndex;
use super::banded::{BandedParams, NormRangeIndex};
use super::budget::ProbeBudget;
use super::core::{AlshIndex, AlshParams, ScoredItem};
use super::multiprobe::for_each_probe_key;
use super::persist::{self, PersistFormat};
use super::rerank::rerank_dual_into;
use super::scheme::{SchemeFamilies, SchemeHasher};
use super::scratch::QueryScratch;
use super::storage::{Mapped, Owned, Storage};
use super::wal::{Wal, WalRecord};
use crate::util::xxh64;
use crate::Result;
use anyhow::{bail, ensure, Context};

const MANIFEST_MAGIC: &[u8; 8] = b"ALSHLIV1";
const MANIFEST_SEED: u64 = 0xA15B_11FE;
const IDS_MAGIC: &[u8; 8] = b"ALSHIDS1";
const IDS_SEED: u64 = 0xA15B_01D5;

/// How each live generation's base file is opened: heap
/// ([`Owned`], streaming load) or zero-copy ([`Mapped`], `open_mmap`).
/// The base is *always* served from the persisted generation file —
/// even right after [`LiveIndex::create`] — so the serving state is the
/// recovery state by construction.
pub trait LiveStorage: Storage + Sized {
    /// Open a generation's base index file in this storage.
    fn open_base(path: &Path) -> Result<AnyIndex<Self>>;
}

impl LiveStorage for Owned {
    fn open_base(path: &Path) -> Result<AnyIndex<Self>> {
        persist::load_any(path)
    }
}

impl LiveStorage for Mapped {
    fn open_base(path: &Path) -> Result<AnyIndex<Self>> {
        persist::open_mmap(path)
    }
}

/// Build-time configuration for a new live index directory.
#[derive(Clone, Copy, Debug)]
pub struct LiveConfig {
    /// ALSH parameters for every generation's frozen base.
    pub params: AlshParams,
    /// Norm bands per generation: `<= 1` builds the flat layout,
    /// otherwise the norm-range banded layout.
    pub n_bands: usize,
    /// Build seed, persisted in the MANIFEST: every compaction rebuilds
    /// with it, so the hash families — and therefore the delta's bucket
    /// keys — are stable across generations.
    pub seed: u64,
    /// Write backpressure: once the pending delta (live rows + dead
    /// delta rows + base tombstones) reaches this bound, mutations are
    /// refused with a structured [`WriteStalled`] until compaction
    /// drains the delta. This caps both memory growth and the
    /// per-mutation copy-on-write clone cost.
    pub delta_cap: usize,
}

impl Default for LiveConfig {
    fn default() -> Self {
        Self {
            params: AlshParams::default(),
            n_bands: 1,
            seed: 0x5EED,
            delta_cap: 1 << 20,
        }
    }
}

/// Structured backpressure error: the delta hit [`LiveConfig::delta_cap`]
/// and the mutation was refused **before** any WAL append or sequence
/// assignment (so a stalled write never diverges replicas). The caller
/// should retry after `retry_after_ms` — derived from the most recent
/// compaction's duration, the best local estimate of how long the drain
/// will take.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WriteStalled {
    /// Pending delta work (live + dead delta rows + base tombstones).
    pub pending: usize,
    /// The configured cap that was hit.
    pub cap: usize,
    /// Suggested client retry delay.
    pub retry_after_ms: u64,
}

impl std::fmt::Display for WriteStalled {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "write stalled: delta backlog {} at cap {} (retry after {} ms)",
            self.pending, self.cap, self.retry_after_ms
        )
    }
}

impl std::error::Error for WriteStalled {}

/// Structured sequencing error on the replicated fan-out path: a member
/// was asked to apply a record whose group sequence number is not the
/// next one its WAL expects. `got > expected` means the member missed
/// writes and must catch up; `got < expected` means it already has the
/// record (an idempotent no-op for the caller).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SeqGap {
    /// The sequence number this member's WAL expects next.
    pub expected: u64,
    /// The sequence number the record carried.
    pub got: u64,
}

impl std::fmt::Display for SeqGap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "sequence gap: record carries seq {}, member expects {}", self.got, self.expected)
    }
}

impl std::error::Error for SeqGap {}

/// Fault-injection plan for the compactor (the crash-consistency test
/// harness; all-off in production). An injected crash abandons the
/// remaining protocol steps and marks the writer defunct — exactly the
/// on-disk state a real crash at that point leaves — after which the
/// instance should be dropped and the directory re-opened.
#[derive(Clone, Copy, Debug, Default)]
pub struct CompactorFaultPlan {
    /// Crash after writing the new generation's files but before the
    /// MANIFEST rename (recovery must land on the *old* generation).
    pub crash_before_manifest: bool,
    /// Crash right after the MANIFEST rename, before the in-memory swap
    /// and old-file sweep (recovery must land on the *new* generation).
    pub crash_after_manifest: bool,
    /// Panic at compaction entry — poisons a background compactor
    /// thread while leaving serving untouched.
    pub poison: bool,
}

/// Point-in-time live counters (mirrored into `coordinator::metrics`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LiveStats {
    /// Live (non-superseded) delta rows.
    pub delta_items: u64,
    /// Tombstoned base rows plus dead (superseded/deleted) delta rows.
    pub tombstones: u64,
    /// Completed compactions over this instance's lifetime.
    pub compactions: u64,
    /// Current WAL file length in bytes.
    pub wal_bytes: u64,
    /// Wall-clock milliseconds of the most recent compaction.
    pub last_compaction_ms: u64,
    /// Current base generation number.
    pub generation: u64,
    /// Logical item count (base − tombstones + live delta rows).
    pub n_items: u64,
    /// Highest durable WAL sequence number (0 before the first write).
    /// Comparable across replica-group members: equal high-waters mean
    /// equal applied mutation histories.
    pub high_water: u64,
}

/// One delta row's bookkeeping; the vector lives at the same row index
/// in `DeltaState::vectors`.
#[derive(Clone, Copy, Debug)]
struct DeltaEntry {
    ext_id: u32,
    /// Band the row was hashed under (0 for a flat base).
    band: u32,
    alive: bool,
}

/// The mutable overlay, cloned copy-on-write per mutation so published
/// snapshots stay immutable. Compaction bounds its size, so the clone
/// is O(delta), not O(corpus).
#[derive(Clone, Debug, Default)]
struct DeltaState {
    entries: Vec<DeltaEntry>,
    /// `[entries.len() × dim]` row-major delta rows (dead rows keep
    /// their slot; rerank only visits alive ones).
    vectors: Vec<f32>,
    /// Per table: `(bucket key, delta row)` sorted ascending — the
    /// mutable twin of the frozen CSR, probed by binary search with the
    /// same `SchemeHasher` codes.
    runs: Vec<Vec<(u64, u32)>>,
    /// External id → live delta row.
    ext_to_row: HashMap<u32, u32>,
    /// Tombstone bitset over base rows.
    base_dead: Vec<u64>,
    n_base_dead: usize,
    n_alive: usize,
}

impl DeltaState {
    fn empty(n_tables: usize) -> Self {
        Self { runs: vec![Vec::new(); n_tables], ..Self::default() }
    }

    fn base_is_dead(&self, id: u32) -> bool {
        self.base_dead
            .get(id as usize / 64)
            .is_some_and(|w| w & (1u64 << (id % 64)) != 0)
    }

    fn kill_base(&mut self, id: u32, n_base: usize) {
        if self.base_dead.is_empty() {
            self.base_dead = vec![0; n_base.div_ceil(64)];
        }
        let w = &mut self.base_dead[id as usize / 64];
        let bit = 1u64 << (id % 64);
        if *w & bit == 0 {
            *w |= bit;
            self.n_base_dead += 1;
        }
    }

    /// Push every alive row in table `t`'s run under `key` into the
    /// dedup sink as a global id (`n_base + row`), skipping rows whose
    /// band is outside the budgeted band set.
    fn probe_run(
        &self,
        t: usize,
        key: u64,
        band_min: u32,
        n_base: usize,
        sink: &mut super::scratch::DedupSink<'_>,
    ) {
        let run = &self.runs[t];
        let lo = run.partition_point(|&(k, _)| k < key);
        for &(k, row) in &run[lo..] {
            if k != key {
                break;
            }
            let e = &self.entries[row as usize];
            if e.alive && e.band >= band_min {
                sink.extend(&[(n_base + row as usize) as u32]);
            }
        }
    }
}

/// One frozen base generation as served: the index, its external ids
/// (ascending, one per base row), and the generation number.
struct BaseGen<S: Storage> {
    index: AnyIndex<S>,
    ids: Vec<u32>,
    gen: u64,
}

/// An immutable point-in-time view of the live index: a frozen base
/// generation plus the delta accumulated over it. Published wholesale
/// through the epoch cell; queries run entirely against one snapshot.
pub struct LiveSnapshot<S: Storage> {
    base: Arc<BaseGen<S>>,
    delta: DeltaState,
}

impl<S: Storage> LiveSnapshot<S> {
    fn n_base(&self) -> usize {
        self.base.index.n_items()
    }

    fn n_items(&self) -> usize {
        self.n_base() - self.delta.n_base_dead + self.delta.n_alive
    }
}

/// Epoch-swapped snapshot cell: an atomic generation plus a mutex'd
/// `Arc` slot. Writers bump the generation under the lock; readers with
/// a current cached generation never touch the lock (see module docs).
struct EpochCell<T> {
    /// Process-unique cell id, so a scratch's cached snapshot can never
    /// be mistaken for another index's at an equal generation.
    id: u64,
    generation: AtomicU64,
    slot: Mutex<Arc<T>>,
}

static CELL_IDS: AtomicU64 = AtomicU64::new(1);

impl<T> EpochCell<T> {
    fn new(value: Arc<T>) -> Self {
        Self {
            id: CELL_IDS.fetch_add(1, Ordering::Relaxed),
            generation: AtomicU64::new(1),
            slot: Mutex::new(value),
        }
    }

    fn publish(&self, value: Arc<T>) {
        let mut slot = lock(&self.slot);
        *slot = value;
        self.generation.fetch_add(1, Ordering::Release);
    }

    /// Clone the current snapshot with the generation it was read at
    /// (consistent: read under the same lock publish holds).
    fn read(&self) -> (u64, Arc<T>) {
        let slot = lock(&self.slot);
        (self.generation.load(Ordering::Acquire), slot.clone())
    }
}

/// Lock that survives a poisoned-by-panic mutex: the injected compactor
/// poison panics before any in-memory mutation, so the guarded state is
/// intact and serving must continue (the poisoned-compactor drill).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Writer-side state: the WAL and generation under one lock, so
/// mutations and compactions serialize while readers stay lock-free.
struct WriterState {
    wal: Wal,
    gen: u64,
    /// Set by an injected crash: the instance is defunct (as after a
    /// real crash) and every further mutation is refused until the
    /// directory is re-opened.
    crashed: bool,
}

struct LiveInner<S: Storage> {
    dir: PathBuf,
    params: AlshParams,
    n_bands: usize,
    seed: u64,
    dim: usize,
    /// Families/fused hasher are seed-determined, hence identical across
    /// generations — cached once for writer-side delta hashing.
    families: SchemeFamilies,
    fused: SchemeHasher,
    cell: EpochCell<LiveSnapshot<S>>,
    writer: Mutex<WriterState>,
    faults: Mutex<CompactorFaultPlan>,
    compactions: AtomicU64,
    /// Mirror of the writer's WAL length, so [`LiveIndex::stats`] never
    /// blocks on the writer lock (a compaction can hold it for a while).
    wal_bytes: AtomicU64,
    /// Mirror of the writer's WAL high-water sequence (same rationale).
    high_water: AtomicU64,
    /// Runtime-adjustable write-backpressure bound (see
    /// [`LiveConfig::delta_cap`]). Not persisted: reopen paths re-apply
    /// their configured cap via [`LiveIndex::set_delta_cap`].
    delta_cap: std::sync::atomic::AtomicUsize,
    last_compaction_ms: AtomicU64,
    stop: AtomicBool,
    compactor: Mutex<Option<std::thread::JoinHandle<()>>>,
}

/// The live mutable index (see module docs). Cheap to clone — a handle
/// over one shared state — which is how the background compactor and
/// the serving side share it.
pub struct LiveIndex<S: Storage = Owned> {
    inner: Arc<LiveInner<S>>,
}

impl<S: Storage> Clone for LiveIndex<S> {
    fn clone(&self) -> Self {
        Self { inner: Arc::clone(&self.inner) }
    }
}

fn gen_index_path(dir: &Path, generation: u64) -> PathBuf {
    dir.join(format!("gen-{generation}.alsh"))
}

fn gen_ids_path(dir: &Path, generation: u64) -> PathBuf {
    dir.join(format!("gen-{generation}.ids"))
}

fn wal_path(dir: &Path, generation: u64) -> PathBuf {
    dir.join(format!("wal-{generation}.log"))
}

fn write_manifest(dir: &Path, generation: u64, seed: u64) -> Result<()> {
    let mut b = Vec::with_capacity(36);
    b.extend_from_slice(MANIFEST_MAGIC);
    b.extend_from_slice(&1u32.to_le_bytes());
    b.extend_from_slice(&generation.to_le_bytes());
    b.extend_from_slice(&seed.to_le_bytes());
    let sum = xxh64(&b, MANIFEST_SEED);
    b.extend_from_slice(&sum.to_le_bytes());
    persist::atomic_write(&dir.join("MANIFEST"), |tmp| Ok(std::fs::write(tmp, &b)?))
}

fn read_manifest(dir: &Path) -> Result<(u64, u64)> {
    let path = dir.join("MANIFEST");
    let b = std::fs::read(&path)
        .with_context(|| format!("live index: read {}", path.display()))?;
    ensure!(
        b.len() == 36 && &b[..8] == MANIFEST_MAGIC,
        "live index: bad MANIFEST in {}",
        dir.display()
    );
    let version = u32::from_le_bytes(b[8..12].try_into().unwrap());
    ensure!(version == 1, "live index: unknown MANIFEST version {version}");
    let sum = u64::from_le_bytes(b[28..36].try_into().unwrap());
    ensure!(
        xxh64(&b[..28], MANIFEST_SEED) == sum,
        "live index: MANIFEST checksum mismatch in {}",
        dir.display()
    );
    let generation = u64::from_le_bytes(b[12..20].try_into().unwrap());
    let seed = u64::from_le_bytes(b[20..28].try_into().unwrap());
    Ok((generation, seed))
}

fn write_ids(path: &Path, ids: &[u32]) -> Result<()> {
    let mut b = Vec::with_capacity(16 + 4 * ids.len() + 8);
    b.extend_from_slice(IDS_MAGIC);
    b.extend_from_slice(&(ids.len() as u64).to_le_bytes());
    for id in ids {
        b.extend_from_slice(&id.to_le_bytes());
    }
    let sum = xxh64(&b, IDS_SEED);
    b.extend_from_slice(&sum.to_le_bytes());
    persist::atomic_write(path, |tmp| Ok(std::fs::write(tmp, &b)?))
}

fn read_ids(path: &Path) -> Result<Vec<u32>> {
    let b = std::fs::read(path)
        .with_context(|| format!("live index: read {}", path.display()))?;
    ensure!(
        b.len() >= 24 && &b[..8] == IDS_MAGIC,
        "live index: bad ids sidecar {}",
        path.display()
    );
    let n = u64::from_le_bytes(b[8..16].try_into().unwrap()) as usize;
    ensure!(
        b.len() == 16 + 4 * n + 8,
        "live index: ids sidecar length mismatch in {}",
        path.display()
    );
    let sum = u64::from_le_bytes(b[16 + 4 * n..].try_into().unwrap());
    ensure!(
        xxh64(&b[..16 + 4 * n], IDS_SEED) == sum,
        "live index: ids sidecar checksum mismatch in {}",
        path.display()
    );
    let ids: Vec<u32> = b[16..16 + 4 * n]
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    ensure!(
        ids.windows(2).all(|w| w[0] < w[1]),
        "live index: ids sidecar not strictly ascending in {}",
        path.display()
    );
    Ok(ids)
}

/// Build a frozen index over `items` with the live config — the same
/// call a from-scratch build would make, which is what makes every
/// compacted generation byte-identical to a fresh build.
fn build_base(items: &[Vec<f32>], params: AlshParams, n_bands: usize, seed: u64) -> AnyIndex {
    if n_bands <= 1 {
        AnyIndex::Flat(AlshIndex::build(items, params, seed))
    } else {
        AnyIndex::Banded(NormRangeIndex::build(
            items,
            params,
            BandedParams { n_bands },
            seed,
        ))
    }
}

/// Remove files belonging to generations other than `keep` plus stale
/// atomic-save temporaries. Best-effort: failures leave garbage, never
/// break recovery (the MANIFEST alone names the live generation).
fn sweep_other_generations(dir: &Path, keep: u64) {
    persist::sweep_stale_temps(dir).ok();
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let stale = ["gen-", "wal-"].iter().any(|&prefix| {
            name.strip_prefix(prefix)
                .and_then(|rest| rest.split('.').next())
                .and_then(|g| g.parse::<u64>().ok())
                .is_some_and(|g| g != keep)
        });
        if stale {
            std::fs::remove_file(entry.path()).ok();
        }
    }
}

impl<S: LiveStorage> LiveIndex<S> {
    /// Create a fresh live index at `dir` over `items` (external ids
    /// `0..n`): build and persist generation 0, create its empty WAL,
    /// commit the MANIFEST, and serve the base back out of the
    /// generation file (so created and recovered instances serve the
    /// exact same bytes).
    pub fn create(dir: impl AsRef<Path>, items: &[Vec<f32>], cfg: LiveConfig) -> Result<Self> {
        let entries: Vec<(u32, Vec<f32>)> = items
            .iter()
            .enumerate()
            .map(|(i, v)| (i as u32, v.clone()))
            .collect();
        Self::create_with_state(dir, &entries, cfg, 1)
    }

    /// Create a live index over an explicit `(external id, vector)` set,
    /// with the WAL numbered from `base_seq`. This is the
    /// rebuild-from-peer path of the replicated write tier: the peer's
    /// live item set plus `peer high-water + 1` produce a member whose
    /// state and sequence numbering both agree with the group. Any
    /// previous contents of `dir` are superseded (the new generation 0
    /// MANIFEST is the commit point; old generations are swept).
    pub fn create_with_state(
        dir: impl AsRef<Path>,
        entries: &[(u32, Vec<f32>)],
        cfg: LiveConfig,
        base_seq: u64,
    ) -> Result<Self> {
        let dir = dir.as_ref();
        ensure!(!entries.is_empty(), "live index: empty initial item set");
        ensure!(base_seq >= 1, "live index: sequence numbers start at 1");
        let dim = entries[0].1.len();
        ensure!(
            entries.iter().all(|(_, v)| v.len() == dim),
            "live index: ragged initial item dims"
        );
        let mut sorted: Vec<&(u32, Vec<f32>)> = entries.iter().collect();
        sorted.sort_unstable_by_key(|(ext, _)| *ext);
        ensure!(
            sorted.windows(2).all(|w| w[0].0 < w[1].0),
            "live index: duplicate external ids in initial item set"
        );
        let ids: Vec<u32> = sorted.iter().map(|(ext, _)| *ext).collect();
        let items: Vec<Vec<f32>> = sorted.iter().map(|(_, v)| v.clone()).collect();
        std::fs::create_dir_all(dir)?;
        let base = build_base(&items, cfg.params, cfg.n_bands, cfg.seed);
        base.save_as(gen_index_path(dir, 0), PersistFormat::V5)?;
        write_ids(&gen_ids_path(dir, 0), &ids)?;
        let wal = Wal::create(wal_path(dir, 0), base_seq)?;
        write_manifest(dir, 0, cfg.seed)?;
        let live = Self::assemble(dir, 0, cfg.seed, ids, wal, Vec::new())?;
        live.set_delta_cap(cfg.delta_cap);
        Ok(live)
    }

    /// Recover a live index from `dir`: read the MANIFEST, open the
    /// generation it names, replay the WAL over it (truncating a torn
    /// tail), and sweep files no committed state references.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let (generation, seed) = read_manifest(dir)?;
        let ids = read_ids(&gen_ids_path(dir, generation))?;
        let (wal, records) = Wal::open(wal_path(dir, generation))?;
        Self::assemble(dir, generation, seed, ids, wal, records)
    }

    /// Shared tail of `create`/`open`/compaction swap: open the base
    /// from its generation file, replay `records` into a fresh delta,
    /// publish, and sweep everything the MANIFEST doesn't reference.
    fn assemble(
        dir: &Path,
        generation: u64,
        seed: u64,
        ids: Vec<u32>,
        wal: Wal,
        records: Vec<WalRecord>,
    ) -> Result<Self> {
        let index = S::open_base(&gen_index_path(dir, generation))?;
        ensure!(
            ids.len() == index.n_items(),
            "live index: ids sidecar holds {} ids for {} base rows",
            ids.len(),
            index.n_items()
        );
        let params = *index.params();
        let n_bands = index.n_bands();
        let dim = index.dim();
        let families = index.scheme_families().clone();
        let fused = families.fuse();
        let base = Arc::new(BaseGen { index, ids, gen: generation });
        let snapshot = Arc::new(LiveSnapshot {
            base: Arc::clone(&base),
            delta: DeltaState::empty(params.n_tables),
        });
        let inner = Arc::new(LiveInner {
            dir: dir.to_path_buf(),
            params,
            n_bands,
            seed,
            dim,
            families,
            fused,
            cell: EpochCell::new(snapshot),
            wal_bytes: AtomicU64::new(wal.bytes()),
            high_water: AtomicU64::new(wal.high_water()),
            delta_cap: std::sync::atomic::AtomicUsize::new(LiveConfig::default().delta_cap),
            writer: Mutex::new(WriterState { wal, gen: generation, crashed: false }),
            faults: Mutex::new(CompactorFaultPlan::default()),
            compactions: AtomicU64::new(0),
            last_compaction_ms: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            compactor: Mutex::new(None),
        });
        let live = Self { inner };
        // Replay the surviving WAL prefix through the normal apply path
        // (without re-logging), so a recovered delta is byte-equal to
        // one built by the original live mutations.
        if !records.is_empty() {
            let snap = live.inner.cell.read().1;
            let mut delta = snap.delta.clone();
            for rec in &records {
                live.check_record_dims(rec)?;
                live.apply_record(&mut delta, &snap, rec);
            }
            live.inner
                .cell
                .publish(Arc::new(LiveSnapshot { base: Arc::clone(&snap.base), delta }));
        }
        sweep_other_generations(dir, generation);
        Ok(live)
    }
}

impl<S: Storage> LiveIndex<S> {
    // -- accessors ---------------------------------------------------------

    /// Item dimensionality.
    pub fn dim(&self) -> usize {
        self.inner.dim
    }

    /// ALSH parameters shared by every generation.
    pub fn params(&self) -> &AlshParams {
        &self.inner.params
    }

    /// The hash scheme.
    pub fn scheme(&self) -> super::scheme::MipsHashScheme {
        self.inner.params.scheme
    }

    /// Norm bands per generation (1 = flat layout).
    pub fn n_bands(&self) -> usize {
        self.inner.n_bands
    }

    /// The seed every generation builds with.
    pub fn seed(&self) -> u64 {
        self.inner.seed
    }

    /// The live directory.
    pub fn dir(&self) -> &Path {
        &self.inner.dir
    }

    /// The hash families (seed-determined, stable across generations).
    pub fn scheme_families(&self) -> &SchemeFamilies {
        &self.inner.families
    }

    /// The fused multi-table hasher (batcher fallback, code-fed paths).
    pub fn hasher(&self) -> &SchemeHasher {
        &self.inner.fused
    }

    /// Current logical item count (base − tombstones + live delta rows).
    pub fn n_items(&self) -> usize {
        self.inner.cell.read().1.n_items()
    }

    /// Current base generation number.
    pub fn generation(&self) -> u64 {
        self.inner.cell.read().1.base.gen
    }

    /// Point-in-time counters (the `coordinator::metrics` feed).
    pub fn stats(&self) -> LiveStats {
        let snap = self.inner.cell.read().1;
        let wal_bytes = self.inner.wal_bytes.load(Ordering::Relaxed);
        let d = &snap.delta;
        LiveStats {
            delta_items: d.n_alive as u64,
            tombstones: (d.n_base_dead + (d.entries.len() - d.n_alive)) as u64,
            compactions: self.inner.compactions.load(Ordering::Relaxed),
            wal_bytes,
            last_compaction_ms: self.inner.last_compaction_ms.load(Ordering::Relaxed),
            generation: snap.base.gen,
            n_items: snap.n_items() as u64,
            high_water: self.inner.high_water.load(Ordering::Relaxed),
        }
    }

    /// Highest durable WAL sequence number (0 before the first write).
    pub fn high_water(&self) -> u64 {
        self.inner.high_water.load(Ordering::Relaxed)
    }

    /// The current generation's WAL file path — what a lagging peer
    /// reads its catch-up suffix from ([`Wal::read_suffix`]).
    pub fn current_wal_path(&self) -> PathBuf {
        wal_path(&self.inner.dir, self.generation())
    }

    /// Adjust the write-backpressure bound at runtime (reopen paths
    /// re-apply their configured cap; the value is not persisted).
    pub fn set_delta_cap(&self, cap: usize) {
        self.inner.delta_cap.store(cap.max(1), Ordering::Relaxed);
    }

    /// The current write-backpressure bound.
    pub fn delta_cap(&self) -> usize {
        self.inner.delta_cap.load(Ordering::Relaxed)
    }

    /// Would a mutation be refused right now? Returns the structured
    /// stall the mutation would fail with. The replicated fan-out
    /// checks this on every member **before** assigning a sequence
    /// number, so group-level backpressure never diverges members.
    pub fn would_stall(&self) -> Option<WriteStalled> {
        let snap = self.inner.cell.read().1;
        self.stall_of(&snap.delta)
    }

    fn stall_of(&self, delta: &DeltaState) -> Option<WriteStalled> {
        let pending = delta.entries.len() + delta.n_base_dead;
        let cap = self.inner.delta_cap.load(Ordering::Relaxed);
        if pending < cap {
            return None;
        }
        // Best local estimate of the drain time: the last compaction's
        // wall clock, clamped to a sane client retry window.
        let retry_after_ms = self
            .inner
            .last_compaction_ms
            .load(Ordering::Relaxed)
            .clamp(10, 1000);
        Some(WriteStalled { pending, cap, retry_after_ms })
    }

    /// The live logical item set `(external id, vector)`, ascending by
    /// external id — the input a from-scratch rebuild (compaction, or a
    /// peer rebuilding a diverged member) would consume.
    pub fn live_items(&self) -> Vec<(u32, Vec<f32>)> {
        let snap = self.inner.cell.read().1;
        Self::collect_live(&snap, self.inner.dim)
    }

    fn collect_live(snap: &LiveSnapshot<S>, dim: usize) -> Vec<(u32, Vec<f32>)> {
        let n_base = snap.n_base();
        let mut live: Vec<(u32, Vec<f32>)> =
            Vec::with_capacity(n_base - snap.delta.n_base_dead + snap.delta.n_alive);
        let base_flat = match &snap.base.index {
            AnyIndex::Flat(i) => i.items_flat(),
            AnyIndex::Banded(i) => i.items_flat(),
        };
        for internal in 0..n_base as u32 {
            if !snap.delta.base_is_dead(internal) {
                let row = &base_flat[internal as usize * dim..(internal as usize + 1) * dim];
                live.push((snap.base.ids[internal as usize], row.to_vec()));
            }
        }
        for (row, e) in snap.delta.entries.iter().enumerate() {
            if e.alive {
                live.push((e.ext_id, snap.delta.vectors[row * dim..(row + 1) * dim].to_vec()));
            }
        }
        live.sort_unstable_by_key(|(ext, _)| *ext);
        live
    }

    /// Order- and layout-independent checksum of the live logical item
    /// set: XXH64 chained over `(external id, vector bytes)` ascending
    /// by id. Deliberately independent of the hash seed, so replica
    /// members built with **different** seeds agree exactly when they
    /// applied the same mutation history — the divergence detector the
    /// scrub exchange compares.
    pub fn state_checksum(&self) -> u64 {
        let mut sum = 0xA15B_57A7u64;
        let mut buf = Vec::new();
        for (ext_id, vector) in self.live_items() {
            buf.clear();
            buf.extend_from_slice(&ext_id.to_le_bytes());
            for v in &vector {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            sum = xxh64(&buf, sum);
        }
        sum
    }

    /// A scratch pre-sized for this index (stamps cover base + a delta
    /// allowance; buffers grow as the delta does).
    pub fn scratch(&self) -> QueryScratch {
        let snap = self.inner.cell.read().1;
        let mut s = QueryScratch::new();
        s.reserve(
            snap.n_base() + snap.delta.entries.len(),
            self.inner.fused.n_codes(),
            self.inner.dim + self.inner.params.scheme.append_len(self.inner.params.m),
        );
        s
    }

    /// Install the compactor fault plan (tests only; defaults all-off).
    pub fn set_compactor_faults(&self, plan: CompactorFaultPlan) {
        *lock(&self.inner.faults) = plan;
    }

    // -- snapshot plumbing -------------------------------------------------

    /// The caller-cached snapshot read (see module docs): one atomic
    /// load while the generation is unchanged, one brief lock to
    /// re-clone when it moved.
    fn snapshot(&self, s: &mut QueryScratch) -> Arc<LiveSnapshot<S>> {
        let current = self.inner.cell.generation.load(Ordering::Acquire);
        if let Some((cell, generation, cached)) = &s.snap.0 {
            if *cell == self.inner.cell.id && *generation == current {
                if let Ok(snap) = Arc::clone(cached).downcast::<LiveSnapshot<S>>() {
                    return snap;
                }
            }
        }
        let (generation, snap) = self.inner.cell.read();
        s.snap.0 = Some((
            self.inner.cell.id,
            generation,
            Arc::clone(&snap) as Arc<dyn std::any::Any + Send + Sync>,
        ));
        snap
    }

    // -- mutation ----------------------------------------------------------

    /// Validate a record's vector dimensions against this index (before
    /// anything is logged or applied).
    fn check_record_dims(&self, rec: &WalRecord) -> Result<()> {
        let dim = self.inner.dim;
        match rec {
            WalRecord::Upsert { ext_id, vector } => ensure!(
                vector.len() == dim,
                "live index: upsert dim {} != index dim {dim} (ext id {ext_id})",
                vector.len()
            ),
            WalRecord::Delete { .. } => {}
            WalRecord::Batch { items } => {
                for (ext_id, vector) in items {
                    ensure!(
                        vector.len() == dim,
                        "live index: upsert dim {} != index dim {dim} (ext id {ext_id})",
                        vector.len()
                    );
                }
            }
        }
        Ok(())
    }

    /// Apply one (already validated, already durable) record to a delta
    /// clone. A batch applies in order, later entries superseding
    /// earlier ones for a duplicated id — matching sequential-upsert
    /// semantics.
    fn apply_record(&self, delta: &mut DeltaState, snap: &LiveSnapshot<S>, rec: &WalRecord) {
        match rec {
            WalRecord::Upsert { ext_id, vector } => self.apply_upsert(delta, snap, *ext_id, vector),
            WalRecord::Delete { ext_id } => self.apply_delete(delta, snap, *ext_id),
            WalRecord::Batch { items } => {
                for (ext_id, vector) in items {
                    self.apply_upsert(delta, snap, *ext_id, vector);
                }
            }
        }
    }

    /// The one mutation path: validate, (optionally) enforce the delta
    /// cap, WAL-append — at an explicit group sequence number when
    /// `at_seq` is given (the replicated fan-out), at the next local
    /// one otherwise — then apply to a delta clone and publish one
    /// snapshot swap. Returns the durable record's sequence number.
    fn log_and_apply(&self, at_seq: Option<u64>, rec: &WalRecord, enforce_cap: bool) -> Result<u64> {
        self.check_record_dims(rec)?;
        let mut w = lock(&self.inner.writer);
        ensure!(!w.crashed, "live index: instance crashed (injected); re-open the directory");
        let snap = self.inner.cell.read().1;
        if enforce_cap {
            if let Some(stall) = self.stall_of(&snap.delta) {
                return Err(anyhow::Error::new(stall));
            }
        }
        if let Some(seq) = at_seq {
            let expected = w.wal.next_seq();
            if seq != expected {
                return Err(anyhow::Error::new(SeqGap { expected, got: seq }));
            }
        }
        let assigned = w.wal.append(rec)?;
        self.inner.wal_bytes.store(w.wal.bytes(), Ordering::Relaxed);
        self.inner.high_water.store(w.wal.high_water(), Ordering::Relaxed);
        let mut delta = snap.delta.clone();
        self.apply_record(&mut delta, &snap, rec);
        self.inner
            .cell
            .publish(Arc::new(LiveSnapshot { base: Arc::clone(&snap.base), delta }));
        Ok(assigned)
    }

    /// Insert or replace the vector for `ext_id`: WAL-logged (durable
    /// before applied), then published to readers via snapshot swap.
    /// Returns the record's sequence number.
    pub fn upsert(&self, ext_id: u32, vector: &[f32]) -> Result<u64> {
        self.log_and_apply(None, &WalRecord::Upsert { ext_id, vector: vector.to_vec() }, true)
    }

    /// Group-commit bulk upsert: the whole batch is **one** WAL record
    /// with one checksum and one fsync ([`WalRecord::Batch`]), applied
    /// to one delta clone and published as one snapshot swap. Readers
    /// see the batch atomically, and so does recovery: a crash
    /// mid-append fails the single record checksum, so replay surfaces
    /// the whole batch or none of it — never a partial batch. Later
    /// entries supersede earlier ones for a duplicated id, matching
    /// sequential-upsert semantics. Nothing is logged or applied if any
    /// entry's dimension is wrong. Returns the batch record's sequence
    /// number (the batch consumes exactly one).
    pub fn upsert_batch(&self, entries: &[(u32, Vec<f32>)]) -> Result<u64> {
        if entries.is_empty() {
            return Ok(self.high_water());
        }
        self.log_and_apply(None, &WalRecord::Batch { items: entries.to_vec() }, true)
    }

    /// Delete `ext_id` (a no-op if absent). WAL-logged like upsert.
    /// Returns the record's sequence number.
    pub fn delete(&self, ext_id: u32) -> Result<u64> {
        self.log_and_apply(None, &WalRecord::Delete { ext_id }, true)
    }

    /// Replicated-fan-out twin of [`Self::upsert`]: the record must
    /// land at exactly group sequence `seq` (see [`SeqGap`]).
    pub fn upsert_at(&self, seq: u64, ext_id: u32, vector: &[f32]) -> Result<u64> {
        self.log_and_apply(Some(seq), &WalRecord::Upsert { ext_id, vector: vector.to_vec() }, true)
    }

    /// Replicated-fan-out twin of [`Self::upsert_batch`].
    pub fn upsert_batch_at(&self, seq: u64, entries: &[(u32, Vec<f32>)]) -> Result<u64> {
        self.log_and_apply(Some(seq), &WalRecord::Batch { items: entries.to_vec() }, true)
    }

    /// Replicated-fan-out twin of [`Self::delete`].
    pub fn delete_at(&self, seq: u64, ext_id: u32) -> Result<u64> {
        self.log_and_apply(Some(seq), &WalRecord::Delete { ext_id }, true)
    }

    /// Catch-up replay: apply a peer's WAL suffix (from
    /// [`Wal::read_suffix`]). Records at or below this member's
    /// high-water are skipped (idempotent); the rest must be contiguous
    /// from `high_water + 1`. The delta cap is **not** enforced —
    /// refusing catch-up work would leave the member permanently
    /// lagging; compaction drains the backlog afterwards. Returns how
    /// many records were applied.
    pub fn apply_suffix(&self, records: &[(u64, WalRecord)]) -> Result<usize> {
        let mut applied = 0;
        for (seq, rec) in records {
            if *seq <= self.high_water() {
                continue;
            }
            self.log_and_apply(Some(*seq), rec, false)?;
            applied += 1;
        }
        Ok(applied)
    }

    /// Write the first `keep` bytes of an upsert record and mark the
    /// instance crashed — the fault-injection twin of [`Self::upsert`]
    /// for mid-WAL torn-write tests (the mutation is *not* applied;
    /// recovery decides whether the record survived).
    pub fn inject_torn_upsert(&self, ext_id: u32, vector: &[f32], keep: usize) -> Result<()> {
        self.inject_torn(&WalRecord::Upsert { ext_id, vector: vector.to_vec() }, keep)
    }

    /// Torn-write injection for a whole batch record — the crash
    /// harness for the all-or-nothing batch contract: any `keep`
    /// strictly inside the record must recover to a state with **none**
    /// of the batch visible.
    pub fn inject_torn_batch(&self, entries: &[(u32, Vec<f32>)], keep: usize) -> Result<()> {
        self.inject_torn(&WalRecord::Batch { items: entries.to_vec() }, keep)
    }

    fn inject_torn(&self, rec: &WalRecord, keep: usize) -> Result<()> {
        let mut w = lock(&self.inner.writer);
        ensure!(!w.crashed, "live index: instance crashed (injected); re-open the directory");
        w.wal.append_torn(rec, keep)?;
        self.inner.wal_bytes.store(w.wal.bytes(), Ordering::Relaxed);
        w.crashed = true;
        Ok(())
    }

    /// Band a vector lands in over the snapshot's frozen banding, plus
    /// the scale factor to hash it with (see module docs on norm-band
    /// migration).
    fn assign_band(&self, snap: &LiveSnapshot<S>, vector: &[f32]) -> (u32, f32) {
        match &snap.base.index {
            AnyIndex::Flat(i) => (0, i.scale().factor),
            AnyIndex::Banded(i) => {
                let norm = vector.iter().map(|x| x * x).sum::<f32>().sqrt();
                let bands = i.bands();
                let last = bands.len() - 1;
                let b = bands
                    .iter()
                    .position(|band| norm <= band.norm_range().1)
                    .unwrap_or(last);
                (b as u32, bands[b].scale().factor)
            }
        }
    }

    fn apply_upsert(
        &self,
        delta: &mut DeltaState,
        snap: &LiveSnapshot<S>,
        ext_id: u32,
        vector: &[f32],
    ) {
        // Supersede any earlier version of this id.
        if let Some(&row) = delta.ext_to_row.get(&ext_id) {
            delta.entries[row as usize].alive = false;
            delta.n_alive -= 1;
        } else if snap.base.ids.binary_search(&ext_id).is_ok() {
            let internal = snap.base.ids.binary_search(&ext_id).unwrap() as u32;
            delta.kill_base(internal, snap.n_base());
        }
        // Hash the new row exactly as the frozen build would: scheme
        // data transform at the assigned band's scale, fused codes, one
        // bucket key per table.
        let (band, factor) = self.assign_band(snap, vector);
        let p = &self.inner.params;
        let dp = self.inner.dim + p.scheme.append_len(p.m);
        let mut data_row = vec![0.0f32; dp];
        p.scheme.data_row_into(vector, factor, p.m, &mut data_row);
        let mut codes = vec![0i32; self.inner.fused.n_codes()];
        self.inner.fused.hash_into(&data_row, &mut codes);
        let row = delta.entries.len() as u32;
        for t in 0..p.n_tables {
            let key = p.scheme.table_key(&codes[t * p.k_per_table..(t + 1) * p.k_per_table]);
            let run = &mut delta.runs[t];
            let at = run.partition_point(|&(k, r)| (k, r) < (key, row));
            run.insert(at, (key, row));
        }
        delta.entries.push(DeltaEntry { ext_id, band, alive: true });
        delta.vectors.extend_from_slice(vector);
        delta.ext_to_row.insert(ext_id, row);
        delta.n_alive += 1;
    }

    fn apply_delete(&self, delta: &mut DeltaState, snap: &LiveSnapshot<S>, ext_id: u32) {
        if let Some(row) = delta.ext_to_row.remove(&ext_id) {
            delta.entries[row as usize].alive = false;
            delta.n_alive -= 1;
        }
        if let Ok(internal) = snap.base.ids.binary_search(&ext_id) {
            delta.kill_base(internal as u32, snap.n_base());
        }
    }

}

// -- compaction ------------------------------------------------------------

impl<S: LiveStorage> LiveIndex<S> {
    /// Drain the delta into a fresh frozen generation and swap it in
    /// (see module docs for the protocol and crash windows). Returns
    /// the new generation number. Errors if the live set is empty —
    /// the frozen layouts don't represent an empty index.
    pub fn compact_once(&self) -> Result<u64> {
        let start = std::time::Instant::now();
        let mut w = lock(&self.inner.writer);
        ensure!(!w.crashed, "live index: instance crashed (injected); re-open the directory");
        let faults = *lock(&self.inner.faults);
        if faults.poison {
            panic!("injected compactor poison");
        }
        let snap = self.inner.cell.read().1;
        // Collect the live rows sorted by external id — identical input
        // to a from-scratch build over the logical item set.
        let live = Self::collect_live(&snap, self.inner.dim);
        ensure!(!live.is_empty(), "live index: refusing to compact to an empty index");
        let (ids, items): (Vec<u32>, Vec<Vec<f32>>) = live.into_iter().unzip();

        let next = w.gen + 1;
        let built = build_base(&items, self.inner.params, self.inner.n_bands, self.inner.seed);
        built.save_as(gen_index_path(&self.inner.dir, next), PersistFormat::V5)?;
        write_ids(&gen_ids_path(&self.inner.dir, next), &ids)?;
        if faults.crash_before_manifest {
            w.crashed = true;
            bail!("injected compactor crash before MANIFEST publish");
        }
        // The fresh WAL continues the drained log's numbering, so
        // sequence numbers — and replica high-water comparisons — are
        // stable across compactions.
        let wal = Wal::create(wal_path(&self.inner.dir, next), w.wal.next_seq())?;
        write_manifest(&self.inner.dir, next, self.inner.seed)?; // commit point
        if faults.crash_after_manifest {
            w.crashed = true;
            bail!("injected compactor crash after MANIFEST publish");
        }
        let index = S::open_base(&gen_index_path(&self.inner.dir, next))?;
        let base = Arc::new(BaseGen { index, ids, gen: next });
        self.inner.cell.publish(Arc::new(LiveSnapshot {
            base,
            delta: DeltaState::empty(self.inner.params.n_tables),
        }));
        self.inner.wal_bytes.store(wal.bytes(), Ordering::Relaxed);
        w.wal = wal;
        w.gen = next;
        drop(w);
        sweep_other_generations(&self.inner.dir, next);
        self.inner.compactions.fetch_add(1, Ordering::Relaxed);
        self.inner
            .last_compaction_ms
            .store(start.elapsed().as_millis() as u64, Ordering::Relaxed);
        Ok(next)
    }

    /// Spawn the background compactor: polls every `poll` and compacts
    /// whenever the delta (live + dead rows) reaches `threshold`. The
    /// thread holds only a weak handle, so dropping the last
    /// [`LiveIndex`] clone ends it; [`Self::stop_compactor`] ends it
    /// deterministically. Panics inside a compaction (e.g. the injected
    /// poison) are contained to the thread — serving continues.
    pub fn spawn_compactor(&self, threshold: usize, poll: std::time::Duration) {
        self.spawn_compactor_when(poll, move |s: &LiveStats| {
            (s.delta_items + s.tombstones) as usize >= threshold
        });
    }

    /// Spawn the background compactor with a caller-supplied trigger
    /// policy: every `poll`, `decide` sees the current [`LiveStats`]
    /// and returns whether to compact now. This is the hook the
    /// coordinator uses for size-tiered scheduling rate-limited against
    /// reader tail latency — the index layer deliberately knows nothing
    /// about serving metrics. Thread lifetime and panic containment
    /// match [`Self::spawn_compactor`].
    pub fn spawn_compactor_when<F>(&self, poll: std::time::Duration, decide: F)
    where
        F: Fn(&LiveStats) -> bool + Send + 'static,
    {
        let weak: Weak<LiveInner<S>> = Arc::downgrade(&self.inner);
        let handle = std::thread::spawn(move || loop {
            let Some(inner) = weak.upgrade() else { return };
            if inner.stop.load(Ordering::Relaxed) {
                return;
            }
            let live = LiveIndex { inner: Arc::clone(&inner) };
            if decide(&live.stats()) {
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    live.compact_once().ok();
                }));
            }
            drop(live);
            drop(inner);
            std::thread::sleep(poll);
        });
        *lock(&self.inner.compactor) = Some(handle);
    }
}

impl<S: Storage> LiveIndex<S> {
    /// Stop and join the background compactor, if one is running.
    pub fn stop_compactor(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        if let Some(handle) = lock(&self.inner.compactor).take() {
            handle.join().ok();
        }
    }

    // -- queries -----------------------------------------------------------

    /// Full allocation-free query: base + delta probe, tombstone
    /// filter, dual-source exact rerank, external-id translation.
    pub fn query_into<'s>(
        &self,
        query: &[f32],
        top_k: usize,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        self.query_budgeted_into(query, top_k, ProbeBudget::full(), s)
    }

    /// Budgeted query (bit-identical to [`Self::query_into`] at
    /// [`ProbeBudget::full`], like the frozen paths).
    pub fn query_budgeted_into<'s>(
        &self,
        query: &[f32],
        top_k: usize,
        budget: ProbeBudget,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        let snap = self.snapshot(s);
        snap.base.index.candidates_budgeted_into(query, budget, s);
        self.overlay(&snap, budget, None, s);
        self.finish(&snap, query, top_k, s)
    }

    /// Multi-probe query (`n_probes` buckets per table in both layers).
    pub fn query_multiprobe_into<'s>(
        &self,
        query: &[f32],
        top_k: usize,
        n_probes: usize,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        self.query_budgeted_into(query, top_k, ProbeBudget::with_probes(n_probes), s)
    }

    /// Code-fed query (the batcher/PJRT re-entry): externally computed
    /// `[L·K]` codes probe both layers; `query` is still needed for the
    /// exact rerank.
    pub fn query_from_codes_into<'s>(
        &self,
        codes_flat: &[i32],
        query: &[f32],
        top_k: usize,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        self.query_from_codes_budgeted_into(codes_flat, query, top_k, ProbeBudget::full(), s)
    }

    /// Budgeted code-fed query (single probe per table, like the frozen
    /// code-fed paths — external codes carry no perturbation info).
    pub fn query_from_codes_budgeted_into<'s>(
        &self,
        codes_flat: &[i32],
        query: &[f32],
        top_k: usize,
        budget: ProbeBudget,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        let snap = self.snapshot(s);
        snap.base.index.candidates_from_codes_budgeted_into(codes_flat, budget, s);
        self.overlay(&snap, budget, Some(codes_flat), s);
        self.finish(&snap, query, top_k, s)
    }

    /// Batch query: the per-query path in a loop (per-query results are
    /// bit-identical to [`Self::query_into`], mirroring the frozen
    /// batch contract).
    pub fn query_batch_into(
        &self,
        queries: &[Vec<f32>],
        top_k: usize,
        s: &mut QueryScratch,
        out: &mut Vec<Vec<ScoredItem>>,
    ) {
        out.clear();
        for q in queries {
            out.push(self.query_into(q, top_k, s).to_vec());
        }
    }

    /// Allocating convenience query.
    pub fn query(&self, query: &[f32], top_k: usize) -> Vec<ScoredItem> {
        super::scratch::with_thread_scratch(|s| self.query_into(query, top_k, s).to_vec())
    }

    /// Replay the scratch (or external) codes against the delta runs,
    /// continuing the base probe's dedup epoch, after filtering
    /// tombstoned base candidates. Base candidates keep priority under
    /// a partial rerank cap, matching the frozen budget semantics.
    fn overlay(
        &self,
        snap: &LiveSnapshot<S>,
        budget: ProbeBudget,
        ext_codes: Option<&[i32]>,
        s: &mut QueryScratch,
    ) {
        let delta = &snap.delta;
        if delta.n_base_dead > 0 {
            s.cands.retain(|&id| !delta.base_is_dead(id));
        }
        if delta.entries.is_empty() {
            return;
        }
        let n_base = snap.n_base();
        let p = &self.inner.params;
        let k = p.k_per_table;
        let nt = budget.tables(p.n_tables);
        let cap = budget.max_rerank;
        let nb = self.inner.n_bands.max(1);
        let b_used = budget.bands(nb);
        // Budgeted banded probes keep the largest-norm bands; delta rows
        // in skipped bands are skipped too.
        let band_min = (nb - b_used) as u32;
        {
            let (mut sink, codes, fracs, perturbs) = s.resume_dedup(n_base + delta.entries.len());
            for t in 0..nt {
                if sink.len() >= cap {
                    break;
                }
                let lo = t * k;
                match ext_codes {
                    Some(c) => {
                        delta.probe_run(t, p.scheme.table_key(&c[lo..lo + k]), band_min, n_base, &mut sink);
                    }
                    None if budget.n_probes == 1 => {
                        delta.probe_run(t, p.scheme.table_key(&codes[lo..lo + k]), band_min, n_base, &mut sink);
                    }
                    None => {
                        for_each_probe_key(
                            p.scheme,
                            &mut codes[lo..lo + k],
                            &fracs[lo..lo + k],
                            perturbs,
                            budget.n_probes,
                            |key| delta.probe_run(t, key, band_min, n_base, &mut sink),
                        );
                    }
                }
            }
        }
        s.truncate_candidates(cap);
    }

    /// Dual-source exact rerank of `s.cands`, then translate internal
    /// ids back to external ids in place.
    fn finish<'s>(
        &self,
        snap: &LiveSnapshot<S>,
        query: &[f32],
        top_k: usize,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        let n_base = snap.n_base();
        let base_flat = match &snap.base.index {
            AnyIndex::Flat(i) => i.items_flat(),
            AnyIndex::Banded(i) => i.items_flat(),
        };
        rerank_dual_into(base_flat, n_base, &snap.delta.vectors, self.inner.dim, query, top_k, s);
        for item in &mut s.top {
            item.id = if (item.id as usize) < n_base {
                snap.base.ids[item.id as usize]
            } else {
                snap.delta.entries[item.id as usize - n_base].ext_id
            };
        }
        &s.top
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::MipsHashScheme;
    use crate::util::Rng;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "alsh_delta_{tag}_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn items(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.normal_f32() * 1.5).collect())
            .collect()
    }

    fn cfg(n_bands: usize) -> LiveConfig {
        LiveConfig {
            params: AlshParams {
                n_tables: 8,
                k_per_table: 4,
                scheme: MipsHashScheme::SignAlsh,
                ..AlshParams::default()
            },
            n_bands,
            seed: 42,
            ..LiveConfig::default()
        }
    }

    /// Empty delta ⇒ byte-identical to the frozen base across paths.
    #[test]
    fn fresh_live_matches_frozen_base() {
        for n_bands in [1usize, 3] {
            let dir = tmp_dir("fresh");
            let data = items(200, 12, 7);
            let c = cfg(n_bands);
            let live: LiveIndex = LiveIndex::create(&dir, &data, c).unwrap();
            let frozen = build_base(&data, c.params, c.n_bands, c.seed);
            let mut s1 = live.scratch();
            let mut s2 = frozen.scratch();
            let queries = items(20, 12, 99);
            for q in &queries {
                let a = live.query_into(q, 10, &mut s1).to_vec();
                let b = frozen.query_into(q, 10, &mut s2).to_vec();
                assert_eq!(a, b, "n_bands={n_bands}");
                let a = live.query_multiprobe_into(q, 10, 4, &mut s1).to_vec();
                let b = frozen.query_multiprobe_into(q, 10, 4, &mut s2).to_vec();
                assert_eq!(a, b, "multiprobe n_bands={n_bands}");
            }
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    /// Data-side codes of `vector` under the live flat base's scale —
    /// feeding these to the code-fed path probes exactly the buckets the
    /// vector occupies, making retrieval deterministic (no LSH luck).
    fn data_codes(live: &LiveIndex, vector: &[f32]) -> Vec<i32> {
        let snap = live.inner.cell.read().1;
        let factor = match &snap.base.index {
            AnyIndex::Flat(i) => i.scale().factor,
            AnyIndex::Banded(_) => unreachable!("flat-only helper"),
        };
        let p = live.params();
        let mut row = vec![0.0f32; live.dim() + p.scheme.append_len(p.m)];
        p.scheme.data_row_into(vector, factor, p.m, &mut row);
        let mut codes = vec![0i32; live.hasher().n_codes()];
        live.hasher().hash_into(&row, &mut codes);
        codes
    }

    /// Upserts and deletes surface/retire items, deterministically:
    /// probing with an item's own data-side codes guarantees its buckets
    /// are hit, so presence/absence is exact, not probabilistic.
    #[test]
    fn mutations_visible_and_exact() {
        let dir = tmp_dir("mut");
        let data = items(100, 8, 3);
        let live: LiveIndex = LiveIndex::create(&dir, &data, cfg(1)).unwrap();
        let mut s = live.scratch();
        let q = &data[7];
        let codes7 = data_codes(&live, &data[7]);
        let has = |r: &[ScoredItem], id: u32| r.iter().find(|it| it.id == id).map(|it| it.score);
        // Base item 7 always answers a probe of its own buckets.
        let r = live.query_from_codes_into(&codes7, q, 100, &mut s).to_vec();
        let base_score = has(&r, 7).expect("own-bucket probe must find item 7");
        // A delta twin (same vector, same flat scale) lands in the same
        // buckets with the same score.
        live.upsert(500, &data[7]).unwrap();
        let r = live.query_from_codes_into(&codes7, q, 100, &mut s).to_vec();
        assert_eq!(has(&r, 7), Some(base_score));
        assert_eq!(has(&r, 500), Some(base_score));
        // Tombstoning the base twin leaves only the delta twin.
        live.delete(7).unwrap();
        let r = live.query_from_codes_into(&codes7, q, 100, &mut s).to_vec();
        assert_eq!(has(&r, 7), None);
        assert_eq!(has(&r, 500), Some(base_score));
        // Re-upserting 500 supersedes the old row: probing the *new*
        // vector's buckets yields the new score, and only one delta row
        // is alive.
        let double: Vec<f32> = data[7].iter().map(|x| x * 2.0).collect();
        live.upsert(500, &double).unwrap();
        let codes_new = data_codes(&live, &double);
        let r = live.query_from_codes_into(&codes_new, q, 100, &mut s).to_vec();
        assert_eq!(has(&r, 500), Some(base_score * 2.0));
        assert_eq!(live.stats().delta_items, 1);
        // Deleting the delta row removes it from its buckets.
        live.delete(500).unwrap();
        let r = live.query_from_codes_into(&codes_new, q, 100, &mut s).to_vec();
        assert_eq!(has(&r, 500), None);
        assert_eq!(live.n_items(), data.len() - 1);
        assert_eq!(live.stats().delta_items, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Compaction swaps in a generation byte-identical to a fresh build
    /// over the surviving logical set.
    #[test]
    fn compaction_matches_fresh_build() {
        let dir = tmp_dir("compact");
        let data = items(150, 10, 11);
        let c = cfg(3);
        let live: LiveIndex = LiveIndex::create(&dir, &data, c).unwrap();
        let extra = items(30, 10, 77);
        for (i, v) in extra.iter().enumerate() {
            live.upsert(1000 + i as u32, v).unwrap();
        }
        for id in [3u32, 60, 149] {
            live.delete(id).unwrap();
        }
        let generation = live.compact_once().unwrap();
        assert_eq!(generation, 1);
        assert_eq!(live.stats().delta_items, 0);
        // The logical set, ext-id ascending.
        let mut logical: Vec<(u32, Vec<f32>)> = (0..data.len() as u32)
            .filter(|id| ![3u32, 60, 149].contains(id))
            .map(|id| (id, data[id as usize].clone()))
            .collect();
        logical.extend(extra.iter().enumerate().map(|(i, v)| (1000 + i as u32, v.clone())));
        let (ids, vecs): (Vec<u32>, Vec<Vec<f32>>) = logical.into_iter().unzip();
        let fresh = build_base(&vecs, c.params, c.n_bands, c.seed);
        let mut s1 = live.scratch();
        let mut s2 = fresh.scratch();
        for q in &items(15, 10, 5) {
            let a = live.query_into(q, 12, &mut s1).to_vec();
            let b: Vec<ScoredItem> = fresh
                .query_into(q, 12, &mut s2)
                .iter()
                .map(|it| ScoredItem { id: ids[it.id as usize], score: it.score })
                .collect();
            assert_eq!(a, b);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Recovery replays the WAL to a state byte-equal to a live twin.
    #[test]
    fn reopen_replays_wal() {
        let dir = tmp_dir("reopen");
        let data = items(80, 6, 2);
        let live: LiveIndex = LiveIndex::create(&dir, &data, cfg(1)).unwrap();
        let extra = items(10, 6, 8);
        for (i, v) in extra.iter().enumerate() {
            live.upsert(200 + i as u32, v).unwrap();
        }
        live.delete(5).unwrap();
        let mut s = live.scratch();
        let q = &items(1, 6, 55)[0];
        let before = live.query_into(q, 10, &mut s).to_vec();
        drop(live);
        let reopened: LiveIndex = LiveIndex::open(&dir).unwrap();
        let mut s2 = reopened.scratch();
        assert_eq!(reopened.query_into(q, 10, &mut s2).to_vec(), before);
        assert_eq!(reopened.stats().delta_items, 10);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Mapped storage serves the same bytes as owned.
    #[test]
    fn mapped_live_matches_owned() {
        let dir_a = tmp_dir("mapped_a");
        let dir_b = tmp_dir("mapped_b");
        let data = items(90, 7, 13);
        let owned: LiveIndex = LiveIndex::create(&dir_a, &data, cfg(2)).unwrap();
        let mapped: LiveIndex<Mapped> = LiveIndex::create(&dir_b, &data, cfg(2)).unwrap();
        let extra = items(5, 7, 21)[0].clone();
        owned.upsert(300, &extra).unwrap();
        mapped.upsert(300, &extra).unwrap();
        let mut s1 = owned.scratch();
        let mut s2 = mapped.scratch();
        for q in &items(10, 7, 31) {
            assert_eq!(
                owned.query_into(q, 8, &mut s1).to_vec(),
                mapped.query_into(q, 8, &mut s2).to_vec()
            );
        }
        std::fs::remove_dir_all(&dir_a).ok();
        std::fs::remove_dir_all(&dir_b).ok();
    }

    /// The delta cap refuses mutations with a structured stall before
    /// any WAL append or sequence assignment; compaction clears it.
    #[test]
    fn delta_cap_stalls_and_compaction_clears() {
        let dir = tmp_dir("cap");
        let data = items(50, 6, 9);
        let c = LiveConfig { delta_cap: 3, ..cfg(1) };
        let live: LiveIndex = LiveIndex::create(&dir, &data, c).unwrap();
        let extra = items(4, 6, 17);
        for (i, v) in extra.iter().take(3).enumerate() {
            live.upsert(100 + i as u32, v).unwrap();
        }
        let hw = live.high_water();
        let err = live.upsert(103, &extra[3]).unwrap_err();
        let stall = err.downcast_ref::<WriteStalled>().expect("typed stall");
        assert_eq!(stall.pending, 3);
        assert_eq!(stall.cap, 3);
        assert!(stall.retry_after_ms >= 10);
        assert_eq!(live.high_water(), hw, "stalled write consumed a seq");
        assert!(live.would_stall().is_some());
        live.compact_once().unwrap();
        assert!(live.would_stall().is_none());
        live.upsert(103, &extra[3]).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Explicit-sequence mutations enforce contiguity with a typed gap
    /// error, and high-water numbering survives compaction.
    #[test]
    fn explicit_seq_contiguity_and_compaction_numbering() {
        let dir = tmp_dir("seq");
        let data = items(40, 5, 4);
        let live: LiveIndex = LiveIndex::create(&dir, &data, cfg(1)).unwrap();
        assert_eq!(live.high_water(), 0);
        let v = &items(1, 5, 6)[0];
        assert_eq!(live.upsert_at(1, 200, v).unwrap(), 1);
        let err = live.upsert_at(3, 201, v).unwrap_err();
        let gap = err.downcast_ref::<SeqGap>().expect("typed gap");
        assert_eq!((gap.expected, gap.got), (2, 3));
        assert_eq!(live.delete_at(2, 200).unwrap(), 2);
        live.compact_once().unwrap();
        assert_eq!(live.high_water(), 2, "numbering reset by compaction");
        assert_eq!(live.upsert_at(3, 202, v).unwrap(), 3);
        drop(live);
        let reopened: LiveIndex = LiveIndex::open(&dir).unwrap();
        assert_eq!(reopened.high_water(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The state checksum is seed-independent: members with different
    /// hash seeds that applied the same history agree; a divergent one
    /// does not. Catch-up via a WAL suffix restores agreement.
    #[test]
    fn state_checksum_and_suffix_catch_up() {
        let dir_a = tmp_dir("ck_a");
        let dir_b = tmp_dir("ck_b");
        let data = items(60, 6, 12);
        let ca = cfg(1);
        let cb = LiveConfig { seed: 777, ..cfg(1) };
        let a: LiveIndex = LiveIndex::create(&dir_a, &data, ca).unwrap();
        let b: LiveIndex = LiveIndex::create(&dir_b, &data, cb).unwrap();
        assert_eq!(a.state_checksum(), b.state_checksum());
        let extra = items(3, 6, 44);
        for (i, v) in extra.iter().enumerate() {
            a.upsert(300 + i as u32, v).unwrap();
        }
        a.delete(5).unwrap();
        assert_ne!(a.state_checksum(), b.state_checksum());
        // b catches up from a's on-disk WAL suffix.
        let suffix = Wal::read_suffix(a.current_wal_path(), b.high_water() + 1)
            .unwrap()
            .expect("suffix available");
        assert_eq!(b.apply_suffix(&suffix).unwrap(), 4);
        assert_eq!(a.state_checksum(), b.state_checksum());
        assert_eq!(a.high_water(), b.high_water());
        // Compact a past the suffix: now b' (a fresh laggard) must rebuild.
        a.upsert(999, &extra[0]).unwrap();
        a.compact_once().unwrap();
        assert!(Wal::read_suffix(a.current_wal_path(), 1).unwrap().is_none());
        // Rebuild-from-peer: explicit state + continued numbering.
        let dir_c = tmp_dir("ck_c");
        let c: LiveIndex =
            LiveIndex::create_with_state(&dir_c, &a.live_items(), cb, a.high_water() + 1).unwrap();
        assert_eq!(c.state_checksum(), a.state_checksum());
        assert_eq!(c.high_water(), a.high_water());
        std::fs::remove_dir_all(&dir_a).ok();
        std::fs::remove_dir_all(&dir_b).ok();
        std::fs::remove_dir_all(&dir_c).ok();
    }

    /// The same scratch serves two live indexes without snapshot-cache
    /// confusion (the cell-id check).
    #[test]
    fn one_scratch_two_indexes() {
        let dir_a = tmp_dir("two_a");
        let dir_b = tmp_dir("two_b");
        let data_a = items(60, 5, 1);
        let data_b = items(60, 5, 2);
        let a: LiveIndex = LiveIndex::create(&dir_a, &data_a, cfg(1)).unwrap();
        let b: LiveIndex = LiveIndex::create(&dir_b, &data_b, cfg(1)).unwrap();
        let mut s = a.scratch();
        let q = &items(1, 5, 3)[0];
        let ra1 = a.query_into(q, 5, &mut s).to_vec();
        let rb1 = b.query_into(q, 5, &mut s).to_vec();
        assert_eq!(a.query_into(q, 5, &mut s).to_vec(), ra1);
        assert_eq!(b.query_into(q, 5, &mut s).to_vec(), rb1);
        std::fs::remove_dir_all(&dir_a).ok();
        std::fs::remove_dir_all(&dir_b).ok();
    }
}

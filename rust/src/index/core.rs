//! The bucketed (K, L) ALSH index: sublinear MIPS serving (Theorem 2).
//!
//! Hot-path architecture (this is the latency-critical serving code):
//!
//! * **Fused hashing** — all `L·K` codes per query come from one blocked
//!   matrix–vector pass over the stacked projection matrix
//!   ([`crate::lsh::FusedHasher`]), bit-identical to per-family hashing.
//! * **Frozen CSR tables** — the parallel sharded build
//!   ([`super::build`]) streams postings straight into flat
//!   sorted-key/offsets/postings arrays
//!   ([`super::frozen::FrozenTable`]); probes touch contiguous memory
//!   and no mutable `HashMap` stage ever exists.
//! * **Caller-owned scratch** — every transient buffer lives in a
//!   [`QueryScratch`] handed in by the caller, so steady-state queries
//!   allocate nothing and concurrent queries share no mutable state (no
//!   locks anywhere on the query path).
//!
//! The allocating methods (`query`, `candidates`, …) are convenience
//! wrappers over the `_into` variants using a thread-local scratch; hot
//! loops should own a scratch and call `query_into` directly. Offline
//! evaluation over many queries should use [`AlshIndex::query_batch_into`]
//! (matrix–matrix hashing).

use crate::util::Rng;

use super::budget::ProbeBudget;
use super::build::{self, BuildOpts, BuildStats};
use super::frozen::{FrozenTable, TableStats};
use super::scheme::{MipsHashScheme, SchemeFamilies, SchemeHasher};
use super::scratch::{with_thread_scratch, QueryScratch};
use super::storage::{Owned, Storage};
use crate::lsh::L2LshFamily;
use crate::transform::UScale;

/// Parameters of a bucketed ALSH index.
#[derive(Clone, Copy, Debug)]
pub struct AlshParams {
    /// Number of norm-power components appended by P/Q (paper recommends
    /// 3 for L2-ALSH; Shrivastava & Li 2015 recommend 2 for Sign-ALSH;
    /// ignored by Simple-LSH, whose transform is single-append).
    pub m: usize,
    /// Norm shrink target U (paper recommends 0.83; Sign-ALSH 0.75).
    pub u: f32,
    /// Quantization width r of the L2LSH family (paper recommends 2.5).
    /// Unused by the SRP schemes (sign bits have no bucket width).
    pub r: f32,
    /// Codes concatenated per table (meta-hash width K). For the SRP
    /// schemes these are sign *bits* packed into one u64 bucket key, so
    /// K <= 64 — and an SRP bit carries less selectivity than an L2
    /// quantization cell, so SRP operating points want a larger K (see
    /// [`AlshParams::recommended`]).
    pub k_per_table: usize,
    /// Number of hash tables L.
    pub n_tables: usize,
    /// Which asymmetric construction to run (transforms + hash family +
    /// bucket keys) — see [`MipsHashScheme`]. Defaults to the paper's
    /// L2-ALSH.
    pub scheme: MipsHashScheme,
}

impl Default for AlshParams {
    fn default() -> Self {
        // m, U, r from §3.5. The default (K, L) is recall-oriented
        // (top1-in-top10 ≈ 0.85-0.95 across workloads); raise K /
        // lower L to trade recall for fewer probed candidates — see
        // `examples/param_sweep.rs` for the measured trade-off curve.
        Self {
            m: 3,
            u: 0.83,
            r: 2.5,
            k_per_table: 6,
            n_tables: 32,
            scheme: MipsHashScheme::L2Alsh,
        }
    }
}

impl AlshParams {
    /// The literature-recommended operating point per scheme: the paper's
    /// §3.5 values for L2-ALSH, Shrivastava & Li 2015's (m=2, U=0.75)
    /// for Sign-ALSH, and a matching bit budget for Simple-LSH. The SRP
    /// schemes run wider K (1-bit codes are individually far less
    /// selective than L2 quantization cells at r=2.5).
    pub fn recommended(scheme: MipsHashScheme) -> Self {
        match scheme {
            MipsHashScheme::L2Alsh => Self::default(),
            MipsHashScheme::SignAlsh => Self {
                m: 2,
                u: 0.75,
                k_per_table: 16,
                n_tables: 32,
                scheme,
                ..Self::default()
            },
            MipsHashScheme::SimpleLsh => {
                Self { k_per_table: 16, n_tables: 32, scheme, ..Self::default() }
            }
        }
    }
}

/// Queries hashed per matrix–matrix chunk by the batch query path — large
/// enough to amortize row-block loads across the chunk, small enough that
/// the scratch's batch buffers stay bounded regardless of batch size.
const QUERY_BATCH_BLOCK: usize = 256;

/// The one implementation of the chunked batch-query loop, shared by the
/// flat and banded indexes ([`AlshIndex::query_batch_into`] and
/// `NormRangeIndex::query_batch_into`): Q-transform + hash each chunk in
/// one fused matrix–matrix pass, then per query stage the code row, run
/// the index-specific `probe`, optionally record the deduplicated
/// candidate count, and exact-rerank into `out` (cleared first). Batch
/// hashing is bit-identical to single-query hashing, so results equal
/// the per-query paths.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_query_batch<P: Fn(&mut QueryScratch)>(
    fused: &SchemeHasher,
    scheme: MipsHashScheme,
    m: usize,
    dim: usize,
    items_flat: &[f32],
    queries: &[Vec<f32>],
    k: usize,
    s: &mut QueryScratch,
    out: &mut Vec<Vec<ScoredItem>>,
    mut counts: Option<&mut Vec<usize>>,
    probe: P,
) {
    for q in queries {
        assert_eq!(q.len(), dim, "query dim mismatch");
    }
    out.clear();
    if let Some(c) = counts.as_deref_mut() {
        c.clear();
    }
    let nc = fused.n_codes();
    for chunk in queries.chunks(QUERY_BATCH_BLOCK) {
        s.hash_codes_batch(fused, scheme, chunk, m);
        for (i, q) in chunk.iter().enumerate() {
            s.stage_batch_codes(i, nc);
            probe(s);
            if let Some(c) = counts.as_deref_mut() {
                c.push(s.candidates().len());
            }
            out.push(super::rerank::rerank_into(items_flat, dim, q, k, s).to_vec());
        }
    }
}

/// A retrieved item with its exact inner-product score.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScoredItem {
    pub id: u32,
    pub score: f32,
}

/// Bucketed ALSH index over a fixed item collection.
///
/// Immutable once built (`Sync` without interior mutability): all query
/// state lives in the caller's [`QueryScratch`].
///
/// Generic over [`Storage`]: `AlshIndex` (the default, heap `Vec`s) is
/// what [`AlshIndex::build`] and the streaming persist loader produce;
/// `AlshIndex<Mapped>` serves the same query surface over zero-copy
/// views into a v5 index file (`index::persist::open_mmap`).
pub struct AlshIndex<S: Storage = Owned> {
    params: AlshParams,
    scale: UScale,
    /// One K-wide hash family per table, over dimension D' = D +
    /// `scheme.append_len(m)` (retained for persistence, the PJRT
    /// artifact inputs, and reference paths), stored per scheme. Small
    /// (O(L·K·D')), so owned under every storage.
    families: SchemeFamilies,
    /// The same families stacked into one `[L·K × D']` matrix.
    fused: SchemeHasher,
    /// Frozen CSR tables (build-side `HashMap` form is dropped after build).
    tables: Vec<FrozenTable<S>>,
    /// Original (unscaled) item vectors, row-major — used for exact rerank.
    items_flat: S::F32s,
    dim: usize,
    n_items: usize,
}

impl AlshIndex {
    /// Build the index over `items` (each of equal dimension) with the
    /// default pipeline options (all available cores).
    ///
    /// Applies Eq. 11 scaling (max norm -> U), the P transform (Eq. 12),
    /// hashes item blocks through the fused matrix (matrix–matrix), and
    /// streams the postings straight into the frozen CSR tables — see
    /// [`super::build`] for the sharded pipeline.
    pub fn build(items: &[Vec<f32>], params: AlshParams, seed: u64) -> Self {
        Self::build_with(items, params, seed, BuildOpts::default()).0
    }

    /// [`AlshIndex::build`] with explicit pipeline options (thread count,
    /// block size), returning build observability stats alongside the
    /// index. The built index is **byte-identical** for every `opts`
    /// choice: shards are contiguous id ranges merged in shard order, and
    /// blocked hashing is bit-identical to per-item hashing
    /// (property-tested in `tests/parallel_build_equivalence.rs`).
    pub fn build_with(
        items: &[Vec<f32>],
        params: AlshParams,
        seed: u64,
        opts: BuildOpts,
    ) -> (Self, BuildStats) {
        assert!(!items.is_empty(), "empty item collection");
        let dim = items[0].len();
        assert!(items.iter().all(|v| v.len() == dim), "ragged item dims");
        let scheme = params.scheme;
        let scale = UScale::fit(items.iter().map(|v| v.as_slice()), params.u);
        let mut rng = Rng::seed_from_u64(seed);
        let families = scheme.sample_families(
            dim + scheme.append_len(params.m),
            params.k_per_table,
            params.n_tables,
            params.r,
            &mut rng,
        );
        let fused = families.fuse();
        let factor = scale.factor;
        let m = params.m;
        let (tables, stats) = build::build_tables(items.len(), &fused, &opts, |id, row| {
            scheme.data_row_into(&items[id], factor, m, row)
        });
        let mut items_flat = Vec::with_capacity(items.len() * dim);
        for item in items {
            items_flat.extend_from_slice(item);
        }
        let index =
            Self { params, scale, families, fused, tables, items_flat, dim, n_items: items.len() };
        (index, stats)
    }
}

impl<S: Storage> AlshIndex<S> {
    pub fn params(&self) -> &AlshParams {
        &self.params
    }

    pub fn n_items(&self) -> usize {
        self.n_items
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn scale(&self) -> &UScale {
        &self.scale
    }

    /// The scheme this index was built with.
    pub fn scheme(&self) -> MipsHashScheme {
        self.params.scheme
    }

    /// The L2LSH hash families (the PJRT artifact inputs and code-fed
    /// reference paths). **Panics** for SRP-scheme indexes — those have
    /// no L2 families; use [`AlshIndex::scheme_families`].
    pub fn families(&self) -> &[L2LshFamily] {
        self.families.as_l2().expect(
            "families(): this index runs an SRP scheme (sign-alsh / simple-lsh); \
             use scheme_families() for scheme-generic access",
        )
    }

    /// The hash families, per scheme (persistence, diagnostics).
    pub fn scheme_families(&self) -> &SchemeFamilies {
        &self.families
    }

    /// The fused multi-table hasher (batcher fallback, benches).
    pub fn hasher(&self) -> &SchemeHasher {
        &self.fused
    }

    /// The frozen CSR hash tables (persistence / diagnostics).
    pub fn tables(&self) -> &[FrozenTable<S>] {
        &self.tables
    }

    /// The row-major `[n_items × dim]` item matrix (persistence — the
    /// v5 writer serializes it as one section).
    pub(crate) fn items_flat(&self) -> &[f32] {
        &self.items_flat
    }

    /// A scratch with the fixed-shape buffers (stamps, codes, fracs)
    /// pre-sized for this index. The variable-size buffers (candidates,
    /// rerank storage) still grow to their workload high-water mark over
    /// the first queries; after that warm-up, queries allocate nothing
    /// (asserted by `tests/zero_alloc.rs`).
    pub fn scratch(&self) -> QueryScratch {
        let mut s = QueryScratch::new();
        s.reserve(
            self.n_items,
            self.fused.n_codes(),
            self.dim + self.params.scheme.append_len(self.params.m),
        );
        s
    }

    /// Reassemble an index from persisted parts (see `index::persist`) —
    /// heap vectors from the streaming loader or mapped views from
    /// `open_mmap`, same constructor.
    pub(crate) fn from_parts(
        params: AlshParams,
        scale: UScale,
        families: SchemeFamilies,
        tables: Vec<FrozenTable<S>>,
        items_flat: S::F32s,
        dim: usize,
        n_items: usize,
    ) -> Self {
        assert_eq!(families.len(), params.n_tables);
        assert_eq!(tables.len(), params.n_tables);
        assert_eq!(items_flat.len(), dim * n_items);
        let fused = families.fuse();
        Self { params, scale, families, fused, tables, items_flat, dim, n_items }
    }

    /// Item vector by id.
    pub fn item(&self, id: u32) -> &[f32] {
        let i = id as usize;
        let flat: &[f32] = &self.items_flat;
        &flat[i * self.dim..(i + 1) * self.dim]
    }

    /// The one probe loop, parameterized by [`ProbeBudget`]: walk the
    /// first `budget.tables(L)` tables over the codes (and, when
    /// `budget.n_probes > 1`, the confidence channel) already staged in
    /// `s`, stopping early between tables once `budget.max_rerank`
    /// candidates are pooled, then trim to exactly the cap. At
    /// [`ProbeBudget::full`] this is bit-identical to the historical
    /// unbudgeted loop — the degraded serving mode is a parameter of this
    /// loop, not a fork of it.
    fn probe_scratch_codes_budgeted(&self, budget: ProbeBudget, s: &mut QueryScratch) {
        let k = self.params.k_per_table;
        let scheme = self.params.scheme;
        let nt = budget.tables(self.params.n_tables);
        let cap = budget.max_rerank;
        {
            let (mut sink, codes, fracs, perturbs) = s.dedup(self.n_items);
            for (t, table) in self.tables.iter().take(nt).enumerate() {
                let base = t * k;
                if budget.n_probes == 1 {
                    sink.extend(table.get_by_key(scheme.table_key(&codes[base..base + k])));
                } else {
                    super::multiprobe::for_each_probe_key(
                        scheme,
                        &mut codes[base..base + k],
                        &fracs[base..base + k],
                        perturbs,
                        budget.n_probes,
                        |key| sink.extend(table.get_by_key(key)),
                    );
                }
                if sink.len() >= cap {
                    break;
                }
            }
        }
        s.truncate_candidates(cap);
    }

    /// Probe all L tables with the codes in `s.codes`, deduplicating into
    /// `s.cands`.
    fn probe_scratch_codes(&self, s: &mut QueryScratch) {
        self.probe_scratch_codes_budgeted(ProbeBudget::full(), s);
    }

    /// Allocation-free candidate retrieval: the union of the probed
    /// buckets across all L tables, deduplicated, in first-seen order.
    pub fn candidates_into<'s>(&self, query: &[f32], s: &'s mut QueryScratch) -> &'s [u32] {
        self.candidates_budgeted_into(query, ProbeBudget::full(), s)
    }

    /// Budgeted candidate retrieval: same probe loop as
    /// [`AlshIndex::candidates_into`] / multi-probe, constrained by
    /// `budget` (tables, probes per table, rerank-pool cap). Bit-identical
    /// to the plain paths at [`ProbeBudget::full`] /
    /// [`ProbeBudget::with_probes`].
    pub fn candidates_budgeted_into<'s>(
        &self,
        query: &[f32],
        budget: ProbeBudget,
        s: &'s mut QueryScratch,
    ) -> &'s [u32] {
        assert_eq!(query.len(), self.dim, "query dim mismatch");
        assert!(budget.n_probes >= 1);
        self.params.scheme.query_into(query, self.params.m, &mut s.qx);
        if budget.n_probes == 1 {
            s.hash_codes(&self.fused);
        } else {
            s.hash_codes_with_conf(&self.fused);
        }
        self.probe_scratch_codes_budgeted(budget, s);
        &s.cands
    }

    /// Candidate retrieval when the caller already computed Q(query)
    /// (used when a whole batch was transformed/hashed up front).
    pub fn candidates_transformed_into<'s>(
        &self,
        qx: &[f32],
        s: &'s mut QueryScratch,
    ) -> &'s [u32] {
        s.hash_codes_external(&self.fused, qx);
        self.probe_scratch_codes(s);
        &s.cands
    }

    /// Candidate retrieval from externally computed per-table codes
    /// (the PJRT path: codes arrive as one `[L * K]` row per query).
    pub fn candidates_from_codes_into<'s>(
        &self,
        codes_flat: &[i32],
        s: &'s mut QueryScratch,
    ) -> &'s [u32] {
        self.candidates_from_codes_budgeted_into(codes_flat, ProbeBudget::full(), s)
    }

    /// Budgeted variant of [`AlshIndex::candidates_from_codes_into`].
    /// Honours `max_tables` and `max_rerank`; `n_probes` is ignored here
    /// because external codes carry no confidence channel to order the
    /// perturbations by.
    pub fn candidates_from_codes_budgeted_into<'s>(
        &self,
        codes_flat: &[i32],
        budget: ProbeBudget,
        s: &'s mut QueryScratch,
    ) -> &'s [u32] {
        let k = self.params.k_per_table;
        let scheme = self.params.scheme;
        assert_eq!(codes_flat.len(), k * self.params.n_tables);
        let nt = budget.tables(self.params.n_tables);
        let cap = budget.max_rerank;
        {
            let (mut sink, _, _, _) = s.dedup(self.n_items);
            for (t, table) in self.tables.iter().take(nt).enumerate() {
                sink.extend(table.get_by_key(scheme.table_key(&codes_flat[t * k..(t + 1) * k])));
                if sink.len() >= cap {
                    break;
                }
            }
        }
        s.truncate_candidates(cap);
        &s.cands
    }

    /// Allocation-free exact rerank of `s.cands` (the batched blocked
    /// rerank over `items_flat`, shared with the banded index via
    /// [`super::rerank`]: scalar path bit-exact, 8-lane FMA under
    /// `--features simd` with runtime CPU detection); top `k` lands in
    /// `s.top`, sorted by descending score.
    pub fn rerank_into<'s>(
        &self,
        query: &[f32],
        k: usize,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        super::rerank::rerank_into(self.items_flat(), self.dim, query, k, s)
    }

    /// Full allocation-free query: probe + exact rerank, results in
    /// (and borrowed from) the caller's scratch.
    pub fn query_into<'s>(
        &self,
        query: &[f32],
        k: usize,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        self.candidates_into(query, s);
        self.rerank_into(query, k, s)
    }

    /// Budgeted probe + exact rerank: the degraded-serving entry point.
    /// Bit-identical to [`AlshIndex::query_into`] at full budget.
    pub fn query_budgeted_into<'s>(
        &self,
        query: &[f32],
        k: usize,
        budget: ProbeBudget,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        self.candidates_budgeted_into(query, budget, s);
        self.rerank_into(query, k, s)
    }

    /// Batch query path for offline evaluation (figures, gold scans,
    /// parameter sweeps): Q-transforms and hashes queries in fused
    /// **matrix–matrix** chunks ([`SchemeHasher::hash_batch_into`], the
    /// same kernel the coordinator batcher uses), then probes and exactly
    /// reranks each query. Results land in `out` (one top-k `Vec` per
    /// query, cleared first) and are identical to per-query
    /// [`AlshIndex::query_into`] — blocked batch hashing is bit-identical
    /// to single-query hashing. Chunking bounds the scratch's batch
    /// buffers to a fixed row count however large the batch is.
    pub fn query_batch_into(
        &self,
        queries: &[Vec<f32>],
        k: usize,
        s: &mut QueryScratch,
        out: &mut Vec<Vec<ScoredItem>>,
    ) {
        self.query_batch_impl(queries, k, s, out, None)
    }

    /// [`AlshIndex::query_batch_into`] that additionally records each
    /// query's deduplicated candidate count in `counts` (cleared first) —
    /// the candidates/query metric every evaluation sweep wants, captured
    /// from the probe that already ran instead of re-probing.
    pub fn query_batch_counts_into(
        &self,
        queries: &[Vec<f32>],
        k: usize,
        s: &mut QueryScratch,
        out: &mut Vec<Vec<ScoredItem>>,
        counts: &mut Vec<usize>,
    ) {
        self.query_batch_impl(queries, k, s, out, Some(counts))
    }

    fn query_batch_impl(
        &self,
        queries: &[Vec<f32>],
        k: usize,
        s: &mut QueryScratch,
        out: &mut Vec<Vec<ScoredItem>>,
        counts: Option<&mut Vec<usize>>,
    ) {
        run_query_batch(
            &self.fused,
            self.params.scheme,
            self.params.m,
            self.dim,
            self.items_flat(),
            queries,
            k,
            s,
            out,
            counts,
            |s| self.probe_scratch_codes(s),
        )
    }

    /// Allocating convenience wrapper over [`AlshIndex::query_batch_into`]
    /// (thread-local scratch).
    pub fn query_batch(&self, queries: &[Vec<f32>], k: usize) -> Vec<Vec<ScoredItem>> {
        let mut out = Vec::with_capacity(queries.len());
        with_thread_scratch(|s| self.query_batch_into(queries, k, s, &mut out));
        out
    }

    // ---- allocating convenience wrappers (thread-local scratch) ----------

    /// Raw candidate ids for `query` — see [`AlshIndex::candidates_into`].
    pub fn candidates(&self, query: &[f32]) -> Vec<u32> {
        with_thread_scratch(|s| self.candidates_into(query, s).to_vec())
    }

    /// See [`AlshIndex::candidates_transformed_into`].
    pub fn candidates_transformed(&self, qx: &[f32]) -> Vec<u32> {
        with_thread_scratch(|s| self.candidates_transformed_into(qx, s).to_vec())
    }

    /// See [`AlshIndex::candidates_from_codes_into`].
    pub fn candidates_from_codes(&self, codes_flat: &[i32]) -> Vec<u32> {
        with_thread_scratch(|s| self.candidates_from_codes_into(codes_flat, s).to_vec())
    }

    /// Exact-rerank an arbitrary candidate list by inner product; top `k`.
    pub fn rerank(&self, query: &[f32], candidates: &[u32], k: usize) -> Vec<ScoredItem> {
        super::rerank::rerank_list(self.items_flat(), self.dim, query, candidates, k)
    }

    /// Full query: retrieve candidates, exact-rerank, return top `k`.
    pub fn query(&self, query: &[f32], k: usize) -> Vec<ScoredItem> {
        with_thread_scratch(|s| self.query_into(query, k, s).to_vec())
    }

    /// See [`AlshIndex::query_budgeted_into`].
    pub fn query_budgeted(&self, query: &[f32], k: usize, budget: ProbeBudget) -> Vec<ScoredItem> {
        with_thread_scratch(|s| self.query_budgeted_into(query, k, budget, s).to_vec())
    }

    /// Aggregate table statistics across the L tables.
    pub fn table_stats(&self) -> TableStats {
        TableStats::from_tables(&self.tables)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transform::{dot, q_transform};

    /// Items with wildly varying norms — the regime where MIPS != NNS.
    fn norm_spread_items(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n)
            .map(|i| {
                let scale = 0.2 + 2.0 * (i as f32 / n as f32);
                (0..d).map(|_| (rng.f32() - 0.5) * scale).collect()
            })
            .collect()
    }

    fn exact_top1(items: &[Vec<f32>], q: &[f32]) -> u32 {
        (0..items.len())
            .max_by(|&a, &b| dot(&items[a], q).partial_cmp(&dot(&items[b], q)).unwrap())
            .unwrap() as u32
    }

    #[test]
    fn build_populates_all_tables() {
        let items = norm_spread_items(100, 8, 1);
        let idx = AlshIndex::build(&items, AlshParams::default(), 2);
        let stats = idx.table_stats();
        assert_eq!(stats.n_postings, 100 * idx.params().n_tables);
        assert!(stats.n_buckets > 0 && stats.max_bucket > 0);
    }

    #[test]
    fn query_returns_sorted_scores() {
        let items = norm_spread_items(300, 12, 3);
        let idx = AlshIndex::build(&items, AlshParams::default(), 4);
        let mut rng = Rng::seed_from_u64(5);
        let q: Vec<f32> = (0..12).map(|_| rng.f32() - 0.5).collect();
        let top = idx.query(&q, 10);
        for w in top.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn scores_are_exact_inner_products() {
        let items = norm_spread_items(200, 10, 6);
        let idx = AlshIndex::build(&items, AlshParams::default(), 7);
        let q: Vec<f32> = (0..10).map(|i| (i as f32 * 0.7).sin()).collect();
        for s in idx.query(&q, 5) {
            let want = dot(&q, &items[s.id as usize]);
            assert!((s.score - want).abs() < 1e-6);
        }
    }

    #[test]
    fn scratch_path_equals_convenience_path() {
        let items = norm_spread_items(400, 12, 30);
        let idx = AlshIndex::build(&items, AlshParams::default(), 31);
        let mut s = idx.scratch();
        let mut rng = Rng::seed_from_u64(32);
        for _ in 0..25 {
            let q: Vec<f32> = (0..12).map(|_| rng.normal_f32()).collect();
            let via_scratch = idx.query_into(&q, 10, &mut s).to_vec();
            assert_eq!(via_scratch, idx.query(&q, 10));
            let cands_scratch = idx.candidates_into(&q, &mut s).to_vec();
            assert_eq!(cands_scratch, idx.candidates(&q));
        }
    }

    #[test]
    fn one_scratch_serves_multiple_indexes() {
        // Scratch buffers only grow; a shared scratch across indexes of
        // different sizes/shapes must stay correct (the router pattern).
        let small = AlshIndex::build(&norm_spread_items(50, 6, 40), AlshParams::default(), 41);
        let big_params = AlshParams { k_per_table: 9, n_tables: 12, ..Default::default() };
        let big = AlshIndex::build(&norm_spread_items(500, 6, 42), big_params, 43);
        let mut s = QueryScratch::new();
        let q = vec![0.25f32; 6];
        for _ in 0..3 {
            let a = small.query_into(&q, 5, &mut s).to_vec();
            assert_eq!(a, small.query(&q, 5));
            let b = big.query_into(&q, 5, &mut s).to_vec();
            assert_eq!(b, big.query(&q, 5));
        }
    }

    #[test]
    fn finds_the_mips_winner_with_enough_tables() {
        // Generous L so the probability of missing the top item is tiny.
        let items = norm_spread_items(500, 16, 8);
        let params = AlshParams { n_tables: 64, k_per_table: 4, ..Default::default() };
        let idx = AlshIndex::build(&items, params, 9);
        let mut rng = Rng::seed_from_u64(10);
        let mut hits = 0;
        let trials = 50;
        for _ in 0..trials {
            let q: Vec<f32> = (0..16).map(|_| rng.f32() - 0.5).collect();
            let want = exact_top1(&items, &q);
            let got = idx.query(&q, 10);
            if got.iter().any(|s| s.id == want) {
                hits += 1;
            }
        }
        assert!(hits >= 45, "top-1 recall {hits}/{trials}");
    }

    #[test]
    fn candidates_sublinear_fraction() {
        // Probing should touch far fewer items than the corpus.
        let items = norm_spread_items(2000, 16, 11);
        let params = AlshParams { n_tables: 16, k_per_table: 8, ..Default::default() };
        let idx = AlshIndex::build(&items, params, 12);
        let mut rng = Rng::seed_from_u64(13);
        let mut total = 0usize;
        for _ in 0..20 {
            let q: Vec<f32> = (0..16).map(|_| rng.f32() - 0.5).collect();
            total += idx.candidates(&q).len();
        }
        let avg = total as f64 / 20.0;
        assert!(avg < 1000.0, "avg candidates {avg} not sublinear-ish");
        assert!(avg > 0.0);
    }

    #[test]
    fn candidates_deduplicated() {
        let items = norm_spread_items(100, 8, 14);
        let idx = AlshIndex::build(&items, AlshParams::default(), 15);
        let q: Vec<f32> = (0..8).map(|i| i as f32 * 0.1).collect();
        let c = idx.candidates(&q);
        let mut sorted = c.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), c.len(), "duplicate candidates returned");
    }

    #[test]
    fn candidates_from_codes_matches_inline_hashing() {
        let items = norm_spread_items(150, 8, 16);
        let idx = AlshIndex::build(&items, AlshParams::default(), 17);
        let q: Vec<f32> = (0..8).map(|i| (i as f32).cos()).collect();
        let qx = q_transform(&q, idx.params().m);
        let mut flat = Vec::new();
        for fam in idx.families() {
            fam.hash_into(&qx, &mut flat);
        }
        let mut a = idx.candidates(&q);
        let mut b = idx.candidates_from_codes(&flat);
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn rerank_k_larger_than_candidates() {
        let items = norm_spread_items(50, 6, 18);
        let idx = AlshIndex::build(&items, AlshParams::default(), 19);
        let q = vec![0.5f32; 6];
        let out = idx.rerank(&q, &[1, 2, 3], 10);
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn rerank_into_matches_rerank() {
        let items = norm_spread_items(300, 10, 50);
        let idx = AlshIndex::build(&items, AlshParams::default(), 51);
        let q: Vec<f32> = (0..10).map(|i| (i as f32 * 0.3).cos()).collect();
        let mut s = idx.scratch();
        let cands = idx.candidates_into(&q, &mut s).to_vec();
        for k in [0usize, 1, 5, 1000] {
            let via_scratch = idx.rerank_into(&q, k, &mut s).to_vec();
            assert_eq!(via_scratch, idx.rerank(&q, &cands, k), "k={k}");
        }
    }

    #[test]
    fn build_with_is_thread_invariant() {
        // The sharded pipeline must yield byte-identical tables for any
        // thread/block choice (the full property test with a naive mirror
        // lives in tests/parallel_build_equivalence.rs).
        let items = norm_spread_items(350, 10, 60);
        let (a, stats_a) = AlshIndex::build_with(
            &items,
            AlshParams::default(),
            61,
            BuildOpts::single_threaded(),
        );
        assert_eq!(stats_a.n_threads, 1);
        let (b, stats_b) = AlshIndex::build_with(
            &items,
            AlshParams::default(),
            61,
            BuildOpts { n_threads: Some(5), block: 17, ..BuildOpts::default() },
        );
        assert_eq!(stats_b.n_threads, 5);
        assert!(stats_b.shard_peak_bytes > 0);
        for (ta, tb) in a.tables().iter().zip(b.tables()) {
            assert_eq!(ta.keys(), tb.keys());
            assert_eq!(ta.offsets(), tb.offsets());
            assert_eq!(ta.postings(), tb.postings());
        }
        let q: Vec<f32> = (0..10).map(|i| (i as f32 * 0.4).sin()).collect();
        assert_eq!(a.query(&q, 10), b.query(&q, 10));
    }

    #[test]
    fn query_batch_matches_per_query_path() {
        let items = norm_spread_items(400, 12, 70);
        let idx = AlshIndex::build(&items, AlshParams::default(), 71);
        let mut rng = Rng::seed_from_u64(72);
        let queries: Vec<Vec<f32>> =
            (0..17).map(|_| (0..12).map(|_| rng.normal_f32()).collect()).collect();
        let batch = idx.query_batch(&queries, 10);
        assert_eq!(batch.len(), queries.len());
        for (q, top) in queries.iter().zip(&batch) {
            assert_eq!(top, &idx.query(q, 10), "batch diverges from single-query path");
        }
        // Scratch variant agrees and handles the empty batch.
        let mut s = idx.scratch();
        let mut out = Vec::new();
        idx.query_batch_into(&queries, 10, &mut s, &mut out);
        assert_eq!(out, batch);
        idx.query_batch_into(&[], 10, &mut s, &mut out);
        assert!(out.is_empty());
        // The counts variant reports each query's probe size.
        let mut counts = Vec::new();
        idx.query_batch_counts_into(&queries, 10, &mut s, &mut out, &mut counts);
        assert_eq!(out, batch);
        assert_eq!(counts.len(), queries.len());
        for (q, &c) in queries.iter().zip(&counts) {
            assert_eq!(c, idx.candidates(q).len());
        }
    }

    /// With `--features simd` the rerank path may reassociate sums; the
    /// returned top-k must still match the exact scalar ranking as a set
    /// (tolerating only genuine near-ties at the k-th score).
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    #[test]
    fn rerank_simd_equivalence() {
        let items = norm_spread_items(500, 40, 80);
        let idx = AlshIndex::build(&items, AlshParams::default(), 81);
        let mut rng = Rng::seed_from_u64(82);
        for _ in 0..10 {
            let q: Vec<f32> = (0..40).map(|_| rng.normal_f32()).collect();
            let cands = idx.candidates(&q);
            let k = 10.min(cands.len());
            if k == 0 {
                continue;
            }
            let got = idx.rerank(&q, &cands, k);
            // Exact scalar reference ranking over the same candidates.
            let mut want: Vec<ScoredItem> = cands
                .iter()
                .map(|&id| ScoredItem { id, score: dot(&q, idx.item(id)) })
                .collect();
            want.sort_unstable_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
            want.truncate(k);
            let kth = want.last().unwrap().score;
            for g in &got {
                let in_want = want.iter().any(|w| w.id == g.id);
                assert!(
                    in_want || (g.score - kth).abs() < 1e-3,
                    "simd top-k id {} not in scalar top-k and not a near-tie",
                    g.id
                );
            }
        }
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        let items = norm_spread_items(10, 4, 20);
        let idx = AlshIndex::build(&items, AlshParams::default(), 21);
        let _ = idx.query(&[1.0, 2.0], 1);
    }

    #[test]
    fn epoch_wraparound_is_safe() {
        let items = norm_spread_items(50, 4, 22);
        let idx = AlshIndex::build(&items, AlshParams::default(), 23);
        let mut s = idx.scratch();
        // Force the scratch epoch counter close to wrap.
        s.set_epoch(u32::MAX - 2);
        let q = vec![0.3f32; 4];
        let want = idx.candidates(&q);
        for _ in 0..6 {
            let c = idx.candidates_into(&q, &mut s).to_vec();
            assert_eq!(c, want, "wraparound changed the candidate stream");
        }
    }
}

//! The bucketed (K, L) ALSH index: sublinear MIPS serving (Theorem 2).

use crate::util::Rng;

use super::hash_table::HashTable;
use crate::lsh::L2LshFamily;
use crate::transform::{dot, p_transform, q_transform, UScale};

/// Parameters of a bucketed ALSH index.
#[derive(Clone, Copy, Debug)]
pub struct AlshParams {
    /// Number of norm-power components appended by P/Q (paper recommends 3).
    pub m: usize,
    /// Norm shrink target U (paper recommends 0.83).
    pub u: f32,
    /// Quantization width r of the L2LSH family (paper recommends 2.5).
    pub r: f32,
    /// Codes concatenated per table (meta-hash width K).
    pub k_per_table: usize,
    /// Number of hash tables L.
    pub n_tables: usize,
}

impl Default for AlshParams {
    fn default() -> Self {
        // m, U, r from §3.5. The default (K, L) is recall-oriented
        // (top1-in-top10 ≈ 0.85-0.95 across workloads); raise K /
        // lower L to trade recall for fewer probed candidates — see
        // `examples/param_sweep.rs` for the measured trade-off curve.
        Self { m: 3, u: 0.83, r: 2.5, k_per_table: 6, n_tables: 32 }
    }
}

/// A retrieved item with its exact inner-product score.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScoredItem {
    pub id: u32,
    pub score: f32,
}

/// Bucketed ALSH index over a fixed item collection.
pub struct AlshIndex {
    params: AlshParams,
    scale: UScale,
    /// One K-wide hash family per table, over dimension D + m.
    families: Vec<L2LshFamily>,
    tables: Vec<HashTable>,
    /// Original (unscaled) item vectors, row-major — used for exact rerank.
    items_flat: Vec<f32>,
    dim: usize,
    n_items: usize,
    /// Visit stamps for allocation-free candidate dedup across tables
    /// (Mutex so the index is Sync; uncontended in the single-batcher path).
    stamps: std::sync::Mutex<(Vec<u32>, u32)>,
}

impl AlshIndex {
    /// Build the index over `items` (each of equal dimension).
    ///
    /// Applies Eq. 11 scaling (max norm -> U), the P transform (Eq. 12),
    /// and inserts every item into all L tables.
    pub fn build(items: &[Vec<f32>], params: AlshParams, seed: u64) -> Self {
        assert!(!items.is_empty(), "empty item collection");
        let dim = items[0].len();
        assert!(items.iter().all(|v| v.len() == dim), "ragged item dims");
        let scale = UScale::fit(items.iter().map(|v| v.as_slice()), params.u);
        let mut rng = Rng::seed_from_u64(seed);
        let families: Vec<L2LshFamily> = (0..params.n_tables)
            .map(|_| L2LshFamily::sample(dim + params.m, params.k_per_table, params.r, &mut rng))
            .collect();
        let mut tables = vec![HashTable::new(); params.n_tables];
        let mut codes = Vec::with_capacity(params.k_per_table);
        for (id, item) in items.iter().enumerate() {
            let px = p_transform(&scale.apply(item), params.m);
            for (family, table) in families.iter().zip(tables.iter_mut()) {
                codes.clear();
                family.hash_into(&px, &mut codes);
                table.insert(&codes, id as u32);
            }
        }
        let mut items_flat = Vec::with_capacity(items.len() * dim);
        for item in items {
            items_flat.extend_from_slice(item);
        }
        Self {
            params,
            scale,
            families,
            tables,
            items_flat,
            dim,
            n_items: items.len(),
            stamps: std::sync::Mutex::new((vec![0u32; items.len()], 0)),
        }
    }

    pub fn params(&self) -> &AlshParams {
        &self.params
    }

    pub fn n_items(&self) -> usize {
        self.n_items
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn scale(&self) -> &UScale {
        &self.scale
    }

    /// The hash families (for the PJRT-accelerated build path).
    pub fn families(&self) -> &[L2LshFamily] {
        &self.families
    }

    /// The hash tables (persistence / diagnostics).
    pub fn tables(&self) -> &[HashTable] {
        &self.tables
    }

    /// Reassemble an index from persisted parts (see `index::persist`).
    pub(crate) fn from_parts(
        params: AlshParams,
        scale: UScale,
        families: Vec<L2LshFamily>,
        tables: Vec<HashTable>,
        items_flat: Vec<f32>,
        dim: usize,
        n_items: usize,
    ) -> Self {
        assert_eq!(families.len(), params.n_tables);
        assert_eq!(tables.len(), params.n_tables);
        assert_eq!(items_flat.len(), dim * n_items);
        Self {
            params,
            scale,
            families,
            tables,
            items_flat,
            dim,
            n_items,
            stamps: std::sync::Mutex::new((vec![0u32; n_items], 0)),
        }
    }

    /// Run `f` with a fresh dedup epoch over the visit-stamp array
    /// (shared by the plain and multi-probe candidate paths).
    pub(crate) fn with_stamps(&self, f: impl FnOnce(&mut Vec<u32>, u32)) {
        let mut guard = self.stamps.lock().unwrap();
        let (stamps, epoch) = &mut *guard;
        *epoch = epoch.wrapping_add(1);
        if *epoch == 0 {
            stamps.fill(0);
            *epoch = 1;
        }
        let e = *epoch;
        f(stamps, e);
    }

    /// Item vector by id.
    pub fn item(&self, id: u32) -> &[f32] {
        let i = id as usize;
        &self.items_flat[i * self.dim..(i + 1) * self.dim]
    }

    /// Raw candidate ids for `query` — the union of the probed buckets
    /// across all L tables, deduplicated, before re-ranking.
    pub fn candidates(&self, query: &[f32]) -> Vec<u32> {
        assert_eq!(query.len(), self.dim, "query dim mismatch");
        let qx = q_transform(query, self.params.m);
        self.candidates_transformed(&qx)
    }

    /// Candidate retrieval when the caller already computed Q(query)
    /// codes-side input (used by the PJRT batcher, which hashes the whole
    /// batch in one executable call).
    pub fn candidates_transformed(&self, qx: &[f32]) -> Vec<u32> {
        let mut codes = Vec::with_capacity(self.params.k_per_table);
        let mut out = Vec::new();
        let mut guard = self.stamps.lock().unwrap();
        let (stamps, epoch) = &mut *guard;
        *epoch = epoch.wrapping_add(1);
        if *epoch == 0 {
            stamps.fill(0);
            *epoch = 1;
        }
        let epoch = *epoch;
        for (family, table) in self.families.iter().zip(&self.tables) {
            codes.clear();
            family.hash_into(qx, &mut codes);
            for &id in table.get(&codes) {
                let s = &mut stamps[id as usize];
                if *s != epoch {
                    *s = epoch;
                    out.push(id);
                }
            }
        }
        out
    }

    /// Candidate retrieval from externally computed per-table codes
    /// (the PJRT path: codes arrive as one `[L * K]` row per query).
    pub fn candidates_from_codes(&self, codes_flat: &[i32]) -> Vec<u32> {
        let k = self.params.k_per_table;
        assert_eq!(codes_flat.len(), k * self.params.n_tables);
        let mut out = Vec::new();
        let mut guard = self.stamps.lock().unwrap();
        let (stamps, epoch) = &mut *guard;
        *epoch = epoch.wrapping_add(1);
        if *epoch == 0 {
            stamps.fill(0);
            *epoch = 1;
        }
        let epoch = *epoch;
        for (t, table) in self.tables.iter().enumerate() {
            for &id in table.get(&codes_flat[t * k..(t + 1) * k]) {
                let s = &mut stamps[id as usize];
                if *s != epoch {
                    *s = epoch;
                    out.push(id);
                }
            }
        }
        out
    }

    /// Exact-rerank `candidates` by inner product with `query`; top `k`.
    pub fn rerank(&self, query: &[f32], candidates: &[u32], k: usize) -> Vec<ScoredItem> {
        let mut scored: Vec<ScoredItem> = candidates
            .iter()
            .map(|&id| ScoredItem { id, score: dot(query, self.item(id)) })
            .collect();
        let k = k.min(scored.len());
        if k == 0 {
            return Vec::new();
        }
        scored.select_nth_unstable_by(k - 1, |a, b| {
            b.score.partial_cmp(&a.score).unwrap()
        });
        scored.truncate(k);
        scored.sort_unstable_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        scored
    }

    /// Full query: retrieve candidates, exact-rerank, return top `k`.
    pub fn query(&self, query: &[f32], k: usize) -> Vec<ScoredItem> {
        let cands = self.candidates(query);
        self.rerank(query, &cands, k)
    }

    /// Aggregate table statistics: (total buckets, total postings, max bucket).
    pub fn table_stats(&self) -> (usize, usize, usize) {
        let b = self.tables.iter().map(|t| t.n_buckets()).sum();
        let p = self.tables.iter().map(|t| t.n_postings()).sum();
        let m = self.tables.iter().map(|t| t.max_bucket()).max().unwrap_or(0);
        (b, p, m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Items with wildly varying norms — the regime where MIPS != NNS.
    fn norm_spread_items(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n)
            .map(|i| {
                let scale = 0.2 + 2.0 * (i as f32 / n as f32);
                (0..d).map(|_| (rng.f32() - 0.5) * scale).collect()
            })
            .collect()
    }

    fn exact_top1(items: &[Vec<f32>], q: &[f32]) -> u32 {
        (0..items.len())
            .max_by(|&a, &b| dot(&items[a], q).partial_cmp(&dot(&items[b], q)).unwrap())
            .unwrap() as u32
    }

    #[test]
    fn build_populates_all_tables() {
        let items = norm_spread_items(100, 8, 1);
        let idx = AlshIndex::build(&items, AlshParams::default(), 2);
        let (_b, postings, _m) = idx.table_stats();
        assert_eq!(postings, 100 * idx.params().n_tables);
    }

    #[test]
    fn query_returns_sorted_scores() {
        let items = norm_spread_items(300, 12, 3);
        let idx = AlshIndex::build(&items, AlshParams::default(), 4);
        let mut rng = Rng::seed_from_u64(5);
        let q: Vec<f32> = (0..12).map(|_| rng.f32() - 0.5).collect();
        let top = idx.query(&q, 10);
        for w in top.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn scores_are_exact_inner_products() {
        let items = norm_spread_items(200, 10, 6);
        let idx = AlshIndex::build(&items, AlshParams::default(), 7);
        let q: Vec<f32> = (0..10).map(|i| (i as f32 * 0.7).sin()).collect();
        for s in idx.query(&q, 5) {
            let want = dot(&q, &items[s.id as usize]);
            assert!((s.score - want).abs() < 1e-6);
        }
    }

    #[test]
    fn finds_the_mips_winner_with_enough_tables() {
        // Generous L so the probability of missing the top item is tiny.
        let items = norm_spread_items(500, 16, 8);
        let params = AlshParams { n_tables: 64, k_per_table: 4, ..Default::default() };
        let idx = AlshIndex::build(&items, params, 9);
        let mut rng = Rng::seed_from_u64(10);
        let mut hits = 0;
        let trials = 50;
        for _ in 0..trials {
            let q: Vec<f32> = (0..16).map(|_| rng.f32() - 0.5).collect();
            let want = exact_top1(&items, &q);
            let got = idx.query(&q, 10);
            if got.iter().any(|s| s.id == want) {
                hits += 1;
            }
        }
        assert!(hits >= 45, "top-1 recall {hits}/{trials}");
    }

    #[test]
    fn candidates_sublinear_fraction() {
        // Probing should touch far fewer items than the corpus.
        let items = norm_spread_items(2000, 16, 11);
        let params = AlshParams { n_tables: 16, k_per_table: 8, ..Default::default() };
        let idx = AlshIndex::build(&items, params, 12);
        let mut rng = Rng::seed_from_u64(13);
        let mut total = 0usize;
        for _ in 0..20 {
            let q: Vec<f32> = (0..16).map(|_| rng.f32() - 0.5).collect();
            total += idx.candidates(&q).len();
        }
        let avg = total as f64 / 20.0;
        assert!(avg < 1000.0, "avg candidates {avg} not sublinear-ish");
        assert!(avg > 0.0);
    }

    #[test]
    fn candidates_deduplicated() {
        let items = norm_spread_items(100, 8, 14);
        let idx = AlshIndex::build(&items, AlshParams::default(), 15);
        let q: Vec<f32> = (0..8).map(|i| i as f32 * 0.1).collect();
        let c = idx.candidates(&q);
        let mut sorted = c.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), c.len(), "duplicate candidates returned");
    }

    #[test]
    fn candidates_from_codes_matches_inline_hashing() {
        let items = norm_spread_items(150, 8, 16);
        let idx = AlshIndex::build(&items, AlshParams::default(), 17);
        let q: Vec<f32> = (0..8).map(|i| (i as f32).cos()).collect();
        let qx = q_transform(&q, idx.params().m);
        let mut flat = Vec::new();
        for fam in idx.families() {
            fam.hash_into(&qx, &mut flat);
        }
        let mut a = idx.candidates(&q);
        let mut b = idx.candidates_from_codes(&flat);
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn rerank_k_larger_than_candidates() {
        let items = norm_spread_items(50, 6, 18);
        let idx = AlshIndex::build(&items, AlshParams::default(), 19);
        let q = vec![0.5f32; 6];
        let out = idx.rerank(&q, &[1, 2, 3], 10);
        assert_eq!(out.len(), 3);
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        let items = norm_spread_items(10, 4, 20);
        let idx = AlshIndex::build(&items, AlshParams::default(), 21);
        let _ = idx.query(&[1.0, 2.0], 1);
    }

    #[test]
    fn epoch_wraparound_is_safe() {
        let items = norm_spread_items(50, 4, 22);
        let idx = AlshIndex::build(&items, AlshParams::default(), 23);
        // Force the epoch counter close to wrap.
        idx.stamps.lock().unwrap().1 = u32::MAX - 2;
        let q = vec![0.3f32; 4];
        for _ in 0..6 {
            let c = idx.candidates(&q);
            let mut s = c.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), c.len());
        }
    }
}

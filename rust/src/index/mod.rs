//! The ALSH index — the paper's contribution as a serving data structure.
//!
//! Two retrieval modes, both from the paper:
//!
//! * **Bucketed (K, L)** (§2.2 + Theorem 2): L hash tables, each keyed by a
//!   meta-hash of K codes; a query probes one bucket per table and re-ranks
//!   the candidate union by exact inner product. This is the sublinear
//!   serving path.
//! * **Collision-count ranking** (Eq. 21, used by the paper's evaluation):
//!   rank every item by the number of hash agreements with the query over
//!   K independent functions. This is what Figures 5–7 measure.
//!
//! The bucketed mode serves in two layouts behind [`AnyIndex`]: the flat
//! single-scale [`AlshIndex`] and the norm-range partitioned
//! [`NormRangeIndex`] ([`banded`]: per-band U scaling, shared hash
//! families, queries hashed once and replayed across bands). Both layouts
//! run any of three hash **schemes** behind [`MipsHashScheme`]
//! ([`scheme`]): the paper's L2-ALSH, Sign-ALSH (SRP over the sign
//! transforms, Shrivastava & Li 2015), and Simple-LSH (single-append
//! symmetric SRP, Neyshabur & Srebro 2015) — selected by
//! [`AlshParams::scheme`] and carried end to end through build, serve,
//! multi-probe, and persistence.
//!
//! On top of the frozen layouts, [`delta`] layers a **live mutable
//! tier** ([`LiveIndex`]): crash-consistent upserts/deletes logged to an
//! append-only WAL ([`wal`]) before application, served to readers
//! through lock-free epoch-swapped snapshots, and drained back into a
//! fresh frozen generation by a verified background compactor. See the
//! [`delta`] module docs for the WAL record format, the
//! snapshot-plus-replay recovery contract, the reader guarantee, and the
//! norm-band migration semantics.

pub mod any;
pub mod banded;
pub mod budget;
pub mod build;
pub mod collision;
pub mod core;
pub mod delta;
pub mod frozen;
pub mod hash_table;
pub mod multiprobe;
pub mod persist;
mod rerank;
pub mod scheme;
pub mod scratch;
mod simd;
pub mod storage;
pub mod wal;

pub use any::{AnyIndex, MappedIndex};
pub use banded::{Band, BandedBuildStats, BandedParams, NormRangeIndex};
pub use budget::ProbeBudget;
pub use build::{BuildOpts, BuildStats};
pub use collision::{CollisionRanker, Scheme};
pub use core::{AlshIndex, AlshParams, ScoredItem};
pub use delta::{
    CompactorFaultPlan, LiveConfig, LiveIndex, LiveStats, LiveStorage, SeqGap, WriteStalled,
};
pub use frozen::{FrozenTable, TableStats};
pub use persist::{
    open_mmap, open_mmap_scheme, open_mmap_verified, sweep_stale_temps, PersistFormat,
};
pub use scheme::{MipsHashScheme, SchemeFamilies, SchemeHasher};
pub use scratch::QueryScratch;
pub use storage::{MapAdvice, MapSlice, Mapped, MmapFile, Owned, Storage};
pub use wal::{Wal, WalRecord};

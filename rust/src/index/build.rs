//! Parallel sharded index build: the streaming build pipeline behind
//! [`super::AlshIndex::build`] (and the symmetric L2LSH baseline).
//!
//! # Pipeline
//!
//! 1. **Shard** — the item id range is split into contiguous shards, one
//!    per worker thread (`std::thread::scope`; no external deps).
//! 2. **Block transform + hash** — each worker fills a flat
//!    `[block × D']` buffer with transformed item rows (the scheme's
//!    `_slice` transform variant) and hashes the whole block through
//!    [`SchemeHasher::hash_batch_into`] — matrix–matrix hashing on the
//!    build side, mirroring the query batcher, for whichever hash
//!    scheme the index runs.
//! 3. **Postings runs** — each worker reduces every item's K codes per
//!    table to a u64 bucket key (avalanche-mixed for L2 codes,
//!    bit-packed for SRP sign bits) and accumulates per-table
//!    `(key, item id)` runs, then sorts each run by `(key, id)`.
//! 4. **Counting merge** — the sorted shard runs are merged (tables in
//!    parallel) with [`FrozenTable::from_sorted_runs`]'s two-pass
//!    counting merge **directly into the frozen CSR layout** — the
//!    mutable `HashMap` build tables of the old path are gone entirely.
//!
//! # Equivalence
//!
//! The result is byte-identical for every thread count and block size:
//! blocked hashing is bit-identical to per-item hashing (never
//! reassociates a row's sum), shards are contiguous ascending id ranges
//! merged in shard order, so every bucket's postings come out
//! id-ascending — exactly what sequential insertion produced. Enforced by
//! `tests/parallel_build_equivalence.rs` against a from-first-principles
//! `HashMap` mirror across the plain, code-fed, and multi-probe query
//! paths.

use super::frozen::FrozenTable;
use super::scheme::SchemeHasher;
use super::scratch::BuildScratch;

/// Options controlling the build pipeline. The options trade build speed
/// and memory only — the built index is byte-identical for every choice.
#[derive(Clone, Copy, Debug)]
pub struct BuildOpts {
    /// Worker threads; `None` uses `std::thread::available_parallelism()`.
    pub n_threads: Option<usize>,
    /// Items transformed + hashed per matrix–matrix block.
    pub block: usize,
    /// Soft cap (bytes) on the transient postings-run memory held by
    /// *concurrent* `build_tables` calls. One call's runs total
    /// ~`n_items · L · 16` bytes whatever the thread count (every shard's
    /// runs stay alive until the counting merge), so the cap is enforced
    /// by callers that issue several builds at once: the norm-range
    /// banded build ([`crate::index::NormRangeIndex`]) groups bands so
    /// the concurrently-building bands' estimates
    /// ([`run_bytes_estimate`]) stay under the cap, serializing band
    /// groups when needed (always at least one band per group). `None`
    /// leaves concurrency unbounded.
    pub max_shard_bytes: Option<usize>,
}

impl Default for BuildOpts {
    fn default() -> Self {
        Self { n_threads: None, block: 64, max_shard_bytes: None }
    }
}

impl BuildOpts {
    /// Single-threaded build (the reference path for equivalence tests
    /// and latency-insensitive callers).
    pub fn single_threaded() -> Self {
        Self { n_threads: Some(1), ..Self::default() }
    }

    /// Build with exactly `n` worker threads.
    pub fn threads(n: usize) -> Self {
        Self { n_threads: Some(n.max(1)), ..Self::default() }
    }
}

/// Estimated bytes of transient per-shard postings runs that one
/// `build_tables` call over `n_items` items and `n_tables` tables holds
/// until its counting merge completes — the quantity
/// [`BuildOpts::max_shard_bytes`] caps across concurrent calls.
pub fn run_bytes_estimate(n_items: usize, n_tables: usize) -> usize {
    n_items * n_tables * std::mem::size_of::<(u64, u32)>()
}

/// Observability from one build run (reported by `BENCH_build.json`).
#[derive(Clone, Copy, Debug, Default)]
pub struct BuildStats {
    /// Shards actually used (= worker threads that ran).
    pub n_threads: usize,
    /// Items indexed.
    pub n_items: usize,
    /// Peak bytes held in per-shard postings runs before the merge
    /// released them (the pipeline's transient memory overhead).
    pub shard_peak_bytes: usize,
}

/// One worker's output: per-table `(bucket key, item id)` runs, each
/// sorted ascending by `(key, id)`.
type ShardRuns = Vec<Vec<(u64, u32)>>;

/// Hash items `start..end` in blocks; `fill_row(id, row)` writes item
/// `id`'s transformed `fused.dim()`-long input row. Bucket keys come
/// from the hasher variant itself ([`SchemeHasher::table_key`]:
/// avalanche mix for L2 codes, bit-pack for SRP sign bits), so build
/// and query keys can never disagree.
fn hash_shard<F: Fn(usize, &mut [f32])>(
    fill_row: &F,
    fused: &SchemeHasher,
    start: usize,
    end: usize,
    block: usize,
) -> ShardRuns {
    let dp = fused.dim();
    let nc = fused.n_codes();
    let k = fused.k();
    let n_tables = fused.n_tables();
    let mut scratch = BuildScratch::new();
    let mut runs: ShardRuns = (0..n_tables).map(|_| Vec::with_capacity(end - start)).collect();
    let mut at = start;
    while at < end {
        let rows = block.min(end - at);
        let (px, codes) = scratch.block_bufs(rows, dp, nc);
        for i in 0..rows {
            fill_row(at + i, &mut px[i * dp..(i + 1) * dp]);
        }
        fused.hash_batch_into(px, rows, codes);
        for i in 0..rows {
            let id = (at + i) as u32;
            let code_row = &codes[i * nc..(i + 1) * nc];
            for (t, run) in runs.iter_mut().enumerate() {
                run.push((fused.table_key(&code_row[t * k..(t + 1) * k]), id));
            }
        }
        at += rows;
    }
    for run in runs.iter_mut() {
        // (key, id) order; ids already ascend within each key because the
        // shard walks ids in ascending order, so unstable sort is safe.
        run.sort_unstable();
    }
    runs
}

/// Run the full pipeline: shard → block transform/hash → sorted postings
/// runs → parallel counting merge into frozen CSR tables.
///
/// `fill_row(id, row)` writes item `id`'s transformed input row (length
/// `fused.dim()`); it must be pure — workers call it concurrently.
pub(crate) fn build_tables<F>(
    n_items: usize,
    fused: &SchemeHasher,
    opts: &BuildOpts,
    fill_row: F,
) -> (Vec<FrozenTable>, BuildStats)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert!(n_items > 0, "empty item collection");
    assert!(n_items <= u32::MAX as usize, "item ids must fit u32");
    let block = opts.block.max(1);
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let n_threads = opts.n_threads.unwrap_or(hw).max(1).min(n_items);
    let shard_len = (n_items + n_threads - 1) / n_threads;
    let ranges: Vec<(usize, usize)> = (0..n_threads)
        .map(|w| (w * shard_len, ((w + 1) * shard_len).min(n_items)))
        .filter(|&(s, e)| s < e)
        .collect();

    // Phase 1: hash shards (one worker per contiguous id range).
    let fill = &fill_row;
    let mut shards: Vec<ShardRuns> = Vec::with_capacity(ranges.len());
    if ranges.len() == 1 {
        let (s, e) = ranges[0];
        shards.push(hash_shard(fill, fused, s, e, block));
    } else {
        std::thread::scope(|sc| {
            let handles: Vec<_> = ranges
                .iter()
                .map(|&(s, e)| sc.spawn(move || hash_shard(fill, fused, s, e, block)))
                .collect();
            for h in handles {
                shards.push(h.join().expect("build hash worker panicked"));
            }
        });
    }

    let entry_bytes = std::mem::size_of::<(u64, u32)>();
    let shard_peak_bytes: usize = shards
        .iter()
        .flat_map(|runs| runs.iter())
        .map(|run| run.capacity() * entry_bytes)
        .sum();

    // Phase 2: merge shard runs per table, tables split across threads.
    let n_tables = fused.n_tables();
    let merge_one = |t: usize| -> FrozenTable {
        let runs: Vec<&[(u64, u32)]> = shards.iter().map(|sh| sh[t].as_slice()).collect();
        FrozenTable::from_sorted_runs(&runs)
    };
    let mut tables: Vec<FrozenTable> = Vec::with_capacity(n_tables);
    let merge_threads = ranges.len().min(n_tables);
    if merge_threads <= 1 {
        for t in 0..n_tables {
            tables.push(merge_one(t));
        }
    } else {
        let chunk = (n_tables + merge_threads - 1) / merge_threads;
        let merge_ref = &merge_one;
        std::thread::scope(|sc| {
            let handles: Vec<_> = (0..merge_threads)
                .map(|w| {
                    let lo = (w * chunk).min(n_tables);
                    let hi = ((w + 1) * chunk).min(n_tables);
                    sc.spawn(move || (lo..hi).map(merge_ref).collect::<Vec<FrozenTable>>())
                })
                .collect();
            for h in handles {
                tables.extend(h.join().expect("build merge worker panicked"));
            }
        });
    }

    let stats =
        BuildStats { n_threads: ranges.len(), n_items, shard_peak_bytes };
    (tables, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsh::{FusedHasher, L2LshFamily};
    use crate::util::Rng;

    fn fused(l: usize, dim: usize, k: usize, seed: u64) -> SchemeHasher {
        let mut rng = Rng::seed_from_u64(seed);
        let fams: Vec<L2LshFamily> =
            (0..l).map(|_| L2LshFamily::sample(dim, k, 2.5, &mut rng)).collect();
        SchemeHasher::L2(FusedHasher::from_families(&fams))
    }

    fn items(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n).map(|_| (0..d).map(|_| rng.normal_f32() * 0.4).collect()).collect()
    }

    /// Every thread count / block size must produce byte-identical tables.
    #[test]
    fn thread_and_block_invariance() {
        let d = 10;
        let its = items(230, d, 1);
        let f = fused(5, d, 3, 2);
        let fill = |id: usize, out: &mut [f32]| out.copy_from_slice(&its[id]);
        let (base, base_stats) = build_tables(
            its.len(),
            &f,
            &BuildOpts { n_threads: Some(1), block: 64, ..BuildOpts::default() },
            fill,
        );
        assert_eq!(base_stats.n_threads, 1);
        assert_eq!(base_stats.n_items, 230);
        assert!(base_stats.shard_peak_bytes > 0);
        for (threads, block) in [(2usize, 64usize), (3, 7), (8, 1), (16, 33)] {
            let (tables, stats) = build_tables(
                its.len(),
                &f,
                &BuildOpts { n_threads: Some(threads), block, ..BuildOpts::default() },
                fill,
            );
            assert_eq!(stats.n_threads, threads.min(230));
            assert_eq!(tables.len(), base.len());
            for (a, b) in tables.iter().zip(&base) {
                assert_eq!(a.keys(), b.keys(), "threads={threads} block={block}");
                assert_eq!(a.offsets(), b.offsets(), "threads={threads} block={block}");
                assert_eq!(a.postings(), b.postings(), "threads={threads} block={block}");
            }
        }
    }

    /// More threads than items must not panic or drop postings.
    #[test]
    fn more_threads_than_items() {
        let d = 4;
        let its = items(3, d, 5);
        let f = fused(2, d, 2, 6);
        let (tables, stats) = build_tables(
            its.len(),
            &f,
            &BuildOpts { n_threads: Some(8), block: 64, ..BuildOpts::default() },
            |id, out| out.copy_from_slice(&its[id]),
        );
        assert!(stats.n_threads <= 3);
        for t in &tables {
            assert_eq!(t.n_postings(), 3);
        }
    }
}

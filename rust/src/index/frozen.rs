//! Immutable CSR hash tables: the serve-side (and now build-target) form.
//!
//! Each table is four flat arrays — sorted bucket keys, a 256-entry
//! top-byte radix, CSR offsets, and one contiguous postings array — so a
//! probe is a bounded binary search into cache-friendly memory instead of
//! a hash-map walk plus a pointer chase into a per-bucket `Vec`. The
//! radix over the (avalanched, uniform) keys first narrows the search to
//! ~1/256 of the key array, leaving a handful of comparisons per probe.
//!
//! Since the parallel sharded build there is no mutable `HashMap` stage at
//! all: build workers emit per-shard `(bucket key, item id)` runs sorted by
//! key, and [`FrozenTable::from_sorted_runs`] merges them with a two-pass
//! counting merge **directly into the CSR arrays** — exact-capacity
//! allocations, no per-bucket `Vec` churn. Runs arrive in ascending
//! item-id shard order, so each bucket's postings come out id-ascending —
//! byte-identical to what sequential insertion used to produce
//! (property-tested in `tests/parallel_build_equivalence.rs` and
//! `tests/fused_csr_equivalence.rs`).
//!
//! # Storage polymorphism
//!
//! The table is generic over [`Storage`]: the build pipeline produces
//! `FrozenTable<Owned>` (plain `Vec`s — and `FrozenTable` still names
//! exactly that, via the default type parameter), while persist v5's
//! `open_mmap` assembles `FrozenTable<Mapped>` from zero-copy views into
//! the index file — the arrays on disk are exactly the arrays the probe
//! loop walks, so the entire query surface runs unchanged on memory that
//! was never copied (`tests/mmap_equivalence.rs`).

use super::hash_table::bucket_key;
use super::storage::{Owned, Storage};

/// Aggregate statistics over a set of frozen CSR tables (one index's L
/// tables, or one norm band's). Replaces the old anonymous
/// `(buckets, postings, max bucket)` tuple.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TableStats {
    /// Non-empty buckets, summed across the tables.
    pub n_buckets: usize,
    /// Postings summed across the tables (= items × L for a full index —
    /// every item lands in exactly one bucket per table).
    pub n_postings: usize,
    /// Largest single bucket in any table (the skew diagnostic metrics
    /// report; giant buckets are what norm-range banding shrinks).
    pub max_bucket: usize,
}

impl TableStats {
    /// Aggregate over `tables` (any storage).
    pub fn from_tables<S: Storage>(tables: &[FrozenTable<S>]) -> Self {
        Self {
            n_buckets: tables.iter().map(|t| t.n_buckets()).sum(),
            n_postings: tables.iter().map(|t| t.n_postings()).sum(),
            max_bucket: tables.iter().map(|t| t.max_bucket()).max().unwrap_or(0),
        }
    }

    /// Combine two aggregates (summing across bands or shards).
    pub fn merge(self, other: TableStats) -> Self {
        Self {
            n_buckets: self.n_buckets + other.n_buckets,
            n_postings: self.n_postings + other.n_postings,
            max_bucket: self.max_bucket.max(other.max_bucket),
        }
    }
}

/// One frozen hash table in CSR layout, over owned or mapped storage.
pub struct FrozenTable<S: Storage = Owned> {
    /// Bucket keys, sorted ascending (unique by construction).
    keys: S::U64s,
    /// Top-byte radix: keys with high byte `b` live at
    /// `keys[starts[b] as usize..starts[b + 1] as usize]`. Length 257.
    starts: S::U32s,
    /// CSR offsets into `postings`; length `keys.len() + 1`.
    offsets: S::U32s,
    /// All postings, concatenated in bucket order.
    postings: S::U32s,
}

impl<S: Storage> Clone for FrozenTable<S> {
    fn clone(&self) -> Self {
        Self {
            keys: self.keys.clone(),
            starts: self.starts.clone(),
            offsets: self.offsets.clone(),
            postings: self.postings.clone(),
        }
    }
}

impl<S: Storage> std::fmt::Debug for FrozenTable<S> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FrozenTable")
            .field("n_buckets", &self.n_buckets())
            .field("n_postings", &self.n_postings())
            .finish()
    }
}

impl Default for FrozenTable<Owned> {
    fn default() -> Self {
        Self::from_pairs(Vec::new())
    }
}

fn radix_starts(keys: &[u64]) -> Vec<u32> {
    let mut starts = vec![0u32; 257];
    for &k in keys {
        starts[(k >> 56) as usize + 1] += 1;
    }
    for b in 0..256 {
        starts[b + 1] += starts[b];
    }
    starts
}

/// The smallest key at any run's cursor, or `None` when every run is
/// exhausted — the one merge-frontier scan both passes of
/// [`FrozenTable::from_sorted_runs`] share.
fn next_min_key(runs: &[&[(u64, u32)]], pos: &[usize]) -> Option<u64> {
    let mut min_key: Option<u64> = None;
    for (r, run) in runs.iter().enumerate() {
        if let Some(&(key, _)) = run.get(pos[r]) {
            min_key = Some(match min_key {
                Some(mk) if mk <= key => mk,
                _ => key,
            });
        }
    }
    min_key
}

impl FrozenTable<Owned> {
    /// Two-pass counting merge of per-shard `(bucket key, item id)` runs,
    /// each sorted ascending by key, directly into the CSR arrays.
    ///
    /// Pass 1 walks the merge to count distinct keys; pass 2 fills
    /// exact-capacity `keys`/`offsets`/`postings` — no intermediate maps,
    /// no reallocation. For every bucket, postings are emitted in run
    /// order: give the runs in ascending item-id shard order and each
    /// bucket's postings come out id-ascending, exactly the order
    /// sequential insertion produced.
    pub fn from_sorted_runs(runs: &[&[(u64, u32)]]) -> Self {
        let total: usize = runs.iter().map(|r| r.len()).sum();
        assert!(total <= u32::MAX as usize, "postings overflow u32 offsets");
        debug_assert!(
            runs.iter().all(|r| r.windows(2).all(|w| w[0].0 <= w[1].0)),
            "runs must be sorted ascending by key"
        );
        let mut pos = vec![0usize; runs.len()];
        // Pass 1: count distinct keys across all runs.
        let mut n_keys = 0usize;
        while let Some(mk) = next_min_key(runs, &pos) {
            n_keys += 1;
            for (r, run) in runs.iter().enumerate() {
                while pos[r] < run.len() && run[pos[r]].0 == mk {
                    pos[r] += 1;
                }
            }
        }
        // Pass 2: exact-capacity fill.
        let mut keys: Vec<u64> = Vec::with_capacity(n_keys);
        let mut offsets: Vec<u32> = Vec::with_capacity(n_keys + 1);
        let mut postings: Vec<u32> = Vec::with_capacity(total);
        offsets.push(0u32);
        for p in pos.iter_mut() {
            *p = 0;
        }
        while let Some(mk) = next_min_key(runs, &pos) {
            keys.push(mk);
            for (r, run) in runs.iter().enumerate() {
                while pos[r] < run.len() && run[pos[r]].0 == mk {
                    postings.push(run[pos[r]].1);
                    pos[r] += 1;
                }
            }
            offsets.push(postings.len() as u32);
        }
        debug_assert_eq!(keys.len(), n_keys);
        debug_assert_eq!(postings.len(), total);
        let starts = radix_starts(&keys);
        Self { keys, starts, offsets, postings }
    }

    /// Build from `(bucket key, item id)` pairs in insertion order; pairs
    /// with equal keys keep their relative order (stable sort), matching
    /// the semantics of the old mutable-`HashMap` insert path. Used by
    /// single-run builds and tests; the parallel build uses
    /// [`FrozenTable::from_sorted_runs`] on presorted shard runs.
    pub fn from_pairs(mut pairs: Vec<(u64, u32)>) -> Self {
        pairs.sort_by_key(|&(key, _)| key);
        Self::from_sorted_runs(&[pairs.as_slice()])
    }

    /// Reassemble from persisted parts, validating CSR invariants in
    /// full (the streaming load path — deep O(table) validation is the
    /// right trade when every byte is being copied anyway). `max_id`
    /// bounds the stored item ids (exclusive).
    pub fn from_parts(
        keys: Vec<u64>,
        offsets: Vec<u32>,
        postings: Vec<u32>,
        max_id: u32,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            offsets.len() == keys.len() + 1,
            "corrupt table: {} offsets for {} keys",
            offsets.len(),
            keys.len()
        );
        anyhow::ensure!(
            keys.windows(2).all(|w| w[0] < w[1]),
            "corrupt table: keys not strictly ascending"
        );
        anyhow::ensure!(offsets.first() == Some(&0), "corrupt table: offsets[0] != 0");
        anyhow::ensure!(
            offsets.windows(2).all(|w| w[0] <= w[1]),
            "corrupt table: offsets not monotonic"
        );
        anyhow::ensure!(
            *offsets.last().unwrap() as usize == postings.len(),
            "corrupt table: offsets end {} != {} postings",
            offsets.last().unwrap(),
            postings.len()
        );
        anyhow::ensure!(
            postings.iter().all(|&id| id < max_id),
            "corrupt table: posting id out of range"
        );
        let starts = radix_starts(&keys);
        Ok(Self { keys, starts, offsets, postings })
    }
}

impl<S: Storage> FrozenTable<S> {
    /// Assemble from already-materialized storage (the persist v5 path:
    /// all four arrays — including the radix `starts` — live in the file
    /// as sections). Validation here is **O(1)-per-table shape checks
    /// plus the 257-entry radix**, deliberately not the O(n) deep CSR
    /// scan of [`FrozenTable::from_parts`]: the mapped open must stay
    /// O(header) and must not fault in the postings pages. Deep
    /// corruption inside keys/postings surfaces as a clean probe miss or
    /// a safe index panic, never UB.
    pub(crate) fn from_storage_parts(
        keys: S::U64s,
        starts: S::U32s,
        offsets: S::U32s,
        postings: S::U32s,
    ) -> anyhow::Result<Self> {
        {
            let s: &[u32] = &starts;
            let o: &[u32] = &offsets;
            anyhow::ensure!(
                s.len() == 257,
                "corrupt table: radix starts length {} != 257",
                s.len()
            );
            anyhow::ensure!(
                o.len() == keys.len() + 1,
                "corrupt table: {} offsets for {} keys",
                o.len(),
                keys.len()
            );
            anyhow::ensure!(s[0] == 0, "corrupt table: radix starts[0] != 0");
            anyhow::ensure!(
                s[256] as usize == keys.len(),
                "corrupt table: radix end {} != {} keys",
                s[256],
                keys.len()
            );
            anyhow::ensure!(
                s.windows(2).all(|w| w[0] <= w[1]),
                "corrupt table: radix starts not monotonic"
            );
            anyhow::ensure!(o[0] == 0, "corrupt table: offsets[0] != 0");
            anyhow::ensure!(
                *o.last().unwrap() as usize == postings.len(),
                "corrupt table: offsets end {} != {} postings",
                o.last().unwrap(),
                postings.len()
            );
        }
        Ok(Self { keys, starts, offsets, postings })
    }

    /// The postings list for `codes` (empty slice for an empty bucket).
    #[inline]
    pub fn get(&self, codes: &[i32]) -> &[u32] {
        self.get_by_key(bucket_key(codes))
    }

    /// Probe by raw bucket key. One code path for both storages: the
    /// slice locals are a single pointer+len load whether the backing is
    /// a `Vec` or a mapped section.
    #[inline]
    pub fn get_by_key(&self, key: u64) -> &[u32] {
        let starts: &[u32] = &self.starts;
        let keys: &[u64] = &self.keys;
        let offsets: &[u32] = &self.offsets;
        let postings: &[u32] = &self.postings;
        let b = (key >> 56) as usize;
        let lo = starts[b] as usize;
        let hi = starts[b + 1] as usize;
        match keys[lo..hi].binary_search(&key) {
            Ok(i) => {
                let i = lo + i;
                &postings[offsets[i] as usize..offsets[i + 1] as usize]
            }
            Err(_) => &[],
        }
    }

    /// Number of non-empty buckets.
    pub fn n_buckets(&self) -> usize {
        self.keys.len()
    }

    /// Total number of postings (= number of inserted items).
    pub fn n_postings(&self) -> usize {
        self.postings.len()
    }

    /// Size of the largest bucket (skew diagnostic for metrics).
    pub fn max_bucket(&self) -> usize {
        self.offsets
            .windows(2)
            .map(|w| (w[1] - w[0]) as usize)
            .max()
            .unwrap_or(0)
    }

    /// Sorted bucket keys (persistence).
    pub fn keys(&self) -> &[u64] {
        &self.keys
    }

    /// Top-byte radix starts, length 257 (persistence — stored as a v5
    /// section so the mapped open never rescans the keys).
    pub fn starts(&self) -> &[u32] {
        &self.starts
    }

    /// CSR offsets (persistence).
    pub fn offsets(&self) -> &[u32] {
        &self.offsets
    }

    /// Concatenated postings (persistence).
    pub fn postings(&self) -> &[u32] {
        &self.postings
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::util::Rng;
    use std::collections::HashMap;

    /// Naive mirror of the old mutable build table plus the insertion
    /// stream that fed it: the oracle for the CSR constructors.
    fn random_pairs(rng: &mut Rng, n_items: u32) -> (Vec<(u64, u32)>, HashMap<u64, Vec<u32>>) {
        let mut pairs = Vec::new();
        let mut mirror: HashMap<u64, Vec<u32>> = HashMap::new();
        for id in 0..n_items {
            let codes: Vec<i32> = (0..3).map(|_| (rng.below(6) as i32) - 3).collect();
            let key = bucket_key(&codes);
            pairs.push((key, id));
            mirror.entry(key).or_default().push(id);
        }
        (pairs, mirror)
    }

    #[test]
    fn from_pairs_preserves_every_bucket() {
        check(40, |rng| {
            let n = 1 + rng.below(300) as u32;
            let (pairs, mirror) = random_pairs(rng, n);
            let frozen = FrozenTable::from_pairs(pairs);
            assert_eq!(frozen.n_buckets(), mirror.len());
            assert_eq!(frozen.n_postings(), n as usize);
            let max = mirror.values().map(|v| v.len()).max().unwrap_or(0);
            assert_eq!(frozen.max_bucket(), max);
            for (key, ids) in &mirror {
                assert_eq!(frozen.get_by_key(*key), ids.as_slice(), "bucket {key:#x}");
            }
        });
    }

    #[test]
    fn sorted_runs_merge_matches_single_run() {
        // Splitting the id range into contiguous shards and merging must
        // give byte-identical CSR arrays to the single-run build.
        check(40, |rng| {
            let n = 1 + rng.below(400) as u32;
            let (pairs, _) = random_pairs(rng, n);
            let whole = FrozenTable::from_pairs(pairs.clone());
            let n_shards = 1 + rng.below(6);
            let shard_len = (pairs.len() + n_shards - 1) / n_shards;
            let mut runs: Vec<Vec<(u64, u32)>> = Vec::new();
            for chunk in pairs.chunks(shard_len.max(1)) {
                let mut run = chunk.to_vec();
                run.sort_unstable(); // by (key, id); ids already ascend per shard
                runs.push(run);
            }
            let borrowed: Vec<&[(u64, u32)]> = runs.iter().map(|r| r.as_slice()).collect();
            let merged = FrozenTable::from_sorted_runs(&borrowed);
            assert_eq!(merged.keys(), whole.keys());
            assert_eq!(merged.offsets(), whole.offsets());
            assert_eq!(merged.postings(), whole.postings());
        });
    }

    #[test]
    fn missing_keys_probe_empty() {
        let mut rng = Rng::seed_from_u64(9);
        let (pairs, mirror) = random_pairs(&mut rng, 100);
        let frozen = FrozenTable::from_pairs(pairs);
        // Probe keys that are almost certainly absent.
        for i in 0..1000u64 {
            let key = i.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xDEAD_BEEF;
            let want: &[u32] = mirror.get(&key).map(|v| v.as_slice()).unwrap_or(&[]);
            assert_eq!(frozen.get_by_key(key), want);
        }
    }

    #[test]
    fn parts_roundtrip() {
        let mut rng = Rng::seed_from_u64(10);
        let (pairs, mirror) = random_pairs(&mut rng, 200);
        let frozen = FrozenTable::from_pairs(pairs);
        let rebuilt = FrozenTable::from_parts(
            frozen.keys().to_vec(),
            frozen.offsets().to_vec(),
            frozen.postings().to_vec(),
            200,
        )
        .unwrap();
        for (key, ids) in &mirror {
            assert_eq!(rebuilt.get_by_key(*key), ids.as_slice());
        }
    }

    #[test]
    fn storage_parts_roundtrip_probes_identically() {
        // Reassembling through the v5-style constructor (radix included)
        // must probe byte-identically to the original.
        let mut rng = Rng::seed_from_u64(23);
        let (pairs, mirror) = random_pairs(&mut rng, 300);
        let frozen = FrozenTable::from_pairs(pairs);
        let rebuilt = FrozenTable::<Owned>::from_storage_parts(
            frozen.keys().to_vec(),
            frozen.starts().to_vec(),
            frozen.offsets().to_vec(),
            frozen.postings().to_vec(),
        )
        .unwrap();
        for (key, ids) in &mirror {
            assert_eq!(rebuilt.get_by_key(*key), ids.as_slice());
        }
    }

    #[test]
    fn from_parts_rejects_corruption() {
        // Unsorted keys.
        assert!(FrozenTable::from_parts(vec![5, 3], vec![0, 1, 2], vec![0, 1], 10).is_err());
        // Offsets length mismatch.
        assert!(FrozenTable::from_parts(vec![3], vec![0], vec![0], 10).is_err());
        // Non-monotonic offsets.
        assert!(FrozenTable::from_parts(vec![1, 2], vec![0, 2, 1], vec![0, 1], 10).is_err());
        // Offsets end != postings length.
        assert!(FrozenTable::from_parts(vec![1], vec![0, 3], vec![0, 1], 10).is_err());
        // Posting id out of range.
        assert!(FrozenTable::from_parts(vec![1], vec![0, 1], vec![10], 10).is_err());
    }

    #[test]
    fn from_storage_parts_rejects_bad_shapes() {
        let good = FrozenTable::from_pairs(vec![(7, 0), (9, 1), (9, 2)]);
        let (k, s, o, p) = (
            good.keys().to_vec(),
            good.starts().to_vec(),
            good.offsets().to_vec(),
            good.postings().to_vec(),
        );
        // Wrong radix length.
        assert!(FrozenTable::<Owned>::from_storage_parts(
            k.clone(),
            s[..256].to_vec(),
            o.clone(),
            p.clone()
        )
        .is_err());
        // Radix end disagrees with key count.
        let mut bad_s = s.clone();
        bad_s[256] += 1;
        assert!(
            FrozenTable::<Owned>::from_storage_parts(k.clone(), bad_s, o.clone(), p.clone())
                .is_err()
        );
        // Non-monotone radix.
        let mut bad_s = s.clone();
        bad_s[10] = 200;
        bad_s[11] = 100;
        assert!(
            FrozenTable::<Owned>::from_storage_parts(k.clone(), bad_s, o.clone(), p.clone())
                .is_err()
        );
        // Offsets length mismatch.
        assert!(FrozenTable::<Owned>::from_storage_parts(
            k.clone(),
            s.clone(),
            o[..o.len() - 1].to_vec(),
            p.clone()
        )
        .is_err());
        // Offsets end != postings.
        let mut bad_o = o.clone();
        *bad_o.last_mut().unwrap() += 1;
        assert!(
            FrozenTable::<Owned>::from_storage_parts(k.clone(), s.clone(), bad_o, p.clone())
                .is_err()
        );
        // The untouched parts still assemble.
        assert!(FrozenTable::<Owned>::from_storage_parts(k, s, o, p).is_ok());
    }

    #[test]
    fn empty_table_builds() {
        let frozen = FrozenTable::from_pairs(Vec::new());
        assert_eq!(frozen.n_buckets(), 0);
        assert_eq!(frozen.n_postings(), 0);
        assert_eq!(frozen.max_bucket(), 0);
        assert!(frozen.get(&[1, 2, 3]).is_empty());
        // Merging only empty runs is also fine.
        let empty_run: &[(u64, u32)] = &[];
        let merged = FrozenTable::from_sorted_runs(&[empty_run, empty_run]);
        assert_eq!(merged.n_buckets(), 0);
    }
}

//! Immutable CSR hash tables: the serve-side form of [`HashTable`].
//!
//! After the build pass, each mutable `HashMap<u64, Vec<u32>>` table is
//! frozen into three flat arrays — sorted bucket keys, CSR offsets, and
//! one contiguous postings array — so a probe is a bounded binary search
//! into cache-friendly memory instead of a hash-map walk plus a pointer
//! chase into a per-bucket `Vec`. A 256-entry top-byte radix over the
//! (avalanched, uniform) keys first narrows the search to ~1/256 of the
//! key array, leaving a handful of comparisons per probe.
//!
//! Freezing preserves each bucket's postings order (ascending item id, the
//! build insertion order), so candidate streams are byte-identical to the
//! mutable form — property-tested in `tests/fused_csr_equivalence.rs`.

use super::hash_table::{bucket_key, HashTable};

/// One frozen hash table in CSR layout.
#[derive(Clone, Debug, Default)]
pub struct FrozenTable {
    /// Bucket keys, sorted ascending (unique by construction).
    keys: Vec<u64>,
    /// Top-byte radix: keys with high byte `b` live at
    /// `keys[starts[b] as usize..starts[b + 1] as usize]`. Length 257.
    starts: Vec<u32>,
    /// CSR offsets into `postings`; length `keys.len() + 1`.
    offsets: Vec<u32>,
    /// All postings, concatenated in bucket order.
    postings: Vec<u32>,
}

fn radix_starts(keys: &[u64]) -> Vec<u32> {
    let mut starts = vec![0u32; 257];
    for &k in keys {
        starts[(k >> 56) as usize + 1] += 1;
    }
    for b in 0..256 {
        starts[b + 1] += starts[b];
    }
    starts
}

impl FrozenTable {
    /// Freeze a build-side table. Postings order within each bucket is
    /// preserved exactly.
    pub fn freeze(table: &HashTable) -> Self {
        let mut entries: Vec<(u64, &Vec<u32>)> =
            table.buckets().map(|(k, v)| (*k, v)).collect();
        entries.sort_unstable_by_key(|e| e.0);
        let n_postings: usize = entries.iter().map(|(_, v)| v.len()).sum();
        assert!(n_postings <= u32::MAX as usize, "postings overflow u32 offsets");
        let mut keys = Vec::with_capacity(entries.len());
        let mut offsets = Vec::with_capacity(entries.len() + 1);
        let mut postings = Vec::with_capacity(n_postings);
        offsets.push(0u32);
        for (key, ids) in entries {
            keys.push(key);
            postings.extend_from_slice(ids);
            offsets.push(postings.len() as u32);
        }
        let starts = radix_starts(&keys);
        Self { keys, starts, offsets, postings }
    }

    /// Reassemble from persisted parts, validating CSR invariants.
    /// `max_id` bounds the stored item ids (exclusive).
    pub fn from_parts(
        keys: Vec<u64>,
        offsets: Vec<u32>,
        postings: Vec<u32>,
        max_id: u32,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            offsets.len() == keys.len() + 1,
            "corrupt table: {} offsets for {} keys",
            offsets.len(),
            keys.len()
        );
        anyhow::ensure!(
            keys.windows(2).all(|w| w[0] < w[1]),
            "corrupt table: keys not strictly ascending"
        );
        anyhow::ensure!(offsets.first() == Some(&0), "corrupt table: offsets[0] != 0");
        anyhow::ensure!(
            offsets.windows(2).all(|w| w[0] <= w[1]),
            "corrupt table: offsets not monotonic"
        );
        anyhow::ensure!(
            *offsets.last().unwrap() as usize == postings.len(),
            "corrupt table: offsets end {} != {} postings",
            offsets.last().unwrap(),
            postings.len()
        );
        anyhow::ensure!(
            postings.iter().all(|&id| id < max_id),
            "corrupt table: posting id out of range"
        );
        let starts = radix_starts(&keys);
        Ok(Self { keys, starts, offsets, postings })
    }

    /// The postings list for `codes` (empty slice for an empty bucket).
    #[inline]
    pub fn get(&self, codes: &[i32]) -> &[u32] {
        self.get_by_key(bucket_key(codes))
    }

    /// Probe by raw bucket key.
    #[inline]
    pub fn get_by_key(&self, key: u64) -> &[u32] {
        let b = (key >> 56) as usize;
        let lo = self.starts[b] as usize;
        let hi = self.starts[b + 1] as usize;
        match self.keys[lo..hi].binary_search(&key) {
            Ok(i) => {
                let i = lo + i;
                &self.postings[self.offsets[i] as usize..self.offsets[i + 1] as usize]
            }
            Err(_) => &[],
        }
    }

    /// Number of non-empty buckets.
    pub fn n_buckets(&self) -> usize {
        self.keys.len()
    }

    /// Total number of postings (= number of inserted items).
    pub fn n_postings(&self) -> usize {
        self.postings.len()
    }

    /// Size of the largest bucket (skew diagnostic for metrics).
    pub fn max_bucket(&self) -> usize {
        self.offsets
            .windows(2)
            .map(|w| (w[1] - w[0]) as usize)
            .max()
            .unwrap_or(0)
    }

    /// Sorted bucket keys (persistence).
    pub fn keys(&self) -> &[u64] {
        &self.keys
    }

    /// CSR offsets (persistence).
    pub fn offsets(&self) -> &[u32] {
        &self.offsets
    }

    /// Concatenated postings (persistence).
    pub fn postings(&self) -> &[u32] {
        &self.postings
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::util::Rng;

    fn random_table(rng: &mut Rng, n_items: u32) -> HashTable {
        let mut t = HashTable::new();
        for id in 0..n_items {
            let codes: Vec<i32> =
                (0..3).map(|_| (rng.below(6) as i32) - 3).collect();
            t.insert(&codes, id);
        }
        t
    }

    #[test]
    fn freeze_preserves_every_bucket() {
        check(40, |rng| {
            let n = 1 + rng.below(300) as u32;
            let table = random_table(rng, n);
            let frozen = FrozenTable::freeze(&table);
            assert_eq!(frozen.n_buckets(), table.n_buckets());
            assert_eq!(frozen.n_postings(), table.n_postings());
            assert_eq!(frozen.max_bucket(), table.max_bucket());
            for (key, ids) in table.buckets() {
                assert_eq!(frozen.get_by_key(*key), ids.as_slice(), "bucket {key:#x}");
            }
        });
    }

    #[test]
    fn missing_keys_probe_empty() {
        let mut rng = Rng::seed_from_u64(9);
        let table = random_table(&mut rng, 100);
        let frozen = FrozenTable::freeze(&table);
        // Probe keys that are almost certainly absent.
        for i in 0..1000u64 {
            let key = i.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xDEAD_BEEF;
            assert_eq!(frozen.get_by_key(key), table.get_by_key(key));
        }
    }

    #[test]
    fn parts_roundtrip() {
        let mut rng = Rng::seed_from_u64(10);
        let table = random_table(&mut rng, 200);
        let frozen = FrozenTable::freeze(&table);
        let rebuilt = FrozenTable::from_parts(
            frozen.keys().to_vec(),
            frozen.offsets().to_vec(),
            frozen.postings().to_vec(),
            200,
        )
        .unwrap();
        for (key, ids) in table.buckets() {
            assert_eq!(rebuilt.get_by_key(*key), ids.as_slice());
        }
    }

    #[test]
    fn from_parts_rejects_corruption() {
        // Unsorted keys.
        assert!(FrozenTable::from_parts(vec![5, 3], vec![0, 1, 2], vec![0, 1], 10).is_err());
        // Offsets length mismatch.
        assert!(FrozenTable::from_parts(vec![3], vec![0], vec![0], 10).is_err());
        // Non-monotonic offsets.
        assert!(FrozenTable::from_parts(vec![1, 2], vec![0, 2, 1], vec![0, 1], 10).is_err());
        // Offsets end != postings length.
        assert!(FrozenTable::from_parts(vec![1], vec![0, 3], vec![0, 1], 10).is_err());
        // Posting id out of range.
        assert!(FrozenTable::from_parts(vec![1], vec![0, 1], vec![10], 10).is_err());
    }

    #[test]
    fn empty_table_freezes() {
        let frozen = FrozenTable::freeze(&HashTable::new());
        assert_eq!(frozen.n_buckets(), 0);
        assert_eq!(frozen.n_postings(), 0);
        assert_eq!(frozen.max_bucket(), 0);
        assert!(frozen.get(&[1, 2, 3]).is_empty());
    }
}

//! Norm-range partitioned ALSH index (Norm-Ranging LSH, Yan et al. 2018):
//! per-band U scaling with shared-hash banded queries.
//!
//! # Why bands: the per-band U math
//!
//! The flat index pays for the whole corpus with a single Eq. 11 scale
//! `s = U / max‖x‖`. Items whose norms sit far below the max are crushed
//! toward the origin: after scaling `‖s·x‖ ≈ 0`, so by Eq. 17 the
//! transformed distance to *any* query collapses to the constant
//! `‖Q(q) − P(x)‖² ≈ 1 + m/4` — the query's angle to the item stops
//! mattering. At that constant mid-range distance the index can neither
//! *find* a crushed item when it is the true match (its collision
//! probability is no higher than anyone else's → recall loss) nor
//! *reject* it when it is noise (its collision probability is no lower →
//! a floor on candidates). Equivalently, the effective approximation
//! ratio c of Theorem 2 degrades, so the only way the flat index keeps
//! recall on skewed-norm data is to run an unselective (low-K) operating
//! point — and eat enormous candidate sets.
//!
//! [`NormRangeIndex`] splits the items into B norm bands (equal-count
//! split over the sorted norms) and fits an **independent** `U`-scale per
//! band: band b is scaled by `s_b = U / max_{x ∈ band b}‖x‖`. Within each
//! band the norm spread is a factor-of-B narrower, so after scaling every
//! band's items sit near the full (0, U] range — the `−2 s·qᵀx` term in
//! Eq. 17 is restored and true matches in *every* norm range hash close
//! to their queries again. That lets the banded index run a **more
//! selective K at equal recall@k**, which is where the measured win
//! lives: candidate sets (and the rerank bill, our dominant per-query
//! cost) shrink by large factors at matched recall — see
//! `tests/banded_equivalence.rs` and the banded-vs-flat section of
//! `BENCH_query.json`. Each band feeds the ordinary sharded streaming
//! build ([`super::build`]) with its own fill closure, producing B
//! independent frozen-CSR table sets.
//!
//! # The shared-query-codes trick
//!
//! The query transform — `Q(q) = [q/‖q‖; ½; …; ½]` (Eq. 13) for
//! L2-ALSH, `[q/‖q‖; 0; …]` for the SRP schemes — does **not** depend
//! on the data-side scale, and all bands share one fused family set
//! ([`crate::index::SchemeHasher`], same seed-derived projections as
//! the flat index). So a query is Q-transformed and hashed **once** —
//! one fused matvec for all `L·K` codes — and the same code block is
//! replayed
//! against every band's CSR tables. Per-band postings are band-local ids;
//! they are translated to global ids through the band's sorted id map as
//! they stream into the **shared** stamp-dedup scratch, and one global
//! exact rerank (the same blocked/SIMD kernel as the flat index,
//! [`super::rerank`]) produces the top-k. Query cost is therefore
//! `1× hash + B× probe + 1× rerank` — and the probes touch *smaller*
//! buckets, so the rerank pool (the dominant per-query cost) shrinks.
//!
//! # Equivalences
//!
//! With `B = 1` the single band contains every item in ascending id order
//! and its fitted scale equals the flat scale bitwise, so the band's
//! tables — and every candidate stream and top-k across the plain,
//! code-fed, and multi-probe paths — are **byte-identical** to the flat
//! [`super::AlshIndex`] (property-tested in `tests/banded_equivalence.rs`).
//! With any B, the top band's scale also equals the flat scale (it
//! contains the global max norm), so top-band retrieval is exactly the
//! flat retrieval restricted to that band — which is why banded recall on
//! large-norm winners can only match or beat flat recall at equal L·K.
//!
//! # Build memory
//!
//! B bands multiply the number of table sets (B·L) but each band holds
//! only its slice of the items, so total hash work stays ~n·L·K. Bands
//! build in parallel by default; because every concurrent
//! `build_tables` call holds its transient postings runs until its merge,
//! [`BuildOpts::max_shard_bytes`] bounds the *concurrent* run bytes —
//! bands are greedily grouped under the cap and the groups run in
//! sequence (see [`BandedBuildStats::peak_concurrent_run_bytes`]).

use crate::util::Rng;

use super::budget::ProbeBudget;
use super::build::{build_tables, run_bytes_estimate, BuildOpts, BuildStats};
use super::core::{run_query_batch, AlshParams, ScoredItem};
use super::frozen::{FrozenTable, TableStats};
use super::scheme::{MipsHashScheme, SchemeFamilies, SchemeHasher};
use super::scratch::{with_thread_scratch, DedupSink, QueryScratch};
use super::storage::{Owned, Storage};
use crate::lsh::L2LshFamily;
use crate::transform::{l2_norm, UScale};

/// Parameters of the norm-range partition.
#[derive(Clone, Copy, Debug)]
pub struct BandedParams {
    /// Number of norm bands B (equal-count split over sorted norms).
    /// Clamped to `[1, n_items]` at build time; `B = 1` reproduces the
    /// flat index byte-for-byte.
    pub n_bands: usize,
}

impl Default for BandedParams {
    fn default() -> Self {
        // 4 bands captures most of the candidate-set win on skewed-norm
        // corpora (see BENCH_query.json) while keeping B× table-set
        // metadata negligible.
        Self { n_bands: 4 }
    }
}

/// Build observability for a banded build (per band + concurrency).
#[derive(Clone, Debug, Default)]
pub struct BandedBuildStats {
    /// Bands actually built (B after clamping).
    pub n_bands: usize,
    /// Per-band pipeline stats, band 0 (smallest norms) first.
    pub per_band: Vec<BuildStats>,
    /// Largest estimated transient postings-run bytes held by any set of
    /// concurrently-built bands (a group is further split into waves of
    /// at most `n_threads` bands) — what
    /// [`BuildOpts::max_shard_bytes`] caps.
    pub peak_concurrent_run_bytes: usize,
    /// Sequential band groups the memory cap forced (1 = fully parallel).
    pub n_groups: usize,
}

/// One norm band: its id slice, per-band scale, and frozen tables.
/// Generic over [`Storage`] like everything downstream of the build: the
/// id map and the tables are mapped views under `Band<Mapped>`.
pub struct Band<S: Storage = Owned> {
    /// Eq. 11 scale fitted to *this band's* max norm.
    pub(crate) scale: UScale,
    /// Smallest item norm in the band (diagnostics / persistence).
    pub(crate) min_norm: f32,
    /// Largest item norm in the band (= `scale.max_norm`).
    pub(crate) max_norm: f32,
    /// Global ids of the band's items, strictly ascending. Table postings
    /// are indices into this map (band-local ids).
    pub(crate) ids: S::U32s,
    /// The band's L frozen CSR tables over band-local ids.
    pub(crate) tables: Vec<FrozenTable<S>>,
}

impl<S: Storage> Band<S> {
    /// Items in the band.
    pub fn n_items(&self) -> usize {
        self.ids.len()
    }

    /// The band's fitted Eq. 11 scale.
    pub fn scale(&self) -> &UScale {
        &self.scale
    }

    /// `(min, max)` item norm in the band.
    pub fn norm_range(&self) -> (f32, f32) {
        (self.min_norm, self.max_norm)
    }

    /// Global ids of the band's items, ascending (postings map).
    pub fn ids(&self) -> &[u32] {
        &self.ids
    }

    /// The band's frozen CSR tables (persistence / diagnostics).
    pub fn tables(&self) -> &[FrozenTable<S>] {
        &self.tables
    }

    /// Aggregate table statistics for this band.
    pub fn table_stats(&self) -> TableStats {
        TableStats::from_tables(&self.tables)
    }
}

/// Norm-range partitioned ALSH index: B bands with per-band U scaling,
/// one shared hash family set, global exact rerank. See the module docs
/// for the math and the shared-query-codes design.
pub struct NormRangeIndex<S: Storage = Owned> {
    params: AlshParams,
    banded: BandedParams,
    /// One K-wide family per table — the *same* sampling as the flat
    /// index at equal seed and scheme (retained for persistence and
    /// code-fed paths), stored per scheme.
    families: SchemeFamilies,
    /// The families stacked into one `[L·K × D']` matrix, shared by
    /// every band.
    fused: SchemeHasher,
    /// Bands in ascending-norm order.
    bands: Vec<Band<S>>,
    /// Original (unscaled) item vectors, row-major by *global* id — the
    /// global rerank pool.
    items_flat: S::F32s,
    dim: usize,
    n_items: usize,
}

impl NormRangeIndex {
    /// Build over `items` with the default pipeline options.
    pub fn build(
        items: &[Vec<f32>],
        params: AlshParams,
        banded: BandedParams,
        seed: u64,
    ) -> Self {
        Self::build_with(items, params, banded, seed, BuildOpts::default()).0
    }

    /// [`NormRangeIndex::build`] with explicit pipeline options. The
    /// built index is byte-identical for every `opts` choice (each band
    /// goes through the thread/block-invariant [`super::build`] pipeline;
    /// band grouping only changes *when* bands build, never what they
    /// contain).
    pub fn build_with(
        items: &[Vec<f32>],
        params: AlshParams,
        banded: BandedParams,
        seed: u64,
        opts: BuildOpts,
    ) -> (Self, BandedBuildStats) {
        assert!(!items.is_empty(), "empty item collection");
        let dim = items[0].len();
        assert!(items.iter().all(|v| v.len() == dim), "ragged item dims");
        let n = items.len();
        let b = banded.n_bands.max(1).min(n);

        // Same family sampling as the flat index at equal seed and
        // scheme: the query-side codes are interchangeable between the
        // two.
        let scheme = params.scheme;
        let mut rng = Rng::seed_from_u64(seed);
        let families = scheme.sample_families(
            dim + scheme.append_len(params.m),
            params.k_per_table,
            params.n_tables,
            params.r,
            &mut rng,
        );
        let fused = families.fuse();

        // Equal-count split over sorted norms; ties broken by id so the
        // partition is deterministic. Within each band, ids are restored
        // to ascending order so every bucket's postings stream out
        // id-ascending exactly as the flat build's do.
        let norms: Vec<f32> = items.iter().map(|v| l2_norm(v)).collect();
        let mut order: Vec<u32> = (0..n as u32).collect();
        order.sort_unstable_by(|&a, &b| {
            norms[a as usize]
                .partial_cmp(&norms[b as usize])
                .unwrap()
                .then(a.cmp(&b))
        });
        let mut band_ids: Vec<Vec<u32>> = Vec::with_capacity(b);
        for band_idx in 0..b {
            let lo = band_idx * n / b;
            let hi = (band_idx + 1) * n / b;
            let mut ids = order[lo..hi].to_vec();
            ids.sort_unstable();
            band_ids.push(ids);
        }

        // Greedy band grouping under the concurrent-run-memory cap: a
        // group's bands build in parallel; groups run in sequence.
        let cap = opts.max_shard_bytes.unwrap_or(usize::MAX).max(1);
        let mut groups: Vec<Vec<usize>> = Vec::new();
        let mut cur: Vec<usize> = Vec::new();
        let mut cur_bytes = 0usize;
        for (band_idx, ids) in band_ids.iter().enumerate() {
            let est = run_bytes_estimate(ids.len(), params.n_tables);
            if !cur.is_empty() && cur_bytes.saturating_add(est) > cap {
                groups.push(std::mem::take(&mut cur));
                cur_bytes = 0;
            }
            cur.push(band_idx);
            cur_bytes += est;
        }
        if !cur.is_empty() {
            groups.push(cur);
        }

        // Per-band build: each band runs the ordinary sharded pipeline
        // with its own scale in the fill closure. Each memory group runs
        // in waves of at most `total_threads` concurrent bands (so
        // `BuildOpts::single_threaded()` really is sequential), and the
        // worker threads are split across a wave's bands so a wave never
        // oversubscribes.
        let hw = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
        let total_threads = opts.n_threads.unwrap_or(hw).max(1);
        // Per-band scale from the norms already computed for the split —
        // the same `u / max` rule as `UScale::fit`, without re-scanning
        // the corpus (max over a band's precomputed norms is bitwise
        // equal to `fit`'s fold, which is what the B=1 flat byte-identity
        // rests on).
        assert!(params.u > 0.0 && params.u < 1.0, "U must be in (0,1), got {}", params.u);
        let band_minmax: Vec<(f32, f32)> = band_ids
            .iter()
            .map(|ids| {
                let mut min_norm = f32::MAX;
                let mut max_norm = 0.0f32;
                for &id in ids {
                    let nv = norms[id as usize];
                    min_norm = min_norm.min(nv);
                    max_norm = max_norm.max(nv);
                }
                (min_norm, max_norm)
            })
            .collect();
        let scales: Vec<UScale> = band_minmax
            .iter()
            .map(|&(_, max_norm)| UScale {
                u: params.u,
                factor: if max_norm > 0.0 { params.u / max_norm } else { 1.0 },
                max_norm,
            })
            .collect();
        let m = params.m;
        let build_band = |band_idx: usize, band_opts: &BuildOpts| {
            let ids = &band_ids[band_idx];
            let factor = scales[band_idx].factor;
            build_tables(ids.len(), &fused, band_opts, |local, row| {
                scheme.data_row_into(&items[ids[local] as usize], factor, m, row)
            })
        };
        let mut built: Vec<Option<(Vec<FrozenTable>, BuildStats)>> =
            (0..b).map(|_| None).collect();
        let mut peak_concurrent_run_bytes = 0usize;
        for group in &groups {
            let concurrency = group.len().min(total_threads);
            let band_opts = BuildOpts {
                n_threads: Some((total_threads / concurrency).max(1)),
                ..opts
            };
            for wave in group.chunks(concurrency) {
                let wave_bytes: usize = wave
                    .iter()
                    .map(|&i| run_bytes_estimate(band_ids[i].len(), params.n_tables))
                    .sum();
                peak_concurrent_run_bytes = peak_concurrent_run_bytes.max(wave_bytes);
                if wave.len() == 1 {
                    built[wave[0]] = Some(build_band(wave[0], &band_opts));
                } else {
                    let build_ref = &build_band;
                    let mut results: Vec<(usize, (Vec<FrozenTable>, BuildStats))> =
                        Vec::with_capacity(wave.len());
                    std::thread::scope(|sc| {
                        let handles: Vec<_> = wave
                            .iter()
                            .map(|&i| {
                                let opts_i = band_opts;
                                sc.spawn(move || (i, build_ref(i, &opts_i)))
                            })
                            .collect();
                        for h in handles {
                            results.push(h.join().expect("band build worker panicked"));
                        }
                    });
                    for (i, r) in results {
                        built[i] = Some(r);
                    }
                }
            }
        }

        let mut bands: Vec<Band> = Vec::with_capacity(b);
        let mut per_band: Vec<BuildStats> = Vec::with_capacity(b);
        for (band_idx, (ids, scale)) in band_ids.into_iter().zip(scales).enumerate() {
            let (tables, stats) = built[band_idx].take().expect("band not built");
            per_band.push(stats);
            let (min_norm, max_norm) = band_minmax[band_idx];
            bands.push(Band { scale, min_norm, max_norm, ids, tables });
        }

        let mut items_flat = Vec::with_capacity(n * dim);
        for item in items {
            items_flat.extend_from_slice(item);
        }
        let index = Self {
            params,
            banded: BandedParams { n_bands: b },
            families,
            fused,
            bands,
            items_flat,
            dim,
            n_items: n,
        };
        let stats = BandedBuildStats {
            n_bands: b,
            per_band,
            peak_concurrent_run_bytes,
            n_groups: groups.len(),
        };
        (index, stats)
    }

    /// Reassemble from persisted parts (see `index::persist`), validating
    /// the band partition invariants **in full** — the streaming (heap)
    /// load path, where the O(n_items) scan is already dwarfed by the
    /// copy. The mapped open uses [`NormRangeIndex::from_parts_shallow`].
    pub(crate) fn from_parts(
        params: AlshParams,
        banded: BandedParams,
        families: SchemeFamilies,
        bands: Vec<Band>,
        items_flat: Vec<f32>,
        dim: usize,
        n_items: usize,
    ) -> anyhow::Result<Self> {
        let mut seen = vec![false; n_items];
        for band in &bands {
            anyhow::ensure!(
                band.ids.windows(2).all(|w| w[0] < w[1]),
                "corrupt index file: band ids not strictly ascending"
            );
            for &id in band.ids.iter() {
                let slot = seen
                    .get_mut(id as usize)
                    .ok_or_else(|| anyhow::anyhow!("corrupt index file: band id out of range"))?;
                anyhow::ensure!(!*slot, "corrupt index file: item id in two bands");
                *slot = true;
            }
        }
        anyhow::ensure!(
            seen.iter().all(|&v| v),
            "corrupt index file: bands do not cover every item"
        );
        Self::from_parts_shallow(params, banded, families, bands, items_flat, dim, n_items)
    }
}

impl<S: Storage> NormRangeIndex<S> {
    /// Assemble from parts with **shape checks only** (band/table/family
    /// counts, item-matrix size) — the `open_mmap` constructor, which
    /// must stay O(header): no band-coverage scan, no O(n_items)
    /// allocation, no postings page ever touched. Deep corruption inside
    /// the mapped arrays surfaces as a safe probe miss or index panic,
    /// never UB.
    pub(crate) fn from_parts_shallow(
        params: AlshParams,
        banded: BandedParams,
        families: SchemeFamilies,
        bands: Vec<Band<S>>,
        items_flat: S::F32s,
        dim: usize,
        n_items: usize,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(families.len() == params.n_tables, "family count mismatch");
        anyhow::ensure!(bands.len() == banded.n_bands, "band count mismatch");
        anyhow::ensure!(items_flat.len() == dim * n_items, "items_flat size mismatch");
        let mut total = 0usize;
        for band in &bands {
            anyhow::ensure!(
                band.tables.len() == params.n_tables,
                "corrupt index file: band table count mismatch"
            );
            total += band.ids.len();
        }
        anyhow::ensure!(
            total == n_items,
            "corrupt index file: band sizes sum to {total}, expected {n_items}"
        );
        let fused = families.fuse();
        Ok(Self { params, banded, families, fused, bands, items_flat, dim, n_items })
    }

    pub fn params(&self) -> &AlshParams {
        &self.params
    }

    pub fn banded_params(&self) -> &BandedParams {
        &self.banded
    }

    /// Number of norm bands B.
    pub fn n_bands(&self) -> usize {
        self.bands.len()
    }

    pub fn n_items(&self) -> usize {
        self.n_items
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The scheme this index was built with.
    pub fn scheme(&self) -> MipsHashScheme {
        self.params.scheme
    }

    /// The shared L2LSH hash families (code-fed reference paths).
    /// **Panics** for SRP-scheme indexes — use
    /// [`NormRangeIndex::scheme_families`].
    pub fn families(&self) -> &[L2LshFamily] {
        self.families.as_l2().expect(
            "families(): this index runs an SRP scheme (sign-alsh / simple-lsh); \
             use scheme_families() for scheme-generic access",
        )
    }

    /// The shared hash families, per scheme (persistence, diagnostics).
    pub fn scheme_families(&self) -> &SchemeFamilies {
        &self.families
    }

    /// The shared fused multi-table hasher.
    pub fn hasher(&self) -> &SchemeHasher {
        &self.fused
    }

    /// The bands, ascending-norm order.
    pub fn bands(&self) -> &[Band<S>] {
        &self.bands
    }

    /// Item vector by global id.
    pub fn item(&self, id: u32) -> &[f32] {
        let i = id as usize;
        let flat: &[f32] = &self.items_flat;
        &flat[i * self.dim..(i + 1) * self.dim]
    }

    /// The row-major `[n_items × dim]` item matrix (persistence).
    pub(crate) fn items_flat(&self) -> &[f32] {
        &self.items_flat
    }

    /// Aggregate table statistics across every band.
    pub fn table_stats(&self) -> TableStats {
        self.bands
            .iter()
            .map(|b| b.table_stats())
            .fold(TableStats::default(), TableStats::merge)
    }

    /// Per-band aggregate table statistics, band 0 (smallest norms) first.
    pub fn band_table_stats(&self) -> Vec<TableStats> {
        self.bands.iter().map(|b| b.table_stats()).collect()
    }

    /// A scratch pre-sized for this index (same shape rules as
    /// [`super::AlshIndex::scratch`]).
    pub fn scratch(&self) -> QueryScratch {
        let mut s = QueryScratch::new();
        s.reserve(
            self.n_items,
            self.fused.n_codes(),
            self.dim + self.params.scheme.append_len(self.params.m),
        );
        s
    }

    /// The one banded probe loop: replay one `[L·K]` code row against
    /// every band's tables, translating band-local postings to global ids
    /// into the shared dedup sink. Band-major so each band's tables
    /// stream contiguously; with B = 1 this is exactly the flat probe
    /// order. When `counts` is given, the per-band deduplicated candidate
    /// counts are appended (bands are disjoint in global id space, so the
    /// attribution is exact). Every code-driven probe path — plain,
    /// code-fed, batch, per-band counting — goes through here.
    fn replay_codes(
        &self,
        sink: &mut DedupSink<'_>,
        codes: &[i32],
        mut counts: Option<&mut Vec<usize>>,
    ) {
        let k = self.params.k_per_table;
        let scheme = self.params.scheme;
        for band in &self.bands {
            let before = sink.len();
            for (t, table) in band.tables.iter().enumerate() {
                sink.extend_mapped(
                    table.get_by_key(scheme.table_key(&codes[t * k..(t + 1) * k])),
                    &band.ids,
                );
            }
            if let Some(c) = counts.as_deref_mut() {
                c.push(sink.len() - before);
            }
        }
    }

    /// Probe every band with the codes in `s.codes` (see
    /// [`Self::replay_codes`]).
    fn probe_scratch_codes(&self, s: &mut QueryScratch) {
        let (mut sink, codes, _, _) = s.dedup(self.n_items);
        self.replay_codes(&mut sink, codes, None);
    }

    /// Budgeted base-probe replay. At [`ProbeBudget::full`] this walks
    /// bands ascending, all tables — bit-identical to
    /// [`Self::replay_codes`]. A partial `max_bands` budget instead walks
    /// descending from the **largest-norm** band (under MIPS the winners
    /// concentrate there, so those bands buy the most recall per probe);
    /// `max_tables` takes each band's first `nt` tables and `max_rerank`
    /// stops probing between bands once the pool is full.
    fn replay_codes_budgeted(&self, sink: &mut DedupSink<'_>, codes: &[i32], budget: ProbeBudget) {
        let k = self.params.k_per_table;
        let scheme = self.params.scheme;
        let nb = self.bands.len();
        let b_used = budget.bands(nb);
        let nt = budget.tables(self.params.n_tables);
        let cap = budget.max_rerank;
        for j in 0..b_used {
            let band = &self.bands[if b_used == nb { j } else { nb - 1 - j }];
            for (t, table) in band.tables.iter().take(nt).enumerate() {
                sink.extend_mapped(
                    table.get_by_key(scheme.table_key(&codes[t * k..(t + 1) * k])),
                    &band.ids,
                );
            }
            if sink.len() >= cap {
                break;
            }
        }
    }

    /// Budgeted multi-probe replay: the shared probe-key enumeration per
    /// table (see [`super::multiprobe::for_each_probe_key`]), each key
    /// replayed against the budgeted band set. At full budget the visit
    /// order — table-outer, bands ascending per key — is bit-identical to
    /// [`Self::candidates_multiprobe_into`]; a partial band budget visits
    /// the largest-norm bands first, as in [`Self::replay_codes_budgeted`].
    fn replay_probes_budgeted(
        &self,
        sink: &mut DedupSink<'_>,
        codes: &mut [i32],
        fracs: &[f32],
        perturbs: &mut Vec<(f32, usize, i32)>,
        budget: ProbeBudget,
    ) {
        let k = self.params.k_per_table;
        let scheme = self.params.scheme;
        let nb = self.bands.len();
        let b_used = budget.bands(nb);
        let nt = budget.tables(self.params.n_tables);
        let cap = budget.max_rerank;
        for t in 0..nt {
            let base = t * k;
            super::multiprobe::for_each_probe_key(
                scheme,
                &mut codes[base..base + k],
                &fracs[base..base + k],
                perturbs,
                budget.n_probes,
                |key| {
                    for j in 0..b_used {
                        let band = &self.bands[if b_used == nb { j } else { nb - 1 - j }];
                        sink.extend_mapped(band.tables[t].get_by_key(key), &band.ids);
                    }
                },
            );
            if sink.len() >= cap {
                break;
            }
        }
    }

    /// Budgeted candidate retrieval — the banded twin of
    /// [`super::AlshIndex::candidates_budgeted_into`]: bit-identical to
    /// the plain paths at [`ProbeBudget::full`] /
    /// [`ProbeBudget::with_probes`], a strict subset under any partial
    /// budget.
    pub fn candidates_budgeted_into<'s>(
        &self,
        query: &[f32],
        budget: ProbeBudget,
        s: &'s mut QueryScratch,
    ) -> &'s [u32] {
        assert_eq!(query.len(), self.dim, "query dim mismatch");
        assert!(budget.n_probes >= 1);
        self.params.scheme.query_into(query, self.params.m, &mut s.qx);
        if budget.n_probes == 1 {
            s.hash_codes(&self.fused);
            let (mut sink, codes, _, _) = s.dedup(self.n_items);
            self.replay_codes_budgeted(&mut sink, codes, budget);
        } else {
            s.hash_codes_with_conf(&self.fused);
            let (mut sink, codes, fracs, perturbs) = s.dedup(self.n_items);
            self.replay_probes_budgeted(&mut sink, codes, fracs, perturbs, budget);
        }
        s.truncate_candidates(budget.max_rerank);
        &s.cands
    }

    /// Budgeted variant of [`Self::candidates_from_codes_into`] (the
    /// degraded batcher re-entry). `n_probes` is ignored — external codes
    /// carry no confidence channel.
    pub fn candidates_from_codes_budgeted_into<'s>(
        &self,
        codes_flat: &[i32],
        budget: ProbeBudget,
        s: &'s mut QueryScratch,
    ) -> &'s [u32] {
        assert_eq!(
            codes_flat.len(),
            self.params.k_per_table * self.params.n_tables
        );
        {
            let (mut sink, _, _, _) = s.dedup(self.n_items);
            self.replay_codes_budgeted(&mut sink, codes_flat, budget);
        }
        s.truncate_candidates(budget.max_rerank);
        &s.cands
    }

    /// Allocation-free candidate retrieval: hash once, replay the codes
    /// against every band, dedup into first-seen global-id order.
    pub fn candidates_into<'s>(&self, query: &[f32], s: &'s mut QueryScratch) -> &'s [u32] {
        assert_eq!(query.len(), self.dim, "query dim mismatch");
        self.params.scheme.query_into(query, self.params.m, &mut s.qx);
        s.hash_codes(&self.fused);
        self.probe_scratch_codes(s);
        &s.cands
    }

    /// Candidate retrieval from externally computed per-table codes (the
    /// batcher/PJRT re-entry; codes arrive as one `[L·K]` row).
    pub fn candidates_from_codes_into<'s>(
        &self,
        codes_flat: &[i32],
        s: &'s mut QueryScratch,
    ) -> &'s [u32] {
        assert_eq!(
            codes_flat.len(),
            self.params.k_per_table * self.params.n_tables
        );
        let (mut sink, _, _, _) = s.dedup(self.n_items);
        self.replay_codes(&mut sink, codes_flat, None);
        &s.cands
    }

    /// Per-band deduplicated candidate counts for one query (bands are
    /// disjoint in global id space, so the per-band attribution is
    /// exact). `counts` is cleared first; the full candidate list is in
    /// `s.candidates()` afterwards, as with [`Self::candidates_into`].
    pub fn band_candidate_counts_into(
        &self,
        query: &[f32],
        s: &mut QueryScratch,
        counts: &mut Vec<usize>,
    ) {
        assert_eq!(query.len(), self.dim, "query dim mismatch");
        self.params.scheme.query_into(query, self.params.m, &mut s.qx);
        s.hash_codes(&self.fused);
        counts.clear();
        let (mut sink, codes, _, _) = s.dedup(self.n_items);
        self.replay_codes(&mut sink, codes, Some(counts));
    }

    /// Allocation-free multi-probe candidate union: the perturbation
    /// ranking is computed **once per table** from the shared query
    /// fractional parts (it is band-independent) and every probed key —
    /// base and perturbed — is replayed against all B bands. With B = 1
    /// the probe order is exactly the flat
    /// [`super::AlshIndex::candidates_multiprobe_into`] order.
    pub fn candidates_multiprobe_into<'s>(
        &self,
        query: &[f32],
        n_probes: usize,
        s: &'s mut QueryScratch,
    ) -> &'s [u32] {
        assert_eq!(query.len(), self.dim, "query dim mismatch");
        assert!(n_probes >= 1);
        let p = self.params;
        p.scheme.query_into(query, p.m, &mut s.qx);
        s.hash_codes_with_conf(&self.fused);
        let (mut sink, codes, fracs, perturbs) = s.dedup(self.n_items);
        for t in 0..p.n_tables {
            let base = t * p.k_per_table;
            // Shared probe-key enumeration (the one ordering, see
            // `super::multiprobe`); each key — base and perturbed —
            // replays against all B bands.
            super::multiprobe::for_each_probe_key(
                p.scheme,
                &mut codes[base..base + p.k_per_table],
                &fracs[base..base + p.k_per_table],
                perturbs,
                n_probes,
                |key| {
                    for band in &self.bands {
                        sink.extend_mapped(band.tables[t].get_by_key(key), &band.ids);
                    }
                },
            );
        }
        &s.cands
    }

    /// Allocation-free global exact rerank of `s.cands` — the same shared
    /// kernel as the flat index ([`super::rerank`]).
    pub fn rerank_into<'s>(
        &self,
        query: &[f32],
        k: usize,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        super::rerank::rerank_into(self.items_flat(), self.dim, query, k, s)
    }

    /// Full allocation-free query: one hash, B band probes, one global
    /// exact rerank.
    pub fn query_into<'s>(
        &self,
        query: &[f32],
        k: usize,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        self.candidates_into(query, s);
        self.rerank_into(query, k, s)
    }

    /// Budgeted probe + global exact rerank — the degraded-serving entry
    /// point. Bit-identical to [`Self::query_into`] at full budget.
    pub fn query_budgeted_into<'s>(
        &self,
        query: &[f32],
        k: usize,
        budget: ProbeBudget,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        self.candidates_budgeted_into(query, budget, s);
        self.rerank_into(query, k, s)
    }

    /// Allocation-free multi-probe query.
    pub fn query_multiprobe_into<'s>(
        &self,
        query: &[f32],
        top_k: usize,
        n_probes: usize,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        self.candidates_multiprobe_into(query, n_probes, s);
        self.rerank_into(query, top_k, s)
    }

    /// Batch query path (offline eval): Q-transform + hash whole chunks
    /// matrix–matrix, then replay each row's codes through the banded
    /// probe — identical results to per-query [`Self::query_into`].
    pub fn query_batch_into(
        &self,
        queries: &[Vec<f32>],
        k: usize,
        s: &mut QueryScratch,
        out: &mut Vec<Vec<ScoredItem>>,
    ) {
        self.query_batch_impl(queries, k, s, out, None)
    }

    /// [`Self::query_batch_into`] that also records each query's
    /// deduplicated candidate count in `counts` (cleared first).
    pub fn query_batch_counts_into(
        &self,
        queries: &[Vec<f32>],
        k: usize,
        s: &mut QueryScratch,
        out: &mut Vec<Vec<ScoredItem>>,
        counts: &mut Vec<usize>,
    ) {
        self.query_batch_impl(queries, k, s, out, Some(counts))
    }

    fn query_batch_impl(
        &self,
        queries: &[Vec<f32>],
        k: usize,
        s: &mut QueryScratch,
        out: &mut Vec<Vec<ScoredItem>>,
        counts: Option<&mut Vec<usize>>,
    ) {
        run_query_batch(
            &self.fused,
            self.params.scheme,
            self.params.m,
            self.dim,
            self.items_flat(),
            queries,
            k,
            s,
            out,
            counts,
            |s| self.probe_scratch_codes(s),
        )
    }

    // ---- allocating convenience wrappers (thread-local scratch) ----------

    /// See [`Self::candidates_into`].
    pub fn candidates(&self, query: &[f32]) -> Vec<u32> {
        with_thread_scratch(|s| self.candidates_into(query, s).to_vec())
    }

    /// See [`Self::candidates_from_codes_into`].
    pub fn candidates_from_codes(&self, codes_flat: &[i32]) -> Vec<u32> {
        with_thread_scratch(|s| self.candidates_from_codes_into(codes_flat, s).to_vec())
    }

    /// See [`Self::candidates_multiprobe_into`].
    pub fn candidates_multiprobe(&self, query: &[f32], n_probes: usize) -> Vec<u32> {
        with_thread_scratch(|s| self.candidates_multiprobe_into(query, n_probes, s).to_vec())
    }

    /// See [`Self::query_into`].
    pub fn query(&self, query: &[f32], k: usize) -> Vec<ScoredItem> {
        with_thread_scratch(|s| self.query_into(query, k, s).to_vec())
    }

    /// See [`Self::query_budgeted_into`].
    pub fn query_budgeted(&self, query: &[f32], k: usize, budget: ProbeBudget) -> Vec<ScoredItem> {
        with_thread_scratch(|s| self.query_budgeted_into(query, k, budget, s).to_vec())
    }

    /// See [`Self::query_multiprobe_into`].
    pub fn query_multiprobe(
        &self,
        query: &[f32],
        top_k: usize,
        n_probes: usize,
    ) -> Vec<ScoredItem> {
        with_thread_scratch(|s| self.query_multiprobe_into(query, top_k, n_probes, s).to_vec())
    }

    /// Allocating convenience over [`Self::query_batch_into`].
    pub fn query_batch(&self, queries: &[Vec<f32>], k: usize) -> Vec<Vec<ScoredItem>> {
        let mut out = Vec::with_capacity(queries.len());
        with_thread_scratch(|s| self.query_batch_into(queries, k, s, &mut out));
        out
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transform::dot;

    /// Heavily skewed norms: most items tiny, a few large — the regime
    /// norm-range banding exists for.
    fn skewed_items(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n)
            .map(|_| {
                let target = if rng.f32() < 0.8 {
                    0.05 + 0.25 * rng.f32()
                } else {
                    1.0 + rng.f32()
                };
                let mut v: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
                let norm = l2_norm(&v).max(1e-9);
                v.iter_mut().for_each(|x| *x *= target / norm);
                v
            })
            .collect()
    }

    #[test]
    fn bands_partition_items_with_ascending_norm_ranges() {
        let items = skewed_items(500, 8, 1);
        let idx = NormRangeIndex::build(
            &items,
            AlshParams::default(),
            BandedParams { n_bands: 4 },
            2,
        );
        assert_eq!(idx.n_bands(), 4);
        let mut all: Vec<u32> = Vec::new();
        for band in idx.bands() {
            assert!(band.n_items() > 0);
            assert!(band.ids().windows(2).all(|w| w[0] < w[1]));
            all.extend_from_slice(band.ids());
            // Per-band postings = band items × L.
            assert_eq!(
                band.table_stats().n_postings,
                band.n_items() * idx.params().n_tables
            );
        }
        all.sort_unstable();
        assert_eq!(all, (0..500).collect::<Vec<u32>>());
        // Equal-count split: bands differ by at most one item.
        let sizes: Vec<usize> = idx.bands().iter().map(Band::n_items).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
        // Ascending norm ranges, and each band's scale is fit to its max.
        for w in idx.bands().windows(2) {
            assert!(w[0].max_norm <= w[1].min_norm + 1e-6);
        }
        for band in idx.bands() {
            assert_eq!(band.scale().max_norm, band.max_norm);
        }
        // Aggregate stats sum the bands.
        assert_eq!(idx.table_stats().n_postings, 500 * idx.params().n_tables);
    }

    #[test]
    fn query_returns_sorted_exact_scores() {
        let items = skewed_items(400, 10, 3);
        let idx = NormRangeIndex::build(
            &items,
            AlshParams::default(),
            BandedParams { n_bands: 4 },
            4,
        );
        let mut rng = Rng::seed_from_u64(5);
        for _ in 0..10 {
            let q: Vec<f32> = (0..10).map(|_| rng.normal_f32()).collect();
            let top = idx.query(&q, 10);
            for w in top.windows(2) {
                assert!(w[0].score >= w[1].score);
            }
            for h in &top {
                let want = dot(&q, &items[h.id as usize]);
                assert!((h.score - want).abs() < 1e-6, "scores must be exact");
            }
        }
    }

    #[test]
    fn scratch_paths_equal_convenience_paths() {
        let items = skewed_items(300, 8, 6);
        let idx = NormRangeIndex::build(
            &items,
            AlshParams::default(),
            BandedParams { n_bands: 3 },
            7,
        );
        let mut s = idx.scratch();
        let mut rng = Rng::seed_from_u64(8);
        for _ in 0..10 {
            let q: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
            assert_eq!(idx.candidates_into(&q, &mut s).to_vec(), idx.candidates(&q));
            assert_eq!(idx.query_into(&q, 5, &mut s).to_vec(), idx.query(&q, 5));
            for probes in [1usize, 3] {
                assert_eq!(
                    idx.candidates_multiprobe_into(&q, probes, &mut s).to_vec(),
                    idx.candidates_multiprobe(&q, probes)
                );
                assert_eq!(
                    idx.query_multiprobe_into(&q, 5, probes, &mut s).to_vec(),
                    idx.query_multiprobe(&q, 5, probes)
                );
            }
        }
    }

    #[test]
    fn band_counts_sum_to_candidate_total() {
        let items = skewed_items(600, 8, 9);
        let idx = NormRangeIndex::build(
            &items,
            AlshParams::default(),
            BandedParams { n_bands: 4 },
            10,
        );
        let mut s = idx.scratch();
        let mut counts = Vec::new();
        let mut rng = Rng::seed_from_u64(11);
        for _ in 0..10 {
            let q: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
            idx.band_candidate_counts_into(&q, &mut s, &mut counts);
            assert_eq!(counts.len(), 4);
            let total: usize = counts.iter().sum();
            assert_eq!(total, s.candidates().len());
            assert_eq!(total, idx.candidates(&q).len());
        }
    }

    #[test]
    fn code_fed_path_matches_inline_hashing() {
        let items = skewed_items(200, 8, 12);
        let idx = NormRangeIndex::build(
            &items,
            AlshParams::default(),
            BandedParams { n_bands: 4 },
            13,
        );
        let q: Vec<f32> = (0..8).map(|i| (i as f32).cos()).collect();
        let qx = crate::transform::q_transform(&q, idx.params().m);
        let mut flat = Vec::new();
        for fam in idx.families() {
            fam.hash_into(&qx, &mut flat);
        }
        assert_eq!(idx.candidates_from_codes(&flat), idx.candidates(&q));
    }

    #[test]
    fn query_batch_matches_per_query_path() {
        let items = skewed_items(400, 10, 14);
        let idx = NormRangeIndex::build(
            &items,
            AlshParams::default(),
            BandedParams { n_bands: 4 },
            15,
        );
        let mut rng = Rng::seed_from_u64(16);
        let queries: Vec<Vec<f32>> =
            (0..13).map(|_| (0..10).map(|_| rng.normal_f32()).collect()).collect();
        let batch = idx.query_batch(&queries, 10);
        assert_eq!(batch.len(), queries.len());
        for (q, top) in queries.iter().zip(&batch) {
            assert_eq!(top, &idx.query(q, 10));
        }
        let mut s = idx.scratch();
        let mut out = Vec::new();
        let mut counts = Vec::new();
        idx.query_batch_counts_into(&queries, 10, &mut s, &mut out, &mut counts);
        assert_eq!(out, batch);
        assert_eq!(counts.len(), queries.len());
        for (q, &c) in queries.iter().zip(&counts) {
            assert_eq!(c, idx.candidates(q).len());
        }
        idx.query_batch_into(&[], 10, &mut s, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn build_is_thread_and_grouping_invariant() {
        let items = skewed_items(350, 8, 17);
        let params = AlshParams::default();
        let banded = BandedParams { n_bands: 4 };
        let (base, base_stats) = NormRangeIndex::build_with(
            &items,
            params,
            banded,
            18,
            BuildOpts::single_threaded(),
        );
        assert_eq!(base_stats.n_bands, 4);
        assert_eq!(base_stats.per_band.len(), 4);
        // A tiny memory cap forces one band per group; tables must be
        // byte-identical anyway.
        let capped_opts = BuildOpts {
            n_threads: Some(4),
            block: 13,
            max_shard_bytes: Some(1),
        };
        let (capped, capped_stats) =
            NormRangeIndex::build_with(&items, params, banded, 18, capped_opts);
        assert_eq!(capped_stats.n_groups, 4, "cap of 1 byte must serialize bands");
        assert!(
            capped_stats.peak_concurrent_run_bytes
                <= base_stats.peak_concurrent_run_bytes
        );
        let (parallel, parallel_stats) = NormRangeIndex::build_with(
            &items,
            params,
            banded,
            18,
            BuildOpts { n_threads: Some(8), block: 5, max_shard_bytes: None },
        );
        assert_eq!(parallel_stats.n_groups, 1, "no cap => one parallel group");
        for other in [&capped, &parallel] {
            for (a, b) in base.bands().iter().zip(other.bands()) {
                assert_eq!(a.ids(), b.ids());
                for (ta, tb) in a.tables().iter().zip(b.tables()) {
                    assert_eq!(ta.keys(), tb.keys());
                    assert_eq!(ta.offsets(), tb.offsets());
                    assert_eq!(ta.postings(), tb.postings());
                }
            }
        }
        let q: Vec<f32> = (0..8).map(|i| (i as f32 * 0.4).sin()).collect();
        assert_eq!(base.query(&q, 10), capped.query(&q, 10));
        assert_eq!(base.query(&q, 10), parallel.query(&q, 10));
    }

    #[test]
    fn more_bands_than_items_clamps() {
        let items = skewed_items(3, 4, 20);
        let idx = NormRangeIndex::build(
            &items,
            AlshParams::default(),
            BandedParams { n_bands: 16 },
            21,
        );
        assert_eq!(idx.n_bands(), 3);
        assert_eq!(idx.table_stats().n_postings, 3 * idx.params().n_tables);
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        let items = skewed_items(10, 4, 22);
        let idx = NormRangeIndex::build(
            &items,
            AlshParams::default(),
            BandedParams { n_bands: 2 },
            23,
        );
        let _ = idx.query(&[1.0, 2.0], 1);
    }
}

//! Flat-vs-banded index dispatch: one enum the coordinator (engine,
//! batcher, router, server) and the offline tools serve through, so a
//! deployment picks the flat [`AlshIndex`] or the norm-range partitioned
//! [`NormRangeIndex`] per corpus without the serving stack caring.
//!
//! Enum (not trait-object) dispatch: the query surface borrows out of the
//! caller's [`QueryScratch`] with lifetimes a dyn-safe trait would
//! obscure, the match arms inline, and there are exactly two variants.

use super::banded::NormRangeIndex;
use super::budget::ProbeBudget;
use super::core::{AlshIndex, AlshParams, ScoredItem};
use super::frozen::TableStats;
use super::scheme::{MipsHashScheme, SchemeFamilies, SchemeHasher};
use super::scratch::{with_thread_scratch, QueryScratch};
use super::storage::{Mapped, Owned, Storage};
use crate::lsh::L2LshFamily;

/// A flat or norm-range banded ALSH index behind one serving surface,
/// over heap ([`Owned`], the default) or zero-copy mmap ([`Mapped`])
/// storage.
pub enum AnyIndex<S: Storage = Owned> {
    /// Single table set, one global U scale.
    Flat(AlshIndex<S>),
    /// B norm bands with per-band U scaling, shared hash families.
    Banded(NormRangeIndex<S>),
}

/// An index of either kind served straight out of a v5 index file: open
/// with [`MappedIndex::open_mmap`] (or `index::persist::open_mmap`) and
/// plug it into `MipsEngine::from_any` / the batcher / the router
/// exactly like a heap index — the whole query surface is
/// storage-generic.
pub type MappedIndex = AnyIndex<Mapped>;

impl MappedIndex {
    /// Zero-copy open of a v5 index file (any kind, any scheme) — see
    /// `index::persist::open_mmap`.
    pub fn open_mmap(path: impl AsRef<std::path::Path>) -> crate::Result<Self> {
        super::persist::open_mmap(path)
    }
}

impl<S: Storage> From<AlshIndex<S>> for AnyIndex<S> {
    fn from(index: AlshIndex<S>) -> Self {
        AnyIndex::Flat(index)
    }
}

impl<S: Storage> From<NormRangeIndex<S>> for AnyIndex<S> {
    fn from(index: NormRangeIndex<S>) -> Self {
        AnyIndex::Banded(index)
    }
}

impl<S: Storage> AnyIndex<S> {
    /// The flat index, if this is one.
    pub fn as_flat(&self) -> Option<&AlshIndex<S>> {
        match self {
            AnyIndex::Flat(i) => Some(i),
            AnyIndex::Banded(_) => None,
        }
    }

    /// The banded index, if this is one.
    pub fn as_banded(&self) -> Option<&NormRangeIndex<S>> {
        match self {
            AnyIndex::Flat(_) => None,
            AnyIndex::Banded(i) => Some(i),
        }
    }

    pub fn params(&self) -> &AlshParams {
        match self {
            AnyIndex::Flat(i) => i.params(),
            AnyIndex::Banded(i) => i.params(),
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            AnyIndex::Flat(i) => i.dim(),
            AnyIndex::Banded(i) => i.dim(),
        }
    }

    pub fn n_items(&self) -> usize {
        match self {
            AnyIndex::Flat(i) => i.n_items(),
            AnyIndex::Banded(i) => i.n_items(),
        }
    }

    /// The stored vector for internal id `id` (its row of the item
    /// matrix). This is what a replica repair reads from a healthy peer
    /// to rebuild a corrupted member's index.
    pub fn item(&self, id: u32) -> &[f32] {
        match self {
            AnyIndex::Flat(i) => i.item(id),
            AnyIndex::Banded(i) => i.item(id),
        }
    }

    /// Norm bands served (1 for the flat index).
    pub fn n_bands(&self) -> usize {
        match self {
            AnyIndex::Flat(_) => 1,
            AnyIndex::Banded(i) => i.n_bands(),
        }
    }

    /// The scheme the served index was built with.
    pub fn scheme(&self) -> MipsHashScheme {
        self.params().scheme
    }

    /// The shared L2LSH hash families (PJRT artifact inputs, code-fed
    /// paths). **Panics** for SRP-scheme indexes — use
    /// [`AnyIndex::scheme_families`].
    pub fn families(&self) -> &[L2LshFamily] {
        match self {
            AnyIndex::Flat(i) => i.families(),
            AnyIndex::Banded(i) => i.families(),
        }
    }

    /// The shared hash families, per scheme.
    pub fn scheme_families(&self) -> &SchemeFamilies {
        match self {
            AnyIndex::Flat(i) => i.scheme_families(),
            AnyIndex::Banded(i) => i.scheme_families(),
        }
    }

    /// The fused multi-table hasher (batcher fallback, benches).
    pub fn hasher(&self) -> &SchemeHasher {
        match self {
            AnyIndex::Flat(i) => i.hasher(),
            AnyIndex::Banded(i) => i.hasher(),
        }
    }

    /// Aggregate table statistics (summed across bands when banded).
    pub fn table_stats(&self) -> TableStats {
        match self {
            AnyIndex::Flat(i) => i.table_stats(),
            AnyIndex::Banded(i) => i.table_stats(),
        }
    }

    /// A scratch pre-sized for this index.
    pub fn scratch(&self) -> QueryScratch {
        match self {
            AnyIndex::Flat(i) => i.scratch(),
            AnyIndex::Banded(i) => i.scratch(),
        }
    }

    /// Allocation-free candidate retrieval.
    pub fn candidates_into<'s>(&self, query: &[f32], s: &'s mut QueryScratch) -> &'s [u32] {
        match self {
            AnyIndex::Flat(i) => i.candidates_into(query, s),
            AnyIndex::Banded(i) => i.candidates_into(query, s),
        }
    }

    /// Allocation-free candidate retrieval from externally computed
    /// `[L·K]` codes (the batcher/PJRT re-entry).
    pub fn candidates_from_codes_into<'s>(
        &self,
        codes_flat: &[i32],
        s: &'s mut QueryScratch,
    ) -> &'s [u32] {
        match self {
            AnyIndex::Flat(i) => i.candidates_from_codes_into(codes_flat, s),
            AnyIndex::Banded(i) => i.candidates_from_codes_into(codes_flat, s),
        }
    }

    /// Budgeted candidate retrieval (degraded serving; bit-identical to
    /// the plain paths at [`ProbeBudget::full`]).
    pub fn candidates_budgeted_into<'s>(
        &self,
        query: &[f32],
        budget: ProbeBudget,
        s: &'s mut QueryScratch,
    ) -> &'s [u32] {
        match self {
            AnyIndex::Flat(i) => i.candidates_budgeted_into(query, budget, s),
            AnyIndex::Banded(i) => i.candidates_budgeted_into(query, budget, s),
        }
    }

    /// Budgeted variant of [`AnyIndex::candidates_from_codes_into`] (the
    /// degraded batcher re-entry; `n_probes` is ignored — external codes
    /// carry no confidence channel).
    pub fn candidates_from_codes_budgeted_into<'s>(
        &self,
        codes_flat: &[i32],
        budget: ProbeBudget,
        s: &'s mut QueryScratch,
    ) -> &'s [u32] {
        match self {
            AnyIndex::Flat(i) => i.candidates_from_codes_budgeted_into(codes_flat, budget, s),
            AnyIndex::Banded(i) => i.candidates_from_codes_budgeted_into(codes_flat, budget, s),
        }
    }

    /// Allocation-free exact rerank of `s.cands`.
    pub fn rerank_into<'s>(
        &self,
        query: &[f32],
        k: usize,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        match self {
            AnyIndex::Flat(i) => i.rerank_into(query, k, s),
            AnyIndex::Banded(i) => i.rerank_into(query, k, s),
        }
    }

    /// Full allocation-free query: probe + exact rerank.
    pub fn query_into<'s>(
        &self,
        query: &[f32],
        k: usize,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        match self {
            AnyIndex::Flat(i) => i.query_into(query, k, s),
            AnyIndex::Banded(i) => i.query_into(query, k, s),
        }
    }

    /// Budgeted probe + exact rerank (degraded serving; bit-identical to
    /// [`AnyIndex::query_into`] at full budget).
    pub fn query_budgeted_into<'s>(
        &self,
        query: &[f32],
        k: usize,
        budget: ProbeBudget,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        match self {
            AnyIndex::Flat(i) => i.query_budgeted_into(query, k, budget, s),
            AnyIndex::Banded(i) => i.query_budgeted_into(query, k, budget, s),
        }
    }

    /// Allocation-free multi-probe query.
    pub fn query_multiprobe_into<'s>(
        &self,
        query: &[f32],
        top_k: usize,
        n_probes: usize,
        s: &'s mut QueryScratch,
    ) -> &'s [ScoredItem] {
        match self {
            AnyIndex::Flat(i) => i.query_multiprobe_into(query, top_k, n_probes, s),
            AnyIndex::Banded(i) => i.query_multiprobe_into(query, top_k, n_probes, s),
        }
    }

    /// Batch query path for offline evaluation (matrix–matrix hashing).
    pub fn query_batch_into(
        &self,
        queries: &[Vec<f32>],
        k: usize,
        s: &mut QueryScratch,
        out: &mut Vec<Vec<ScoredItem>>,
    ) {
        match self {
            AnyIndex::Flat(i) => i.query_batch_into(queries, k, s, out),
            AnyIndex::Banded(i) => i.query_batch_into(queries, k, s, out),
        }
    }

    /// [`AnyIndex::query_batch_into`] that also records per-query
    /// deduplicated candidate counts.
    pub fn query_batch_counts_into(
        &self,
        queries: &[Vec<f32>],
        k: usize,
        s: &mut QueryScratch,
        out: &mut Vec<Vec<ScoredItem>>,
        counts: &mut Vec<usize>,
    ) {
        match self {
            AnyIndex::Flat(i) => i.query_batch_counts_into(queries, k, s, out, counts),
            AnyIndex::Banded(i) => i.query_batch_counts_into(queries, k, s, out, counts),
        }
    }

    /// Allocating convenience query (thread-local scratch).
    pub fn query(&self, query: &[f32], k: usize) -> Vec<ScoredItem> {
        with_thread_scratch(|s| self.query_into(query, k, s).to_vec())
    }

    /// See [`AnyIndex::query_budgeted_into`].
    pub fn query_budgeted(&self, query: &[f32], k: usize, budget: ProbeBudget) -> Vec<ScoredItem> {
        with_thread_scratch(|s| self.query_budgeted_into(query, k, budget, s).to_vec())
    }

    /// Allocating convenience candidates (thread-local scratch).
    pub fn candidates(&self, query: &[f32]) -> Vec<u32> {
        with_thread_scratch(|s| self.candidates_into(query, s).to_vec())
    }

    /// Serialize to `path` (persist v4 — the streaming container; flat
    /// and banded kinds share the format — see `index::persist`).
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> crate::Result<()> {
        match self {
            AnyIndex::Flat(i) => i.save(path),
            AnyIndex::Banded(i) => i.save(path),
        }
    }

    /// Serialize to `path` in the chosen container format (v4 streaming
    /// or v5 mmap-ready aligned sections — see `index::persist`).
    pub fn save_as(
        &self,
        path: impl AsRef<std::path::Path>,
        format: super::persist::PersistFormat,
    ) -> crate::Result<()> {
        match self {
            AnyIndex::Flat(i) => i.save_as(path, format),
            AnyIndex::Banded(i) => i.save_as(path, format),
        }
    }
}

impl AnyIndex {
    /// Load either kind from `path` into heap storage (any version —
    /// see `index::persist::load_any`).
    pub fn load(path: impl AsRef<std::path::Path>) -> crate::Result<Self> {
        super::persist::load_any(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::banded::BandedParams;
    use crate::util::Rng;

    fn items(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n)
            .map(|_| {
                let s = 0.1 + 1.9 * rng.f32();
                (0..d).map(|_| rng.normal_f32() * s).collect()
            })
            .collect()
    }

    #[test]
    fn dispatch_agrees_with_direct_paths() {
        let its = items(300, 8, 1);
        let flat = AlshIndex::build(&its, AlshParams::default(), 2);
        let banded = NormRangeIndex::build(
            &its,
            AlshParams::default(),
            BandedParams { n_bands: 3 },
            2,
        );
        let any_flat: AnyIndex = AlshIndex::build(&its, AlshParams::default(), 2).into();
        let any_banded: AnyIndex = NormRangeIndex::build(
            &its,
            AlshParams::default(),
            BandedParams { n_bands: 3 },
            2,
        )
        .into();
        assert_eq!(any_flat.n_bands(), 1);
        assert_eq!(any_banded.n_bands(), 3);
        assert!(any_flat.as_flat().is_some() && any_flat.as_banded().is_none());
        assert!(any_banded.as_banded().is_some());
        let mut rng = Rng::seed_from_u64(3);
        let mut s = any_flat.scratch();
        for _ in 0..10 {
            let q: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
            assert_eq!(any_flat.query(&q, 5), flat.query(&q, 5));
            assert_eq!(any_banded.query(&q, 5), banded.query(&q, 5));
            assert_eq!(any_flat.query_into(&q, 5, &mut s).to_vec(), flat.query(&q, 5));
            assert_eq!(
                any_banded.query_into(&q, 5, &mut s).to_vec(),
                banded.query(&q, 5)
            );
            assert_eq!(any_banded.candidates(&q), banded.candidates(&q));
        }
        assert_eq!(any_flat.table_stats(), flat.table_stats());
        assert_eq!(any_banded.table_stats(), banded.table_stats());
        // Batch dispatch.
        let queries: Vec<Vec<f32>> =
            (0..7).map(|_| (0..8).map(|_| rng.normal_f32()).collect()).collect();
        let mut out = Vec::new();
        let mut counts = Vec::new();
        any_banded.query_batch_counts_into(&queries, 5, &mut s, &mut out, &mut counts);
        assert_eq!(out, banded.query_batch(&queries, 5));
        assert_eq!(counts.len(), queries.len());
    }
}

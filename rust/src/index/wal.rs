//! Append-only write-ahead log for the live mutable index tier
//! (`index::delta`).
//!
//! # Record format
//!
//! The file starts with the 8-byte magic `b"ALSHWAL1"`. Each record is:
//!
//! ```text
//! len      u32 LE   payload length in bytes
//! checksum u64 LE   XXH64(payload, seed = WAL_SEED)
//! payload  [u8]     kind u8 | ext_id u32 LE | (upsert only:) dim u32 LE | dim * f32 LE
//! ```
//!
//! `kind` is 1 for upsert, 2 for delete. Every append is `write_all` +
//! `sync_data` **before** the mutation is applied to the in-memory
//! tier, so a record's presence in the file is a durable promise that
//! the mutation survives a crash.
//!
//! # Torn-tail recovery
//!
//! [`Wal::open`] replays records from the start and stops at the first
//! one that is incomplete or fails its checksum — the expected artifact
//! of a crash mid-append — then truncates the file back to the last
//! good record so subsequent appends extend a clean prefix. A record
//! whose checksum verifies but whose payload is malformed is *not* a
//! torn tail (XXH64 makes that astronomically unlikely by accident);
//! it is reported as a hard corruption error instead of being silently
//! dropped.
//!
//! Replay is idempotent: an upsert sets the vector for `ext_id`
//! (replacing any earlier value) and a delete tombstones it, so
//! replaying a prefix twice reaches the same state as replaying it
//! once.

use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::util::xxh64;
use crate::Result;
use anyhow::{bail, Context};

/// 8-byte file magic (includes the format version).
pub const WAL_MAGIC: &[u8; 8] = b"ALSHWAL1";
/// Seed for the per-record XXH64 checksum.
pub const WAL_SEED: u64 = 0xA15B_0007;
/// Per-record header: len u32 + checksum u64.
pub const WAL_HEADER: usize = 12;
/// Sanity cap on a single record's payload (a corrupt length field must
/// not trigger a huge allocation).
const MAX_PAYLOAD: usize = 1 << 30;

const KIND_UPSERT: u8 = 1;
const KIND_DELETE: u8 = 2;

/// One logged mutation.
#[derive(Clone, Debug, PartialEq)]
pub enum WalRecord {
    /// Insert or replace the vector for `ext_id`.
    Upsert { ext_id: u32, vector: Vec<f32> },
    /// Tombstone `ext_id` (a no-op if absent — replay stays idempotent).
    Delete { ext_id: u32 },
}

/// Encode a record to its on-disk bytes (header + payload). Public so
/// fault-injection tests can write deliberately torn prefixes.
pub fn encode(rec: &WalRecord) -> Vec<u8> {
    let mut payload = Vec::new();
    match rec {
        WalRecord::Upsert { ext_id, vector } => {
            payload.push(KIND_UPSERT);
            payload.extend_from_slice(&ext_id.to_le_bytes());
            payload.extend_from_slice(&(vector.len() as u32).to_le_bytes());
            for v in vector {
                payload.extend_from_slice(&v.to_le_bytes());
            }
        }
        WalRecord::Delete { ext_id } => {
            payload.push(KIND_DELETE);
            payload.extend_from_slice(&ext_id.to_le_bytes());
        }
    }
    let mut out = Vec::with_capacity(WAL_HEADER + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&xxh64(&payload, WAL_SEED).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

fn decode_payload(payload: &[u8]) -> Result<WalRecord> {
    let kind = *payload.first().context("wal: empty payload")?;
    match kind {
        KIND_UPSERT => {
            if payload.len() < 9 {
                bail!("wal: upsert payload too short ({} bytes)", payload.len());
            }
            let ext_id = u32::from_le_bytes(payload[1..5].try_into().unwrap());
            let dim = u32::from_le_bytes(payload[5..9].try_into().unwrap()) as usize;
            if payload.len() != 9 + dim * 4 {
                bail!(
                    "wal: upsert payload length {} does not match dim {}",
                    payload.len(),
                    dim
                );
            }
            let vector = payload[9..]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(WalRecord::Upsert { ext_id, vector })
        }
        KIND_DELETE => {
            if payload.len() != 5 {
                bail!("wal: delete payload length {} != 5", payload.len());
            }
            let ext_id = u32::from_le_bytes(payload[1..5].try_into().unwrap());
            Ok(WalRecord::Delete { ext_id })
        }
        k => bail!("wal: unknown record kind {k}"),
    }
}

/// An open WAL file positioned for appends.
pub struct Wal {
    file: File,
    path: PathBuf,
    bytes: u64,
}

impl Wal {
    /// Create a fresh, empty WAL at `path` (truncating any existing
    /// file) and fsync it so the empty log itself is durable.
    pub fn create(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .with_context(|| format!("wal: create {}", path.display()))?;
        file.write_all(WAL_MAGIC)?;
        file.sync_all()?;
        if let Some(parent) = path.parent() {
            if let Ok(dir) = File::open(parent) {
                dir.sync_all().ok();
            }
        }
        Ok(Wal { file, path, bytes: WAL_MAGIC.len() as u64 })
    }

    /// Open an existing WAL, replay every intact record, truncate any
    /// torn tail, and return the log positioned for appends together
    /// with the replayed records.
    pub fn open(path: impl AsRef<Path>) -> Result<(Self, Vec<WalRecord>)> {
        let path = path.as_ref().to_path_buf();
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("wal: open {}", path.display()))?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;
        if bytes.len() < WAL_MAGIC.len() || &bytes[..WAL_MAGIC.len()] != WAL_MAGIC {
            bail!("wal: bad magic in {}", path.display());
        }
        let mut records = Vec::new();
        let mut good = WAL_MAGIC.len();
        let mut pos = good;
        loop {
            let rest = &bytes[pos..];
            if rest.len() < WAL_HEADER {
                break; // torn header (or clean EOF)
            }
            let len = u32::from_le_bytes(rest[..4].try_into().unwrap()) as usize;
            let checksum = u64::from_le_bytes(rest[4..12].try_into().unwrap());
            if len > MAX_PAYLOAD || rest.len() < WAL_HEADER + len {
                break; // torn payload (or absurd length from a torn header)
            }
            let payload = &rest[WAL_HEADER..WAL_HEADER + len];
            if xxh64(payload, WAL_SEED) != checksum {
                break; // torn/corrupt record: recover the prefix before it
            }
            // Checksum holds: a malformed payload here is real corruption,
            // not a crash artifact — surface it rather than dropping data.
            records.push(decode_payload(payload)?);
            pos += WAL_HEADER + len;
            good = pos;
        }
        if good < bytes.len() {
            file.set_len(good as u64)?;
            file.sync_all()?;
        }
        use std::io::Seek;
        file.seek(std::io::SeekFrom::Start(good as u64))?;
        Ok((Wal { file, path, bytes: good as u64 }, records))
    }

    /// Append one record and `sync_data` it. Returns only once the
    /// record is durable; the caller applies the mutation after.
    pub fn append(&mut self, rec: &WalRecord) -> Result<()> {
        self.append_batch(std::slice::from_ref(rec))
    }

    /// Group commit: append every record in `recs` as one contiguous
    /// write followed by a **single** `sync_data`. Durability is
    /// all-or-prefix — a crash mid-write leaves a torn tail that
    /// [`Wal::open`] truncates back to the last intact record, exactly
    /// as for single appends — and the per-record format is unchanged,
    /// so replay cannot tell a batch from the same records appended one
    /// at a time. This is the bulk-upsert fast path: one fsync amortized
    /// over the whole batch instead of one per record.
    pub fn append_batch(&mut self, recs: &[WalRecord]) -> Result<()> {
        if recs.is_empty() {
            return Ok(());
        }
        let mut buf = Vec::new();
        for rec in recs {
            buf.extend_from_slice(&encode(rec));
        }
        self.file
            .write_all(&buf)
            .with_context(|| format!("wal: append to {}", self.path.display()))?;
        self.file.sync_data()?;
        self.bytes += buf.len() as u64;
        Ok(())
    }

    /// Append only the first `keep` bytes of the record's encoding and
    /// sync — a deliberately torn write, for crash-injection tests. The
    /// log is left in the state a mid-append crash would leave it.
    pub fn append_torn(&mut self, rec: &WalRecord, keep: usize) -> Result<()> {
        let buf = encode(rec);
        let keep = keep.min(buf.len());
        self.file.write_all(&buf[..keep])?;
        self.file.sync_data()?;
        self.bytes += keep as u64;
        Ok(())
    }

    /// Total file length in bytes (magic + durable records).
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// The file path.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "alsh_wal_{tag}_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn recs() -> Vec<WalRecord> {
        vec![
            WalRecord::Upsert { ext_id: 7, vector: vec![1.0, -2.5, 0.25] },
            WalRecord::Delete { ext_id: 7 },
            WalRecord::Upsert { ext_id: 9, vector: vec![0.0; 5] },
        ]
    }

    #[test]
    fn roundtrip_and_reopen() {
        let dir = tmp_dir("roundtrip");
        let path = dir.join("wal.log");
        let mut wal = Wal::create(&path).unwrap();
        for r in recs() {
            wal.append(&r).unwrap();
        }
        let n = wal.bytes();
        drop(wal);
        let (mut wal, replayed) = Wal::open(&path).unwrap();
        assert_eq!(replayed, recs());
        assert_eq!(wal.bytes(), n);
        // Appends after reopen extend the log.
        wal.append(&WalRecord::Delete { ext_id: 1 }).unwrap();
        let (_, replayed) = Wal::open(&path).unwrap();
        assert_eq!(replayed.len(), 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_truncated_at_every_cut() {
        let torn = WalRecord::Upsert { ext_id: 42, vector: vec![3.0, 1.0, 4.0, 1.0] };
        let full = encode(&torn).len();
        for keep in 0..full {
            let dir = tmp_dir("torn");
            let path = dir.join("wal.log");
            let mut wal = Wal::create(&path).unwrap();
            for r in recs() {
                wal.append(&r).unwrap();
            }
            let clean = wal.bytes();
            wal.append_torn(&torn, keep).unwrap();
            drop(wal);
            let (wal2, replayed) = Wal::open(&path).unwrap();
            assert_eq!(replayed, recs(), "keep={keep}");
            assert_eq!(wal2.bytes(), clean, "keep={keep}: tail not truncated");
            assert_eq!(
                std::fs::metadata(&path).unwrap().len(),
                clean,
                "keep={keep}: file not truncated on disk"
            );
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn corrupt_payload_with_valid_checksum_is_an_error() {
        let dir = tmp_dir("corrupt");
        let path = dir.join("wal.log");
        let mut wal = Wal::create(&path).unwrap();
        wal.append(&recs()[0]).unwrap();
        drop(wal);
        // Hand-craft a record with a checksum that matches a garbage
        // payload (unknown kind 9): checksum passes, decode must fail.
        let payload = [9u8, 0, 0, 0, 0];
        let mut raw = Vec::new();
        raw.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        raw.extend_from_slice(&xxh64(&payload, WAL_SEED).to_le_bytes());
        raw.extend_from_slice(&payload);
        {
            use std::io::Write;
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(&raw).unwrap();
        }
        assert!(Wal::open(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn flipped_bit_in_middle_record_stops_replay_there() {
        let dir = tmp_dir("flip");
        let path = dir.join("wal.log");
        let mut wal = Wal::create(&path).unwrap();
        for r in recs() {
            wal.append(&r).unwrap();
        }
        drop(wal);
        // Flip a bit inside the second record's payload.
        let first_len = encode(&recs()[0]).len();
        let mut bytes = std::fs::read(&path).unwrap();
        let off = WAL_MAGIC.len() + first_len + WAL_HEADER + 1;
        bytes[off] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let (wal2, replayed) = Wal::open(&path).unwrap();
        assert_eq!(replayed, recs()[..1].to_vec());
        assert_eq!(wal2.bytes(), (WAL_MAGIC.len() + first_len) as u64);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = tmp_dir("magic");
        let path = dir.join("wal.log");
        std::fs::write(&path, b"NOTAWAL!").unwrap();
        assert!(Wal::open(&path).is_err());
        std::fs::write(&path, b"AL").unwrap();
        assert!(Wal::open(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}

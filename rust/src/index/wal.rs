//! Append-only write-ahead log for the live mutable index tier
//! (`index::delta`).
//!
//! # File format (v2)
//!
//! The file starts with a 16-byte header: the 8-byte magic `b"ALSHWAL2"`
//! followed by `base_seq` (u64 LE) — the sequence number the **first**
//! record in this file carries. Records are sequence-numbered implicitly
//! by position: record `i` (0-based) has `seq = base_seq + i`. A fresh
//! index's WAL starts at `base_seq = 1`; compaction rolls to a new WAL
//! whose `base_seq` continues where the drained one ended, so sequence
//! numbers are stable across the whole life of the index and comparable
//! between replicas that applied the same mutation history.
//!
//! Each record is:
//!
//! ```text
//! len      u32 LE   payload length in bytes
//! checksum u64 LE   XXH64(payload, seed = WAL_SEED)
//! payload  [u8]     kind u8 | body
//! ```
//!
//! Bodies by `kind`:
//!
//! * `1` upsert: `ext_id u32 LE | dim u32 LE | dim * f32 LE`
//! * `2` delete: `ext_id u32 LE`
//! * `3` batch:  `count u32 LE | count * (ext_id u32 LE | dim u32 LE | dim * f32 LE)`
//!
//! A batch is **one record with one checksum** covering every entry, and
//! it consumes **one sequence number**. That makes group commit
//! all-or-nothing, not all-or-prefix: a crash anywhere inside the batch
//! write leaves a record that fails its checksum, so recovery sees
//! either the whole batch or none of it — never a partial batch.
//!
//! Every append is `write_all` + `sync_data` **before** the mutation is
//! applied to the in-memory tier, so a record's presence in the file is
//! a durable promise that the mutation survives a crash.
//!
//! # Torn-tail recovery
//!
//! [`Wal::open`] replays records from the start and stops at the first
//! one that is incomplete or fails its checksum — the expected artifact
//! of a crash mid-append — then truncates the file back to the last
//! good record so subsequent appends extend a clean prefix. A record
//! whose checksum verifies but whose payload is malformed is *not* a
//! torn tail (XXH64 makes that astronomically unlikely by accident);
//! it is reported as a hard corruption error instead of being silently
//! dropped.
//!
//! Replay is idempotent: an upsert sets the vector for `ext_id`
//! (replacing any earlier value) and a delete tombstones it, so
//! replaying a prefix twice reaches the same state as replaying it
//! once.
//!
//! # Peer catch-up
//!
//! [`Wal::read_suffix`] is a read-only scan used by a lagging replica to
//! pull the records it missed from an up-to-date peer's WAL: it returns
//! every intact record with `seq >= from_seq`, or `None` when the peer
//! has already compacted past `from_seq` (its `base_seq` is too high),
//! in which case the only way back is a full rebuild from the peer's
//! live item set. It never truncates or mutates the peer's file.

use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::util::xxh64;
use crate::Result;
use anyhow::{bail, Context};

/// 8-byte file magic (includes the format version).
pub const WAL_MAGIC: &[u8; 8] = b"ALSHWAL2";
/// Seed for the per-record XXH64 checksum.
pub const WAL_SEED: u64 = 0xA15B_0007;
/// File header: magic + base_seq u64.
pub const WAL_FILE_HEADER: usize = 16;
/// Per-record header: len u32 + checksum u64.
pub const WAL_HEADER: usize = 12;
/// Sanity cap on a single record's payload (a corrupt length field must
/// not trigger a huge allocation).
const MAX_PAYLOAD: usize = 1 << 30;

const KIND_UPSERT: u8 = 1;
const KIND_DELETE: u8 = 2;
const KIND_BATCH: u8 = 3;

/// One logged mutation. Each variant — including a whole batch —
/// occupies exactly one WAL record and one sequence number.
#[derive(Clone, Debug, PartialEq)]
pub enum WalRecord {
    /// Insert or replace the vector for `ext_id`.
    Upsert { ext_id: u32, vector: Vec<f32> },
    /// Tombstone `ext_id` (a no-op if absent — replay stays idempotent).
    Delete { ext_id: u32 },
    /// A group-committed batch of upserts, durable all-or-nothing.
    Batch { items: Vec<(u32, Vec<f32>)> },
}

fn push_upsert_body(payload: &mut Vec<u8>, ext_id: u32, vector: &[f32]) {
    payload.extend_from_slice(&ext_id.to_le_bytes());
    payload.extend_from_slice(&(vector.len() as u32).to_le_bytes());
    for v in vector {
        payload.extend_from_slice(&v.to_le_bytes());
    }
}

/// Encode a record to its on-disk bytes (header + payload). Public so
/// fault-injection tests can write deliberately torn prefixes.
pub fn encode(rec: &WalRecord) -> Vec<u8> {
    let mut payload = Vec::new();
    match rec {
        WalRecord::Upsert { ext_id, vector } => {
            payload.push(KIND_UPSERT);
            push_upsert_body(&mut payload, *ext_id, vector);
        }
        WalRecord::Delete { ext_id } => {
            payload.push(KIND_DELETE);
            payload.extend_from_slice(&ext_id.to_le_bytes());
        }
        WalRecord::Batch { items } => {
            payload.push(KIND_BATCH);
            payload.extend_from_slice(&(items.len() as u32).to_le_bytes());
            for (ext_id, vector) in items {
                push_upsert_body(&mut payload, *ext_id, vector);
            }
        }
    }
    let mut out = Vec::with_capacity(WAL_HEADER + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&xxh64(&payload, WAL_SEED).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

fn read_upsert_body(body: &[u8]) -> Result<((u32, Vec<f32>), usize)> {
    if body.len() < 8 {
        bail!("wal: upsert body too short ({} bytes)", body.len());
    }
    let ext_id = u32::from_le_bytes(body[..4].try_into().unwrap());
    let dim = u32::from_le_bytes(body[4..8].try_into().unwrap()) as usize;
    let need = 8 + dim * 4;
    if body.len() < need {
        bail!("wal: upsert body length {} < dim {} needs", body.len(), dim);
    }
    let vector = body[8..need]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(((ext_id, vector), need))
}

fn decode_payload(payload: &[u8]) -> Result<WalRecord> {
    let kind = *payload.first().context("wal: empty payload")?;
    let body = &payload[1..];
    match kind {
        KIND_UPSERT => {
            let ((ext_id, vector), used) = read_upsert_body(body)?;
            if body.len() != used {
                bail!("wal: upsert payload has {} trailing bytes", body.len() - used);
            }
            Ok(WalRecord::Upsert { ext_id, vector })
        }
        KIND_DELETE => {
            if body.len() != 4 {
                bail!("wal: delete payload length {} != 5", payload.len());
            }
            let ext_id = u32::from_le_bytes(body[..4].try_into().unwrap());
            Ok(WalRecord::Delete { ext_id })
        }
        KIND_BATCH => {
            if body.len() < 4 {
                bail!("wal: batch payload too short ({} bytes)", payload.len());
            }
            let count = u32::from_le_bytes(body[..4].try_into().unwrap()) as usize;
            let mut rest = &body[4..];
            let mut items = Vec::with_capacity(count.min(1 << 20));
            for _ in 0..count {
                let (item, used) = read_upsert_body(rest)?;
                items.push(item);
                rest = &rest[used..];
            }
            if !rest.is_empty() {
                bail!("wal: batch payload has {} trailing bytes", rest.len());
            }
            Ok(WalRecord::Batch { items })
        }
        k => bail!("wal: unknown record kind {k}"),
    }
}

/// An open WAL file positioned for appends.
pub struct Wal {
    file: File,
    path: PathBuf,
    bytes: u64,
    base_seq: u64,
    count: u64,
}

impl Wal {
    /// Create a fresh, empty WAL at `path` (truncating any existing
    /// file) whose first record will carry `base_seq`, and fsync it so
    /// the empty log itself is durable. A brand-new index starts at
    /// `base_seq = 1`; a post-compaction WAL continues the drained
    /// log's numbering.
    pub fn create(path: impl AsRef<Path>, base_seq: u64) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .with_context(|| format!("wal: create {}", path.display()))?;
        file.write_all(WAL_MAGIC)?;
        file.write_all(&base_seq.to_le_bytes())?;
        file.sync_all()?;
        if let Some(parent) = path.parent() {
            if let Ok(dir) = File::open(parent) {
                dir.sync_all().ok();
            }
        }
        Ok(Wal { file, path, bytes: WAL_FILE_HEADER as u64, base_seq, count: 0 })
    }

    fn parse_header(bytes: &[u8], path: &Path) -> Result<u64> {
        if bytes.len() < WAL_FILE_HEADER || &bytes[..WAL_MAGIC.len()] != WAL_MAGIC {
            bail!("wal: bad magic/header in {}", path.display());
        }
        Ok(u64::from_le_bytes(bytes[8..16].try_into().unwrap()))
    }

    /// Scan intact records starting at `WAL_FILE_HEADER`, stopping at
    /// the first torn/incomplete record. Returns the records and the
    /// byte offset of the end of the last good record. A record whose
    /// checksum verifies but whose payload is malformed is a hard error.
    fn scan(bytes: &[u8]) -> Result<(Vec<WalRecord>, usize)> {
        let mut records = Vec::new();
        let mut good = WAL_FILE_HEADER;
        let mut pos = good;
        loop {
            let rest = &bytes[pos..];
            if rest.len() < WAL_HEADER {
                break; // torn header (or clean EOF)
            }
            let len = u32::from_le_bytes(rest[..4].try_into().unwrap()) as usize;
            let checksum = u64::from_le_bytes(rest[4..12].try_into().unwrap());
            if len > MAX_PAYLOAD || rest.len() < WAL_HEADER + len {
                break; // torn payload (or absurd length from a torn header)
            }
            let payload = &rest[WAL_HEADER..WAL_HEADER + len];
            if xxh64(payload, WAL_SEED) != checksum {
                break; // torn/corrupt record: recover the prefix before it
            }
            // Checksum holds: a malformed payload here is real corruption,
            // not a crash artifact — surface it rather than dropping data.
            records.push(decode_payload(payload)?);
            pos += WAL_HEADER + len;
            good = pos;
        }
        Ok((records, good))
    }

    /// Open an existing WAL, replay every intact record, truncate any
    /// torn tail, and return the log positioned for appends together
    /// with the replayed records. The first replayed record carries
    /// [`Wal::base_seq`]; record `i` carries `base_seq + i`.
    pub fn open(path: impl AsRef<Path>) -> Result<(Self, Vec<WalRecord>)> {
        let path = path.as_ref().to_path_buf();
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("wal: open {}", path.display()))?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;
        let base_seq = Self::parse_header(&bytes, &path)?;
        let (records, good) = Self::scan(&bytes)?;
        if good < bytes.len() {
            file.set_len(good as u64)?;
            file.sync_all()?;
        }
        use std::io::Seek;
        file.seek(std::io::SeekFrom::Start(good as u64))?;
        let count = records.len() as u64;
        Ok((Wal { file, path, bytes: good as u64, base_seq, count }, records))
    }

    /// Read-only catch-up scan: every intact record with
    /// `seq >= from_seq`, paired with its sequence number. Returns
    /// `None` when this WAL starts **after** `from_seq` (the suffix was
    /// compacted away — the caller must fall back to a full rebuild).
    /// Never truncates or otherwise mutates the file, so it is safe to
    /// point at a live peer's WAL.
    pub fn read_suffix(
        path: impl AsRef<Path>,
        from_seq: u64,
    ) -> Result<Option<Vec<(u64, WalRecord)>>> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("wal: read {}", path.display()))?;
        let base_seq = Self::parse_header(&bytes, path)?;
        if from_seq < base_seq {
            return Ok(None); // compacted past the requested point
        }
        let (records, _) = Self::scan(&bytes)?;
        Ok(Some(
            records
                .into_iter()
                .enumerate()
                .map(|(i, rec)| (base_seq + i as u64, rec))
                .filter(|(seq, _)| *seq >= from_seq)
                .collect(),
        ))
    }

    /// Append one record at the next sequence number and `sync_data`
    /// it. Returns the assigned sequence number only once the record is
    /// durable; the caller applies the mutation after.
    pub fn append(&mut self, rec: &WalRecord) -> Result<u64> {
        let seq = self.next_seq();
        let buf = encode(rec);
        self.file
            .write_all(&buf)
            .with_context(|| format!("wal: append to {}", self.path.display()))?;
        self.file.sync_data()?;
        self.bytes += buf.len() as u64;
        self.count += 1;
        Ok(seq)
    }

    /// Append a record that must land at exactly `seq` — the replicated
    /// fan-out path, where the router assigns group-level sequence
    /// numbers and every member's WAL must stay a contiguous prefix of
    /// the group history. A gap (this member missed a write) or a
    /// replay (it already has the record) is an error; the caller
    /// routes the member to catch-up instead.
    pub fn append_at(&mut self, seq: u64, rec: &WalRecord) -> Result<u64> {
        let expect = self.next_seq();
        if seq != expect {
            bail!("wal: sequence gap: record carries seq {seq}, log expects {expect}");
        }
        self.append(rec)
    }

    /// Append only the first `keep` bytes of the record's encoding and
    /// sync — a deliberately torn write, for crash-injection tests. The
    /// log is left in the state a mid-append crash would leave it.
    pub fn append_torn(&mut self, rec: &WalRecord, keep: usize) -> Result<()> {
        let buf = encode(rec);
        let keep = keep.min(buf.len());
        self.file.write_all(&buf[..keep])?;
        self.file.sync_data()?;
        self.bytes += keep as u64;
        Ok(())
    }

    /// Total file length in bytes (header + durable records).
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Sequence number of the first record this file holds (or would
    /// hold, if empty).
    pub fn base_seq(&self) -> u64 {
        self.base_seq
    }

    /// Sequence number the next appended record will carry.
    pub fn next_seq(&self) -> u64 {
        self.base_seq + self.count
    }

    /// Highest durable sequence number, or `base_seq - 1` when the file
    /// is empty (0 for a brand-new index).
    pub fn high_water(&self) -> u64 {
        self.base_seq + self.count - 1
    }

    /// The file path.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "alsh_wal_{tag}_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn recs() -> Vec<WalRecord> {
        vec![
            WalRecord::Upsert { ext_id: 7, vector: vec![1.0, -2.5, 0.25] },
            WalRecord::Delete { ext_id: 7 },
            WalRecord::Upsert { ext_id: 9, vector: vec![0.0; 5] },
        ]
    }

    #[test]
    fn roundtrip_and_reopen() {
        let dir = tmp_dir("roundtrip");
        let path = dir.join("wal.log");
        let mut wal = Wal::create(&path, 1).unwrap();
        for (i, r) in recs().iter().enumerate() {
            assert_eq!(wal.append(r).unwrap(), 1 + i as u64);
        }
        assert_eq!(wal.high_water(), 3);
        let n = wal.bytes();
        drop(wal);
        let (mut wal, replayed) = Wal::open(&path).unwrap();
        assert_eq!(replayed, recs());
        assert_eq!(wal.bytes(), n);
        assert_eq!(wal.base_seq(), 1);
        assert_eq!(wal.next_seq(), 4);
        // Appends after reopen extend the log.
        assert_eq!(wal.append(&WalRecord::Delete { ext_id: 1 }).unwrap(), 4);
        let (_, replayed) = Wal::open(&path).unwrap();
        assert_eq!(replayed.len(), 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_truncated_at_every_cut() {
        let torn = WalRecord::Upsert { ext_id: 42, vector: vec![3.0, 1.0, 4.0, 1.0] };
        let full = encode(&torn).len();
        for keep in 0..full {
            let dir = tmp_dir("torn");
            let path = dir.join("wal.log");
            let mut wal = Wal::create(&path, 1).unwrap();
            for r in recs() {
                wal.append(&r).unwrap();
            }
            let clean = wal.bytes();
            wal.append_torn(&torn, keep).unwrap();
            drop(wal);
            let (wal2, replayed) = Wal::open(&path).unwrap();
            assert_eq!(replayed, recs(), "keep={keep}");
            assert_eq!(wal2.bytes(), clean, "keep={keep}: tail not truncated");
            assert_eq!(wal2.high_water(), 3, "keep={keep}: torn record counted");
            assert_eq!(
                std::fs::metadata(&path).unwrap().len(),
                clean,
                "keep={keep}: file not truncated on disk"
            );
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn batch_record_is_atomic_at_every_cut() {
        let batch = WalRecord::Batch {
            items: vec![
                (10, vec![1.0, 2.0, 3.0]),
                (11, vec![-1.0, 0.5, 0.0]),
                (12, vec![4.0, 4.0, 4.0]),
            ],
        };
        let full = encode(&batch).len();
        // Every cut strictly inside the batch record loses the WHOLE
        // batch — replay never surfaces a partial one.
        for keep in 0..full {
            let dir = tmp_dir("batchcut");
            let path = dir.join("wal.log");
            let mut wal = Wal::create(&path, 1).unwrap();
            wal.append(&recs()[0]).unwrap();
            wal.append_torn(&batch, keep).unwrap();
            drop(wal);
            let (wal2, replayed) = Wal::open(&path).unwrap();
            assert_eq!(replayed, recs()[..1].to_vec(), "keep={keep}");
            assert_eq!(wal2.high_water(), 1, "keep={keep}");
            std::fs::remove_dir_all(&dir).ok();
        }
        // And the full record replays the whole batch as one sequence.
        let dir = tmp_dir("batchfull");
        let path = dir.join("wal.log");
        let mut wal = Wal::create(&path, 5).unwrap();
        assert_eq!(wal.append(&batch).unwrap(), 5);
        drop(wal);
        let (wal2, replayed) = Wal::open(&path).unwrap();
        assert_eq!(replayed, vec![batch]);
        assert_eq!(wal2.high_water(), 5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn append_at_enforces_contiguity() {
        let dir = tmp_dir("seqgap");
        let path = dir.join("wal.log");
        let mut wal = Wal::create(&path, 3).unwrap();
        assert_eq!(wal.append_at(3, &recs()[0]).unwrap(), 3);
        assert!(wal.append_at(5, &recs()[1]).is_err(), "gap accepted");
        assert!(wal.append_at(3, &recs()[1]).is_err(), "replay accepted");
        assert_eq!(wal.append_at(4, &recs()[1]).unwrap(), 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn read_suffix_returns_tail_or_signals_compaction() {
        let dir = tmp_dir("suffix");
        let path = dir.join("wal.log");
        let mut wal = Wal::create(&path, 4).unwrap();
        for r in recs() {
            wal.append(&r).unwrap(); // seqs 4, 5, 6
        }
        drop(wal);
        let tail = Wal::read_suffix(&path, 5).unwrap().unwrap();
        assert_eq!(tail.len(), 2);
        assert_eq!(tail[0], (5, recs()[1].clone()));
        assert_eq!(tail[1], (6, recs()[2].clone()));
        // from_seq at exactly base_seq: the whole file.
        assert_eq!(Wal::read_suffix(&path, 4).unwrap().unwrap().len(), 3);
        // from_seq past the end: nothing to give, but not a rebuild.
        assert_eq!(Wal::read_suffix(&path, 9).unwrap().unwrap().len(), 0);
        // from_seq before base_seq: compacted away — rebuild required.
        assert!(Wal::read_suffix(&path, 3).unwrap().is_none());
        // The scan never truncated anything.
        let (_, replayed) = Wal::open(&path).unwrap();
        assert_eq!(replayed.len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_payload_with_valid_checksum_is_an_error() {
        let dir = tmp_dir("corrupt");
        let path = dir.join("wal.log");
        let mut wal = Wal::create(&path, 1).unwrap();
        wal.append(&recs()[0]).unwrap();
        drop(wal);
        // Hand-craft a record with a checksum that matches a garbage
        // payload (unknown kind 9): checksum passes, decode must fail.
        let payload = [9u8, 0, 0, 0, 0];
        let mut raw = Vec::new();
        raw.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        raw.extend_from_slice(&xxh64(&payload, WAL_SEED).to_le_bytes());
        raw.extend_from_slice(&payload);
        {
            use std::io::Write;
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(&raw).unwrap();
        }
        assert!(Wal::open(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn flipped_bit_in_middle_record_stops_replay_there() {
        let dir = tmp_dir("flip");
        let path = dir.join("wal.log");
        let mut wal = Wal::create(&path, 1).unwrap();
        for r in recs() {
            wal.append(&r).unwrap();
        }
        drop(wal);
        // Flip a bit inside the second record's payload.
        let first_len = encode(&recs()[0]).len();
        let mut bytes = std::fs::read(&path).unwrap();
        let off = WAL_FILE_HEADER + first_len + WAL_HEADER + 1;
        bytes[off] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let (wal2, replayed) = Wal::open(&path).unwrap();
        assert_eq!(replayed, recs()[..1].to_vec());
        assert_eq!(wal2.bytes(), (WAL_FILE_HEADER + first_len) as u64);
        assert_eq!(wal2.high_water(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = tmp_dir("magic");
        let path = dir.join("wal.log");
        std::fs::write(&path, b"NOTAWAL!\0\0\0\0\0\0\0\0").unwrap();
        assert!(Wal::open(&path).is_err());
        std::fs::write(&path, b"AL").unwrap();
        assert!(Wal::open(&path).is_err());
        // v1 files (no base_seq header) are not silently misread.
        std::fs::write(&path, b"ALSHWAL1").unwrap();
        assert!(Wal::open(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Reusable per-caller query scratch: the allocation-free query path.
//!
//! Every transient buffer a query needs — the Q-transformed vector, the
//! fused code block, the candidate list, visit stamps for dedup, rerank
//! storage — lives in one [`QueryScratch`] owned by the *caller* (engine
//! loop, batcher thread, bench loop, example). Buffers only ever grow, so
//! steady-state queries perform **zero heap allocations** (asserted by
//! `tests/zero_alloc.rs` with a counting global allocator), and because
//! each caller owns its scratch there is no shared mutable state: the old
//! global stamp `Mutex` in `AlshIndex` is gone and concurrent queries
//! never serialize.
//!
//! # Visit-stamp dedup
//!
//! Candidate dedup across the L probed buckets uses an epoch-stamped
//! array: item `i` is fresh iff `stamps[i] != epoch`. Bumping the epoch
//! invalidates all stamps in O(1); on u32 wraparound the array is cleared
//! once. This logic exists exactly once, in [`QueryScratch::dedup`] — the
//! plain, code-fed, and multi-probe candidate paths all borrow a
//! [`DedupSink`] from it.

use super::core::ScoredItem;
use super::scheme::{MipsHashScheme, SchemeHasher};

/// Caller-owned scratch for the allocation-free query path. Construct via
/// [`QueryScratch::new`] (or the pre-sizing `AlshIndex::scratch` /
/// `MipsEngine::scratch`) and hand `&mut` to each query call. One scratch
/// serves any number of indexes/shards; buffers grow to the largest seen.
#[derive(Clone, Debug, Default)]
pub struct QueryScratch {
    /// Q-transformed query, `D + m` long.
    pub(crate) qx: Vec<f32>,
    /// Fused code block, `L·K` long.
    pub(crate) codes: Vec<i32>,
    /// Pre-floor fractional parts (multi-probe), `L·K` long.
    pub(crate) fracs: Vec<f32>,
    /// Deduplicated candidate ids, in first-seen probe order.
    pub(crate) cands: Vec<u32>,
    /// Visit stamps per item id.
    stamps: Vec<u32>,
    /// Current dedup epoch.
    epoch: u32,
    /// Scored candidates (rerank working set).
    pub(crate) scored: Vec<ScoredItem>,
    /// Final top-k, sorted by descending score.
    pub(crate) top: Vec<ScoredItem>,
    /// Multi-probe perturbation heap: (boundary distance, coord, ±1).
    pub(crate) perturbs: Vec<(f32, usize, i32)>,
    /// Scatter/gather merge buffer (sharded router).
    pub(crate) merged: Vec<ScoredItem>,
    /// Batch-query Q-transformed inputs, `[batch × (D+m)]` row-major.
    pub(crate) qx_batch: Vec<f32>,
    /// Batch-query fused code block, `[batch × L·K]` row-major.
    pub(crate) codes_batch: Vec<i32>,
    /// Cached live-tier snapshot (see [`super::delta`]): the epoch-cell
    /// id + generation it was read at, plus the type-erased
    /// `Arc<LiveSnapshot>`. Repeat queries against an unchanged live
    /// index skip the publish lock entirely — one atomic load.
    pub(crate) snap: SnapCache,
}

/// Type-erased live-snapshot cache slot: `(cell id, generation, snapshot)`.
/// Erased so `QueryScratch` stays non-generic over the index storage.
#[derive(Clone, Default)]
pub(crate) struct SnapCache(
    pub(crate) Option<(u64, u64, std::sync::Arc<dyn std::any::Any + Send + Sync>)>,
);

impl std::fmt::Debug for SnapCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.0 {
            Some((cell, generation, _)) => {
                write!(f, "SnapCache(cell {cell}, gen {generation})")
            }
            None => write!(f, "SnapCache(empty)"),
        }
    }
}

impl QueryScratch {
    /// An empty scratch; buffers grow on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow the fixed-shape buffers (stamps, codes, fracs, qx, perturbs)
    /// up front (`n_codes` = L·K, `dp` = D + m). Variable-size buffers
    /// (candidates, rerank storage) still grow to the workload's
    /// high-water mark over the first queries.
    pub fn reserve(&mut self, n_items: usize, n_codes: usize, dp: usize) {
        if self.stamps.len() < n_items {
            self.stamps.resize(n_items, 0);
        }
        if self.codes.len() < n_codes {
            self.codes.resize(n_codes, 0);
        }
        if self.fracs.len() < n_codes {
            self.fracs.resize(n_codes, 0.0);
        }
        self.qx.reserve(dp);
        self.perturbs.reserve(2 * n_codes);
    }

    /// The candidate ids produced by the most recent probe call.
    pub fn candidates(&self) -> &[u32] {
        &self.cands
    }

    /// The top-k produced by the most recent query call.
    pub fn top(&self) -> &[ScoredItem] {
        &self.top
    }

    /// Start a fresh dedup epoch over `n_items` ids and return the sink
    /// plus the remaining scratch fields (split-borrowed so probe loops
    /// can use codes/fracs/perturbs alongside the sink). This is the one
    /// implementation of the epoch/stamp logic.
    #[allow(clippy::type_complexity)]
    pub(crate) fn dedup(
        &mut self,
        n_items: usize,
    ) -> (DedupSink<'_>, &mut Vec<i32>, &mut Vec<f32>, &mut Vec<(f32, usize, i32)>) {
        if self.stamps.len() < n_items {
            self.stamps.resize(n_items, 0);
        }
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            self.stamps.fill(0);
            self.epoch = 1;
        }
        self.cands.clear();
        (
            DedupSink { stamps: &mut self.stamps, epoch: self.epoch, out: &mut self.cands },
            &mut self.codes,
            &mut self.fracs,
            &mut self.perturbs,
        )
    }

    /// Re-borrow the *current* dedup epoch — no epoch bump, no candidate
    /// clear — growing the stamp array to `n_total` ids. The live mutable
    /// tier uses this to continue one dedup pass after the base index
    /// probe: base candidates stay stamped, and delta entries occupy the
    /// id range `[n_base, n_total)`. Fresh stamp slots hold 0, which can
    /// never equal the live epoch (the epoch is always >= 1 after
    /// [`QueryScratch::dedup`]), so grown slots start unvisited.
    #[allow(clippy::type_complexity)]
    pub(crate) fn resume_dedup(
        &mut self,
        n_total: usize,
    ) -> (DedupSink<'_>, &mut Vec<i32>, &mut Vec<f32>, &mut Vec<(f32, usize, i32)>) {
        if self.stamps.len() < n_total {
            self.stamps.resize(n_total, 0);
        }
        debug_assert!(self.epoch >= 1, "resume_dedup before any dedup epoch");
        (
            DedupSink { stamps: &mut self.stamps, epoch: self.epoch, out: &mut self.cands },
            &mut self.codes,
            &mut self.fracs,
            &mut self.perturbs,
        )
    }

    /// Cap the candidate list at `cap` entries (no-op when already
    /// within). The budgeted probe paths stop probing early once the cap
    /// is reached, but a single postings list can overshoot it — this
    /// trims the tail so the rerank pool is exactly bounded.
    pub(crate) fn truncate_candidates(&mut self, cap: usize) {
        if self.cands.len() > cap {
            self.cands.truncate(cap);
        }
    }

    /// Grow `codes` (and optionally `fracs`) to `n_codes` entries,
    /// returning nothing — the single place the code-buffer sizing rule
    /// lives.
    fn grow_codes(&mut self, n_codes: usize, with_fracs: bool) {
        if self.codes.len() < n_codes {
            self.codes.resize(n_codes, 0);
        }
        if with_fracs && self.fracs.len() < n_codes {
            self.fracs.resize(n_codes, 0.0);
        }
    }

    /// Hash the Q-transformed query already in `self.qx` into
    /// `self.codes` with the scheme's fused hasher.
    pub(crate) fn hash_codes(&mut self, fused: &SchemeHasher) {
        let nc = fused.n_codes();
        self.grow_codes(nc, false);
        fused.hash_into(&self.qx, &mut self.codes[..nc]);
    }

    /// Hash an externally supplied input vector into `self.codes`.
    pub(crate) fn hash_codes_external(&mut self, fused: &SchemeHasher, x: &[f32]) {
        let nc = fused.n_codes();
        self.grow_codes(nc, false);
        fused.hash_into(x, &mut self.codes[..nc]);
    }

    /// Hash `self.qx` into `self.codes` + `self.fracs` (multi-probe:
    /// fractional parts for L2, sign margins for SRP).
    pub(crate) fn hash_codes_with_conf(&mut self, fused: &SchemeHasher) {
        let nc = fused.n_codes();
        self.grow_codes(nc, true);
        fused.hash_conf_into(&self.qx, &mut self.codes[..nc], &mut self.fracs[..nc]);
    }

    /// Q-transform (per scheme) and hash a whole batch of queries in one
    /// fused matrix–matrix pass: row `i` of `codes_batch` holds query
    /// `i`'s `L·K` codes afterwards (the `query_batch_into` front half).
    pub(crate) fn hash_codes_batch(
        &mut self,
        fused: &SchemeHasher,
        scheme: MipsHashScheme,
        queries: &[Vec<f32>],
        m: usize,
    ) {
        let dp = fused.dim();
        let nc = fused.n_codes();
        let nb = queries.len();
        if self.qx_batch.len() < nb * dp {
            self.qx_batch.resize(nb * dp, 0.0);
        }
        if self.codes_batch.len() < nb * nc {
            self.codes_batch.resize(nb * nc, 0);
        }
        for (i, q) in queries.iter().enumerate() {
            debug_assert_eq!(q.len() + scheme.append_len(m), dp);
            scheme.query_row_into(q, m, &mut self.qx_batch[i * dp..(i + 1) * dp]);
        }
        fused.hash_batch_into(&self.qx_batch[..nb * dp], nb, &mut self.codes_batch[..nb * nc]);
    }

    /// Copy batch row `i` (`nc` codes) into the single-query code buffer
    /// so the existing probe machinery can consume it.
    pub(crate) fn stage_batch_codes(&mut self, i: usize, nc: usize) {
        if self.codes.len() < nc {
            self.codes.resize(nc, 0);
        }
        self.codes[..nc].copy_from_slice(&self.codes_batch[i * nc..(i + 1) * nc]);
    }

    /// Force the epoch counter (wraparound tests).
    #[cfg(test)]
    pub(crate) fn set_epoch(&mut self, epoch: u32) {
        self.epoch = epoch;
    }
}

/// Per-worker scratch for the parallel sharded build: the flat transformed
/// item block and its fused code block. Buffers grow once per worker and
/// are reused across every block the shard processes, so the build's inner
/// loop allocates only into the per-table postings runs.
#[derive(Clone, Debug, Default)]
pub(crate) struct BuildScratch {
    px_block: Vec<f32>,
    codes_block: Vec<i32>,
}

impl BuildScratch {
    pub(crate) fn new() -> Self {
        Self::default()
    }

    /// Exact-size views for a block of `rows` items: the `[rows × dp]`
    /// transformed-input buffer and the `[rows × nc]` code buffer.
    pub(crate) fn block_bufs(
        &mut self,
        rows: usize,
        dp: usize,
        nc: usize,
    ) -> (&mut [f32], &mut [i32]) {
        let need_px = rows * dp;
        if self.px_block.len() < need_px {
            self.px_block.resize(need_px, 0.0);
        }
        let need_codes = rows * nc;
        if self.codes_block.len() < need_codes {
            self.codes_block.resize(need_codes, 0);
        }
        (&mut self.px_block[..need_px], &mut self.codes_block[..need_codes])
    }
}

/// Run `f` with the calling thread's shared scratch — the allocating
/// convenience wrappers (`AlshIndex::query` & co.) route through this so
/// they stay lock-free and amortize their buffers per thread.
pub(crate) fn with_thread_scratch<R>(f: impl FnOnce(&mut QueryScratch) -> R) -> R {
    thread_local! {
        static SCRATCH: std::cell::RefCell<QueryScratch> =
            std::cell::RefCell::new(QueryScratch::new());
    }
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Borrowed stamp array + epoch: pushes each id at most once per epoch.
pub(crate) struct DedupSink<'a> {
    stamps: &'a mut [u32],
    epoch: u32,
    out: &'a mut Vec<u32>,
}

impl DedupSink<'_> {
    /// Offer a probed postings list; fresh ids are appended in order.
    #[inline]
    pub fn extend(&mut self, ids: &[u32]) {
        for &id in ids {
            let s = &mut self.stamps[id as usize];
            if *s != self.epoch {
                *s = self.epoch;
                self.out.push(id);
            }
        }
    }

    /// Offer a postings list of *band-local* ids, translating through
    /// `map[local] -> global id` before stamping (the norm-range banded
    /// probe path: each band's frozen tables store ids local to the band).
    #[inline]
    pub fn extend_mapped(&mut self, locals: &[u32], map: &[u32]) {
        for &local in locals {
            let id = map[local as usize];
            let s = &mut self.stamps[id as usize];
            if *s != self.epoch {
                *s = self.epoch;
                self.out.push(id);
            }
        }
    }

    /// Candidates emitted so far this epoch (per-band count capture).
    /// (No `is_empty` twin: counting, not emptiness, is the use case.)
    #[inline]
    pub fn len(&self) -> usize {
        self.out.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedups_within_and_across_lists() {
        let mut s = QueryScratch::new();
        let (mut sink, _, _, _) = s.dedup(10);
        sink.extend(&[1, 2, 2, 3]);
        sink.extend(&[3, 4, 1]);
        assert_eq!(s.candidates(), &[1, 2, 3, 4]);
    }

    #[test]
    fn epochs_are_independent() {
        let mut s = QueryScratch::new();
        let (mut sink, _, _, _) = s.dedup(5);
        sink.extend(&[0, 1]);
        assert_eq!(s.candidates(), &[0, 1]);
        // A new epoch forgets the previous one's visits.
        let (mut sink, _, _, _) = s.dedup(5);
        sink.extend(&[1, 4]);
        assert_eq!(s.candidates(), &[1, 4]);
    }

    #[test]
    fn wraparound_clears_stamps() {
        let mut s = QueryScratch::new();
        s.set_epoch(u32::MAX - 2);
        for _ in 0..6 {
            let (mut sink, _, _, _) = s.dedup(4);
            sink.extend(&[2, 2, 3]);
            assert_eq!(s.candidates(), &[2, 3]);
        }
    }

    #[test]
    fn mapped_extend_translates_and_dedups_against_plain_extend() {
        // Band-local ids [0, 1, 2] mapping to globals [7, 3, 9]: the
        // mapped sink must dedup in *global* id space, interleaved with
        // plain (already-global) postings.
        let map = [7u32, 3, 9];
        let mut s = QueryScratch::new();
        let (mut sink, _, _, _) = s.dedup(10);
        sink.extend_mapped(&[0, 1, 0], &map);
        assert_eq!(sink.len(), 2);
        sink.extend(&[3, 9, 5]);
        sink.extend_mapped(&[2, 1], &map);
        assert_eq!(s.candidates(), &[7, 3, 9, 5]);
    }

    #[test]
    fn resume_dedup_continues_the_epoch_over_a_grown_id_space() {
        let mut s = QueryScratch::new();
        let (mut sink, _, _, _) = s.dedup(4);
        sink.extend(&[1, 3]);
        // Resume: ids 1 and 3 stay deduped, new ids (incl. grown range)
        // are fresh, and the candidate list is extended, not cleared.
        let (mut sink, _, _, _) = s.resume_dedup(8);
        sink.extend(&[3, 6, 1, 7, 6]);
        assert_eq!(s.candidates(), &[1, 3, 6, 7]);
        // The next plain dedup starts over.
        let (mut sink, _, _, _) = s.dedup(8);
        sink.extend(&[6]);
        assert_eq!(s.candidates(), &[6]);
    }

    #[test]
    fn grows_to_largest_index() {
        let mut s = QueryScratch::new();
        let (mut sink, _, _, _) = s.dedup(3);
        sink.extend(&[2]);
        let (mut sink, _, _, _) = s.dedup(100);
        sink.extend(&[99]);
        assert_eq!(s.candidates(), &[99]);
    }
}

//! Per-query probe budget: degraded serving as a *parameter* of the one
//! probe implementation, not a fork of it.
//!
//! Under overload the coordinator's degradation ladder (see
//! `coordinator::admission`) wants to shed *work* before shedding
//! *requests* — ALSH recall degrades smoothly with the probe budget, so a
//! reduced-budget query is still a correct (exact-scored) MIPS answer
//! over a smaller candidate pool. [`ProbeBudget`] carries the four knobs
//! every candidate path honours:
//!
//! * `n_probes` — multi-probe buckets per table (1 = base probe only);
//! * `max_tables` — how many of the L tables to probe;
//! * `max_bands` — how many norm bands to probe (banded index only; the
//!   *largest-norm* bands are kept, since under MIPS the winners
//!   concentrate there);
//! * `max_rerank` — cap on the deduplicated candidate pool handed to the
//!   exact rerank (the dominant per-query cost).
//!
//! [`ProbeBudget::full`] is the identity: every budgeted path produces
//! **bit-identical** results to its unbudgeted twin at full budget
//! (property-tested in `tests/budget_equivalence.rs`), which is what lets
//! the batcher route *all* traffic — healthy and degraded — through the
//! budgeted entry points.

/// Per-query probe/rerank budget. `Default` is [`ProbeBudget::full`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ProbeBudget {
    /// Buckets probed per table (multi-probe); 1 = base probe only.
    pub n_probes: usize,
    /// Tables probed (clamped to `[1, L]` at query time).
    pub max_tables: usize,
    /// Norm bands probed (clamped to `[1, B]`; ignored by the flat
    /// index). A partial band budget keeps the largest-norm bands.
    pub max_bands: usize,
    /// Cap on the deduplicated candidate pool handed to the exact rerank.
    /// Probing stops early (between tables/bands) once the cap is
    /// reached, and the pool is truncated to exactly this size.
    pub max_rerank: usize,
}

impl ProbeBudget {
    /// The unconstrained budget: bit-identical to the plain query paths.
    pub const fn full() -> Self {
        Self {
            n_probes: 1,
            max_tables: usize::MAX,
            max_bands: usize::MAX,
            max_rerank: usize::MAX,
        }
    }

    /// Full budget except `n_probes` buckets per table — bit-identical to
    /// the plain multi-probe paths.
    pub const fn with_probes(n_probes: usize) -> Self {
        Self { n_probes, ..Self::full() }
    }

    /// Whether this budget constrains nothing (the healthy-mode check).
    pub fn is_full(&self) -> bool {
        *self == Self::full()
    }

    /// Tables to probe for an index with `l` tables: `max_tables` clamped
    /// to `[1, l]` (a query always probes at least one table).
    pub fn tables(&self, l: usize) -> usize {
        self.max_tables.clamp(1, l.max(1))
    }

    /// Bands to probe for an index with `b` bands, clamped to `[1, b]`.
    pub fn bands(&self, b: usize) -> usize {
        self.max_bands.clamp(1, b.max(1))
    }
}

impl Default for ProbeBudget {
    fn default() -> Self {
        Self::full()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_is_identity_shaped() {
        let f = ProbeBudget::full();
        assert!(f.is_full());
        assert_eq!(f, ProbeBudget::default());
        assert_eq!(f.tables(32), 32);
        assert_eq!(f.bands(4), 4);
        assert!(!ProbeBudget::with_probes(4).is_full());
        assert_eq!(ProbeBudget::with_probes(4).n_probes, 4);
    }

    #[test]
    fn clamps_to_index_shape() {
        let b = ProbeBudget { max_tables: 8, max_bands: 2, ..ProbeBudget::full() };
        assert_eq!(b.tables(32), 8);
        assert_eq!(b.tables(4), 4);
        assert_eq!(b.bands(4), 2);
        assert_eq!(b.bands(1), 1);
        // Degenerate budgets still probe something.
        let z = ProbeBudget { max_tables: 0, max_bands: 0, ..ProbeBudget::full() };
        assert_eq!(z.tables(32), 1);
        assert_eq!(z.bands(4), 1);
    }
}

//! Storage polymorphism for the frozen serve-side arrays: one set of
//! query kernels, two memories.
//!
//! Every hot array an index serves from — radix `starts`, CSR `offsets`,
//! `postings`, bucket `keys`, the row-major item matrix, band id maps —
//! is reached through the [`Storage`] trait's associated slice types:
//!
//! * [`Owned`] — plain `Vec`s, produced by the build pipeline and the
//!   streaming persist loader. This is the default type parameter
//!   everywhere, so `AlshIndex` still means `AlshIndex<Owned>` and no
//!   build-side call site changes.
//! * [`Mapped`] — [`MapSlice`] views into one [`MmapFile`], produced by
//!   `index::persist::open_mmap` from a v5 file whose sections are laid
//!   out exactly as the in-memory arrays. Opening copies **nothing**:
//!   the kernel pages the arrays in on first touch and the page cache
//!   shares them across every process serving the same file.
//!
//! [`MapSlice`] holds `(ptr, len, Arc<MmapFile>)` rather than a borrowed
//! `&[T]` so a mapped index is `'static + Send + Sync` like an owned one
//! — no self-referential lifetimes, and the mapping lives exactly as
//! long as the last view into it. The `Arc` bump per section is the only
//! per-section cost, which is how `open_mmap` keeps its O(tables)
//! allocation budget (asserted by `tests/mmap_equivalence.rs` with a
//! counting allocator).
//!
//! The mmap itself is a self-contained raw-libc wrapper (`mmap`,
//! `munmap` via `extern "C"` — `libc` is already linked by std on every
//! unix target), consistent with the repo's hermetic vendored-deps
//! policy: no new external crates. Non-unix targets fall back to one
//! 64-byte-aligned heap read ([`MmapFile::read_aligned`]), which keeps
//! the same section-view machinery working at the cost of the one copy
//! mmap avoids.

use std::fmt;
use std::ops::Deref;
use std::path::Path;
use std::sync::Arc;

/// Alignment every v5 section starts on (and the alignment of the heap
/// fallback buffer): comfortably covers the widest element (u64) and
/// matches the cache-line size the hot probe loops are blocked for.
pub const SECTION_ALIGN: usize = 64;

/// Selects the memory the frozen serve-side arrays live in. Implemented
/// by the [`Owned`] and [`Mapped`] markers; generic code only ever sees
/// the associated slice types, so the query kernels compile once per
/// storage with identical code shape (a `Vec` and a `MapSlice` both
/// deref to a fat pointer).
pub trait Storage: 'static {
    type U64s: Deref<Target = [u64]> + Clone + fmt::Debug + Send + Sync + 'static;
    type U32s: Deref<Target = [u32]> + Clone + fmt::Debug + Send + Sync + 'static;
    type F32s: Deref<Target = [f32]> + Clone + fmt::Debug + Send + Sync + 'static;
}

/// Heap-owned storage (`Vec`s): the build pipeline's output and the
/// streaming loader's destination. The default everywhere.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Owned;

impl Storage for Owned {
    type U64s = Vec<u64>;
    type U32s = Vec<u32>;
    type F32s = Vec<f32>;
}

/// Zero-copy storage: every array is a [`MapSlice`] view into one
/// [`MmapFile`] (persist v5, `index::persist::open_mmap`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Mapped;

impl Storage for Mapped {
    type U64s = MapSlice<u64>;
    type U32s = MapSlice<u32>;
    type F32s = MapSlice<f32>;
}

/// The targets the raw `mmap` declaration below is known-correct for:
/// 64-bit unix, where `off_t` is 64 bits wide so the hand-written
/// prototype matches the C ABI. 32-bit unix would need `mmap64` (glibc's
/// plain `mmap` takes a 32-bit `off_t` there) — those targets, like
/// non-unix ones, take the aligned-heap-read fallback instead of risking
/// a mismatched FFI signature.
#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use std::ffi::c_void;

    // Raw prototypes for the three calls we need; libc is linked by std
    // on every unix target, so no crate is required.
    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
        pub fn madvise(addr: *mut c_void, len: usize, advice: i32) -> i32;
    }

    // Identical values on Linux and macOS.
    pub const PROT_READ: i32 = 1;
    pub const MAP_SHARED: i32 = 1;
    pub const MADV_RANDOM: i32 = 1;
    pub const MADV_WILLNEED: i32 = 3;
}

/// Access-pattern hints a caller can attach to a mapped section
/// ([`MapSlice::advise`] / [`MmapFile::advise`]). Forwarded to the
/// kernel via `madvise(2)` on 64-bit unix; a no-op for heap-backed
/// buffers and on every other target. Purely advisory — failure (or the
/// no-op path) changes nothing functional, only paging behavior.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MapAdvice {
    /// Expect random point accesses (postings probes, item rows hit by
    /// rerank): disables readahead so each probe faults only the pages
    /// it actually touches.
    Random,
    /// Expect imminent dense use (bucket keys, radix starts, CSR
    /// offsets — the per-query probe metadata): ask the kernel to
    /// prefetch the range so first queries don't pay a fault per page.
    WillNeed,
}

enum Backing {
    /// A live `mmap(2)` mapping (64-bit unix).
    #[cfg(all(unix, target_pointer_width = "64"))]
    Mmap,
    /// A 64-byte-aligned heap buffer (the fallback for targets without
    /// the raw mmap path, and [`MmapFile::read_aligned`] callers).
    Heap(std::alloc::Layout),
}

/// A read-only byte region backing a set of [`MapSlice`] views: either a
/// shared file mapping ([`MmapFile::map`]) or an aligned heap buffer
/// ([`MmapFile::read_aligned`]). Unmapped/freed when the last
/// `Arc<MmapFile>` drops.
pub struct MmapFile {
    ptr: *mut u8,
    len: usize,
    backing: Backing,
}

// Safety: the region is read-only for its whole lifetime (PROT_READ, or
// a heap buffer never written after construction).
unsafe impl Send for MmapFile {}
unsafe impl Sync for MmapFile {}

impl MmapFile {
    /// Map `path` read-only and page-cache-shared (`MAP_SHARED`), so
    /// concurrent processes serving the same index file share physical
    /// pages. O(1) in the file size — nothing is read until a query
    /// touches a page. Falls back to [`MmapFile::read_aligned`] on
    /// non-unix targets.
    pub fn map(path: impl AsRef<Path>) -> anyhow::Result<Arc<Self>> {
        let path = path.as_ref();
        #[cfg(all(unix, target_pointer_width = "64"))]
        {
            use std::os::fd::AsRawFd;
            let file = std::fs::File::open(path)?;
            let len = file.metadata()?.len();
            anyhow::ensure!(len > 0, "not an ALSH index file: {} is empty", path.display());
            anyhow::ensure!(len <= usize::MAX as u64, "file too large to map");
            let len = len as usize;
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_SHARED,
                    file.as_raw_fd(),
                    0,
                )
            };
            anyhow::ensure!(
                ptr as isize != -1,
                "mmap({}) failed: {}",
                path.display(),
                std::io::Error::last_os_error()
            );
            // The fd can close now; the mapping keeps the file alive.
            Ok(Arc::new(Self { ptr: ptr as *mut u8, len, backing: Backing::Mmap }))
        }
        #[cfg(not(all(unix, target_pointer_width = "64")))]
        {
            Self::read_aligned(path)
        }
    }

    /// Read the whole file into one `SECTION_ALIGN`-aligned heap buffer.
    /// Used by the streaming (heap) loader for v5 files — same section
    /// parsing as the mapped path, one copy instead of zero — and as the
    /// portable fallback for [`MmapFile::map`].
    pub fn read_aligned(path: impl AsRef<Path>) -> anyhow::Result<Arc<Self>> {
        use std::io::Read;
        let path = path.as_ref();
        let mut file = std::fs::File::open(path)?;
        let len = file.metadata()?.len();
        anyhow::ensure!(len > 0, "not an ALSH index file: {} is empty", path.display());
        anyhow::ensure!(len <= usize::MAX as u64, "file too large to read");
        let len = len as usize;
        let layout = std::alloc::Layout::from_size_align(len, SECTION_ALIGN)
            .map_err(|e| anyhow::anyhow!("bad buffer layout: {e}"))?;
        let ptr = unsafe { std::alloc::alloc(layout) };
        anyhow::ensure!(!ptr.is_null(), "allocation of {len} bytes failed");
        let this = Self { ptr, len, backing: Backing::Heap(layout) };
        // `this` owns the buffer from here on, so an early `?` frees it.
        let buf = unsafe { std::slice::from_raw_parts_mut(ptr, len) };
        file.read_exact(buf)?;
        Ok(Arc::new(this))
    }

    /// The mapped bytes.
    pub fn bytes(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    /// Total byte length.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Never true — construction rejects empty files.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Forward an access-pattern hint for `byte_len` bytes at `byte_off`
    /// to the kernel. Only a live mapping takes advice — the heap
    /// fallback has no pages to advise — and the result is deliberately
    /// ignored: `madvise` is a hint, and a refused hint must never fail
    /// an open that would otherwise serve correctly.
    pub fn advise(&self, byte_off: usize, byte_len: usize, advice: MapAdvice) {
        #[cfg(all(unix, target_pointer_width = "64"))]
        {
            if !matches!(self.backing, Backing::Mmap) || byte_len == 0 || byte_off >= self.len {
                return;
            }
            // madvise needs a page-aligned start. The mapping base is
            // page-aligned, so round the offset down to a power-of-two
            // multiple generous enough for every page size in the wild
            // (4K–64K) and widen the range to compensate — advice
            // spilling onto a few neighboring pages is harmless.
            const PAGE_ALIGN: usize = 64 * 1024;
            let start = byte_off & !(PAGE_ALIGN - 1);
            let len = (byte_off + byte_len).min(self.len) - start;
            let flag = match advice {
                MapAdvice::Random => sys::MADV_RANDOM,
                MapAdvice::WillNeed => sys::MADV_WILLNEED,
            };
            unsafe {
                let _ = sys::madvise(self.ptr.add(start) as *mut std::ffi::c_void, len, flag);
            }
        }
        #[cfg(not(all(unix, target_pointer_width = "64")))]
        {
            let _ = (byte_off, byte_len, advice);
        }
    }
}

/// A typed view of `byte_len` bytes of `owner` at `byte_off`, validating
/// bounds, element-size divisibility, and `T`'s alignment (section
/// offsets are 64-byte aligned on disk and the base is page- or
/// 64-byte-aligned, so this only fails on corrupt section tables).
/// Restricted to the crate: `T` must be a plain-old-data type with no
/// invalid bit patterns (u32/u64/f32 here).
pub(crate) fn map_slice<T>(
    owner: &Arc<MmapFile>,
    byte_off: usize,
    byte_len: usize,
    what: &str,
) -> anyhow::Result<MapSlice<T>> {
    let elem = std::mem::size_of::<T>();
    let end = byte_off
        .checked_add(byte_len)
        .ok_or_else(|| anyhow::anyhow!("corrupt index file: {what} section overflows"))?;
    anyhow::ensure!(
        end <= owner.len,
        "corrupt index file: {what} section [{byte_off}, {end}) exceeds file length {}",
        owner.len
    );
    anyhow::ensure!(
        byte_len % elem == 0,
        "corrupt index file: {what} section length {byte_len} not a multiple of {elem}"
    );
    anyhow::ensure!(
        byte_off % std::mem::align_of::<T>() == 0,
        "corrupt index file: {what} section offset {byte_off} misaligned for {elem}-byte elements"
    );
    Ok(MapSlice {
        ptr: unsafe { owner.ptr.add(byte_off) } as *const T,
        len: byte_len / elem,
        _owner: Arc::clone(owner),
    })
}

impl Drop for MmapFile {
    fn drop(&mut self) {
        match &self.backing {
            #[cfg(all(unix, target_pointer_width = "64"))]
            Backing::Mmap => unsafe {
                sys::munmap(self.ptr as *mut std::ffi::c_void, self.len);
            },
            Backing::Heap(layout) => unsafe { std::alloc::dealloc(self.ptr, *layout) },
        }
    }
}

impl fmt::Debug for MmapFile {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("MmapFile").field("len", &self.len).finish()
    }
}

/// A `'static` typed view into an [`MmapFile`]: `(ptr, len)` plus an
/// `Arc` keeping the mapping alive. Derefs to `&[T]`, so every generic
/// query kernel consumes it exactly like a `Vec`.
pub struct MapSlice<T> {
    ptr: *const T,
    len: usize,
    _owner: Arc<MmapFile>,
}

// Safety: the underlying memory is read-only and outlives the slice via
// the Arc; T is a plain-old-data type.
unsafe impl<T: Send + Sync> Send for MapSlice<T> {}
unsafe impl<T: Send + Sync> Sync for MapSlice<T> {}

impl<T> MapSlice<T> {
    /// Forward an access-pattern hint for exactly this view's bytes
    /// (see [`MmapFile::advise`] for the no-op and alignment rules).
    pub fn advise(&self, advice: MapAdvice) {
        // `ptr` was constructed as `owner.ptr.add(byte_off)`, so the
        // subtraction recovers the section offset.
        let byte_off = self.ptr as usize - self._owner.ptr as usize;
        self._owner.advise(byte_off, self.len * std::mem::size_of::<T>(), advice);
    }
}

impl<T> Deref for MapSlice<T> {
    type Target = [T];

    #[inline]
    fn deref(&self) -> &[T] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl<T> Clone for MapSlice<T> {
    fn clone(&self) -> Self {
        Self { ptr: self.ptr, len: self.len, _owner: Arc::clone(&self._owner) }
    }
}

impl<T> fmt::Debug for MapSlice<T> {
    // Deliberately not printing elements: Debug on a mapped index must
    // not page in gigabytes of postings.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("MapSlice").field("len", &self.len).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("alsh-storage-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn map_and_slice_roundtrip() {
        let path = tmp("map_roundtrip.bin");
        let vals: Vec<u64> = (0..32).map(|i| i * 3 + 1).collect();
        let mut bytes = Vec::new();
        for v in &vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        for open in [MmapFile::map(&path).unwrap(), MmapFile::read_aligned(&path).unwrap()] {
            let s: MapSlice<u64> = map_slice(&open, 0, bytes.len(), "vals").unwrap();
            assert_eq!(&*s, vals.as_slice());
            // Offset view (8-byte aligned).
            let tail: MapSlice<u64> = map_slice(&open, 16, bytes.len() - 16, "tail").unwrap();
            assert_eq!(&*tail, &vals[2..]);
            // The view keeps the mapping alive after the Arc drops.
            drop(open);
            assert_eq!(s[31], 31 * 3 + 1);
        }
    }

    #[test]
    fn slice_rejects_bad_geometry() {
        let path = tmp("bad_geometry.bin");
        std::fs::write(&path, vec![0u8; 64]).unwrap();
        let map = MmapFile::map(&path).unwrap();
        // Out of bounds.
        assert!(map_slice::<u64>(&map, 0, 72, "x").is_err());
        assert!(map_slice::<u64>(&map, 64, 8, "x").is_err());
        // Overflowing offset.
        assert!(map_slice::<u64>(&map, usize::MAX - 4, 16, "x").is_err());
        // Length not a multiple of the element size.
        assert!(map_slice::<u64>(&map, 0, 12, "x").is_err());
        // Misaligned offset.
        assert!(map_slice::<u64>(&map, 4, 8, "x").is_err());
        // Empty view at the end is fine.
        assert_eq!(map_slice::<u32>(&map, 64, 0, "x").unwrap().len(), 0);
    }

    #[test]
    fn advise_never_fails_on_either_backing() {
        let path = tmp("advise.bin");
        std::fs::write(&path, vec![7u8; 4096]).unwrap();
        for open in [MmapFile::map(&path).unwrap(), MmapFile::read_aligned(&path).unwrap()] {
            open.advise(0, 4096, MapAdvice::WillNeed);
            open.advise(100, 8, MapAdvice::Random);
            // Past the end: silently ignored, it's only a hint.
            open.advise(4096, 1, MapAdvice::Random);
            let s: MapSlice<u32> = map_slice(&open, 64, 128, "x").unwrap();
            s.advise(MapAdvice::Random);
            s.advise(MapAdvice::WillNeed);
            assert_eq!(s[0], 0x0707_0707);
        }
    }

    #[test]
    fn empty_file_rejected() {
        let path = tmp("empty.bin");
        std::fs::write(&path, b"").unwrap();
        assert!(MmapFile::map(&path).is_err());
        assert!(MmapFile::read_aligned(&path).is_err());
    }
}

//! Index persistence: save/load built indexes to a compact binary file,
//! so a service restart skips the (re)build.
//!
//! Format v3 adds an index-kind discriminator so one container format
//! carries both layouts: the flat [`AlshIndex`] (kind 0, body identical
//! to v2) and the norm-range banded [`NormRangeIndex`] (kind 1: shared
//! families once, then per band its scale, norm range, sorted global-id
//! map, and L frozen CSR tables over band-local ids). v2 files (flat,
//! no kind field) still load. There is deliberately no v1 (HashMap
//! bucket dump) read path: no shipping build ever produced a v1 file.
//!
//! Tables are serialized in their frozen CSR form (sorted keys + offsets
//! + contiguous postings), so loading is a straight read into the
//! serve-side layout. The fast-load reader decodes every array in one
//! streaming pass through a single reused 64 KiB chunk buffer into
//! exact-capacity destination `Vec`s: no per-table byte-array
//! intermediates, no reallocation.
//!
//! ```text
//! magic "ALSH" | version u32 (3) | kind u32 (0 flat, 1 banded)
//! flat body (== the v2 body, which had no kind field):
//!   params (m, u, r, K, L) | scale (u, factor, max_norm)
//!   | dim u64 | n_items u64 | items_flat f32[n*dim]
//!   | L × family { dp u64, k u64, r f32, a f32[k*dp], b f32[k] }
//!   | L × table { n_buckets u64, n_postings u64, keys u64[n_buckets],
//!                 offsets u32[n_buckets+1], postings u32[n_postings] }
//! banded body:
//!   params | n_bands u64 | dim u64 | n_items u64 | items_flat f32[n*dim]
//!   | L × family
//!   | B × band { scale (u, factor, max_norm), min_norm f32, max_norm f32,
//!                band_len u64, ids u32[band_len], L × table }
//! ```
//!
//! No external serialization crates exist in this environment (DESIGN.md
//! §5b), so the codec is hand-rolled with explicit versioning and
//! corruption checks (CSR and band-partition invariants are revalidated
//! on load).

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::any::AnyIndex;
use super::banded::{Band, BandedParams, NormRangeIndex};
use super::core::{AlshIndex, AlshParams};
use super::frozen::FrozenTable;
use crate::lsh::L2LshFamily;
use crate::transform::UScale;

const MAGIC: &[u8; 4] = b"ALSH";
const VERSION: u32 = 3;
/// Last version without the kind field (flat body starts right after the
/// version word).
const VERSION_FLAT_ONLY: u32 = 2;
const KIND_FLAT: u32 = 0;
const KIND_BANDED: u32 = 1;

struct Writer<W: Write> {
    w: W,
}

impl<W: Write> Writer<W> {
    fn u32(&mut self, v: u32) -> std::io::Result<()> {
        self.w.write_all(&v.to_le_bytes())
    }
    fn u64(&mut self, v: u64) -> std::io::Result<()> {
        self.w.write_all(&v.to_le_bytes())
    }
    fn f32(&mut self, v: f32) -> std::io::Result<()> {
        self.w.write_all(&v.to_le_bytes())
    }
    fn f32s(&mut self, vs: &[f32]) -> std::io::Result<()> {
        for v in vs {
            self.f32(*v)?;
        }
        Ok(())
    }
    fn u32s(&mut self, vs: &[u32]) -> std::io::Result<()> {
        for v in vs {
            self.u32(*v)?;
        }
        Ok(())
    }
    fn u64s(&mut self, vs: &[u64]) -> std::io::Result<()> {
        for v in vs {
            self.u64(*v)?;
        }
        Ok(())
    }

    fn params(&mut self, p: &AlshParams) -> std::io::Result<()> {
        self.u64(p.m as u64)?;
        self.f32(p.u)?;
        self.f32(p.r)?;
        self.u64(p.k_per_table as u64)?;
        self.u64(p.n_tables as u64)
    }

    fn scale(&mut self, s: &UScale) -> std::io::Result<()> {
        self.f32(s.u)?;
        self.f32(s.factor)?;
        self.f32(s.max_norm)
    }

    fn families(&mut self, families: &[L2LshFamily]) -> std::io::Result<()> {
        for fam in families {
            self.u64(fam.dim() as u64)?;
            self.u64(fam.k() as u64)?;
            self.f32(fam.r())?;
            self.f32s(&fam.a_scaled_raw())?;
            self.f32s(fam.b_vector())?;
        }
        Ok(())
    }

    fn tables(&mut self, tables: &[FrozenTable]) -> std::io::Result<()> {
        for t in tables {
            self.u64(t.n_buckets() as u64)?;
            self.u64(t.n_postings() as u64)?;
            self.u64s(t.keys())?;
            self.u32s(t.offsets())?;
            self.u32s(t.postings())?;
        }
        Ok(())
    }
}

/// Fixed decode-chunk size: every array in the file streams through one
/// reused buffer of this many bytes, so loading a multi-GB index never
/// allocates per-table intermediates (fast-load path). Must be a multiple
/// of 8 so u64 reads never split an element across chunks.
const READ_CHUNK: usize = 64 * 1024;

/// Define a `fn $name(&mut self, n: usize) -> Result<Vec<$ty>>` on
/// `Reader` decoding `n` little-endian elements of byte width `$w` via the
/// shared chunk buffer — the single definition of the streaming decode
/// loop (`READ_CHUNK` is a multiple of every `$w`, so elements never split
/// across chunks).
macro_rules! read_array {
    ($name:ident, $ty:ty, $w:expr) => {
        fn $name(&mut self, n: usize) -> anyhow::Result<Vec<$ty>> {
            let mut out: Vec<$ty> = Vec::with_capacity(n);
            let mut left = n * $w;
            while left > 0 {
                let take = left.min(READ_CHUNK);
                self.r.read_exact(&mut self.buf[..take])?;
                for chunk in self.buf[..take].chunks_exact($w) {
                    out.push(<$ty>::from_le_bytes(chunk.try_into().unwrap()));
                }
                left -= take;
            }
            Ok(out)
        }
    };
}

struct Reader<R: Read> {
    r: R,
    /// Reusable decode buffer — the load's only transient allocation.
    buf: Vec<u8>,
}

impl<R: Read> Reader<R> {
    fn new(r: R) -> Self {
        Self { r, buf: vec![0u8; READ_CHUNK] }
    }
    fn u32(&mut self) -> anyhow::Result<u32> {
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    fn u64(&mut self) -> anyhow::Result<u64> {
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn len(&mut self, cap: u64, what: &str) -> anyhow::Result<usize> {
        let v = self.u64()?;
        anyhow::ensure!(v <= cap, "corrupt index file: {what} = {v} exceeds sanity cap {cap}");
        Ok(v as usize)
    }
    fn f32(&mut self) -> anyhow::Result<f32> {
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        Ok(f32::from_le_bytes(b))
    }
    // Array decoders: `n` elements into a fresh exact-capacity Vec in one
    // streaming pass through the chunk buffer (no `n`-sized byte
    // intermediate). One definition of the chunking rule for all widths.
    read_array!(f32s, f32, 4);
    read_array!(u32s, u32, 4);
    read_array!(u64s, u64, 8);

    fn params(&mut self) -> anyhow::Result<AlshParams> {
        Ok(AlshParams {
            m: self.len(64, "m")?,
            u: self.f32()?,
            r: self.f32()?,
            k_per_table: self.len(1 << 20, "k_per_table")?,
            n_tables: self.len(1 << 20, "n_tables")?,
        })
    }

    fn scale(&mut self) -> anyhow::Result<UScale> {
        Ok(UScale { u: self.f32()?, factor: self.f32()?, max_norm: self.f32()? })
    }

    fn families(&mut self, params: &AlshParams, dim: usize) -> anyhow::Result<Vec<L2LshFamily>> {
        let mut families = Vec::with_capacity(params.n_tables);
        for _ in 0..params.n_tables {
            let fdim = self.len(1 << 24, "family dim")?;
            let fk = self.len(1 << 20, "family k")?;
            anyhow::ensure!(
                fdim == dim + params.m && fk == params.k_per_table,
                "corrupt index file: family shape mismatch"
            );
            let fr = self.f32()?;
            let a = self.f32s(fk * fdim)?;
            let b = self.f32s(fk)?;
            families.push(L2LshFamily::from_raw(fdim, fk, fr, a, b));
        }
        Ok(families)
    }

    /// `n_tables` frozen tables whose postings ids must be `< max_id`
    /// (global n_items for flat, band length for a band).
    fn tables(&mut self, n_tables: usize, max_id: u32) -> anyhow::Result<Vec<FrozenTable>> {
        let mut tables = Vec::with_capacity(n_tables);
        for _ in 0..n_tables {
            // Every bucket is non-empty, so buckets <= postings <= items.
            let n_buckets = self.len(max_id as u64, "n_buckets")?;
            let n_postings = self.len(max_id as u64, "n_postings")?;
            let keys = self.u64s(n_buckets)?;
            let offsets = self.u32s(n_buckets + 1)?;
            let postings = self.u32s(n_postings)?;
            tables.push(FrozenTable::from_parts(keys, offsets, postings, max_id)?);
        }
        Ok(tables)
    }
}

fn write_flat_body<W: Write>(w: &mut Writer<W>, idx: &AlshIndex) -> std::io::Result<()> {
    w.params(idx.params())?;
    w.scale(idx.scale())?;
    w.u64(idx.dim() as u64)?;
    w.u64(idx.n_items() as u64)?;
    for id in 0..idx.n_items() as u32 {
        w.f32s(idx.item(id))?;
    }
    w.families(idx.families())?;
    w.tables(idx.tables())
}

fn read_flat_body<R: Read>(r: &mut Reader<R>) -> anyhow::Result<AlshIndex> {
    let params = r.params()?;
    let scale = r.scale()?;
    let dim = r.len(1 << 24, "dim")?;
    // Item ids are u32 throughout, so n_items is capped accordingly.
    let n_items = r.len(u32::MAX as u64, "n_items")?;
    let items_flat = r.f32s(n_items * dim)?;
    let families = r.families(&params, dim)?;
    let tables = r.tables(params.n_tables, n_items as u32)?;
    Ok(AlshIndex::from_parts(params, scale, families, tables, items_flat, dim, n_items))
}

fn write_banded_body<W: Write>(w: &mut Writer<W>, idx: &NormRangeIndex) -> std::io::Result<()> {
    w.params(idx.params())?;
    w.u64(idx.n_bands() as u64)?;
    w.u64(idx.dim() as u64)?;
    w.u64(idx.n_items() as u64)?;
    for id in 0..idx.n_items() as u32 {
        w.f32s(idx.item(id))?;
    }
    w.families(idx.families())?;
    for band in idx.bands() {
        w.scale(band.scale())?;
        let (min_norm, max_norm) = band.norm_range();
        w.f32(min_norm)?;
        w.f32(max_norm)?;
        w.u64(band.n_items() as u64)?;
        w.u32s(band.ids())?;
        w.tables(band.tables())?;
    }
    Ok(())
}

fn read_banded_body<R: Read>(r: &mut Reader<R>) -> anyhow::Result<NormRangeIndex> {
    let params = r.params()?;
    let n_bands = r.len(u32::MAX as u64, "n_bands")?;
    anyhow::ensure!(n_bands >= 1, "corrupt index file: zero bands");
    let dim = r.len(1 << 24, "dim")?;
    let n_items = r.len(u32::MAX as u64, "n_items")?;
    anyhow::ensure!(
        n_bands <= n_items,
        "corrupt index file: {n_bands} bands for {n_items} items"
    );
    let items_flat = r.f32s(n_items * dim)?;
    let families = r.families(&params, dim)?;
    let mut bands = Vec::with_capacity(n_bands);
    for _ in 0..n_bands {
        let scale = r.scale()?;
        let min_norm = r.f32()?;
        let max_norm = r.f32()?;
        let band_len = r.len(n_items as u64, "band_len")?;
        let ids = r.u32s(band_len)?;
        let tables = r.tables(params.n_tables, band_len as u32)?;
        bands.push(Band { scale, min_norm, max_norm, ids, tables });
    }
    NormRangeIndex::from_parts(
        params,
        BandedParams { n_bands },
        families,
        bands,
        items_flat,
        dim,
        n_items,
    )
}

/// Open `path`, check magic/version/kind, and decode whichever index kind
/// the file holds (rejecting trailing garbage). When `want_kind` is set,
/// a kind mismatch is rejected right after the 12-byte header — the
/// wrong-kind body (potentially gigabytes of items and tables) is never
/// decoded.
fn load_file(path: &Path, want_kind: Option<u32>) -> anyhow::Result<AnyIndex> {
    let file = std::fs::File::open(path)?;
    let mut r = Reader::new(BufReader::new(file));
    let mut magic = [0u8; 4];
    r.r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not an ALSH index file");
    let version = r.u32()?;
    let kind = match version {
        // v2 files predate the kind field and are always flat.
        VERSION_FLAT_ONLY => KIND_FLAT,
        VERSION => {
            let k = r.u32()?;
            anyhow::ensure!(
                k == KIND_FLAT || k == KIND_BANDED,
                "unknown index kind {k} (this build knows 0=flat, 1=banded)"
            );
            k
        }
        other => anyhow::bail!(
            "unsupported index version {other} (this build reads v{VERSION_FLAT_ONLY} and v{VERSION})"
        ),
    };
    if let Some(want) = want_kind {
        if want != kind {
            if kind == KIND_BANDED {
                anyhow::bail!(
                    "index file holds a banded (norm-range) index; load it with \
                     NormRangeIndex::load or index::persist::load_any"
                );
            }
            anyhow::bail!(
                "index file holds a flat index; load it with AlshIndex::load \
                 or index::persist::load_any"
            );
        }
    }
    let index = if kind == KIND_FLAT {
        AnyIndex::Flat(read_flat_body(&mut r)?)
    } else {
        AnyIndex::Banded(read_banded_body(&mut r)?)
    };
    // Reject trailing garbage.
    let mut extra = [0u8; 1];
    anyhow::ensure!(
        r.r.read(&mut extra)? == 0,
        "corrupt index file: trailing bytes"
    );
    Ok(index)
}

/// Load whichever index kind `path` holds (flat v2/v3 or banded v3).
pub fn load_any(path: impl AsRef<Path>) -> crate::Result<AnyIndex> {
    load_file(path.as_ref(), None)
}

impl AlshIndex {
    /// Serialize the index to `path` (v3, kind flat).
    pub fn save(&self, path: impl AsRef<Path>) -> crate::Result<()> {
        let file = std::fs::File::create(path.as_ref())?;
        let mut w = Writer { w: BufWriter::new(file) };
        w.w.write_all(MAGIC)?;
        w.u32(VERSION)?;
        w.u32(KIND_FLAT)?;
        write_flat_body(&mut w, self)?;
        w.w.flush()?;
        Ok(())
    }

    /// Load a **flat** index previously written by [`AlshIndex::save`]
    /// (v3 kind 0, or a legacy v2 file). A banded file is rejected from
    /// its header (before any body is decoded) with a pointer to
    /// [`NormRangeIndex::load`]; use
    /// [`load_any`](super::persist::load_any) when the kind is unknown.
    pub fn load(path: impl AsRef<Path>) -> crate::Result<Self> {
        match load_file(path.as_ref(), Some(KIND_FLAT))? {
            AnyIndex::Flat(index) => Ok(index),
            AnyIndex::Banded(_) => unreachable!("load_file verified the kind"),
        }
    }
}

impl NormRangeIndex {
    /// Serialize the banded index to `path` (v3, kind banded).
    pub fn save(&self, path: impl AsRef<Path>) -> crate::Result<()> {
        let file = std::fs::File::create(path.as_ref())?;
        let mut w = Writer { w: BufWriter::new(file) };
        w.w.write_all(MAGIC)?;
        w.u32(VERSION)?;
        w.u32(KIND_BANDED)?;
        write_banded_body(&mut w, self)?;
        w.w.flush()?;
        Ok(())
    }

    /// Load a **banded** index previously written by
    /// [`NormRangeIndex::save`]. A flat file is rejected from its header
    /// (before any body is decoded) with a pointer to
    /// [`AlshIndex::load`]; use [`load_any`](super::persist::load_any)
    /// when the kind is unknown.
    pub fn load(path: impl AsRef<Path>) -> crate::Result<Self> {
        match load_file(path.as_ref(), Some(KIND_BANDED))? {
            AnyIndex::Banded(index) => Ok(index),
            AnyIndex::Flat(_) => unreachable!("load_file verified the kind"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::banded::BandedParams;
    use crate::util::Rng;

    fn items(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n)
            .map(|_| (0..d).map(|_| rng.normal_f32() * 0.5).collect())
            .collect()
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("alsh-persist-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    /// Byte-surgery a v3 **flat** file down to the exact v2 layout: drop
    /// the 4-byte kind field and stamp version 2 (the v2 body is
    /// identical to the v3 flat body).
    fn to_v2_bytes(v3_flat: &[u8]) -> Vec<u8> {
        assert_eq!(&v3_flat[..4], b"ALSH");
        assert_eq!(u32::from_le_bytes(v3_flat[4..8].try_into().unwrap()), 3);
        assert_eq!(u32::from_le_bytes(v3_flat[8..12].try_into().unwrap()), 0);
        let mut out = Vec::with_capacity(v3_flat.len() - 4);
        out.extend_from_slice(&v3_flat[..4]);
        out.extend_from_slice(&2u32.to_le_bytes());
        out.extend_from_slice(&v3_flat[12..]);
        out
    }

    #[test]
    fn roundtrip_preserves_queries() {
        let its = items(300, 12, 1);
        let idx = AlshIndex::build(&its, AlshParams::default(), 2);
        let path = tmp("roundtrip.alsh");
        idx.save(&path).unwrap();
        let loaded = AlshIndex::load(&path).unwrap();
        assert_eq!(loaded.n_items(), idx.n_items());
        assert_eq!(loaded.dim(), idx.dim());
        let mut rng = Rng::seed_from_u64(3);
        for _ in 0..20 {
            let q: Vec<f32> = (0..12).map(|_| rng.normal_f32()).collect();
            assert_eq!(idx.query(&q, 10), loaded.query(&q, 10));
            // Candidate sets identical, including order (frozen CSR
            // round-trips the exact probe stream).
            assert_eq!(idx.candidates(&q), loaded.candidates(&q));
            assert_eq!(
                idx.candidates_multiprobe(&q, 4),
                loaded.candidates_multiprobe(&q, 4)
            );
        }
    }

    /// Fast-load roundtrip at realistic scale (≥10k items): the chunked
    /// one-pass reader must reproduce the index exactly — table stats,
    /// candidate streams, and query results.
    #[test]
    fn roundtrip_10k_items_fast_load() {
        let its = items(10_000, 12, 20);
        let idx = AlshIndex::build(&its, AlshParams::default(), 21);
        let path = tmp("roundtrip10k.alsh");
        idx.save(&path).unwrap();
        let loaded = AlshIndex::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded.n_items(), 10_000);
        assert_eq!(idx.table_stats(), loaded.table_stats());
        for (a, b) in idx.tables().iter().zip(loaded.tables()) {
            assert_eq!(a.keys(), b.keys());
            assert_eq!(a.offsets(), b.offsets());
            assert_eq!(a.postings(), b.postings());
        }
        let mut rng = Rng::seed_from_u64(22);
        for _ in 0..10 {
            let q: Vec<f32> = (0..12).map(|_| rng.normal_f32()).collect();
            assert_eq!(idx.candidates(&q), loaded.candidates(&q));
            assert_eq!(idx.query(&q, 10), loaded.query(&q, 10));
        }
    }

    #[test]
    fn roundtrip_preserves_table_stats() {
        let its = items(200, 8, 10);
        let idx = AlshIndex::build(&its, AlshParams::default(), 11);
        let path = tmp("stats.alsh");
        idx.save(&path).unwrap();
        let loaded = AlshIndex::load(&path).unwrap();
        assert_eq!(idx.table_stats(), loaded.table_stats());
    }

    #[test]
    fn banded_roundtrip_preserves_everything() {
        // Norm spread so the bands are meaningfully different.
        let mut rng = Rng::seed_from_u64(30);
        let its: Vec<Vec<f32>> = (0..500)
            .map(|_| {
                let s = 0.1 + 2.0 * rng.f32();
                (0..10).map(|_| rng.normal_f32() * s).collect()
            })
            .collect();
        let idx = NormRangeIndex::build(
            &its,
            AlshParams::default(),
            BandedParams { n_bands: 4 },
            31,
        );
        let path = tmp("banded_roundtrip.alsh");
        idx.save(&path).unwrap();
        let loaded = NormRangeIndex::load(&path).unwrap();
        assert_eq!(loaded.n_items(), idx.n_items());
        assert_eq!(loaded.n_bands(), 4);
        assert_eq!(idx.table_stats(), loaded.table_stats());
        assert_eq!(idx.band_table_stats(), loaded.band_table_stats());
        for (a, b) in idx.bands().iter().zip(loaded.bands()) {
            assert_eq!(a.ids(), b.ids());
            assert_eq!(a.norm_range(), b.norm_range());
            assert_eq!(a.scale().factor, b.scale().factor);
            for (ta, tb) in a.tables().iter().zip(b.tables()) {
                assert_eq!(ta.keys(), tb.keys());
                assert_eq!(ta.offsets(), tb.offsets());
                assert_eq!(ta.postings(), tb.postings());
            }
        }
        for _ in 0..15 {
            let q: Vec<f32> = (0..10).map(|_| rng.normal_f32()).collect();
            assert_eq!(idx.candidates(&q), loaded.candidates(&q));
            assert_eq!(idx.query(&q, 10), loaded.query(&q, 10));
            assert_eq!(
                idx.candidates_multiprobe(&q, 4),
                loaded.candidates_multiprobe(&q, 4)
            );
        }
        // load_any agrees on the kind.
        let any = load_any(&path).unwrap();
        assert!(any.as_banded().is_some());
        assert_eq!(any.table_stats(), idx.table_stats());
    }

    #[test]
    fn legacy_v2_flat_file_still_loads() {
        let its = items(120, 8, 40);
        let idx = AlshIndex::build(&its, AlshParams::default(), 41);
        let path = tmp("v2_legacy.alsh");
        idx.save(&path).unwrap();
        let v2 = to_v2_bytes(&std::fs::read(&path).unwrap());
        std::fs::write(&path, &v2).unwrap();
        let loaded = AlshIndex::load(&path).unwrap();
        let mut rng = Rng::seed_from_u64(42);
        for _ in 0..10 {
            let q: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
            assert_eq!(idx.query(&q, 10), loaded.query(&q, 10));
            assert_eq!(idx.candidates(&q), loaded.candidates(&q));
        }
        // load_any reads v2 too, as a flat index.
        assert!(load_any(&path).unwrap().as_flat().is_some());
    }

    #[test]
    fn flat_reader_rejects_banded_file_with_clear_error() {
        let its = items(60, 6, 50);
        let idx = NormRangeIndex::build(
            &its,
            AlshParams::default(),
            BandedParams { n_bands: 2 },
            51,
        );
        let path = tmp("kind_banded.alsh");
        idx.save(&path).unwrap();
        let err = AlshIndex::load(&path).err().expect("should fail");
        let msg = format!("{err:#}");
        assert!(msg.contains("banded"), "unhelpful error: {msg}");
    }

    #[test]
    fn banded_reader_rejects_flat_file_with_clear_error() {
        let its = items(60, 6, 52);
        let idx = AlshIndex::build(&its, AlshParams::default(), 53);
        let path = tmp("kind_flat.alsh");
        idx.save(&path).unwrap();
        let err = NormRangeIndex::load(&path).err().expect("should fail");
        let msg = format!("{err:#}");
        assert!(msg.contains("flat"), "unhelpful error: {msg}");
    }

    /// A v3 banded file whose version word is stamped v2 is what a v2
    /// reader would have seen: the banded body misparses as a flat body
    /// and must die on the sanity caps, not load garbage.
    #[test]
    fn v3_banded_bytes_with_v2_version_fail_clearly() {
        let its = items(40, 6, 54);
        let idx = NormRangeIndex::build(
            &its,
            AlshParams::default(),
            BandedParams { n_bands: 2 },
            55,
        );
        let path = tmp("banded_as_v2.alsh");
        idx.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4..8].copy_from_slice(&2u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = AlshIndex::load(&path).err().expect("should fail");
        assert!(format!("{err:#}").contains("corrupt"), "got: {err:#}");
    }

    /// The reverse: a genuine v2 file whose version word is stamped v3
    /// makes the reader parse the flat body's first field as a kind and
    /// must fail with the unknown-kind error.
    #[test]
    fn v2_bytes_with_v3_version_fail_clearly() {
        let its = items(40, 6, 56);
        let idx = AlshIndex::build(&its, AlshParams::default(), 57);
        let path = tmp("v2_as_v3.alsh");
        idx.save(&path).unwrap();
        let mut v2 = to_v2_bytes(&std::fs::read(&path).unwrap());
        v2[4..8].copy_from_slice(&3u32.to_le_bytes());
        std::fs::write(&path, &v2).unwrap();
        let err = AlshIndex::load(&path).err().expect("should fail");
        // The v2 body starts with m = 3 (the default), which reads as
        // kind 3 — unknown.
        assert!(format!("{err:#}").contains("unknown index kind"), "got: {err:#}");
    }

    #[test]
    fn rejects_unknown_kind() {
        let its = items(20, 4, 58);
        let idx = AlshIndex::build(&its, AlshParams::default(), 59);
        let path = tmp("bad_kind.alsh");
        idx.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[8..12].copy_from_slice(&7u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load_any(&path).err().expect("should fail");
        assert!(format!("{err:#}").contains("unknown index kind"), "got: {err:#}");
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("bad_magic.alsh");
        std::fs::write(&path, b"NOPE....").unwrap();
        let err = AlshIndex::load(&path).err().expect("should fail");
        assert!(format!("{err:#}").contains("not an ALSH index"));
    }

    #[test]
    fn rejects_truncation() {
        let its = items(50, 6, 4);
        let idx = AlshIndex::build(&its, AlshParams::default(), 5);
        let path = tmp("trunc.alsh");
        idx.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(AlshIndex::load(&path).is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let its = items(20, 4, 6);
        let idx = AlshIndex::build(&its, AlshParams::default(), 7);
        let path = tmp("trail.alsh");
        idx.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"junk");
        std::fs::write(&path, &bytes).unwrap();
        let err = AlshIndex::load(&path).err().expect("should fail");
        assert!(format!("{err:#}").contains("trailing"));
    }

    #[test]
    fn rejects_wrong_version() {
        let its = items(20, 4, 8);
        let idx = AlshIndex::build(&its, AlshParams::default(), 9);
        let path = tmp("version.alsh");
        idx.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4] = 99; // version field
        std::fs::write(&path, &bytes).unwrap();
        let err = AlshIndex::load(&path).err().expect("should fail");
        assert!(format!("{err:#}").contains("version"));
    }

    #[test]
    fn rejects_corrupted_table_section() {
        let its = items(40, 4, 12);
        let idx = AlshIndex::build(&its, AlshParams::default(), 13);
        let path = tmp("csr_corrupt.alsh");
        idx.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Smash the last 4 bytes (inside the final table's postings) with
        // an out-of-range id; the CSR validator must reject it.
        let n = bytes.len();
        bytes[n - 4..].copy_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = AlshIndex::load(&path).err().expect("should fail");
        assert!(format!("{err:#}").contains("corrupt"), "got: {err:#}");
    }

    #[test]
    fn rejects_corrupted_band_partition() {
        let its = items(50, 4, 60);
        let idx = NormRangeIndex::build(
            &its,
            AlshParams::default(),
            BandedParams { n_bands: 2 },
            61,
        );
        let path = tmp("band_corrupt.alsh");
        idx.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Truncating inside the final band's tables must be caught (the
        // reader hits EOF before the partition validates).
        std::fs::write(&path, &bytes[..bytes.len() - 8]).unwrap();
        assert!(NormRangeIndex::load(&path).is_err());
    }
}
